"""Quickstart: build a SQUASH index and run hybrid (filtered) queries.

    PYTHONPATH=src python examples/quickstart.py

Builds the full OSQ pipeline (partitions → KLT → non-uniform bits → segment
packing → low-bit index → quantized attributes) on a SIFT-like synthetic
dataset, then answers attribute-filtered top-10 queries and reports recall
against exact brute force.
"""

import numpy as np

from repro.core.attributes import Predicate
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data.synthetic import (default_predicates, ground_truth,
                                  make_vector_dataset)


def main():
    print("building dataset (SIFT-like, 20k × 128d, 4 attributes)...")
    ds = make_vector_dataset("sift1m", scale=0.02, num_queries=20)

    print("building SQUASH index (10 partitions, b = 4d, S = 8)...")
    idx = SquashIndex.build(ds.vectors, ds.attributes,
                            SquashConfig(num_partitions=10))
    sizes = idx.index_bytes()
    full = sizes.pop("full_precision")
    print(f"  index: {sum(sizes.values()) / 1e6:.1f} MB quantized "
          f"(vs {full / 1e6:.1f} MB full precision)")

    # Hybrid query: "attr0 in [0, 2] AND attr1 < 6 AND attr2 >= 3"
    preds = [Predicate(attr=0, op="B", lo=0, hi=2),
             Predicate(attr=1, op="<", lo=6),
             Predicate(attr=2, op=">=", lo=3)]
    ids, dists, stats = idx.search(ds.queries, preds, k=10)
    gt_ids, _ = ground_truth(ds, preds, k=10)
    hits = sum(len(set(ids[i]) & set(gt_ids[i])) for i in range(len(ids)))
    print(f"  recall@10 = {hits / gt_ids.size:.3f}  "
          f"({stats.partitions_visited / stats.queries:.1f} partitions/query, "
          f"{stats.hamming_kept / max(stats.hamming_in, 1):.0%} kept "
          f"after Hamming prune)")

    # The §5.1 benchmark predicates (~8 % joint selectivity).
    preds = default_predicates(ds.attr_cardinality)
    ids, _, _ = idx.search(ds.queries, preds, k=10)
    gt_ids, _ = ground_truth(ds, preds, k=10)
    hits = sum(len(set(ids[i]) & set(gt_ids[i])) for i in range(len(ids)))
    print(f"  paper-benchmark predicates: recall@10 = {hits / gt_ids.size:.3f}")


if __name__ == "__main__":
    main()
