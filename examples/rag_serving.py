"""RAG-style serving: LM embeddings → SQUASH hybrid retrieval → generation.

    PYTHONPATH=src python examples/rag_serving.py

The integration showcase (DESIGN.md §5.i–ii): a small decoder LM (reduced
qwen2-vl text path) produces document embeddings from its final hidden
state; SQUASH indexes them with attributes; queries retrieve filtered
neighbors; the LM then "generates" continuations with batched requests
through the serving engine — including the OSQ-quantized KV cache option
(the paper's quantization technique applied to the serving substrate).
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.attributes import Predicate
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.models import layers as L
from repro.models import transformer as T
from repro.serve import Engine, ServeConfig, cache_bytes, quantize_caches

N_DOCS, DOC_LEN, K = 512, 24, 5
GEN_LEN = 24


def embed_documents(params, cfg, tokens):
    """Mean-pooled final hidden state (pre-logits) as the doc embedding."""
    # reuse forward pieces: embed → blocks → final norm
    x = L.embed(params["embed"], tokens)
    b, s = x.shape[:2]
    positions = T.make_positions(b, s)

    def body(carry, lp):
        y, _ = T.block_train(lp, carry, positions, cfg)
        return y, None
    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return np.asarray(x.mean(axis=1), dtype=np.float32)


def main():
    cfg = get_config("phi4-mini-3.8b").reduced(vocab_size=1024, d_model=128,
                                               num_layers=2)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    print(f"embedding {N_DOCS} documents with the LM...")
    docs = rng.integers(0, cfg.vocab_size, (N_DOCS, DOC_LEN), dtype=np.int32)
    embs = embed_documents(params, cfg, jnp.asarray(docs))

    print("indexing embeddings + attributes with SQUASH...")
    attrs = rng.integers(0, 16, (N_DOCS, 4)).astype(np.float64)
    idx = SquashIndex.build(embs, attrs, SquashConfig(
        num_partitions=4, min_hamming_keep=32))

    print("hybrid retrieval (category < 8, freshness >= 4)...")
    preds = [Predicate(attr=0, op="<", lo=8), Predicate(attr=1, op=">=", lo=4)]
    queries = embs[:4] + rng.normal(0, 0.01, (4, embs.shape[1])).astype(
        np.float32)
    ids, dists, _ = idx.search(queries, preds, k=K)
    print(f"  retrieved ids: {ids[:, :3].tolist()}")

    print("generating with retrieved context (batched serving)...")
    prompts = np.stack([
        np.concatenate([docs[i][:8] for i in ids_row[:2]])
        for ids_row in ids])
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=GEN_LEN))
    out = eng.generate(prompts)
    print(f"  generated {out.shape} tokens")

    # OSQ-quantized KV: same outputs at 4x less cache traffic.
    eng_q = Engine(cfg, params, ServeConfig(max_new_tokens=GEN_LEN, kv_bits=8))
    out_q = eng_q.generate(prompts)
    _, caches = T.prefill(params, jnp.asarray(prompts), cfg,
                          buf_len=prompts.shape[1] + GEN_LEN)
    qc, meta = quantize_caches(caches, 8)
    ratio = cache_bytes(caches) / cache_bytes(qc)
    agree = float((out == out_q).mean())
    print(f"  OSQ-KV(8-bit): cache {ratio:.1f}x smaller, "
          f"token agreement {agree:.0%}")
    assert agree >= 0.75


if __name__ == "__main__":
    main()
