"""Train a tiny LM end-to-end with the training substrate.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps N]

Uses the llama3 block wiring at toy scale (~4M params), AdamW + cosine
schedule + grad clipping + grad accumulation, deterministic synthetic data
with a learnable bigram structure so the loss provably drops, and a
checkpoint save/restore round-trip at the end.
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_pytree, save_pytree
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init, cosine_schedule
from repro.train import make_train_step


def make_batch(rng, b, s, vocab):
    """Markov bigram stream: next ≡ (5·tok + 1) mod vocab with 10% noise."""
    first = rng.integers(0, vocab, (b, 1), dtype=np.int32)
    toks = [first]
    for _ in range(s):
        nxt = (5 * toks[-1] + 1) % vocab
        noise = rng.random((b, 1)) < 0.1
        rnd = rng.integers(0, vocab, (b, 1), dtype=np.int32)
        toks.append(np.where(noise, rnd, nxt).astype(np.int32))
    return {"tokens": jnp.asarray(np.concatenate(toks, axis=1))}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = get_config("llama3-8b").reduced(
        num_layers=2, d_model=128, d_ff=256, vocab_size=256)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model})")

    opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.01)
    sched = cosine_schedule(3e-3, warmup=10, total=args.steps)
    state = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, sched, accum_steps=2))

    rng = np.random.default_rng(0)
    losses = []
    t0 = time.time()
    for i in range(args.steps):
        batch = make_batch(rng, b=8, s=64, vocab=cfg.vocab_size)
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"  step {i:4d}  loss {losses[-1]:.3f}  "
                  f"lr {float(m['lr']):.2e}  |g| {float(m['grad_norm']):.2f}")
    dt = time.time() - t0
    print(f"trained {args.steps} steps in {dt:.0f}s "
          f"({8 * 64 * args.steps / dt:.0f} tok/s)")
    assert losses[-1] < losses[0] * 0.7, "loss must drop"

    with tempfile.TemporaryDirectory() as d:
        save_pytree({"params": params, "opt": state}, d)
        restored = restore_pytree({"params": params, "opt": state}, d)
        same = all(bool(jnp.array_equal(a, b)) for a, b in zip(
            jax.tree_util.tree_leaves(params),
            jax.tree_util.tree_leaves(restored["params"])))
        print(f"checkpoint round-trip: {'OK' if same else 'FAILED'}")
        assert same


if __name__ == "__main__":
    main()
