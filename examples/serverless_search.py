"""End-to-end serverless hybrid search driver (the paper's system, executed).

    PYTHONPATH=src python examples/serverless_search.py

Drives batched hybrid queries through the real serverless runtime
(``repro.serverless``): the Coordinator fans out over the Alg. 2 ID-jump
tree, each QueryAllocator runs attribute filtering + Alg. 1 partition
selection (with the §2.5 filter-count guarantee) on its query slice, each
QueryProcessor executes Stages 3–5 of the batched jax data plane on its
partition shard, and results merge back up the tree. Payload bytes are
budgeted against the Lambda 6 MB cap, warm containers reuse their index
singletons (DRE, §3.2), and the run is priced by the §3.5 cost model.

Prints recall, cold/warm makespans, QPS, DRE savings and dollars per 1k
queries — and checks the runtime's ids against the single-host jax plane.
A third pass re-runs the same batch with the §5.6 result cache enabled:
the Coordinator serves every repeated query itself and the fleet below it
never launches, which is where the paper's "retention of relevant data in
re-used runtime containers" cost story lands.
"""

import numpy as np

from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data.synthetic import (default_predicates, ground_truth,
                                  make_vector_dataset)
from repro.serverless import RuntimeConfig, ServerlessRuntime

N_QA_F, N_QA_L = 4, 3          # F=4, l_max=3 → N_QA = 84 (paper sweet spot)


def main():
    ds = make_vector_dataset("sift1m", scale=0.02, num_queries=50)
    preds = default_predicates(ds.attr_cardinality)
    idx = SquashIndex.build(ds.vectors, ds.attributes,
                            SquashConfig(num_partitions=10))

    rt = ServerlessRuntime(idx, RuntimeConfig(
        branching=N_QA_F, max_level=N_QA_L, warm_prob=0.95))
    cold = rt.search(ds.queries, preds, k=10)      # cold fleet
    warm = rt.search(ds.queries, preds, k=10)      # warm containers + DRE

    gt_ids, _ = ground_truth(ds, preds, k=10)
    hits = sum(len(set(warm.ids[i]) & set(gt_ids[i]))
               for i in range(len(warm.ids)))
    recall = hits / gt_ids.size

    # The runtime must agree bit-for-bit with the single-host jax plane.
    ids_ref, _, _ = idx.search(ds.queries, preds, k=10, backend="jax")
    assert np.array_equal(warm.ids, ids_ref), "runtime diverged from jax plane"

    t = warm.trace
    qps = ds.queries.shape[0] / t.makespan_s
    cost_per_1k = t.cost["total"] * 1000 / ds.queries.shape[0]
    print(f"recall@10            = {recall:.3f}")
    print(f"fleet                = 1 CO + {t.invocations('qa')} QA + "
          f"{t.invocations('qp')} QP invocations "
          f"(N_QA={t.invocations('qa')}, F={N_QA_F}, l_max={N_QA_L})")
    print(f"makespan cold → warm = {cold.trace.makespan_s * 1e3:.0f} ms → "
          f"{t.makespan_s * 1e3:.0f} ms "
          f"({ds.queries.shape[0]} queries)")
    print(f"simulated QPS        = {qps:.0f}")
    print(f"DRE                  : {t.dre.s3_gets} S3 GETs for "
          f"{t.dre.invocations} invocations ({t.dre.dre_hits} singleton hits;"
          f" cold wave paid {cold.trace.dre.s3_gets})")
    print(f"payload moved        = {t.payload_bytes / 1e6:.2f} MB "
          f"(≤ {rt.cfg.max_payload_bytes // 2**20} MB per invocation)")
    print(f"escalated visits     = {t.escalations} (§2.5 filter-count "
          f"guarantee)")
    print(f"cost per 1k queries  = ${cost_per_1k:.4f} "
          f"(λ-runtime {t.cost['lambda_runtime'] / t.cost['total']:.0%})")
    assert recall >= 0.9
    assert t.dre.s3_gets < cold.trace.dre.s3_gets

    # §5.6 result cache: same batch twice through a cache-enabled runtime —
    # the repeat pass is served entirely at the Coordinator.
    rt_c = ServerlessRuntime(idx, RuntimeConfig(
        branching=N_QA_F, max_level=N_QA_L, warm_prob=0.95,
        cache_enabled=True))
    rt_c.search(ds.queries, preds, k=10)                 # populate
    cached = rt_c.search(ds.queries, preds, k=10)        # all hits
    tc = cached.trace
    assert np.array_equal(cached.ids, ids_ref), "cached ids diverged"
    assert tc.cache_hits == ds.queries.shape[0]
    print(f"result cache repeat  = {tc.cache_hits}/{ds.queries.shape[0]} "
          f"hits; {len(tc.nodes)} invocation(s) vs {len(t.nodes)}, "
          f"${tc.cost['total'] * 1000 / ds.queries.shape[0]:.6f} per 1k "
          f"(was ${cost_per_1k:.4f}), makespan "
          f"{tc.makespan_s * 1e3:.0f} ms")
    assert tc.cost["total"] < t.cost["total"]

    # Real multi-process transport: the same choreography over long-lived
    # worker processes (one per QP partition + an allocator pool) — payloads
    # cross real process boundaries, QP waves execute concurrently, warm
    # starts are real, and the measured wall-clock sits next to the modeled
    # timeline in the trace.
    rt_p = ServerlessRuntime(idx, RuntimeConfig(
        branching=2, max_level=1, transport="process", qa_workers=2))
    try:
        p_cold = rt_p.search(ds.queries, preds, k=10)
        p_warm = rt_p.search(ds.queries, preds, k=10)
    finally:
        rt_p.close()
    assert np.array_equal(p_warm.ids, ids_ref), "process transport diverged"
    tw = p_warm.trace
    print(f"process transport    = {tw.invocations('qa')} QA + "
          f"{tw.invocations('qp')} QP real invocations; measured "
          f"{p_cold.trace.measured_makespan_s * 1e3:.0f} ms cold → "
          f"{tw.measured_makespan_s * 1e3:.0f} ms warm "
          f"(modeled {tw.makespan_s * 1e3:.0f} ms); "
          f"{tw.dre.dre_hits}/{tw.dre.invocations} pid-keyed warm hits, "
          f"{tw.worker_retries} retries")
    assert tw.dre.s3_gets == 0, "live workers must serve the repeat warm"


if __name__ == "__main__":
    main()
