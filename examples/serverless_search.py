"""End-to-end serverless hybrid search driver (the paper's system, simulated).

    PYTHONPATH=src python examples/serverless_search.py

Drives batched hybrid queries through the full SQUASH runtime:

  Coordinator → tree-based QA invocation (Alg. 2) → per-QA attribute
  filtering + Alg. 1 partition selection → QP shard search on a jax mesh
  (the QP plane: partitions over the 'model' axis, queries over 'data') →
  single-pass top-k merge → DRE warm-container accounting → §3.5 cost model.

Prints recall, simulated serverless latency/QPS, and dollars per 1k queries.
"""

import time

import numpy as np

from repro.core.cost_model import LambdaFleet, squash_query_cost
from repro.core.distributed import distributed_search
from repro.core.dre import ContainerPool
from repro.core.invocation import InvocationSim, tree_size
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data.synthetic import (default_predicates, ground_truth,
                                  make_vector_dataset)

N_QA_F, N_QA_L = 4, 3          # F=4, l_max=3 → N_QA = 84 (paper sweet spot)


def main():
    ds = make_vector_dataset("sift1m", scale=0.02, num_queries=50)
    preds = default_predicates(ds.attr_cardinality)
    idx = SquashIndex.build(ds.vectors, ds.attributes,
                            SquashConfig(num_partitions=10))

    # --- QP plane: mesh-sharded search (1 real device here; the same code
    # lowers onto the 16×16 production mesh in launch/dryrun.py) ----------
    t0 = time.perf_counter()
    ids, dists = distributed_search(idx, ds.queries, preds, k=10)
    t_search = time.perf_counter() - t0
    gt_ids, _ = ground_truth(ds, preds, k=10)
    hits = sum(len(set(ids[i]) & set(gt_ids[i])) for i in range(len(ids)))
    recall = hits / gt_ids.size

    # --- control plane: Alg. 2 invocation + DRE + cost -------------------
    n_qa = tree_size(N_QA_F, N_QA_L)
    sim = InvocationSim(branching=N_QA_F, max_level=N_QA_L, node_compute=0.02)
    t_tree = sim.makespan()
    # one warm pool per QP function (squash-processor-<pid>), as in §3.2
    pools = [ContainerPool(warm_prob=0.95, seed=pid) for pid in range(10)]
    for wave in range(3):                       # 3 successive batches
        for pid, pool in enumerate(pools):
            pool.invoke(f"sift1m/part{pid}", 35_000_000, use_dre=True)
    qps = ds.queries.shape[0] / (t_tree + t_search / 10)  # 10 parallel QPs
    s3_gets = sum(p.stats.s3_gets for p in pools)
    dre_hits = sum(p.stats.dre_hits for p in pools)
    invocations = sum(p.stats.invocations for p in pools)
    fleet = LambdaFleet(n_qa=n_qa, n_qp=10 * 3,
                        t_qa_s=n_qa * 0.3, t_qp_s=30 * t_search / 10,
                        t_co_s=t_tree,
                        s3_gets=s3_gets,
                        efs_read_bytes=int(50 * 2 * 10 * ds.d * 4))
    cost = squash_query_cost(fleet)

    print(f"recall@10           = {recall:.3f}")
    print(f"tree launch (84 QA) = {t_tree * 1e3:.0f} ms")
    print(f"mesh search         = {t_search * 1e3:.0f} ms "
          f"({ds.queries.shape[0]} queries)")
    print(f"simulated QPS       = {qps:.0f}")
    print(f"DRE                 : {s3_gets} S3 GETs for "
          f"{invocations} invocations ({dre_hits} warm-container hits)")
    print(f"cost per 1k queries = ${cost['total'] * 1000 / 50:.4f} "
          f"(λ-runtime {cost['lambda_runtime'] / cost['total']:.0%})")
    assert recall >= 0.9


if __name__ == "__main__":
    main()
