"""Parsed source file + the comment conventions squashlint understands.

Three line-comment conventions carry checker metadata (they are plain
comments, invisible to the runtime):

* ``# guarded-by: <lock>`` on an attribute assignment declares that the
  assigned attribute may only be read/written while ``<lock>`` (an attribute
  of the owning object or its manager — matched by *name*) is held.
* ``# squash: holds[<lock>, ...]`` on a ``def`` line declares a contract:
  every caller of this function already holds the named locks (the checker
  seeds its held-set instead of flagging the body).
* ``# squash: ignore[rule-id, ...] -- <justification>`` suppresses the named
  rules on that line. The justification is **mandatory** — a pragma without
  one is itself a finding (``bad-pragma``), so every suppression in the tree
  records why it is sound.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

__all__ = ["SourceFile", "parse_source"]

_IGNORE_RE = re.compile(
    r"#\s*squash:\s*ignore\[([A-Za-z0-9_,\-\s]*)\]\s*(--\s*(\S.*))?")
_HOLDS_RE = re.compile(r"#\s*squash:\s*holds\[([A-Za-z0-9_,\s]+)\]")
_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)")


class SourceFile:
    """One parsed module: text, AST, and the squashlint comment maps."""

    def __init__(self, rel: str, text: str):
        self.rel = rel                    # repo-relative posix path
        self.text = text
        self.lines = text.splitlines()
        self.tree: Optional[ast.Module] = None
        self.parse_error: Optional[str] = None
        # line → (set of suppressed rule ids, justification or None)
        self.ignores: Dict[int, Tuple[Set[str], Optional[str]]] = {}
        # line → lock names a `def` on that line holds by contract
        self.holds: Dict[int, Set[str]] = {}
        # line → lock name guarding the attribute assigned on that line
        self.guard_lines: Dict[int, str] = {}
        try:
            self.tree = ast.parse(text)
        except SyntaxError as exc:
            self.parse_error = f"{exc.msg} (line {exc.lineno})"
        for i, raw in enumerate(self.lines, start=1):
            if "#" not in raw:
                continue
            m = _IGNORE_RE.search(raw)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                self.ignores[i] = (rules, m.group(3))
            m = _HOLDS_RE.search(raw)
            if m:
                self.holds[i] = {
                    n.strip() for n in m.group(1).split(",") if n.strip()}
            m = _GUARDED_RE.search(raw)
            if m:
                self.guard_lines[i] = m.group(1)

    # -------------------------------------------------------------- helpers

    def guarded_attrs(self) -> Dict[str, Set[str]]:
        """attr name → lock names, from ``# guarded-by:`` assignment lines.

        The attribute name is taken from the AST assignment whose line
        carries the annotation (``self.x = ...`` or ``x: T = ...``), so the
        comment can never drift from a renamed field silently — an
        annotation on a non-assignment line is simply inert.
        """
        out: Dict[str, Set[str]] = {}
        if self.tree is None:
            return out
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                lock = self.guard_lines.get(node.lineno)
                if lock is None:
                    continue
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Attribute):
                        out.setdefault(t.attr, set()).add(lock)
                    elif isinstance(t, ast.Name):
                        out.setdefault(t.id, set()).add(lock)
        return out

    def holds_for_def(self, node: ast.AST) -> Set[str]:
        """Locks a function holds by contract (``# squash: holds[...]``).

        The pragma may sit on any line of the signature (``def`` through the
        line before the first body statement, covering wrapped parameter
        lists) or on the line of any of its decorators.
        """
        last_sig_line = node.lineno
        body = getattr(node, "body", None)
        if body:
            last_sig_line = max(node.lineno, body[0].lineno - 1)
        lines = list(range(node.lineno, last_sig_line + 1))
        for dec in getattr(node, "decorator_list", []):
            lines.append(dec.lineno)
        held: Set[str] = set()
        for ln in lines:
            held |= self.holds.get(ln, set())
        return held


def parse_source(rel: str, text: str) -> SourceFile:
    return SourceFile(rel, text)
