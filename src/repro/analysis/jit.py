"""jit / recompile hygiene checker for the compiled data plane.

Applied to the jitted modules (``core/dataplane.py``, ``core/distributed.py``,
``kernels/``). Four rules:

* ``jit-concretize`` — ``.item()``, ``float(...)`` or ``bool(...)`` on a
  traced value inside a jitted body forces a device sync and breaks under
  abstract tracing. Shape arithmetic is exempt: ``int(x.shape[0])``,
  ``float(len(xs))`` and friends are static at trace time.
* ``jit-mutable-global`` — a jitted body reading a module-level mutable
  numpy array closes over host state the trace bakes in: mutating the
  global later silently diverges from the compiled computation. (Immutable
  ``jnp`` constants are fine — jax arrays cannot be mutated in place.)
* ``jit-static-argnames`` — a ``jax.jit`` application whose target has
  scalar-default parameters (int/bool/str — shape knobs and dispatch flags)
  not named in ``static_argnames``/``static_argnums``: passing them traced
  either fails (shape-determining) or retraces per distinct value without
  the cache keying the caller expects.
* ``jit-per-call`` — an immediately-invoked ``jax.jit(f)(args...)`` builds
  a *fresh* jit wrapper per call, so jax's trace cache (keyed on wrapper
  identity) never hits and every call retraces + recompiles. Hoist the
  ``jax.jit`` or cache the wrapped callable.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.findings import Finding
from repro.analysis.source import SourceFile

__all__ = ["check_jit"]

_NP_NAMES = {"np", "numpy"}
_NP_MUTABLE_CTORS = {"array", "zeros", "ones", "empty", "full", "arange",
                     "zeros_like", "ones_like", "empty_like", "full_like",
                     "linspace", "eye", "tile"}
_SHAPE_ATTRS = {"shape", "ndim", "size"}
_STATIC_DEFAULT_TYPES = (int, bool, str)


def _is_jax_jit(node: ast.AST) -> bool:
    """``jax.jit`` or a bare ``jit`` name."""
    if isinstance(node, ast.Attribute) and node.attr == "jit":
        return isinstance(node.value, ast.Name) and node.value.id == "jax"
    return isinstance(node, ast.Name) and node.id == "jit"


def _is_partial(node: ast.AST) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "partial":
        return True
    return isinstance(node, ast.Name) and node.id == "partial"


def _static_names(call: ast.Call) -> Optional[Set[str]]:
    """Names listed in ``static_argnames=`` (None when not present)."""
    for kw in call.keywords:
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            return {v.value}
        if isinstance(v, (ast.Tuple, ast.List)):
            return {e.value for e in v.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, str)}
        return set()
    return None


def _static_nums(call: ast.Call) -> Optional[List[int]]:
    for kw in call.keywords:
        if kw.arg != "static_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return [v.value]
        if isinstance(v, (ast.Tuple, ast.List)):
            return [e.value for e in v.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, int)]
        return []
    return None


def _jit_application(node: ast.AST) -> Optional[ast.Call]:
    """The jit-configuring Call if ``node`` applies jax.jit, else None.

    Recognizes ``jax.jit``, ``jax.jit(...)`` (bare attribute has no config
    call — a synthetic empty one is returned) and
    ``functools.partial(jax.jit, ...)``.
    """
    if _is_jax_jit(node):
        return ast.Call(func=node, args=[], keywords=[])
    if isinstance(node, ast.Call):
        if _is_jax_jit(node.func):
            return node
        if _is_partial(node.func) and node.args and _is_jax_jit(node.args[0]):
            return node
    return None


def _contains_shape_access(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _SHAPE_ATTRS:
            return True
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                and sub.func.id == "len":
            return True
    return False


class _JitBodyVisitor(ast.NodeVisitor):
    """Concretization + mutable-global checks inside one jitted body."""

    def __init__(self, src: SourceFile, mutable_globals: Set[str],
                 findings: List[Finding]):
        self.src = src
        self.mutable_globals = mutable_globals
        self.findings = findings

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute) and func.attr == "item":
            self.findings.append(Finding(
                self.src.rel, node.lineno, "jit-concretize",
                "`.item()` inside a jitted body forces a device sync / "
                "fails under tracing"))
        elif isinstance(func, ast.Name) and func.id in ("float", "bool") \
                and node.args:
            arg = node.args[0]
            if not (isinstance(arg, ast.Constant)
                    or _contains_shape_access(arg)):
                self.findings.append(Finding(
                    self.src.rel, node.lineno, "jit-concretize",
                    f"`{func.id}()` on a (potentially traced) value inside "
                    "a jitted body; only shape arithmetic is static"))
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if isinstance(node.ctx, ast.Load) and node.id in self.mutable_globals:
            self.findings.append(Finding(
                self.src.rel, node.lineno, "jit-mutable-global",
                f"jitted body reads mutable numpy global `{node.id}`; the "
                "trace bakes in its current contents"))


def _module_mutable_np_globals(tree: ast.Module) -> Set[str]:
    out: Set[str] = set()
    for stmt in tree.body:
        if not isinstance(stmt, ast.Assign):
            continue
        v = stmt.value
        if isinstance(v, ast.Call) and isinstance(v.func, ast.Attribute) \
                and isinstance(v.func.value, ast.Name) \
                and v.func.value.id in _NP_NAMES \
                and v.func.attr in _NP_MUTABLE_CTORS:
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


def _check_signature(src: SourceFile, fn: ast.FunctionDef, config: ast.Call,
                     findings: List[Finding]) -> None:
    names = _static_names(config) or set()
    nums = _static_nums(config) or []
    args = fn.args
    all_params = args.posonlyargs + args.args + args.kwonlyargs
    for i in nums:
        if 0 <= i < len(args.posonlyargs + args.args):
            names.add((args.posonlyargs + args.args)[i].arg)
    # Pair params with their defaults (positional defaults right-align).
    defaults: Dict[str, ast.AST] = {}
    pos = args.posonlyargs + args.args
    for param, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        defaults[param.arg] = d
    for param, d in zip(args.kwonlyargs, args.kw_defaults):
        if d is not None:
            defaults[param.arg] = d
    for pname, d in defaults.items():
        if pname in names:
            continue
        if isinstance(d, ast.Constant) \
                and isinstance(d.value, _STATIC_DEFAULT_TYPES) \
                and not isinstance(d.value, float) and d.value is not None:
            findings.append(Finding(
                src.rel, fn.lineno, "jit-static-argnames",
                f"jitted `{fn.name}` has scalar-default param `{pname}` "
                f"not in static_argnames — traced flags/shape knobs "
                "retrace unpredictably or fail"))


def check_jit(src: SourceFile) -> List[Finding]:
    if src.tree is None:
        return []
    findings: List[Finding] = []
    mutable_globals = _module_mutable_np_globals(src.tree)

    # Functions by name, so `jax.jit(fn)` marks `fn`'s def as jitted.
    defs: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(src.tree):
        if isinstance(node, ast.FunctionDef):
            defs[node.name] = node

    jitted: Dict[int, ast.FunctionDef] = {}   # id(node) → def
    configs: List = []                        # (def, config Call)

    for node in ast.walk(src.tree):
        # Decorated defs: @jax.jit / @partial(jax.jit, ...)
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                config = _jit_application(dec)
                if config is not None:
                    jitted[id(node)] = node
                    configs.append((node, config))
        # Call-wrapped: jax.jit(fn) — mark fn; immediately-invoked form.
        elif isinstance(node, ast.Call):
            # `jax.jit(...)(args)` → fresh wrapper per call.
            if isinstance(node.func, ast.Call) and _is_jax_jit(node.func.func):
                findings.append(Finding(
                    src.rel, node.lineno, "jit-per-call",
                    "immediately-invoked `jax.jit(...)(...)` builds a "
                    "fresh wrapper per call — the trace cache never "
                    "hits; hoist or cache the jitted callable"))
            if _is_jax_jit(node.func):
                for arg in node.args[:1]:
                    if isinstance(arg, ast.Name) and arg.id in defs:
                        target = defs[arg.id]
                        jitted[id(target)] = target
                        configs.append((target, node))

    for fn, config in configs:
        _check_signature(src, fn, config, findings)
    for fn in jitted.values():
        body = _JitBodyVisitor(src, mutable_globals, findings)
        for stmt in fn.body:
            body.visit(stmt)
    return findings
