"""squashlint: AST-based invariant checkers for the SQUASH repro.

Four checker families over ``src/repro`` (see DESIGN.md §"Static
invariants" for the conventions they enforce):

* :mod:`repro.analysis.locks` — ``# guarded-by:`` field discipline and the
  cross-file lock-acquisition-order graph;
* :mod:`repro.analysis.determinism` — wall-clock / unseeded-RNG /
  set-iteration bans inside the bitwise-parity modules;
* :mod:`repro.analysis.wire` — pickle and raw socket I/O confined to the
  ``serverless/payload.py`` codec;
* :mod:`repro.analysis.jit` — concretization, mutable-global closure and
  trace-cache hygiene in the jitted data plane.

Run with ``python -m repro.analysis`` (``--strict`` in CI). Suppress a
finding inline with ``# squash: ignore[rule-id] -- justification`` or
grandfather it in ``baseline.json`` (the ratchet only shrinks).
"""

from repro.analysis.findings import Finding, RULES
from repro.analysis.runner import analyze_source, analyze_tree, main

__all__ = ["Finding", "RULES", "analyze_source", "analyze_tree", "main"]
