"""Shared finding model for the squashlint checker suite.

Every checker (``locks``, ``determinism``, ``wire``, ``jit``) emits
:class:`Finding` records; the runner handles suppression (inline pragmas),
baselining (the grandfather ratchet) and reporting, so checkers stay pure
AST visitors. A finding is identified for baseline purposes by its
``(rule, path)`` pair — counts per pair ratchet downward — while the report
shows exact ``file:line`` anchors.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

__all__ = ["Finding", "RULES", "count_by_key"]

# rule id → (severity, one-line description). Rule ids are the tokens inline
# pragmas name: ``# squash: ignore[rule-id] -- justification``.
RULES: Dict[str, Tuple[str, str]] = {
    # -- lock discipline (locks.py)
    "lock-guarded-access": (
        "error",
        "read/write of a `# guarded-by:` attribute outside its lock"),
    "lock-order": (
        "error",
        "lock-acquisition-order cycle (potential deadlock inversion)"),
    # -- determinism (determinism.py)
    "wallclock": (
        "error",
        "wall-clock call inside a bitwise-parity module"),
    "unseeded-rng": (
        "error",
        "module-level / unseeded RNG inside a bitwise-parity module"),
    "set-iteration": (
        "error",
        "iteration over an unordered set feeding result ordering"),
    # -- wire discipline (wire.py)
    "wire-pickle": (
        "error",
        "pickle outside serverless/payload.py bypasses budget accounting"),
    "wire-raw-socket": (
        "error",
        "raw sendall/recv outside serverless/payload.py frame helpers"),
    # -- jit / recompile hygiene (jit.py)
    "jit-concretize": (
        "error",
        "float()/bool()/.item() on a traced value inside a jitted body"),
    "jit-mutable-global": (
        "error",
        "jitted body closes over a mutable module-level numpy array"),
    "jit-static-argnames": (
        "error",
        "jax.jit over scalar-default params not named in static_argnames"),
    "jit-per-call": (
        "error",
        "fresh jax.jit(...)(...) per call defeats the trace cache"),
    # -- meta (runner/pragmas)
    "bad-pragma": (
        "error",
        "suppression pragma without a `-- justification`"),
    "parse-error": ("error", "file failed to parse"),
}


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One checker hit, anchored to ``path:line``.

    ``path`` is repo-relative with forward slashes (stable across hosts so
    baseline entries and pragma bookkeeping never depend on the checkout
    location).
    """

    path: str
    line: int
    rule: str
    message: str

    @property
    def severity(self) -> str:
        return RULES.get(self.rule, ("error", ""))[0]

    @property
    def key(self) -> str:
        """Baseline aggregation key (line numbers drift; rule+path don't)."""
        return f"{self.rule}:{self.path}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def count_by_key(findings: List[Finding]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for f in findings:
        out[f.key] = out.get(f.key, 0) + 1
    return out
