"""squashlint runner: scoping, pragma suppression, baseline ratchet, CLI.

``python -m repro.analysis`` walks ``src/repro``, applies each checker to
its configured scope, filters findings through inline
``# squash: ignore[rule] -- justification`` pragmas, and compares what is
left against ``baseline.json``:

* a finding not covered by the baseline **fails** the run;
* a baseline entry whose finding count *shrank* (or vanished) fails
  ``--strict`` with a ratchet message until the baseline is re-recorded
  (``--update-baseline``) — grandfathered debt may only go down, never
  quietly stay stale;
* ``--update-baseline`` rewrites the file from the current findings.

Scopes (repo-relative, under ``src/repro``):

* lock discipline — every module (annotations are opt-in per file; the
  acquisition-order graph aggregates over all of them);
* determinism — the bitwise-parity surface: ``core/``, ``kernels/`` and the
  serverless *choreography* (``runtime``/``nodes``/``events``/``payload``).
  Transports and workers measure wall-clock by design and stay out;
* wire discipline — every module, with ``serverless/payload.py`` (the codec
  itself) allowlisted;
* jit hygiene — the compiled plane: ``core/dataplane.py``,
  ``core/distributed.py``, ``kernels/``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis import determinism, jit, locks, wire
from repro.analysis.findings import Finding, count_by_key
from repro.analysis.source import SourceFile, parse_source

__all__ = [
    "DETERMINISM_SCOPE", "WIRE_ALLOWLIST", "JIT_SCOPE", "EXTERNAL_GUARDS",
    "analyze_source", "analyze_tree", "Report", "load_baseline", "main",
]

# ------------------------------------------------------------------- scopes

# Bitwise-parity modules: ids/SearchStats computed here must never consult
# ambient nondeterminism (prefix match on repo-relative paths).
DETERMINISM_SCOPE: Tuple[str, ...] = (
    "core/",
    "kernels/",
    "serverless/runtime.py",
    "serverless/nodes.py",
    "serverless/events.py",
    "serverless/payload.py",
)

# The codec module itself — the only place pickle / raw socket I/O may live.
WIRE_ALLOWLIST: Tuple[str, ...] = ("serverless/payload.py",)

# The jit-compiled data plane.
JIT_SCOPE: Tuple[str, ...] = (
    "core/dataplane.py",
    "core/distributed.py",
    "kernels/",
)

# Third-party / cross-file guarded shapes the `# guarded-by:` convention
# cannot annotate in place: repo-relative path → {attr name → lock names}.
# (Currently empty — every guarded field in the tree is annotated at its
# assignment; keep entries here for vendored classes only.)
EXTERNAL_GUARDS: Dict[str, Dict[str, Set[str]]] = {}

_BASELINE_NAME = "baseline.json"


def _in_scope(rel: str, scope: Iterable[str]) -> bool:
    return any(rel == s or rel.startswith(s) for s in scope)


# ------------------------------------------------------------------ analysis

def analyze_source(rel: str, text: str
                   ) -> Tuple[List[Finding], List[locks.LockEdge]]:
    """All applicable checkers over one in-memory module.

    Returns (findings after pragma suppression, lock-order edges). Pragma
    misuse (missing justification) surfaces as ``bad-pragma`` findings.
    """
    src = parse_source(rel, text)
    raw: List[Finding] = []
    edges: List[locks.LockEdge] = []
    if src.parse_error is not None:
        return [Finding(rel, 1, "parse-error", src.parse_error)], []
    lf, edges = locks.check_locks(src, EXTERNAL_GUARDS.get(rel))
    raw.extend(lf)
    if _in_scope(rel, DETERMINISM_SCOPE):
        raw.extend(determinism.check_determinism(src))
    if not _in_scope(rel, WIRE_ALLOWLIST):
        raw.extend(wire.check_wire(src))
    if _in_scope(rel, JIT_SCOPE):
        raw.extend(jit.check_jit(src))
    return _apply_pragmas(src, raw), edges


def _apply_pragmas(src: SourceFile, raw: List[Finding]) -> List[Finding]:
    kept: List[Finding] = []
    for f in raw:
        pragma = src.ignores.get(f.line)
        if pragma is not None and f.rule in pragma[0]:
            continue                      # suppressed (justified or not —
                                          # bad-pragma reports the latter)
        kept.append(f)
    for line, (rules, justification) in sorted(src.ignores.items()):
        if justification is None:
            kept.append(Finding(
                src.rel, line, "bad-pragma",
                f"`squash: ignore[{', '.join(sorted(rules))}]` without a "
                "`-- justification`; every suppression must say why"))
    return kept


class Report:
    """Outcome of a tree run: findings vs the baseline ratchet."""

    def __init__(self, findings: List[Finding], baseline: Dict[str, int]):
        self.findings = sorted(findings)
        self.baseline = dict(baseline)
        counts = count_by_key(self.findings)
        self.new: List[Finding] = []
        self.baselined: List[Finding] = []
        remaining = dict(self.baseline)
        for f in self.findings:
            if remaining.get(f.key, 0) > 0:
                remaining[f.key] -= 1
                self.baselined.append(f)
            else:
                self.new.append(f)
        # Ratchet: baseline entries that no longer match reality.
        self.stale: Dict[str, int] = {
            k: self.baseline[k] - counts.get(k, 0)
            for k in self.baseline
            if self.baseline[k] > counts.get(k, 0)
        }

    @property
    def clean(self) -> bool:
        return not self.new

    @property
    def ratchet_ok(self) -> bool:
        return not self.stale


def analyze_tree(root: str, baseline: Optional[Dict[str, int]] = None
                 ) -> Report:
    """Run every checker over ``root`` (the ``src/repro`` package dir)."""
    findings: List[Finding] = []
    edges: List[locks.LockEdge] = []
    for rel, path in sorted(_iter_py(root)):
        with open(path, "r", encoding="utf-8") as fh:
            text = fh.read()
        f, e = analyze_source(rel, text)
        findings.extend(f)
        edges.extend(e)
    findings.extend(locks.order_cycles(edges))
    if baseline is None:
        baseline = load_baseline()
    return Report(findings, baseline)


def _iter_py(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            yield rel, path


# ------------------------------------------------------------------ baseline

def baseline_path() -> str:
    return os.path.join(os.path.dirname(__file__), _BASELINE_NAME)


def load_baseline(path: Optional[str] = None) -> Dict[str, int]:
    path = path or baseline_path()
    if not os.path.exists(path):
        return {}
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    return {str(k): int(v) for k, v in data.get("entries", {}).items()}


def save_baseline(entries: Dict[str, int],
                  path: Optional[str] = None) -> None:
    path = path or baseline_path()
    payload = {
        "comment": "squashlint grandfathered findings: `rule:path` → count. "
                   "The ratchet only goes down — fix findings and rerun "
                   "`python -m repro.analysis --update-baseline`.",
        "entries": {k: entries[k] for k in sorted(entries)},
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")


# ----------------------------------------------------------------------- CLI

def default_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="squashlint: AST invariants checker (lock discipline, "
                    "determinism, wire discipline, jit hygiene). See "
                    "DESIGN.md 'Static invariants'.")
    ap.add_argument("--root", default=None,
                    help="package root to scan (default: the installed "
                         "repro package directory)")
    ap.add_argument("--strict", action="store_true",
                    help="also fail when the baseline is stale (the ratchet "
                         "must shrink) — the CI mode")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite baseline.json from the current findings")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout")
    args = ap.parse_args(argv)

    root = args.root or default_root()
    report = analyze_tree(root)

    if args.update_baseline:
        save_baseline(count_by_key(report.findings))
        print(f"baseline updated: {len(report.findings)} finding(s) "
              f"grandfathered in {baseline_path()}")
        return 0

    if args.json:
        print(json.dumps({
            "new": [f.render() for f in report.new],
            "baselined": [f.render() for f in report.baselined],
            "stale_baseline": report.stale,
        }, indent=2))
    else:
        for f in report.new:
            print(f.render())
        if report.baselined:
            print(f"[baseline] {len(report.baselined)} grandfathered "
                  "finding(s) suppressed")
        for key, by in sorted(report.stale.items()):
            print(f"[ratchet] baseline entry `{key}` overcounts by {by} — "
                  "run --update-baseline to shrink it")

    if report.new:
        print(f"squashlint: {len(report.new)} new finding(s)",
              file=sys.stderr)
        return 1
    if args.strict and not report.ratchet_ok:
        print("squashlint: baseline is stale (ratchet must shrink)",
              file=sys.stderr)
        return 1
    print(f"squashlint: clean ({len(report.findings)} finding(s) total, "
          f"{len(report.baselined)} baselined)")
    return 0
