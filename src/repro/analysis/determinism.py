"""Determinism checker for bitwise-parity modules.

The repo's headline guarantee is that ids and ``SearchStats`` are bitwise
identical across the numpy/jax/local/process/socket planes. That only holds
if the modules those planes share never consult ambient nondeterminism.
Three rules, applied to the configured parity scope (``core/``,
``kernels/``, the serverless choreography — see the runner):

* ``wallclock`` — calls to ``time.time`` / ``time.monotonic`` /
  ``time.perf_counter`` (and their ``_ns`` variants). Wall-clock belongs in
  trace/measurement code; a site that only feeds measured timelines carries
  an ``# squash: ignore[wallclock] -- ...`` pragma saying so.
* ``unseeded-rng`` — module-level numpy RNG (``np.random.rand`` etc. — the
  legacy global stream), ``np.random.seed`` (mutates that global stream),
  and bare ``random.*`` module functions. Seeded constructions
  (``np.random.default_rng(seed)``, ``random.Random(seed)``,
  ``np.random.Generator``/``SeedSequence``) are the sanctioned forms.
* ``set-iteration`` — ``for`` loops over set displays/comprehensions or
  ``set(...)`` calls, and ``list()``/``tuple()``/``enumerate()`` over the
  same: set iteration order is salted per process, so any result ordering
  derived from it diverges across workers. ``sorted(set(...))`` is fine.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.findings import Finding
from repro.analysis.source import SourceFile

__all__ = ["check_determinism"]

_WALLCLOCK_FNS = {
    "time", "monotonic", "perf_counter", "time_ns", "monotonic_ns",
    "perf_counter_ns", "clock_gettime",
}
_NP_NAMES = {"np", "numpy"}
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "BitGenerator"}
_RANDOM_MODULE_OK = {"Random", "SystemRandom"}


def _attr_chain(node: ast.AST) -> Optional[List[str]]:
    """``np.random.rand`` → ["np", "random", "rand"]; None if not a chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id == "set":
        return True
    # set ops on set exprs: (a_set | b_set) — only literal-rooted ones.
    if isinstance(node, ast.BinOp) and isinstance(node.op, (ast.BitOr,
                                                            ast.BitAnd,
                                                            ast.Sub)):
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


class _DetVisitor(ast.NodeVisitor):
    def __init__(self, src: SourceFile):
        self.src = src
        self.findings: List[Finding] = []

    def _flag(self, node: ast.AST, rule: str, msg: str) -> None:
        self.findings.append(Finding(self.src.rel, node.lineno, rule, msg))

    def visit_Call(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func)
        if chain:
            # time.time() / time.perf_counter() ...
            if len(chain) == 2 and chain[0] == "time" \
                    and chain[1] in _WALLCLOCK_FNS:
                self._flag(node, "wallclock",
                           f"`time.{chain[1]}()` in a bitwise-parity module "
                           "(confine wall-clock to trace/measurement code)")
            # np.random.<legacy fn>() — the unseeded global stream.
            elif len(chain) == 3 and chain[0] in _NP_NAMES \
                    and chain[1] == "random" and chain[2] not in _NP_RANDOM_OK:
                self._flag(node, "unseeded-rng",
                           f"`{chain[0]}.random.{chain[2]}()` uses numpy's "
                           "global RNG stream; use "
                           "`np.random.default_rng(seed)`")
            # random.<fn>() — the stdlib global stream.
            elif len(chain) == 2 and chain[0] == "random" \
                    and chain[1] not in _RANDOM_MODULE_OK:
                self._flag(node, "unseeded-rng",
                           f"`random.{chain[1]}()` uses the stdlib global "
                           "RNG; use a seeded `random.Random(seed)` instance")
        # list(set(...)) / tuple(set(...)) / enumerate(set(...))
        if isinstance(node.func, ast.Name) \
                and node.func.id in ("list", "tuple", "enumerate") \
                and node.args and _is_set_expr(node.args[0]):
            self._flag(node, "set-iteration",
                       f"`{node.func.id}()` over a set has salted, "
                       "process-dependent order; wrap in `sorted(...)`")
        self.generic_visit(node)

    def _check_iter(self, node) -> None:
        if _is_set_expr(node.iter):
            self._flag(node, "set-iteration",
                       "iterating a set has salted, process-dependent "
                       "order; wrap in `sorted(...)`")

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node)
        self.generic_visit(node)

    def visit_AsyncFor(self, node: ast.AsyncFor) -> None:
        self._check_iter(node)
        self.generic_visit(node)

    def visit_comprehension(self, node: ast.comprehension) -> None:
        if _is_set_expr(node.iter):
            self.findings.append(Finding(
                self.src.rel, node.iter.lineno, "set-iteration",
                "comprehension over a set has salted, process-dependent "
                "order; wrap in `sorted(...)`"))
        self.generic_visit(node)


def check_determinism(src: SourceFile) -> List[Finding]:
    if src.tree is None:
        return []
    visitor = _DetVisitor(src)
    visitor.visit(src.tree)
    return visitor.findings
