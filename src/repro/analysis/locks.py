"""Lock-discipline checker: guarded-attribute access + acquisition order.

Two rules over the same traversal:

* ``lock-guarded-access`` — an attribute declared ``# guarded-by: <lock>``
  (see :mod:`repro.analysis.source`) is read or written while no ``with
  <obj>.<lock>:`` scope is lexically active and the enclosing function does
  not carry a ``# squash: holds[<lock>]`` contract. Matching is by *name*:
  the checker cannot resolve runtime types, so ``worker.assigned`` matches a
  guard declared on ``_Worker.assigned`` even though the lock lives on the
  managing transport — exactly the shape of this repo's transports, where
  one manager lock guards the per-worker bookkeeping fields.
* ``lock-order`` — whenever lock B is acquired lexically inside a scope
  holding lock A, the edge A→B enters a global acquisition-order graph
  (aggregated across files by the runner). A cycle in that graph is a
  potential deadlock inversion and is reported at every edge on the cycle.

Scoping rules that keep the name-matching honest:

* ``__init__``/``__new__`` bodies are exempt — the object is not published
  to other threads until construction returns.
* A nested ``def`` does **not** inherit the lexical held-set (it usually
  becomes a thread target or callback that runs on another stack); a
  ``lambda`` does (it runs synchronously at its use site — ``min(...,
  key=...)`` under the caller's lock).
* Guards declared in a file apply to that file only, plus any entries the
  runner's third-party registry contributes for shapes we cannot annotate.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.source import SourceFile

__all__ = ["LockEdge", "check_locks", "order_cycles"]

_CONSTRUCTORS = {"__init__", "__new__"}


@dataclasses.dataclass(frozen=True)
class LockEdge:
    """Acquisition of ``inner`` while ``outer`` is held, at path:line."""

    outer: str
    inner: str
    path: str
    line: int


def _with_locks(node: ast.With, lock_names: Set[str]) -> Set[str]:
    """Lock names acquired by a ``with`` statement's items."""
    out: Set[str] = set()
    for item in node.items:
        expr = item.context_expr
        # `with self._lock:` / `with t._lock:` / `with w.send_lock:`
        if isinstance(expr, ast.Attribute):
            name = expr.attr
        elif isinstance(expr, ast.Name):
            name = expr.id
        else:
            continue
        if name in lock_names or name.endswith("lock"):
            out.add(name)
    return out


class _LockVisitor(ast.NodeVisitor):
    def __init__(self, src: SourceFile, guards: Dict[str, Set[str]]):
        self.src = src
        self.guards = guards
        self.lock_names: Set[str] = set()
        for locks in guards.values():
            self.lock_names |= locks
        self.held: Set[str] = set()
        self.findings: List[Finding] = []
        self.edges: List[LockEdge] = []
        self._flagged: Set[Tuple[int, str]] = set()

    # ------------------------------------------------------------ functions

    def _enter_function(self, node, constructor: bool) -> None:
        saved = self.held
        if constructor:
            # Constructor writes are pre-publication; grant every guard.
            self.held = set().union(*self.guards.values()) if self.guards \
                else set()
            self.held |= self.lock_names
        else:
            self.held = set(self.src.holds_for_def(node))
        for stmt in node.body:
            self.visit(stmt)
        self.held = saved

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._enter_function(node, node.name in _CONSTRUCTORS)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._enter_function(node, node.name in _CONSTRUCTORS)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        # Lambdas run synchronously at their use site: inherit the held-set.
        self.visit(node.body)

    # ---------------------------------------------------------------- with

    def visit_With(self, node: ast.With) -> None:
        acquired = _with_locks(node, self.lock_names)
        for outer in self.held:
            for inner in acquired - {outer}:
                self.edges.append(LockEdge(outer, inner, self.src.rel,
                                           node.lineno))
        for item in node.items:
            self.visit(item.context_expr)
        self.held = self.held | acquired
        for stmt in node.body:
            self.visit(stmt)
        self.held = self.held - acquired

    # ------------------------------------------------------------ accesses

    def _check_attr(self, name: str, line: int) -> None:
        locks = self.guards.get(name)
        if not locks or locks & self.held:
            return
        if (line, name) in self._flagged:
            return
        self._flagged.add((line, name))
        want = "/".join(sorted(locks))
        self.findings.append(Finding(
            self.src.rel, line, "lock-guarded-access",
            f"access to guarded attribute `{name}` outside `with "
            f"...{want}:` (declare `# squash: holds[{want}]` if the caller "
            f"holds it)"))

    def visit_Attribute(self, node: ast.Attribute) -> None:
        self._check_attr(node.attr, node.lineno)
        self.generic_visit(node)


def check_locks(src: SourceFile,
                extra_guards: Dict[str, Set[str]] = None
                ) -> Tuple[List[Finding], List[LockEdge]]:
    """Run the lock-discipline rules over one file.

    ``extra_guards`` merges the runner's third-party registry (attr name →
    lock names) into the file's own ``# guarded-by:`` declarations.
    """
    if src.tree is None:
        return [], []
    guards = src.guarded_attrs()
    for attr, locks in (extra_guards or {}).items():
        guards.setdefault(attr, set()).update(locks)
    if not guards:
        # Still walk `with` nesting so unannotated files contribute
        # acquisition-order edges (e.g. third-party lock pairings).
        guards = {}
    visitor = _LockVisitor(src, guards)
    visitor.visit(src.tree)
    return visitor.findings, visitor.edges


def order_cycles(edges: List[LockEdge]) -> List[Finding]:
    """Cycle detection over the aggregated acquisition-order graph.

    Every edge participating in a cycle gets one ``lock-order`` finding at
    its acquisition site, so the report names each inversion pair —
    ``_lock → send_lock`` in one file vs ``send_lock → _lock`` in another
    shows up as two anchored findings.
    """
    graph: Dict[str, Set[str]] = {}
    for e in edges:
        graph.setdefault(e.outer, set()).add(e.inner)

    # Nodes on any cycle: iterative DFS with colors.
    on_cycle: Set[Tuple[str, str]] = set()

    def reachable(frm: str, to: str) -> bool:
        seen, stack = set(), [frm]
        while stack:
            n = stack.pop()
            if n == to:
                return True
            if n in seen:
                continue
            seen.add(n)
            stack.extend(graph.get(n, ()))
        return False

    for e in edges:
        if reachable(e.inner, e.outer):
            on_cycle.add((e.outer, e.inner))

    findings: List[Finding] = []
    seen_sites: Set[Tuple[str, int, str, str]] = set()
    for e in edges:
        if (e.outer, e.inner) not in on_cycle:
            continue
        site = (e.path, e.line, e.outer, e.inner)
        if site in seen_sites:
            continue
        seen_sites.add(site)
        findings.append(Finding(
            e.path, e.line, "lock-order",
            f"acquiring `{e.inner}` while holding `{e.outer}` completes an "
            f"acquisition-order cycle (deadlock inversion)"))
    return findings
