"""Wire-discipline checker: every byte on the wire goes through the codec.

The §3.3 6 MB payload budget is only honest if *every* path that moves
bytes between processes/hosts flows through ``serverless/payload.py``'s
helpers (``encode_message``/``decode_message`` for codec bodies,
``write_frame``/``read_frame`` for TCP frames, ``encode_init``/
``decode_init`` for the budget-exempt deployment bundle). Two rules:

* ``wire-pickle`` — ``pickle.dumps``/``loads``/``dump``/``load`` anywhere
  outside the allowlisted codec module. Pickled bytes bypass the codec's
  byte accounting (and accept arbitrary object graphs the framing cannot
  paginate), so ad-hoc pickling is how a payload sneaks past the budget.
* ``wire-raw-socket`` — ``.sendall(...)`` / ``.recv(...)`` method calls
  outside the codec module. Raw socket I/O skips the per-frame budget
  check in ``write_frame`` and the exact-length framing of ``read_frame``.
  Multiprocessing-pipe ``Connection.recv`` sites share the method name and
  are suppressed inline with a justification (the pipes carry bytes the
  submit path already budget-checked).

The allowlist is by repo-relative path (see the runner's configuration).
"""

from __future__ import annotations

import ast
from typing import List

from repro.analysis.findings import Finding
from repro.analysis.source import SourceFile

__all__ = ["check_wire"]

_PICKLE_FNS = {"dumps", "loads", "dump", "load"}
_RAW_SOCKET_METHODS = {"sendall", "recv", "recv_into", "recvfrom"}


class _WireVisitor(ast.NodeVisitor):
    def __init__(self, src: SourceFile):
        self.src = src
        self.findings: List[Finding] = []

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute):
            base = func.value
            if isinstance(base, ast.Name) and base.id in ("pickle", "cPickle") \
                    and func.attr in _PICKLE_FNS:
                self.findings.append(Finding(
                    self.src.rel, node.lineno, "wire-pickle",
                    f"`pickle.{func.attr}` outside serverless/payload.py — "
                    "wire bytes must flow through the budgeted codec "
                    "(encode_message/encode_init)"))
            elif func.attr in _RAW_SOCKET_METHODS:
                self.findings.append(Finding(
                    self.src.rel, node.lineno, "wire-raw-socket",
                    f"raw `.{func.attr}()` outside serverless/payload.py — "
                    "socket I/O must go through write_frame/read_frame so "
                    "the 6 MB per-frame budget applies"))
        self.generic_visit(node)


def check_wire(src: SourceFile) -> List[Finding]:
    if src.tree is None:
        return []
    visitor = _WireVisitor(src)
    visitor.visit(src.tree)
    return visitor.findings
