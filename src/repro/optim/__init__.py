"""Optimizers & schedules (pure pytree transforms, no external deps).

AdamW with decoupled weight decay, global-norm clipping, and cosine/linear
warmup schedules. State dtype is configurable: fp32 moments by default,
bf16 moments for memory-bound giants (arctic-480b — see DESIGN.md §5).
"""

from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               global_norm, clip_by_global_norm)
from repro.optim.schedule import cosine_schedule, linear_schedule

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "clip_by_global_norm", "cosine_schedule", "linear_schedule"]
