"""Learning-rate schedules (step-indexed, jit-safe)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_schedule", "linear_schedule"]


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return sched


def linear_schedule(peak_lr: float, warmup: int, total: int):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, warm, peak_lr * (1 - frac))
    return sched
