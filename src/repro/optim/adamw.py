"""AdamW (decoupled weight decay) over arbitrary param pytrees."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32   # bf16 for memory-bound giants


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, dtype=cfg.state_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr_schedule: Optional[Callable] = None):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state["step"] + 1
    lr = cfg.lr if lr_schedule is None else lr_schedule(step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / c1
        vhat = vf / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return (pf.astype(p.dtype), mf.astype(cfg.state_dtype),
                vf.astype(cfg.state_dtype))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, {
        "grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
