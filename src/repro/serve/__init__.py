"""Serving substrate: batched prefill/decode engine, OSQ-quantized KV, and
the vector-search service facade (backend-routed SquashIndex queries)."""

from repro.serve.engine import Engine, ServeConfig
from repro.serve.kv_quant import quantize_caches, dequantize_caches, cache_bytes
from repro.serve.vector_service import ServiceConfig, VectorSearchService

__all__ = ["Engine", "ServeConfig", "quantize_caches", "dequantize_caches",
           "cache_bytes", "ServiceConfig", "VectorSearchService"]
