"""Serving substrate: batched prefill/decode engine + OSQ-quantized KV."""

from repro.serve.engine import Engine, ServeConfig
from repro.serve.kv_quant import quantize_caches, dequantize_caches, cache_bytes

__all__ = ["Engine", "ServeConfig", "quantize_caches", "dequantize_caches",
           "cache_bytes"]
