"""Vector-search serving facade: QA-style request routing over SquashIndex.

The simulated serverless runtime (examples/, benchmarks/) talks to the index
through this service rather than calling ``SquashIndex.search`` directly, so
the data-plane backend becomes a deployment decision:

* ``backend="numpy"`` — per-query reference loop (debug / tiny batches).
* ``backend="jax"``   — batched jitted plane (the production hot path).
* ``backend="auto"``  — route by batch size: single-query lookups take the
  loop (no trace/dispatch overhead), real batches take the batched plane.

The service also plays the QueryAllocator's accounting role: it accumulates
:class:`~repro.core.pipeline.SearchStats` across requests and tracks wall
time per backend, which ``benchmarks/bench_qps.py`` reads for the
numpy-vs-jax shootout.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import attributes as attr_mod
from repro.core.pipeline import SearchStats, SquashIndex

__all__ = ["ServiceConfig", "VectorSearchService"]

_AUTO_BATCH_THRESHOLD = 4  # ≥ this many queries → batched jax plane


@dataclasses.dataclass
class ServiceConfig:
    backend: str = "auto"              # numpy | jax | auto
    default_k: int = 10


class VectorSearchService:
    """One QueryAllocator front-end bound to a resident SquashIndex."""

    def __init__(self, index: SquashIndex, config: Optional[ServiceConfig] = None):
        self.index = index
        self.config = config or ServiceConfig()
        if self.config.backend not in ("numpy", "jax", "auto"):
            raise ValueError(f"unknown backend {self.config.backend!r}")
        self.stats = SearchStats()
        self.requests = 0
        self.wall_s: Dict[str, float] = {"numpy": 0.0, "jax": 0.0}
        self.queries_served: Dict[str, int] = {"numpy": 0, "jax": 0}

    def resolve_backend(self, num_queries: int) -> str:
        if self.config.backend != "auto":
            return self.config.backend
        return "jax" if num_queries >= _AUTO_BATCH_THRESHOLD else "numpy"

    def warmup(self, num_queries: int, k: Optional[int] = None) -> None:
        """Pre-trace the jax plane for a batch shape (DRE-style warm start)."""
        k = k or self.config.default_k
        q = np.zeros((num_queries, self.index.dim))
        self.index.search(q, [], k=k, backend="jax")

    def query(
        self,
        queries: np.ndarray,
        predicates: Sequence[attr_mod.Predicate] = (),
        k: Optional[int] = None,
        backend: Optional[str] = None,
    ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        """Serve one request batch; returns (ids, dists, per-request stats)."""
        queries = np.atleast_2d(np.asarray(queries, dtype=np.float64))
        k = k or self.config.default_k
        chosen = (self.resolve_backend(queries.shape[0])
                  if backend in (None, "auto") else backend)
        t0 = time.perf_counter()
        ids, dists, stats = self.index.search(
            queries, list(predicates), k=k, backend=chosen
        )
        dt = time.perf_counter() - t0
        self.requests += 1
        self.stats.merge(stats)
        self.wall_s[chosen] += dt
        self.queries_served[chosen] += queries.shape[0]
        return ids, dists, stats

    def qps(self, backend: str) -> float:
        """Served-queries-per-second for one backend (0 if unused)."""
        t = self.wall_s.get(backend, 0.0)
        return self.queries_served.get(backend, 0) / t if t > 0 else 0.0
