"""Vector-search serving facade: QA-style request routing over SquashIndex.

Callers talk to the index through this service rather than calling
``SquashIndex.search`` directly, so the data-plane/deployment becomes a
routing decision:

* ``backend="numpy"``      — per-query reference loop (debug / tiny batches).
* ``backend="jax"``        — batched jitted plane (the production hot path).
* ``backend="serverless"`` — the full event-driven Coordinator → QA → QP
  runtime (``repro.serverless``): same ids as the jax plane, plus per-node
  latency / payload / DRE / cost traces (kept on ``last_trace``). With
  ``ServiceConfig(cache_enabled=True)`` the runtime's §5.6 result cache
  serves repeated queries at the Coordinator; ``swap_index`` invalidates
  it when the index is rebuilt.
* ``backend="auto"``       — route by batch size: single-query lookups take
  the loop (no trace/dispatch overhead), real batches the batched plane.

The service also plays the QueryAllocator's accounting role: it accumulates
:class:`~repro.core.pipeline.SearchStats` across requests and tracks wall
time per backend, which ``benchmarks/bench_qps.py`` reads for the
numpy-vs-jax shootout.

With ``ServiceConfig(recall_target=…)`` the service additionally runs the
recall-targeted Hamming autotune (``core/autotune.py``) against the bound
index at bind time and again on every ``swap_index`` — per-partition keep
budgets replace the static ``hamming_perc`` in all backends, ids staying
bitwise-identical across them.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import attributes as attr_mod
from repro.core.pipeline import SearchStats, SquashIndex

__all__ = ["ServiceConfig", "VectorSearchService"]

_AUTO_BATCH_THRESHOLD = 4  # ≥ this many queries → batched jax plane

# Backends a request may name explicitly ("auto" resolves before dispatch).
_CALL_BACKENDS = ("numpy", "jax", "serverless")


@dataclasses.dataclass
class ServiceConfig:
    backend: str = "auto"              # numpy | jax | serverless | auto
    default_k: int = 10
    serverless: Optional[object] = None  # repro.serverless.RuntimeConfig
    # §5.6 result-cache knobs for the serverless backend. They overlay onto
    # the RuntimeConfig (an explicit ``serverless`` config that already
    # enables the cache wins), so callers can turn caching on per service
    # without hand-building a runtime config.
    cache_enabled: bool = False
    result_cache_bytes: int = 64 * 1024 * 1024
    # Execution substrate of the serverless backend (serverless.transport):
    # None keeps the RuntimeConfig's choice; "local" pins the in-process
    # virtual-time scheduler, "process" the real multi-process worker pool,
    # "socket" the TCP worker fleet (ids bitwise-identical in every case).
    transport: Optional[str] = None
    # Socket-transport host fleet ("host:port", ...). None keeps the
    # RuntimeConfig's choice (auto-spawned loopback hosts by default).
    hosts: Optional[Tuple[str, ...]] = None
    # Recall-targeted Hamming autotune (core/autotune.py). When set, the
    # service calibrates a per-partition keep-budget profile against the
    # bound index (and re-calibrates on ``swap_index``); every backend —
    # numpy, jax, serverless — then consumes the same profile, so ids stay
    # bitwise-identical across them at strictly fewer ADC evaluations.
    recall_target: Optional[float] = None
    calibration_sample: int = 64
    calibration_seed: int = 0


class VectorSearchService:
    """One QueryAllocator front-end bound to a resident SquashIndex."""

    def __init__(self, index: SquashIndex, config: Optional[ServiceConfig] = None):
        base = getattr(index, "base", None)     # accept a LiveIndex wrapper
        self.index = base if isinstance(base, SquashIndex) else index
        self.config = config or ServiceConfig()
        if self.config.backend not in _CALL_BACKENDS + ("auto",):
            raise ValueError(f"unknown backend {self.config.backend!r}")
        self.stats = SearchStats()
        self.requests = 0
        self.wall_s: Dict[str, float] = {b: 0.0 for b in _CALL_BACKENDS}
        self.queries_served: Dict[str, int] = {b: 0 for b in _CALL_BACKENDS}
        self._runtime = None
        self.last_trace = None         # RunTrace of the last serverless call
        self._calibrate()

    def _calibrate(self) -> None:
        """(Re)derive the autotune profile for the currently-bound index."""
        if self.config.recall_target is None:
            return
        self.index.autotune(
            recall_target=self.config.recall_target,
            k=self.config.default_k,
            sample=self.config.calibration_sample,
            seed=self.config.calibration_seed)

    @property
    def profile(self):
        """The bound index's active CalibrationProfile (None if untuned)."""
        return self.index.profile

    def resolve_backend(self, num_queries: int) -> str:
        if self.config.backend != "auto":
            return self.config.backend
        return "jax" if num_queries >= _AUTO_BATCH_THRESHOLD else "numpy"

    def runtime(self):
        """The lazily-built serverless runtime bound to this index."""
        if self._runtime is None:
            from repro.serverless import RuntimeConfig, ServerlessRuntime

            cfg = self.config.serverless or RuntimeConfig()
            if self.config.cache_enabled and not cfg.cache_enabled:
                cfg = dataclasses.replace(
                    cfg, cache_enabled=True,
                    result_cache_bytes=self.config.result_cache_bytes)
            if (self.config.transport is not None
                    and cfg.transport != self.config.transport):
                cfg = dataclasses.replace(cfg,
                                          transport=self.config.transport)
            if (self.config.hosts is not None
                    and cfg.hosts != self.config.hosts):
                cfg = dataclasses.replace(cfg, hosts=self.config.hosts)
            self._runtime = ServerlessRuntime(self.index, cfg)
        return self._runtime

    @property
    def result_cache(self):
        """The serverless backend's §5.6 ResultCache (None if unbuilt/off)."""
        return self._runtime.result_cache if self._runtime else None

    def swap_index(self, index: SquashIndex) -> None:
        """Rebind the service to a rebuilt (or live-wrapped) index.

        The serverless runtime survives the swap via
        ``ServerlessRuntime.rebind``: its container pools keep their warm
        containers while the version bump stales every fetch/derived
        singleton key and the epoch bump drains in-flight leases — cached
        results and retained state from the old index can never be served,
        without the old cost of discarding the whole runtime (and its real
        worker fleet's warmth model) on every swap. Process/socket workers
        holding old shards are still shut down and respawn with fresh
        bundles on the next call.
        """
        base = getattr(index, "base", None)     # accept a LiveIndex wrapper
        self.index = base if isinstance(base, SquashIndex) else index
        if self._runtime is not None:
            self._runtime.rebind(self.index)
        self._calibrate()

    def close(self) -> None:
        """Release backend resources (process-transport worker pools)."""
        if self._runtime is not None:
            self._runtime.close()
            self._runtime = None

    def warmup(self, num_queries: int, k: Optional[int] = None) -> None:
        """Pre-trace the jax plane for a batch shape (DRE-style warm start)."""
        k = k or self.config.default_k
        q = np.zeros((num_queries, self.index.dim))
        self.index.search(q, [], k=k, backend="jax")

    def query(
        self,
        queries: np.ndarray,
        predicates: Sequence[attr_mod.Predicate] = (),
        k: Optional[int] = None,
        backend: Optional[str] = None,
    ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        """Serve one request batch; returns (ids, dists, per-request stats).

        ``backend`` must be one of ``_CALL_BACKENDS`` or ``"auto"``/None; an
        unknown string fails here, before any index state is touched.
        """
        if backend not in (None, "auto") + _CALL_BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; expected one of "
                f"{('auto',) + _CALL_BACKENDS}")
        queries = np.atleast_2d(np.asarray(queries, dtype=np.float64))
        k = k or self.config.default_k
        chosen = (self.resolve_backend(queries.shape[0])
                  if backend in (None, "auto") else backend)
        t0 = time.perf_counter()
        if chosen == "serverless":
            result = self.runtime().search(queries, list(predicates), k=k)
            ids, dists, stats = result.ids, result.dists, result.stats
            self.last_trace = result.trace
        else:
            ids, dists, stats = self.index.search(
                queries, list(predicates), k=k, backend=chosen
            )
        dt = time.perf_counter() - t0
        self.requests += 1
        self.stats.merge(stats)
        self.wall_s[chosen] += dt
        self.queries_served[chosen] += queries.shape[0]
        return ids, dists, stats

    def qps(self, backend: str) -> float:
        """Served-queries-per-second for one backend (0 if unused)."""
        t = self.wall_s.get(backend, 0.0)
        return self.queries_served.get(backend, 0) / t if t > 0 else 0.0
