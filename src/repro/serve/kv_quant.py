"""OSQ applied to the KV cache — the paper's technique as a serving feature.

SQUASH's core move is scalar quantization with segment packing so sub-word
codes realize their theoretical compression (DESIGN.md §5.ii). A KV cache is
dimension-structured exactly like the paper's vectors: per-(head, channel)
value ranges are narrow and stable, so ``bits``-bit codes per channel with
``32 // bits`` codes packed per int32 lane word give a 4–8× HBM (and, more
importantly, HBM→VMEM bandwidth) reduction at decode time.

Packing is along the *sequence* axis of each buffer, keeping channel
extraction a pure shift/mask — the TPU translation of OSQ's dimensional-
extraction scheme (paper §2.2.2, lanes instead of bytes). Cache leaves are
identified by name (k/v/latent/k_rope) with the buffer axis located relative
to the trailing dims, so arbitrarily layer-stacked pytrees work.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_leaf", "dequantize_leaf", "quantize_caches",
           "dequantize_caches", "cache_bytes",
           "quantize_leaf_nonuniform", "dequantize_leaf_nonuniform"]

# name → buffer-axis position counted from the END of the shape
#   k/v     : (..., B, buf, kv, hd) → -3
#   latent  : (..., B, buf, r)      → -2
#   k_rope  : (..., B, buf, r)      → -2
_BUF_AXIS_FROM_END = {"k": 3, "v": 3, "latent": 2, "k_rope": 2}


def quantize_leaf(x: jnp.ndarray, bits: int, axis: int):
    """Pack ``bits``-bit codes along ``axis`` (per-channel lo/scale)."""
    assert 32 % bits == 0
    axis = axis % x.ndim
    per = 32 // bits
    levels = (1 << bits) - 1
    lo = x.min(axis=axis, keepdims=True)
    hi = x.max(axis=axis, keepdims=True)
    scale = (hi - lo) / levels
    scale = jnp.where(scale == 0, 1.0, scale)
    codes = jnp.clip(jnp.round((x - lo) / scale), 0, levels).astype(jnp.uint32)
    s = x.shape[axis]
    pad = (-s) % per
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        codes = jnp.pad(codes, widths)
    g = codes.shape[axis] // per
    new_shape = (*x.shape[:axis], g, per, *x.shape[axis + 1:])
    codes = codes.reshape(new_shape)
    shift_shape = [1] * codes.ndim
    shift_shape[axis + 1] = per
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits).reshape(shift_shape)
    packed = jnp.sum(codes << shifts, axis=axis + 1, dtype=jnp.uint32)
    return packed.astype(jnp.int32), (lo, scale, s, x.dtype, bits, axis)


def dequantize_leaf(packed: jnp.ndarray, meta) -> jnp.ndarray:
    lo, scale, s, dtype, bits, axis = meta
    per = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    p = jnp.expand_dims(packed.astype(jnp.uint32), axis + 1)
    shift_shape = [1] * p.ndim
    shift_shape[axis + 1] = per
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits).reshape(shift_shape)
    codes = (p >> shifts) & mask
    flat = codes.reshape(*packed.shape[:axis], -1, *packed.shape[axis + 1:])
    sl = [slice(None)] * flat.ndim
    sl[axis] = slice(0, s)
    return (flat[tuple(sl)].astype(jnp.float32) * scale + lo).astype(dtype)


def _buf_axis(path, leaf) -> int:
    name = None
    for p in reversed(path):
        if hasattr(p, "key"):
            name = str(p.key)
            break
    off = _BUF_AXIS_FROM_END.get(name or "", 0)
    if not off:
        return -1
    axis = leaf.ndim - off
    # buffer must be long enough to be worth packing
    if axis < 0 or leaf.shape[axis] < 16:
        return -1
    if not jnp.issubdtype(leaf.dtype, jnp.floating):
        return -1
    return axis


def quantize_caches(caches, bits: int):
    """Quantize every KV-like float leaf in a cache pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    out, metas = [], []
    for path, leaf in flat:
        axis = _buf_axis(path, leaf)
        if axis >= 0:
            q, m = quantize_leaf(leaf, bits, axis)
            out.append(q)
            metas.append(m)
        else:
            out.append(leaf)
            metas.append(None)
    return treedef.unflatten(out), (treedef, metas)


def dequantize_caches(qcaches, meta):
    treedef, metas = meta
    leaves = treedef.flatten_up_to(qcaches)
    out = [leaf if m is None else dequantize_leaf(leaf, m)
           for leaf, m in zip(leaves, metas)]
    return treedef.unflatten(out)


def cache_bytes(caches) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(caches))


# ---------------------------------------------------------------------------
# Non-uniform OSQ-KV: variance-based per-channel bit allocation (paper §2.2).
# Channels are ranked by their value variance over the buffer; the top
# ``hi_frac`` get ``hi_bits`` codes, the rest ``lo_bits`` — the serving-side
# analogue of OSQ's variance-greedy allocation, stored as two packed tensors
# (each internally uniform, so extraction stays a shift/mask).
# ---------------------------------------------------------------------------

def quantize_leaf_nonuniform(x: jnp.ndarray, axis: int, *, hi_bits: int = 8,
                             lo_bits: int = 4, hi_frac: float = 0.5):
    """Returns ((packed_hi, packed_lo), meta). Channels = trailing dims
    flattened; variance measured along ``axis`` (the buffer)."""
    axis = axis % x.ndim
    ch_shape = x.shape[axis + 1:]
    nch = 1
    for s in ch_shape:
        nch *= s
    lead = x.shape[:axis]
    xr = x.reshape(*lead, x.shape[axis], nch)          # (..., S, C)
    var = jnp.var(xr.astype(jnp.float32), axis=tuple(range(xr.ndim - 1)))
    n_hi = max(int(nch * hi_frac), 1)
    order = jnp.argsort(-var)                           # high-variance first
    hi_idx, lo_idx = order[:n_hi], order[n_hi:]
    q_hi, m_hi = quantize_leaf(jnp.take(xr, hi_idx, axis=-1), hi_bits,
                               axis)
    if lo_idx.shape[0]:
        q_lo, m_lo = quantize_leaf(jnp.take(xr, lo_idx, axis=-1), lo_bits,
                                   axis)
    else:
        q_lo, m_lo = None, None
    return (q_hi, q_lo), (m_hi, m_lo, hi_idx, lo_idx, x.shape, axis)


def dequantize_leaf_nonuniform(packed, meta) -> jnp.ndarray:
    (q_hi, q_lo) = packed
    m_hi, m_lo, hi_idx, lo_idx, shape, axis = meta
    x_hi = dequantize_leaf(q_hi, m_hi)
    nch = hi_idx.shape[0] + (lo_idx.shape[0] if lo_idx is not None else 0)
    out = jnp.zeros((*x_hi.shape[:-1], nch), x_hi.dtype)
    out = out.at[..., hi_idx].set(x_hi)
    if q_lo is not None:
        out = out.at[..., lo_idx].set(dequantize_leaf(q_lo, m_lo))
    return out.reshape(shape)
