"""Batched serving engine: prefill + greedy/sampled decode over any arch.

The engine mirrors SQUASH's QA/QP division of labor (DESIGN.md §2): prefill
is the "allocator" phase (big parallel pass building per-request state), and
the decode loop is the "processor" phase (small steps against resident state
— the KV cache plays the role of the DRE warm container: pay the build cost
once, reuse it across invocations).

Optional OSQ-quantized KV cache (``kv_bits``): the paper's segment-packed
scalar quantization applied to the KV tensor — per-(head, channel) ranges,
``kv_bits``-bit codes packed ``32 // kv_bits`` to an int32 lane word
(beyond-paper feature; see EXPERIMENTS.md §Perf for the bandwidth math).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.serve.kv_quant import dequantize_caches, quantize_caches

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 → greedy
    kv_bits: int = 0                  # 0 → fp cache; 8/4 → OSQ-packed cache
    seed: int = 0


class Engine:
    """Holds params + jitted step functions for one architecture."""

    def __init__(self, cfg: ArchConfig, params, serve_cfg: ServeConfig = None):
        self.cfg = cfg
        self.params = params
        self.serve_cfg = serve_cfg or ServeConfig()
        self._prefill = jax.jit(
            functools.partial(T.prefill, cfg=cfg),
            static_argnames=("buf_len",))
        self._decode = jax.jit(functools.partial(self._decode_impl, cfg=cfg))

    @staticmethod
    def _decode_impl(params, tokens, caches, pos, *, cfg):
        return T.decode_step(params, tokens, caches, pos, cfg)

    def _sample(self, logits, key):
        sc = self.serve_cfg
        if sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / sc.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, *, max_new_tokens: int = 0,
                 embeds: Optional[np.ndarray] = None) -> np.ndarray:
        """prompts: (B, S) int32 (audio: (B, K, S)). Returns generated ids
        (B, n_new) (audio: (B, K, n_new))."""
        cfg, sc = self.cfg, self.serve_cfg
        n_new = max_new_tokens or sc.max_new_tokens
        audio = bool(cfg.num_codebooks)
        s0 = prompts.shape[-1]
        prefix = cfg.vlm_num_patches if cfg.mrope else 0
        buf_len = prefix + s0 + n_new
        logits, caches = self._prefill(
            self.params, jnp.asarray(prompts), buf_len=buf_len,
            embeds=None if embeds is None else jnp.asarray(embeds))
        if sc.kv_bits:
            qc, meta = quantize_caches(caches, sc.kv_bits)
            caches = dequantize_caches(qc, meta)
        key = jax.random.PRNGKey(sc.seed)
        outs = []
        tok = self._sample(logits[:, 0], key)           # (B,) or (B, K)
        for i in range(n_new):
            outs.append(np.asarray(tok))
            step_tok = tok[:, :, None] if audio else tok[:, None]
            key, sub = jax.random.split(key)
            logits, caches = self._decode(
                self.params, step_tok, caches, prefix + s0 + i)
            tok = self._sample(logits[:, 0] if not audio
                               else logits[:, 0], sub)
        arr = np.stack(outs, axis=-1)                   # (B, n) / (B, K, n)
        return arr
