"""Synthetic datasets: attributed vectors (SQUASH benchmarks) + token streams.

The container is offline, so SIFT1M/GIST1M/DEEP10M are stood in for by
clustered Gaussians with matching dimensionality and N scaled to the test
budget; attributes follow §5.1 (A = 4 uniform attributes, predicates tuned to
~8 % joint selectivity). Ground truth is exact brute force under the filter.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.attributes import Predicate

__all__ = [
    "VectorDataset",
    "make_vector_dataset",
    "default_predicates",
    "ground_truth",
    "DATASET_PRESETS",
    "token_batch",
]

# Paper Table 2 shapes (N scaled down by `scale` at generation time).
# ``lid`` mimics the paper's Local Intrinsic Dimensionality column: points are
# generated on a low-dimensional manifold within each cluster plus small
# ambient noise, so neighborhood structure matches the real benchmarks.
DATASET_PRESETS = {
    "sift1m": dict(n=1_000_000, d=128, clusters=64, lid=13),
    "gist1m": dict(n=1_000_000, d=960, clusters=64, lid=29),
    "sift10m": dict(n=10_000_000, d=128, clusters=128, lid=13),
    "deep10m": dict(n=10_000_000, d=96, clusters=128, lid=10),
}


@dataclasses.dataclass
class VectorDataset:
    name: str
    vectors: np.ndarray     # (N, d) float32
    attributes: np.ndarray  # (N, A) float64 (integer-valued uniform)
    queries: np.ndarray     # (Q, d) float32
    attr_cardinality: int

    @property
    def n(self) -> int:
        return int(self.vectors.shape[0])

    @property
    def d(self) -> int:
        return int(self.vectors.shape[1])


def make_vector_dataset(
    preset: str = "sift1m",
    scale: float = 0.02,
    num_queries: int = 100,
    num_attributes: int = 4,
    attr_cardinality: int = 16,
    seed: int = 0,
) -> VectorDataset:
    """Clustered-Gaussian stand-in for a paper dataset.

    ``scale`` shrinks N (default 2 % ⇒ 20 000 rows for the 1M presets) while
    keeping d faithful. Vectors are drawn from ``clusters`` anisotropic
    Gaussians — realistic local intrinsic dimensionality for partition/KLT
    behaviour. Queries are held-out draws from the same mixture.
    """
    spec = DATASET_PRESETS[preset]
    n = max(int(spec["n"] * scale), 1024)
    d = spec["d"]
    lid = spec["lid"]
    c = min(spec["clusters"], max(4, n // 256))
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 10.0, size=(c, d))
    # Low intrinsic dimensionality: each cluster lives on a ``lid``-dim
    # affine manifold (random basis, decaying energy) + small ambient noise,
    # matching the LID figures of Table 2 and giving real neighbor structure.
    bases = rng.normal(size=(c, lid, d)) / np.sqrt(d)
    energies = np.geomspace(4.0, 0.5, lid)
    which = rng.integers(0, c, size=n + num_queries)
    latent = rng.normal(size=(n + num_queries, lid)) * energies[None, :]
    ambient = rng.normal(size=(n + num_queries, d)) * 0.05
    pts = centers[which] + np.einsum("nl,nld->nd", latent, bases[which]) + ambient
    attrs = rng.integers(0, attr_cardinality, size=(n, num_attributes)).astype(
        np.float64
    )
    return VectorDataset(
        name=preset,
        vectors=pts[:n].astype(np.float32),
        attributes=attrs,
        queries=pts[n:].astype(np.float32),
        attr_cardinality=attr_cardinality,
    )


def default_predicates(
    attr_cardinality: int = 16,
    num_attributes: int = 4,
    target_selectivity: float = 0.08,
) -> List[Predicate]:
    """Conjunctive predicates with ≈8 % joint selectivity (paper §5.1).

    Per-attribute selectivity s = target^(1/A); each attribute gets a range
    predicate covering ⌈s·cardinality⌉ integer values.
    """
    s = target_selectivity ** (1.0 / num_attributes)
    width = max(1, int(round(s * attr_cardinality)))
    preds = []
    for a in range(num_attributes):
        lo = (a * 3) % max(attr_cardinality - width, 1)
        preds.append(Predicate(attr=a, op="B", lo=float(lo), hi=float(lo + width - 1)))
    return preds


def ground_truth(
    ds: VectorDataset, predicates: Sequence[Predicate], k: int = 10
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact filtered top-k by brute force. Returns (ids (Q,k), dists (Q,k))."""
    from repro.core.attributes import ground_truth_mask

    mask = ground_truth_mask(ds.attributes, predicates)
    idx = np.where(mask)[0]
    sub = ds.vectors[idx].astype(np.float64)
    out_ids = np.full((ds.queries.shape[0], k), -1, dtype=np.int64)
    out_d = np.full((ds.queries.shape[0], k), np.inf)
    for qi, q in enumerate(ds.queries.astype(np.float64)):
        dist = np.sqrt(((sub - q[None, :]) ** 2).sum(axis=1))
        kk = min(k, idx.size)
        best = np.argpartition(dist, kk - 1)[:kk]
        best = best[np.argsort(dist[best])]
        out_ids[qi, :kk] = idx[best]
        out_d[qi, :kk] = dist[best]
    return out_ids, out_d


def token_batch(
    batch: int, seq_len: int, vocab: int, seed: int = 0, shard: int = 0
) -> np.ndarray:
    """Deterministic per-shard token stream for LM training/smoke tests."""
    rng = np.random.default_rng(seed * 1_000_003 + shard)
    return rng.integers(0, vocab, size=(batch, seq_len), dtype=np.int32)
