"""Mamba2 (SSD — state-space duality) mixer: chunked train scan + O(1) decode.

Follows the SSD formulation of arXiv:2405.21060: the selective SSM is computed
chunk-wise — a quadratic *intra-chunk* term (a masked attention-like einsum
over chunk length, MXU-friendly) plus a linear *inter-chunk* recurrence over
per-chunk states carried by ``lax.scan``. Per-token decode maintains the
recurrent state ``(B, H, hd, N)`` explicitly, giving O(1) work per generated
token — this is what makes the ``long_500k`` shape native for SSM archs.

Conventions (n_groups = 1, B/C shared across heads, as in the 370m config):
  d_inner = expand · d_model,  H = d_inner / headdim,  N = ssm_state.
in_proj emits [z | x | B | C | dt]; a depthwise causal conv runs over
[x | B | C] channels; gated RMSNorm before out_proj.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.hints import hint

__all__ = ["init_mamba2", "mamba2_train", "mamba2_decode", "init_mamba2_cache",
           "ssd_chunked"]

# Intra-chunk SSD einsum dtype. fp32 is the correctness-safe default; the
# perf pass (EXPERIMENTS.md §Perf, mamba2 iteration) measures bf16 with the
# inter-chunk state kept fp32.
SSD_COMPUTE_DTYPE = jnp.float32

# Route the intra-chunk term through the fused Pallas kernel
# (kernels/ssd.py) instead of the jnp einsum chain. On TPU this keeps the
# (lc × lc) decay block in VMEM; on CPU the kernel runs interpret=True
# (slow — default off here, on for TPU deployments).
USE_PALLAS_INTRA = False


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_headdim
    return d_inner, heads, cfg.ssm_state, cfg.ssm_headdim


def init_mamba2(key, cfg: ArchConfig, dtype=jnp.float32):
    """Input projections are SPLIT (z | xBC | dt as separate matmuls) rather
    than one fused in_proj: fused output slices land at non-shard-aligned
    offsets under tensor parallelism and cost a collective-permute shuffle
    per slice per layer (EXPERIMENTS.md §Perf, mamba2 iteration 3). Three
    aligned projections shard cleanly and lower to zero resharding."""
    d = cfg.d_model
    d_inner, h, n, _ = _dims(cfg)
    conv_ch = d_inner + 2 * n
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "in_z": L.init_linear(k1, d, d_inner, dtype),
        "in_xbc": L.init_linear(k4, d, conv_ch, dtype),
        "in_dt": L.init_linear(k5, d, h, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, conv_ch),
                                     dtype=jnp.float32)
                   * (cfg.ssm_conv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype=dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((h,), dtype=jnp.float32),
        "norm": L.init_rms_norm(d_inner, dtype),
        "out_proj": L.init_linear(k3, d_inner, d, dtype),
    }


def _project_in(params, x):
    """Split input projections (see init_mamba2 docstring)."""
    return (L.linear(params["in_z"], x),
            L.linear(params["in_xbc"], x),
            L.linear(params["in_dt"], x))


def _causal_conv(xbc, conv_w, conv_b):
    """Depthwise causal conv over the sequence axis. xbc: (B, S, C)."""
    kw = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (kw - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * conv_w[i][None, None, :]
        for i in range(kw)
    )
    return jax.nn.silu(out + conv_b[None, None, :])


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{j < t <= i} x[..., t].

    x: (..., Lc) → (..., Lc, Lc) lower-triangular log-decay matrix.
    """
    lc = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(lc)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int,
                init_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    x: (B, S, H, P)  dt: (B, S, H)  a: (H,) (negative)
    b_mat/c_mat: (B, S, N)  (n_groups=1, broadcast over heads)
    Returns (y (B, S, H, P), final_state (B, H, P, N)).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    lc = min(chunk, s)
    pad = (-s) % lc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // lc

    xc = x.reshape(bsz, nc, lc, h, p)
    dtc = dt.reshape(bsz, nc, lc, h)
    bc = b_mat.reshape(bsz, nc, lc, n)
    cc = c_mat.reshape(bsz, nc, lc, n)

    da = dtc * a[None, None, None, :]                       # (B,nc,lc,H) ≤ 0
    a_cs = jnp.cumsum(da, axis=2)                           # within-chunk
    xdt = xc * dtc[..., None]

    # Intra-chunk (quadratic in lc — the "attention duality" term).
    ct = SSD_COMPUTE_DTYPE
    if USE_PALLAS_INTRA:
        from repro.kernels import ops as kops
        g = bsz * nc
        y_k = kops.ssd_intra(
            cc.reshape(g, lc, n).astype(jnp.float32),
            bc.reshape(g, lc, n).astype(jnp.float32),
            da.reshape(g, lc, h).transpose(0, 2, 1).astype(jnp.float32),
            xdt.reshape(g, lc, h, p).transpose(0, 2, 1, 3)
            .astype(jnp.float32))                       # (G, H, lc, P)
        y_diag = y_k.transpose(0, 2, 1, 3).reshape(bsz, nc, lc, h, p)
    else:
        decay = jnp.exp(_segsum(jnp.moveaxis(da, 3, 2)))    # (B,nc,H,lc,lc)
        y_diag = jnp.einsum("bcln,bcsn,bchls,bcshp->bclhp",
                            cc.astype(ct), bc.astype(ct), decay.astype(ct),
                            xdt.astype(ct)).astype(jnp.float32)

    # Per-chunk input → state contribution.
    decay_states = jnp.exp(a_cs[:, :, -1:, :] - a_cs)       # (B,nc,lc,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn",
                        bc, decay_states, xdt)              # (B,nc,H,P,N)

    # Inter-chunk recurrence (linear scan over chunks).
    chunk_decay = jnp.exp(a_cs[:, :, -1, :])                # (B,nc,H)
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), dtype=states.dtype)

    def step(carry, inp):
        st, dec = inp                                       # (B,H,P,N),(B,H)
        new = carry * dec[:, :, None, None] + st
        return new, carry                                    # emit *prior*

    final, prior = jax.lax.scan(
        step, init_state,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prior = jnp.moveaxis(prior, 0, 1)                       # (B,nc,H,P,N)

    # Inter-chunk output: prior state read out through C with in-chunk decay.
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp",
                       cc.astype(ct), prior.astype(ct),
                       jnp.exp(a_cs).astype(ct)).astype(jnp.float32)
    y = (y_diag + y_off).reshape(bsz, nc * lc, h, p)
    return y[:, :s], final


def mamba2_train(params, x, cfg: ArchConfig, init_state=None):
    """Full-sequence mixer. x: (B, S, d_model) → (B, S, d_model)."""
    d_inner, h, n, p = _dims(cfg)
    z, xbc, dt = _project_in(params, x)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs = xbc[..., :d_inner]
    b_mat = xbc[..., d_inner : d_inner + n]
    c_mat = xbc[..., d_inner + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    a = -jnp.exp(params["A_log"])
    # §Perf (mamba2 iteration): heads shard over 'model'; the slim shared
    # B/C/dt streams are replicated (n_groups=1 — every head reads them),
    # preventing GSPMD from resharding the fat xs stream instead.
    xs = hint(xs, "data", None, "model")
    b_mat = hint(b_mat, "data", None, None)
    c_mat = hint(c_mat, "data", None, None)
    xh = xs.reshape(*xs.shape[:2], h, p).astype(jnp.float32)
    xh = hint(xh, "data", None, "model", None)
    y, state = ssd_chunked(xh, dt, a, b_mat.astype(jnp.float32),
                           c_mat.astype(jnp.float32), cfg.ssm_chunk)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(*xs.shape[:2], d_inner).astype(x.dtype)
    y = L.rms_norm(params["norm"], y * jax.nn.silu(z))
    return L.linear(params["out_proj"], y), state


def init_mamba2_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    d_inner, h, n, p = _dims(cfg)
    conv_ch = d_inner + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype=dtype),
        "state": jnp.zeros((batch, h, p, n), dtype=jnp.float32),
    }


def mamba2_decode(params, x, cache, cfg: ArchConfig):
    """Single-token recurrent step. x: (B, 1, d_model)."""
    d_inner, h, n, p = _dims(cfg)
    bsz = x.shape[0]
    z, xbc, dt = _project_in(params, x[:, 0, :])
    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    xs = xbc[..., :d_inner]
    b_vec = xbc[..., d_inner : d_inner + n]
    c_vec = xbc[..., d_inner + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, :])
    da = jnp.exp(dt * (-jnp.exp(params["A_log"]))[None, :])     # (B,H)
    xh = xs.reshape(bsz, h, p)
    state = (cache["state"] * da[:, :, None, None]
             + jnp.einsum("bhp,bn,bh->bhpn", xh, b_vec, dt))
    y = jnp.einsum("bhpn,bn->bhp", state, c_vec)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = L.rms_norm(params["norm"], y * jax.nn.silu(z[:, None, :]))
    out = L.linear(params["out_proj"], y)
    return out, {"conv": window[:, 1:].astype(cache["conv"].dtype),
                 "state": state}
