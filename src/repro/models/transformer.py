"""Decoder assembly for every assigned architecture family.

One code path builds all ten configs. Layers are stacked on a leading axis and
applied with ``lax.scan`` (bounded HLO size / compile time at pod scale);
heterogeneous schedules scan over *repeating units*:

  dense / moe / ssm / vlm / audio — one homogeneous stack of ``num_layers``.
  gemma3 (local:global R:1)       — outer scan over units of (R local + 1
                                    global); remainder locals form a tail
                                    stack. Local layers keep ring caches of
                                    ``sliding_window``; globals keep full
                                    buffers — heterogeneous cache shapes are
                                    why the unit structure exists.
  zamba2 (hybrid)                 — outer scan over units of (E mamba blocks +
                                    1 *shared* attention+MLP block whose
                                    params are closure-captured, i.e. one
                                    weight set reused at every unit, per the
                                    Zamba2 design); remainder mamba tail.

Three entry points per model: ``forward_train`` (full-seq logits + aux loss),
``prefill`` (populate caches, last-token logits), ``decode_step`` (one token).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.hints import hint

__all__ = ["init_params", "forward_train", "prefill", "decode_step",
           "init_decode_caches", "make_positions", "vlm_positions_3d"]

_BIG_BUF = 1 << 30


# ======================================================================
# single blocks
# ======================================================================

def _block_kind(cfg: ArchConfig) -> str:
    if cfg.family == "ssm":
        return "mamba"
    if cfg.mla:
        return "mla"
    return "gqa"


def init_block(key, cfg: ArchConfig, dtype=jnp.float32,
               kind: Optional[str] = None):
    kind = kind or _block_kind(cfg)
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"norm1": L.init_rms_norm(cfg.d_model, dtype)}
    if kind == "mamba":
        p["mixer"] = S.init_mamba2(k1, cfg, dtype)
        if cfg.d_ff:
            p["norm2"] = L.init_rms_norm(cfg.d_model, dtype)
            p["ffn"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
        return p
    p["attn"] = (A.init_mla(k1, cfg, dtype) if kind == "mla"
                 else A.init_gqa(k1, cfg, dtype))
    p["norm2"] = L.init_rms_norm(cfg.d_model, dtype)
    if cfg.num_experts:
        p["ffn"] = M.init_moe(k2, cfg, dtype)
    else:
        p["ffn"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def block_train(params, x, positions, cfg: ArchConfig, *, window: int = 0,
                positions_3d=None, kind: Optional[str] = None):
    """(x, aux) → (y, aux). Full-sequence (train/prefill math, no cache)."""
    kind = kind or _block_kind(cfg)
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(params["norm1"], x, cfg.norm_eps)
    if kind == "mamba":
        mixed, _ = S.mamba2_train(params["mixer"], h, cfg)
        x = x + mixed
        if cfg.d_ff and "ffn" in params:
            h2 = L.rms_norm(params["norm2"], x, cfg.norm_eps)
            x = x + L.mlp(params["ffn"], h2)
        return x, aux
    if kind == "mla":
        x = x + A.mla_train(params["attn"], h, positions, cfg, window)
    else:
        x = x + A.gqa_train(params["attn"], h, positions, cfg, window,
                            positions_3d)
    h2 = L.rms_norm(params["norm2"], x, cfg.norm_eps)
    if cfg.num_experts:
        y, aux = M.moe_ffn(params["ffn"], h2, cfg)
        x = x + y
    else:
        x = x + L.mlp(params["ffn"], h2)
    return x, aux


def block_prefill(params, x, positions, cfg: ArchConfig, buf_len: int, *,
                  window: int = 0, positions_3d=None,
                  kind: Optional[str] = None):
    kind = kind or _block_kind(cfg)
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(params["norm1"], x, cfg.norm_eps)
    if kind == "mamba":
        d_inner, nh, n, pdim = S._dims(cfg)
        z, xbc, dt = S._project_in(params["mixer"], h)
        xbc_conv = S._causal_conv(xbc, params["mixer"]["conv_w"],
                                  params["mixer"]["conv_b"])
        xs = xbc_conv[..., :d_inner]
        b_mat = xbc_conv[..., d_inner : d_inner + n]
        c_mat = xbc_conv[..., d_inner + n :]
        dtf = jax.nn.softplus(dt.astype(jnp.float32)
                              + params["mixer"]["dt_bias"][None, None, :])
        a = -jnp.exp(params["mixer"]["A_log"])
        xh = xs.reshape(*xs.shape[:2], nh, pdim).astype(jnp.float32)
        y, state = S.ssd_chunked(xh, dtf, a, b_mat.astype(jnp.float32),
                                 c_mat.astype(jnp.float32), cfg.ssm_chunk)
        y = y + params["mixer"]["D"][None, None, :, None] * xh
        y = y.reshape(*xs.shape[:2], d_inner).astype(x.dtype)
        y = L.rms_norm(params["mixer"]["norm"], y * jax.nn.silu(z))
        x = x + L.linear(params["mixer"]["out_proj"], y)
        cache = {"conv": xbc[:, -(cfg.ssm_conv - 1):, :].astype(x.dtype),
                 "state": state}
        if cfg.d_ff and "ffn" in params:
            h2 = L.rms_norm(params["norm2"], x, cfg.norm_eps)
            x = x + L.mlp(params["ffn"], h2)
        return x, cache, aux
    if kind == "mla":
        y, cache = A.mla_prefill(params["attn"], h, positions, cfg, buf_len,
                                 window)
    else:
        y, cache = A.gqa_prefill(params["attn"], h, positions, cfg, buf_len,
                                 window, positions_3d)
    x = x + y
    h2 = L.rms_norm(params["norm2"], x, cfg.norm_eps)
    if cfg.num_experts:
        y2, aux = M.moe_ffn(params["ffn"], h2, cfg)
        x = x + y2
    else:
        x = x + L.mlp(params["ffn"], h2)
    return x, cache, aux


def block_decode(params, x, cache, pos, cfg: ArchConfig, *, window: int = 0,
                 kind: Optional[str] = None):
    kind = kind or _block_kind(cfg)
    h = L.rms_norm(params["norm1"], x, cfg.norm_eps)
    if kind == "mamba":
        mixed, cache = S.mamba2_decode(params["mixer"], h, cache, cfg)
        x = x + mixed
        if cfg.d_ff and "ffn" in params:
            h2 = L.rms_norm(params["norm2"], x, cfg.norm_eps)
            x = x + L.mlp(params["ffn"], h2)
        return x, cache
    if kind == "mla":
        y, cache = A.mla_decode(params["attn"], h, cache, pos, cfg, window)
    else:
        y, cache = A.gqa_decode(params["attn"], h, cache, pos, cfg, window)
    x = x + y
    h2 = L.rms_norm(params["norm2"], x, cfg.norm_eps)
    if cfg.num_experts:
        y2, _ = M.moe_ffn(params["ffn"], h2, cfg)
        x = x + y2
    else:
        x = x + L.mlp(params["ffn"], h2)
    return x, cache


def init_block_cache(cfg: ArchConfig, batch: int, buf_len: int,
                     dtype=jnp.float32, kind: Optional[str] = None):
    kind = kind or _block_kind(cfg)
    if kind == "mamba":
        return S.init_mamba2_cache(cfg, batch, dtype)
    if kind == "mla":
        return A.init_mla_cache(cfg, batch, buf_len, dtype)
    return A.init_gqa_cache(cfg, batch, buf_len, dtype)


# ======================================================================
# layer schedules
# ======================================================================

def _schedule(cfg: ArchConfig):
    """Returns (kind, counts...) describing the stacked-layer layout."""
    if cfg.family == "dense" and cfg.local_global_ratio:
        r = cfg.local_global_ratio
        units = cfg.num_layers // (r + 1)
        tail = cfg.num_layers - units * (r + 1)
        return ("local_global", r, units, tail)
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        e = cfg.hybrid_attn_every
        units = cfg.num_layers // e
        tail = cfg.num_layers - units * e
        return ("hybrid", e, units, tail)
    return ("uniform", cfg.num_layers)


def _stacked_init(key, n: int, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _window_for(cfg: ArchConfig) -> int:
    if cfg.attention == "sliding" and cfg.sliding_window:
        return cfg.sliding_window
    return 0


# ======================================================================
# params
# ======================================================================

def init_params(key, cfg: ArchConfig, dtype=jnp.float32):
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": L.init_rms_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_linear(keys[1], cfg.d_model,
                                          cfg.vocab_size, dtype)
    if cfg.num_codebooks:
        # MusicGen: K codebook embeddings (summed) + K output heads.
        params["cb_embed"] = jax.vmap(
            lambda k: L.init_embedding(k, cfg.vocab_size, cfg.d_model, dtype)
        )(jax.random.split(keys[2], cfg.num_codebooks))
        params["cb_head"] = jax.vmap(
            lambda k: L.init_linear(k, cfg.d_model, cfg.vocab_size, dtype)
        )(jax.random.split(keys[3], cfg.num_codebooks))
        del params["embed"]

    sched = _schedule(cfg)
    if sched[0] == "uniform":
        params["blocks"] = _stacked_init(
            keys[4], cfg.num_layers, lambda k: init_block(k, cfg, dtype))
    elif sched[0] == "local_global":
        _, r, units, tail = sched
        def unit_init(k):
            kl, kg = jax.random.split(k)
            return {
                "local": _stacked_init(kl, r,
                                       lambda kk: init_block(kk, cfg, dtype)),
                "global": init_block(kg, cfg, dtype),
            }
        params["units"] = _stacked_init(keys[4], units, unit_init)
        if tail:
            params["tail"] = _stacked_init(
                keys[5], tail, lambda k: init_block(k, cfg, dtype))
    else:  # hybrid
        _, e, units, tail = sched
        params["units"] = _stacked_init(
            keys[4], units,
            lambda k: _stacked_init(k, e,
                                    lambda kk: init_block(kk, cfg, dtype,
                                                          kind="mamba")))
        if tail:
            params["tail"] = _stacked_init(
                keys[5], tail,
                lambda k: init_block(k, cfg, dtype, kind="mamba"))
        # ONE shared attention+MLP block reused at every unit boundary.
        params["shared_attn"] = init_block(keys[6], cfg, dtype, kind="gqa")
    return params


# ======================================================================
# positions / embeddings helpers
# ======================================================================

def make_positions(batch: int, seq: int):
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None, :],
                            (batch, seq))


def vlm_positions_3d(batch: int, seq: int, num_patches: int):
    """Qwen2-VL M-RoPE ids: patch prefix gets (t=0, h, w) grid; text runs on.

    Returns (3, B, S) int32.
    """
    side = max(int(num_patches ** 0.5), 1)
    idx = jnp.arange(seq)
    in_img = idx < num_patches
    # Image patches: t = 0, (h, w) on the patch grid. Text: t = h = w = idx,
    # which makes M-RoPE coincide with 1-D RoPE for text (so the decode path,
    # which rotates with a scalar position, is exactly consistent).
    t = jnp.where(in_img, 0, idx)
    h = jnp.where(in_img, idx // side, idx)
    w = jnp.where(in_img, idx % side, idx)
    pos3 = jnp.stack([t, h, w]).astype(jnp.int32)        # (3, S)
    return jnp.broadcast_to(pos3[:, None, :], (3, batch, seq))


def _embed_inputs(params, tokens, cfg: ArchConfig, embeds=None):
    """tokens → (B, S, d). Audio sums K codebook embeddings; VLM prepends
    provided patch embeddings before the token embeddings."""
    if cfg.num_codebooks:
        # tokens: (B, K, S)
        embs = jax.vmap(L.embed, in_axes=(0, 1), out_axes=2)(
            params["cb_embed"], tokens)                  # (B, S, K, d)
        return hint(embs.sum(axis=2), "data", None, None)
    x = L.embed(params["embed"], tokens)                 # (B, S, d)
    if cfg.mrope and embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    # Pin the embed output to batch-over-`data` before it reaches any block
    # scan: left to GSPMD, the vocab-sharded embedding gather feeding a
    # lax.scan over stacked MLA blocks miscompiles on host-device meshes
    # (mean |Δ|≈0.4 — repro pinned in
    # test_sharded_mla_scan_after_embed_repro). The constraint is the
    # sharding batch_shardings assigns activations anyway and a no-op
    # without an ambient mesh; applied here so train, prefill and decode
    # all get it.
    return hint(x, "data", None, None)


def _lm_logits(params, x, cfg: ArchConfig):
    if cfg.num_codebooks:
        return jnp.einsum("bsd,kdv->bskv", x,
                          params["cb_head"]["w"])        # (B, S, K, V)
    if cfg.tie_embeddings:
        return x @ params["embed"]["table"].T
    return L.linear(params["lm_head"], x)


# ======================================================================
# forward (train)
# ======================================================================

def forward_train(params, tokens, cfg: ArchConfig, *, embeds=None,
                  remat: bool = True, unroll: bool = False):
    """Full-sequence forward. Returns (logits, aux_loss)."""
    x = _embed_inputs(params, tokens, cfg, embeds)
    b, s = x.shape[:2]
    positions = make_positions(b, s)
    pos3 = (vlm_positions_3d(b, s, cfg.vlm_num_patches)
            if cfg.mrope else None)
    window = _window_for(cfg)
    sched = _schedule(cfg)
    aux = jnp.zeros((), jnp.float32)

    def scan_stack(x, stacked, *, kind=None, window=0):
        def body(carry, lp):
            y, a = block_train(lp, carry, positions, cfg, window=window,
                               positions_3d=pos3, kind=kind)
            return y, a
        if remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, stacked, unroll=unroll)
        return x, auxs.sum()

    if sched[0] == "uniform":
        x, a = scan_stack(x, params["blocks"], window=window)
        aux += a
    elif sched[0] == "local_global":
        _, r, units, tail = sched
        win = cfg.sliding_window

        def unit_body(carry, up):
            y, a1 = scan_stack(carry, up["local"], window=win)
            y, a2 = block_train(up["global"], y, positions, cfg, window=0,
                                positions_3d=pos3)
            return y, a1 + a2
        if remat:
            unit_body = jax.checkpoint(unit_body)
        x, auxs = jax.lax.scan(unit_body, x, params["units"], unroll=unroll)
        aux += auxs.sum()
        if tail:
            x, a = scan_stack(x, params["tail"], window=win)
            aux += a
    else:  # hybrid
        _, e, units, tail = sched
        shared = params["shared_attn"]

        def unit_body(carry, up):
            y, a1 = scan_stack(carry, up, kind="mamba")
            y, a2 = block_train(shared, y, positions, cfg, kind="gqa")
            return y, a1 + a2
        if remat:
            unit_body = jax.checkpoint(unit_body)
        x, auxs = jax.lax.scan(unit_body, x, params["units"], unroll=unroll)
        aux += auxs.sum()
        if tail:
            x, a = scan_stack(x, params["tail"], kind="mamba")
            aux += a

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return _lm_logits(params, x, cfg), aux


# ======================================================================
# prefill
# ======================================================================

def prefill(params, tokens, cfg: ArchConfig, *, buf_len: Optional[int] = None,
            embeds=None, unroll: bool = False):
    """Populate all caches; return (last-token logits, caches pytree)."""
    x = _embed_inputs(params, tokens, cfg, embeds)
    b, s = x.shape[:2]
    buf_len = buf_len or s
    positions = make_positions(b, s)
    pos3 = (vlm_positions_3d(b, s, cfg.vlm_num_patches)
            if cfg.mrope else None)
    window = _window_for(cfg)
    sched = _schedule(cfg)
    caches: Dict[str, Any] = {}

    def scan_stack(x, stacked, *, kind=None, window=0, buf=None):
        def body(carry, lp):
            y, cache, _ = block_prefill(lp, carry, positions, cfg,
                                        buf if buf is not None else buf_len,
                                        window=window, positions_3d=pos3,
                                        kind=kind)
            return y, cache
        return jax.lax.scan(body, x, stacked, unroll=unroll)

    if sched[0] == "uniform":
        x, caches["blocks"] = scan_stack(x, params["blocks"], window=window)
    elif sched[0] == "local_global":
        _, r, units, tail = sched
        win = cfg.sliding_window
        wbuf = min(win, buf_len)

        def unit_body(carry, up):
            y, lc = scan_stack(carry, up["local"], window=win, buf=wbuf)
            y, gc, _ = block_prefill(up["global"], y, positions, cfg,
                                     buf_len, window=0, positions_3d=pos3)
            return y, {"local": lc, "global": gc}
        x, caches["units"] = jax.lax.scan(unit_body, x, params["units"],
                                          unroll=unroll)
        if tail:
            x, caches["tail"] = scan_stack(x, params["tail"], window=win,
                                           buf=wbuf)
    else:  # hybrid
        _, e, units, tail = sched
        shared = params["shared_attn"]

        def unit_body(carry, up):
            y, mc = scan_stack(carry, up, kind="mamba")
            y, ac, _ = block_prefill(shared, y, positions, cfg, buf_len,
                                     kind="gqa")
            return y, {"mamba": mc, "attn": ac}
        x, caches["units"] = jax.lax.scan(unit_body, x, params["units"],
                                          unroll=unroll)
        if tail:
            x, caches["tail"] = scan_stack(x, params["tail"], kind="mamba")

    x = L.rms_norm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return _lm_logits(params, x, cfg), caches


# ======================================================================
# decode
# ======================================================================

def init_decode_caches(cfg: ArchConfig, batch: int, buf_len: int,
                       dtype=jnp.float32):
    """Cache pytree matching :func:`prefill` layout (for decode dry-runs)."""
    sched = _schedule(cfg)
    window = _window_for(cfg)
    buf = min(window, buf_len) if window else buf_len

    def stack(n, fn):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), fn())

    if sched[0] == "uniform":
        return {"blocks": stack(
            cfg.num_layers,
            lambda: init_block_cache(cfg, batch, buf, dtype))}
    if sched[0] == "local_global":
        _, r, units, tail = sched
        wbuf = min(cfg.sliding_window, buf_len)
        unit = lambda: {
            "local": stack(r, lambda: init_block_cache(cfg, batch, wbuf,
                                                       dtype, kind="gqa")),
            "global": init_block_cache(cfg, batch, buf_len, dtype,
                                       kind="gqa"),
        }
        out = {"units": stack(units, unit)}
        if tail:
            out["tail"] = stack(tail, lambda: init_block_cache(
                cfg, batch, wbuf, dtype, kind="gqa"))
        return out
    _, e, units, tail = sched
    unit = lambda: {
        "mamba": stack(e, lambda: init_block_cache(cfg, batch, 0, dtype,
                                                   kind="mamba")),
        "attn": init_block_cache(cfg, batch, buf_len, dtype, kind="gqa"),
    }
    out = {"units": stack(units, unit)}
    if tail:
        out["tail"] = stack(tail, lambda: init_block_cache(
            cfg, batch, 0, dtype, kind="mamba"))
    return out


def decode_step(params, tokens, caches, pos, cfg: ArchConfig, *,
                unroll: bool = False):
    """One decode step. tokens: (B, 1) (or (B, K, 1) audio); pos scalar."""
    if cfg.num_codebooks:
        embs = jax.vmap(L.embed, in_axes=(0, 1), out_axes=2)(
            params["cb_embed"], tokens)
        x = embs.sum(axis=2)
    else:
        x = L.embed(params["embed"], tokens)
    window = _window_for(cfg)
    sched = _schedule(cfg)
    new_caches: Dict[str, Any] = {}

    def scan_stack(x, stacked, cstack, *, kind=None, window=0):
        def body(carry, inp):
            lp, lc = inp
            y, nc = block_decode(lp, carry, lc, pos, cfg, window=window,
                                 kind=kind)
            return y, nc
        return jax.lax.scan(body, x, (stacked, cstack), unroll=unroll)

    if sched[0] == "uniform":
        x, new_caches["blocks"] = scan_stack(
            x, params["blocks"], caches["blocks"], window=window)
    elif sched[0] == "local_global":
        _, r, units, tail = sched
        win = cfg.sliding_window

        def unit_body(carry, inp):
            up, uc = inp
            y, lc = scan_stack(carry, up["local"], uc["local"], window=win)
            y, gc = block_decode(up["global"], y, uc["global"], pos, cfg,
                                 window=0)
            return y, {"local": lc, "global": gc}
        x, new_caches["units"] = jax.lax.scan(
            unit_body, x, (params["units"], caches["units"]), unroll=unroll)
        if tail:
            x, new_caches["tail"] = scan_stack(
                x, params["tail"], caches["tail"], window=win)
    else:  # hybrid
        _, e, units, tail = sched
        shared = params["shared_attn"]

        def unit_body(carry, inp):
            up, uc = inp
            y, mc = scan_stack(carry, up, uc["mamba"], kind="mamba")
            y, ac = block_decode(shared, y, uc["attn"], pos, cfg, kind="gqa")
            return y, {"mamba": mc, "attn": ac}
        x, new_caches["units"] = jax.lax.scan(
            unit_body, x, (params["units"], caches["units"]), unroll=unroll)
        if tail:
            x, new_caches["tail"] = scan_stack(
                x, params["tail"], caches["tail"], kind="mamba")

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return _lm_logits(params, x, cfg), new_caches
