"""Optional GSPMD sharding hints inside model code.

``hint(x, *spec)`` applies ``with_sharding_constraint`` only when the
surrounding (abstract) mesh actually defines the named axes — so the same
model code runs unannotated on a single host device and fully annotated
under the production mesh. Perf-pass iterations (EXPERIMENTS.md §Perf) toggle
these via ``HINTS_ENABLED``.
"""

from __future__ import annotations

import warnings

import jax
from jax.sharding import PartitionSpec as P

HINTS_ENABLED = True


def ambient_mesh_sizes() -> dict:
    """Axis-name → size of the mesh in scope at trace time ({} if none).

    ``get_abstract_mesh()`` does not reflect a ``with mesh:`` context in
    JAX 0.8, so we fall back to the (deprecated but functional)
    thread-resources mesh.
    """
    # Narrowed to the shapes jax version drift actually produces: a missing
    # accessor (AttributeError), a signature change (TypeError), or a mesh
    # object refusing the query (ValueError/RuntimeError). Anything else —
    # a genuine bug — propagates instead of being silently eaten.
    try:
        am = jax.sharding.get_abstract_mesh()
        if getattr(am, "axis_names", ()):
            return dict(zip(am.axis_names, am.axis_sizes))
    except (AttributeError, TypeError, ValueError, RuntimeError):
        pass
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            pm = jax.interpreters.pxla.thread_resources.env.physical_mesh
        if pm is not None and pm.axis_names:
            return dict(pm.shape)
    except (AttributeError, TypeError, ValueError, RuntimeError):
        pass
    return {}


def _axes_of(spec_entry):
    if spec_entry is None:
        return ()
    if isinstance(spec_entry, tuple):
        return spec_entry
    return (spec_entry,)


def hint(x, *spec):
    """Constrain ``x`` to PartitionSpec(*spec); silently no-op when the
    ambient mesh (trace-time context) doesn't define the axes — i.e. on a
    plain single-device jit."""
    if not HINTS_ENABLED:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, TypeError, KeyError, RuntimeError):
        # The no-mesh / unknown-axis rejection varies by jax version;
        # anything outside these (e.g. a tracer leak) should propagate.
        return x
