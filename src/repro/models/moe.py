"""Mixture-of-Experts FFN: top-k routing, shared experts, dense residual.

TPU-native dispatch (DESIGN.md §2): we deliberately avoid the GShard one-hot
dispatch einsum — its (tokens, E, capacity) tensor is quadratic in routing
fan-out and blows past HBM at pod scale. Instead dispatch is **gather-based**:

  1. router top-k → flat (T·K,) expert assignments,
  2. capacity slots via a stable-sort rank (tokens beyond ``capacity`` drop,
     as in Switch/GShard capacity-factor semantics),
  3. ``dispatch_idx (E, C)`` gathers token states → (E, C, d),
  4. one grouped einsum per weight over the stacked expert tensors (the MXU
     sees E independent (C × d) @ (d × f) matmuls),
  5. scatter-add combine weighted by router probabilities.

Expert weight tensors are stacked on a leading E axis — the natural
expert-parallel sharding axis (E over ``model``). The gathers/scatters lower
to all-to-all under GSPMD when tokens and experts live on different axes.

Load-balance aux loss follows Switch Transformers (mean fraction × mean
router prob per expert, scaled by E).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.hints import hint

__all__ = ["init_moe", "moe_ffn"]


def _init_expert_stack(key, e: int, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_ff = d_ff ** -0.5

    def mk(k, shape, scale):
        return (jax.random.normal(k, shape, dtype=jnp.float32) * scale).astype(dtype)

    return {
        "gate": mk(k1, (e, d_model, d_ff), s_in),
        "up": mk(k2, (e, d_model, d_ff), s_in),
        "down": mk(k3, (e, d_ff, d_model), s_ff),
    }


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32):
    e = cfg.num_experts
    keys = jax.random.split(key, 4)
    params = {
        "router": L.init_linear(keys[0], cfg.d_model, e, jnp.float32),
        "experts": _init_expert_stack(keys[1], e, cfg.d_model, cfg.d_ff, dtype),
    }
    if cfg.num_shared_experts:
        params["shared"] = L.init_mlp(
            keys[2], cfg.d_model, cfg.d_ff * cfg.num_shared_experts, dtype)
    if cfg.moe_dense_residual:
        params["dense"] = L.init_mlp(keys[3], cfg.d_model, cfg.d_ff, dtype)
    return params


def _capacity(cfg: ArchConfig, tokens: int) -> int:
    cap = int(cfg.capacity_factor * tokens * cfg.top_k / cfg.num_experts)
    return max(cap, cfg.top_k)


def moe_ffn(params, x, cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE feed-forward. x: (B, S, d) → (y (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)
    c = _capacity(cfg, t)

    # --- routing (fp32 for a stable softmax) --------------------------------
    logits = L.linear(params["router"], xt.astype(jnp.float32))     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                          # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(axis=-1, keepdims=True), 1e-9)

    # --- aux load-balance loss (Switch eq. 4) -------------------------------
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(top_e[:, 0], e)), axis=0)                   # top-1 share
    mean_prob = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_weight * e * jnp.sum(frac_tokens * mean_prob)

    # --- capacity slots: stable sort by expert, rank within expert ----------
    flat_e = top_e.reshape(-1)                                      # (T·K,)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank of each sorted entry within its expert run
    idx = jnp.arange(t * k)
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank_sorted = idx - seg_start[sorted_e]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)   # unsort
    keep = rank < c
    slot = flat_e * c + rank                                        # (T·K,)
    slot = jnp.where(keep, slot, e * c)                             # drop → pad

    # --- dispatch: gather tokens into (E·C, d) ------------------------------
    tok_for_slot = jnp.full((e * c + 1,), t, dtype=jnp.int32)       # pad row
    tok_for_slot = tok_for_slot.at[slot].set(flat_tok.astype(jnp.int32))
    tok_for_slot = tok_for_slot[: e * c]
    # Masked safe-gather, NOT a concat-padded gather: gathering through a
    # concatenate whose axis-0 operand is sharded diverges under GSPMD
    # (tests/test_multidevice.py::test_sharded_moe_dispatch_gather_repro —
    # this was the 2×2-mesh MoE forward divergence).
    empty_slot = tok_for_slot >= t
    dispatched = jnp.where(
        empty_slot[:, None], 0.0,
        xt[jnp.where(empty_slot, 0, tok_for_slot)]).reshape(e, c, d)
    # EXPERIMENTS.md §Perf (arctic-480b iteration 1): without this
    # constraint GSPMD replicates the dispatch buffer per device. Only
    # worth it at train/prefill token counts — at decode (t = batch) the
    # buffers are small and the constraint forces needless resharding.
    big = t >= 4096
    if big:
        dispatched = hint(dispatched, "model", None, None)

    # --- grouped expert SwiGLU (one einsum per weight, E-stacked) -----------
    w = params["experts"]
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", dispatched, w["gate"]))
    u = jnp.einsum("ecd,edf->ecf", dispatched, w["up"])
    out = jnp.einsum("ecf,efd->ecd", g * u, w["down"])              # (E, C, d)
    if big:
        out = hint(out, "model", None, None)

    # --- combine: scatter-add weighted expert outputs back to tokens --------
    # Same masked-gather form as dispatch (dropped entries have slot == E·C
    # and are zeroed by the `keep` mask below anyway).
    out_flat = out.reshape(e * c, d)
    gathered = out_flat[jnp.where(slot >= e * c, 0, slot)]          # (T·K, d)
    weighted = gathered * flat_p[:, None].astype(gathered.dtype)
    y = jnp.zeros((t, d), x.dtype).at[flat_tok].add(
        jnp.where(keep[:, None], weighted, 0.0).astype(x.dtype))
    if big:
        y = hint(y, "data", None)

    # --- shared experts & dense residual (DeepSeek / Arctic variants) -------
    if "shared" in params:
        y = y + L.mlp(params["shared"], xt)
    if "dense" in params:
        y = y + L.mlp(params["dense"], xt)
    return y.reshape(b, s, d), aux
