"""Attention blocks: GQA/MQA (+ sliding window, M-RoPE) and MLA (DeepSeek-V2).

Three modes share one code path per variant:
  * ``train``   — full-sequence causal, no cache.
  * ``prefill`` — full-sequence causal, returns the populated KV cache.
  * ``decode``  — one new token against a cache (ring buffer for windowed
    layers, full buffer otherwise).

Memory discipline: prefill/train attention is **query-chunked** (lax.scan over
query blocks) so the (S × S) score matrix never materializes — peak scores are
(chunk × S). Decode for MLA uses the *absorbed* form (q projected into latent
space) so per-step compute is O(S · kv_lora), never materializing per-head keys.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.hints import ambient_mesh_sizes, hint

__all__ = [
    "init_gqa", "gqa_train", "gqa_prefill", "gqa_decode", "init_gqa_cache",
    "init_mla", "mla_train", "mla_prefill", "mla_decode", "init_mla_cache",
]

_NEG = -1e9
# Module-level so the roofline harness can disable chunking: the q-chunk
# lax.scan body is counted ONCE by XLA cost_analysis, so accurate-FLOPs
# compiles set Q_CHUNK >= seq_len (scan length 1). Production default 512
# bounds the live score block to (512 x S).
Q_CHUNK = 512


def _heads_need_pinning(num_heads: int, num_kv: int) -> bool:
    """Pin kv-group sharding iff (a) a 'model' mesh axis exists, (b) it does
    NOT divide num_heads (GSPMD would shard head_dim and all-reduce S×S
    scores), and (c) padding kv heads up to the axis wastes ≤ 2×
    (measured: kv=2 padded 8× regresses qwen2-vl train +226 %;
    kv=8 padded 2× wins arctic −73 % — EXPERIMENTS.md §Perf D)."""
    m = ambient_mesh_sizes().get("model", 0)
    return bool(m) and num_heads % m != 0 and 2 * num_kv >= m


# ---------------------------------------------------------------- core attend

def _attend(q, k, v, q_pos, k_pos, window: int, q_chunk: int = 0):
    """Chunked masked attention.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd); q_pos: (B, Sq); k_pos: (B, Sk).
    Causal + optional sliding window; k_pos < 0 marks invalid slots.
    Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    vd = v.shape[-1]
    g = h // kv
    scale = hd ** -0.5
    qc = min(q_chunk or Q_CHUNK, sq)
    pad = (-sq) % qc
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    nq = q.shape[1] // qc
    qs = q.reshape(b, nq, qc, kv, g, hd)
    qps = q_pos.reshape(b, nq, qc)
    if sq > 1 and _heads_need_pinning(h, kv):
        # Train/prefill, ONLY when q-heads don't divide the model axis (then
        # GSPMD may shard head_dim and all-reduce the full (S × S) score
        # tensor — 60 GB/layer on arctic prefill, EXPERIMENTS.md §Perf D):
        # pin the kv-group axis to the model shards (padded) so the score
        # einsum contracts an UNsharded head_dim. When heads divide evenly
        # GSPMD's own choice is better — forcing kv padding there REGRESSES
        # (llama3 train +33×, measured). Decode (sq == 1) uses the
        # seq-sharded cache layout instead.
        qs = hint(qs, "data", None, None, "model", None, None)
        k = hint(k, "data", None, "model", None)
        v = hint(v, "data", None, "model", None)

    def chunk(carry, xs):
        qi, qp = xs                                   # (B,qc,KV,g,hd), (B,qc)
        # Operands stay in their storage dtype (bf16 on TPU) with fp32 MXU
        # accumulation — an upfront .astype(f32) would force any GSPMD
        # cache gather to move twice the bytes (EXPERIMENTS.md §Perf B-2).
        s = jnp.einsum("bqkgh,bskh->bkgqs", qi, k,
                       preferred_element_type=jnp.float32) * scale
        mask = (k_pos[:, None, :] <= qp[:, :, None]) & (k_pos[:, None, :] >= 0)
        if window:
            mask &= k_pos[:, None, :] > (qp[:, :, None] - window)
        mask &= (qp[:, :, None] >= 0)
        s = jnp.where(mask[:, None, None, :, :], s, _NEG)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return carry, o

    _, outs = jax.lax.scan(
        chunk, None,
        (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(qps, 1, 0)),
    )                                                  # (nq, B, qc, KV, g, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * qc, h, vd)
    return out[:, :sq].astype(v.dtype)


# ----------------------------------------------------------------------- GQA

def init_gqa(key, cfg: ArchConfig, dtype=jnp.float32):
    d, h, kv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": L.init_linear(k1, d, h * hd, dtype),
        "wk": L.init_linear(k2, d, kv * hd, dtype),
        "wv": L.init_linear(k3, d, kv * hd, dtype),
        "wo": L.init_linear(k4, h * hd, d, dtype),
    }


def _qkv(params, x, positions, cfg: ArchConfig, positions_3d=None):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = L.linear(params["wq"], x).reshape(b, s, h, hd)
    k = L.linear(params["wk"], x).reshape(b, s, kv, hd)
    v = L.linear(params["wv"], x).reshape(b, s, kv, hd)
    if cfg.mrope and positions_3d is not None:
        q = L.apply_mrope(q, positions_3d, cfg.rope_theta)
        k = L.apply_mrope(k, positions_3d, cfg.rope_theta)
    else:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_train(params, x, positions, cfg: ArchConfig, window: int = 0,
              positions_3d=None):
    q, k, v = _qkv(params, x, positions, cfg, positions_3d)
    out = _attend(q, k, v, positions, positions, window)
    return L.linear(params["wo"], out.reshape(*x.shape[:2], -1))


def init_gqa_cache(cfg: ArchConfig, batch: int, buf_len: int, dtype=jnp.float32):
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, buf_len, kv, hd), dtype=dtype),
        "v": jnp.zeros((batch, buf_len, kv, hd), dtype=dtype),
    }


def gqa_prefill(params, x, positions, cfg: ArchConfig, buf_len: int,
                window: int = 0, positions_3d=None):
    """Full-seq attention + cache population. Returns (y, cache)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, positions, cfg, positions_3d)
    out = _attend(q, k, v, positions, positions, window)
    y = L.linear(params["wo"], out.reshape(b, s, -1))
    if buf_len >= s:
        ck = jnp.pad(k, ((0, 0), (0, buf_len - s), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, buf_len - s), (0, 0), (0, 0)))
    else:  # ring buffer keeps the trailing ``buf_len`` positions
        tail_k = k[:, s - buf_len:]
        tail_v = v[:, s - buf_len:]
        roll = s % buf_len
        ck = jnp.roll(tail_k, roll, axis=1)
        cv = jnp.roll(tail_v, roll, axis=1)
    return y, {"k": ck.astype(x.dtype), "v": cv.astype(x.dtype)}


def gqa_decode(params, x, cache, pos, cfg: ArchConfig, window: int = 0):
    """One-token step. ``pos`` is the absolute position of the new token.

    Full buffers place token at slot ``pos``; windowed (ring) buffers at
    ``pos % buf_len`` with slot→position recovered arithmetically.
    """
    b = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    buf = cache["k"].shape[1]
    posv = jnp.full((b, 1), pos, dtype=jnp.int32)
    q = L.linear(params["wq"], x).reshape(b, 1, h, hd)
    k = L.linear(params["wk"], x).reshape(b, 1, kv, hd)
    v = L.linear(params["wv"], x).reshape(b, 1, kv, hd)
    q = L.apply_rope(q, posv, cfg.rope_theta)
    k = L.apply_rope(k, posv, cfg.rope_theta)
    slot = pos % buf if window else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    idx = jnp.arange(buf)
    if window:
        # slot i holds absolute position pos − ((pos − i) mod buf).
        k_pos = pos - jnp.mod(pos - idx, buf)
    else:
        k_pos = jnp.where(idx <= pos, idx, -1)
    k_pos = jnp.broadcast_to(k_pos[None, :], (b, buf)).astype(jnp.int32)
    out = _attend(q, ck, cv, posv, k_pos, window, q_chunk=1)
    y = L.linear(params["wo"], out.reshape(b, 1, -1))
    return y, {"k": ck, "v": cv}


# ----------------------------------------------------------------------- MLA

def init_mla(key, cfg: ArchConfig, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.num_heads
    nope, rope, vd, lora = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                            cfg.kv_lora_rank)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "wq": L.init_linear(k1, d, h * (nope + rope), dtype),
        "w_dkv": L.init_linear(k2, d, lora + rope, dtype),   # latent + shared k_rope
        "w_uk": L.init_linear(k3, lora, h * nope, dtype),
        "w_uv": L.init_linear(k4, lora, h * vd, dtype),
        "wo": L.init_linear(k5, h * vd, d, dtype),
    }


def _mla_qkv_full(params, x, positions, cfg: ArchConfig):
    """Materialized (train/prefill) form: build per-head k, v from the latent."""
    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rope, vd, lora = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                            cfg.kv_lora_rank)
    q = L.linear(params["wq"], x).reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    dkv = L.linear(params["w_dkv"], x)                       # (B,S,lora+rope)
    latent, k_rope = dkv[..., :lora], dkv[..., lora:]
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    k_nope = L.linear(params["w_uk"], latent).reshape(b, s, h, nope)
    v = L.linear(params["w_uv"], latent).reshape(b, s, h, vd)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope))], axis=-1)
    return q_full, k_full, v, latent, k_rope[:, :, 0, :]


def mla_train(params, x, positions, cfg: ArchConfig, window: int = 0):
    q, k, v, _, _ = _mla_qkv_full(params, x, positions, cfg)
    out = _attend(q, k, v, positions, positions, window)
    return L.linear(params["wo"], out.reshape(*x.shape[:2], -1))


def init_mla_cache(cfg: ArchConfig, batch: int, buf_len: int, dtype=jnp.float32):
    return {
        "latent": jnp.zeros((batch, buf_len, cfg.kv_lora_rank), dtype=dtype),
        "k_rope": jnp.zeros((batch, buf_len, cfg.qk_rope_dim), dtype=dtype),
    }


def mla_prefill(params, x, positions, cfg: ArchConfig, buf_len: int,
                window: int = 0):
    b, s, _ = x.shape
    q, k, v, latent, k_rope = _mla_qkv_full(params, x, positions, cfg)
    out = _attend(q, k, v, positions, positions, window)
    y = L.linear(params["wo"], out.reshape(b, s, -1))
    pad = buf_len - s
    cache = {
        "latent": jnp.pad(latent, ((0, 0), (0, pad), (0, 0))).astype(x.dtype),
        "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))).astype(x.dtype),
    }
    return y, cache


def mla_decode(params, x, cache, pos, cfg: ArchConfig, window: int = 0):
    """Absorbed-MLA decode: scores/context live in the kv_lora latent space.

    score_h(t) = q_nope_h · (W_uk latent_t)  +  q_rope_h · k_rope_t
               = (W_uk^T q_nope_h) · latent_t + q_rope_h · k_rope_t
    ctx_h      = Σ_t p_t latent_t  →  out_h = W_uv ctx_h
    Per-step memory is O(S · lora), independent of head count.
    """
    b = x.shape[0]
    h = cfg.num_heads
    nope, rope, vd, lora = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                            cfg.kv_lora_rank)
    buf = cache["latent"].shape[1]
    posv = jnp.full((b, 1), pos, dtype=jnp.int32)
    q = L.linear(params["wq"], x).reshape(b, 1, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, posv, cfg.rope_theta)
    dkv = L.linear(params["w_dkv"], x)
    latent_new, k_rope_new = dkv[..., :lora], dkv[..., lora:]
    k_rope_new = L.apply_rope(k_rope_new[:, :, None, :], posv, cfg.rope_theta)
    c_lat = jax.lax.dynamic_update_slice(
        cache["latent"], latent_new.astype(cache["latent"].dtype), (0, pos, 0))
    c_kr = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new[:, :, 0, :].astype(cache["k_rope"].dtype),
        (0, pos, 0))
    # Absorb W_uk into the query.
    w_uk = params["w_uk"]["w"].reshape(lora, h, nope)
    q_lat = jnp.einsum("bqhn,lhn->bhql", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))             # (B,h,1,lora)
    s_lat = jnp.einsum("bhql,bsl->bhqs", q_lat,
                       c_lat.astype(jnp.float32))
    s_rope = jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                        c_kr.astype(jnp.float32))
    scale = (nope + rope) ** -0.5
    s = (s_lat + s_rope) * scale
    idx = jnp.arange(buf)
    mask = (idx <= pos)
    if window:
        mask &= idx > (pos - window)
    s = jnp.where(mask[None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsl->bhql", p, c_lat.astype(jnp.float32))
    w_uv = params["w_uv"]["w"].reshape(lora, h, vd)
    out = jnp.einsum("bhql,lhv->bqhv", ctx, w_uv.astype(jnp.float32))
    y = L.linear(params["wo"], out.reshape(b, 1, h * vd).astype(x.dtype))
    return y, {"latent": c_lat, "k_rope": c_kr}
