"""Shared neural-net layers: RMSNorm, RoPE (+M-RoPE), SwiGLU MLP, embeddings.

Pure-functional: every layer is an ``init_*`` returning a param pytree and an
``apply`` taking (params, inputs). Weight layout favors 2-D matmuls whose
contraction dims are multiples of 128 (MXU-aligned) wherever the public spec
allows.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm", "init_rms_norm",
    "init_linear", "linear",
    "init_mlp", "mlp",
    "init_embedding", "embed",
    "rope_frequencies", "apply_rope", "apply_mrope",
    "cross_entropy_loss",
]


# ------------------------------------------------------------------- RMSNorm

def init_rms_norm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rms_norm(params, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# -------------------------------------------------------------------- Linear

def init_linear(key, d_in: int, d_out: int, dtype=jnp.float32,
                scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    return {"w": w.astype(dtype)}


def linear(params, x):
    return x @ params["w"]


# -------------------------------------------------------------------- SwiGLU

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d_model, d_ff, dtype),
        "up": init_linear(k2, d_model, d_ff, dtype),
        "down": init_linear(k3, d_ff, d_model, dtype),
    }


def mlp(params, x):
    g = jax.nn.silu(linear(params["gate"], x))
    u = linear(params["up"], x)
    return linear(params["down"], g * u)


# ---------------------------------------------------------------- Embeddings

def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    w = jax.random.normal(key, (vocab, d_model), dtype=jnp.float32) * 0.02
    return {"table": w.astype(dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


# ---------------------------------------------------------------------- RoPE

def rope_frequencies(head_dim: int, theta: float = 10_000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _rotate(x, cos, sin):
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (B, S, H, hd); positions: (B, S) int."""
    freqs = rope_frequencies(x.shape[-1], theta)                  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(x, positions_3d, theta: float = 10_000.0):
    """Multimodal RoPE (Qwen2-VL): three position streams (t, h, w).

    x: (B, S, H, hd); positions_3d: (3, B, S). The rotary half-dim is split
    into three contiguous sections, each rotated by its own position stream
    (text tokens carry t = h = w, recovering 1-D RoPE exactly).
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = rope_frequencies(hd, theta)                           # (half,)
    s1 = half // 3
    s2 = (half - s1) // 2
    sections = [s1, s2, half - s1 - s2]
    angs = []
    start = 0
    for i, sec in enumerate(sections):
        f = freqs[start : start + sec]
        angs.append(positions_3d[i][..., None].astype(jnp.float32) * f)
        start += sec
    ang = jnp.concatenate(angs, axis=-1)                          # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


# ---------------------------------------------------------------------- Loss

def cross_entropy_loss(logits, labels, ignore_id: int = -100):
    """Mean token cross-entropy in fp32; labels == ignore_id are masked."""
    logits = logits.astype(jnp.float32)
    mask = labels != ignore_id
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
