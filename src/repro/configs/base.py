"""Architecture + run configuration schema.

One :class:`ArchConfig` per assigned architecture lives in
``repro/configs/<id>.py`` with the exact public-literature spec; smoke tests
use :func:`ArchConfig.reduced` (≤2 layers, d_model ≤ 512, ≤4 experts).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "register", "get_config",
           "list_configs"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity ---------------------------------------------------------------
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                # citation

    # trunk ------------------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: Optional[int] = None  # default: d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # attention pattern --------------------------------------------------------
    attention: str = "full"         # full | local_global | sliding
    sliding_window: int = 0         # window for sliding / local layers
    local_global_ratio: int = 0     # gemma3: N local per 1 global

    # MLA (deepseek) -----------------------------------------------------------
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # M-RoPE (qwen2-vl) ----------------------------------------------------------
    mrope: bool = False
    vlm_num_patches: int = 256      # stubbed vision prefix length

    # MoE ----------------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_dense_residual: bool = False   # arctic: dense FFN in parallel
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (mamba2) ---------------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 64
    hybrid_attn_every: int = 0      # zamba2: shared attn block every N layers

    # audio (musicgen) -------------------------------------------------------------
    num_codebooks: int = 0

    # --------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test variant: same family/wiring, tiny sizes."""
        small = dict(
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=64 if self.head_dim else None,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            vlm_num_patches=8 if self.mrope else self.vlm_num_patches,
        )
        if self.num_experts:
            small.update(num_experts=4, top_k=min(self.top_k, 2),
                         num_shared_experts=min(self.num_shared_experts, 1))
        if self.mla:
            small.update(kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16,
                         v_head_dim=32)
        if self.ssm_state:
            small.update(ssm_state=16, ssm_headdim=16, ssm_chunk=8)
        if self.hybrid_attn_every:
            small.update(num_layers=max(4, 2 * self.hybrid_attn_every // 3))
        if self.local_global_ratio:
            small.update(num_layers=4)
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

_REGISTRY = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs():
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    # Importing each module runs its register() call.
    from repro.configs import (  # noqa: F401
        arctic_480b, deepseek_v2_lite_16b, gemma3_4b, granite_20b, llama3_8b,
        mamba2_370m, musicgen_large, phi4_mini_3_8b, qwen2_vl_2b, zamba2_7b,
    )
