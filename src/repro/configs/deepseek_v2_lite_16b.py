"""DeepSeek-V2-Lite-16B: MLA (kv_lora=512) + MoE 64 routed top-6, 2 shared
experts [arXiv:2405.04434].

Assignment-spec note: the assignment line lists both "64e top-6" and
"2 shared+160 routed"; 160 routed experts belongs to full DeepSeek-V2 —
we follow the primary V2-Lite spec (64 routed, 2 shared, top-6)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-lite-16b", family="moe", source="arXiv:2405.04434",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    num_experts=64, top_k=6, num_shared_experts=2,
))
