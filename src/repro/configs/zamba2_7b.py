"""Zamba2-7B: Mamba2 backbone with ONE shared attention(+MLP) block applied
every 6 layers — the shared weights are reused at each application
[arXiv:2411.15242]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b", family="hybrid", source="arXiv:2411.15242",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_conv=4, ssm_chunk=256,
    hybrid_attn_every=6,
))
