"""Snowflake Arctic-480B: 128 experts top-2 MoE with a parallel dense
residual FFN [hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="arctic-480b", family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    num_experts=128, top_k=2, moe_dense_residual=True,
))
