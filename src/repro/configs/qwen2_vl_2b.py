"""Qwen2-VL-2B: GQA (kv=2) decoder with M-RoPE over a stubbed dynamic-
resolution ViT frontend [arXiv:2409.12191]. ``input_specs`` provides
precomputed patch embeddings (the modality carve-out)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b", family="vlm", source="arXiv:2409.12191",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936,
    mrope=True, vlm_num_patches=256,
))
