"""Mamba2-370m: attention-free SSD (state-space duality) stack
[arXiv:2405.21060]. d_ff=0 — blocks are pure Mamba2 mixers."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-370m", family="ssm", source="arXiv:2405.21060",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv=4, ssm_chunk=256,
))
