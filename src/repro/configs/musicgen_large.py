"""MusicGen-large: decoder-only LM over K=4 EnCodec codebook streams
[arXiv:2306.05284]. The conv codec frontend is stubbed: inputs are the
(B, K, S) token streams; embeddings are summed across codebooks and K
output heads predict the next step of each stream."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-large", family="audio", source="arXiv:2306.05284",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    num_codebooks=4,
))
