"""Gemma-3-4B: 5:1 local:global attention, 128k ctx, 262k vocab
[hf:google/gemma-3-1b-pt]. Local layers use a 1024-token sliding window
(ring caches); every 6th layer is global full attention."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-4b", family="dense", source="hf:google/gemma-3-1b-pt",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144, rope_theta=1_000_000.0,
    attention="local_global", local_global_ratio=5, sliding_window=1024,
))
