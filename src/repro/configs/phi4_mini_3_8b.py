"""Phi-4-mini-3.8B: RoPE + SwiGLU + GQA decoder [arXiv:2412.08905]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="phi4-mini-3.8b", family="dense", source="arXiv:2412.08905",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=200064,
))
