"""SQUASH multi-stage search pipeline (paper §2.4, Fig. 4 + Fig. 5 data plane).

Build: balanced partitions → per-partition KLT → variance-greedy bit
allocation → Lloyd-Max scalar quantizers → segment-packed primary OSQ index +
1-bit low-bit OSQ index → quantized attribute index.

Search: predicate parse → R lookup → filter mask F → Algorithm 1 partition
selection → per-partition: low-bit Hamming prune → ADC lookup-table LB
distances → optional R·k full-precision post-refinement → single-pass
MPI-style top-k merge.

Two query data planes execute Stages 3–5, selected by
``SquashConfig.backend`` (or per-call via ``search(..., backend=...)``):

* ``"numpy"`` — the per-query reference loop in this module: per visited
  partition, NumPy stage math with deterministic (score, row) tie-breaking.
* ``"jax"`` — the batched plane in ``repro.core.dataplane``: all queries ×
  all partitions stacked to fixed shapes, jit-compiled end to end (one trace
  per (Q, k, index shape)), kernels dispatched via ``repro.kernels.ops``
  (Pallas on TPU, XLA twins on CPU). Returns bitwise-identical ids to the
  NumPy plane; the dynamic per-(query, partition) keep/take counts are
  computed on host and applied as masks inside the traced function.

``repro.core.distributed`` shards the same batched plane over a TPU mesh and
``repro.serve`` drives it under the simulated serverless runtime.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import adc, attributes as attr_mod, lowbit, osq, partitions, segments

__all__ = ["SquashConfig", "PartitionIndex", "SquashIndex", "SearchStats"]

BACKENDS = ("numpy", "jax")


@dataclasses.dataclass
class SquashConfig:
    """Index + search hyper-parameters (paper §5.1/§5.3 defaults)."""

    num_partitions: int = 10
    bits_per_dim: float = 4.0          # bit budget b = bits_per_dim * d
    segment_bits: int = 8              # S
    use_klt: bool = True               # unitary decorrelating transform
    hamming_perc: float = 10.0         # H_perc — % of candidates kept (static;
                                       # superseded per-partition by an
                                       # installed autotune CalibrationProfile)
    refine_ratio: float = 2.0          # R — full-precision re-rank multiplier
    beta: float = 0.001                # Eq. 1 β
    threshold_override: Optional[float] = None
    kmeans_iters: int = 10
    lloyd_iters: int = 15
    max_bits_per_dim: int = 12
    enable_refine: bool = True
    min_hamming_keep: int = 64         # floor so tiny candidate sets survive
    backend: str = "numpy"             # Stage 3–5 data plane: numpy | jax


@dataclasses.dataclass
class PartitionIndex:
    """Per-partition OSQ index — what one QueryProcessor holds (paper §3.1)."""

    vector_ids: np.ndarray           # (n_p,) global ids, local order
    klt: Optional[np.ndarray]        # (d, d) unitary transform (or None)
    mean: np.ndarray                 # (d,) transform centering
    quant: osq.OSQQuantizer
    layout: segments.SegmentLayout
    packed: np.ndarray               # (n_p, G) packed primary codes
    codes: np.ndarray                # (n_p, d) unpacked codes (in-memory Q-index)
    low: lowbit.LowBitIndex          # 1-bit secondary index
    vectors: np.ndarray              # (n_p, d) full precision (the 'EFS' copy)

    @property
    def size(self) -> int:
        return int(self.vector_ids.shape[0])

    def transform(self, q: np.ndarray) -> np.ndarray:
        q = q - self.mean
        return q @ self.klt if self.klt is not None else q

    def index_bytes(self) -> int:
        return int(self.packed.nbytes + self.low.packed.nbytes)


@dataclasses.dataclass
class SearchStats:
    """Per-stage pruning accounting (drives the cost model + EXPERIMENTS.md)."""

    queries: int = 0
    filter_pass: int = 0
    partitions_visited: int = 0
    hamming_in: int = 0
    hamming_kept: int = 0
    adc_evals: int = 0
    refined: int = 0

    def merge(self, other: "SearchStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))


class SquashIndex:
    """End-to-end SQUASH index over a vector dataset + attribute table."""

    def __init__(
        self,
        config: SquashConfig,
        partitioning: partitions.Partitioning,
        parts: List[PartitionIndex],
        attr_index: attr_mod.AttributeIndex,
        dim: int,
    ):
        self.config = config
        self.partitioning = partitioning
        self.parts = parts
        self.attr_index = attr_index
        self.dim = dim
        # Liveness bitmap over global vector ids (core/live.py). None for a
        # frozen index — zero overhead on the static path. When set, dead
        # (tombstoned) rows are excluded from the Stage 1 filter mask and
        # defensively masked again in Stage 3 on every backend.
        self.live_mask: Optional[np.ndarray] = None
        # Back-reference to the owning LiveIndex (set by core/live.py) so
        # the serverless runtime can pull mutation events lazily.
        self.live_owner = None
        # Optional recall-targeted calibration (core/autotune.py): when set,
        # per-partition keep fractions + a calibrated floor replace the
        # static hamming_perc / min_hamming_keep in every data plane.
        self.profile = None
        # jax-backend caches: stacked device payload per dtype, jitted plane
        # per (k, keep_s, take_s, refine). jit itself caches per (Q, d) shape,
        # so each (Q, k, index shape) traces exactly once (see
        # ``_trace_counter``, asserted by the backend-parity tests).
        self._stacked_cache: Dict = {}
        self._plane_cache: Dict = {}
        self._trace_counter = [0]

    def set_profile(self, profile) -> None:
        """Install (or clear) a calibration profile for this index.

        ``profile`` is a :class:`repro.core.autotune.CalibrationProfile`
        whose partition count must match; ``None`` restores the static
        config knobs. The jitted-plane cache is dropped because the static
        keep/take shapes derive from the active profile.
        """
        if profile is not None and profile.num_partitions != len(self.parts):
            raise ValueError(
                f"profile covers {profile.num_partitions} partitions, index "
                f"has {len(self.parts)}")
        self.profile = profile
        self._plane_cache.clear()

    def autotune(self, queries=None, *, recall_target: float = 0.95,
                 k: int = 10, sample: int = 64, seed: int = 0, **kw):
        """Calibrate + install a recall-targeted profile; returns it."""
        from repro.core import autotune as at

        profile = at.calibrate(self, queries, recall_target=recall_target,
                               k=k, sample=sample, seed=seed, **kw)
        self.set_profile(profile)
        return profile

    # ------------------------------------------------------------------ build

    @classmethod
    def build(
        cls,
        vectors: np.ndarray,
        attrs: np.ndarray,
        config: Optional[SquashConfig] = None,
        attr_bits: Optional[Sequence[int]] = None,
        seed: int = 0,
    ) -> "SquashIndex":
        config = config or SquashConfig()
        vectors = np.asarray(vectors, dtype=np.float64)
        n, d = vectors.shape
        cent, assign = partitions.balanced_kmeans(
            vectors, config.num_partitions, iters=config.kmeans_iters, seed=seed
        )
        t = (
            config.threshold_override
            if config.threshold_override is not None
            else partitions.compute_threshold(vectors, cent, assign, beta=config.beta)
        )
        part_obj = partitions.Partitioning(centroids=cent, assign=assign, threshold=t)
        budget = int(round(config.bits_per_dim * d))
        parts: List[PartitionIndex] = []
        for pid in range(config.num_partitions):
            ids = np.where(assign == pid)[0]
            x = vectors[ids]
            mean = x.mean(axis=0)
            xc = x - mean
            if config.use_klt and x.shape[0] > d:
                cov = (xc.T @ xc) / max(x.shape[0] - 1, 1)
                _, eigvec = np.linalg.eigh(cov)
                klt = eigvec[:, ::-1]            # descending-variance order
                xt = xc @ klt
            else:
                klt = None
                xt = xc
            var = xt.var(axis=0)
            bits = osq.allocate_bits(var, budget, max_bits=config.max_bits_per_dim)
            quant = osq.design_quantizers(xt, bits, iters=config.lloyd_iters)
            codes = osq.encode(quant, xt)
            layout = segments.build_layout(bits, seg_bits=config.segment_bits)
            packed = segments.pack_codes(layout, codes)
            # Low-bit index binarizes the *raw* (centered) space: KLT compacts
            # energy into few dims, and post-KLT standardization would amplify
            # the near-noise trailing dims into uninformative random bits.
            low = lowbit.build_lowbit_index(xc)
            parts.append(
                PartitionIndex(
                    vector_ids=ids,
                    klt=klt,
                    mean=mean,
                    quant=quant,
                    layout=layout,
                    packed=packed,
                    codes=codes.astype(np.int32),
                    low=low,
                    vectors=x,
                )
            )
        attr_index = attr_mod.build_attribute_index(attrs, bits=attr_bits)
        return cls(config, part_obj, parts, attr_index, dim=d)

    # ----------------------------------------------------------------- search

    def search(
        self,
        queries: np.ndarray,
        predicates: Sequence[attr_mod.Predicate],
        k: int = 10,
        collect_stats: bool = False,
        backend: Optional[str] = None,
    ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        """Batched hybrid top-k. Returns (ids (Q,k), dists (Q,k), stats).

        ``backend`` overrides ``config.backend`` for this call: ``"numpy"``
        runs the per-query reference loop, ``"jax"`` the batched jitted data
        plane (identical ids, same stats counters).
        """
        backend = backend or self.config.backend
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected "
                             f"{BACKENDS}")
        queries = np.atleast_2d(np.asarray(queries, dtype=np.float64))
        qn = queries.shape[0]
        stats = SearchStats(queries=qn)

        # Stage 1 — attribute filtering (global mask F per query). Dead
        # (tombstoned) rows fail the filter outright: they can never become
        # Stage 2 candidates on any backend, which is what keeps mutation
        # bitwise-invisible to the downstream stages.
        r = attr_mod.build_r_lookup(self.attr_index, predicates)
        f_one = np.asarray(attr_mod.filter_mask(r, self.attr_index.codes))
        if self.live_mask is not None:
            f_one = f_one & self.live_mask
        f = np.broadcast_to(f_one, (qn, f_one.shape[0]))
        stats.filter_pass += int(f_one.sum()) * qn

        # Stage 2 — Algorithm 1 partition ranking/selection.
        visit, cands = partitions.select_partitions(
            queries,
            self.partitioning.centroids,
            f,
            self.partitioning.assign,
            self.partitioning.threshold,
            k,
        )
        stats.partitions_visited += int(visit.sum())

        if backend == "jax":
            return self._search_jax(queries, cands, k, stats)
        return self._search_numpy(queries, cands, k, stats)

    def _search_numpy(
        self,
        queries: np.ndarray,
        cands,
        k: int,
        stats: SearchStats,
    ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        """Reference Stage 3–5 plane: per-query loop over visited partitions.

        Candidate streams are consumed in ascending-partition order and every
        sort is stable, so ties resolve as (score, partition, row) — exactly
        the order ``lax.top_k`` produces in the jax plane.
        """
        qn = queries.shape[0]
        all_ids = np.full((qn, k), -1, dtype=np.int64)
        all_dists = np.full((qn, k), np.inf, dtype=np.float64)
        for qi in range(qn):
            heap: List[Tuple[float, int]] = []
            for pid in sorted(cands[qi]):
                ids, dists = self._search_partition(
                    self.parts[pid], pid, queries[qi], cands[qi][pid], k,
                    stats
                )
                heap.extend(zip(dists.tolist(), ids.tolist()))
            # Single-pass MPI-style reduce: merge per-partition local top-k.
            # Stable sort on distance alone keeps (partition, rank) tie order.
            heap.sort(key=lambda t: t[0])
            top = heap[:k]
            for r_i, (dist, vid) in enumerate(top):
                all_ids[qi, r_i] = vid
                all_dists[qi, r_i] = dist
        return all_ids, all_dists, stats

    def _search_jax(
        self,
        queries: np.ndarray,
        cands,
        k: int,
        stats: SearchStats,
    ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        """Batched Stage 3–5 plane (repro.core.dataplane), jitted end to end.

        Host side prepares dense masks + per-(query, partition) keep/take
        counts; one jitted call executes Hamming prune, ADC lower bounds,
        refinement and the cross-partition merge for the whole batch.
        """
        import jax
        import jax.numpy as jnp

        from repro.core import dataplane

        cfg = self.config
        qn = queries.shape[0]
        dtype = np.float64 if jax.config.jax_enable_x64 else np.float32
        stacked = self._stacked_cache.get(dtype)
        if stacked is None:
            stacked = dataplane.stack_index(self, dtype=dtype)
            self._stacked_cache[dtype] = stacked
        p, n_max = stacked.num_partitions, stacked.n_max

        cand_mask, n_cand = dataplane.build_cand_arrays(cands, qn, p, n_max)
        keep, take = dataplane.stage_counts(n_cand, cfg, k, self.profile)
        keep_s, take_s = dataplane.static_counts(n_max, cfg, k, self.profile)

        # Bucket Q to the next power of two so a service seeing naturally
        # varying batch sizes pays O(log Q) traces, not one per size. Padded
        # queries are dead (keep=0, empty mask) and sliced off below.
        bucket = 1 << (qn - 1).bit_length() if qn > 1 else 1
        if bucket != qn:
            pad = bucket - qn
            queries = np.pad(queries, ((0, pad), (0, 0)))
            cand_mask = np.pad(cand_mask, ((0, pad), (0, 0), (0, 0)))
            keep = np.pad(keep, ((0, pad), (0, 0)))
            take = np.pad(take, ((0, pad), (0, 0)))
        key = (k, keep_s, take_s, cfg.enable_refine)
        plane = self._plane_cache.get(key)
        if plane is None:
            plane = dataplane.make_plane(
                k=k, keep_s=keep_s, take_s=take_s, refine=cfg.enable_refine,
                trace_counter=self._trace_counter,
            )
            self._plane_cache[key] = plane
        ids, dists = plane(
            jnp.asarray(queries, dtype), stacked, jnp.asarray(cand_mask),
            jnp.asarray(keep), jnp.asarray(take),
        )
        ids, dists = ids[:qn], dists[:qn]
        stats.hamming_in += int(n_cand.sum())
        stats.hamming_kept += int(keep.sum())
        stats.adc_evals += int(keep.sum())
        if cfg.enable_refine:
            stats.refined += int(take.sum())
        return (np.asarray(ids, dtype=np.int64),
                np.asarray(dists, dtype=np.float64), stats)

    def _search_partition(
        self,
        part: PartitionIndex,
        pid: int,
        query: np.ndarray,
        local_rows: np.ndarray,
        k: int,
        stats: SearchStats,
    ) -> Tuple[np.ndarray, np.ndarray]:
        from repro.core import autotune

        cfg = self.config
        # Stage 3 tombstone mask (defense in depth): Stage 1 already fails
        # dead rows, but requests constructed outside `search` (e.g. a raw
        # QP request) must still never return a tombstoned id.
        if self.live_mask is not None:
            alive = self.live_mask[part.vector_ids[local_rows]]
            if not alive.all():
                local_rows = local_rows[alive]
        if local_rows.size == 0:
            return (np.empty(0, dtype=np.int64), np.empty(0, dtype=np.float64))
        qt = part.transform(query)

        # Stage 3 — low-bit OSQ Hamming pruning (only rows passing the filter).
        # Binary codes live in the raw centered space (see build()).
        qbits = part.low.encode_queries((query - part.mean)[None, :])[0]
        cand_packed = part.low.packed[local_rows]
        x = np.bitwise_xor(cand_packed, qbits[None, :])
        ham = _popcount_u32(x).sum(axis=1)
        stats.hamming_in += local_rows.size
        # Keep budget: the partition's calibrated fraction + global floor
        # under an active profile, the static config knobs otherwise — the
        # same keep_count formula stage_counts applies in the batched plane.
        if self.profile is not None:
            frac = float(self.profile.keep_frac[pid])
            floor = int(self.profile.min_keep)
        else:
            frac, floor = cfg.hamming_perc, cfg.min_hamming_keep
        keep = autotune.keep_count(local_rows.size, frac, floor)
        # Total-order composite key (ham, row): keeps the O(n) argpartition
        # while resolving ties by ascending row — the order the jax plane's
        # lax.top_k produces, required for backend id parity.
        n_c = local_rows.size
        comp = ham.astype(np.int64) * n_c + np.arange(n_c)
        kept_sel = np.argpartition(comp, keep - 1)[:keep]
        kept_sel = kept_sel[np.argsort(comp[kept_sel])]
        kept_rows = local_rows[kept_sel]
        stats.hamming_kept += keep

        # Stage 4 — ADC lookup-table LB distances on survivors.
        table = adc.build_adc_table(qt, part.quant.boundaries, part.quant.cells)
        codes = part.codes[kept_rows]
        safe = np.where(np.isfinite(table), table, 0.0)
        lb = np.sqrt(safe[codes, np.arange(self.dim)[None, :]].sum(axis=1))
        stats.adc_evals += keep

        take = min(int(np.ceil(cfg.refine_ratio * k)), keep) if cfg.enable_refine \
            else min(k, keep)
        order = np.argsort(lb, kind="stable")[:take]
        cand = kept_rows[order]

        if cfg.enable_refine:
            # Stage 5 — post-refinement on full-precision rows ('EFS' reads).
            full = part.vectors[cand]
            exact = np.sqrt(((full - query[None, :]) ** 2).sum(axis=1))
            stats.refined += cand.size
            fin = np.argsort(exact, kind="stable")[:k]
            return part.vector_ids[cand[fin]], exact[fin]
        return part.vector_ids[cand[:k]], lb[order][:k]

    # ------------------------------------------------------------- accounting

    def index_bytes(self) -> Dict[str, int]:
        primary = sum(p.packed.nbytes for p in self.parts)
        low = sum(p.low.packed.nbytes for p in self.parts)
        attrs = self.attr_index.codes.nbytes
        full = sum(p.vectors.nbytes for p in self.parts)
        return {
            "primary_osq": int(primary),
            "lowbit_osq": int(low),
            "attr_codes": int(attrs),
            "full_precision": int(full),
        }


_POP_TABLE = np.array([bin(i).count("1") for i in range(256)], dtype=np.uint8)


def _popcount_u32(x: np.ndarray) -> np.ndarray:
    """Byte-table popcount for uint32 arrays (NumPy reference path)."""
    b = x.view(np.uint8).reshape(*x.shape, 4)
    return _POP_TABLE[b].sum(axis=-1).astype(np.int32)
