"""Shared segment-based storage + dimensional extraction (paper §2.2.1–2.2.2).

Variable-length per-dimension bit codes are concatenated MSB-first into shared
S-bit segments: ``G_OSQ = ceil(b / S)`` segments per vector versus ``G_SQ = d``
fixed slots under standard SQ. Extraction recovers dimension ``j`` of *all*
rows simultaneously via static shift/mask/OR plans (paper Fig. 3), which are
jittable in JAX and have a Pallas TPU kernel twin in ``repro.kernels.bitpack``.

Bit-order convention: global bit position ``p`` (0-based from the start of the
vector's code stream) lives in segment ``p // S`` at MSB-based offset ``p % S``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "SegmentLayout",
    "build_layout",
    "pack_codes",
    "extract_dim",
    "extract_all",
    "sq_wastage",
]

_DTYPES = {8: np.uint8, 16: np.uint16, 32: np.uint32}


@dataclasses.dataclass(frozen=True)
class Piece:
    """One contiguous chunk of a dimension's code inside a single segment."""

    seg: int      # segment index
    rshift: int   # right shift to land the piece at the LSB of the segment word
    nbits: int    # piece width
    lshift: int   # left shift to place the piece inside the dim code


@dataclasses.dataclass(frozen=True)
class SegmentLayout:
    """Static packing metadata shared by pack/extract."""

    bits: Tuple[int, ...]          # per-dim bit widths B
    seg_bits: int                  # S
    total_bits: int                # b = sum(B)
    num_segments: int              # G = ceil(b / S)
    offsets: Tuple[int, ...]       # per-dim global start bit
    plans: Tuple[Tuple[Piece, ...], ...]  # per-dim extraction plan

    @property
    def dtype(self):
        return _DTYPES[self.seg_bits]

    @property
    def d(self) -> int:
        return len(self.bits)


def build_layout(bits: Sequence[int], seg_bits: int = 8) -> SegmentLayout:
    if seg_bits not in _DTYPES:
        raise ValueError(f"seg_bits must be one of {sorted(_DTYPES)}")
    bits = tuple(int(b) for b in bits)
    offsets = []
    plans: List[Tuple[Piece, ...]] = []
    pos = 0
    for bj in bits:
        offsets.append(pos)
        pieces: List[Piece] = []
        covered = 0
        while covered < bj:
            p = pos + covered
            seg = p // seg_bits
            in_seg = p % seg_bits            # MSB-based offset inside segment
            take = min(bj - covered, seg_bits - in_seg)
            # piece occupies segment bits [in_seg, in_seg+take) (MSB-based)
            rshift = seg_bits - in_seg - take
            lshift = bj - covered - take     # placement inside the dim code
            pieces.append(Piece(seg=seg, rshift=rshift, nbits=take, lshift=lshift))
            covered += take
        plans.append(tuple(pieces))
        pos += bj
    total = pos
    g = -(-total // seg_bits) if total else 0
    return SegmentLayout(
        bits=bits,
        seg_bits=seg_bits,
        total_bits=total,
        num_segments=g,
        offsets=tuple(offsets),
        plans=tuple(plans),
    )


def pack_codes(
    layout: SegmentLayout, codes: np.ndarray, chunk: int = 65536
) -> np.ndarray:
    """Pack (N, d) integer codes into (N, G) segments of ``layout.dtype``."""
    codes = np.asarray(codes)
    n, d = codes.shape
    if d != layout.d:
        raise ValueError(f"dim mismatch {d} != {layout.d}")
    s = layout.seg_bits
    g = layout.num_segments
    out = np.zeros((n, g), dtype=np.uint64)
    weights = (1 << np.arange(s - 1, -1, -1, dtype=np.uint64))  # MSB-first
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        cols = []
        for j, bj in enumerate(layout.bits):
            if bj == 0:
                continue
            shifts = np.arange(bj - 1, -1, -1, dtype=np.uint64)
            cols.append(
                (codes[lo:hi, j].astype(np.uint64)[:, None] >> shifts[None, :]) & 1
            )
        if cols:
            bitmat = np.concatenate(cols, axis=1)
        else:
            bitmat = np.zeros((hi - lo, 0), dtype=np.uint64)
        pad = g * s - layout.total_bits
        if pad:
            bitmat = np.pad(bitmat, ((0, 0), (0, pad)))
        out[lo:hi] = bitmat.reshape(hi - lo, g, s) @ weights
    return out.astype(layout.dtype)


def extract_dim(segments, layout: SegmentLayout, j: int):
    """Extract dimension ``j`` for all rows (paper Fig. 3). JAX-jittable.

    Left/right-shift semantics from the paper are realized as a single
    combined right shift + mask per overlapped segment, followed by a left
    shift into the residue position and a bitwise OR across segments.
    """
    segs = jnp.asarray(segments)
    wide = segs.astype(jnp.uint32)
    out = jnp.zeros(segs.shape[:-1], dtype=jnp.uint32)
    for piece in layout.plans[j]:
        chunk = (wide[..., piece.seg] >> piece.rshift) & ((1 << piece.nbits) - 1)
        out = out | (chunk << piece.lshift)
    return out.astype(jnp.int32)


def extract_all(segments, layout: SegmentLayout):
    """Extract every dimension: (N, G) segments -> (N, d) int32 codes."""
    cols = [extract_dim(segments, layout, j) for j in range(layout.d)]
    return jnp.stack(cols, axis=-1)


def sq_wastage(bits: Sequence[int], seg_bits: int = 8) -> dict:
    """Paper Fig. 2 quantities: bit wastage of standard SQ vs OSQ."""
    bits = np.asarray(list(bits), dtype=np.int64)
    b = int(bits.sum())
    g_osq = -(-b // seg_bits)
    g_sq = int(bits.shape[0])  # one S-bit slot per dim
    waste_sq = int(np.maximum(seg_bits - bits, 0).sum())
    waste_osq = g_osq * seg_bits - b
    return {
        "total_bits": b,
        "segments_osq": g_osq,
        "segments_sq": g_sq,
        "bits_sq": g_sq * seg_bits,
        "bits_osq": g_osq * seg_bits,
        "waste_sq": waste_sq,
        "waste_osq": waste_osq,
        "saving_ratio": (g_sq * seg_bits) / max(g_osq * seg_bits, 1),
    }
