"""Asymmetric lower-bound distances via per-query lookup tables (paper §2.4.4).

For query q and dimension j, ``L[c, j]`` holds the squared distance from
``q[j]`` to the *nearest edge* of cell ``c``: 0 if c is q's own cell, distance
to the right boundary if c < cell(q[j]), to the left boundary if c > cell(q[j]).
LB(vec) = sqrt(Σ_j L[code_j, j]) — a VA-file-style lower bound on Euclidean
distance [68], asymmetric because the query stays un-quantized [31].

Building L costs (Σ_j C[j]) − 1 subtractions; evaluating candidates is a
gather + row-sum ("advanced indexing"). On TPU the gather is re-expressed as a
one-hot × table matmul so the MXU does the work — see
``repro.kernels.adc_lookup``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["build_adc_table", "lb_distances", "lb_distances_onehot"]


def build_adc_table(
    query: np.ndarray, boundaries: np.ndarray, cells: np.ndarray
) -> np.ndarray:
    """Construct L of shape (M+1, d) for one query (vectorized, NumPy).

    Args:
      query: (d,) un-quantized query (same transform space as the index).
      boundaries: (M+1, d) padded boundary matrix V (±inf edges, +inf padding).
        Row c is the left boundary of cell c; row c+1 its right boundary.
      cells: (d,) per-dimension cell counts C.
    Returns:
      (M+1, d) float32 squared edge distances; rows ≥ C[j] are +inf padding
      (valid codes never index them).

    For any valid cell c with c < cell(q[j]) the right boundary (row c+1 ≤
    C[j]−1) is an interior, finite boundary; symmetrically for c > cell(q[j]).
    The query's own cell contributes 0. Hence every reachable entry is finite.
    """
    q = np.asarray(query, dtype=np.float64)
    boundaries = np.asarray(boundaries, dtype=np.float64)
    cells = np.asarray(cells, dtype=np.int64)
    m1, d = boundaries.shape
    qcell = np.empty(d, dtype=np.int64)
    for j in range(d):
        k = int(cells[j])
        qcell[j] = 0 if k == 1 else np.searchsorted(
            boundaries[1:k, j], q[j], side="right"
        )
    cell_idx = np.arange(m1)[:, None]                  # (M+1, 1)
    right = np.vstack([boundaries[1:], np.full((1, d), np.inf)])
    left = boundaries
    diff = np.where(
        cell_idx < qcell[None, :],
        q[None, :] - right,
        np.where(cell_idx > qcell[None, :], left - q[None, :], 0.0),
    )
    out = np.square(diff)
    out[~np.isfinite(diff)] = np.inf
    out = np.where(cell_idx >= cells[None, :], np.inf, out)
    return out.astype(np.float32)


def lb_distances(table, codes):
    """Gather formulation: (M+1, d) table, (N, d) codes → (N,) LB distances."""
    t = jnp.asarray(table)
    c = jnp.asarray(codes)
    picked = t[c, jnp.arange(c.shape[1])[None, :]]     # (N, d)
    return jnp.sqrt(jnp.sum(picked, axis=-1))


def lb_distances_onehot(table, codes):
    """MXU formulation: one-hot(codes) contracted against the table.

    Mathematically identical to :func:`lb_distances`; on TPU the per-dimension
    lookup becomes a matmul the MXU executes at peak rather than a scalar
    gather stream. Padding rows of ``table`` are +inf, but one-hot rows never
    select them, so we zero the padding before the contraction.
    """
    t = jnp.asarray(table)
    c = jnp.asarray(codes)
    m1 = t.shape[0]
    t_safe = jnp.where(jnp.isfinite(t), t, 0.0)
    onehot = jax.nn.one_hot(c, m1, dtype=t.dtype)      # (N, d, M+1)
    picked = jnp.einsum("ndm,md->n", onehot, t_safe)
    return jnp.sqrt(picked)
