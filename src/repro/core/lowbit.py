"""Low-bit (binary) OSQ index for fast Hamming pruning (paper §2.4.3).

One bit per dimension: standardize, threshold at 0, pack 32 dims per uint32
lane via the OSQ segment scheme. Query→candidate Hamming distances are
XOR + popcount over packed words; the best ``H_perc`` % of candidates (ascending
Hamming order) survive to the fine-grained ADC stage.

The jnp implementation here is the reference; ``repro.kernels.hamming`` is the
Pallas TPU kernel twin (BlockSpec-tiled popcount on the VPU).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LowBitIndex", "build_lowbit_index", "binarize", "pack_bits_u32",
           "hamming_distances", "hamming_prune"]


def binarize(x: np.ndarray, mean: np.ndarray, std: np.ndarray) -> np.ndarray:
    """Standardize then threshold around 0 (paper §2.4.3). Returns {0,1} int8."""
    z = (np.asarray(x, dtype=np.float64) - mean) / np.maximum(std, 1e-12)
    return (z > 0).astype(np.int8)


def pack_bits_u32(bits: np.ndarray) -> np.ndarray:
    """Pack (N, d) {0,1} into (N, ceil(d/32)) uint32, MSB-first per word."""
    bits = np.asarray(bits)
    n, d = bits.shape
    g = -(-d // 32)
    padded = np.zeros((n, g * 32), dtype=np.uint64)
    padded[:, :d] = bits
    weights = 1 << np.arange(31, -1, -1, dtype=np.uint64)
    return (padded.reshape(n, g, 32) @ weights).astype(np.uint32)


@dataclasses.dataclass
class LowBitIndex:
    """Packed binary codes + standardization stats."""

    packed: np.ndarray  # (N, G32) uint32
    mean: np.ndarray    # (d,)
    std: np.ndarray     # (d,)
    d: int

    def encode_queries(self, q: np.ndarray) -> np.ndarray:
        return pack_bits_u32(binarize(q, self.mean, self.std))


def build_lowbit_index(x: np.ndarray) -> LowBitIndex:
    x = np.asarray(x, dtype=np.float64)
    mean = x.mean(axis=0)
    std = x.std(axis=0)
    packed = pack_bits_u32(binarize(x, mean, std))
    return LowBitIndex(packed=packed, mean=mean, std=std, d=x.shape[1])


def hamming_distances(q_packed, db_packed):
    """Hamming distance between one packed query and all packed rows.

    Args:
      q_packed: (G,) uint32.
      db_packed: (N, G) uint32.
    Returns:
      (N,) int32 — Eq. 2, computed 32 dims per popcount lane.
    """
    x = jnp.bitwise_xor(db_packed, q_packed[None, :])
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def hamming_prune(q_packed, db_packed, candidate_mask, keep: int):
    """Retain the ``keep`` best candidates by ascending Hamming distance.

    Non-candidates (mask 0) are pushed to +inf so they never survive. Returns
    (indices, distances) of the kept set, both length ``keep``.
    """
    dist = hamming_distances(q_packed, db_packed)
    big = jnp.iinfo(jnp.int32).max
    dist = jnp.where(candidate_mask.astype(bool), dist, big)
    neg_top, idx = jax.lax.top_k(-dist, keep)
    return idx, -neg_top
