"""Coarse partitioning + filtered partition ranking/selection (paper §2.4.1–2.4.2).

Balanced (capacity-constrained) k-means yields computationally balanced
partitions for the resource-constrained workers; Eq. 1 derives the centroid
distance-ratio threshold T; Algorithm 1 selects, per query, the minimal
partition set that (a) covers every centroid within factor T of the nearest
and (b) contains ≥ k predicate-passing vectors — guaranteeing a single
distributed pass.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "balanced_kmeans",
    "compute_threshold",
    "select_partitions",
    "Partitioning",
]


@dataclasses.dataclass
class Partitioning:
    centroids: np.ndarray   # (P, d)
    assign: np.ndarray      # (N,) partition id per vector
    threshold: float        # T from Eq. 1

    @property
    def num_partitions(self) -> int:
        return int(self.centroids.shape[0])

    def residency_bitmap(self) -> np.ndarray:
        """Compact P_V map: (P, N) bool — vector residency per partition.

        Rows with out-of-range assignment (the ``assign == P`` sentinel a
        live-index compaction leaves on physically removed vectors) reside
        nowhere.
        """
        p = self.num_partitions
        n = self.assign.shape[0]
        pv = np.zeros((p, n), dtype=bool)
        resident = self.assign < p
        pv[self.assign[resident], np.arange(n)[resident]] = True
        return pv


def balanced_kmeans(
    x: np.ndarray,
    num_partitions: int,
    iters: int = 15,
    seed: int = 0,
    slack: float = 1.05,
) -> Tuple[np.ndarray, np.ndarray]:
    """Capacity-constrained Lloyd iterations (paper's 'constrained clustering').

    Each iteration assigns vectors greedily in order of *assignment margin*
    (gap between best and second-best centroid), respecting a per-partition
    capacity of ``slack * ceil(N/P)``. Returns (centroids, assign).
    """
    x = np.asarray(x, dtype=np.float64)
    n, d = x.shape
    p = num_partitions
    rng = np.random.default_rng(seed)
    cent = x[rng.choice(n, size=p, replace=False)].copy()
    cap = int(np.ceil(slack * n / p))
    assign = np.zeros(n, dtype=np.int64)
    for _ in range(iters):
        d2 = ((x[:, None, :] - cent[None, :, :]) ** 2).sum(-1) if n * p * d < 5e7 \
            else _chunked_sqdist(x, cent)
        order = np.argsort(np.partition(d2, 1, axis=1)[:, 0] - np.partition(d2, 1, axis=1)[:, 1])
        counts = np.zeros(p, dtype=np.int64)
        pref = np.argsort(d2, axis=1)
        for i in order:
            for c in pref[i]:
                if counts[c] < cap:
                    assign[i] = c
                    counts[c] += 1
                    break
        for c in range(p):
            members = x[assign == c]
            if members.shape[0]:
                cent[c] = members.mean(axis=0)
    return cent, assign


def _chunked_sqdist(x: np.ndarray, cent: np.ndarray, chunk: int = 8192) -> np.ndarray:
    n = x.shape[0]
    out = np.empty((n, cent.shape[0]), dtype=np.float64)
    c2 = (cent ** 2).sum(-1)
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        xx = x[lo:hi]
        out[lo:hi] = (xx ** 2).sum(-1)[:, None] - 2 * xx @ cent.T + c2[None, :]
    return np.maximum(out, 0.0)


def compute_threshold(
    x: np.ndarray,
    centroids: np.ndarray,
    assign: np.ndarray,
    beta: float = 0.001,
    sample: Optional[int] = 20000,
    seed: int = 0,
) -> float:
    """Centroid distance-ratio threshold T (Eq. 1).

    Builds the vector↔centroid distance-ratio matrix R (each row divided by
    the home-centroid distance), takes row-wise means/stds, then
    T = 1 + σ_µ/µ_µ + β·√d over *their* means.
    """
    x = np.asarray(x, dtype=np.float64)
    n, d = x.shape
    if sample is not None and n > sample:
        idx = np.random.default_rng(seed).choice(n, size=sample, replace=False)
        x, assign = x[idx], assign[idx]
        n = sample
    dist = np.sqrt(_chunked_sqdist(x, centroids))
    home = dist[np.arange(n), assign]
    ratio = dist / np.maximum(home[:, None], 1e-12)
    mu_r = ratio.mean(axis=1)
    sigma_r = ratio.std(axis=1)
    mu_mu = float(mu_r.mean())
    sigma_mu = float(sigma_r.mean())
    return 1.0 + sigma_mu / max(mu_mu, 1e-12) + beta * np.sqrt(d)


def select_partitions(
    queries: np.ndarray,
    centroids: np.ndarray,
    filter_masks: np.ndarray,
    assign: np.ndarray,
    threshold: float,
    k: int,
    balance: bool = False,
    escalations: Optional[list] = None,
) -> Tuple[np.ndarray, List[Dict[int, np.ndarray]]]:
    """Algorithm 1 — Filtered Partition Ranking and Selection.

    Args:
      queries: (Q, d).
      centroids: (P, d).
      filter_masks: (Q, N) bool — attribute satisfaction mask F per query.
      assign: (N,) home partition of each vector (the P_V map).
      threshold: T (multiplicative factor over the nearest centroid distance).
      k: top-k target.
      balance: optional batch load-balancing step (assign extra queries to
        under-visited partitions, narrowest-miss first).
      escalations: optional one-element list; incremented by the number of
        (query, partition) visits *past* the Eq. 1 threshold cut — the §2.5
        filter-count guarantee at work (counted here, where the cut decision
        is made, so callers can't drift from it).

    Returns:
      visit: (Q, P) bool — partitions each query must be issued to.
      cands: per-query dict partition → local candidate row indices (into the
        partition's local vector order). Every visited partition carries a
        non-empty candidate bitmap, so per-partition processors prune all
        non-passing vectors (single-pass guarantee).
    """
    queries = np.asarray(queries, dtype=np.float64)
    qn, d = queries.shape
    p = centroids.shape[0]
    n = assign.shape[0]
    # Local (within-partition) index of every vector, in global order.
    order = np.argsort(assign, kind="stable")
    local_pos = np.empty(n, dtype=np.int64)
    counts = np.bincount(assign, minlength=p)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    local_pos[order] = np.arange(n) - np.repeat(starts, counts)

    dists = np.sqrt(_chunked_sqdist(queries, centroids))
    visit = np.zeros((qn, p), dtype=bool)
    cands: List[Dict[int, np.ndarray]] = []
    near_miss: List[Tuple[float, int, int]] = []  # (margin, q, partition)
    escalated = 0
    for qi in range(qn):
        cand_total = 0
        per_part: Dict[int, np.ndarray] = {}
        ranked = np.argsort(dists[qi])
        dmin = dists[qi, ranked[0]]
        for rank, pid in enumerate(ranked):
            past_cut = dists[qi, pid] > threshold * max(dmin, 1e-12)
            if past_cut and cand_total >= k:
                near_miss.append((dists[qi, pid] / max(dmin, 1e-12), qi, pid))
                break
            rows = np.where(filter_masks[qi] & (assign == pid))[0]
            if rows.size:
                visit[qi, pid] = True
                per_part[pid] = local_pos[rows]
                cand_total += rows.size
                if past_cut:
                    escalated += 1
        cands.append(per_part)
    if escalations is not None:
        escalations[0] += escalated
    if balance:
        visits_per_part = visit.sum(axis=0)
        target = max(1, int(np.ceil(visit.sum() / p)))
        near_miss.sort()
        for margin, qi, pid in near_miss:
            if visits_per_part[pid] < target and not visit[qi, pid]:
                rows = np.where(filter_masks[qi] & (assign == pid))[0]
                if rows.size:
                    visit[qi, pid] = True
                    cands[qi][pid] = local_pos[rows]
                    visits_per_part[pid] += 1
    return visit, cands
