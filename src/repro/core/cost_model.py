"""Serverless cost model for distributed vector search (paper §3.5, Eqs. 3–8).

C_total = C_λ + C_S3 + C_EFS, with λ split into per-invocation and
MB-second runtime charges. Constants default to public AWS eu-west-1 prices
(the paper's region); all are overridable so the model stays provider-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

__all__ = ["PricingConstants", "LambdaFleet", "squash_query_cost",
           "server_baseline_cost", "daily_cost_curve"]


@dataclasses.dataclass(frozen=True)
class PricingConstants:
    lambda_per_invocation: float = 2.0e-7        # $/request
    lambda_per_mb_second: float = 1.6279e-8      # $/MB-s  (== $1.66667e-5 per GB-s)
    s3_per_get: float = 4.0e-7                   # $/GET
    efs_per_byte: float = 3.0e-11                # $/byte (Elastic Throughput reads)

    # Server-baseline comparison points (on-demand, eu-west-1).
    ec2_c7i_16xlarge_hour: float = 2.8560
    ec2_c7i_4xlarge_hour: float = 0.7140


@dataclasses.dataclass
class LambdaFleet:
    """One query batch's worth of FaaS activity (inputs to Eqs. 5–8)."""

    n_qa: int
    n_qp: int
    mem_qa_mb: int = 1770
    mem_qp_mb: int = 1770
    mem_co_mb: int = 512
    t_qa_s: float = 0.0        # summed QA runtimes (Σ T_A_i)
    t_qp_s: float = 0.0        # summed QP runtimes (Σ T_P_i)
    t_co_s: float = 0.0
    s3_gets: int = 0           # L
    efs_reads: int = 0         # S (count of random full-precision reads)
    efs_read_bytes: int = 0    # S · R_size


def squash_query_cost(
    fleet: LambdaFleet, prices: PricingConstants = PricingConstants()
) -> dict:
    """Evaluate Eqs. 3–8 for one batch. Returns per-component dollars."""
    c_invoc = (fleet.n_qa + fleet.n_qp + 1) * prices.lambda_per_invocation
    c_run = (
        fleet.mem_qa_mb * fleet.t_qa_s
        + fleet.mem_qp_mb * fleet.t_qp_s
        + fleet.mem_co_mb * fleet.t_co_s
    ) * prices.lambda_per_mb_second
    c_s3 = fleet.s3_gets * prices.s3_per_get
    c_efs = fleet.efs_read_bytes * prices.efs_per_byte
    total = c_invoc + c_run + c_s3 + c_efs
    return {
        "lambda_invocation": c_invoc,
        "lambda_runtime": c_run,
        "s3": c_s3,
        "efs": c_efs,
        "total": total,
    }


def server_baseline_cost(
    hours: float,
    instances: int = 2,
    hourly: float = PricingConstants().ec2_c7i_16xlarge_hour,
) -> float:
    """Provisioned-server comparison (paper Fig. 8 assumes 2 instances)."""
    return hours * instances * hourly


def daily_cost_curve(
    per_batch_cost: float,
    batch_queries: int,
    daily_volumes: Sequence[int],
) -> list:
    """SQUASH daily cost at uniform arrival volumes (x-axis of Fig. 8)."""
    return [v / batch_queries * per_batch_cost for v in daily_volumes]
