"""Data Retention Exploitation (paper §3.2) + optional result cache (§3.2/§5.6).

DRE: FaaS containers persist process-global state across warm invocations.
Each QA/QP holds a singleton whose key identifies the dataset/partition; on
invoke, if the singleton already holds matching index data the S3 fetch is
skipped entirely. The QP-per-partition function naming
(``squash-processor-<pid>``) guarantees a warm QP container always matches its
partition.

On TPU the analogue is HBM residency of the index pytree across jitted steps;
this simulator exists to reproduce Fig. 6 (cost / latency / S3-request
reduction) and to drive the cost model.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, Hashable, Optional, Tuple

__all__ = ["ContainerPool", "ResultCache", "DreStats", "Lease"]


@dataclasses.dataclass
class DreStats:
    invocations: int = 0
    warm_starts: int = 0
    dre_hits: int = 0
    s3_gets: int = 0
    bytes_fetched: int = 0
    fetch_seconds: float = 0.0

    def merge(self, other: "DreStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))


@dataclasses.dataclass(frozen=True)
class Lease:
    """Outcome of one container acquisition (what the runtime schedules on).

    ``fetch_s`` is the S3 fetch latency *this* invocation pays (0 on a DRE
    hit) — per-call, unlike the cumulative ``DreStats.fetch_seconds``.
    ``stats`` is this call's one-invocation :class:`DreStats` delta, so
    callers aggregate run-level accounting with ``DreStats.merge`` instead
    of re-deriving the field logic.
    """

    container_id: int
    warm: bool
    dre_hit: bool
    fetch_s: float
    stats: DreStats = dataclasses.field(default_factory=DreStats)


class ContainerPool:
    """Warm-container simulator for one Lambda *function* (e.g. one QP id).

    ``invoke`` returns (warm, dre_hit): a warm start reuses a container; a DRE
    hit additionally finds the singleton already loaded with matching data.
    """

    def __init__(
        self,
        warm_prob: float = 0.9,
        fetch_bandwidth_bps: float = 85e6,
        fetch_rtt_s: float = 0.02,
        seed: int = 0,
    ):
        self._singletons: Dict[int, Hashable] = {}   # container id → data key
        self._next_container = 0
        self._free: list = []
        self._rng = random.Random(seed)
        self.warm_prob = warm_prob
        self.fetch_bandwidth_bps = fetch_bandwidth_bps
        self.fetch_rtt_s = fetch_rtt_s
        self.stats = DreStats()

    def acquire(self, data_key: Hashable, data_bytes: int,
                use_dre: bool = True) -> Lease:
        """Lease a container for one invocation *without* releasing it.

        Concurrent invocations of the same function (one wave of the
        serverless runtime) must each hold a distinct container; call
        :meth:`release` when the invocation's response has been sent.
        """
        warm = bool(self._free) and self._rng.random() < self.warm_prob
        if warm:
            cid = self._free.pop()
        else:
            cid = self._next_container
            self._next_container += 1
        hit = use_dre and self._singletons.get(cid) == data_key
        fetch_s = 0.0
        if not hit:
            fetch_s = self.fetch_rtt_s + data_bytes / self.fetch_bandwidth_bps
            self._singletons[cid] = data_key
        delta = DreStats(
            invocations=1,
            warm_starts=int(warm),
            dre_hits=int(hit),
            s3_gets=int(not hit),
            bytes_fetched=0 if hit else data_bytes,
            fetch_seconds=fetch_s,
        )
        self.stats.merge(delta)
        return Lease(container_id=cid, warm=warm, dre_hit=hit,
                     fetch_s=fetch_s, stats=delta)

    def release(self, lease: Lease) -> None:
        self._free.append(lease.container_id)

    def invoke(self, data_key: Hashable, data_bytes: int, use_dre: bool = True
               ) -> Tuple[bool, bool]:
        lease = self.acquire(data_key, data_bytes, use_dre=use_dre)
        self.release(lease)
        return lease.warm, lease.dre_hit


class ResultCache:
    """Optional lightweight result cache (disabled by default, §5.6)."""

    def __init__(self, capacity: int = 100_000):
        self.capacity = capacity
        self._store: Dict[Hashable, object] = {}
        self.hits = 0
        self.misses = 0

    def key(self, query_vec, predicates, k: int) -> Hashable:
        pv = tuple(round(float(v), 6) for v in query_vec)
        pp = tuple(
            (p.attr, p.op, float(p.lo), float(p.hi), tuple(p.values), p.group)
            for p in predicates
        )
        return (pv, pp, k)

    def get(self, key: Hashable) -> Optional[object]:
        if key in self._store:
            self.hits += 1
            return self._store[key]
        self.misses += 1
        return None

    def put(self, key: Hashable, value: object) -> None:
        if len(self._store) >= self.capacity:
            self._store.pop(next(iter(self._store)))
        self._store[key] = value

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
