"""Data Retention Exploitation (paper §3.2) + result cache (§3.2/§5.6).

DRE: FaaS containers persist process-global state across warm invocations.
Each QA/QP holds a singleton whose key identifies the dataset/partition; on
invoke, if the singleton already holds matching index data the S3 fetch is
skipped entirely. The QP-per-partition function naming
(``squash-processor-<pid>``) guarantees a warm QP container always matches
its partition. Beyond the fetched bytes, containers also retain *derived*
state (device-resident arrays built from the fetch) keyed per container id —
a warm container that already materialized its partition slice skips that
setup as well.

The result cache is the §5.6 layer above DRE: whole (query, predicates, k)
results are retained at the Coordinator so repeated queries never re-enter
the QA/QP fleet. Keys are exact — dtype-normalized query bytes plus a
canonicalized predicate tuple — so distinct queries can never alias, and
eviction is true LRU under both an entry cap and a byte budget.

On TPU the analogue is HBM residency of the index pytree across jitted
steps; this simulator exists to reproduce Fig. 6 (cost / latency /
S3-request reduction) and to drive the cost model.
"""

from __future__ import annotations

import dataclasses
import random
import sys
from collections import OrderedDict
from typing import Callable, Dict, Hashable, Iterable, Optional, Set, Tuple

import numpy as np

from repro.obs.metrics import REGISTRY as _METRICS

__all__ = ["ContainerPool", "ResultCache", "DreStats", "Lease"]


@dataclasses.dataclass
class DreStats:
    invocations: int = 0
    warm_starts: int = 0
    dre_hits: int = 0
    derived_hits: int = 0     # retained *derived* state reused (beyond fetch)
    s3_gets: int = 0
    bytes_fetched: int = 0
    fetch_seconds: float = 0.0

    def merge(self, other: "DreStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))


@dataclasses.dataclass(frozen=True)
class Lease:
    """Outcome of one container acquisition (what the runtime schedules on).

    ``fetch_s`` is the S3 fetch latency *this* invocation pays (0 on a DRE
    hit) — per-call, unlike the cumulative ``DreStats.fetch_seconds``.
    ``stats`` is this call's one-invocation :class:`DreStats` delta, so
    callers aggregate run-level accounting with ``DreStats.merge`` instead
    of re-deriving the field logic.
    """

    container_id: int
    warm: bool
    dre_hit: bool
    fetch_s: float
    stats: DreStats = dataclasses.field(default_factory=DreStats)
    epoch: int = 0    # pool derived-state epoch at acquire (staleness guard)


class ContainerPool:
    """Warm-container simulator for one Lambda *function* (e.g. one QP id).

    ``invoke`` returns (warm, dre_hit): a warm start reuses a container; a DRE
    hit additionally finds the singleton already loaded with matching data.
    """

    def __init__(
        self,
        warm_prob: float = 0.9,
        fetch_bandwidth_bps: float = 85e6,
        fetch_rtt_s: float = 0.02,
        seed: int = 0,
    ):
        self._singletons: Dict[int, Hashable] = {}   # container id → data key
        self._derived: Dict[int, Set[Hashable]] = {}  # container id → state keys
        self._epoch = 0                               # bumps on clear_derived
        self._next_container = 0
        self._free: list = []
        self._free_set: Set[int] = set()   # mirrors _free for O(1) membership
        self._rng = random.Random(seed)
        self.warm_prob = warm_prob
        self.fetch_bandwidth_bps = fetch_bandwidth_bps
        self.fetch_rtt_s = fetch_rtt_s
        self.stats = DreStats()

    def acquire(self, data_key: Hashable, data_bytes: int,
                use_dre: bool = True) -> Lease:
        """Lease a container for one invocation *without* releasing it.

        Concurrent invocations of the same function (one wave of the
        serverless runtime) must each hold a distinct container; call
        :meth:`release` when the invocation's response has been sent.

        With ``use_dre=False`` the singleton is neither consulted nor
        installed: a DRE-off invocation must not seed retention that a later
        DRE-on call would then score as a hit it never paid for.
        """
        warm = bool(self._free) and self._rng.random() < self.warm_prob
        if warm:
            cid = self._free.pop()
            self._free_set.discard(cid)
        else:
            cid = self._next_container
            self._next_container += 1
        hit = use_dre and self._singletons.get(cid) == data_key
        fetch_s = 0.0
        if not hit:
            fetch_s = self.fetch_rtt_s + data_bytes / self.fetch_bandwidth_bps
            if use_dre:
                self._singletons[cid] = data_key
        delta = DreStats(
            invocations=1,
            warm_starts=int(warm),
            dre_hits=int(hit),
            s3_gets=int(not hit),
            bytes_fetched=0 if hit else data_bytes,
            fetch_seconds=fetch_s,
        )
        self.stats.merge(delta)
        _METRICS.counter("dre.pool.leases").inc()
        if warm:
            _METRICS.counter("dre.pool.warm_starts").inc()
        if hit:
            _METRICS.counter("dre.pool.dre_hits").inc()
        return Lease(container_id=cid, warm=warm, dre_hit=hit,
                     fetch_s=fetch_s, stats=delta, epoch=self._epoch)

    def release(self, lease: Lease) -> None:
        """Return the lease's container to the free pool (idempotent).

        Guarded against double-release: without the check the same
        ``container_id`` entered ``_free`` twice and two concurrent leases
        were handed the *same* container — their warm/DRE accounting then
        described one singleton serving two in-flight invocations at once.
        The membership check runs against a set mirror of ``_free``, so a
        release stays O(1) even with thousands of idle containers.
        """
        if lease.container_id not in self._free_set:
            self._free.append(lease.container_id)
            self._free_set.add(lease.container_id)

    def invoke(self, data_key: Hashable, data_bytes: int, use_dre: bool = True
               ) -> Tuple[bool, bool]:
        lease = self.acquire(data_key, data_bytes, use_dre=use_dre)
        self.release(lease)
        return lease.warm, lease.dre_hit

    # ------------------------------------------------- derived-state retention

    def derived_hit(self, lease: Lease, key: Hashable,
                    use_dre: bool = True) -> bool:
        """True iff this lease's container already retains derived state
        under ``key`` (e.g. the device-resident partition slice built from a
        previous fetch).

        Counted once in the lease's per-call :class:`DreStats` delta *and*
        in the pool's cumulative ``stats`` — mirroring how ``acquire``
        records every other field — so callers that aggregate via
        ``DreStats.merge`` on ``lease.stats`` see the hit without a separate
        manual bump (which previously double-counted against the pool).
        """
        hit = use_dre and key in self._derived.get(lease.container_id, ())
        if hit:
            lease.stats.derived_hits += 1
            self.stats.derived_hits += 1
            _METRICS.counter("dre.pool.derived_hits").inc()
        return hit

    def retain_derived(self, lease: Lease, key: Hashable) -> None:
        """Record that the lease's container now holds derived state ``key``
        (only meaningful under DRE — callers gate on ``use_dre``).

        A lease acquired *before* the last :meth:`clear_derived` is stale:
        its retain is dropped, so an in-flight invocation that straddles an
        ``invalidate_cache()``/``swap_index`` cannot resurrect derived state
        the invalidation just cleared (and would otherwise leak forever,
        since its key embeds a dead ``index_version``)."""
        if lease.epoch != self._epoch:
            return
        self._derived.setdefault(lease.container_id, set()).add(key)

    def clear_derived(self) -> None:
        """Forget all retained derived state (e.g. on index invalidation),
        so permanently-stale keys don't accumulate across rebuilds. Bumps
        the epoch: leases acquired before the clear can no longer retain."""
        self._derived.clear()
        self._epoch += 1


def _entry_nbytes(key: Hashable, value: object) -> int:
    """Approximate resident size of one cache entry (key + value)."""
    n = 0
    parts = [key, value]
    while parts:
        item = parts.pop()
        if isinstance(item, tuple):
            parts.extend(item)
        elif isinstance(item, np.ndarray):
            n += item.nbytes
        elif isinstance(item, (bytes, bytearray)):
            n += len(item)
        else:
            n += sys.getsizeof(item)
    return n


_MISSING = object()


class ResultCache:
    """LRU result cache over (query, predicates, k) triples (§5.6).

    Keys are **exact**: the query's dtype-normalized float64 bytes (no
    rounding — distinct queries can never alias) plus a canonicalized
    predicate tuple (sorted, with IN value-sets sorted) so logically equal
    filters produce one key regardless of spelling order. Entries evict in
    true least-recently-*used* order — ``get`` refreshes recency — under
    both an entry-count cap and an optional byte budget with per-entry size
    accounting.

    Entries may carry a *partition dependency set* (``put(..., parts=...)``):
    the ids a cached result returned can only change if one of those
    partitions changes, so live-index mutations invalidate at segment
    granularity via :meth:`invalidate_partitions` instead of dropping the
    whole cache. Entries stored without a dependency set are conservatively
    treated as depending on everything.
    """

    def __init__(self, capacity: int = 100_000,
                 max_bytes: Optional[int] = None):
        self.capacity = capacity
        self.max_bytes = max_bytes
        self._store: "OrderedDict[Hashable, object]" = OrderedDict()
        self._sizes: Dict[Hashable, int] = {}
        self._deps: Dict[Hashable, Optional[frozenset]] = {}
        self.current_bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self.targeted_evictions = 0   # entries dropped by segment-granular
                                      # invalidation (not LRU pressure)
        self.oversize_skips = 0   # puts dropped for exceeding the whole budget

    @staticmethod
    def query_key(query_vec) -> bytes:
        """Exact dtype-normalized bytes of one query vector."""
        return np.ascontiguousarray(
            np.asarray(query_vec, dtype=np.float64)).tobytes()

    @staticmethod
    def canonical_predicates(predicates) -> Tuple:
        """Order-insensitive canonical form of a predicate list (hoistable:
        compute once per request batch, not once per query)."""
        return tuple(sorted(
            (int(p.attr), p.op, float(p.lo), float(p.hi),
             tuple(sorted(float(v) for v in p.values)),
             # None sorts before any group id without mixed-type comparison
             (0, 0) if p.group is None else (1, int(p.group)))
            for p in predicates
        ))

    @staticmethod
    def key(query_vec, predicates, k: int) -> Hashable:
        return (ResultCache.query_key(query_vec),
                ResultCache.canonical_predicates(predicates), int(k))

    def get(self, key: Hashable) -> Optional[object]:
        entry = self._store.get(key, _MISSING)
        if entry is not _MISSING:
            self._store.move_to_end(key)   # LRU refresh
            self.hits += 1
            _METRICS.counter("dre.result_cache.hits").inc()
            return entry
        self.misses += 1
        _METRICS.counter("dre.result_cache.misses").inc()
        return None

    def put(self, key: Hashable, value: object,
            parts: Optional[Iterable[int]] = None) -> None:
        """Admit ``value`` under ``key``; ``parts`` (optional) is the set of
        partition ids the result depends on, consumed by
        :meth:`invalidate_partitions`."""
        nbytes = _entry_nbytes(key, value)
        if self.capacity < 1:
            # A zero-entry cache can never retain anything: rejecting up
            # front (like the oversize path) avoids admit-then-evict churn
            # that misreported the drop as an LRU ``eviction``.
            self.oversize_skips += 1
            _METRICS.counter("dre.result_cache.oversize_skips").inc()
            return
        if self.max_bytes is not None and nbytes > self.max_bytes:
            # Larger than the whole budget: never admitted — and checked
            # *before* touching the store, so an existing entry under the
            # same key survives (the old order evicted it first and then
            # cached nothing, silently losing a live entry). The drop is
            # visible in ``oversize_skips``.
            self.oversize_skips += 1
            _METRICS.counter("dre.result_cache.oversize_skips").inc()
            return
        if key in self._store:
            self.current_bytes -= self._sizes.pop(key)
            del self._store[key]
            self._deps.pop(key, None)
        self._store[key] = value
        self._sizes[key] = nbytes
        self._deps[key] = None if parts is None else frozenset(
            int(p) for p in parts)
        self.current_bytes += nbytes
        while self._store and (
            len(self._store) > self.capacity
            or (self.max_bytes is not None
                and self.current_bytes > self.max_bytes)
        ):
            old_key, _ = self._store.popitem(last=False)
            self.current_bytes -= self._sizes.pop(old_key)
            self._deps.pop(old_key, None)
            self.evictions += 1
            _METRICS.counter("dre.result_cache.evictions").inc()

    def invalidate(self) -> None:
        """Drop every entry (index rebuilt / dataset swapped)."""
        self._store.clear()
        self._sizes.clear()
        self._deps.clear()
        self.current_bytes = 0
        self.invalidations += 1
        _METRICS.counter("dre.result_cache.invalidations").inc()

    def _evict_keys(self, keys) -> int:
        dropped = 0
        for key in keys:
            if key in self._store:
                self.current_bytes -= self._sizes.pop(key)
                del self._store[key]
                self._deps.pop(key, None)
                dropped += 1
                self.targeted_evictions += 1
                _METRICS.counter("dre.result_cache.targeted_evictions").inc()
        return dropped

    def invalidate_partitions(self, pids: Iterable[int]) -> int:
        """Segment-granular invalidation: drop only entries whose dependency
        set intersects ``pids`` (entries with no recorded dependency set are
        dropped too — unknown deps must be treated as depending on every
        partition). Returns the number of entries dropped."""
        pid_set = frozenset(int(p) for p in pids)
        doomed = [key for key, deps in self._deps.items()
                  if deps is None or (deps & pid_set)]
        dropped = self._evict_keys(doomed)
        if dropped:
            self.invalidations += 1
            _METRICS.counter("dre.result_cache.invalidations").inc()
        return dropped

    def invalidate_where(self, pred: Callable[[Hashable, object], bool]) -> int:
        """Drop entries for which ``pred(key, value)`` is true — the hook
        live-index inserts use to evict only results a new vector could
        displace. Returns the number of entries dropped."""
        doomed = [key for key, value in self._store.items()
                  if pred(key, value)]
        dropped = self._evict_keys(doomed)
        if dropped:
            self.invalidations += 1
            _METRICS.counter("dre.result_cache.invalidations").inc()
        return dropped

    def deps(self, key: Hashable) -> Optional[frozenset]:
        """The recorded partition dependency set (None = unknown/all)."""
        return self._deps.get(key)

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._store

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
