"""Data Retention Exploitation (paper §3.2) + optional result cache (§3.2/§5.6).

DRE: FaaS containers persist process-global state across warm invocations.
Each QA/QP holds a singleton whose key identifies the dataset/partition; on
invoke, if the singleton already holds matching index data the S3 fetch is
skipped entirely. The QP-per-partition function naming
(``squash-processor-<pid>``) guarantees a warm QP container always matches its
partition.

On TPU the analogue is HBM residency of the index pytree across jitted steps;
this simulator exists to reproduce Fig. 6 (cost / latency / S3-request
reduction) and to drive the cost model.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, Hashable, Optional, Tuple

__all__ = ["ContainerPool", "ResultCache", "DreStats"]


@dataclasses.dataclass
class DreStats:
    invocations: int = 0
    warm_starts: int = 0
    dre_hits: int = 0
    s3_gets: int = 0
    bytes_fetched: int = 0
    fetch_seconds: float = 0.0


class ContainerPool:
    """Warm-container simulator for one Lambda *function* (e.g. one QP id).

    ``invoke`` returns (warm, dre_hit): a warm start reuses a container; a DRE
    hit additionally finds the singleton already loaded with matching data.
    """

    def __init__(
        self,
        warm_prob: float = 0.9,
        fetch_bandwidth_bps: float = 85e6,
        fetch_rtt_s: float = 0.02,
        seed: int = 0,
    ):
        self._singletons: Dict[int, Hashable] = {}   # container id → data key
        self._next_container = 0
        self._free: list = []
        self._rng = random.Random(seed)
        self.warm_prob = warm_prob
        self.fetch_bandwidth_bps = fetch_bandwidth_bps
        self.fetch_rtt_s = fetch_rtt_s
        self.stats = DreStats()

    def invoke(self, data_key: Hashable, data_bytes: int, use_dre: bool = True
               ) -> Tuple[bool, bool]:
        self.stats.invocations += 1
        warm = bool(self._free) and self._rng.random() < self.warm_prob
        if warm:
            cid = self._free.pop()
            self.stats.warm_starts += 1
        else:
            cid = self._next_container
            self._next_container += 1
        hit = use_dre and self._singletons.get(cid) == data_key
        if hit:
            self.stats.dre_hits += 1
        else:
            self.stats.s3_gets += 1
            self.stats.bytes_fetched += data_bytes
            self.stats.fetch_seconds += (
                self.fetch_rtt_s + data_bytes / self.fetch_bandwidth_bps
            )
            self._singletons[cid] = data_key
        self._free.append(cid)
        return warm, hit


class ResultCache:
    """Optional lightweight result cache (disabled by default, §5.6)."""

    def __init__(self, capacity: int = 100_000):
        self.capacity = capacity
        self._store: Dict[Hashable, object] = {}
        self.hits = 0
        self.misses = 0

    def key(self, query_vec, predicates, k: int) -> Hashable:
        pv = tuple(round(float(v), 6) for v in query_vec)
        pp = tuple(
            (p.attr, p.op, float(p.lo), float(p.hi), tuple(p.values))
            for p in predicates
        )
        return (pv, pp, k)

    def get(self, key: Hashable) -> Optional[object]:
        if key in self._store:
            self.hits += 1
            return self._store[key]
        self.misses += 1
        return None

    def put(self, key: Hashable, value: object) -> None:
        if len(self._store) >= self.capacity:
            self._store.pop(next(iter(self._store)))
        self._store[key] = value

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
