"""HNSW baseline (Malkov & Yashunin) — the index family the paper argues
against for serverless deployment (§2.1, Table 1; Vexless uses it).

A faithful, compact implementation: multi-layer navigable small-world graph,
greedy beam search (ef), heuristic neighbor selection, post-filtering for
hybrid queries. Exists so the paper's comparisons (recall/latency/memory vs
OSQ, and the post-filter recall cliff under selective predicates) are
reproducible in this repo rather than cited.

NumPy-only on purpose: the point of the baseline is the *algorithm*, and the
paper's argument is precisely that its pointer-chasing structure doesn't map
onto FaaS/TPU-style workers the way scan-based OSQ does.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.attributes import Predicate

__all__ = ["HNSWConfig", "HNSWIndex"]


@dataclasses.dataclass(frozen=True)
class HNSWConfig:
    m: int = 16                   # max neighbors per node (layer > 0)
    ef_construction: int = 100
    ef_search: int = 64
    seed: int = 0


class HNSWIndex:
    """Hierarchical navigable small-world graph over (N, d) float vectors."""

    def __init__(self, vectors: np.ndarray, config: HNSWConfig = HNSWConfig(),
                 attributes: Optional[np.ndarray] = None):
        self.vectors = np.asarray(vectors, dtype=np.float32)
        self.attributes = attributes
        self.config = config
        self._m0 = 2 * config.m
        self._ml = 1.0 / math.log(config.m)
        self._rng = np.random.default_rng(config.seed)
        n = self.vectors.shape[0]
        self._levels = np.minimum(
            (-np.log(self._rng.uniform(1e-12, 1.0, n)) * self._ml)
            .astype(np.int32), 32)
        self._max_level = int(self._levels.max(initial=0))
        # adjacency: per level, list of neighbor arrays
        self._adj: List[Dict[int, List[int]]] = [
            {} for _ in range(self._max_level + 1)]
        self._entry = int(np.argmax(self._levels))
        for i in range(n):
            self._insert(i)

    # ------------------------------------------------------------- internals

    def _dist(self, q: np.ndarray, ids) -> np.ndarray:
        sub = self.vectors[ids]
        return np.sqrt(((sub - q[None, :]) ** 2).sum(axis=1))

    def _search_layer(self, q: np.ndarray, entry: int, ef: int, level: int,
                      allow: Optional[np.ndarray] = None) -> List[Tuple[float, int]]:
        """Beam search on one layer. Returns up to ef (dist, id) ascending."""
        d0 = float(self._dist(q, [entry])[0])
        visited = {entry}
        cand = [(d0, entry)]                   # min-heap by distance
        best: List[Tuple[float, int]] = [(-d0, entry)]  # max-heap (neg)
        while cand:
            d, u = heapq.heappop(cand)
            if d > -best[0][0] and len(best) >= ef:
                break
            for v in self._adj[level].get(u, []):
                if v in visited:
                    continue
                visited.add(v)
                dv = float(self._dist(q, [v])[0])
                if len(best) < ef or dv < -best[0][0]:
                    heapq.heappush(cand, (dv, v))
                    heapq.heappush(best, (-dv, v))
                    if len(best) > ef:
                        heapq.heappop(best)
        out = sorted((-nd, i) for nd, i in best)
        if allow is not None:
            out = [(d, i) for d, i in out if allow[i]]
        return out

    def _select_heuristic(self, q: np.ndarray, cands: List[Tuple[float, int]],
                          m: int) -> List[int]:
        """Heuristic neighbor selection (alg. 4): keep diverse neighbors."""
        selected: List[int] = []
        for d, c in sorted(cands):
            if len(selected) >= m:
                break
            ok = True
            for s in selected:
                if float(self._dist(self.vectors[c], [s])[0]) < d:
                    ok = False
                    break
            if ok:
                selected.append(c)
        if len(selected) < m:                      # backfill closest
            seen = set(selected)
            for d, c in sorted(cands):
                if c not in seen:
                    selected.append(c)
                    seen.add(c)
                if len(selected) >= m:
                    break
        return selected

    def _insert(self, i: int):
        level = int(self._levels[i])
        q = self.vectors[i]
        if i == self._entry:
            for l in range(level + 1):
                self._adj[l][i] = []
            return
        ep = self._entry
        for l in range(self._max_level, level, -1):
            res = self._search_layer(q, ep, 1, l)
            if res:
                ep = res[0][1]
        for l in range(min(level, self._max_level), -1, -1):
            ef = self.config.ef_construction
            res = self._search_layer(q, ep, ef, l)
            m = self._m0 if l == 0 else self.config.m
            nbrs = self._select_heuristic(q, res, m)
            self._adj[l][i] = list(nbrs)
            for v in nbrs:
                lst = self._adj[l].setdefault(v, [])
                lst.append(i)
                if len(lst) > m:
                    # Overflow pruning MUST use the diversity heuristic
                    # (alg. 4), not keep-closest: keep-closest severs every
                    # long-range/cross-cluster edge and fragments the graph.
                    ds = self._dist(self.vectors[v], lst)
                    cands = list(zip(ds.tolist(), lst))
                    self._adj[l][v] = self._select_heuristic(
                        self.vectors[v], cands, m)
            if res:
                ep = res[0][1]

    # ----------------------------------------------------------------- search

    def search(self, queries: np.ndarray, k: int = 10,
               ef: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Unfiltered ANN search. Returns (ids (Q,k), dists (Q,k))."""
        queries = np.atleast_2d(np.asarray(queries, dtype=np.float32))
        ef = ef or max(self.config.ef_search, k)
        out_i = np.full((len(queries), k), -1, np.int64)
        out_d = np.full((len(queries), k), np.inf)
        for qi, q in enumerate(queries):
            ep = self._entry
            for l in range(self._max_level, 0, -1):
                res = self._search_layer(q, ep, 1, l)
                if res:
                    ep = res[0][1]
            res = self._search_layer(q, ep, ef, 0)[:k]
            for r, (d, i) in enumerate(res):
                out_i[qi, r] = i
                out_d[qi, r] = d
        return out_i, out_d

    def search_filtered(self, queries: np.ndarray,
                        predicates: Sequence[Predicate], k: int = 10,
                        ef: Optional[int] = None,
                        expansion: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        """Post-filtered hybrid search (the decomposition the paper critiques):
        run ANN with an ef widened by ``expansion``, then drop vectors that
        fail the predicate. Under selective filters recall collapses unless
        ef grows ~1/selectivity — the effect bench_baselines measures."""
        assert self.attributes is not None
        mask = np.ones(self.vectors.shape[0], dtype=bool)
        for p in predicates:
            mask &= p.eval(self.attributes[:, p.attr])
        queries = np.atleast_2d(np.asarray(queries, dtype=np.float32))
        ef = (ef or max(self.config.ef_search, k)) * expansion
        out_i = np.full((len(queries), k), -1, np.int64)
        out_d = np.full((len(queries), k), np.inf)
        for qi, q in enumerate(queries):
            ep = self._entry
            for l in range(self._max_level, 0, -1):
                res = self._search_layer(q, ep, 1, l)
                if res:
                    ep = res[0][1]
            res = self._search_layer(q, ep, ef, 0, allow=mask)[:k]
            for r, (d, i) in enumerate(res):
                out_i[qi, r] = i
                out_d[qi, r] = d
        return out_i, out_d

    def graph_bytes(self) -> int:
        """In-memory footprint: full-precision vectors + adjacency."""
        edges = sum(len(v) for lvl in self._adj for v in lvl.values())
        return int(self.vectors.nbytes + edges * 8)
