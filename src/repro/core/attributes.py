"""Quantized attribute index + hybrid-filter machinery (paper §2.3).

Attributes are scalar-quantized with the same OSQ machinery as vector
dimensions. At query time each predicate compiles to a binary lookup array
``R[(M+1), A]`` over quantization cells; the global filter mask ``F`` is a
cascade of vectorized lookups combined with bitwise ANDs (conjunctive
predicates; the OR extension the paper mentions is supported via the ``IN``
operator and disjunct groups).

Supported operators (Def. 1): <, <=, =, >, >=, B (between), plus IN for
categorical sets. Any subset of attributes may be filtered.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import osq

__all__ = ["Predicate", "AttributeIndex", "build_attribute_index",
           "build_r_lookup", "filter_mask", "predicate_selectivity"]

_OPS = ("<", "<=", "=", ">", ">=", "B", "IN")


@dataclasses.dataclass(frozen=True)
class Predicate:
    """One per-attribute constraint: (attr, op, operands) — Def. 1 triple.

    ``group`` forms disjunct groups: predicates sharing a (non-None) group id
    on the same attribute are OR-combined before the cross-group AND cascade.
    A group must stay within one attribute — the filter array R factorizes
    per attribute, so cross-attribute disjunction cannot be represented.
    """

    attr: int
    op: str
    lo: float = 0.0
    hi: float = 0.0
    values: Tuple[float, ...] = ()
    group: Optional[int] = None

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown operator {self.op!r}; expected {_OPS}")

    def eval(self, x: np.ndarray) -> np.ndarray:
        """Evaluate on raw attribute values (ground-truth semantics)."""
        if self.op == "<":
            return x < self.lo
        if self.op == "<=":
            return x <= self.lo
        if self.op == "=":
            return x == self.lo
        if self.op == ">":
            return x > self.lo
        if self.op == ">=":
            return x >= self.lo
        if self.op == "B":
            return (x >= self.lo) & (x <= self.hi)
        if self.op == "IN":
            return np.isin(x, np.asarray(self.values))
        raise AssertionError(self.op)


@dataclasses.dataclass
class AttributeIndex:
    """Quantized attribute data (the 'Attribute Q-Index' of Fig. 4).

    Attributes:
      codes: (N, A) int32 quantized cells, held in memory for all vectors.
      boundaries: (M+1, A) boundary values V.
      centers: (M, A) cell representatives (for categorical: the value map).
      cells: (A,) cell counts.
    """

    codes: np.ndarray
    boundaries: np.ndarray
    centers: np.ndarray
    cells: np.ndarray

    @property
    def num_attributes(self) -> int:
        return int(self.codes.shape[1])

    @property
    def max_cells(self) -> int:
        return int(self.cells.max())


def build_attribute_index(
    attrs: np.ndarray, bits: Optional[Sequence[int]] = None
) -> AttributeIndex:
    """Quantize (N, A) attribute matrix.

    If ``bits`` is None, each attribute gets enough cells to give every
    distinct value its own cell when cardinality permits (exact filtering —
    matches the paper's uniform-attribute setup), capped at 8 bits.
    """
    attrs = np.asarray(attrs, dtype=np.float64)
    n, a = attrs.shape
    uniques = [np.unique(attrs[:, i]) for i in range(a)]
    if bits is None:
        bits = [
            int(min(8, max(1, np.ceil(np.log2(max(u.size, 2))))))
            for u in uniques
        ]
    bits = np.asarray(bits, dtype=np.int32)
    cells = (1 << bits.astype(np.int64)).astype(np.int64)
    m = int(cells.max())
    boundaries = np.full((m + 1, a), np.inf)
    centers = np.full((m, a), np.inf)
    codes = np.empty((n, a), dtype=np.int32)
    for i in range(a):
        u = uniques[i]
        k = int(cells[i])
        if u.size <= k:
            # Exact: one cell per distinct value (filtering is lossless —
            # the paper's categorical cell→value mapping).
            cells[i] = u.size
            boundaries[0, i] = -np.inf
            boundaries[1 : u.size, i] = (u[:-1] + u[1:]) / 2.0
            boundaries[u.size, i] = np.inf
            centers[: u.size, i] = u
            codes[:, i] = np.searchsorted(u, attrs[:, i])
        else:
            quant = osq.design_quantizers(attrs[:, i : i + 1], bits[i : i + 1])
            boundaries[: k + 1, i] = quant.boundaries[:, 0]
            centers[:k, i] = quant.centers[:, 0]
            codes[:, i] = osq.encode(quant, attrs[:, i : i + 1])[:, 0]
    return AttributeIndex(
        codes=codes,
        boundaries=boundaries,
        centers=centers,
        cells=cells,
    )


def build_r_lookup(
    index: AttributeIndex, predicates: Sequence[Predicate]
) -> np.ndarray:
    """Compile predicates to the binary cell-satisfaction array R (Fig. 4 step 1).

    Returns (M+1, A) uint8 — R[c, a] = 1 iff quantization cell c of attribute a
    satisfies the predicates on a; attributes without predicates are all-1.
    Cells are tested on their representative value (centers), which is exact
    when each distinct attribute value owns a cell. Predicates sharing a
    ``group`` id are OR-combined (disjunct group), groups and ungrouped
    predicates AND together.
    """
    m1, a = index.boundaries.shape
    r = np.ones((m1, a), dtype=np.uint8)
    # Padding cells never pass (defensive; valid codes never reach them).
    cell_idx = np.arange(m1)[:, None]
    r = np.where(cell_idx < index.cells[None, :], r, 0).astype(np.uint8)

    def cell_col(pred: Predicate) -> np.ndarray:
        k = int(index.cells[pred.attr])
        reps = index.centers[:k, pred.attr]
        col = np.zeros(m1, dtype=np.uint8)
        col[:k] = pred.eval(reps).astype(np.uint8)
        return col

    for attr, cols in _conjunct_terms(
            predicates, cell_col, lambda c1, c2: np.bitwise_or(c1, c2)):
        r[:, attr] &= cols
    return r


def _conjunct_terms(predicates, evaluate, disjoin):
    """Group-aware predicate combination shared by R-lookup and ground truth.

    Yields (attr, term) pairs to AND together, where each term is either one
    ungrouped predicate's evaluation or the OR over a disjunct group. Raises
    if a disjunct group spans attributes (R factorizes per attribute).
    """
    groups: Dict[int, List[Predicate]] = {}
    for pred in predicates:
        if pred.group is None:
            yield pred.attr, evaluate(pred)
        else:
            groups.setdefault(pred.group, []).append(pred)
    for gid, members in groups.items():
        attrs = {p.attr for p in members}
        if len(attrs) > 1:
            raise ValueError(
                f"disjunct group {gid} spans attributes {sorted(attrs)}; "
                "OR groups must reference a single attribute")
        term = evaluate(members[0])
        for pred in members[1:]:
            term = disjoin(term, evaluate(pred))
        yield members[0].attr, term


def filter_mask(r_lookup, codes):
    """Cascaded lookup + bitwise AND (Fig. 4 steps 2–3). JAX-jittable.

    Args:
      r_lookup: (M+1, A) binary satisfaction array for one query.
      codes: (N, A) in-memory quantized attribute codes.
    Returns:
      (N,) bool mask F — 1 where *all* attribute predicates pass.
    """
    r = jnp.asarray(r_lookup)
    c = jnp.asarray(codes)
    n, a = c.shape
    f = jnp.ones((n,), dtype=jnp.bool_)
    for attr in range(a):
        s = r[:, attr][c[:, attr]].astype(jnp.bool_)   # vectorized lookup
        f = jnp.logical_and(f, s)                      # F = F ∧ S_a
    return f


def predicate_selectivity(attrs: np.ndarray, predicates: Sequence[Predicate]) -> float:
    """Exact joint selectivity on raw values (for experiment calibration)."""
    return float(ground_truth_mask(attrs, predicates).mean())


def ground_truth_mask(attrs: np.ndarray, predicates: Sequence[Predicate]) -> np.ndarray:
    """Raw-value filter semantics: OR within disjunct groups, AND across."""
    mask = np.ones(attrs.shape[0], dtype=bool)
    for _, term in _conjunct_terms(
            predicates, lambda p: p.eval(attrs[:, p.attr]), np.logical_or):
        mask &= term
    return mask
