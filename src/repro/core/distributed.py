"""Distributed SQUASH search over a TPU mesh (DESIGN.md §5).

The serverless topology maps onto the mesh:

* ``model`` axis = QueryProcessors: each shard holds a fixed-size stack of
  partitions (packed low-bit codes, primary codes, full-precision rows).
* ``data`` axis (optionally ``("pod", "data")``) = QueryAllocators: the query
  batch is sharded.
* The paper's single-parallel-pass guarantee becomes a single collective
  round: each shard computes local top-k for (its queries × its partitions),
  then one ``all_gather`` over ``model`` + merge produces global results — the
  MPI-style reduce of §2.4.5 on the ICI collective tree.

Everything inside :func:`distributed_search` is jittable with fixed shapes;
the dynamic stages (predicate parsing, Algorithm 1) run on host and enter as
dense masks, mirroring how QAs ship bitmaps to QPs in request payloads.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.pipeline import SquashIndex

__all__ = ["StackedIndex", "stack_index", "local_topk", "distributed_search",
           "make_search_fn"]


@dataclasses.dataclass
class StackedIndex:
    """All partitions stacked to a fixed row budget (leading axis = partition).

    Padding rows have ``valid=False`` and never reach the results. This is the
    payload a QP shard holds resident (the DRE singleton, in HBM terms).
    """

    low_packed: jnp.ndarray   # (P, n_max, G32) uint32
    codes: jnp.ndarray        # (P, n_max, d) int32
    vectors: jnp.ndarray      # (P, n_max, d) float32
    valid: jnp.ndarray        # (P, n_max) bool
    vector_ids: jnp.ndarray   # (P, n_max) int32
    part_mean: jnp.ndarray    # (P, d)
    klt: jnp.ndarray          # (P, d, d)
    low_mean: jnp.ndarray     # (P, d)
    low_std: jnp.ndarray      # (P, d)
    boundaries: jnp.ndarray   # (P, M+1, d) float32 (+inf padding)
    cells: jnp.ndarray        # (P, d) int32

    @property
    def num_partitions(self) -> int:
        return int(self.low_packed.shape[0])

    @property
    def n_max(self) -> int:
        return int(self.low_packed.shape[1])


jax.tree_util.register_dataclass(
    StackedIndex,
    data_fields=[f.name for f in dataclasses.fields(StackedIndex)],
    meta_fields=[],
)


def stack_index(index: SquashIndex, pad_to_multiple: int = 1) -> StackedIndex:
    """Stack a built :class:`SquashIndex` into fixed-shape device arrays."""
    parts = index.parts
    p = len(parts)
    pad_p = -(-p // pad_to_multiple) * pad_to_multiple
    n_max = max(pt.size for pt in parts)
    d = index.dim
    g32 = parts[0].low.packed.shape[1]
    m1 = max(pt.quant.boundaries.shape[0] for pt in parts)

    def zeros(shape, dtype):
        return np.zeros(shape, dtype=dtype)

    low_packed = zeros((pad_p, n_max, g32), np.uint32)
    codes = zeros((pad_p, n_max, d), np.int32)
    vectors = zeros((pad_p, n_max, d), np.float32)
    valid = zeros((pad_p, n_max), bool)
    vector_ids = np.full((pad_p, n_max), -1, np.int32)
    part_mean = zeros((pad_p, d), np.float32)
    klt = np.tile(np.eye(d, dtype=np.float32), (pad_p, 1, 1))
    low_mean = zeros((pad_p, d), np.float32)
    low_std = np.ones((pad_p, d), np.float32)
    boundaries = np.full((pad_p, m1, d), np.inf, np.float32)
    cells = np.ones((pad_p, d), np.int32)

    for i, pt in enumerate(parts):
        n = pt.size
        low_packed[i, :n] = pt.low.packed
        codes[i, :n] = pt.codes
        vectors[i, :n] = pt.vectors
        valid[i, :n] = True
        vector_ids[i, :n] = pt.vector_ids
        part_mean[i] = pt.mean
        if pt.klt is not None:
            klt[i] = pt.klt.astype(np.float32)
        low_mean[i] = pt.low.mean
        low_std[i] = np.maximum(pt.low.std, 1e-12)
        mb = pt.quant.boundaries.shape[0]
        boundaries[i, :mb] = pt.quant.boundaries.astype(np.float32)
        cells[i] = pt.quant.cells
    return StackedIndex(
        low_packed=jnp.asarray(low_packed),
        codes=jnp.asarray(codes),
        vectors=jnp.asarray(vectors),
        valid=jnp.asarray(valid),
        vector_ids=jnp.asarray(vector_ids),
        part_mean=jnp.asarray(part_mean),
        klt=jnp.asarray(klt),
        low_mean=jnp.asarray(low_mean),
        low_std=jnp.asarray(low_std),
        boundaries=jnp.asarray(boundaries),
        cells=jnp.asarray(cells),
    )


def _pack_query_bits(z: jnp.ndarray) -> jnp.ndarray:
    """Binarize (already standardized) query and pack into uint32 words."""
    d = z.shape[-1]
    g = -(-d // 32)
    bits = (z > 0).astype(jnp.uint32)
    bits = jnp.pad(bits, (0, g * 32 - d))
    bits = bits.reshape(g, 32)
    weights = (jnp.uint32(1) << jnp.arange(31, -1, -1, dtype=jnp.uint32))
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


def _adc_table(qt: jnp.ndarray, boundaries: jnp.ndarray, cells: jnp.ndarray
               ) -> jnp.ndarray:
    """jnp twin of ``adc.build_adc_table`` (padding cells → 0, never selected)."""
    m1, d = boundaries.shape
    inner = boundaries[1:]                                   # (M, d)
    qcell = jnp.sum((inner <= qt[None, :]) & jnp.isfinite(inner), axis=0)
    cell_idx = jnp.arange(m1)[:, None]
    right = jnp.concatenate([boundaries[1:], jnp.full((1, d), jnp.inf)], axis=0)
    left = boundaries
    diff = jnp.where(
        cell_idx < qcell[None, :],
        qt[None, :] - right,
        jnp.where(cell_idx > qcell[None, :], left - qt[None, :], 0.0),
    )
    sq = jnp.where(jnp.isfinite(diff), diff * diff, 0.0)
    return jnp.where(cell_idx >= cells[None, :], 0.0, sq)


def local_topk(
    query: jnp.ndarray,
    stacked: StackedIndex,
    cand_mask: jnp.ndarray,
    *,
    k: int,
    ham_keep: int,
    refine_k: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One query × one partition-stack shard → (k ids, k dists). Jittable.

    Stages 3–5 of §2.4 with fixed shapes: Hamming prune to ``ham_keep``, ADC
    LB distances, full-precision refinement of ``refine_k``, local top-k.
    ``cand_mask`` is (P, n_max) — filter ∧ residency ∧ Alg.-1 visit decision.
    """

    def one_partition(lp, codes, vecs, valid, vids, mean, klt, lmean, lstd,
                      bounds, cells, cmask):
        n_max = lp.shape[0]
        cand = cmask & valid
        # --- low-bit Hamming prune (raw centered space) ------------------
        zq = (query - mean - lmean) / lstd
        qbits = _pack_query_bits(zq)
        x = jnp.bitwise_xor(lp, qbits[None, :])
        ham = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
        big = jnp.int32(1 << 30)
        ham = jnp.where(cand, ham, big)
        keep = min(ham_keep, n_max)
        neg, sel = jax.lax.top_k(-ham, keep)                  # (keep,)
        kept_alive = (-neg) < big
        # --- ADC LB distances on survivors -------------------------------
        qt = (query - mean) @ klt
        table = _adc_table(qt, bounds, cells)                 # (M+1, d)
        kept_codes = codes[sel]                               # (keep, d)
        picked = jnp.take_along_axis(table, kept_codes, axis=0)
        lb = jnp.sqrt(jnp.sum(picked, axis=-1))
        lb = jnp.where(kept_alive, lb, jnp.inf)
        rk = min(refine_k, keep)
        neg_lb, sel2 = jax.lax.top_k(-lb, rk)
        rows = sel[sel2]
        alive2 = jnp.isfinite(-neg_lb)
        # --- full-precision refinement ('EFS' rows live in the shard) ----
        full = vecs[rows]                                     # (rk, d)
        exact = jnp.sqrt(jnp.sum((full - query[None, :]) ** 2, axis=-1))
        exact = jnp.where(alive2, exact, jnp.inf)
        kk = min(k, rk)
        neg_e, sel3 = jax.lax.top_k(-exact, kk)
        out_ids = vids[rows[sel3]]
        out_d = -neg_e
        out_ids = jnp.where(jnp.isfinite(out_d), out_ids, -1)
        if kk < k:
            out_ids = jnp.pad(out_ids, (0, k - kk), constant_values=-1)
            out_d = jnp.pad(out_d, (0, k - kk), constant_values=jnp.inf)
        return out_ids, out_d

    ids, dists = jax.vmap(one_partition)(
        stacked.low_packed, stacked.codes, stacked.vectors, stacked.valid,
        stacked.vector_ids, stacked.part_mean, stacked.klt, stacked.low_mean,
        stacked.low_std, stacked.boundaries, stacked.cells, cand_mask,
    )                                                         # (P, k) each
    flat_d = dists.reshape(-1)
    flat_i = ids.reshape(-1)
    neg, sel = jax.lax.top_k(-flat_d, k)
    return flat_i[sel], -neg


def make_search_fn(
    mesh: Mesh,
    *,
    k: int,
    ham_keep: int,
    refine_k: int,
    data_axes=("data",),
    model_axis: str = "model",
):
    """Build the jitted shard_map search function for ``mesh``.

    Inputs (global shapes):
      queries     (Q, d)        — sharded over data axes
      cand_mask   (Q, P, n_max) — filter ∧ residency ∧ visit (from Alg. 1)
      stacked     StackedIndex  — partition axis sharded over ``model``
    Output: ids (Q, k) int32, dists (Q, k) f32 — sharded like queries.
    """
    from jax import shard_map

    dq = data_axes if len(data_axes) > 1 else data_axes[0]
    query_spec = P(dq)                       # (Q, d): Q over data axes
    mask_spec = P(dq, model_axis)            # (Q, P, n_max)
    treedef_box = {}

    def _shard_body(queries, cand_mask, *stacked_leaves):
        stacked = jax.tree_util.tree_unflatten(treedef_box["td"], stacked_leaves)

        def per_query(q, cm):
            return local_topk(
                q, stacked, cm, k=k, ham_keep=ham_keep, refine_k=refine_k
            )

        ids, dists = jax.vmap(per_query)(queries, cand_mask)   # (Qs, k)
        # Single-pass MPI-style reduce over the model axis (§2.4.5).
        all_ids = jax.lax.all_gather(ids, model_axis, axis=1, tiled=True)
        all_d = jax.lax.all_gather(dists, model_axis, axis=1, tiled=True)
        neg, sel = jax.lax.top_k(-all_d, k)
        return jnp.take_along_axis(all_ids, sel, axis=1), -neg

    def search(queries, cand_mask, stacked: StackedIndex):
        leaves, treedef_box["td"] = jax.tree_util.tree_flatten(stacked)
        in_specs = (query_spec, mask_spec, *(P(model_axis) for _ in leaves))
        out_specs = (query_spec, query_spec)
        fn = shard_map(
            _shard_body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
        return jax.jit(fn)(queries, cand_mask, *leaves)

    return search


def distributed_search(
    index: SquashIndex,
    queries: np.ndarray,
    predicates,
    k: int,
    mesh: Optional[Mesh] = None,
    data_axes=("data",),
    model_axis: str = "model",
) -> Tuple[np.ndarray, np.ndarray]:
    """Host-orchestrated distributed hybrid search (QA plane + QP plane).

    Runs the dynamic stages (predicate parse → filter mask → Algorithm 1) on
    host, then dispatches the jitted shard_map kernel.
    """
    from repro.core import attributes as am, partitions as pm

    queries = np.atleast_2d(np.asarray(queries, dtype=np.float32))
    qn = queries.shape[0]
    cfg = index.config
    r = am.build_r_lookup(index.attr_index, predicates)
    f_one = np.asarray(am.filter_mask(r, index.attr_index.codes))
    f = np.broadcast_to(f_one, (qn, f_one.shape[0]))
    visit, cands = pm.select_partitions(
        queries.astype(np.float64), index.partitioning.centroids, f,
        index.partitioning.assign, index.partitioning.threshold, k,
    )

    if mesh is None:
        devs = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(devs, (data_axes[0], model_axis))

    model_size = int(np.prod([mesh.shape[a] for a in (model_axis,)]))
    stacked = stack_index(index, pad_to_multiple=model_size)
    p, n_max = stacked.num_partitions, stacked.n_max

    # Dense candidate mask (Q, P, n_max): visit ∧ filter ∧ residency.
    cand_mask = np.zeros((qn, p, n_max), dtype=bool)
    for qi in range(qn):
        for pid, rows in cands[qi].items():
            cand_mask[qi, pid, rows] = True

    data_size = int(np.prod([mesh.shape[a] for a in data_axes]))
    pad_q = -(-qn // data_size) * data_size
    if pad_q != qn:
        queries = np.pad(queries, ((0, pad_q - qn), (0, 0)))
        cand_mask = np.pad(cand_mask, ((0, pad_q - qn), (0, 0), (0, 0)))

    n_cand = max(int(cand_mask.sum(axis=(1, 2)).max()), 1)
    ham_keep = min(
        n_max,
        max(min(cfg.min_hamming_keep, n_max),
            int(np.ceil(n_max * cfg.hamming_perc / 100.0))),
    )
    refine_k = min(int(np.ceil(cfg.refine_ratio * k)), ham_keep)
    search = make_search_fn(
        mesh, k=k, ham_keep=ham_keep, refine_k=refine_k,
        data_axes=data_axes, model_axis=model_axis,
    )
    with mesh:
        ids, dists = search(jnp.asarray(queries), jnp.asarray(cand_mask), stacked)
    return np.asarray(ids)[:qn], np.asarray(dists)[:qn]
