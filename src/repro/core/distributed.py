"""Distributed SQUASH search over a TPU mesh (DESIGN.md §6).

The serverless topology maps onto the mesh:

* ``model`` axis = QueryProcessors: each shard holds a fixed-size stack of
  partitions (packed low-bit codes, primary codes, full-precision rows).
* ``data`` axis (optionally ``("pod", "data")``) = QueryAllocators: the query
  batch is sharded.
* The paper's single-parallel-pass guarantee becomes a single collective
  round: each shard computes local top-k for (its queries × its partitions),
  then one ``all_gather`` over ``model`` + merge produces global results — the
  MPI-style reduce of §2.4.5 on the ICI collective tree.

Stages 3–5 inside the shard body are the **same batched data plane** the
single-host jax backend uses (``repro.core.dataplane.batched_stage345``) —
each shard simply runs it over its local partition stack, so single-host and
distributed search cannot drift apart. The dynamic stages (predicate parsing,
Algorithm 1) run on host and enter as dense masks plus per-(query, partition)
keep/take counts, mirroring how QAs ship bitmaps to QPs in request payloads.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import dataplane
from repro.core.dataplane import StackedIndex, stack_index
from repro.core.pipeline import SquashIndex

try:  # jax >= 0.6 exports shard_map at top level
    from jax import shard_map as _shard_map_raw

    _REP_KWARG = "check_vma"
except ImportError:  # jax 0.4.x keeps it in experimental
    from jax.experimental.shard_map import shard_map as _shard_map_raw

    _REP_KWARG = "check_rep"


def _shard_map(f, *, mesh, in_specs, out_specs):
    # Replication checking rejects the data-dependent masks; both jax
    # generations disable it under a different kwarg name.
    return _shard_map_raw(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **{_REP_KWARG: False})

__all__ = ["StackedIndex", "stack_index", "distributed_search",
           "make_search_fn"]


def make_search_fn(
    mesh: Mesh,
    *,
    k: int,
    keep_s: int,
    take_s: int,
    refine: bool = True,
    data_axes=("data",),
    model_axis: str = "model",
):
    """Build the jitted shard_map search function for ``mesh``.

    Inputs (global shapes):
      queries     (Q, d)        — sharded over data axes
      cand_mask   (Q, P, n_max) — filter ∧ residency ∧ visit (from Alg. 1)
      keep, take  (Q, P) int32  — per-pair dynamic stage counts
      stacked     StackedIndex  — partition axis sharded over ``model``
    Output: ids (Q, k) int32, dists (Q, k) float — sharded like queries.

    ``keep_s``/``take_s`` are the static top_k sizes (see
    ``dataplane.static_counts``).
    """
    dq = data_axes if len(data_axes) > 1 else data_axes[0]
    query_spec = P(dq)                       # (Q, d): Q over data axes
    mask_spec = P(dq, model_axis)            # (Q, P, n_max) / (Q, P)

    # jax's trace cache is keyed on the *wrapper's identity*, so the old
    # `return jax.jit(fn)(...)` built a fresh wrapper per search and
    # recompiled the shard_map kernel on every call. Cache one jitted
    # wrapper per stacked-index treedef instead (the treedef is the only
    # call-to-call structural variation; shape changes within a treedef hit
    # jax's own signature cache inside the retained wrapper).
    jit_cache = {}

    def _build(treedef):
        def _shard_body(queries, cand_mask, keep, take, *stacked_leaves):
            stacked = jax.tree_util.tree_unflatten(treedef, stacked_leaves)
            # Local batched Stage 3–5 over this shard's partition stack.
            ids, dists = dataplane.batched_stage345(
                queries, stacked, cand_mask, keep, take,
                k=k, keep_s=keep_s, take_s=take_s, refine=refine,
            )                                                   # (Qs, k)
            # Single-pass MPI-style reduce over the model axis (§2.4.5).
            all_ids = jax.lax.all_gather(ids, model_axis, axis=1, tiled=True)
            all_d = jax.lax.all_gather(dists, model_axis, axis=1, tiled=True)
            neg, sel = jax.lax.top_k(-all_d, k)
            return jnp.take_along_axis(all_ids, sel, axis=1), -neg

        in_specs = (query_spec, mask_spec, mask_spec, mask_spec,
                    *(P(model_axis) for _ in range(treedef.num_leaves)))
        out_specs = (query_spec, query_spec)
        fn = _shard_map(
            _shard_body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        )
        return jax.jit(fn)

    def search(queries, cand_mask, keep, take, stacked: StackedIndex):
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        fn = jit_cache.get(treedef)
        if fn is None:
            fn = jit_cache[treedef] = _build(treedef)
        return fn(queries, cand_mask, keep, take, *leaves)

    return search


def distributed_search(
    index: SquashIndex,
    queries: np.ndarray,
    predicates,
    k: int,
    mesh: Optional[Mesh] = None,
    data_axes=("data",),
    model_axis: str = "model",
) -> Tuple[np.ndarray, np.ndarray]:
    """Host-orchestrated distributed hybrid search (QA plane + QP plane).

    Runs the dynamic stages (predicate parse → filter mask → Algorithm 1) on
    host, then dispatches the jitted shard_map kernel. Results match
    ``index.search`` (either backend) bit-for-bit on ids up to cross-shard
    padding of the partition axis.
    """
    from repro.core import attributes as am, partitions as pm

    queries = np.atleast_2d(np.asarray(queries, dtype=np.float64))
    qn = queries.shape[0]
    cfg = index.config
    r = am.build_r_lookup(index.attr_index, predicates)
    f_one = np.asarray(am.filter_mask(r, index.attr_index.codes))
    if getattr(index, "live_mask", None) is not None:
        f_one = f_one & index.live_mask   # tombstoned rows fail Stage 1
    f = np.broadcast_to(f_one, (qn, f_one.shape[0]))
    visit, cands = pm.select_partitions(
        queries, index.partitioning.centroids, f,
        index.partitioning.assign, index.partitioning.threshold, k,
    )

    if mesh is None:
        devs = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(devs, (data_axes[0], model_axis))

    dtype = np.float64 if jax.config.jax_enable_x64 else np.float32
    model_size = int(np.prod([mesh.shape[a] for a in (model_axis,)]))
    stacked = stack_index(index, pad_to_multiple=model_size, dtype=dtype)
    p, n_max = stacked.num_partitions, stacked.n_max

    # Dense per-(query, partition) payloads: mask + dynamic stage counts.
    cand_mask, n_cand = dataplane.build_cand_arrays(cands, qn, p, n_max)
    profile = getattr(index, "profile", None)
    keep, take = dataplane.stage_counts(n_cand, cfg, k, profile)
    keep_s, take_s = dataplane.static_counts(n_max, cfg, k, profile)

    data_size = int(np.prod([mesh.shape[a] for a in data_axes]))
    pad_q = -(-qn // data_size) * data_size
    if pad_q != qn:
        queries = np.pad(queries, ((0, pad_q - qn), (0, 0)))
        cand_mask = np.pad(cand_mask, ((0, pad_q - qn), (0, 0), (0, 0)))
        keep = np.pad(keep, ((0, pad_q - qn), (0, 0)))
        take = np.pad(take, ((0, pad_q - qn), (0, 0)))

    search = make_search_fn(
        mesh, k=k, keep_s=keep_s, take_s=take_s, refine=cfg.enable_refine,
        data_axes=data_axes, model_axis=model_axis,
    )
    with mesh:
        ids, dists = search(
            jnp.asarray(queries, dtype), jnp.asarray(cand_mask),
            jnp.asarray(keep), jnp.asarray(take), stacked,
        )
    return np.asarray(ids)[:qn], np.asarray(dists)[:qn]
