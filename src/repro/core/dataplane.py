"""Batched JAX query data plane — Stages 3–5 of §2.4 with fixed shapes.

This module is the single implementation of the paper's per-partition hot
path (low-bit Hamming prune → ADC lookup-table lower bounds → full-precision
refinement → single-pass top-k merge), batched over queries *and* partitions
and jit-compiled end to end. Two consumers share it:

* ``SquashIndex.search(backend="jax")`` (``repro.core.pipeline``) — single
  host, the whole :class:`StackedIndex` resident.
* ``repro.core.distributed`` — the same stages inside a ``shard_map`` body,
  partitions sharded over the ``model`` mesh axis (the QP plane).

Layout: all partitions are stacked to a fixed row budget ``n_max`` with
validity masks (:func:`stack_index`), so every stage is a dense fixed-shape
tensor op — ``(Q, P, G)`` packed query words × ``(P, n_max, G)`` stacked
codes for the Hamming kernel, ``(Q·P, M+1, d)`` tables × ``(Q·P, keep, d)``
survivor codes for the ADC kernel. The kernels dispatch through
``repro.kernels.ops``: Pallas on TPU, pure-jnp XLA twins on CPU.

Parity contract: the returned ids are **bitwise identical** to the NumPy
reference path in ``pipeline.py``. Data-dependent per-(query, partition)
candidate/keep/refine counts (byproducts of Algorithm 1 on the host) enter
as dense integer arrays and are applied as masks over statically-shaped
``top_k`` results, so shapes never depend on data — one trace per
(Q, k, index-shape). Ties are broken identically on both sides: ascending
(score, row) within a stage, ascending (distance, partition, rank) at the
merge — ``lax.top_k`` prefers lower indices, the NumPy path uses stable
sorts over partition-ascending candidate streams.

Known residual: both sides compute identical float32 ADC table *entries*,
but row sums reduce in backend-specific order (NumPy pairwise vs XLA), so
two survivors whose LB sums differ only at f32-ULP scale could straddle the
refine-take cut differently. Final ids then still agree unless the excluded
row belonged to the true top-k — a measure-zero event the R·k refinement
buffer absorbs; the parity suite and smoke gate run seed-deterministic data
where this holds exactly.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.obs.metrics import REGISTRY as _METRICS

__all__ = [
    "StackedIndex", "stack_index", "part_stack_arrays", "stack_single_part",
    "pack_query_bits", "adc_table_batch",
    "query_cells", "adc_lb_direct", "build_cand_arrays", "stage_counts",
    "static_counts", "batched_stage345", "make_plane",
]

_BIG_HAMMING = jnp.int32(1 << 30)

# Stage 4 formulation switch: dense per-(query, partition) tables feed the
# one-hot/MXU kernel, but their (M+1) axis scales with the *hottest*
# dimension's cell count (2^12 at the default max_bits_per_dim) — a dense
# (Q, P, M+1, d) build is gigabytes at batch size. Above this M+1 the plane
# switches to the direct boundary-gather evaluation (two gathers per
# (survivor, dim) — the paper's "advanced indexing", batched).
ADC_TABLE_MAX_M1 = 129


@dataclasses.dataclass
class StackedIndex:
    """All partitions stacked to a fixed row budget (leading axis = partition).

    Padding rows have ``valid=False`` and never reach the results. This is the
    payload a QP shard holds resident (the DRE singleton, in HBM terms).
    """

    low_packed: jnp.ndarray   # (P, n_max, G32) uint32
    codes: jnp.ndarray        # (P, n_max, d) int32
    vectors: jnp.ndarray      # (P, n_max, d) float
    valid: jnp.ndarray        # (P, n_max) bool
    vector_ids: jnp.ndarray   # (P, n_max) int32
    part_mean: jnp.ndarray    # (P, d)
    klt: jnp.ndarray          # (P, d, d)
    low_mean: jnp.ndarray     # (P, d)
    low_std: jnp.ndarray      # (P, d)
    boundaries: jnp.ndarray   # (P, M+1, d) float (+inf padding)
    cells: jnp.ndarray        # (P, d) int32

    @property
    def num_partitions(self) -> int:
        return int(self.low_packed.shape[0])

    @property
    def n_max(self) -> int:
        return int(self.low_packed.shape[1])


jax.tree_util.register_dataclass(
    StackedIndex,
    data_fields=[f.name for f in dataclasses.fields(StackedIndex)],
    meta_fields=[],
)


def part_stack_arrays(pt, *, n_max: int, m1: int, d: int,
                      dtype=np.float32,
                      live_rows: Optional[np.ndarray] = None
                      ) -> Dict[str, np.ndarray]:
    """One partition's numpy slab of the stacked payload (no leading P axis).

    The field values are exactly what :func:`stack_index` writes at that
    partition's row, so a QueryProcessor worker holding only its own
    partition can rebuild ``stack_index(index)[pid:pid+1]`` bit-for-bit from
    (this dict, the global ``n_max``/``m1``) without the rest of the index —
    the contract the ProcessTransport parity tests pin.

    ``live_rows`` (optional, (n,) bool) folds a live-index tombstone bitmap
    into ``valid`` so Stage 3 (``batched_stage345``'s ``alive0`` mask) drops
    dead rows even when a request names them as candidates.
    """
    n = pt.size
    g32 = pt.low.packed.shape[1]
    out = {
        "low_packed": np.zeros((n_max, g32), np.uint32),
        "codes": np.zeros((n_max, d), np.int32),
        "vectors": np.zeros((n_max, d), dtype),
        "valid": np.zeros((n_max,), bool),
        "vector_ids": np.full((n_max,), -1, np.int32),
        "part_mean": np.asarray(pt.mean, dtype),
        "klt": (pt.klt.astype(dtype) if pt.klt is not None
                else np.eye(d, dtype=dtype)),
        "low_mean": np.asarray(pt.low.mean, dtype),
        "low_std": np.maximum(pt.low.std, 1e-12).astype(dtype),
        "boundaries": np.full((m1, d), np.inf, dtype),
        "cells": np.asarray(pt.quant.cells, np.int32),
    }
    out["low_packed"][:n] = pt.low.packed
    out["codes"][:n] = pt.codes
    out["vectors"][:n] = pt.vectors
    out["valid"][:n] = True if live_rows is None else np.asarray(
        live_rows, dtype=bool)
    out["vector_ids"][:n] = pt.vector_ids
    mb = pt.quant.boundaries.shape[0]
    out["boundaries"][:mb] = pt.quant.boundaries.astype(dtype)
    return out


def stack_single_part(arrays: Dict[str, np.ndarray]) -> StackedIndex:
    """Build a 1-partition :class:`StackedIndex` from a part's slab arrays."""
    return StackedIndex(**{k: jnp.asarray(v[None]) for k, v in arrays.items()})


def stack_index(index, pad_to_multiple: int = 1,
                dtype=np.float32) -> StackedIndex:
    """Stack a built ``SquashIndex`` into fixed-shape device arrays.

    ``dtype`` sets the float width of the stacked payload: the jax backend
    uses float64 when x64 is enabled so it matches the NumPy reference
    bit-for-bit, float32 otherwise (the deployment configuration).
    """
    parts = index.parts
    p = len(parts)
    pad_p = -(-p // pad_to_multiple) * pad_to_multiple
    n_max = max(pt.size for pt in parts)
    d = index.dim
    g32 = parts[0].low.packed.shape[1]
    m1 = max(pt.quant.boundaries.shape[0] for pt in parts)

    def zeros(shape, dt):
        return np.zeros(shape, dtype=dt)

    low_packed = zeros((pad_p, n_max, g32), np.uint32)
    codes = zeros((pad_p, n_max, d), np.int32)
    vectors = zeros((pad_p, n_max, d), dtype)
    valid = zeros((pad_p, n_max), bool)
    vector_ids = np.full((pad_p, n_max), -1, np.int32)
    part_mean = zeros((pad_p, d), dtype)
    klt = np.tile(np.eye(d, dtype=dtype), (pad_p, 1, 1))
    low_mean = zeros((pad_p, d), dtype)
    low_std = np.ones((pad_p, d), dtype)
    boundaries = np.full((pad_p, m1, d), np.inf, dtype)
    cells = np.ones((pad_p, d), np.int32)

    live_mask = getattr(index, "live_mask", None)
    for i, pt in enumerate(parts):
        live_rows = None if live_mask is None else live_mask[pt.vector_ids]
        pa = part_stack_arrays(pt, n_max=n_max, m1=m1, d=d, dtype=dtype,
                               live_rows=live_rows)
        low_packed[i] = pa["low_packed"]
        codes[i] = pa["codes"]
        vectors[i] = pa["vectors"]
        valid[i] = pa["valid"]
        vector_ids[i] = pa["vector_ids"]
        part_mean[i] = pa["part_mean"]
        klt[i] = pa["klt"]
        low_mean[i] = pa["low_mean"]
        low_std[i] = pa["low_std"]
        boundaries[i] = pa["boundaries"]
        cells[i] = pa["cells"]
    return StackedIndex(
        low_packed=jnp.asarray(low_packed),
        codes=jnp.asarray(codes),
        vectors=jnp.asarray(vectors),
        valid=jnp.asarray(valid),
        vector_ids=jnp.asarray(vector_ids),
        part_mean=jnp.asarray(part_mean),
        klt=jnp.asarray(klt),
        low_mean=jnp.asarray(low_mean),
        low_std=jnp.asarray(low_std),
        boundaries=jnp.asarray(boundaries),
        cells=jnp.asarray(cells),
    )


def pack_query_bits(z: jnp.ndarray) -> jnp.ndarray:
    """Binarize standardized values and pack into uint32 words, MSB-first.

    Works over arbitrary leading batch axes: (..., d) → (..., ceil(d/32)).
    Twin of ``lowbit.pack_bits_u32(binarize(...))``.
    """
    d = z.shape[-1]
    g = -(-d // 32)
    bits = (z > 0).astype(jnp.uint32)
    pad = [(0, 0)] * (z.ndim - 1) + [(0, g * 32 - d)]
    bits = jnp.pad(bits, pad)
    bits = bits.reshape(*z.shape[:-1], g, 32)
    weights = jnp.uint32(1) << jnp.arange(31, -1, -1, dtype=jnp.uint32)
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


def adc_table_batch(qt: jnp.ndarray, boundaries: jnp.ndarray,
                    cells: jnp.ndarray) -> jnp.ndarray:
    """Batched jnp twin of ``adc.build_adc_table``.

    qt: (..., d) transformed queries; boundaries: (..., M+1, d) with +inf
    padding; cells: (..., d). Returns (..., M+1, d) squared edge distances
    with padding cells set to 0 (one-hot/gather never selects them for valid
    codes, and zeros keep the kernels' accumulators finite).
    """
    m1 = boundaries.shape[-2]
    inner = boundaries[..., 1:, :]                          # (..., M, d)
    qcell = jnp.sum(
        (inner <= qt[..., None, :]) & jnp.isfinite(inner), axis=-2
    )                                                       # (..., d)
    cell_idx = jnp.arange(m1)[:, None]                      # (M+1, 1)
    pad_inf = jnp.full(boundaries.shape[:-2] + (1, boundaries.shape[-1]),
                       jnp.inf, boundaries.dtype)
    right = jnp.concatenate([inner, pad_inf], axis=-2)
    left = boundaries
    diff = jnp.where(
        cell_idx < qcell[..., None, :],
        qt[..., None, :] - right,
        jnp.where(cell_idx > qcell[..., None, :],
                  left - qt[..., None, :], 0.0),
    )
    sq = jnp.where(jnp.isfinite(diff), diff * diff, 0.0)
    return jnp.where(cell_idx >= cells[..., None, :], 0.0, sq)


def query_cells(qt: jnp.ndarray, boundaries: jnp.ndarray) -> jnp.ndarray:
    """Per-dimension home cell of each query: (Q, P, d) int32.

    Batched twin of the ``searchsorted`` loop in ``adc.build_adc_table``:
    counts interior boundaries ≤ qt (the +inf padding never counts), via a
    binary search per (query, partition, dim) instead of an O(M·d) scan.
    """
    inner = jnp.swapaxes(boundaries[:, 1:, :], -1, -2)      # (P, d, M)

    def one(a, v):
        return jnp.searchsorted(a, v, side="right")

    per_dim = jax.vmap(one)                                 # (d,M),(d,) → (d,)
    per_part = jax.vmap(per_dim)                            # (P,d,M),(P,d)

    def per_q(qtq):                                         # (P, d) → (P, d)
        return per_part(inner, qtq)

    return jax.vmap(per_q)(qt).astype(jnp.int32)


def adc_lb_direct(qt: jnp.ndarray, qcell: jnp.ndarray, boundaries: jnp.ndarray,
                  codes: jnp.ndarray) -> jnp.ndarray:
    """Squared LB sums via direct boundary gathers (no dense table).

    qt/qcell: (Q, P, d); boundaries: (P, M+1, d); codes: (Q, P, S, d) →
    (Q, P, S) f32. Per (survivor, dim): 0 in the query's own cell, squared
    distance to the facing cell edge otherwise — identical values to the
    dense-table entries (computed in the same dtype, cast f32 before the
    row sum, matching the NumPy reference's float32 tables).
    """
    m1 = boundaries.shape[-2]
    c = codes
    cc = qcell[:, :, None, :]                               # (Q, P, 1, d)
    b = boundaries[None]                                    # (1, P, M+1, d)
    right = jnp.take_along_axis(b, jnp.clip(c + 1, 0, m1 - 1), axis=2)
    left = jnp.take_along_axis(b, jnp.clip(c, 0, m1 - 1), axis=2)
    qtb = qt[:, :, None, :]
    diff = jnp.where(c < cc, qtb - right,
                     jnp.where(c > cc, left - qtb, 0.0))
    sq = jnp.where(jnp.isfinite(diff), diff * diff, 0.0).astype(jnp.float32)
    return jnp.sum(sq, axis=-1, dtype=jnp.float32)


# ------------------------------------------------------------ host helpers

def build_cand_arrays(
    cands: List[Dict[int, np.ndarray]], qn: int, p: int, n_max: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Densify Algorithm 1's per-query candidate dicts.

    Returns ``cand_mask`` (Q, P, n_max) bool — filter ∧ residency ∧ visit —
    and ``n_cand`` (Q, P) int32 candidate counts.
    """
    cand_mask = np.zeros((qn, p, n_max), dtype=bool)
    n_cand = np.zeros((qn, p), dtype=np.int32)
    for qi in range(qn):
        for pid, rows in cands[qi].items():
            cand_mask[qi, pid, rows] = True
            n_cand[qi, pid] = rows.size
    return cand_mask, n_cand


def stage_counts(n_cand: np.ndarray, config, k: int, profile=None
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-(query, partition) Hamming-keep and refine-take counts.

    Elementwise twin of the NumPy reference's data-dependent formulas in
    ``SquashIndex._search_partition`` (zero where no candidates). With a
    :class:`~repro.core.autotune.CalibrationProfile` the keep fraction is
    per-partition (broadcast over the partition axis) and the floor is the
    profile's calibrated ``min_keep``; otherwise the static config knobs.
    """
    from repro.core import autotune

    frac = autotune.keep_fracs(config, profile, n_cand.shape[1])
    floor = autotune.keep_floor(config, profile)
    keep = autotune.keep_counts(n_cand, frac[None, :], floor)
    cap = int(np.ceil(config.refine_ratio * k)) if config.enable_refine else k
    take = np.minimum(cap, keep)
    return keep.astype(np.int32), take.astype(np.int32)


def static_counts(n_max: int, config, k: int, profile=None
                  ) -> Tuple[int, int]:
    """Static upper bounds for keep/take (the fixed ``top_k`` sizes).

    Both per-pair formulas are monotone in the candidate count, so their
    value at ``n_max`` — under the *largest* per-partition keep fraction —
    bounds every (query, partition) pair.
    """
    from repro.core import autotune

    n = max(int(n_max), 1)
    if profile is None:
        frac = float(config.hamming_perc)
        floor = int(config.min_hamming_keep)
    else:
        frac = float(np.max(profile.keep_frac))
        floor = int(profile.min_keep)
    keep_s = max(int(autotune.keep_count(n, frac, floor)), 1)
    cap = int(np.ceil(config.refine_ratio * k)) if config.enable_refine else k
    take_s = max(min(cap, keep_s), 1)
    return keep_s, take_s


# ------------------------------------------------------------- traced plane

def batched_stage345(
    queries: jnp.ndarray,
    stacked: StackedIndex,
    cand_mask: jnp.ndarray,
    keep: jnp.ndarray,
    take: jnp.ndarray,
    *,
    k: int,
    keep_s: int,
    take_s: int,
    refine: bool = True,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stages 3–5 for a query batch against a partition stack. Traceable.

    Args:
      queries: (Q, d) float.
      stacked: the resident partition stack (P partitions, n_max row budget).
      cand_mask: (Q, P, n_max) bool — filter ∧ residency ∧ Alg.-1 visit.
      keep: (Q, P) int32 — per-pair Hamming survivors (≤ ``keep_s``).
      take: (Q, P) int32 — per-pair refinement candidates (≤ ``take_s``).
      k / keep_s / take_s: static shape parameters (see
        :func:`static_counts`).
      refine: include Stage 5 full-precision re-ranking.
      use_pallas / interpret: kernel dispatch overrides (see kernels/ops.py).
    Returns:
      ids (Q, k) int32 (-1 padding), dists (Q, k) float (+inf padding) —
      merged across all P partitions in one pass.
    """
    qn = queries.shape[0]
    p, n_max = stacked.valid.shape

    # --- Stage 3: low-bit Hamming prune (raw centered space) -------------
    qc = queries[:, None, :] - stacked.part_mean[None]          # (Q, P, d)
    zq = (qc - stacked.low_mean[None]) / stacked.low_std[None]
    qbits = pack_query_bits(zq)                                 # (Q, P, G)
    ham = ops.hamming_stacked(qbits, stacked.low_packed,
                              use_pallas=use_pallas, interpret=interpret)
    alive0 = cand_mask & stacked.valid[None]
    ham = jnp.where(alive0, ham, _BIG_HAMMING)
    neg_h, sel = jax.lax.top_k(-ham, keep_s)                    # (Q, P, keep_s)
    slot = jnp.arange(keep_s, dtype=keep.dtype)
    alive1 = slot[None, None, :] < keep[:, :, None]

    # --- Stage 4: ADC lookup-table lower bounds on survivors -------------
    qt = jnp.einsum("qpd,pde->qpe", qc, stacked.klt)            # (Q, P, d)
    d = queries.shape[-1]
    m1 = stacked.boundaries.shape[1]
    p_idx = jnp.arange(p)[None, :, None]
    kept_codes = stacked.codes[p_idx, sel]                      # (Q,P,keep_s,d)
    if m1 <= ADC_TABLE_MAX_M1:
        # Dense per-pair tables → batched one-hot/MXU lookup kernel.
        tables = adc_table_batch(qt, stacked.boundaries[None],
                                 stacked.cells[None])
        lb = ops.adc_batch(
            tables.reshape(qn * p, m1, d).astype(jnp.float32),
            kept_codes.reshape(qn * p, keep_s, d),
            use_pallas=use_pallas, interpret=interpret,
        ).reshape(qn, p, keep_s)
    else:
        # Tall tables (hot 2^12-cell dims): direct boundary gathers.
        qcell = query_cells(qt, stacked.boundaries)
        lb = jnp.sqrt(adc_lb_direct(qt, qcell, stacked.boundaries,
                                    kept_codes))
    lb = jnp.where(alive1, lb, jnp.inf)
    neg_lb, sel2 = jax.lax.top_k(-lb, take_s)                   # (Q, P, take_s)
    slot2 = jnp.arange(take_s, dtype=take.dtype)
    alive2 = slot2[None, None, :] < take[:, :, None]
    rows = jnp.take_along_axis(sel, sel2, axis=-1)              # (Q, P, take_s)

    kk = min(k, take_s)
    if refine:
        # --- Stage 5: full-precision refinement ('EFS' rows) -------------
        full = stacked.vectors[p_idx, rows]                     # (Q,P,take_s,d)
        diff = full - queries[:, None, None, :]
        exact = jnp.sqrt(jnp.sum(diff * diff, axis=-1))
        exact = jnp.where(alive2, exact, jnp.inf)
        neg_e, sel3 = jax.lax.top_k(-exact, kk)
        part_d = -neg_e                                         # (Q, P, kk)
        final_rows = jnp.take_along_axis(rows, sel3, axis=-1)
    else:
        part_d = jnp.where(alive2, -neg_lb, jnp.inf)[..., :kk]
        final_rows = rows[..., :kk]
    part_ids = stacked.vector_ids[p_idx, final_rows]
    part_ids = jnp.where(jnp.isfinite(part_d), part_ids, -1)
    if kk < k:
        part_ids = jnp.pad(part_ids, ((0, 0), (0, 0), (0, k - kk)),
                           constant_values=-1)
        part_d = jnp.pad(part_d, ((0, 0), (0, 0), (0, k - kk)),
                         constant_values=jnp.inf)

    # --- single-pass MPI-style merge over partitions (§2.4.5) ------------
    flat_d = part_d.reshape(qn, p * k)
    flat_i = part_ids.reshape(qn, p * k)
    neg, msel = jax.lax.top_k(-flat_d, k)
    return jnp.take_along_axis(flat_i, msel, axis=1), -neg


def make_plane(
    *,
    k: int,
    keep_s: int,
    take_s: int,
    refine: bool = True,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
    trace_counter: Optional[list] = None,
):
    """Build the jitted batched search callable for one index/config shape.

    The returned function has signature ``(queries, stacked, cand_mask,
    keep, take) -> (ids, dists)`` and retraces only when array *shapes*
    change — i.e. once per (Q, k, index-shape). ``trace_counter`` (a
    one-element list) is incremented on each trace, which tests use to pin
    the one-trace guarantee.
    """

    @jax.jit
    def plane(queries, stacked, cand_mask, keep, take):
        # Python here runs only at trace time (shapes static), so both the
        # test counter and the obs compile metric count jit retraces, not
        # calls. Bucketing by pow2 query-batch size mirrors the trace-cache
        # key the padding scheme aims for.
        if trace_counter is not None:
            trace_counter[0] += 1
        q = int(queries.shape[0])
        bucket = 1 if q <= 1 else 1 << (q - 1).bit_length()
        _METRICS.counter(f"dataplane.jit_compiles.q{bucket}").inc()
        return batched_stage345(
            queries, stacked, cand_mask, keep, take,
            k=k, keep_s=keep_s, take_s=take_s, refine=refine,
            use_pallas=use_pallas, interpret=interpret,
        )

    return plane
