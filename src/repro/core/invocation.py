"""Tree-based FaaS invocation scheme (paper §3.3, Algorithm 2).

The Coordinator (id = −1) synchronously invokes F children; each internal
QueryAllocator invokes F more with geometrically shrinking ID jumps so that
the sub-tree rooted at a node with id x (next-sibling x + J_S) contains
exactly the ids y with x < y < x + J_S. That invariant lets every node know
which child ids will return results to it — bi-directional data flow over
request/response payloads with no storage rendezvous.

On TPU this *is* the hardware collective tree (DESIGN.md §2); we keep the
simulator for (a) correctness tests of the ID scheme and (b) the latency /
cost benchmarks of Figs. 8–10, where invocation fan-out time matters.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

__all__ = ["tree_size", "children_of", "build_tree", "tree_nodes",
           "NodeSpec", "InvocationSim"]


def tree_size(branching: int, max_level: int) -> int:
    """N_QA = F · (1 − F^l_max) / (1 − F)   (Alg. 2, line 1)."""
    f, l = branching, max_level
    if f == 1:
        return l
    return f * (1 - f**l) // (1 - f)


def children_of(
    node_id: int, level: int, branching: int, max_level: int
) -> List[int]:
    """Child ids a node invokes (Alg. 2). Coordinator is (id=−1, level=0).

    QA ids are 0-based. A node at level l with id x owns the id range
    (x, x + J_S(l)) where the jump sizes shrink geometrically by F per level.
    """
    f = branching
    n_qa = tree_size(f, max_level)
    if node_id == -1:
        js = math.ceil(n_qa / f)
        return [i * js for i in range(f) if i * js < n_qa]
    # Remaining depth below this node.
    depth_left = max_level - level
    if depth_left < 1:
        return []
    # Jump size at this node's level: the sub-tree below holds
    # tree_size(f, depth_left) ids; children split it in f.
    sub = tree_size(f, depth_left)
    js = math.ceil(sub / f)
    kids = []
    for i in range(f):
        cid = node_id + 1 + i * js
        if cid <= node_id + sub and cid < n_qa:
            kids.append(cid)
    return kids


def build_tree(branching: int, max_level: int) -> Dict[int, List[int]]:
    """Materialize the full invocation tree: parent id → child ids."""
    tree: Dict[int, List[int]] = {}
    frontier: List[Tuple[int, int]] = [(-1, 0)]
    while frontier:
        nid, lvl = frontier.pop()
        kids = children_of(nid, lvl, branching, max_level)
        tree[nid] = kids
        frontier.extend((k, lvl + 1) for k in kids)
    return tree


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One tree node with everything the runtime needs to route payloads.

    ``subtree`` counts the QA ids strictly below this node, so the id range
    a node is responsible for is ``[node_id, node_id + subtree]`` (inclusive;
    the coordinator covers ``[0, n_qa)``). That range is what makes
    bi-directional request/response routing storage-free: a parent knows
    exactly which ids — hence which query slices — return through each child.
    """

    node_id: int
    level: int
    children: Tuple[int, ...]
    subtree: int

    def id_range(self, n_qa: int) -> Tuple[int, int]:
        """[lo, hi) of QA ids this node's subtree covers (self included)."""
        if self.node_id == -1:
            return 0, n_qa
        return self.node_id, min(self.node_id + self.subtree + 1, n_qa)


def tree_nodes(branching: int, max_level: int) -> Dict[int, NodeSpec]:
    """Alg. 2 tree with levels + subtree spans (the runtime's routing table)."""
    nodes: Dict[int, NodeSpec] = {}
    frontier: List[Tuple[int, int]] = [(-1, 0)]
    while frontier:
        nid, lvl = frontier.pop()
        kids = children_of(nid, lvl, branching, max_level)
        sub = (tree_size(branching, max_level) if nid == -1
               else tree_size(branching, max_level - lvl))
        nodes[nid] = NodeSpec(node_id=nid, level=lvl,
                              children=tuple(kids), subtree=sub)
        frontier.extend((k, lvl + 1) for k in kids)
    return nodes


@dataclasses.dataclass
class InvocationSim:
    """Latency simulator for the invocation tree.

    Models per-invocation overhead (cold vs warm) and per-node compute, and
    returns the critical-path makespan — sequential CO fan-out vs the tree.
    """

    branching: int
    max_level: int
    invoke_latency_warm: float = 0.015   # s — warm synchronous Lambda invoke
    invoke_latency_cold: float = 0.400   # s — cold start
    warm_fraction: float = 1.0
    node_compute: float = 0.050          # s — QA-side work per node

    def _invoke_cost(self, child_index: int) -> float:
        # Children are launched on threads; model thread spawn serialization
        # as a small per-child stagger before overlap.
        stagger = 0.002 * child_index
        cold = self.invoke_latency_cold if self.warm_fraction < 1.0 else 0.0
        lat = (
            self.warm_fraction * self.invoke_latency_warm
            + (1.0 - self.warm_fraction) * self.invoke_latency_cold
        )
        del cold
        return stagger + lat

    def makespan(self) -> float:
        """Critical path of the tree launch + response gathering."""
        tree = build_tree(self.branching, self.max_level)

        def finish(nid: int) -> float:
            kids = tree.get(nid, [])
            t_children = 0.0
            for i, kid in enumerate(kids):
                t_children = max(
                    t_children, self._invoke_cost(i) + finish(kid)
                )
            return self.node_compute + t_children

        return finish(-1)

    def sequential_makespan(self) -> float:
        """Naïve CO-invokes-everything baseline (paper's strawman)."""
        n = tree_size(self.branching, self.max_level)
        launch = sum(self._invoke_cost(i) for i in range(n))
        return launch + self.node_compute * 2  # CO work + slowest QA overlap
