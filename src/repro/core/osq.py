"""Optimized Scalar Quantization (OSQ) — paper §2.2.

Non-uniform per-dimension bit allocation (variance-greedy, VA+-file lineage),
per-dimension Lloyd-Max scalar quantizers, and encode/decode between float
vectors and per-dimension cell codes.

Build-time code is NumPy (offline indexing); the query-time hot path lives in
``adc.py`` / ``lowbit.py`` / ``segments.py`` and is JAX-jittable.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "allocate_bits",
    "lloyd_max_1d",
    "design_quantizers",
    "encode",
    "decode_cell_centers",
    "OSQQuantizer",
]


def allocate_bits(variances: np.ndarray, budget: int, max_bits: int = 12) -> np.ndarray:
    """Greedy non-uniform bit allocation (paper §2.2.1).

    Bits are iteratively assigned to the dimension with the highest remaining
    variance; each assignment divides that dimension's variance by 4 (one bit
    halves quantization step ⇒ quarters the expected squared error) [22].

    Args:
      variances: (d,) per-dimension variances (post-transform).
      budget: total bit budget ``b`` (paper uses b = 4·d).
      max_bits: cap per dimension. The paper allows >S bits for a single hot
        dimension (e.g. 9 with S=8); segments make that free.

    Returns:
      (d,) int array of per-dimension bit counts, summing to ``budget``.
    """
    var = np.asarray(variances, dtype=np.float64).copy()
    if np.any(var < 0):
        raise ValueError("variances must be non-negative")
    d = var.shape[0]
    if budget > d * max_bits:
        raise ValueError(f"budget {budget} exceeds d*max_bits {d * max_bits}")
    bits = np.zeros(d, dtype=np.int32)
    # Tiny epsilon so zero-variance dims still get bits if budget is huge.
    var = var + 1e-30
    for _ in range(budget):
        j = int(np.argmax(var))
        bits[j] += 1
        var[j] /= 4.0
        if bits[j] >= max_bits:
            var[j] = -np.inf
    return bits


def lloyd_max_1d(
    x: np.ndarray, k: int, iters: int = 25, rng: Optional[np.random.Generator] = None
) -> np.ndarray:
    """Vectorized 1-D Lloyd-Max quantizer design over a batch of dimensions.

    Paper §2.4.1: "efficient one-dimensional K-means clustering to design
    optimal scalar quantizers based on the data distribution" [33].

    Args:
      x: (N, D) samples for D dimensions that all want ``k`` cells.
      k: number of quantization cells.
      iters: Lloyd iterations.

    Returns:
      (k+1, D) cell *boundaries* per dimension: b[0] = -inf, b[k] = +inf,
      interior boundaries are midpoints between sorted centroids.
    """
    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 1:
        x = x[:, None]
    n, dd = x.shape
    # Initialize centroids at quantiles — near-optimal for 1-D, deterministic.
    qs = (np.arange(k, dtype=np.float64) + 0.5) / k
    cent = np.quantile(x, qs, axis=0)  # (k, D)
    for _ in range(iters):
        bounds = (cent[:-1] + cent[1:]) / 2.0  # (k-1, D)
        # Assign: searchsorted per column.
        codes = np.empty((n, dd), dtype=np.int64)
        for j in range(dd):
            codes[:, j] = np.searchsorted(bounds[:, j], x[:, j], side="right")
        # Update: mean of members (keep old centroid when a cell is empty).
        new_cent = cent.copy()
        for c in range(k):
            mask = codes == c
            cnt = mask.sum(axis=0)
            sums = np.where(mask, x, 0.0).sum(axis=0)
            nz = cnt > 0
            new_cent[c, nz] = sums[nz] / cnt[nz]
        new_cent = np.sort(new_cent, axis=0)
        if np.allclose(new_cent, cent, rtol=0, atol=1e-12):
            cent = new_cent
            break
        cent = new_cent
    inner = (cent[:-1] + cent[1:]) / 2.0
    out = np.empty((k + 1, dd), dtype=np.float64)
    out[0] = -np.inf
    out[-1] = np.inf
    out[1:-1] = inner
    return out


@dataclasses.dataclass
class OSQQuantizer:
    """Per-dimension scalar quantizer bundle.

    Attributes:
      bits: (d,) per-dimension bit allocation B.
      boundaries: (M+1, d) padded boundary matrix V. M = max cells. For a
        dimension with C[j] cells only rows 0..C[j] are meaningful; the rest
        are +inf padding (searchsorted then never selects them). Row 0 is the
        *finite* data minimum proxy (used for ADC edge distances); we store
        finite sentinels for ADC and treat the outermost cells as unbounded
        during encode.
      centers: (M, d) cell centroids (padding = +inf).
    """

    bits: np.ndarray
    boundaries: np.ndarray
    centers: np.ndarray

    @property
    def d(self) -> int:
        return int(self.bits.shape[0])

    @property
    def cells(self) -> np.ndarray:
        return (1 << self.bits.astype(np.int64)).astype(np.int64)

    @property
    def max_cells(self) -> int:
        return int(self.cells.max())

    @property
    def total_bits(self) -> int:
        return int(self.bits.sum())


def design_quantizers(
    x: np.ndarray, bits: np.ndarray, iters: int = 25
) -> OSQQuantizer:
    """Design per-dimension Lloyd-Max quantizers under allocation ``bits``."""
    x = np.asarray(x, dtype=np.float64)
    n, d = x.shape
    bits = np.asarray(bits, dtype=np.int32)
    cells = (1 << bits.astype(np.int64)).astype(np.int64)
    m = int(cells.max())
    boundaries = np.full((m + 1, d), np.inf, dtype=np.float64)
    centers = np.full((m, d), np.inf, dtype=np.float64)
    for k in np.unique(cells):
        cols = np.where(cells == k)[0]
        if k == 1:
            # 0 bits: single cell covering everything; center = mean.
            boundaries[0, cols] = -np.inf
            boundaries[1, cols] = np.inf
            centers[0, cols] = x[:, cols].mean(axis=0)
            continue
        b = lloyd_max_1d(x[:, cols], int(k), iters=iters)
        boundaries[: k + 1, cols] = b
        # Centers = member means approximated by midpoint of boundaries,
        # with data min/max standing in for the infinite edges.
        lo = np.minimum(x[:, cols].min(axis=0), b[1])
        hi = np.maximum(x[:, cols].max(axis=0), b[-2])
        bb = b.copy()
        bb[0] = lo
        bb[-1] = hi
        centers[:k, cols] = (bb[:-1] + bb[1:]) / 2.0
    return OSQQuantizer(bits=bits, boundaries=boundaries, centers=centers)


def encode(q: OSQQuantizer, x: np.ndarray) -> np.ndarray:
    """Quantize vectors to per-dimension cell codes. Returns (N, d) int32."""
    x = np.asarray(x, dtype=np.float64)
    n, d = x.shape
    if d != q.d:
        raise ValueError(f"dim mismatch {d} != {q.d}")
    codes = np.empty((n, d), dtype=np.int32)
    cells = q.cells
    for j in range(d):
        k = int(cells[j])
        if k == 1:
            codes[:, j] = 0
        else:
            inner = q.boundaries[1:k, j]
            codes[:, j] = np.searchsorted(inner, x[:, j], side="right")
    return codes


def decode_cell_centers(q: OSQQuantizer, codes: np.ndarray) -> np.ndarray:
    """Reconstruct vectors as their cell centers (for error measurement)."""
    codes = np.asarray(codes)
    n, d = codes.shape
    out = np.empty((n, d), dtype=np.float64)
    for j in range(d):
        out[:, j] = q.centers[codes[:, j], j]
    return out
