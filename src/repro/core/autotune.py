"""Recall-targeted Hamming threshold autotune (paper §2.4 / Fig. 5, Eq. 1).

SQUASH prunes most vectors at the low-bit Hamming lower-bound stage; the
static ``SquashConfig.hamming_perc`` applies one keep fraction to every
partition, but how well Hamming LB ranks predict exact ranks varies per
partition (KLT quality, local intrinsic dimensionality, cluster shape).
This module derives **per-partition** keep fractions from a seeded
calibration pass so search hits a recall target with strictly fewer ADC
evaluations than the one-knob configuration:

1. Sample calibration queries (held-out draws from the indexed vectors by
   default, or a caller-provided query set).
2. Replay Algorithm 1 unfiltered, so each sampled query visits the same
   partitions production queries would.
3. Per visited (query, partition): rank all resident rows by Hamming LB and
   by exact distance; record (a) the Spearman rank correlation between the
   two orders and (b) the minimal keep count such that the partition's exact
   top-k rows all survive the Hamming cut.
4. Per partition: the keep fraction is a high quantile (the recall target)
   of the sampled required fractions, inflated by a safety margin that grows
   as the LB/exact rank correlation degrades, and floored globally.

The result is a :class:`CalibrationProfile` — a serializable dict-of-arrays
artifact, deterministic given (index, sample, seed) — consumed by every
data plane through :func:`keep_fracs` / :func:`keep_floor`:
``core.pipeline`` (NumPy reference), ``core.dataplane`` (batched jax plane,
via ``stage_counts``/``static_counts``), ``core.distributed`` (mesh plane)
and the serverless runtime (QAs compute per-partition budgets from the
profile and ship them to QPs inside the Alg. 2 request payloads). All
backends must return bitwise-identical ids under the same profile.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

__all__ = [
    "CalibrationProfile", "calibrate", "keep_count", "keep_counts",
    "keep_fracs", "keep_floor", "spearman",
]


# ------------------------------------------------------------ keep-count math

def keep_count(n: int, frac: float, floor: int) -> int:
    """Hamming survivors for ``n`` candidates at keep fraction ``frac`` (%).

    The single reference formula every data plane derives from:
    ``max(min(floor, n), ceil(n · frac / 100))`` clamped to ``n``. The floor
    keeps tiny candidate sets alive (paper default 64); zero candidates keep
    zero rows.
    """
    n = int(n)
    if n <= 0:
        return 0
    keep = max(min(int(floor), n), int(np.ceil(n * float(frac) / 100.0)))
    return min(keep, n)


def keep_counts(n: np.ndarray, frac, floor: int) -> np.ndarray:
    """Vectorized :func:`keep_count` — ``frac`` scalar or broadcastable."""
    n = np.asarray(n, dtype=np.int64)
    keep = np.maximum(
        np.minimum(int(floor), n),
        np.ceil(n * np.asarray(frac, dtype=np.float64) / 100.0).astype(
            np.int64),
    )
    return np.minimum(keep, n)


def keep_fracs(config, profile: Optional["CalibrationProfile"],
               p: int) -> np.ndarray:
    """(p,) per-partition keep percentages for one index.

    ``profile=None`` broadcasts the static ``config.hamming_perc``; a profile
    supplies its calibrated vector, edge-padded when the consumer stacked
    extra (empty) partition slots (``stack_index(pad_to_multiple=...)``).
    """
    if profile is None:
        return np.full(p, float(config.hamming_perc))
    frac = np.asarray(profile.keep_frac, dtype=np.float64)
    if frac.shape[0] < p:
        frac = np.pad(frac, (0, p - frac.shape[0]), mode="edge")
    return frac[:p]


def keep_floor(config, profile: Optional["CalibrationProfile"]) -> int:
    """The global keep floor: profile's calibrated floor, else the config's."""
    return int(config.min_hamming_keep if profile is None
               else profile.min_keep)


# ------------------------------------------------------------------ profile

@dataclasses.dataclass
class CalibrationProfile:
    """Serializable per-partition keep-budget artifact.

    ``keep_frac[p]`` is the percentage of partition ``p``'s post-filter
    candidates kept past the Hamming stage; ``min_keep`` is the global floor
    replacing ``SquashConfig.min_hamming_keep``. ``rank_corr``/``required``
    are calibration diagnostics (mean Spearman LB/exact correlation and the
    raw per-partition quantile before the safety margin).
    """

    keep_frac: np.ndarray          # (P,) float64 percent, in (0, 100]
    min_keep: int                  # global floor on kept rows
    recall_target: float
    seed: int
    sample_queries: int
    rank_corr: np.ndarray          # (P,) mean Spearman corr (diagnostic)
    required: np.ndarray           # (P,) pre-margin quantile (diagnostic)

    def __post_init__(self):
        self.keep_frac = np.asarray(self.keep_frac, dtype=np.float64)
        self.rank_corr = np.asarray(self.rank_corr, dtype=np.float64)
        self.required = np.asarray(self.required, dtype=np.float64)
        if self.keep_frac.ndim != 1 or self.keep_frac.shape[0] == 0:
            raise ValueError("keep_frac must be a non-empty 1-D vector")
        if not ((self.keep_frac > 0) & (self.keep_frac <= 100.0)).all():
            raise ValueError("keep_frac entries must be in (0, 100]")
        if self.min_keep < 1:
            raise ValueError("min_keep must be >= 1")

    @property
    def num_partitions(self) -> int:
        return int(self.keep_frac.shape[0])

    def to_dict(self) -> Dict:
        """Plain-types artifact (JSON-safe); :meth:`from_dict` round-trips."""
        return {
            "keep_frac": [float(x) for x in self.keep_frac],
            "min_keep": int(self.min_keep),
            "recall_target": float(self.recall_target),
            "seed": int(self.seed),
            "sample_queries": int(self.sample_queries),
            "rank_corr": [float(x) for x in self.rank_corr],
            "required": [float(x) for x in self.required],
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "CalibrationProfile":
        return cls(
            keep_frac=np.asarray(d["keep_frac"], dtype=np.float64),
            min_keep=int(d["min_keep"]),
            recall_target=float(d["recall_target"]),
            seed=int(d["seed"]),
            sample_queries=int(d["sample_queries"]),
            rank_corr=np.asarray(d["rank_corr"], dtype=np.float64),
            required=np.asarray(d["required"], dtype=np.float64),
        )


# -------------------------------------------------------------- measurement

def spearman(a: np.ndarray, b: np.ndarray) -> float:
    """Spearman rank correlation with average-rank tie handling."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    if a.size < 2:
        return 1.0
    ra = _avg_ranks(a)
    rb = _avg_ranks(b)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra * ra).sum() * (rb * rb).sum())
    if denom == 0.0:
        return 1.0
    return float((ra * rb).sum() / denom)


def _avg_ranks(x: np.ndarray) -> np.ndarray:
    """Average ranks (ties share their mean rank), 0-based."""
    order = np.argsort(x, kind="stable")
    ranks = np.empty(x.size, dtype=np.float64)
    ranks[order] = np.arange(x.size, dtype=np.float64)
    # Average tied groups: sort values, find runs, assign mean rank.
    sx = x[order]
    i = 0
    while i < sx.size:
        j = i
        while j + 1 < sx.size and sx[j + 1] == sx[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = 0.5 * (i + j)
        i = j + 1
    return ranks


def _partition_hamming(part, query: np.ndarray) -> np.ndarray:
    """Low-bit Hamming LB of ``query`` against every row of one partition."""
    from repro.core.pipeline import _popcount_u32

    qbits = part.low.encode_queries((query - part.mean)[None, :])[0]
    x = np.bitwise_xor(part.low.packed, qbits[None, :])
    return _popcount_u32(x).sum(axis=1)


# -------------------------------------------------------------- calibration

def calibrate(
    index,
    queries: Optional[np.ndarray] = None,
    *,
    recall_target: float = 0.95,
    k: int = 10,
    sample: int = 64,
    seed: int = 0,
    min_keep: Optional[int] = None,
    margin: float = 0.5,
    quantile: Optional[float] = None,
) -> CalibrationProfile:
    """Measure LB/exact rank agreement and derive per-partition keep budgets.

    Args:
      index: a built ``SquashIndex``.
      queries: optional (S, d) calibration query set. Default: ``sample``
        seeded draws from the indexed vectors themselves, jittered by a small
        fraction of the dataset scale so calibration queries are near — not
        exactly on — database points (the paper's query distribution).
      recall_target: target recall@k the profile is tuned for; also the
        quantile of the per-partition required-keep distribution (unless
        ``quantile`` overrides it).
      k: the top-k the target refers to (also Stage 5's refinement k).
      sample: number of auto-drawn calibration queries when ``queries=None``.
      seed: RNG seed — calibration is fully deterministic given it.
      min_keep: global floor; default ``2 · ceil(refine_ratio · k)`` so the
        Stage 4 → Stage 5 take (R·k) never consumes the whole Hamming set.
      margin: safety inflation per unit of *missing* rank correlation:
        ``frac *= 1 + margin · (1 − corr_p)``.
      quantile: override for the required-keep quantile.
    Returns:
      a :class:`CalibrationProfile` (see module docstring).
    """
    from repro.core import partitions as part_mod

    cfg = index.config
    p = len(index.parts)
    rng = np.random.default_rng(seed)
    if queries is None:
        # Sample (partition, row) pairs through the per-partition sizes —
        # no transient copy of the whole dataset on the serving path.
        sizes = np.array([pt.size for pt in index.parts], dtype=np.int64)
        offsets = np.concatenate([[0], np.cumsum(sizes)])
        pick = np.sort(rng.choice(int(offsets[-1]),
                                  size=min(sample, int(offsets[-1])),
                                  replace=False))
        pids = np.searchsorted(offsets, pick, side="right") - 1
        queries = np.stack([
            index.parts[pid].vectors[g - offsets[pid]]
            for pid, g in zip(pids, pick)
        ]).astype(np.float64)
        jitter = 0.01 * float(np.std(queries))
        queries = queries + rng.normal(0.0, jitter, size=queries.shape)
    queries = np.atleast_2d(np.asarray(queries, dtype=np.float64))
    s = queries.shape[0]

    # Replay Algorithm 1 unfiltered: calibration sees the partitions (and
    # candidate populations) production queries see before predicates thin
    # them — keep *fractions* transfer across selectivities.
    n_total = sum(pt.size for pt in index.parts)
    f = np.ones((s, n_total), dtype=bool)
    _, cands = part_mod.select_partitions(
        queries, index.partitioning.centroids, f,
        index.partitioning.assign, index.partitioning.threshold, k)

    req_fracs = [[] for _ in range(p)]       # required keep fraction samples
    corrs = [[] for _ in range(p)]           # Spearman samples
    for qi in range(s):
        per_part = {}
        pool = []                            # (exact, pid, local_row) stream
        for pid in sorted(cands[qi]):
            part = index.parts[pid]
            n = part.size
            if n < 2:
                continue
            ham = _partition_hamming(part, queries[qi])
            exact = np.sqrt(
                ((part.vectors - queries[qi][None, :]) ** 2).sum(axis=1))
            corrs[pid].append(spearman(ham, exact))
            # Hamming rank of every row under the plane's (ham, row) total
            # order — the order Stage 3's cut walks.
            comp = ham.astype(np.int64) * n + np.arange(n)
            ham_rank = np.empty(n, dtype=np.int64)
            ham_rank[np.argsort(comp, kind="stable")] = np.arange(n)
            per_part[pid] = (ham_rank, n)
            kk = min(k, n)
            local_top = np.argsort(exact, kind="stable")[:kk]
            pool.extend(
                (float(exact[r]), pid, int(r)) for r in local_top)
        if not pool:
            continue
        # Recall@k is a *global* property: only the rows in the query's
        # global top-k must survive their home partition's Hamming cut, so
        # the required keep count is the worst Hamming rank among a
        # partition's global-top-k residents — zero for partitions that
        # contribute nothing (they only ever need the floor).
        pool.sort()
        winners: Dict[int, list] = {}
        for exact_d, pid, row in pool[:k]:
            winners.setdefault(pid, []).append(row)
        for pid, (ham_rank, n) in per_part.items():
            rows = winners.get(pid)
            need = int(ham_rank[rows].max()) + 1 if rows else 0
            req_fracs[pid].append(need / n)

    if min_keep is None:
        take_cap = int(np.ceil(cfg.refine_ratio * k)) if cfg.enable_refine \
            else k
        min_keep = max(2 * take_cap, 16)
    q = recall_target if quantile is None else quantile
    keep_frac = np.empty(p, dtype=np.float64)
    rank_corr = np.empty(p, dtype=np.float64)
    required = np.empty(p, dtype=np.float64)
    fallback = float(cfg.hamming_perc)
    for pid in range(p):
        if not req_fracs[pid]:
            # Partition never visited by the sample: keep the static knob.
            required[pid] = fallback / 100.0
            rank_corr[pid] = 0.0
            keep_frac[pid] = fallback
            continue
        base = float(np.quantile(np.asarray(req_fracs[pid]), q))
        corr = float(np.mean(corrs[pid]))
        rank_corr[pid] = corr
        required[pid] = base
        inflated = base * (1.0 + margin * max(0.0, 1.0 - corr))
        keep_frac[pid] = float(np.clip(inflated * 100.0, 1e-3, 100.0))
    return CalibrationProfile(
        keep_frac=keep_frac,
        min_keep=int(min_keep),
        recall_target=float(recall_target),
        seed=int(seed),
        sample_queries=int(s),
        rank_corr=rank_corr,
        required=required,
    )
