"""Live (mutable) index layer: streaming inserts/deletes over SQUASH segments.

The paper's index is built once and frozen; a serving system needs a mutable
corpus. This module wraps a built :class:`~repro.core.pipeline.SquashIndex`
with the three mutation primitives the segment-based storage (§2.2) makes
cheap:

* **insert** — new vectors append to their nearest partition as a *tail
  segment*: codes are quantized under the partition's frozen transform /
  quantizers and packed incrementally with ``segments.pack_codes``, so an
  insert never rewrites existing rows. Global ids grow monotonically, which
  keeps every partition's local order ascending-by-global-id — the invariant
  ``partitions.select_partitions`` derives local row positions from.
* **delete** — tombstones. A global liveness bitmap (``base.live_mask``) is
  flipped off; dead rows fail Stage 1 filtering on every backend and are
  defensively masked again in Stage 3 (numpy ``_search_partition``, the jax
  plane's ``StackedIndex.valid``, serverless QP bundles), so a tombstoned id
  can never be returned — even by a hand-built QP request naming it.
* **compact** — physically drops a dirty partition's dead rows and (by
  default) re-runs OSQ on the survivors (fresh KLT / bit allocation /
  Lloyd-Max quantizers / low-bit stats), collapsing the tail-segment ledger
  to a single block under a **new generation**. Compacted-away rows keep
  their global id forever but their partition assignment becomes the
  out-of-range sentinel ``P``, so id space stays append-only.

Every mutation bumps the touched partitions' **generation** and appends an
event to a log the serverless runtime drains lazily (pull model — the index
has no reference to any runtime): generations feed the DRE fetch/derived
singleton keys so warm containers cannot serve stale partition bytes, and
events drive segment-granular ``ResultCache`` invalidation instead of
whole-index drops.

Parity contract (pinned by ``tests/test_live.py`` and the ``--smoke``
mutation gate): a search during the tombstone phase and the same search
after ``compact`` return bitwise-identical ids *and* ``SearchStats`` —
candidate sets, visit sets and all stage counters depend only on live rows,
and compaction preserves relative local order, so every backend's
deterministic (score, partition, row) tie-breaking is unaffected.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import lowbit, osq, segments
from repro.core.pipeline import PartitionIndex, SquashIndex

__all__ = ["LiveIndex", "SegmentBlock", "MutationEvent"]


@dataclasses.dataclass(frozen=True)
class SegmentBlock:
    """One contiguous block of a partition's local rows: ``[lo, hi)``.

    ``generation`` is the partition generation the block was published
    under; a compaction collapses all blocks into one with a fresh
    generation.
    """

    lo: int
    hi: int
    generation: int


@dataclasses.dataclass(frozen=True)
class MutationEvent:
    """One entry of the mutation log the runtime drains via `events_since`."""

    seq: int
    kind: str                      # "insert" | "delete" | "compact"
    pids: Tuple[int, ...]          # partitions whose bytes changed
    ids: Tuple[int, ...] = ()      # delete: tombstoned global ids
    vectors: Optional[np.ndarray] = None   # insert: the new rows (m, d)
    requantize: bool = False       # compact: whether OSQ was re-run


class LiveIndex:
    """Streaming mutation wrapper around a built :class:`SquashIndex`.

    The wrapped index stays the single source of truth for search — all
    backends keep reading ``base.parts`` / ``base.partitioning`` /
    ``base.attr_index`` / ``base.live_mask`` directly, so a ``LiveIndex``
    never forks query behavior; it only mutates those structures under the
    invariants documented in the module docstring.
    """

    def __init__(self, base: SquashIndex):
        if getattr(base, "live_owner", None) is not None:
            raise ValueError("index already wrapped by a LiveIndex")
        self.base = base
        n = base.partitioning.assign.shape[0]
        p = len(base.parts)
        base.live_mask = np.ones(n, dtype=bool)
        base.live_owner = self
        self.generations: List[int] = [0] * p
        self._segments: Dict[int, List[SegmentBlock]] = {
            pid: [SegmentBlock(0, base.parts[pid].size, 0)] for pid in range(p)
        }
        self._dirty: set = set()
        self._events: List[MutationEvent] = []
        self._seq = 0

    # ------------------------------------------------------------- inspection

    @property
    def version(self) -> int:
        """Monotone mutation counter (0 for a freshly wrapped index)."""
        return self._seq

    @property
    def num_partitions(self) -> int:
        return len(self.base.parts)

    @property
    def sentinel(self) -> int:
        """Out-of-range assignment marking compacted-away rows."""
        return len(self.base.parts)

    def segments_of(self, pid: int) -> Tuple[SegmentBlock, ...]:
        """The partition's current tail-segment ledger."""
        return tuple(self._segments[pid])

    def dirty_partitions(self) -> Tuple[int, ...]:
        """Partitions holding tombstones or un-requantized tail rows."""
        return tuple(sorted(self._dirty))

    def live_count(self) -> int:
        return int(self.base.live_mask.sum())

    def events_since(self, cursor: int) -> Tuple[int, List[MutationEvent]]:
        """Events with ``seq > cursor`` plus the new cursor (pull model)."""
        return self._seq, [e for e in self._events if e.seq > cursor]

    # -------------------------------------------------------------- mutations

    def insert(self, vectors: np.ndarray, attrs: np.ndarray) -> np.ndarray:
        """Append new (vector, attribute) rows; returns their global ids.

        Rows join the partition of their nearest centroid as a tail segment
        encoded under that partition's *frozen* transform and quantizers
        (requantization is compaction's job). Attribute values quantize
        against the existing cell boundaries — exact for values seen at
        build time, nearest-cell for novel ones.
        """
        base = self.base
        vectors = np.atleast_2d(np.asarray(vectors, dtype=np.float64))
        attrs = np.atleast_2d(np.asarray(attrs, dtype=np.float64))
        m, d = vectors.shape
        if d != base.dim:
            raise ValueError(f"dim mismatch {d} != {base.dim}")
        if attrs.shape != (m, base.attr_index.num_attributes):
            raise ValueError(
                f"attrs shape {attrs.shape} != "
                f"({m}, {base.attr_index.num_attributes})")
        part_obj = base.partitioning
        n0 = part_obj.assign.shape[0]
        new_ids = np.arange(n0, n0 + m, dtype=np.int64)

        d2 = ((vectors[:, None, :] - part_obj.centroids[None, :, :]) ** 2
              ).sum(axis=-1)
        assign_new = np.argmin(d2, axis=1).astype(part_obj.assign.dtype)

        touched = sorted(int(pid) for pid in np.unique(assign_new))
        for pid in touched:
            rows = np.where(assign_new == pid)[0]
            self._append_tail(pid, new_ids[rows], vectors[rows])

        part_obj.assign = np.concatenate([part_obj.assign, assign_new])
        ai = base.attr_index
        ai.codes = np.concatenate(
            [ai.codes, _encode_attrs(ai, attrs)], axis=0)
        base.live_mask = np.concatenate(
            [base.live_mask, np.ones(m, dtype=bool)])
        self._dirty.update(touched)
        self._record("insert", touched, vectors=vectors.copy())
        return new_ids

    def delete(self, ids: Sequence[int]) -> int:
        """Tombstone global ids; returns how many were newly deleted.

        Unknown or already-dead ids are ignored. Rows stay physically
        resident (and keep their local positions — the parity invariant)
        until ``compact`` runs on their partition.
        """
        base = self.base
        ids = np.unique(np.asarray(ids, dtype=np.int64))
        n = base.live_mask.shape[0]
        ids = ids[(ids >= 0) & (ids < n)]
        ids = ids[base.live_mask[ids]]
        if ids.size == 0:
            return 0
        base.live_mask[ids] = False
        pids = sorted(int(p) for p in np.unique(base.partitioning.assign[ids])
                      if p < self.sentinel)
        self._dirty.update(pids)
        self._record("delete", pids, ids=tuple(int(i) for i in ids))
        return int(ids.size)

    def compact(self, pid: int, requantize: bool = True) -> bool:
        """Drop partition ``pid``'s dead rows; optionally re-run OSQ.

        Returns False (no-op, no generation bump) when the partition is
        clean. With ``requantize`` the surviving rows get a fresh KLT, bit
        allocation, Lloyd-Max quantizers and low-bit stats — the "background
        requantize" path; without it the frozen codes are merely sliced
        (bitwise-invisible to search). Either way the tail-segment ledger
        collapses to one block under a new generation and the compacted-away
        rows' assignment becomes the ``P`` sentinel.
        """
        base = self.base
        if pid not in self._dirty and len(self._segments[pid]) <= 1:
            return False
        part = base.parts[pid]
        live_rows = base.live_mask[part.vector_ids]
        alive_ids = part.vector_ids[live_rows]
        dead_ids = part.vector_ids[~live_rows]
        x = part.vectors[live_rows]

        if dead_ids.size:
            base.partitioning.assign[dead_ids] = self.sentinel
        if requantize and alive_ids.size:
            base.parts[pid] = _requantize_partition(
                base.config, alive_ids, x, base.dim)
        else:
            base.parts[pid] = PartitionIndex(
                vector_ids=alive_ids,
                klt=part.klt,
                mean=part.mean,
                quant=part.quant,
                layout=part.layout,
                packed=part.packed[live_rows],
                codes=part.codes[live_rows],
                low=lowbit.LowBitIndex(
                    packed=part.low.packed[live_rows],
                    mean=part.low.mean, std=part.low.std, d=part.low.d),
                vectors=x,
            )
        self._dirty.discard(pid)
        self._record("compact", [pid], requantize=bool(requantize))
        self._segments[pid] = [SegmentBlock(
            0, int(alive_ids.size), self.generations[pid])]
        return True

    # --------------------------------------------------------------- internal

    def _append_tail(self, pid: int, ids: np.ndarray,
                     x: np.ndarray) -> None:
        """Encode + append rows under the partition's frozen quantizers."""
        base = self.base
        part = base.parts[pid]
        xc = x - part.mean
        xt = xc @ part.klt if part.klt is not None else xc
        codes = osq.encode(part.quant, xt)
        packed = segments.pack_codes(part.layout, codes)
        low_packed = lowbit.pack_bits_u32(
            lowbit.binarize(xc, part.low.mean, part.low.std))
        lo = part.size
        base.parts[pid] = PartitionIndex(
            vector_ids=np.concatenate([part.vector_ids, ids]),
            klt=part.klt,
            mean=part.mean,
            quant=part.quant,
            layout=part.layout,
            packed=np.concatenate([part.packed, packed], axis=0),
            codes=np.concatenate(
                [part.codes, codes.astype(np.int32)], axis=0),
            low=lowbit.LowBitIndex(
                packed=np.concatenate([part.low.packed, low_packed], axis=0),
                mean=part.low.mean, std=part.low.std, d=part.low.d),
            vectors=np.concatenate([part.vectors, x], axis=0),
        )
        self._segments[pid].append(SegmentBlock(
            lo, lo + int(ids.size), self.generations[pid] + 1))

    def _record(self, kind: str, pids, *, ids: Tuple[int, ...] = (),
                vectors: Optional[np.ndarray] = None,
                requantize: bool = False) -> None:
        for pid in pids:
            self.generations[pid] += 1
        self._seq += 1
        self._events.append(MutationEvent(
            seq=self._seq, kind=kind, pids=tuple(int(p) for p in pids),
            ids=ids, vectors=vectors, requantize=requantize))
        # Mutation invalidates the stacked device payload (shapes / valid
        # bits changed); the jitted-plane cache stays — its keys embed the
        # static keep/take counts, so stale shapes simply miss.
        self.base._stacked_cache.clear()

    # ------------------------------------------------------------ convenience

    def search(self, *args, **kw):
        return self.base.search(*args, **kw)

    def autotune(self, *args, **kw):
        return self.base.autotune(*args, **kw)


def _encode_attrs(ai, attrs: np.ndarray) -> np.ndarray:
    """Quantize new attribute rows against the frozen cell boundaries.

    Mirrors ``build_attribute_index``'s encode: interior boundaries +
    ``side="right"`` searchsorted reproduce the build-time codes exactly for
    any value already in an attribute's domain.
    """
    m, a = attrs.shape
    codes = np.empty((m, a), dtype=np.int32)
    for i in range(a):
        k = int(ai.cells[i])
        if k <= 1:
            codes[:, i] = 0
        else:
            inner = ai.boundaries[1:k, i]
            codes[:, i] = np.searchsorted(inner, attrs[:, i], side="right")
    return codes


def _requantize_partition(config, ids: np.ndarray, x: np.ndarray,
                          d: int) -> PartitionIndex:
    """Re-run the per-partition build (KLT → bits → Lloyd-Max → pack) on the
    surviving rows — the same procedure ``SquashIndex.build`` applies."""
    mean = x.mean(axis=0)
    xc = x - mean
    if config.use_klt and x.shape[0] > d:
        cov = (xc.T @ xc) / max(x.shape[0] - 1, 1)
        _, eigvec = np.linalg.eigh(cov)
        klt = eigvec[:, ::-1]
        xt = xc @ klt
    else:
        klt = None
        xt = xc
    budget = int(round(config.bits_per_dim * d))
    var = xt.var(axis=0)
    bits = osq.allocate_bits(var, budget, max_bits=config.max_bits_per_dim)
    quant = osq.design_quantizers(xt, bits, iters=config.lloyd_iters)
    codes = osq.encode(quant, xt)
    layout = segments.build_layout(bits, seg_bits=config.segment_bits)
    packed = segments.pack_codes(layout, codes)
    low = lowbit.build_lowbit_index(xc)
    return PartitionIndex(
        vector_ids=ids, klt=klt, mean=mean, quant=quant, layout=layout,
        packed=packed, codes=codes.astype(np.int32), low=low, vectors=x)
