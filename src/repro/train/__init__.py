"""Training loop substrate: jitted train step, remat, grad accumulation."""

from repro.train.steps import loss_fn, make_train_step

__all__ = ["loss_fn", "make_train_step"]
