"""Jitted training step: loss → grads → clip → AdamW, with grad accumulation.

``make_train_step(cfg)`` closes over the architecture and returns a function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` that lowers
cleanly under pjit (all shapes static; batch enters pre-sharded).

Batch layout:
  text / ssm / moe : {"tokens": (B, S+1) int32}
  audio            : {"tokens": (B, K, S+1) int32}
  vlm              : {"tokens": (B, S+1) int32, "embeds": (B, P, d) f32}
                     (loss masks the P patch-prefix positions)
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_update

__all__ = ["loss_fn", "make_train_step"]


def loss_fn(params, batch: Dict[str, Any], cfg: ArchConfig, *,
            remat: bool = True, unroll: bool = False):
    """Scalar LM loss (mean token CE + router aux)."""
    tokens = batch["tokens"]
    embeds = batch.get("embeds")
    if cfg.num_codebooks:
        inputs, labels = tokens[:, :, :-1], tokens[:, :, 1:]
    else:
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits, aux = T.forward_train(params, inputs, cfg, embeds=embeds,
                                  remat=remat, unroll=unroll)
    if cfg.num_codebooks:
        # (B, S, K, V) vs labels (B, K, S): mean CE over codebooks.
        logits = jnp.moveaxis(logits, 2, 1)          # (B, K, S, V)
        ce = L.cross_entropy_loss(logits, labels)
    elif cfg.mrope:
        # Drop the patch-prefix positions; predict text only.
        p = cfg.vlm_num_patches
        ce = L.cross_entropy_loss(logits[:, p:], labels)
    else:
        ce = L.cross_entropy_loss(logits, labels)
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: Optional[AdamWConfig] = None,
    lr_schedule: Optional[Callable] = None,
    *,
    accum_steps: int = 1,
    remat: bool = True,
    unroll: bool = False,
):
    """Build the train step. With ``accum_steps > 1`` the batch's leading dim
    must be divisible by it; microbatches run under ``lax.scan`` and grads
    are averaged (memory-bound large-batch configs)."""
    opt_cfg = opt_cfg or AdamWConfig()
    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, b, cfg, remat=remat, unroll=unroll),
        has_aux=True)

    def split_micro(batch):
        def sp(x):
            b = x.shape[0]
            return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])
        return jax.tree_util.tree_map(sp, batch)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, parts), grads = grad_fn(params, batch)
        else:
            micro = split_micro(batch)

            def body(carry, mb):
                acc, lsum, asum = carry
                (l, pp), g = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return (acc, lsum + l, asum + pp["aux"]), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum, asum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros(()), jnp.zeros(())), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps
            parts = {"ce": loss - asum / accum_steps,
                     "aux": asum / accum_steps}
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg, lr_schedule)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step
