"""Pallas TPU kernel: Mamba2 SSD intra-chunk block (beyond-paper addition).

The intra-chunk term of the SSD dual form (models/ssm.py) is, per
(batch, chunk, head):

    y = (tril(exp(segsum(da))) ∘ (C Bᵀ)) · (x·dt)          (lc × P)

i.e. two MXU matmuls — (lc,N)@(N,lc) for scores and (lc,lc)@(lc,P) for the
output — plus a VPU decay/mask elementwise stage. The jnp path materializes
the (B, nc, H, lc, lc) decay tensor in HBM; this kernel fuses decay
construction, masking and both matmuls so the (lc × lc) block lives only in
VMEM. Grid: one step per (batch·chunk, head); chunk length and state/head
dims (256/128/64 defaults) are MXU-aligned.

Target: TPU MXU; validated on CPU via ``interpret=True`` against
``ref.ssd_intra_ref`` (and transitively against ``models/ssm.ssd_chunked``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_intra_kernel", "ssd_intra_block"]


def ssd_intra_kernel(c_ref, b_ref, da_ref, x_ref, out_ref):
    """One (batch·chunk, head) block.

    c_ref/b_ref: (1, lc, N); da_ref: (1, lc, 1); x_ref: (1, lc, P).
    out: (1, lc, P).
    """
    c = c_ref[0]                              # (lc, N)
    b = b_ref[0]                              # (lc, N)
    da = da_ref[0, :, 0]                      # (lc,)
    x = x_ref[0]                              # (lc, P)
    lc = c.shape[0]
    # decay(i, j) = exp(sum_{j < t <= i} da[t]) on the lower triangle
    cs = jnp.cumsum(da)
    diff = cs[:, None] - cs[None, :]          # (lc, lc)
    ii = jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 1)
    tri = ii >= jj
    decay = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32)
    out_ref[0] = jnp.dot(scores * decay, x,
                         preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_block(c_mat, b_mat, da, x, *, interpret: bool = False):
    """Intra-chunk SSD output for all (batch·chunk, head) blocks.

    Args:
      c_mat/b_mat: (G, lc, N) f32 — per-(batch·chunk) C/B (shared across
        heads when n_groups = 1, as in the assigned configs).
      da: (G, H, lc) f32 — per-head discretized log-decays (≤ 0).
      x: (G, H, lc, P) f32 — dt-scaled inputs.
    Returns:
      (G, H, lc, P) f32 intra-chunk outputs.
    """
    g, lc, n = c_mat.shape
    h = da.shape[1]
    p = x.shape[-1]
    da_t = jnp.transpose(da, (0, 2, 1))            # (G, lc, H)
    x_flat = x.reshape(g * h, lc, p)               # head-major blocks
    out = pl.pallas_call(
        ssd_intra_kernel,
        grid=(g, h),
        in_specs=[
            pl.BlockSpec((1, lc, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, lc, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, lc, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, lc, p), lambda i, j: (i * h + j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, lc, p),
                               lambda i, j: (i * h + j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g * h, lc, p), jnp.float32),
        interpret=interpret,
    )(c_mat, b_mat, da_t, x_flat)
    return out.reshape(g, h, lc, p)
