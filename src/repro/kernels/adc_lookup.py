"""Pallas TPU kernel: ADC lookup-table LB distances (paper §2.4.4).

The paper's "advanced indexing" — ``Σ_j L[code[i,j], j]`` — is a scalar gather
stream on TPU, which is slow. The TPU-native adaptation (DESIGN.md §2) turns
each block's lookups into a one-hot × table **matvec the MXU executes**:

    acc[i] = onehot(codes_block)[i, (j,m)] · L_flat[(j,m)]

Grid is 2-D (row blocks × dim blocks) with a VMEM accumulator; dim blocks are
sized so the (BLOCK_N, BLOCK_D·M1) one-hot tile fits VMEM.

Target: TPU MXU; validated on CPU via ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["adc_kernel", "adc_lb_distances", "adc_batch_kernel",
           "adc_lb_distances_batch"]

BLOCK_N = 256
BLOCK_D = 16


def adc_kernel(codes_ref, table_ref, out_ref):
    """One (row-block, dim-block) step: accumulate partial LB sums.

    codes_ref: (BLOCK_N, BLOCK_D) int32 cell indices.
    table_ref: (M1, BLOCK_D) f32 per-dim boundary distance columns.
    out_ref:   (BLOCK_N,) f32 accumulator (summed over dim-block grid axis).
    """
    codes = codes_ref[...]
    table = table_ref[...]                       # (M1, BD)
    m1 = table.shape[0]
    # One-hot over cells: (BN, BD, M1) — flattened to drive the MXU as a
    # (BN, BD·M1) × (BD·M1,) matvec.
    onehot = (codes[:, :, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, m1), 2)).astype(table.dtype)
    flat = onehot.reshape(codes.shape[0], -1)    # (BN, BD*M1)
    tflat = table.T.reshape(-1)                  # (BD*M1,)
    partial = jnp.dot(flat, tflat, preferred_element_type=jnp.float32)
    dstep = pl.program_id(1)

    @pl.when(dstep == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(
    jax.jit, static_argnames=("interpret", "block_n", "block_d", "sqrt")
)
def adc_lb_distances(table, codes, *, interpret: bool = False,
                     block_n: int = BLOCK_N, block_d: int = BLOCK_D,
                     sqrt: bool = True):
    """LB distances for all candidate rows.

    Args:
      table: (M+1, d) f32 — per-query boundary-distance table (padding rows
        must be finite; callers zero the +inf padding — one-hot never selects
        rows ≥ C[j] for valid codes anyway).
      codes: (N, d) int32 quantized cells.
    Returns:
      (N,) f32 — sqrt of the per-row table sums (set ``sqrt=False`` for the
      squared form used when only ordering matters).
    """
    n, d = codes.shape
    m1 = table.shape[0]
    bn = min(block_n, max(int(n), 1))
    bd = min(block_d, d)
    pad_n = (-n) % bn
    pad_d = (-d) % bd
    if pad_n or pad_d:
        # Padding dims point at table column 0 of padded columns, which are 0.
        codes = jnp.pad(codes, ((0, pad_n), (0, pad_d)))
        table = jnp.pad(table, ((0, 0), (0, pad_d)))
    np_, dp = codes.shape
    grid = (np_ // bn, dp // bd)
    out = pl.pallas_call(
        adc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((m1, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=interpret,
    )(codes, table.astype(jnp.float32))
    out = out[:n]
    return jnp.sqrt(out) if sqrt else out


def adc_batch_kernel(codes_ref, table_ref, out_ref):
    """One (batch, row-block, dim-block) step of the batched ADC lookup.

    codes_ref: (1, BLOCK_N, BLOCK_D) int32 cell indices for this batch item.
    table_ref: (1, M1, BLOCK_D) f32 — this batch item's lookup-table columns.
    out_ref:   (1, BLOCK_N,) f32 accumulator over the dim-block grid axis.
    """
    codes = codes_ref[0]
    table = table_ref[0]                          # (M1, BD)
    m1 = table.shape[0]
    onehot = (codes[:, :, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, m1), 2)).astype(table.dtype)
    flat = onehot.reshape(codes.shape[0], -1)     # (BN, BD*M1)
    tflat = table.T.reshape(-1)                   # (BD*M1,)
    partial = jnp.dot(flat, tflat, preferred_element_type=jnp.float32)
    dstep = pl.program_id(2)

    @pl.when(dstep == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial[None, :]


@functools.partial(
    jax.jit, static_argnames=("interpret", "block_n", "block_d", "sqrt")
)
def adc_lb_distances_batch(tables, codes, *, interpret: bool = False,
                           block_n: int = BLOCK_N, block_d: int = BLOCK_D,
                           sqrt: bool = True):
    """LB distances for a batch of (query×partition) lookup problems.

    The batched query data plane evaluates one per-(query, partition) table
    against that pair's Hamming-surviving code rows; the grid walks
    (batch, row-block, dim-block) so every (table, codes) pair streams once.

    Args:
      tables: (B, M+1, d) f32 per-pair boundary-distance tables (finite
        entries only — callers zero the +inf padding).
      codes: (B, N, d) int32 quantized cells of each pair's survivors.
    Returns:
      (B, N) f32 LB distances (``sqrt=False`` for the squared form).
    """
    b, n, d = codes.shape
    m1 = tables.shape[1]
    bn = min(block_n, max(int(n), 1))
    bd = min(block_d, d)
    pad_n = (-n) % bn
    pad_d = (-d) % bd
    if pad_n or pad_d:
        codes = jnp.pad(codes, ((0, 0), (0, pad_n), (0, pad_d)))
        tables = jnp.pad(tables, ((0, 0), (0, 0), (0, pad_d)))
    np_, dp = codes.shape[1], codes.shape[2]
    grid = (b, np_ // bn, dp // bd)
    out = pl.pallas_call(
        adc_batch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, bd), lambda b_, i, j: (b_, i, j)),
            pl.BlockSpec((1, m1, bd), lambda b_, i, j: (b_, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b_, i, j: (b_, i)),
        out_shape=jax.ShapeDtypeStruct((b, np_), jnp.float32),
        interpret=interpret,
    )(codes, tables.astype(jnp.float32))
    out = out[:, :n]
    return jnp.sqrt(out) if sqrt else out
