"""Pallas TPU kernel: OSQ dimensional extraction (paper §2.2.2, Fig. 3).

Recovers per-dimension cell codes from shared S-bit segments with the paper's
shift/mask/OR scheme. The extraction *plan* (which segments a dimension
overlaps and by how much) is static metadata baked into the kernel at trace
time, so the inner loop is pure register arithmetic — no gathers, no control
flow. Rows are BlockSpec-tiled; all dimensions of a block's rows are
extracted in one VMEM residency (the "extract the same dimension of all
candidate vectors simultaneously" property).

Target: TPU VPU; validated on CPU via ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.segments import SegmentLayout

__all__ = ["make_extract_kernel", "extract_codes"]

BLOCK_N = 512


def make_extract_kernel(layout: SegmentLayout):
    """Bake the static extraction plan into a Pallas kernel body."""

    plans = layout.plans

    def kernel(seg_ref, out_ref):
        segs = seg_ref[...].astype(jnp.uint32)        # (BN, G)
        cols = []
        for plan in plans:                             # static unroll over d
            acc = jnp.zeros(segs.shape[:1], dtype=jnp.uint32)
            for piece in plan:                         # ≤ ceil(B[j]/S) pieces
                chunk = (segs[:, piece.seg] >> piece.rshift) & (
                    (1 << piece.nbits) - 1
                )
                acc = acc | (chunk << piece.lshift)
            cols.append(acc.astype(jnp.int32))
        out_ref[...] = jnp.stack(cols, axis=-1)        # (BN, d)

    return kernel


@functools.partial(jax.jit, static_argnames=("layout", "interpret", "block_n"))
def extract_codes(segments, layout: SegmentLayout, *, interpret: bool = False,
                  block_n: int = BLOCK_N):
    """(N, G) packed segments → (N, d) int32 codes."""
    n, g = segments.shape
    assert g == layout.num_segments, (g, layout.num_segments)
    bn = min(block_n, max(int(n), 1))
    pad = (-n) % bn
    if pad:
        segments = jnp.pad(segments, ((0, pad), (0, 0)))
    grid = (segments.shape[0] // bn,)
    out = pl.pallas_call(
        make_extract_kernel(layout),
        grid=grid,
        in_specs=[pl.BlockSpec((bn, g), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, layout.d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((segments.shape[0], layout.d), jnp.int32),
        interpret=interpret,
    )(segments)
    return out[:n]
