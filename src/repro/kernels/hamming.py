"""Pallas TPU kernel: packed binary Hamming distance (paper §2.4.3).

XOR + popcount over uint32 segment words — 32 dimensions per VPU lane. The
query's packed words are tiny and broadcast to every grid step; the database
is BlockSpec-tiled over rows so each block's codes stream HBM→VMEM once.

Target: TPU (VPU popcount); validated on CPU via ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["hamming_kernel", "packed_hamming"]

BLOCK_N = 512  # rows per grid step; G (words/row) rides along un-tiled.


def hamming_kernel(q_ref, db_ref, out_ref):
    """One block: (BLOCK_N, G) uint32 codes vs (1, G) query → (BLOCK_N,) i32."""
    q = q_ref[...]                       # (1, G)
    db = db_ref[...]                     # (BLOCK_N, G)
    x = jnp.bitwise_xor(db, q)           # broadcast over rows
    pc = jax.lax.population_count(x).astype(jnp.int32)
    out_ref[...] = jnp.sum(pc, axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def packed_hamming(q_packed, db_packed, *, interpret: bool = False,
                   block_n: int = BLOCK_N):
    """Hamming distances between one packed query and all packed rows.

    Args:
      q_packed: (G,) uint32 packed query bits.
      db_packed: (N, G) uint32 packed database bits (N padded internally).
    Returns:
      (N,) int32 distances.
    """
    n, g = db_packed.shape
    bn = min(block_n, max(int(n), 1))
    pad = (-n) % bn
    if pad:
        db_packed = jnp.pad(db_packed, ((0, pad), (0, 0)))
    grid = (db_packed.shape[0] // bn,)
    out = pl.pallas_call(
        hamming_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g), lambda i: (0, 0)),      # query: replicated
            pl.BlockSpec((bn, g), lambda i: (i, 0)),     # db rows: tiled
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((db_packed.shape[0],), jnp.int32),
        interpret=interpret,
    )(q_packed[None, :], db_packed)
    return out[:n]
