"""Pallas TPU kernels: packed binary Hamming distance (paper §2.4.3).

XOR + popcount over uint32 segment words — 32 dimensions per VPU lane. The
query's packed words are tiny and broadcast to every grid step; the database
is BlockSpec-tiled over rows so each block's codes stream HBM→VMEM once.

Two entry points:

* :func:`packed_hamming` — one query vs one code matrix (the seed kernel).
* :func:`packed_hamming_stacked` — the batched query data plane's shape:
  per-(query, partition) packed query words ``(Q, P, G)`` against a stacked
  partition code tensor ``(P, N, G)`` → ``(Q, P, N)``. The grid walks
  (query-block, partition, row-block); each db row block is re-used across
  the whole query-block axis, so codes stream HBM→VMEM once per Q/BLOCK_Q
  rather than once per query.

Target: TPU (VPU popcount); validated on CPU via ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["hamming_kernel", "packed_hamming", "hamming_stacked_kernel",
           "packed_hamming_stacked", "packed_hamming_multi"]

BLOCK_N = 512  # rows per grid step; G (words/row) rides along un-tiled.
BLOCK_Q = 8    # queries per grid step in the multi-query kernel.


def hamming_kernel(q_ref, db_ref, out_ref):
    """One block: (BLOCK_N, G) uint32 codes vs (1, G) query → (BLOCK_N,) i32."""
    q = q_ref[...]                       # (1, G)
    db = db_ref[...]                     # (BLOCK_N, G)
    x = jnp.bitwise_xor(db, q)           # broadcast over rows
    pc = jax.lax.population_count(x).astype(jnp.int32)
    out_ref[...] = jnp.sum(pc, axis=-1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def packed_hamming(q_packed, db_packed, *, interpret: bool = False,
                   block_n: int = BLOCK_N):
    """Hamming distances between one packed query and all packed rows.

    Args:
      q_packed: (G,) uint32 packed query bits.
      db_packed: (N, G) uint32 packed database bits (N padded internally).
    Returns:
      (N,) int32 distances.
    """
    n, g = db_packed.shape
    bn = min(block_n, max(int(n), 1))
    pad = (-n) % bn
    if pad:
        db_packed = jnp.pad(db_packed, ((0, pad), (0, 0)))
    grid = (db_packed.shape[0] // bn,)
    out = pl.pallas_call(
        hamming_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g), lambda i: (0, 0)),      # query: replicated
            pl.BlockSpec((bn, g), lambda i: (i, 0)),     # db rows: tiled
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((db_packed.shape[0],), jnp.int32),
        interpret=interpret,
    )(q_packed[None, :], db_packed)
    return out[:n]


def hamming_stacked_kernel(q_ref, db_ref, out_ref):
    """One (query-block, partition, row-block) step.

    q_ref:   (BQ, 1, G) uint32 — per-(query, this partition) packed words.
    db_ref:  (1, BN, G) uint32 — this partition's code rows.
    out_ref: (BQ, 1, BN) int32.
    """
    q = q_ref[...]                        # (BQ, 1, G)
    db = db_ref[...]                      # (1, BN, G)
    x = jnp.bitwise_xor(db, q[:, 0, :][:, None, :])       # (BQ, BN, G)
    pc = jax.lax.population_count(x).astype(jnp.int32)
    out_ref[...] = jnp.sum(pc, axis=-1, dtype=jnp.int32)[:, None, :]


@functools.partial(
    jax.jit, static_argnames=("interpret", "block_n", "block_q")
)
def packed_hamming_stacked(q_packed, db_packed, *, interpret: bool = False,
                           block_n: int = BLOCK_N, block_q: int = BLOCK_Q):
    """Batched Hamming distances for the stacked multi-partition data plane.

    Args:
      q_packed: (Q, P, G) uint32 — packed query bits, already standardized in
        each partition's binarization space (one word row per (query, part)).
      db_packed: (P, N, G) uint32 — stacked per-partition code rows (N padded
        to the partition row budget; padding rows are masked by the caller).
    Returns:
      (Q, P, N) int32 distances.
    """
    qn, p, g = q_packed.shape
    n = db_packed.shape[1]
    bq = min(block_q, max(int(qn), 1))
    bn = min(block_n, max(int(n), 1))
    pad_q = (-qn) % bq
    pad_n = (-n) % bn
    if pad_q:
        q_packed = jnp.pad(q_packed, ((0, pad_q), (0, 0), (0, 0)))
    if pad_n:
        db_packed = jnp.pad(db_packed, ((0, 0), (0, pad_n), (0, 0)))
    qp, np_ = q_packed.shape[0], db_packed.shape[1]
    grid = (qp // bq, p, np_ // bn)
    out = pl.pallas_call(
        hamming_stacked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, 1, g), lambda i, j, l: (i, j, 0)),
            pl.BlockSpec((1, bn, g), lambda i, j, l: (j, l, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1, bn), lambda i, j, l: (i, j, l)),
        out_shape=jax.ShapeDtypeStruct((qp, p, np_), jnp.int32),
        interpret=interpret,
    )(q_packed, db_packed)
    return out[:qn, :, :n]


def packed_hamming_multi(q_packed, db_packed, *, interpret: bool = False,
                         block_n: int = BLOCK_N, block_q: int = BLOCK_Q):
    """(Q, G) queries vs one (N, G) code matrix → (Q, N) distances.

    Thin single-partition view of :func:`packed_hamming_stacked`.
    """
    out = packed_hamming_stacked(
        q_packed[:, None, :], db_packed[None], interpret=interpret,
        block_n=block_n, block_q=block_q,
    )
    return out[:, 0, :]
