"""Public jit'd wrappers around the Pallas kernels.

On a real TPU these dispatch compiled kernels; on CPU (this container) they
run the same kernel bodies under ``interpret=True``. The switch is automatic
from the backend, overridable for tests.

The *batched* entry points (``hamming_stacked``, ``adc_batch``) feed the hot
query data plane (``repro.core.dataplane``), so they add a second switch:
``use_pallas``. On TPU the Pallas kernels run compiled; on CPU the default is
the pure-jnp oracle from :mod:`repro.kernels.ref` — XLA fuses it well, whereas
the Pallas interpreter is an emulator and orders of magnitude slower. Tests
pass ``use_pallas=True, interpret=True`` to exercise the kernel bodies.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.core.segments import SegmentLayout
from repro.kernels import adc_lookup, bitpack, hamming, ref

__all__ = ["hamming_distances", "hamming_stacked", "adc_distances",
           "adc_batch", "extract_codes", "ssd_intra"]


def _interpret(override: Optional[bool]) -> bool:
    if override is not None:
        return override
    return jax.default_backend() != "tpu"


def _use_pallas(override: Optional[bool]) -> bool:
    if override is not None:
        return override
    return jax.default_backend() == "tpu"


def hamming_distances(q_packed, db_packed, *, interpret: Optional[bool] = None):
    """(G,) uint32 query vs (N, G) uint32 rows → (N,) int32 Hamming."""
    return hamming.packed_hamming(
        q_packed, db_packed, interpret=_interpret(interpret)
    )


def hamming_stacked(q_packed, db_packed, *, use_pallas: Optional[bool] = None,
                    interpret: Optional[bool] = None):
    """(Q, P, G) query words vs (P, N, G) stacked rows → (Q, P, N) int32."""
    if _use_pallas(use_pallas):
        return hamming.packed_hamming_stacked(
            q_packed, db_packed, interpret=_interpret(interpret)
        )
    return ref.hamming_stacked_ref(q_packed, db_packed)


def adc_distances(table, codes, *, sqrt: bool = True,
                  interpret: Optional[bool] = None):
    """(M+1, d) table + (N, d) codes → (N,) LB distances."""
    return adc_lookup.adc_lb_distances(
        table, codes, sqrt=sqrt, interpret=_interpret(interpret)
    )


def adc_batch(tables, codes, *, sqrt: bool = True,
              use_pallas: Optional[bool] = None,
              interpret: Optional[bool] = None):
    """(B, M+1, d) tables + (B, N, d) codes → (B, N) LB distances."""
    if _use_pallas(use_pallas):
        return adc_lookup.adc_lb_distances_batch(
            tables, codes, sqrt=sqrt, interpret=_interpret(interpret)
        )
    return ref.adc_lb_batch_ref(tables, codes, sqrt=sqrt)


def extract_codes(segments, layout: SegmentLayout, *,
                  interpret: Optional[bool] = None):
    """(N, G) packed segments → (N, d) int32 codes."""
    return bitpack.extract_codes(
        segments, layout, interpret=_interpret(interpret)
    )


def ssd_intra(c_mat, b_mat, da, x, *, interpret: Optional[bool] = None):
    """(G,lc,N)/(G,lc,N)/(G,H,lc)/(G,H,lc,P) → (G,H,lc,P) SSD intra-chunk."""
    from repro.kernels import ssd
    return ssd.ssd_intra_block(c_mat, b_mat, da, x,
                               interpret=_interpret(interpret))
