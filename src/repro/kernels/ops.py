"""Public jit'd wrappers around the Pallas kernels.

On a real TPU these dispatch compiled kernels; on CPU (this container) they
run the same kernel bodies under ``interpret=True``. The switch is automatic
from the backend, overridable for tests.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.core.segments import SegmentLayout
from repro.kernels import adc_lookup, bitpack, hamming

__all__ = ["hamming_distances", "adc_distances", "extract_codes",
           "ssd_intra"]


def _interpret(override: Optional[bool]) -> bool:
    if override is not None:
        return override
    return jax.default_backend() != "tpu"


def hamming_distances(q_packed, db_packed, *, interpret: Optional[bool] = None):
    """(G,) uint32 query vs (N, G) uint32 rows → (N,) int32 Hamming."""
    return hamming.packed_hamming(
        q_packed, db_packed, interpret=_interpret(interpret)
    )


def adc_distances(table, codes, *, sqrt: bool = True,
                  interpret: Optional[bool] = None):
    """(M+1, d) table + (N, d) codes → (N,) LB distances."""
    return adc_lookup.adc_lb_distances(
        table, codes, sqrt=sqrt, interpret=_interpret(interpret)
    )


def extract_codes(segments, layout: SegmentLayout, *,
                  interpret: Optional[bool] = None):
    """(N, G) packed segments → (N, d) int32 codes."""
    return bitpack.extract_codes(
        segments, layout, interpret=_interpret(interpret)
    )


def ssd_intra(c_mat, b_mat, da, x, *, interpret: Optional[bool] = None):
    """(G,lc,N)/(G,lc,N)/(G,H,lc)/(G,H,lc,P) → (G,H,lc,P) SSD intra-chunk."""
    from repro.kernels import ssd
    return ssd.ssd_intra_block(c_mat, b_mat, da, x,
                               interpret=_interpret(interpret))
