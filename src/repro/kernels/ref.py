"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.segments import SegmentLayout, extract_all

__all__ = ["hamming_ref", "hamming_stacked_ref", "adc_lb_ref",
           "adc_lb_batch_ref", "extract_ref", "ssd_intra_ref"]


def hamming_ref(q_packed, db_packed):
    """Oracle for kernels.hamming.packed_hamming."""
    x = jnp.bitwise_xor(db_packed, q_packed[None, :])
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def hamming_stacked_ref(q_packed, db_packed):
    """Oracle for kernels.hamming.packed_hamming_stacked.

    q_packed: (Q, P, G) uint32; db_packed: (P, N, G) uint32 → (Q, P, N) i32.
    Also the XLA fast path the CPU jax backend dispatches to (kernels/ops.py).
    """
    x = jnp.bitwise_xor(db_packed[None], q_packed[:, :, None, :])
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1,
                   dtype=jnp.int32)


def adc_lb_ref(table, codes, sqrt: bool = True):
    """Oracle for kernels.adc_lookup.adc_lb_distances (gather formulation)."""
    t = jnp.asarray(table, dtype=jnp.float32)
    c = jnp.asarray(codes)
    picked = t[c, jnp.arange(c.shape[1])[None, :]]
    s = jnp.sum(picked, axis=-1)
    return jnp.sqrt(s) if sqrt else s


def adc_lb_batch_ref(tables, codes, sqrt: bool = True):
    """Oracle for kernels.adc_lookup.adc_lb_distances_batch.

    tables: (B, M+1, d) f32; codes: (B, N, d) int32 → (B, N) f32.
    Also the XLA fast path the CPU jax backend dispatches to (kernels/ops.py).
    """
    t = jnp.asarray(tables, dtype=jnp.float32)
    c = jnp.asarray(codes)
    picked = jnp.take_along_axis(t, c, axis=1)         # (B, N, d)
    s = jnp.sum(picked, axis=-1)
    return jnp.sqrt(s) if sqrt else s


def extract_ref(segments, layout: SegmentLayout):
    """Oracle for kernels.bitpack.extract_codes."""
    return extract_all(segments, layout)


def ssd_intra_ref(c_mat, b_mat, da, x):
    """jnp oracle for the SSD intra-chunk block (see kernels/ssd.py).

    c_mat/b_mat: (G, lc, N); da: (G, H, lc); x: (G, H, lc, P)
    → (G, H, lc, P).
    """
    cs = jnp.cumsum(da, axis=-1)                       # (G, H, lc)
    diff = cs[..., :, None] - cs[..., None, :]         # (G, H, lc, lc)
    lc = da.shape[-1]
    ii = jnp.arange(lc)
    tri = ii[:, None] >= ii[None, :]
    decay = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
    scores = jnp.einsum("gln,gsn->gls", c_mat, b_mat)  # (G, lc, lc)
    return jnp.einsum("gls,ghls,ghsp->ghlp", scores, decay, x)
