"""Rolling SLO monitors + policy gates over the live run-record stream.

The ROADMAP's million-QPS item needs "p50/p99 SLO gates ... from production
NodeTrace streams"; this module is the gate machinery. A
:class:`SloTracker` consumes finished runs — ``RunTrace`` objects straight
from the runtime (``ServerlessRuntime`` feeds its tracker on every
obs-enabled ``search``), or persisted JSONL run records
(:meth:`SloTracker.from_records`) — and maintains rolling windows:

* **latency** — exact p50/p99 over the last N runs' makespans (measured
  wall-clock when a real transport ran, modeled otherwise). Exact, not
  interpolated-bucket: the window is bounded, so sorting it is cheap and
  the tail quantile is the true order statistic.
* **retry / error budget** — worker re-invocations per invocation issued,
  and failed runs per run, over the same window.
* **cache hit rate** — §5.6 result-cache hits over lookups; runs with no
  cache activity don't dilute the ratio.

A :class:`SloPolicy` is a list of :class:`SloObjective` thresholds over
those monitors; ``policy.evaluate(tracker)`` returns an :class:`SloReport`
whose ``ok`` is the gate — the runtime exposes it for admission control and
``benchmarks/run.py --smoke`` asserts it in CI. Objectives with no data yet
report *insufficient* rather than failing: an empty window means "nothing
measured", not "SLO violated".

Everything here is plain Python over finished traces — nothing touches the
search hot path, so the obs-off bitwise-parity contract is untouched.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "RollingQuantile", "RollingRatio",
    "SloObjective", "SloPolicy", "SloReport", "SloTracker",
    "default_policy",
]


class RollingQuantile:
    """Exact quantiles over the last ``window`` observations.

    A bounded deque of samples; ``quantile(q)`` sorts the window and
    interpolates linearly between the two straddling order statistics
    (numpy's default), so a single-sample window answers every q with that
    sample and a full window gives the true windowed order statistic.
    """

    def __init__(self, window: int = 256):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.samples: Deque[float] = deque(maxlen=window)

    @property
    def window(self) -> int:
        return self.samples.maxlen

    @property
    def count(self) -> int:
        return len(self.samples)

    def observe(self, value: float) -> None:
        self.samples.append(float(value))

    def quantile(self, q: float) -> Optional[float]:
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if not self.samples:
            return None
        s = sorted(self.samples)
        if len(s) == 1:
            return s[0]
        pos = q * (len(s) - 1)
        lo = math.floor(pos)
        hi = min(lo + 1, len(s) - 1)
        frac = pos - lo
        return s[lo] + (s[hi] - s[lo]) * frac

    @property
    def mean(self) -> Optional[float]:
        if not self.samples:
            return None
        return sum(self.samples) / len(self.samples)


class RollingRatio:
    """A windowed numerator/denominator ratio (retries per invocation,
    cache hits per lookup, errors per run). Each ``observe`` is one run's
    contribution; evicting a run from the window removes both sides."""

    def __init__(self, window: int = 256):
        if window < 1:
            raise ValueError("window must be >= 1")
        self._events: Deque[Tuple[float, float]] = deque(maxlen=window)

    @property
    def count(self) -> int:
        return len(self._events)

    def observe(self, num: float, den: float = 1.0) -> None:
        self._events.append((float(num), float(den)))

    @property
    def ratio(self) -> Optional[float]:
        den = sum(d for _, d in self._events)
        if den <= 0:
            return None
        return sum(n for n, _ in self._events) / den


# Monitor keys an objective can target.
_METRICS = ("latency_p50", "latency_p99", "latency_mean",
            "retry_rate", "error_rate", "cache_hit_rate")


@dataclasses.dataclass(frozen=True)
class SloObjective:
    """One thresholded objective: ``metric op threshold``.

    ``op`` is ``"<="`` (budgets: latency, retries, errors) or ``">="``
    (floors: cache hit rate).
    """

    name: str
    metric: str
    threshold: float
    op: str = "<="

    def __post_init__(self):
        if self.metric not in _METRICS:
            raise ValueError(f"unknown SLO metric {self.metric!r}; "
                             f"expected one of {_METRICS}")
        if self.op not in ("<=", ">="):
            raise ValueError(f"unknown SLO op {self.op!r}")

    def check(self, value: float) -> bool:
        return value <= self.threshold if self.op == "<=" \
            else value >= self.threshold


@dataclasses.dataclass
class SloReport:
    """One policy evaluation: per-objective verdicts + the overall gate."""

    entries: List[Dict]

    @property
    def ok(self) -> bool:
        """The gate: no objective *with data* is violated. Insufficient
        data is not a violation (but see ``conclusive``)."""
        return all(e["ok"] is not False for e in self.entries)

    @property
    def conclusive(self) -> bool:
        """Every objective had data to evaluate."""
        return all(e["ok"] is not None for e in self.entries)

    @property
    def failures(self) -> List[Dict]:
        return [e for e in self.entries if e["ok"] is False]

    def to_json(self) -> Dict:
        return {"ok": self.ok, "conclusive": self.conclusive,
                "entries": list(self.entries)}

    def summary(self) -> str:
        parts = []
        for e in self.entries:
            val = ("n/a" if e["value"] is None
                   else f"{e['value']:.6g}")
            mark = {True: "ok", False: "VIOLATED", None: "no-data"}[e["ok"]]
            parts.append(f"{e['name']}: {val} {e['op']} "
                         f"{e['threshold']:.6g} [{mark}]")
        return "; ".join(parts)


@dataclasses.dataclass
class SloPolicy:
    """A named bundle of objectives the runtime / CI can gate on."""

    objectives: List[SloObjective]
    name: str = "slo"

    def evaluate(self, tracker: "SloTracker") -> SloReport:
        entries = []
        for obj in self.objectives:
            value = tracker.value(obj.metric)
            entries.append({
                "name": obj.name, "metric": obj.metric,
                "threshold": obj.threshold, "op": obj.op,
                "value": value,
                "ok": None if value is None else obj.check(value),
            })
        return SloReport(entries)


def default_policy(p50_s: float = 30.0, p99_s: float = 120.0,
                   retry_rate: float = 0.1,
                   error_rate: float = 0.01) -> SloPolicy:
    """A permissive latency/retry/error policy: the CI smoke gate's
    defaults (wide enough for cold jit compiles on a loaded runner —
    the gate pins the *machinery*, deployments tighten the numbers)."""
    return SloPolicy(name="default", objectives=[
        SloObjective("latency.p50", "latency_p50", p50_s),
        SloObjective("latency.p99", "latency_p99", p99_s),
        SloObjective("retry.budget", "retry_rate", retry_rate),
        SloObjective("error.budget", "error_rate", error_rate),
    ])


class SloTracker:
    """Rolling monitors over a stream of finished runs."""

    def __init__(self, window: int = 256):
        self.window = window
        self.latency = RollingQuantile(window)
        self.retries = RollingRatio(window)
        self.errors = RollingRatio(window)
        self.cache = RollingRatio(window)
        self.runs = 0

    # -------------------------------------------------------------- feeding

    def observe_run(self, trace) -> None:
        """Fold one finished ``RunTrace`` in (the runtime's per-search feed).

        Latency prefers the measured wall-clock (real transports); a purely
        modeled run contributes its virtual makespan — one tracker should
        watch one transport, which is how the runtime wires it.
        """
        measured = float(getattr(trace, "measured_makespan_s", 0.0) or 0.0)
        makespan = float(getattr(trace, "makespan_s", 0.0) or 0.0)
        self._observe(
            latency_s=measured if measured > 0 else makespan,
            retries=int(getattr(trace, "worker_retries", 0)),
            invocations=len(getattr(trace, "nodes", ()) or ()),
            cache_hits=int(getattr(trace, "cache_hits", 0)),
            cache_misses=int(getattr(trace, "cache_misses", 0)))

    def observe_record(self, record: Dict) -> None:
        """Fold one persisted JSONL run record in (offline/streamed form)."""
        meta = record.get("meta") or {}
        rt = record.get("run_trace") or {}
        measured = float(meta.get("measured_makespan_s")
                         or rt.get("measured_makespan_s") or 0.0)
        makespan = float(meta.get("makespan_s") or rt.get("makespan_s")
                         or 0.0)
        self._observe(
            latency_s=measured if measured > 0 else makespan,
            retries=int(rt.get("worker_retries", 0)),
            invocations=len(rt.get("nodes", ()) or ()),
            cache_hits=int(rt.get("cache_hits", 0)),
            cache_misses=int(rt.get("cache_misses", 0)))

    def observe_error(self) -> None:
        """One failed run (the error-budget numerator)."""
        self.runs += 1
        self.errors.observe(1.0)

    def _observe(self, *, latency_s: float, retries: int, invocations: int,
                 cache_hits: int, cache_misses: int) -> None:
        self.runs += 1
        self.latency.observe(latency_s)
        self.errors.observe(0.0)
        self.retries.observe(retries, max(invocations, 1))
        lookups = cache_hits + cache_misses
        if lookups > 0:
            self.cache.observe(cache_hits, lookups)

    @classmethod
    def from_records(cls, records: Iterable[Dict],
                     window: int = 256) -> "SloTracker":
        tracker = cls(window=window)
        for rec in records:
            tracker.observe_record(rec)
        return tracker

    # ------------------------------------------------------------- reading

    def value(self, metric: str) -> Optional[float]:
        if metric == "latency_p50":
            return self.latency.quantile(0.50)
        if metric == "latency_p99":
            return self.latency.quantile(0.99)
        if metric == "latency_mean":
            return self.latency.mean
        if metric == "retry_rate":
            return self.retries.ratio
        if metric == "error_rate":
            return self.errors.ratio
        if metric == "cache_hit_rate":
            return self.cache.ratio
        raise ValueError(f"unknown SLO metric {metric!r}; "
                         f"expected one of {_METRICS}")

    def snapshot(self) -> Dict:
        """JSON-able dump of every monitor (exported next to metrics)."""
        return {
            "window": self.window,
            "runs": self.runs,
            "samples": self.latency.count,
            "latency_p50_s": self.latency.quantile(0.50),
            "latency_p99_s": self.latency.quantile(0.99),
            "latency_mean_s": self.latency.mean,
            "retry_rate": self.retries.ratio,
            "error_rate": self.errors.ratio,
            "cache_hit_rate": self.cache.ratio,
        }
