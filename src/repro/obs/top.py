"""Live fleet dashboard (``python -m repro.obs.top TRACE.jsonl``).

A text ``top`` for a SQUASH fleet, rendered from the JSONL run-record
stream the runtime exports (``repro.obs.export``). Three panes:

* **fleet metrics** — the latest record's merged registry snapshot
  (client + every pipe worker / socket host the aggregation layer pulled),
  with the remote sources listed so a silent host is visible at a glance;
* **SLO** — rolling p50/p99 latency, retry/error budgets and cache hit
  rate over the last ``--window`` runs, gated by the default policy
  (``repro.obs.slo``);
* **$/query** — the latest run's per-node cost attribution
  (``RunTrace.dollars_attributed``) folded by kind, plus the running
  average dollars per query over the window.

``--follow`` re-reads the file every ``--interval`` seconds and redraws,
so a long benchmark can be watched live; a single shot is the default (CI
logs, piping to a file). Everything here is read-only over persisted
records — it never touches a runtime.
"""

from __future__ import annotations

import argparse
import math
import time
from typing import Dict, List, Optional

from repro.obs.export import read_jsonl
from repro.obs.metrics import Histogram, bounds_from_buckets
from repro.obs.slo import SloPolicy, SloTracker, default_policy

__all__ = ["render_metrics", "render_slo", "render_cost",
           "render_dashboard", "main"]


def _fmt(v: float) -> str:
    if v == 0:
        return "0"
    if abs(v) >= 1e-3:
        return f"{v:.4g}"
    return f"{v:.3e}"


def _registry_snapshot(snapshot: Dict) -> Dict:
    """Accept either a plain registry snapshot or a fleet snapshot
    (``{"local", "remote", "merged"}``) — render the merged view."""
    if "merged" in snapshot and "counters" not in snapshot:
        return snapshot.get("merged") or {}
    return snapshot


def render_metrics(snapshot: Dict, limit: int = 16) -> str:
    """Summarize a registry snapshot: counters, then histogram quantiles.

    ``snapshot`` may be a fleet snapshot, in which case the merged view is
    rendered and the remote source labels are listed first.
    """
    lines: List[str] = []
    sources = sorted((snapshot.get("remote") or {})
                     if "merged" in snapshot else ())
    if sources:
        lines.append(f"  sources: local + {', '.join(sources)}")
    reg = _registry_snapshot(snapshot)
    counters = reg.get("counters") or {}
    for name in sorted(counters)[:limit]:
        lines.append(f"  {name:<40s} {counters[name]}")
    if len(counters) > limit:
        lines.append(f"  ... {len(counters) - limit} more counters")
    for name in sorted(reg.get("gauges") or {}):
        lines.append(f"  {name:<40s} {_fmt(reg['gauges'][name])}")
    for name, h in sorted((reg.get("histograms") or {}).items()):
        # Rebuild a Histogram from the snapshot so quantiles use the same
        # interpolation the live registry reports.
        hist = Histogram(name, buckets=bounds_from_buckets(h["buckets"]))
        hist.merge(h)
        mean = h["sum"] / h["count"] if h["count"] else 0.0
        lines.append(
            f"  {name:<40s} n={h['count']} mean={_fmt(mean)} "
            f"p50={_fmt(hist.quantile(0.5) or 0.0)} "
            f"p99={_fmt(hist.quantile(0.99) or 0.0)}")
    return "\n".join(lines) if lines else "  (no metrics)"


def render_slo(tracker: SloTracker,
               policy: Optional[SloPolicy] = None) -> str:
    policy = policy or default_policy()
    report = policy.evaluate(tracker)
    gate = "PASS" if report.ok else "FAIL"
    if not report.conclusive:
        gate += " (partial data)"
    lines = [f"  gate [{policy.name}]: {gate}"]
    for e in report.entries:
        val = "n/a" if e["value"] is None else _fmt(e["value"])
        mark = {True: "ok", False: "VIOLATED", None: "no-data"}[e["ok"]]
        lines.append(f"  {e['name']:<16s} {val:>10s} {e['op']} "
                     f"{_fmt(e['threshold'])}  [{mark}]")
    snap = tracker.snapshot()
    hit = snap["cache_hit_rate"]
    lines.append(f"  window: {snap['samples']}/{snap['window']} runs"
                 + ("" if hit is None else f", cache hit {hit:.1%}"))
    return "\n".join(lines)


def render_cost(record: Dict) -> str:
    """The latest run's $/query attribution, folded by node kind."""
    trace = record.get("run_trace") or {}
    rows = trace.get("dollars_attributed") or []
    cost = trace.get("cost") or {}
    if not rows:
        return "  (no cost attribution in latest record)"
    queries = max(int((record.get("meta") or {}).get("queries", 0))
                  or int(trace.get("stats", {}).get("queries", 0)), 1)
    by_kind: Dict[str, Dict[str, float]] = {}
    for row in rows:
        agg = by_kind.setdefault(row["kind"],
                                 {"n": 0, "invocation": 0.0, "runtime": 0.0,
                                  "s3": 0.0, "efs": 0.0, "total": 0.0})
        agg["n"] += 1
        for comp in ("invocation", "runtime", "s3", "efs", "total"):
            agg[comp] += row[comp]
    lines = [f"  {'kind':<6s} {'n':>4s} {'invoke':>10s} {'runtime':>10s} "
             f"{'s3':>10s} {'efs':>10s} {'total':>10s}"]
    for kind in ("co", "qa", "qp"):
        agg = by_kind.get(kind)
        if agg is None:
            continue
        lines.append(f"  {kind:<6s} {agg['n']:>4d} "
                     + " ".join(f"{_fmt(agg[c]):>10s}" for c in
                                ("invocation", "runtime", "s3", "efs",
                                 "total")))
    total = cost.get("total", math.fsum(r["total"] for r in rows))
    lines.append(f"  run total ${_fmt(total)}  "
                 f"(${_fmt(total / queries)}/query over {queries} queries)")
    return "\n".join(lines)


def render_dashboard(records: List[Dict], *, window: int = 256,
                     policy: Optional[SloPolicy] = None,
                     metrics: Optional[Dict] = None) -> str:
    """One full dashboard frame from a record stream.

    ``metrics`` overrides the metrics pane's snapshot (e.g. a standalone
    ``SMOKE_metrics.json``); by default the latest record that carried a
    fleet snapshot supplies it.
    """
    if not records:
        return "(no run records yet)"
    last = records[-1]
    if metrics is None:
        for rec in reversed(records):
            if rec.get("metrics"):
                metrics = rec["metrics"]
                break
    tracker = SloTracker.from_records(records, window=window)
    avg_cost = math.fsum(
        (r.get("run_trace") or {}).get("cost", {}).get("total", 0.0)
        for r in records) / len(records)
    meta = last.get("meta") or {}
    lines = [
        f"squash top — {len(records)} runs, latest "
        f"run={last.get('run', '?')} transport={meta.get('transport', '?')} "
        f"avg ${_fmt(avg_cost)}/run",
        "fleet metrics:",
        render_metrics(metrics or {}),
        "slo:",
        render_slo(tracker, policy),
        "cost attribution (latest run):",
        render_cost(last),
    ]
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.top",
        description="Live text dashboard over an obs run-record JSONL: "
                    "fleet metrics, SLO gate, per-query cost.")
    ap.add_argument("trace", help="JSONL trace file (repro.obs.export)")
    ap.add_argument("--window", type=int, default=256, metavar="N",
                    help="SLO rolling window (runs)")
    ap.add_argument("--follow", action="store_true",
                    help="redraw every --interval seconds until ^C")
    ap.add_argument("--interval", type=float, default=2.0, metavar="S",
                    help="refresh period with --follow")
    args = ap.parse_args(argv)
    while True:
        try:
            records = read_jsonl(args.trace)
        except FileNotFoundError:
            records = []
        frame = render_dashboard(records, window=args.window)
        if args.follow:
            print(f"\x1b[2J\x1b[H{frame}", flush=True)
            try:
                time.sleep(args.interval)
            except KeyboardInterrupt:
                return 0
        else:
            print(frame)
            return 0


if __name__ == "__main__":
    raise SystemExit(main())
