"""Text Gantt renderer for persisted run traces.

    python -m repro.obs.timeline results/SMOKE_trace.jsonl [--width N] [--run ID]

Each run record (see ``repro.obs.export``) renders as one per-node Gantt of
the Alg. 2 tree walk: a bar per Coordinator / QueryAllocator /
QueryProcessor invocation on the modeled clock, with cold/warm (``C``/``W``)
and retry (``rN!``) markers, the derived issue → wire → compute → respond
phase split, and the worker-reported wall-clock sub-spans (deserialize /
compute / serialize / fetch) indented beneath the node that shipped them
back. Wall-clock sub-spans are durations, not bars — they live on the
worker's clock, which the modeled axis does not share.

Records with no stitched node spans (a trace exported with span recording
off, or a worker echo that failed verification) degrade gracefully: the
rows are synthesized from the ``run_trace`` node traces instead — same
bars, with the invoke/fetch/compute phase split coming from the modeled
timeline rather than recorded phase spans.

``--metrics`` additionally prints each record's merged fleet-metrics
summary (counters + histogram quantiles) beneath its Gantt.
"""

from __future__ import annotations

import argparse
from typing import Dict, List, Optional

from repro.obs.export import read_jsonl
from repro.obs.spans import Span

__all__ = ["render_record", "render_records", "main"]

_NODE_KINDS = ("co", "qa", "qp")


def _fmt_s(seconds: float) -> str:
    if seconds >= 1.0:
        return f"{seconds:.3f}s"
    return f"{seconds * 1e3:.1f}ms"


def _bar(t0: float, t1: float, tmax: float, width: int) -> str:
    lo = int(round(t0 / tmax * width)) if tmax > 0 else 0
    hi = int(round(t1 / tmax * width)) if tmax > 0 else 0
    lo = min(max(lo, 0), width - 1)
    hi = min(max(hi, lo + 1), width)
    return "·" * lo + "█" * (hi - lo) + "·" * (width - hi)


def _trace_rows(record: Dict, width: int, tmax: float,
                lines: List[str]) -> None:
    """Fallback rows from ``run_trace`` nodes when no node spans exist."""
    nodes = (record.get("run_trace") or {}).get("nodes") or ()
    tmax = max(tmax, max((float(n["t_end"]) for n in nodes), default=0.0))
    for n in sorted(nodes, key=lambda d: (d["t_issue"], d["node"],
                                          d.get("chunk", 0))):
        marker = "W" if n.get("warm") else "C"
        retries = int(n.get("retries", 0))
        if retries:
            marker += f" r{retries}!"
        t0, t1 = float(n["t_issue"]), float(n["t_end"])
        label = f"{n['node']}#{n.get('chunk', 0)}"
        lines.append(f"  {label:<10s} [{marker:<4s}] "
                     f"|{_bar(t0, t1, tmax, width)}| "
                     f"{_fmt_s(t0)}–{_fmt_s(t1)}")
        phases = [("invoke", n.get("invoke_s", 0.0)),
                  ("fetch", n.get("fetch_s", 0.0)),
                  ("setup", n.get("setup_s", 0.0)),
                  ("compute", n.get("compute_s", 0.0))]
        lines.append("      " + " · ".join(
            f"{name} {_fmt_s(float(dur))}"
            for name, dur in phases if dur) + "  (modeled)")


def render_record(record: Dict, width: int = 56) -> str:
    spans = [Span.from_json(d) for d in record.get("spans", ())]
    meta = record.get("meta", {})
    kids: Dict[Optional[str], List[Span]] = {}
    for s in spans:
        kids.setdefault(s.parent_id, []).append(s)
    nodes = sorted((s for s in spans if s.attrs.get("kind") in _NODE_KINDS),
                   key=lambda s: (s.t0, s.name, s.attrs.get("chunk", 0)))
    modeled = [s for s in spans if s.attrs.get("clock") != "wall"]
    tmax = max((s.t1 for s in modeled), default=0.0)
    head = " ".join(f"{k}={meta[k]}" for k in
                    ("transport", "queries", "k") if k in meta)
    lines = [f"run {record.get('run', '?')}  {head}  "
             f"modeled={_fmt_s(float(meta.get('makespan_s', tmax)))}"
             + (f"  measured={_fmt_s(float(meta['measured_makespan_s']))}"
                if meta.get("measured_makespan_s") else "")]
    if not nodes:
        _trace_rows(record, width, tmax, lines)
        return "\n".join(lines)
    for node in nodes:
        marker = "W" if node.attrs.get("warm") else "C"
        retries = int(node.attrs.get("retries", 0))
        if retries:
            marker += f" r{retries}!"
        label = f"{node.name}#{node.attrs.get('chunk', 0)}"
        lines.append(f"  {label:<10s} [{marker:<4s}] "
                     f"|{_bar(node.t0, node.t1, tmax, width)}| "
                     f"{_fmt_s(node.t0)}–{_fmt_s(node.t1)}")
        phases = [s for s in kids.get(node.span_id, ())
                  if s.attrs.get("phase")]
        if phases:
            lines.append("      " + " · ".join(
                f"{p.name} {_fmt_s(p.duration)}"
                for p in sorted(phases, key=lambda s: s.t0)))
        workers = [s for s in kids.get(node.span_id, ())
                   if s.attrs.get("clock") == "wall"]
        if workers:
            where = ""
            pid = node.attrs.get("worker_pid")
            host = node.attrs.get("worker_host")
            if pid or host:
                where = f"  (pid {pid}" + (f" @ {host}" if host else "") + ")"
            lines.append("      worker: " + " · ".join(
                f"{w.name.removeprefix('worker.')} {_fmt_s(w.duration)}"
                for w in sorted(workers, key=lambda s: s.t0)) + where)
    return "\n".join(lines)


def render_records(records: List[Dict], width: int = 56,
                   run: Optional[str] = None,
                   metrics: bool = False) -> str:
    picked = [r for r in records if run is None or r.get("run") == run]
    parts = []
    for r in picked:
        text = render_record(r, width=width)
        if metrics and r.get("metrics"):
            from repro.obs.top import render_metrics
            text += "\nfleet metrics:\n" + render_metrics(r["metrics"])
        parts.append(text)
    return "\n\n".join(parts)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.timeline",
        description="Render a per-node text Gantt from an obs trace JSONL.")
    ap.add_argument("trace", help="JSONL trace file (repro.obs.export)")
    ap.add_argument("--width", type=int, default=56, metavar="N",
                    help="bar width in characters")
    ap.add_argument("--run", default=None, metavar="ID",
                    help="render only this run id")
    ap.add_argument("--metrics", action="store_true",
                    help="also print each record's merged fleet-metrics "
                         "summary")
    args = ap.parse_args(argv)
    records = read_jsonl(args.trace)
    out = render_records(records, width=args.width, run=args.run,
                         metrics=args.metrics)
    print(out if out else f"(no matching runs in {args.trace})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
