"""Lightweight distributed spans for the serverless runtime.

A *span* is one timed, named interval with a parent — the Alg. 2 tree walk
becomes a span tree: the run-level ``search`` span parents the Coordinator
node span, which parents its QueryAllocator children, which parent their
QueryProcessor fan-outs; each node span carries derived phase children
(issue → wire → compute → respond) on the modeled clock and, for real
transports, the worker-reported wall-clock sub-spans (deserialize /
compute / serialize / fetch) stitched back across the process or TCP
boundary.

The cross-boundary carrier is a :class:`SpanContext` — ``(run id, span
id)`` — injected into the transport ``extra`` envelope
(``payload.inject_span_context``), never into the budgeted payload bytes,
so request-byte accounting is identical with tracing on or off. The worker
echoes the context back with its sub-span offsets; the client-side
:class:`Recorder` verifies the echo and stitches the spans under the node
span it minted at submit time.

Recording is post-hoc and allocation-light: handlers compute their
timelines anyway (``NodeTrace``), so the recorder just appends finished
spans — there is no context-manager timing machinery on the hot path.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import uuid
from typing import Dict, List, Optional

__all__ = ["Span", "SpanContext", "Recorder", "new_run_id"]


def new_run_id() -> str:
    return uuid.uuid4().hex[:16]


@dataclasses.dataclass
class Span:
    """One finished interval in a run's span tree."""

    name: str
    span_id: str
    parent_id: Optional[str]
    t0: float                 # seconds, relative to the run origin
    t1: float
    attrs: Dict = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return max(self.t1 - self.t0, 0.0)

    def to_json(self) -> Dict:
        return {"name": self.name, "id": self.span_id,
                "parent": self.parent_id, "t0": self.t0, "t1": self.t1,
                "attrs": dict(self.attrs)}

    @staticmethod
    def from_json(d: Dict) -> "Span":
        return Span(name=d["name"], span_id=d["id"], parent_id=d["parent"],
                    t0=float(d["t0"]), t1=float(d["t1"]),
                    attrs=dict(d.get("attrs") or {}))


@dataclasses.dataclass(frozen=True)
class SpanContext:
    """The wire-crossing identity of one span: who to stitch back to."""

    run_id: str
    span_id: str

    def to_wire(self) -> Dict[str, str]:
        """JSON/pickle-able envelope form (what ``extra['obs']`` carries)."""
        return {"run": self.run_id, "span": self.span_id}

    @staticmethod
    def from_wire(d: Optional[Dict]) -> Optional["SpanContext"]:
        if not d:
            return None
        return SpanContext(run_id=str(d["run"]), span_id=str(d["span"]))


class Recorder:
    """Span accumulator for one run (one ``ServerlessRuntime.search``)."""

    def __init__(self, run_id: Optional[str] = None):
        self.run_id = run_id or new_run_id()
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self.spans: List[Span] = []

    def new_span_id(self) -> str:
        """Mint an id before the span's interval is known (submit time)."""
        return f"s{next(self._ids)}"

    def context(self, span_id: str) -> SpanContext:
        return SpanContext(self.run_id, span_id)

    def record(self, name: str, t0: float, t1: float, *,
               span_id: Optional[str] = None,
               parent_id: Optional[str] = None, **attrs) -> str:
        sid = span_id or self.new_span_id()
        span = Span(name=name, span_id=sid, parent_id=parent_id,
                    t0=float(t0), t1=float(t1), attrs=attrs)
        with self._lock:
            self.spans.append(span)
        return sid

    def by_name(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]

    def children(self, span_id: str) -> List[Span]:
        return [s for s in self.spans if s.parent_id == span_id]

    def to_json(self) -> List[Dict]:
        return [s.to_json() for s in self.spans]
