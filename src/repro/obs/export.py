"""Trace exporters: JSONL persistence under ``results/`` + in-memory.

One *run record* per completed ``ServerlessRuntime.search``::

    {"run": <run id>, "meta": {transport, queries, k, makespan_s, ...},
     "spans": [Span.to_json(), ...], "run_trace": RunTrace.to_json(),
     "metrics": REGISTRY.fleet_snapshot(),   # when fleet telemetry is live
     "slo": SloTracker.snapshot()}           # rolling monitors at export

``JsonlExporter`` appends one record per line (append-mode per write, so
several runtimes — or several smoke gates — can share one artifact file);
``InMemoryExporter`` keeps records on a list for tests. ``read_jsonl``
loads a file back into record dicts, which is what
``python -m repro.obs.timeline`` renders.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

__all__ = ["InMemoryExporter", "JsonlExporter", "run_record", "read_jsonl"]


def run_record(recorder, run_trace=None, meta: Optional[Dict] = None,
               metrics: Optional[Dict] = None,
               slo: Optional[Dict] = None) -> Dict:
    """Assemble one exportable record from a finished run's recorder.

    ``metrics`` is the fleet snapshot (local/remote/merged registries) at
    export time; ``slo`` the rolling-monitor dump. Both are optional so
    pre-telemetry records stay valid and readers treat them as absent.
    """
    rec: Dict = {
        "run": recorder.run_id,
        "meta": dict(meta or {}),
        "spans": recorder.to_json(),
    }
    if run_trace is not None:
        rec["run_trace"] = run_trace.to_json()
    if metrics is not None:
        rec["metrics"] = metrics
    if slo is not None:
        rec["slo"] = slo
    return rec


class InMemoryExporter:
    """Collects run records on a list (the test/inspection exporter)."""

    def __init__(self):
        self.records: List[Dict] = []

    def export(self, record: Dict) -> None:
        self.records.append(record)


class JsonlExporter:
    """Appends one JSON line per run record to ``path``."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)

    def export(self, record: Dict) -> None:
        with open(self.path, "a") as f:
            f.write(json.dumps(record, separators=(",", ":"), default=float))
            f.write("\n")


def read_jsonl(path: str) -> List[Dict]:
    """Load every run record from a JSONL trace file (blank lines skipped)."""
    records: List[Dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records
