"""Observability layer: distributed spans, metrics, trace export (PR 7).

* ``metrics``  — the process-global :data:`~repro.obs.metrics.REGISTRY` of
  counters / gauges / fixed-bucket latency histograms (p50/p95/p99),
  disabled by default and zero-cost when off. Instrumented call sites live
  in ``serverless.transport`` / ``socket_transport`` (submits, retries,
  respawns, reconnects, heartbeats, frame bytes, invoke latency),
  ``core.dre`` (result-cache hits/misses/evictions, pool leases/warm rate)
  and ``core.dataplane`` (jit trace-cache compiles per pow2 query bucket).
* ``spans``    — span contexts that cross the transport boundary inside the
  ``extra`` envelope (never the budgeted payload), worker-side sub-spans
  echoed back in the response ``info``, and the per-run :class:`Recorder`
  that stitches them into one tree.
* ``export``   — JSONL persistence under ``results/`` + an in-memory
  exporter for tests.
* ``timeline`` — ``python -m repro.obs.timeline <trace.jsonl>``: a per-node
  text Gantt of the Alg. 2 tree walk.
* ``slo``      — rolling p50/p99 latency, retry/error-budget and
  cache-hit monitors over the run-record stream, with the
  :class:`~repro.obs.slo.SloPolicy` gate API (PR 10).
* ``top``      — ``python -m repro.obs.top <trace.jsonl>``: live text
  dashboard of fleet metrics, SLO status and $/query attribution.

Fleet aggregation (PR 10): ``Counter``/``Gauge``/``Histogram`` merge
losslessly from snapshots; pipe workers echo registry deltas in response
``info`` and socket hosts answer a STATS frame, so
``REGISTRY.fleet_snapshot()`` is one merged, source-labelled view of the
whole fleet.

The whole layer is opt-in via ``RuntimeConfig(obs_enabled=True,
obs_trace_path=...)``; ids, ``SearchStats`` and all traces are
bitwise-identical with it on or off (pinned by tests). This module imports
only the standard library, so ``core``/``serverless`` can instrument
freely without cycles.
"""

from repro.obs.export import InMemoryExporter, JsonlExporter, read_jsonl, run_record
from repro.obs.metrics import REGISTRY, Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.slo import SloObjective, SloPolicy, SloTracker, default_policy
from repro.obs.spans import Recorder, Span, SpanContext, new_run_id

__all__ = [
    "REGISTRY", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Recorder", "Span", "SpanContext", "new_run_id",
    "InMemoryExporter", "JsonlExporter", "read_jsonl", "run_record",
    "SloObjective", "SloPolicy", "SloTracker", "default_policy",
]
