"""Process-global metrics registry: counters, gauges, latency histograms.

The registry is **disabled by default and zero-cost when off**: every
accessor returns one shared no-op metric, so instrumented hot paths (the
transports, DRE, the dataplane trace hook) pay a dict-free method call and
nothing else — ids, ``SearchStats`` and all traces are bitwise-identical
with metrics on or off. Enabling (``REGISTRY.enable()``, or transparently
via ``RuntimeConfig(obs_enabled=True)``) turns the same call sites into real
instruments.

Histograms are fixed-bucket: each observation lands in the first bucket
whose upper bound contains it (plus an implicit +inf overflow bucket), and
quantiles come out by Prometheus-style linear interpolation inside the
containing bucket — exact on distributions whose mass fills buckets
uniformly, which the tests pin. ``snapshot()`` serializes everything
(including p50/p95/p99 per histogram) into one JSON-able dict.

Metric name convention: dotted, ``<subsystem>.<object>.<event>`` —
see DESIGN.md §4 for the full table the runtime emits.

**Fleet aggregation** (PR 10): every metric merges *losslessly* from a
snapshot — counters add, gauges sum, histograms add per-bucket tallies (the
fixed bounds are the reason merge loses nothing; quantiles recompute from
the merged buckets). ``REGISTRY.absorb_snapshot(snap, source=...)`` folds a
remote process's snapshot (a pipe worker's response-info delta, or a socket
host's STATS reply) into a per-source store, and ``fleet_snapshot()``
returns the three-level view::

    {"local": <this process>, "remote": {"host:port/pid:N": snap, ...},
     "merged": <local + every remote, quantiles recomputed>}

so worker-only metrics (``worker.*``, a remote host's jit compiles) appear
in the merged view host/pid-labelled while staying absent from ``local``.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "DEFAULT_LATENCY_BUCKETS", "DEFAULT_BYTES_BUCKETS",
    "bounds_from_buckets", "snapshot_delta",
]


def _geometric(lo: float, hi: float, steps: Sequence[float]) -> Tuple[float, ...]:
    out, scale = [], lo
    while scale <= hi:
        out.extend(s * scale for s in steps if s * scale <= hi)
        scale *= 10.0
    return tuple(sorted(set(round(v, 12) for v in out)))


# Latency seconds: 10 µs … 60 s in 1/2.5/5 decade steps (FaaS invocations
# span cold-start seconds down to sub-millisecond warm pipe round-trips).
DEFAULT_LATENCY_BUCKETS = _geometric(1e-5, 10.0, (1.0, 2.5, 5.0)) + (30.0, 60.0)

# Payload/frame bytes: 64 B … 64 MiB in powers of 4 (the 6 MB Lambda budget
# sits inside the top decade).
DEFAULT_BYTES_BUCKETS = tuple(float(64 * 4 ** i) for i in range(11))


class _NullMetric:
    """Shared do-nothing metric handed out while the registry is disabled."""

    __slots__ = ()

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    def merge(self, snapshot) -> None:
        pass

    def quantile(self, q: float) -> float:
        return 0.0

    @property
    def value(self) -> float:
        return 0.0

    @property
    def count(self) -> int:
        return 0

    @property
    def sum(self) -> float:
        return 0.0


_NULL = _NullMetric()


def bounds_from_buckets(buckets: Dict[str, int]) -> Tuple[float, ...]:
    """Recover a histogram's finite bounds from a snapshot's bucket keys.

    Bucket keys are ``repr(bound)`` strings (plus ``"+inf"``), and
    ``float(repr(x)) == x`` for every finite float, so the round-trip is
    exact — a merged histogram rebuilt from a snapshot has bitwise-identical
    bounds to the one that produced it.
    """
    return tuple(sorted(float(k) for k in buckets if k != "+inf"))


def snapshot_delta(cur: Dict, prev: Optional[Dict]) -> Dict:
    """Lossless difference of two cumulative registry snapshots.

    ``cur - prev`` per metric: counters subtract, histogram count/sum and
    per-bucket tallies subtract, gauges pass through at their current value
    (a gauge is instantaneous — the "delta" of a last-write-wins value is
    the value). Metrics absent from ``prev`` pass through whole. This is
    what a pipe worker echoes in its response info: each echo carries only
    what happened since the previous one, so the client can absorb every
    response without double counting.
    """
    if not prev:
        return cur
    out: Dict = {"counters": {}, "gauges": dict(cur.get("gauges", {})),
                 "histograms": {}}
    pc = prev.get("counters", {})
    for name, v in cur.get("counters", {}).items():
        d = v - pc.get(name, 0)
        if d:
            out["counters"][name] = d
    ph = prev.get("histograms", {})
    for name, h in cur.get("histograms", {}).items():
        p = ph.get(name)
        if p is None:
            out["histograms"][name] = h
            continue
        dcount = h["count"] - p["count"]
        if dcount <= 0:
            continue
        pb = p.get("buckets", {})
        buckets = {k: c - pb.get(k, 0) for k, c in h["buckets"].items()}
        dh = {"count": dcount, "sum": h["sum"] - p["sum"],
              "buckets": buckets}
        out["histograms"][name] = dh
    return out


class Counter:
    """Monotonically increasing event count."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0  # guarded-by: _lock

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    def merge(self, snapshot_value: int) -> None:
        """Fold a remote counter's snapshot value in (lossless: counts add)."""
        with self._lock:
            self._value += int(snapshot_value)

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins instantaneous value.

    ``inc`` is a read-modify-write, so it takes a lock like Counter does —
    the original lock-free version lost updates whenever two transport
    threads bumped the same gauge concurrently.
    """

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0  # guarded-by: _lock

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    def merge(self, snapshot_value: float) -> None:
        """Fold a remote gauge in. Fleet semantics are *additive*: a gauge
        like pool occupancy or inflight count sums across processes into
        the fleet total (last-write-wins only applies within one process)."""
        with self._lock:
            self._value += float(snapshot_value)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket histogram with interpolated quantile extraction.

    ``buckets`` are increasing upper bounds; an implicit +inf bucket
    catches overflow. ``quantile(q)`` interpolates linearly inside the
    bucket containing rank ``q * count`` (lower edge 0 for the first
    bucket, Prometheus-style); observations past the last finite bound
    clamp to it, so quantiles never extrapolate beyond known bounds.
    """

    def __init__(self, name: str,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        if list(buckets) != sorted(buckets) or len(buckets) < 1:
            raise ValueError("histogram buckets must be increasing bounds")
        self.name = name
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in buckets)
        self._counts = [0] * (len(self.bounds) + 1)  # guarded-by: _lock
        self._lock = threading.Lock()
        self._sum = 0.0    # guarded-by: _lock
        self._count = 0    # guarded-by: _lock

    def observe(self, value: float) -> None:
        value = float(value)
        idx = len(self.bounds)
        for i, bound in enumerate(self.bounds):
            if value <= bound:
                idx = i
                break
        with self._lock:
            self._counts[idx] += 1
            self._sum += value
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def merge(self, snapshot: Dict) -> None:
        """Fold a remote histogram snapshot in — lossless by construction.

        ``snapshot`` is one ``snapshot()`` histogram entry (``count``,
        ``sum``, ``buckets``). Fixed bounds make the merge exact: per-bucket
        tallies (including ``+inf`` overflow) and the count/sum moments add,
        and quantiles recomputed from the merged buckets are identical to a
        single histogram that observed both streams. Bounds must match —
        a remote histogram with different bounds cannot merge losslessly,
        so that raises instead of silently re-binning.
        """
        buckets = snapshot["buckets"]
        if bounds_from_buckets(buckets) != self.bounds:
            raise ValueError(
                f"histogram {self.name!r}: snapshot bounds do not match "
                "(lossless merge requires identical buckets)")
        add = [buckets[repr(b)] for b in self.bounds]
        add.append(buckets.get("+inf", 0))
        with self._lock:
            for i, c in enumerate(add):
                self._counts[i] += int(c)
            self._sum += float(snapshot["sum"])
            self._count += int(snapshot["count"])

    def snapshot(self) -> Dict:
        """One registry-snapshot histogram entry — the unit :meth:`merge`
        consumes, so ``a.merge(b.snapshot())`` works on bare histograms."""
        return {"count": self.count, "sum": self.sum,
                "p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99), "buckets": self.bucket_counts()}

    def bucket_counts(self) -> Dict[str, int]:
        # Snapshot under the lock: reading _counts while observe() mutates
        # it could pair a bucket tally with a +inf tally from a different
        # instant, so the dump's buckets wouldn't sum to its count.
        with self._lock:
            counts = list(self._counts)
        out = {repr(b): c for b, c in zip(self.bounds, counts)}
        out["+inf"] = counts[-1]
        return out

    def quantile(self, q: float) -> Optional[float]:
        """Interpolated q-quantile (q in [0, 1]); None with no observations."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        with self._lock:
            total = self._count
            counts = list(self._counts)
        if total == 0:
            return None
        rank = q * total
        cum = 0
        for i, c in enumerate(counts[:-1]):
            if cum + c >= rank and c > 0:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i]
                return lo + (hi - lo) * (rank - cum) / c
            cum += c
        return self.bounds[-1]        # mass in the +inf bucket clamps


class MetricsRegistry:
    """Named metric store; disabled instances hand out the null singleton."""

    def __init__(self, enabled: bool = False):
        self._enabled = enabled
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}      # guarded-by: _lock
        self._gauges: Dict[str, Gauge] = {}          # guarded-by: _lock
        self._histograms: Dict[str, Histogram] = {}  # guarded-by: _lock
        # Per-source remote aggregates (fleet telemetry): one sub-registry
        # per "host:port/pid:N" label, fed by absorb_snapshot.
        self._remote: Dict[str, "MetricsRegistry"] = {}  # guarded-by: _lock

    # ------------------------------------------------------------- switches

    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    def reset(self) -> None:
        """Drop every metric, local and absorbed-remote (test isolation)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()
            self._remote.clear()

    # ------------------------------------------------------------ accessors

    def counter(self, name: str) -> Counter:
        if not self._enabled:
            return _NULL
        c = self._counters.get(name)  # squash: ignore[lock-guarded-access] -- lock-free hot-path read: dict.get is atomic under the GIL; a miss falls through to the locked setdefault
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        if not self._enabled:
            return _NULL
        g = self._gauges.get(name)  # squash: ignore[lock-guarded-access] -- lock-free hot-path read: dict.get is atomic under the GIL; a miss falls through to the locked setdefault
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        """Get-or-create; ``buckets`` only applies on first creation."""
        if not self._enabled:
            return _NULL
        h = self._histograms.get(name)  # squash: ignore[lock-guarded-access] -- lock-free hot-path read: dict.get is atomic under the GIL; a miss falls through to the locked setdefault
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(
                    name, Histogram(name, buckets or DEFAULT_LATENCY_BUCKETS))
        return h

    # ------------------------------------------------- fleet aggregation

    def merge_snapshot(self, snap: Dict, *, gauge_set: bool = False) -> None:
        """Fold one registry snapshot into *this* registry's metrics.

        Lossless per metric kind (see the individual ``merge`` docs);
        metrics the snapshot names that don't exist here yet are created —
        histograms with the bounds recovered from the snapshot's bucket
        keys, so the merge target never re-bins. ``gauge_set=True`` makes
        gauges last-write-wins instead of additive — used when absorbing
        repeated reports *from one source*, where each report carries the
        gauge's current value (adding them would inflate the aggregate).
        """
        if not self._enabled:
            return
        for name, v in (snap.get("counters") or {}).items():
            self.counter(name).merge(v)
        for name, v in (snap.get("gauges") or {}).items():
            g = self.gauge(name)
            g.set(v) if gauge_set else g.merge(v)
        for name, h in (snap.get("histograms") or {}).items():
            self.histogram(
                name, buckets=bounds_from_buckets(h["buckets"])).merge(h)

    def absorb_snapshot(self, snap: Dict, *, source: str,
                        replace: bool = False) -> None:
        """Fold a remote process's snapshot into the per-``source`` store.

        ``source`` labels where the numbers came from (``"pid:1234"`` for a
        pipe worker, ``"host:port/pid:N"`` for a socket host). With
        ``replace=False`` the snapshot is a *delta* (a pipe worker's
        response-info echo) and accumulates into the source's aggregate;
        with ``replace=True`` it is *cumulative* (a socket host's STATS
        reply — the host registry already holds the totals) and supersedes
        whatever this source reported before, so repeated pulls never
        double-count. No-op while disabled — absorbing telemetry is part of
        the obs layer's zero-cost-when-off contract.
        """
        if not self._enabled or not snap:
            return
        with self._lock:
            sub = self._remote.get(source)
            if sub is None or replace:
                sub = MetricsRegistry(enabled=True)
                self._remote[source] = sub
        sub.merge_snapshot(snap, gauge_set=True)

    def remote_sources(self) -> Tuple[str, ...]:
        """Labels of every absorbed remote source (sorted)."""
        with self._lock:
            return tuple(sorted(self._remote))

    def fleet_snapshot(self) -> Dict:
        """The merged, host/pid-labelled fleet view.

        ``local`` is this process's ``snapshot()``; ``remote`` maps each
        absorbed source label to its aggregate snapshot; ``merged`` folds
        local + every remote into one fresh registry and snapshots it — so
        merged histogram quantiles are recomputed from the *combined*
        buckets, not averaged from per-source quantiles.
        """
        local = self.snapshot()
        with self._lock:
            remote = dict(self._remote)
        remote_snaps = {src: sub.snapshot()
                        for src, sub in sorted(remote.items())}
        merged = MetricsRegistry(enabled=True)
        merged.merge_snapshot(local)
        for snap in remote_snaps.values():
            merged.merge_snapshot(snap)
        return {"local": local, "remote": remote_snaps,
                "merged": merged.snapshot()}

    # ------------------------------------------------------------- snapshot

    def snapshot(self) -> Dict:
        """JSON-able view of every metric, with p50/p95/p99 per histogram."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {n: c.value for n, c in sorted(counters.items())},
            "gauges": {n: g.value for n, g in sorted(gauges.items())},
            "histograms": {
                n: {
                    "count": h.count,
                    "sum": h.sum,
                    "p50": h.quantile(0.50),
                    "p95": h.quantile(0.95),
                    "p99": h.quantile(0.99),
                    "buckets": h.bucket_counts(),
                }
                for n, h in sorted(histograms.items())
            },
        }


# The process-global registry every instrumented module shares. Disabled by
# default: the importing hot paths stay no-ops until a runtime (or a test)
# flips it on.
REGISTRY = MetricsRegistry(enabled=False)
