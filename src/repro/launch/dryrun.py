"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) pair.

The FIRST TWO LINES request 512 XLA host devices — they must run before any
other import (jax locks device count on first init). Do NOT replicate this
flag anywhere global; smoke tests and benches see the single real device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out]

For each pair this lowers the right step function (train_step / prefill_step /
serve_step per DESIGN.md §5), compiles it for the production mesh, and
reports memory_analysis + cost_analysis + a collective-bytes breakdown parsed
from the compiled HLO — the inputs to EXPERIMENTS.md §Dry-run/§Roofline.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (INPUT_SHAPES, ArchConfig, InputShape,
                                get_config, list_configs)
from repro.launch.mesh import HW, batch_axes, make_production_mesh
from repro.launch import shardings as SH
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.train import make_train_step

__all__ = ["input_specs", "arch_for_shape", "lower_pair", "dryrun_pair",
           "collective_bytes", "run_all"]

# Pure full-attention archs get a documented sliding-window serving variant
# for long_500k (sub-quadratic rule, DESIGN.md §5); SSM/hybrid/local:global
# run natively.
LONG_WINDOW = 8192
_NATIVE_LONG = {"mamba2-370m", "zamba2-7b", "gemma3-4b"}


def arch_for_shape(name: str, shape: InputShape) -> ArchConfig:
    cfg = get_config(name)
    if shape.name == "long_500k" and name not in _NATIVE_LONG:
        cfg = dataclasses.replace(cfg, attention="sliding",
                                  sliding_window=LONG_WINDOW)
    return cfg


def _token_sds(cfg: ArchConfig, batch: int, seq: int):
    if cfg.num_codebooks:
        return jax.ShapeDtypeStruct((batch, cfg.num_codebooks, seq),
                                    jnp.int32)
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(cfg: ArchConfig, shape: InputShape,
                param_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.mode == "train":
        batch = {"tokens": _token_sds(cfg, b, s + 1)}
        if cfg.mrope:
            batch["embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vlm_num_patches, cfg.d_model), jnp.float32)
        return {"batch": batch}
    if shape.mode == "prefill":
        out = {"tokens": _token_sds(cfg, b, s)}
        if cfg.mrope:
            out["embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vlm_num_patches, cfg.d_model), jnp.float32)
        return out
    # decode: ONE new token against a seq_len cache
    caches = jax.eval_shape(
        lambda: T.init_decode_caches(cfg, b, s, dtype=param_dtype))
    return {"tokens": _token_sds(cfg, b, 1), "caches": caches,
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def _param_sds(cfg: ArchConfig, dtype):
    return jax.eval_shape(
        lambda k: T.init_params(k, cfg, dtype=dtype),
        jax.random.PRNGKey(0))


def lower_pair(name: str, shape_name: str, *, multi_pod: bool = False,
               mesh=None, param_dtype=jnp.bfloat16,
               remat: bool = True, accum_steps: int = 1,
               unroll: bool = False, cache_profile: str = "seq"):
    """Lower one (arch × shape) for the production mesh. Returns lowered."""
    shape = INPUT_SHAPES[shape_name]
    cfg = arch_for_shape(name, shape)
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    params = _param_sds(cfg, param_dtype)
    p_sh = SH.params_shardings(mesh, params)
    specs = input_specs(cfg, shape, param_dtype)

    if shape.mode == "train":
        # bf16 moments for the 480B giant (DESIGN.md §5), fp32 otherwise.
        state_dtype = jnp.bfloat16 if cfg.d_model >= 7168 else jnp.float32
        opt_cfg = AdamWConfig(state_dtype=state_dtype)
        opt = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params)
        o_sh = SH.opt_shardings(mesh, opt)
        b_sh = SH.batch_shardings(mesh, specs["batch"])
        step = make_train_step(cfg, opt_cfg, remat=remat,
                               accum_steps=accum_steps, unroll=unroll)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None))
        with mesh:
            return fn.lower(params, opt, specs["batch"]), cfg, mesh

    if shape.mode == "prefill":
        b_sh = SH.batch_shardings(mesh, specs)
        buf = shape.seq_len + (cfg.vlm_num_patches if cfg.mrope else 0)

        def prefill_step(params, inputs):
            return T.prefill(params, inputs["tokens"], cfg, buf_len=buf,
                             embeds=inputs.get("embeds"), unroll=unroll)

        fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
        with mesh:
            return fn.lower(params, specs), cfg, mesh

    # decode
    long_ctx = shape.name == "long_500k"
    c_sh = SH.cache_shardings(mesh, specs["caches"], long_context=long_ctx,
                              profile=cache_profile)
    t_sh = SH.batch_shardings(mesh, {"t": specs["tokens"]})["t"]

    def serve_step(params, tokens, caches, pos):
        return T.decode_step(params, tokens, caches, pos, cfg,
                             unroll=unroll)

    fn = jax.jit(serve_step,
                 in_shardings=(p_sh, t_sh, c_sh, NamedSharding(mesh, P())),
                 out_shardings=(None, c_sh))
    with mesh:
        return fn.lower(params, specs["tokens"], specs["caches"],
                        specs["pos"]), cfg, mesh


_HLO_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"(?:\()?([a-z][a-z0-9]*)\[([0-9,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[\w.\-]*\(")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum output-shape bytes of every collective op in compiled HLO.

    NOTE: collectives inside un-unrolled while loops are counted once —
    roofline runs use ``unroll=True`` so per-layer collectives all appear.
    """
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _HLO_OP_RE.match(line)
        if not m:
            continue
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        nel = int(np.prod([int(x) for x in dims.split(",") if x] or [1]))
        nbytes = nel * _DTYPE_BYTES.get(dt, 4)
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    total = sum(v["bytes"] for v in out.values())
    return {"per_op": out, "total_bytes": total}


def dryrun_pair(name: str, shape_name: str, *, multi_pod: bool = False,
                mesh=None, verbose: bool = True,
                **kw) -> Dict[str, Any]:
    t0 = time.time()
    lowered, cfg, mesh = lower_pair(name, shape_name, multi_pod=multi_pod,
                                    mesh=mesh, **kw)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    nchips = int(np.prod(list(mesh.shape.values())))
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    result = {
        "arch": name,
        "shape": shape_name,
        "mesh": dict(mesh.shape),
        "chips": nchips,
        "lower_s": round(t1 - t0, 1),
        "compile_s": round(t2 - t1, 1),
        "hlo_flops": flops,
        "hlo_bytes": bytes_hbm,
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0)
                           + getattr(mem, "argument_size_in_bytes", 0)),
        },
        # roofline terms (seconds) — single-chip share of the global work
        "t_compute": flops / (nchips * HW.PEAK_BF16_FLOPS),
        "t_memory": bytes_hbm / (nchips * HW.HBM_BW),
        "t_collective": coll["total_bytes"] / (nchips * HW.ICI_BW),
    }
    terms = {k: result[k] for k in ("t_compute", "t_memory", "t_collective")}
    result["bottleneck"] = max(terms, key=terms.get)
    if verbose:
        print(f"[dryrun] {name} × {shape_name} mesh={tuple(mesh.shape.values())} "
              f"lower={result['lower_s']}s compile={result['compile_s']}s")
        print(f"  FLOPs={flops:.3e}  bytes={bytes_hbm:.3e}  "
              f"coll={coll['total_bytes']:.3e}B")
        print(f"  t_comp={result['t_compute']*1e3:.2f}ms  "
              f"t_mem={result['t_memory']*1e3:.2f}ms  "
              f"t_coll={result['t_collective']*1e3:.2f}ms  "
              f"→ {result['bottleneck']}")
    return result


def run_all(archs=None, shapes=None, *, multi_pod: bool = False,
            json_path: Optional[str] = None, unroll: bool = False,
            cache_profile: str = "seq") -> list:
    archs = archs or list_configs()
    shapes = shapes or list(INPUT_SHAPES)
    mesh = make_production_mesh(multi_pod=multi_pod)
    results = []
    for a in archs:
        for s in shapes:
            try:
                results.append(dryrun_pair(a, s, mesh=mesh, unroll=unroll,
                                           cache_profile=cache_profile))
            except Exception as e:  # a failure here is a bug in the system
                print(f"[dryrun] FAILED {a} × {s}: {type(e).__name__}: {e}")
                results.append({"arch": a, "shape": s, "error": str(e)})
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=1, default=float)
    ok = sum(1 for r in results if "error" not in r)
    print(f"[dryrun] {ok}/{len(results)} pairs compiled OK")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="fully unroll layer scans (accurate cost_analysis)")
    ap.add_argument("--cache-profile", default="seq",
                    choices=["seq", "tp", "dp-cache"],
                    help="decode KV-cache layout (seq = flash-decoding "
                         "default, adopted in EXPERIMENTS.md §Perf B-3)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    if args.all:
        res = run_all(multi_pod=args.multi_pod, json_path=args.json,
                      unroll=args.unroll, cache_profile=args.cache_profile)
        return 0 if all("error" not in r for r in res) else 1
    res = dryrun_pair(args.arch, args.shape or "train_4k",
                      multi_pod=args.multi_pod, unroll=args.unroll,
                      cache_profile=args.cache_profile)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1, default=float)
    return 0


if __name__ == "__main__":
    sys.exit(main())
