"""Production mesh builders (DESIGN.md §6).

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import).

Single pod : (16, 16)      axes ("data", "model")   — 256 chips (v5e pod)
Multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") — 512 chips
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "batch_axes",
           "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


class HW:
    """TPU v5e hardware constants for the roofline (per chip)."""
    PEAK_BF16_FLOPS = 197e12        # FLOP/s
    HBM_BW = 819e9                  # B/s
    ICI_BW = 50e9                   # B/s per link
    HBM_BYTES = 16 * 2 ** 30        # 16 GiB
