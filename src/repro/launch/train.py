"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --steps 20 \\
      [--reduced] [--batch 8] [--seq 128] [--accum 1] [--ckpt DIR]

On this CPU container use ``--reduced`` (2-layer variant). On a real pod the
same entry point runs the full config sharded over ``make_production_mesh()``
(params/optimizer/batch shardings from ``launch.shardings``); the dry-run
(launch/dryrun.py) is the no-hardware proof of that path.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs.base import get_config
from repro.data.synthetic import token_batch
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init, cosine_schedule
from repro.train import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer smoke variant (CPU)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name}: {n / 1e6:.1f}M params, "
          f"{len(jax.devices())} device(s)")
    opt_cfg = AdamWConfig(lr=args.lr)
    state = adamw_init(params, opt_cfg)
    sched = cosine_schedule(args.lr, warmup=max(args.steps // 10, 1),
                            total=args.steps)
    step = jax.jit(make_train_step(cfg, opt_cfg, sched,
                                   accum_steps=args.accum))
    t0 = time.time()
    for i in range(args.steps):
        toks = token_batch(args.batch, args.seq + 1, cfg.vocab_size,
                           seed=i)
        if cfg.num_codebooks:
            toks = np.broadcast_to(
                toks[:, None, :], (args.batch, cfg.num_codebooks,
                                   args.seq + 1)).copy()
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.mrope:
            batch["embeds"] = jnp.zeros(
                (args.batch, cfg.vlm_num_patches, cfg.d_model), jnp.float32)
        params, state, m = step(params, state, batch)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"  step {i:5d} loss {float(m['loss']):.4f} "
                  f"|g| {float(m['grad_norm']):.2f} "
                  f"lr {float(m['lr']):.2e}")
    dt = time.time() - t0
    print(f"[train] {args.steps} steps in {dt:.1f}s "
          f"({args.batch * args.seq * args.steps / dt:.0f} tok/s)")
    if args.ckpt:
        path = save_pytree({"params": params, "opt": state}, args.ckpt,
                           name=cfg.name)
        print(f"[train] checkpoint → {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
