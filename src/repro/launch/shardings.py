"""GSPMD sharding profiles for params, optimizer state, batches, KV caches.

Rules are name-based over pytree paths (DESIGN.md §6):

  * projections whose OUTPUT grows (wq/wk/wv/gate/up/router/in_proj/w_dkv/
    w_uk/w_uv/lm_head/cb_head): d_out over ``model``, d_in over ``data``
    (tensor-parallel + FSDP — the "2-D sharded" megatron layout).
  * projections whose INPUT grows (wo/down/out_proj): d_in over ``model``,
    d_out over ``data``.
  * expert stacks (E, ·, ·): E over ``model`` (expert parallelism), second
    dim over ``data``.
  * embeddings (V, d): vocab over ``model``.
  * 1-D leaves (norm scales, A_log, D, dt_bias, conv) replicated.
  * leading layer-stack axes are always unsharded (scanned over).

Optimizer moments inherit the param spec (ZeRO-style: same shards hold the
same slice of param + m + v). Batches shard the leading dim over
``("pod",) data``. KV caches shard batch over data and heads over model —
except ``long_context`` (batch = 1), where the *sequence* axis takes the
data dimension (sequence parallelism).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes

__all__ = ["param_pspec", "params_shardings", "opt_shardings",
           "batch_shardings", "cache_shardings", "tree_pspecs"]

_OUT_GROWS = {"wq", "wk", "wv", "gate", "up", "router", "in_z", "in_xbc",
              "in_dt", "w_dkv", "w_uk", "w_uv", "lm_head", "cb_head",
              "table"}
_IN_GROWS = {"wo", "down", "out_proj"}


def _path_names(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return out


def fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop axis assignments that do not divide the corresponding dim.

    Explicit pjit in_shardings require exact divisibility; rule-derived specs
    fall back to replication on any dim where the mesh axis doesn't fit
    (e.g. mamba2's vocab 50280 % 16, MQA kv = 1, batch = 1 decode).
    """
    out = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(ax if shape[i] % size == 0 else None)
    return P(*out)


def param_pspec(path, leaf) -> P:
    names = _path_names(path)
    ndim = leaf.ndim
    tagged = [n for n in names if n in _OUT_GROWS | _IN_GROWS | {"experts"}]
    name = names[-1] if names else ""
    if ndim <= 1:
        return P()
    if "experts" in names:
        # (..., E, d_in, d_out): experts over model, middle over data.
        return P(*([None] * (ndim - 3)), "model", "data", None)
    if name == "w" and len(names) >= 2:
        name = names[-2]
    if name == "table":  # embeddings (…, V, d) — vocab over model
        return P(*([None] * (ndim - 2)), "model", None)
    if name in _OUT_GROWS:
        return P(*([None] * (ndim - 2)), "data", "model")
    if name in _IN_GROWS:
        return P(*([None] * (ndim - 2)), "model", "data")
    if ndim >= 2 and name == "conv_w":
        return P()
    return P()


def tree_pspecs(tree, spec_fn) -> Any:
    return jax.tree_util.tree_map_with_path(spec_fn, tree)


def params_shardings(mesh: Mesh, params) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(
            mesh, fit_spec(param_pspec(p, l), l.shape, mesh)), params)


def opt_shardings(mesh: Mesh, opt_state) -> Any:
    """m/v inherit param specs; step is replicated."""
    def spec(path, leaf):
        names = _path_names(path)
        if names and names[0] == "step":
            return NamedSharding(mesh, P())
        # strip the leading "m"/"v" key so param rules apply
        return NamedSharding(
            mesh, fit_spec(param_pspec(path[1:], leaf), leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(spec, opt_state)


def batch_shardings(mesh: Mesh, batch) -> Any:
    ba = batch_axes(mesh)
    def spec(path, leaf):
        ps = P(ba, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, fit_spec(ps, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_shardings(mesh: Mesh, caches, *, long_context: bool = False,
                    profile: str = "tp") -> Any:
    """Cache leaves carry leading stack dims, then (B, buf, …).

    Identified by name: k/v (B, buf, kv, hd); latent/k_rope (B, buf, r);
    conv (B, k, C); state (B, H, P, N). Leading stack dims (scan axes) are
    counted as ndim − base_rank.

    ``profile``:
      "tp"       — batch over data, heads (or head_dim) over model.
      "dp-cache" — batch over data ONLY; the cache is replicated across the
                   model axis so per-step attention needs no cache
                   resharding (params stay model-sharded and are gathered
                   per layer instead). EXPERIMENTS.md §Perf, decode
                   iteration.
      "seq"      — flash-decoding layout: batch over data, the cache BUFFER
                   over model. The (tiny) query visits every buffer shard;
                   the (huge) cache never moves — softmax reductions cross
                   shards instead of cache bytes.
    """
    ba = batch_axes(mesh)
    dp = profile == "dp-cache"
    # long_500k (batch = 1) already sequence-shards the buffer over data;
    # the seq profile is a decode_32k layout.
    seq = profile == "seq" and not long_context

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        nd = leaf.ndim
        if name in ("k", "v"):
            lead = nd - 4
            # MQA / small-GQA: if kv heads don't divide the model axis,
            # put the model axis on head_dim instead.
            hd_axis = leaf.shape[-2] % mesh.shape["model"] != 0
            kv_s = None if (hd_axis or dp or seq) else "model"
            hd_s = "model" if (hd_axis and not dp and not seq) else None
            buf_s = "model" if seq else None
            if long_context:
                s = P(*([None] * lead), None, ba, kv_s, hd_s)
            else:
                s = P(*([None] * lead), ba, buf_s, kv_s, hd_s)
        elif name in ("latent", "k_rope"):
            lead = nd - 3
            r_s = None if (dp or seq) else "model"
            buf_s = "model" if seq else None
            if long_context:
                s = P(*([None] * lead), None, ba, r_s)
            else:
                s = P(*([None] * lead), ba, buf_s, r_s)
        elif name == "state":  # (…, B, H, P, N)
            lead = nd - 4
            s = P(*([None] * lead), None if long_context else ba,
                  "model", None, None)
        elif name == "conv":   # (…, B, k, C)
            lead = nd - 3
            s = P(*([None] * lead), None if long_context else ba,
                  None, "model")
        else:
            s = P()
        return NamedSharding(mesh, fit_spec(s, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, caches)
