"""Serving launcher: batched generation through the engine.

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \\
      --reduced --requests 8 --prompt-len 32 --new-tokens 16 [--kv-bits 8]

Runs batched requests through prefill + greedy decode (optionally with the
OSQ-quantized KV cache) and reports per-phase latency and tokens/s. On a
real pod the decode step runs under the ``seq`` flash-decoding cache layout
verified in launch/dryrun.py.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serve import Engine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--kv-bits", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(
        max_new_tokens=args.new_tokens, kv_bits=args.kv_bits,
        temperature=args.temperature))
    rng = np.random.default_rng(0)
    if cfg.num_codebooks:
        prompts = rng.integers(0, cfg.vocab_size,
                               (args.requests, cfg.num_codebooks,
                                args.prompt_len), dtype=np.int32)
    else:
        prompts = rng.integers(0, cfg.vocab_size,
                               (args.requests, args.prompt_len),
                               dtype=np.int32)
    embeds = (rng.normal(size=(args.requests, cfg.vlm_num_patches,
                               cfg.d_model)).astype(np.float32)
              if cfg.mrope else None)
    t0 = time.time()
    out = eng.generate(prompts, embeds=embeds)
    dt = time.time() - t0
    total_new = out.size
    print(f"[serve] {cfg.name}: {args.requests} requests × "
          f"{args.new_tokens} tokens in {dt:.2f}s "
          f"({total_new / dt:.0f} tok/s, kv_bits={args.kv_bits or 'fp'})")
    print(f"[serve] sample continuation: {out.reshape(out.shape[0], -1)[0][:12].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
