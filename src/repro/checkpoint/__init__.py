"""Checkpoint substrate: per-shard npz + manifest save/restore."""

from repro.checkpoint.store import save_pytree, restore_pytree

__all__ = ["save_pytree", "restore_pytree"]
