"""Checkpointing: per-shard .npz + JSON manifest for arbitrary pytrees.

Leaves are flattened with path-derived keys; restore rebuilds the exact
pytree. Device arrays round-trip through host numpy (the container has one
device; on a real pod each host writes its addressable shards — the manifest
records the global treedef so restore is layout-independent).
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_pytree", "restore_pytree"]


def _key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(tree, directory: str, name: str = "ckpt") -> str:
    os.makedirs(directory, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    manifest = {"order": [], "treedef": str(treedef)}
    for i, (path, leaf) in enumerate(flat):
        k = f"{i:05d}__{_key(path)}"
        arrays[k] = np.asarray(leaf)
        manifest["order"].append(k)
    np.savez(os.path.join(directory, f"{name}.npz"), **arrays)
    with open(os.path.join(directory, f"{name}.json"), "w") as f:
        json.dump(manifest, f)
    return os.path.join(directory, f"{name}.npz")


def restore_pytree(template, directory: str, name: str = "ckpt"):
    """Restore into the structure of ``template`` (shapes must match)."""
    with open(os.path.join(directory, f"{name}.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, f"{name}.npz"))
    leaves = [jnp.asarray(data[k]) for k in manifest["order"]]
    treedef = jax.tree_util.tree_structure(template)
    return treedef.unflatten(leaves)
