"""TCP worker host for the socket transport (``python -m repro.serverless.host``).

One host process serves any number of tree-node *functions*: each accepted
connection is one long-lived worker — the client's
:class:`~repro.serverless.socket_transport.SocketTransport` opens one
connection per worker slot and deploys it with an INIT frame (the pickled
:class:`~repro.serverless.workers.WorkerInit`, the analogue of the S3 code
package). After the deploy-ack every request is served by the *same*
:class:`~repro.serverless.workers.RequestServer` the pipe-backed
ProcessTransport workers run, so warm starts, fetch timing and derived-state
retention are reported identically whether the worker lives behind a pipe or
a TCP link — and a dropped connection loses the retained singleton exactly
like a reclaimed container.

Per connection, two threads split the work so the hang guard stays honest:

* the **receiver** thread owns the socket's read side. It answers PING
  frames with PONG *immediately* — even while a request is executing — so
  the client can tell "worker busy computing" (PONGs keep flowing) from
  "link dead" (silence); it answers STATS frames the same way, with the
  host process's cumulative metrics-registry snapshot (fleet telemetry);
* the **compute** thread drains a local queue of decoded requests, runs
  :meth:`RequestServer.handle`, and writes RESP frames back. Oversized
  responses paginate into budget-sized pages (``seq``/``nseq``) rather than
  violating the per-frame cap.

The CLI prints ``LISTENING <port>`` once bound (port 0 picks a free one),
so a remote launcher — or a test spawning a genuinely separate server
process — can scrape the port and pass ``host:port`` to the client.
"""

from __future__ import annotations

import argparse
import os
import queue
import socket
import threading
from typing import Callable, Optional, Tuple

import numpy as np

from repro.obs.metrics import REGISTRY as _METRICS
from repro.serverless import payload as pl
from repro.serverless import workers as wk

__all__ = ["serve", "main"]


def _compute_loop(conn, send_lock: threading.Lock, jobs: "queue.Queue",
                  server: wk.RequestServer, max_bytes: int) -> None:
    """Serve queued requests; one RESP frame per response page."""
    while True:
        job = jobs.get()
        if job is None:
            return
        rid, payload, extra = job
        ok, data, info = server.handle(payload, extra)
        if not ok:
            data = data.encode("utf-8")       # formatted traceback
        pages = [data[i:i + max_bytes]
                 for i in range(0, len(data), max_bytes)] or [b""]
        try:
            for seq, page in enumerate(pages):
                body = pl.encode_message({
                    "rid": rid, "ok": ok, "seq": seq, "nseq": len(pages),
                    "info": info,
                    "data": np.frombuffer(page, dtype=np.uint8),
                })
                with send_lock:
                    pl.write_frame(conn, pl.FRAME_RESP, body,
                                   max_bytes=max_bytes + pl.FRAME_SLACK)
        except (OSError, ConnectionError):
            # Client went away; this worker dies with the connection (the
            # transport's reconnect path deploys a fresh one). Counted so a
            # fleet silently shedding workers shows up in the metrics dump.
            _METRICS.counter("transport.host.swallowed_errors").inc()
            return


def _serve_connection(conn: socket.socket) -> None:
    """Receiver loop for one worker connection (see module docstring)."""
    try:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    except OSError:
        pass
    send_lock = threading.Lock()
    jobs: "queue.Queue" = queue.Queue()
    try:
        while True:
            try:
                kind, body = pl.read_frame(conn)
            except (ConnectionError, OSError):
                break
            if kind == pl.FRAME_INIT:
                init, max_bytes = pl.decode_init(body)
                wk.configure_jax(init)
                server = wk.RequestServer(init)
                threading.Thread(
                    target=_compute_loop,
                    args=(conn, send_lock, jobs, server, max_bytes),
                    daemon=True,
                    name=f"squash-host-compute-{init.fn.replace(':', '-')}",
                ).start()
                with send_lock:               # deploy ack: function is live
                    pl.write_frame(conn, pl.FRAME_PONG)
            elif kind == pl.FRAME_PING:
                with send_lock:
                    pl.write_frame(conn, pl.FRAME_PONG)
            elif kind == pl.FRAME_STATS:
                # Fleet-telemetry pull: dump this process's cumulative
                # registry (every RequestServer in this host shares it).
                # Answered from the receiver thread like PONG, so a busy
                # compute thread never delays the fleet snapshot.
                body = pl.encode_message({
                    "os_pid": os.getpid(),
                    "snapshot": _METRICS.snapshot(),
                })
                with send_lock:
                    pl.write_frame(conn, pl.FRAME_STATS, body)
            elif kind == pl.FRAME_REQ:
                msg = pl.decode_message(body)
                jobs.put((int(msg["rid"]), msg["payload"].tobytes(),
                          msg.get("extra") or {}))
            elif kind == pl.FRAME_SHUTDOWN:
                break
    finally:
        jobs.put(None)                        # stop the compute thread
        try:
            conn.close()
        except OSError:
            pass


def serve(address: Tuple[str, int], *,
          ready: Optional[Callable[[int], None]] = None) -> None:
    """Listen on ``address`` and serve worker connections forever.

    ``ready(port)`` fires once the socket is bound (with the *actual* port —
    callers may bind port 0), before the first ``accept``.
    """
    srv = socket.create_server(address)
    if ready is not None:
        ready(srv.getsockname()[1])
    while True:
        try:
            conn, _ = srv.accept()
        except OSError:                       # listening socket closed
            break
        threading.Thread(target=_serve_connection, args=(conn,),
                         daemon=True, name="squash-host-conn").start()


def _spawned_main(port_conn, port: int = 0) -> None:
    """Entry for auto-spawned loopback hosts: report the bound port, serve."""

    def ready(bound: int) -> None:
        port_conn.send(bound)
        port_conn.close()

    serve(("127.0.0.1", port), ready=ready)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serverless.host",
        description="Serve SQUASH tree-node workers over TCP.")
    ap.add_argument("--listen", default="127.0.0.1:0", metavar="HOST:PORT",
                    help="bind address (port 0 picks a free port; the bound "
                         "port is printed as 'LISTENING <port>')")
    args = ap.parse_args(argv)
    hostname, _, port = args.listen.rpartition(":")

    def ready(bound: int) -> None:
        print(f"LISTENING {bound}", flush=True)

    serve((hostname or "127.0.0.1", int(port)), ready=ready)


if __name__ == "__main__":
    main()
