"""TCP-backed invocation transport: the QA/QP fleet off one box (§4).

``SocketTransport`` is the third :class:`~repro.serverless.transport.Transport`
backend. The choreography in ``runtime.py`` is untouched by construction —
it speaks the same ``submit(fn, payload, extra) → Invocation`` contract —
but every function worker now lives behind a TCP connection to a
``repro.serverless.host`` process, possibly on another machine:

* **Deployment**: one connection per worker slot. Connecting sends an INIT
  frame carrying the pickled :class:`~repro.serverless.workers.WorkerInit`
  (the S3-code-package analogue, budget-exempt); the host's PONG ack means
  the function is live. Worker slots round-robin across the host list, so
  ``hosts=("10.0.0.5:7070", "10.0.0.6:7070")`` genuinely spreads the fleet.
  With no ``hosts`` given, loopback host processes are auto-spawned — the
  zero-config default that still exercises the full wire path.
* **Budget**: request payloads are capped at the 6 MB synchronous-invocation
  budget at ``submit`` *and* per frame at the socket layer
  (:func:`~repro.serverless.payload.write_frame`); oversized responses
  paginate host-side into budget-sized RESP pages reassembled here.
* **Crash/retry**: connection loss is the socket-era worker crash. The read
  loop detects EOF/reset; a monitor thread PINGs every link and declares a
  link dead only when it has in-flight work *and* has gone silent past the
  heartbeat window — a busy worker keeps answering PONG from its receiver
  thread, so long compute never masquerades as a dead link. A failed link
  reconnects with exponential backoff (respawning its host process first if
  this transport owns it and it died), and in-flight invocations are re-sent
  under the same ``max_retries`` budget ProcessTransport applies — ids and
  ``SearchStats`` stay bitwise-identical across the retry.

Counter discipline matches the repaired ProcessTransport exactly: a
timed-out invocation rebalances its link's ``assigned`` and parks its rid in
``_timed_out`` so a late page cannot re-book ``done``.
"""

from __future__ import annotations

import itertools
import multiprocessing as mp
import socket
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import DEFAULT_BYTES_BUCKETS
from repro.obs.metrics import REGISTRY as _METRICS
from repro.serverless import payload as pl
from repro.serverless import transport as tr
from repro.serverless import workers as wk

__all__ = ["SocketTransport"]


def _parse_host(spec: str) -> Tuple[str, int]:
    hostname, _, port = spec.rpartition(":")
    if not hostname or not port:
        raise ValueError(f"host spec {spec!r} is not 'host:port'")
    return hostname, int(port)


class _LocalHostHandle:
    """One auto-spawned loopback host process (respawnable at its port)."""

    def __init__(self, ctx):
        self._ctx = ctx
        self._lock = threading.Lock()
        self.proc = None
        self.address: Optional[Tuple[str, int]] = None

    def spawn(self) -> Tuple[str, int]:
        # Imported here (not at module load) so `python -m
        # repro.serverless.host` doesn't see the module pre-imported by the
        # package and warn about double execution.
        from repro.serverless import host as host_mod

        parent, child = self._ctx.Pipe(duplex=False)
        port = 0 if self.address is None else self.address[1]
        self.proc = self._ctx.Process(
            target=host_mod._spawned_main, args=(child, port),
            daemon=True, name="squash-host")
        self.proc.start()
        child.close()
        deadline = time.monotonic() + 60.0
        while not parent.poll(0.1):
            if not self.proc.is_alive():
                raise ConnectionError(
                    "spawned host died before reporting its port")
            if time.monotonic() > deadline:
                raise ConnectionError("spawned host never reported its port")
        port = parent.recv()  # squash: ignore[wire-raw-socket] -- mp pipe Connection.recv (the spawned host's port report), not a TCP socket; no payload bytes travel here
        parent.close()
        self.address = ("127.0.0.1", port)
        return self.address

    def ensure_alive(self) -> None:
        """Respawn (at the same port) if the host process died."""
        with self._lock:
            if self.proc is None or not self.proc.is_alive():
                self.spawn()

    def terminate(self) -> None:
        if self.proc is not None and self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=1.0)


class _Link:
    """One worker slot: a function deployed over one TCP connection.

    Unlike a ``_Worker`` (whose identity dies with its process), a link
    survives reconnects — ``generation`` counts them, so stale read loops
    and racing failure detectors cannot double-handle one loss. The
    retained singleton does *not* survive: a fresh connection is a fresh
    ``RequestServer``, i.e. a cold container, exactly as a crash should be.
    """

    def __init__(self, fn: str, init: wk.WorkerInit,
                 address: Tuple[str, int],
                 owner: Optional[_LocalHostHandle] = None):
        self.fn = fn
        self.init = init
        self.address = address
        self.owner = owner
        self.sock: Optional[socket.socket] = None
        self.generation = 0          # guarded-by: _lock
        self.assigned = 0            # guarded-by: _lock -- routed (sent or queued)
        self.done = 0                # guarded-by: _lock -- responses received
        self.dead = False            # guarded-by: _lock
        self.send_lock = threading.Lock()
        self.up = threading.Event()  # connection established + deploy-acked
        self.last_seen = time.perf_counter()   # last frame received
        self.pages: Dict[int, List[Optional[bytes]]] = {}  # guarded-by: _lock
        self.stats_event = threading.Event()   # a STATS reply landed
        self.stats_reply: Optional[Dict] = None  # last decoded STATS body

    @property
    def inflight(self) -> int:  # squash: holds[_lock]
        return self.assigned - self.done

    @property
    def host(self) -> str:
        return f"{self.address[0]}:{self.address[1]}"


class _SocketInvocation(tr._ProcessInvocation):
    """Same await/timeout/rebalance semantics; adds the serving host."""

    def result(self):
        resp, info = super().result()
        link = self._pending.worker  # squash: ignore[lock-guarded-access] -- name collision: this _pending is the invocation's own _Pending object (bound once at construction), not the transport's guarded dict; worker is read post-resolution
        if link is not None:
            info.host = link.host
        return resp, info


class SocketTransport(tr.Transport):
    """TCP worker-fleet backend (see module docstring)."""

    kind = "socket"

    def __init__(
        self,
        inits: Dict[str, Tuple[wk.WorkerInit, int]],
        *,
        hosts: Optional[Tuple[str, ...]] = None,
        auto_hosts: int = 2,
        eager: bool = True,
        start_method: str = "spawn",
        invoke_timeout_s: float = 180.0,
        max_retries: int = 2,
        max_payload_bytes: int = pl.MAX_SYNC_PAYLOAD_BYTES,
        heartbeat_s: float = 0.25,
        heartbeat_misses: int = 8,
        connect_timeout_s: float = 60.0,
    ):
        self._ctx = mp.get_context(start_method)
        self.eager = eager
        self.invoke_timeout_s = invoke_timeout_s
        self.max_retries = max_retries
        self.max_payload_bytes = max_payload_bytes
        self.heartbeat_s = heartbeat_s
        self.heartbeat_misses = heartbeat_misses
        self.connect_timeout_s = connect_timeout_s
        self._rid = itertools.count()
        self._lock = threading.Lock()
        self._pending: Dict[int, tr._Pending] = {}  # guarded-by: _lock
        self._timed_out: Dict[int, _Link] = {}      # guarded-by: _lock
        self._closed = False                        # guarded-by: _lock
        self._owned_hosts: List[_LocalHostHandle] = []
        if hosts:
            addresses = [_parse_host(h) for h in hosts]
            owners: List[Optional[_LocalHostHandle]] = [None] * len(addresses)
        else:
            self._owned_hosts = [_LocalHostHandle(self._ctx)
                                 for _ in range(max(1, int(auto_hosts)))]
            addresses = [h.spawn() for h in self._owned_hosts]
            owners = list(self._owned_hosts)
        slot = itertools.count()
        self._links: Dict[str, List[_Link]] = {}
        for fn, (init, count) in inits.items():
            self._links[fn] = []
            for _ in range(count):
                i = next(slot) % len(addresses)
                self._links[fn].append(
                    _Link(fn, init, addresses[i], owner=owners[i]))
        self._deploy_all()
        self._monitor = threading.Thread(
            target=self._monitor_loop, daemon=True,
            name="squash-socket-monitor")
        self._monitor.start()

    # ------------------------------------------------------------ deployment

    def _deploy_all(self) -> None:
        """Connect + INIT every link concurrently (one deploy per slot)."""
        errors: List[Exception] = []

        def go(link: _Link) -> None:
            try:
                self._connect(link)
            except Exception as exc:             # noqa: BLE001
                errors.append(exc)

        threads = [threading.Thread(target=go, args=(link,), daemon=True)
                   for links in self._links.values() for link in links]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            self.close()
            raise tr.TransportError(
                f"socket transport failed to deploy: {errors[0]}")

    def _connect(self, link: _Link) -> None:
        """Dial, deploy (INIT → PONG ack), install the socket, start reading."""
        sock = socket.create_connection(link.address,
                                        timeout=self.connect_timeout_s)
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            pl.write_frame(sock, pl.FRAME_INIT,
                           pl.encode_init(link.init, self.max_payload_bytes))
            kind, _ = pl.read_frame(sock)        # honors the connect timeout
            if kind != pl.FRAME_PONG:
                raise ConnectionError(
                    f"host {link.host} sent {kind!r} instead of a deploy ack")
            sock.settimeout(None)
        except BaseException:
            sock.close()
            raise
        with self._lock:
            if self._closed:
                sock.close()
                raise ConnectionError("transport closed during connect")
            link.sock = sock
            link.last_seen = time.perf_counter()
            gen = link.generation
            link.up.set()
        threading.Thread(
            target=self._read_loop, args=(link, gen, sock), daemon=True,
            name=f"squash-sock-read-{link.fn.replace(':', '-')}").start()

    # ------------------------------------------------------------ submission

    def submit(self, fn, *, request=None, payload=None, extra=None):
        if payload is None:
            payload = pl.encode_message(request)
        if len(payload) > self.max_payload_bytes:
            raise pl.PayloadOverflowError(
                f"invocation payload of {len(payload)} B exceeds the "
                f"{self.max_payload_bytes} B budget")
        _METRICS.counter("transport.socket.submits").inc()
        _METRICS.histogram("transport.socket.request_bytes",
                           buckets=DEFAULT_BYTES_BUCKETS).observe(len(payload))
        pending = tr._Pending(next(self._rid), fn, payload, dict(extra or {}))
        with self._lock:
            if self._closed:
                raise tr.TransportError("transport is closed")
            link = self._pick(fn)
            predicted_warm = link.assigned > 0 or link.done > 0
            pending.worker = link
            link.assigned += 1
            self._pending[pending.rid] = pending
        if self.eager:
            self._send(pending)
        return _SocketInvocation(self, pending, predicted_warm)

    def _pick(self, fn: str) -> _Link:  # squash: holds[_lock]
        if fn not in self._links:
            raise tr.TransportError(f"no worker links for function {fn!r}")
        pool = [link for link in self._links[fn] if not link.dead]
        if not pool:
            raise tr.TransportError(
                f"no live link for {fn!r} (reconnect budget exhausted)")
        return min(pool, key=lambda link: (link.inflight, link.assigned))

    def _send(self, pending: tr._Pending) -> None:
        """Deliver a pending request, waiting out reconnects of its link."""
        while not pending.resolved and not pending.sent:
            link = pending.worker
            if link.dead:  # squash: ignore[lock-guarded-access] -- lock-free fast-path read; the locked failure path already failed/parked this pending, so a stale False only costs one extra loop
                return
            if not link.up.wait(0.1):
                continue             # reconnect in progress
            sock = link.sock
            if sock is None:
                continue
            body = pl.encode_message({
                "rid": pending.rid, "extra": pending.extra,
                "payload": np.frombuffer(pending.payload, dtype=np.uint8),
            })
            try:
                with link.send_lock:
                    pl.write_frame(sock, pl.FRAME_REQ, body,
                                   max_bytes=self.max_payload_bytes
                                   + pl.FRAME_SLACK)
                _METRICS.histogram(
                    "transport.socket.frame_bytes",
                    buckets=DEFAULT_BYTES_BUCKETS).observe(len(body))
                # Mark sent under the transport lock, re-checking that the
                # connection we wrote to is still current: if the link
                # failed between write_frame and here, the failure handler
                # has already decided this pending's fate (resend with
                # sent=False) and marking it sent now would strand it —
                # the reconnect path only re-sends what it saw as sent.
                with self._lock:
                    if link.sock is sock and not link.dead:
                        pending.sent = True
                        pending.t_sent = time.perf_counter()
            except (OSError, ConnectionError):
                self._on_link_failure(link, link.generation)  # squash: ignore[lock-guarded-access] -- generation snapshot read: a stale value makes _on_link_failure a no-op by design (another thread already handled this loss)

    # ------------------------------------------------------------ collection

    def _read_loop(self, link: _Link, gen: int, sock: socket.socket) -> None:
        try:
            while True:
                kind, body = pl.read_frame(sock)
                link.last_seen = time.perf_counter()
                if kind == pl.FRAME_RESP:
                    _METRICS.histogram(
                        "transport.socket.frame_bytes",
                        buckets=DEFAULT_BYTES_BUCKETS).observe(len(body))
                    self._on_response(link, body)
                elif kind == pl.FRAME_STATS:
                    # Fleet-telemetry reply: stash for collect_metrics().
                    link.stats_reply = pl.decode_message(body)
                    link.stats_event.set()
                # PONG (and anything else) only refreshes liveness
        except (OSError, ConnectionError, ValueError):
            self._on_link_failure(link, gen)

    def _on_response(self, link: _Link, body: bytes) -> None:
        msg = pl.decode_message(body)
        rid = int(msg["rid"])
        nseq = int(msg["nseq"])
        data = msg["data"].tobytes()
        ok = bool(msg["ok"])
        winfo = msg["info"]
        with self._lock:
            if nseq > 1:                      # paginated response: reassemble
                # Under _lock: _on_link_failure clears link.pages (also
                # under _lock) when the connection drops, and unlocked
                # reassembly raced it — a clear between setdefault and the
                # final del left a KeyError that killed the read thread.
                pages = link.pages.setdefault(rid, [None] * nseq)
                pages[int(msg["seq"])] = data
                if any(p is None for p in pages):
                    return
                link.pages.pop(rid, None)
                data = b"".join(pages)
            pending = self._pending.pop(rid, None)
            if pending is not None:
                link.done += 1
            else:
                # Late page for a timed-out (or cleared) request — its
                # assignment was rebalanced at drop time; see transport.py.
                self._timed_out.pop(rid, None)
        if pending is None or pending.resolved:
            return
        if ok:
            pending.resolve(data, winfo)
        else:
            pending.fail(tr.TransportError(
                f"worker {link.fn!r} on {link.host} (pid "
                f"{winfo.get('os_pid')}) handler raised:\n"
                f"{data.decode('utf-8', 'replace')}"))

    # ----------------------------------------------------- crash / retry path

    def _monitor_loop(self) -> None:
        """Heartbeat every link; silence + in-flight work ⇒ link is dead."""
        while not self._closed:  # squash: ignore[lock-guarded-access] -- lock-free shutdown poll; a stale read costs one extra heartbeat tick, never correctness
            time.sleep(self.heartbeat_s / 2.0)
            with self._lock:
                links = [link for links in self._links.values()
                         for link in links
                         if not link.dead and link.up.is_set()]
                inflight = {id(link): link.inflight for link in links}
            now = time.perf_counter()
            for link in links:
                if (inflight[id(link)] > 0 and now - link.last_seen
                        > self.heartbeat_s * self.heartbeat_misses):
                    self._on_link_failure(link, link.generation)  # squash: ignore[lock-guarded-access] -- generation snapshot read: a stale value makes _on_link_failure a no-op by design
                    continue
                sock = link.sock
                if sock is None:
                    continue
                try:
                    with link.send_lock:
                        pl.write_frame(sock, pl.FRAME_PING)
                    _METRICS.counter("transport.socket.heartbeats").inc()
                except (OSError, ConnectionError):
                    self._on_link_failure(link, link.generation)  # squash: ignore[lock-guarded-access] -- generation snapshot read: a stale value makes _on_link_failure a no-op by design

    def _on_link_failure(self, link: _Link, gen: int) -> None:
        """Reconnect a lost link and re-send its in-flight invocations.

        ``gen`` is the generation the caller observed the failure on; a
        stale generation means another thread already handled this loss
        (bumping the counter), so the call is a no-op. Re-sent invocations
        stay on the *same* link — the link is the function's slot, the
        connection is merely its current container — and burn one retry
        each under the shared ``max_retries`` budget.
        """
        with self._lock:
            if link.dead or self._closed or gen != link.generation:
                return
            link.generation += 1
            link.up.clear()
            old = link.sock
            link.sock = None
            link.pages.clear()
            for rid in [r for r, l in self._timed_out.items() if l is link]:
                del self._timed_out[rid]
            resend: List[tr._Pending] = []
            for p in list(self._pending.values()):
                if p.worker is not link or p.resolved or not p.sent:
                    continue
                p.retries += 1
                if p.retries > self.max_retries:
                    self._fail_locked([p], tr.TransportError(
                        f"invocation of {p.fn!r} failed after "
                        f"{p.retries - 1} retries (link to {link.host} "
                        f"kept dropping)"))
                    continue
                p.sent = False
                resend.append(p)
        _METRICS.counter("transport.socket.reconnects").inc()
        if resend:
            _METRICS.counter("transport.socket.retries").inc(len(resend))
        if old is not None:
            try:
                old.close()
            except OSError:
                _METRICS.counter("transport.socket.swallowed_errors").inc()
        delay = 0.05
        deadline = time.perf_counter() + self.connect_timeout_s
        while True:
            if self._closed:  # squash: ignore[lock-guarded-access] -- lock-free shutdown poll during reconnect backoff; close() fails the stragglers itself
                return
            try:
                if link.owner is not None:
                    link.owner.ensure_alive()
                self._connect(link)
                break
            except (OSError, ConnectionError):
                if time.perf_counter() > deadline:
                    with self._lock:
                        link.dead = True
                        stuck = [p for p in self._pending.values()
                                 if p.worker is link and not p.resolved]
                        self._fail_locked(stuck, tr.TransportError(
                            f"could not reconnect to {link.host} for "
                            f"{link.fn!r} within {self.connect_timeout_s:.0f}s"))
                    return
                time.sleep(delay)
                delay = min(delay * 2.0, 1.0)
        for p in resend:
            if not p.resolved:
                self._send(p)

    def _fail_locked(self, pendings: List[tr._Pending],
                     exc: Exception) -> None:  # squash: holds[_lock]
        """Fail + forget pendings, rebalancing their link (lock held).

        Links outlive failures (unlike workers), so a failed invocation must
        hand back its ``assigned`` slot or the least-loaded routing shuns
        the link forever.
        """
        for p in pendings:
            if not p.resolved:
                p.fail(exc)
                if p.worker is not None:
                    p.worker.assigned -= 1
            self._pending.pop(p.rid, None)

    # ---------------------------------------------------------- fleet telemetry

    def collect_metrics(self, timeout_s: float = 5.0) -> Dict[str, Dict]:
        """Pull every host process's metrics registry into the local one.

        Sends one STATS frame per distinct host address (every link to one
        ``host:port`` is served by the same host process, whose registry is
        process-global — one pull covers them all), waits for the receiver
        thread's reply, and absorbs each snapshot into
        ``REGISTRY`` under a ``"host:port/pid:N"`` source label with
        ``replace=True`` — host snapshots are cumulative, so repeated pulls
        supersede rather than double-count. Returns ``{source: snapshot}``
        for the hosts that answered in time; an empty dict when the
        registry is disabled (telemetry stays zero-cost when obs is off —
        no frame ever hits the wire).
        """
        if not _METRICS.enabled:
            return {}
        with self._lock:
            links = [link for links in self._links.values() for link in links
                     if not link.dead and link.up.is_set()]
        by_host: Dict[str, _Link] = {}
        for link in links:
            by_host.setdefault(link.host, link)
        out: Dict[str, Dict] = {}
        for link in by_host.values():
            sock = link.sock
            if sock is None:
                continue
            link.stats_event.clear()
            try:
                with link.send_lock:
                    pl.write_frame(sock, pl.FRAME_STATS)
            except (OSError, ConnectionError):
                _METRICS.counter("transport.socket.stats_failures").inc()
                continue
            if not link.stats_event.wait(timeout_s):
                _METRICS.counter("transport.socket.stats_failures").inc()
                continue
            reply = link.stats_reply
            if not reply:
                continue
            source = f"{link.host}/pid:{int(reply['os_pid'])}"
            _METRICS.absorb_snapshot(reply["snapshot"], source=source,
                                     replace=True)
            out[source] = reply["snapshot"]
        return out

    # --------------------------------------------------------------- lifecycle

    def worker_hosts(self, fn: str) -> List[str]:
        """Host:port serving each live link of ``fn`` (in slot order)."""
        with self._lock:
            return [link.host for link in self._links.get(fn, ())
                    if not link.dead]

    def drop_connection(self, fn: str, index: int = 0) -> None:
        """Sever one link's TCP connection (tests exercise reconnect+retry)."""
        with self._lock:
            link = self._links[fn][index]
            sock = link.sock
        if sock is not None:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                _METRICS.counter("transport.socket.swallowed_errors").inc()

    def close(self) -> None:
        # Check-and-set under the lock: two racing close() calls (user +
        # __del__, or two fixtures) both used to pass the unlocked
        # `if self._closed` test and double-send SHUTDOWN frames.
        with self._lock:
            if self._closed:
                return
            self._closed = True
            links = [link for ls in self._links.values() for link in ls]
            for p in self._pending.values():
                if not p.resolved:
                    p.fail(tr.TransportError("transport closed"))
            self._pending.clear()
            self._timed_out.clear()
        for link in links:
            sock = link.sock
            if sock is None:
                continue
            try:
                with link.send_lock:
                    pl.write_frame(sock, pl.FRAME_SHUTDOWN)
            except (OSError, ConnectionError):
                _METRICS.counter("transport.socket.swallowed_errors").inc()
            try:
                sock.close()
            except OSError:
                _METRICS.counter("transport.socket.swallowed_errors").inc()
        for h in self._owned_hosts:
            h.terminate()
        monitor = getattr(self, "_monitor", None)  # deploy may fail earlier
        if monitor is not None and monitor.is_alive():
            monitor.join(timeout=1.0)

    def __del__(self):  # pragma: no cover - best-effort cleanup
        try:
            self.close()
        except Exception:
            pass
