"""Serverless runtime subsystem — the paper's system layer (§3), executable.

Event-driven Coordinator → QueryAllocator → QueryProcessor execution of the
real SQUASH data plane:

* ``events``    — the discrete-event loop (virtual clock) actors run on.
* ``payload``   — request/response codec + Lambda-style byte budgets with an
  explicit overflow policy (error vs chunked re-invocation; oversized
  single-query QP requests chunk on the candidate-row axis).
* ``nodes``     — the three actor roles: Coordinator fan-out/merge, QA
  attribute filtering + Alg. 1 selection with the §2.5 filter-count
  guarantee, QP Stages 3–5 on its partition shard (``core.dataplane``).
* ``workers``   — the function *bodies* (QA plan / QP stages) plus the
  shared ``RequestServer`` container loop the process and socket workers
  both run.
* ``transport`` — the pluggable execution substrate: ``LocalTransport``
  (inline, virtual-time modeled) and ``ProcessTransport`` (real
  multiprocessing worker pool: codec-encoded payloads over process
  boundaries, truly concurrent QP waves, real warm starts, crash retry).
* ``socket_transport`` / ``host`` — the third substrate: workers behind TCP
  connections to ``python -m repro.serverless.host`` processes (loopback by
  default, other machines via ``RuntimeConfig(hosts=...)``), with
  length-prefixed budgeted frames, heartbeat liveness and
  reconnect-with-retry on connection loss.
* ``traces``    — per-node latency/payload/DRE/cache records, the measured
  wall-clock twin fields, and the §3.5 cost assembly (``core.cost_model``).
* ``runtime``   — the façade tying it together: ``ServerlessRuntime.search``
  returns ids bitwise-identical to ``SquashIndex.search(backend="jax")``
  plus a full run trace, under any transport
  (``RuntimeConfig(transport="local" | "process" | "socket")``). With
  ``RuntimeConfig(cache_enabled=True)`` the Coordinator consults the §5.6
  result cache and only cache-miss queries traverse the Alg. 2 tree.
"""

from repro.core.dre import ResultCache
from repro.serverless.events import EventLoop
from repro.serverless.payload import (MAX_SYNC_PAYLOAD_BYTES,
                                      PayloadOverflowError, decode_message,
                                      encode_message)
from repro.serverless.runtime import (RuntimeConfig, SearchResult,
                                      ServerlessRuntime)
from repro.serverless.socket_transport import SocketTransport
from repro.serverless.traces import NodeTrace, RunTrace
from repro.serverless.transport import (LocalTransport, ProcessTransport,
                                        Transport, TransportError)

__all__ = [
    "EventLoop", "MAX_SYNC_PAYLOAD_BYTES", "PayloadOverflowError",
    "decode_message", "encode_message", "ResultCache", "RuntimeConfig",
    "SearchResult", "ServerlessRuntime", "NodeTrace", "RunTrace",
    "Transport", "LocalTransport", "ProcessTransport", "SocketTransport",
    "TransportError",
]
