"""Serverless runtime subsystem — the paper's system layer (§3), executable.

Event-driven Coordinator → QueryAllocator → QueryProcessor execution of the
real SQUASH data plane:

* ``events``  — the discrete-event loop (virtual clock) actors run on.
* ``payload`` — request/response codec + Lambda-style byte budgets with an
  explicit overflow policy (error vs chunked re-invocation).
* ``nodes``   — the three actor roles: Coordinator fan-out/merge, QA
  attribute filtering + Alg. 1 selection with the §2.5 filter-count
  guarantee, QP Stages 3–5 on its partition shard (``core.dataplane``).
* ``traces``  — per-node latency/payload/DRE/cache records and the §3.5
  cost assembly (``core.cost_model``).
* ``runtime`` — the façade tying it together: ``ServerlessRuntime.search``
  returns ids bitwise-identical to ``SquashIndex.search(backend="jax")``
  plus a full run trace. With ``RuntimeConfig(cache_enabled=True)`` the
  Coordinator consults the §5.6 result cache (``core.dre.ResultCache``)
  and only cache-miss queries traverse the Alg. 2 tree.
"""

from repro.core.dre import ResultCache
from repro.serverless.events import EventLoop
from repro.serverless.payload import (MAX_SYNC_PAYLOAD_BYTES,
                                      PayloadOverflowError, decode_message,
                                      encode_message)
from repro.serverless.runtime import (RuntimeConfig, SearchResult,
                                      ServerlessRuntime)
from repro.serverless.traces import NodeTrace, RunTrace

__all__ = [
    "EventLoop", "MAX_SYNC_PAYLOAD_BYTES", "PayloadOverflowError",
    "decode_message", "encode_message", "ResultCache", "RuntimeConfig",
    "SearchResult", "ServerlessRuntime", "NodeTrace", "RunTrace",
]
