"""ServerlessRuntime — event-driven execution of the SQUASH system layer.

One ``search()`` call replays the paper's §3.3 choreography: the client
invokes the Coordinator; the Coordinator fans out over the Algorithm 2
ID-jump tree (or the sequential strawman); every QueryAllocator runs
Stage 1 + Algorithm 1 on its own query slice and invokes one QueryProcessor
per visited partition; QPs execute Stages 3–5 of the real batched data
plane on their partition shard; results merge back up the tree via the
MPI-style top-k combine. Along the way the runtime models what the old
simulators only sketched:

* payload byte budgets — every hop is encoded through the codec and checked
  against the Lambda-style 6 MB cap with an explicit overflow policy
  (oversized requests chunk on the query axis, and a single query whose
  candidate rows alone bust the budget chunks on the partition-row axis);
* DRE — warm-container reuse through ``core.dre.ContainerPool`` leases, one
  pool per function (``squash-allocator``, ``squash-processor-<pid>``),
  extended from "dataset fetched" to *derived-state retention*;
* the §5.6 result cache — with ``cache_enabled`` the Coordinator splits
  every incoming batch into hit/miss query slices before fan-out;
* per-node latency traces and the §3.5 dollar breakdown via
  ``core.cost_model``.

Since PR 5 the *execution substrate* is pluggable
(``RuntimeConfig(transport=...)``, see ``serverless.transport``):

* ``"local"`` — handler bodies run inline under the virtual-time scheduler
  (``events.EventLoop``); concurrency, warm starts and fetches are modeled.
  This is bit- and trace-compatible with PRs 2–4.
* ``"process"`` — handler bodies run in long-lived worker *processes* (one
  per QP partition + a pool for the allocator function): payloads cross
  real process boundaries codec-encoded under the same byte budget, QP
  waves execute genuinely concurrently (eager submission; the
  ``sequential=True`` strawman defers sends so the measured comparison is
  honest), warm starts / data retention are real (keyed to worker OS pids)
  and crashed workers are respawned with bounded re-invocation. The
  *modeled* §3.5 timeline is still assembled — with measured handler/fetch
  times folded in — and ``RunTrace.measured_makespan_s`` plus the per-node
  ``wall_*`` fields report the real clock next to it.
* ``"socket"`` — same worker bodies, but each one lives behind a TCP
  connection to a ``repro.serverless.host`` process
  (``serverless.socket_transport``): pass ``hosts=("10.0.0.5:7070", ...)``
  to spread the QA/QP fleet across machines, or let the runtime auto-spawn
  loopback hosts. Connection loss is handled like a worker crash —
  heartbeat-guarded detection, reconnect with backoff, bounded
  re-invocation — and ``NodeTrace.worker_host`` records who served what.

Parity contract: for the same index/queries/predicates/k, the returned ids
are **bitwise identical** across ``transport="local"``,
``transport="process"``, ``transport="socket"`` and
``SquashIndex.search(backend="jax")`` — every
substrate runs the same jitted plane over the same partition slices, and
the ascending-partition stable merge reproduces the reference tie-breaking.
The aggregate :class:`~repro.core.pipeline.SearchStats` match exactly too,
*except* that on a cache-enabled run the stage counters cover only the miss
slice, and under row-axis payload chunking the keep/take counters reflect
the per-chunk budgets (documented in ``nodes.split_processor_rows``).
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dataplane, invocation
from repro.core.attributes import Predicate
from repro.core.cost_model import PricingConstants
from repro.core.dre import ContainerPool, DreStats, Lease, ResultCache
from repro.core.pipeline import SearchStats, SquashIndex
from repro.obs.export import InMemoryExporter, JsonlExporter, run_record
from repro.obs.metrics import REGISTRY as _METRICS
from repro.obs.spans import Recorder
from repro.serverless import nodes as nd
from repro.serverless import payload as pl
from repro.serverless import transport as tp
from repro.serverless import workers as wk
from repro.serverless.events import EventLoop
from repro.serverless.traces import NodeTrace, RunTrace, assemble_run_trace

__all__ = ["RuntimeConfig", "SearchResult", "ServerlessRuntime"]


def _unwrap_live(index):
    """Accept either a ``SquashIndex`` or its ``LiveIndex`` wrapper."""
    base = getattr(index, "base", None)
    if base is not None and getattr(base, "live_owner", None) is index:
        return base
    return index


@dataclasses.dataclass
class RuntimeConfig:
    """Topology, latency model, payload budget and pricing of one deployment."""

    branching: int = 4                 # F — Alg. 2 fan-out
    max_level: int = 2                 # l_max — tree depth below the CO
    sequential: bool = False           # CO-invokes-everything strawman (Fig. 7)

    # Execution substrate (serverless.transport).
    transport: str = "local"           # "local" | "process" | "socket"
    qa_workers: int = 2                # allocator-function pool size (real)
    worker_start_method: str = "spawn"  # multiprocessing start method
    invoke_timeout_s: float = 180.0    # per-invocation hang guard (real)
    max_worker_retries: int = 2        # re-invocations after a worker crash
    hosts: Optional[Tuple[str, ...]] = None  # socket: "host:port" fleet; None
                                             # auto-spawns loopback hosts
    auto_hosts: int = 2                # loopback hosts when hosts is None
    heartbeat_s: float = 0.25          # socket link liveness probe interval
    worker_sleep_s: float = 0.0        # injected QueryProcessor busy-sleep —
                                       # emulates heavyweight Stage 3–5 work
                                       # so concurrency benches/tests measure
                                       # the transport, not the tiny index

    # Payload budget (§3.3): Lambda's synchronous request/response cap.
    max_payload_bytes: int = pl.MAX_SYNC_PAYLOAD_BYTES
    overflow: str = "chunk"            # "chunk" | "error"

    # DRE / container model (§3.2).
    use_dre: bool = True
    warm_prob: float = 1.0
    fetch_bandwidth_bps: float = 85e6
    fetch_rtt_s: float = 0.02
    qp_setup_s: float = 0.002          # derived-state build on first use of a
                                       # container (skipped on a retained hit)

    # §5.6 result cache (CO-level hit/miss split; off by default).
    cache_enabled: bool = False
    result_cache_bytes: int = 64 * 1024 * 1024
    result_cache_entries: int = 100_000

    # Invocation latency model (Alg. 2 / Fig. 7).
    invoke_latency_warm_s: float = 0.015
    invoke_latency_cold_s: float = 0.400
    invoke_stagger_s: float = 0.002    # thread-spawn serialization per child
    payload_bandwidth_bps: float = 300e6

    # Node busy times: None → measured wall time of the real handler (host
    # wall under LocalTransport, the worker's own report under
    # ProcessTransport); a float pins the virtual compute time.
    co_compute_s: Optional[float] = None
    qa_compute_s: Optional[float] = None
    qp_compute_s: Optional[float] = None

    # §3.5 cost model inputs.
    mem_co_mb: int = 512
    mem_qa_mb: int = 1770
    mem_qp_mb: int = 1770
    prices: PricingConstants = dataclasses.field(default_factory=PricingConstants)

    # Observability (repro.obs). Off by default and zero-cost when off; ids,
    # SearchStats and all traces are bitwise-identical with it on or off
    # (the span context rides the transport envelope, never the budgeted
    # payload). ``obs_enabled=True`` also enables the process-global metrics
    # REGISTRY for the process lifetime (enabling is one-way here — tests
    # that need isolation call ``REGISTRY.disable()``/``reset()`` directly).
    obs_enabled: bool = False
    obs_trace_path: Optional[str] = None  # JSONL trace file; None → in-memory

    dataset_tag: str = "dataset"       # DRE singleton key prefix
    seed: int = 0

    def __post_init__(self):
        if self.overflow not in pl.OVERFLOW_POLICIES:
            raise ValueError(f"unknown overflow policy {self.overflow!r}; "
                             f"expected {pl.OVERFLOW_POLICIES}")
        if self.transport not in tp.TRANSPORTS:
            raise ValueError(f"unknown transport {self.transport!r}; "
                             f"expected {tp.TRANSPORTS}")
        if self.branching < 1 or self.max_level < 1:
            raise ValueError("branching and max_level must be >= 1")


@dataclasses.dataclass
class SearchResult:
    """Final merged top-k plus the run's full accounting."""

    ids: np.ndarray        # (Q, k) int64, -1 padding
    dists: np.ndarray      # (Q, k) float64, +inf padding
    stats: SearchStats
    trace: RunTrace


class _Gather:
    """Scatter-accumulator for (possibly chunked) responses, by query index."""

    def __init__(self, qidx: np.ndarray, k: int):
        self.pos = {int(q): i for i, q in enumerate(qidx)}
        self.ids = np.full((qidx.shape[0], k), -1, dtype=np.int64)
        self.dists = np.full((qidx.shape[0], k), np.inf, dtype=np.float64)

    def rows_of(self, qidx: np.ndarray) -> np.ndarray:
        return np.fromiter((self.pos[int(q)] for q in qidx),
                           dtype=np.int64, count=qidx.shape[0])

    def scatter(self, resp: Dict) -> None:
        if resp["qidx"].shape[0] == 0:
            return
        rows = self.rows_of(resp["qidx"])
        self.ids[rows] = resp["ids"]
        self.dists[rows] = resp["dists"]


class _ChunkGather(_Gather):
    """Chunk-ordered top-k merge accumulator for QueryProcessor responses.

    Query-axis chunks carry disjoint query sets, for which the merge
    degenerates to the plain scatter; *row-axis* chunks (one query's
    candidate rows split across invocations) share a query index, and their
    per-chunk top-k streams merge by (distance, chunk order) — chunk order
    is ascending row order, reproducing the unsplit stream's tie-breaking.
    Responses are merged in ascending chunk index regardless of arrival
    order, so ProcessTransport completion races cannot reorder ties.
    """

    def __init__(self, qidx: np.ndarray, k: int):
        super().__init__(qidx, k)
        self.k = k
        self._parts: Dict[int, Dict] = {}

    def add(self, ci: int, resp: Dict) -> None:
        self._parts[ci] = resp

    def merged(self):
        for ci in sorted(self._parts):
            resp = self._parts[ci]
            if resp["qidx"].shape[0] == 0:
                continue
            rows = self.rows_of(resp["qidx"])
            cat_i = np.concatenate([self.ids[rows], resp["ids"]], axis=1)
            cat_d = np.concatenate([self.dists[rows], resp["dists"]], axis=1)
            order = np.argsort(cat_d, axis=1, kind="stable")[:, :self.k]
            self.ids[rows] = np.take_along_axis(cat_i, order, axis=1)
            self.dists[rows] = np.take_along_axis(cat_d, order, axis=1)
        return self.ids, self.dists


class ServerlessRuntime:
    """The serverless system façade bound to one resident :class:`SquashIndex`."""

    def __init__(self, index: SquashIndex, config: Optional[RuntimeConfig] = None):
        import jax

        self.index = _unwrap_live(index)
        index = self.index
        self.cfg = config or RuntimeConfig()
        self.n_qp = len(index.parts)
        self.n_qa = invocation.tree_size(self.cfg.branching, self.cfg.max_level)
        self.topology = self._build_topology()
        pool_kw = dict(warm_prob=self.cfg.warm_prob,
                       fetch_bandwidth_bps=self.cfg.fetch_bandwidth_bps,
                       fetch_rtt_s=self.cfg.fetch_rtt_s)
        # One pool per Lambda *function*: the shared allocator function and
        # one processor function per partition (squash-processor-<pid>), so a
        # warm QP container always matches its partition's singleton. Under
        # ProcessTransport these virtual pools are bypassed — warm/retention
        # economics come from the real workers.
        self.qa_pool = ContainerPool(seed=self.cfg.seed + 1, **pool_kw)
        self.qp_pools = {
            pid: ContainerPool(seed=self.cfg.seed + 2 + pid, **pool_kw)
            for pid in range(self.n_qp)
        }
        self.allocator = nd.QueryAllocator(index)
        self.result_cache = (
            ResultCache(capacity=self.cfg.result_cache_entries,
                        max_bytes=self.cfg.result_cache_bytes)
            if self.cfg.cache_enabled else None)
        self.index_version = 0
        # Mutation-log cursor into the index's LiveIndex owner (if any):
        # `search` drains events past it lazily (pull model), so the runtime
        # stays consistent with streaming inserts/deletes/compactions
        # without the index ever holding a runtime reference.
        live = getattr(index, "live_owner", None)
        self._live_cursor = live.version if live is not None else 0
        self._dtype = np.float64 if jax.config.jax_enable_x64 else np.float32
        self._stacked = None
        self._processors: Dict[int, nd.QueryProcessor] = {}
        self._planes: Dict = {}
        self._trace_counter = [0]
        self._transport: Optional[tp.Transport] = None
        self._obs_exporter = None
        self._slo_tracker = None
        if self.cfg.obs_enabled:
            _METRICS.enable()

    @property
    def obs_exporter(self):
        """Trace sink for obs-enabled runs: a JSONL file when
        ``obs_trace_path`` is set, else an in-memory exporter whose
        ``records`` tests inspect. None when observability is off."""
        if not self.cfg.obs_enabled:
            return None
        if self._obs_exporter is None:
            self._obs_exporter = (
                JsonlExporter(self.cfg.obs_trace_path)
                if self.cfg.obs_trace_path else InMemoryExporter())
        return self._obs_exporter

    @property
    def slo_tracker(self):
        """Rolling SLO monitors fed by every obs-enabled search (one
        tracker per runtime, so it watches one transport's latency
        profile). None when observability is off; gate it with any
        :class:`repro.obs.slo.SloPolicy`."""
        if not self.cfg.obs_enabled:
            return None
        if self._slo_tracker is None:
            from repro.obs.slo import SloTracker
            self._slo_tracker = SloTracker()
        return self._slo_tracker

    # ------------------------------------------------------------- transport

    @property
    def is_process(self) -> bool:
        return self.cfg.transport == "process"

    @property
    def is_socket(self) -> bool:
        return self.cfg.transport == "socket"

    @property
    def is_real(self) -> bool:
        """Real workers behind a boundary (process pipes or TCP links), as
        opposed to the modeled inline LocalTransport."""
        return self.cfg.transport != "local"

    @property
    def transport(self) -> tp.Transport:
        """The execution substrate, built lazily (real workers are
        long-lived across searches — that is what makes DRE warm hits real)."""
        if self._transport is None:
            if self.is_process:
                self._transport = self._build_process_transport()
            elif self.is_socket:
                self._transport = self._build_socket_transport()
            else:
                self._transport = tp.LocalTransport(self._local_handlers())
        return self._transport

    def _local_handlers(self) -> Dict[str, Callable]:
        def qa(fn: str, req: Dict, extra: Dict):
            return wk.qa_compute(self.allocator, req,
                                 int(extra["olo"]), int(extra["ohi"]))

        def qp(fn: str, req: Dict, extra: Dict):
            pid = int(fn.split(":", 1)[1])
            return wk.qp_compute(self.processor(pid), req)

        return {"qa": qa, "qp": qp}

    def _worker_inits(self) -> Dict:
        """Function → (WorkerInit, pool size): the fleet's deployment map,
        shared by the process and socket substrates."""
        import jax

        cfg = self.cfg
        x64 = bool(jax.config.jax_enable_x64)
        platform = os.environ.get("JAX_PLATFORMS", "cpu") or "cpu"
        inits = {
            "qa": (wk.WorkerInit(role="qa", fn="qa", pid=None, x64=x64,
                                 platform=platform,
                                 bundle=wk.build_qa_bundle(self.index)),
                   max(1, cfg.qa_workers)),
        }
        for pid in range(self.n_qp):
            inits[f"qp:{pid}"] = (
                wk.WorkerInit(role="qp", fn=f"qp:{pid}", pid=pid, x64=x64,
                              platform=platform,
                              bundle=wk.build_qp_bundle(self.index, pid,
                                                        self._dtype)),
                1)
        return inits

    def _build_process_transport(self) -> tp.ProcessTransport:
        cfg = self.cfg
        return tp.ProcessTransport(
            self._worker_inits(),
            eager=not cfg.sequential,
            start_method=cfg.worker_start_method,
            invoke_timeout_s=cfg.invoke_timeout_s,
            max_retries=cfg.max_worker_retries)

    def _build_socket_transport(self):
        # Imported lazily so the TCP machinery never loads for in-process
        # runs (and LocalTransport stays importable with no socket support).
        from repro.serverless.socket_transport import SocketTransport

        cfg = self.cfg
        return SocketTransport(
            self._worker_inits(),
            hosts=cfg.hosts,
            auto_hosts=cfg.auto_hosts,
            eager=not cfg.sequential,
            start_method=cfg.worker_start_method,
            invoke_timeout_s=cfg.invoke_timeout_s,
            max_retries=cfg.max_worker_retries,
            max_payload_bytes=cfg.max_payload_bytes,
            heartbeat_s=cfg.heartbeat_s)

    def close(self) -> None:
        """Shut down the transport (terminates process workers)."""
        if self._transport is not None:
            self._transport.close()
            self._transport = None

    def __enter__(self) -> "ServerlessRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- resources

    def _build_topology(self) -> Dict[int, invocation.NodeSpec]:
        if self.cfg.sequential:
            nodes = {-1: invocation.NodeSpec(node_id=-1, level=0,
                                             children=tuple(range(self.n_qa)),
                                             subtree=self.n_qa)}
            for x in range(self.n_qa):
                nodes[x] = invocation.NodeSpec(node_id=x, level=1,
                                               children=(), subtree=0)
            return nodes
        return invocation.tree_nodes(self.cfg.branching, self.cfg.max_level)

    @property
    def stacked(self) -> dataplane.StackedIndex:
        if self._stacked is None:
            self._stacked = dataplane.stack_index(self.index, dtype=self._dtype)
        return self._stacked

    def processor(self, pid: int) -> nd.QueryProcessor:
        if pid not in self._processors:
            import jax

            # The QP's DRE singleton: this partition's slice of the stacked
            # payload (same arrays the jax backend searches, so bit-parity).
            sl = jax.tree_util.tree_map(lambda a: a[pid:pid + 1], self.stacked)
            self._processors[pid] = nd.QueryProcessor(
                pid, sl, self._plane_for, self.index.config, self._dtype)
        return self._processors[pid]

    def _plane_for(self, k: int):
        cfg = self.index.config
        keep_s, take_s = dataplane.static_counts(
            self.stacked.n_max, cfg, k, getattr(self.index, "profile", None))
        key = (k, keep_s, take_s, cfg.enable_refine)
        plane = self._planes.get(key)
        if plane is None:
            plane = dataplane.make_plane(
                k=k, keep_s=keep_s, take_s=take_s, refine=cfg.enable_refine,
                trace_counter=self._trace_counter)
            self._planes[key] = plane
        return plane

    def invalidate_cache(self, pids: Optional[Sequence[int]] = None) -> None:
        """Drop cached results and retained DRE state, whole or per-segment.

        With ``pids=None`` (whole-index): bumping ``index_version`` makes
        every container's retained state stale — *both* the fetch-level
        singletons and the derived state embed the version in their keys, so
        a warm container acquired afterwards pays the S3 fetch and the setup
        again. Clearing the pools' retained sets keeps permanently-stale
        keys from accumulating, and bumps the pools' epoch so an in-flight
        lease cannot resurrect the cleared state on release.

        With ``pids`` (segment-granular, the live-index path): only the
        named partitions' result-cache entries are evicted (dependency-set
        intersection) and only their pools — plus the allocator's, whose
        bundle always covers every partition — drop retained state; fetch
        keys go stale through the per-partition *generation* they embed, so
        untouched partitions keep their warm retention.

        Neither form rebinds the runtime to new index *data* — ``rebind``
        (or the live-index event sync in ``search``) does that.
        """
        if pids is None:
            self.index_version += 1
            if self.result_cache is not None:
                self.result_cache.invalidate()
            for pool in (self.qa_pool, *self.qp_pools.values()):
                pool.clear_derived()
            return
        if self.result_cache is not None:
            self.result_cache.invalidate_partitions(pids)
        self.qa_pool.clear_derived()
        for pid in pids:
            if pid in self.qp_pools:
                self.qp_pools[pid].clear_derived()

    # ------------------------------------------------------ live-index state

    def _generation(self, pid: int) -> int:
        """The partition's live-index generation (0 for a frozen index)."""
        live = getattr(self.index, "live_owner", None)
        return live.generations[pid] if live is not None else 0

    def _qa_generation(self) -> int:
        """Generation of the QA-visible state (partitioning + attributes +
        tombstones): any mutation changes it, so the mutation counter is
        the natural key component."""
        live = getattr(self.index, "live_owner", None)
        return live.version if live is not None else 0

    def _sync_index(self) -> None:
        """Drain the LiveIndex mutation log and rebind derived state.

        Pull model: mutations only record events; the next ``search`` pays
        the rebinding — stacked payload and touched per-partition processors
        drop (they rebuild from the mutated index), real-transport workers
        restart with fresh bundles, touched pools' derived state clears
        (their keys embed the new generations anyway — the clear stops stale
        keys accumulating and epoch-fences in-flight leases), and the result
        cache invalidates at segment granularity per event kind.
        """
        live = getattr(self.index, "live_owner", None)
        if live is None:
            return
        cursor, events = live.events_since(self._live_cursor)
        if not events:
            return
        self._live_cursor = cursor
        touched = sorted({pid for ev in events for pid in ev.pids})
        self._stacked = None
        for pid in touched:
            self._processors.pop(pid, None)
        if self.is_real:
            # Live workers hold bundles of the pre-mutation index; closing
            # the transport respawns them lazily with fresh bundles. The
            # modeled pools survive — the virtual warm/fetch economics are
            # what the local transport reports.
            self.close()
        self.qa_pool.clear_derived()
        for pid in touched:
            if pid in self.qp_pools:
                self.qp_pools[pid].clear_derived()
        if self.result_cache is not None:
            for ev in events:
                self._invalidate_cache_for_event(ev)

    def _invalidate_cache_for_event(self, ev) -> None:
        """Segment-granular §5.6 invalidation for one mutation event.

        * delete — evict entries whose partition dependency set intersects
          the touched partitions, plus underfilled entries (fewer than k
          results means every candidate was returned, so candidate-count
          changes can reshape them).
        * insert — evict entries the new vectors could displace: the
          nearest new vector reaches the entry's kth distance (underfilled
          entries have an infinite kth and always evict). Over-eviction
          only — if the new vector's partition wouldn't even be visited,
          the fresh search returns the same ids the entry held.
        * compact — drop-only compaction is bitwise-invisible (same codes,
          same order), nothing evicts; requantization changes the
          partition's quantized geometry, so entries depending on it, in
          its threshold radius, or underfilled evict.

        Residual (documented in DESIGN.md §Live index): entries whose query
        reached k candidates only through §2.5 escalations may survive a
        delete/requantize that would now escalate differently — the
        dependency sets cover returned ids, not the visit set.
        """
        cache = self.result_cache
        pid_set = frozenset(ev.pids)

        def underfilled(value) -> bool:
            ids, _ = value
            return bool((np.asarray(ids) < 0).any())

        if ev.kind == "delete":
            cache.invalidate_where(lambda key, value: (
                underfilled(value)
                or cache.deps(key) is None
                or bool(cache.deps(key) & pid_set)))
        elif ev.kind == "insert":
            vecs = ev.vectors

            def displaced(key, value) -> bool:
                if underfilled(value):
                    return True
                _, dists = value
                q = np.frombuffer(key[0], dtype=np.float64)
                dmin = float(np.sqrt(
                    ((vecs - q[None, :]) ** 2).sum(axis=1)).min())
                return dmin <= float(np.asarray(dists)[-1])

            cache.invalidate_where(displaced)
        elif ev.kind == "compact" and ev.requantize:
            cent = self.index.partitioning.centroids
            thr = self.index.partitioning.threshold

            def touches(key, value) -> bool:
                if underfilled(value):
                    return True
                deps = cache.deps(key)
                if deps is None or (deps & pid_set):
                    return True
                q = np.frombuffer(key[0], dtype=np.float64)
                d = np.sqrt(((cent - q[None, :]) ** 2).sum(axis=1))
                return any(d[p] <= thr * max(float(d.min()), 1e-12)
                           for p in pid_set)

            cache.invalidate_where(touches)

    def rebind(self, index) -> None:
        """Swap this runtime onto a (re)built index without dropping warm
        container state.

        The container pools survive the swap: their free lists keep the
        warm containers, while ``invalidate_cache()`` bumps the index
        version (staling every fetch/derived key) and the pools' epoch — so
        in-flight leases *drain* through the existing epoch machinery
        (their releases still return containers to the pool; their derived
        retains are dropped) instead of the old behavior of discarding the
        runtime wholesale. Partition-count changes keep the overlapping
        processor pools' warmth and add/remove the rest.
        """
        index = _unwrap_live(index)
        self.index = index
        n_new = len(index.parts)
        if n_new != self.n_qp:
            pool_kw = dict(warm_prob=self.cfg.warm_prob,
                           fetch_bandwidth_bps=self.cfg.fetch_bandwidth_bps,
                           fetch_rtt_s=self.cfg.fetch_rtt_s)
            for pid in range(self.n_qp, n_new):
                self.qp_pools[pid] = ContainerPool(
                    seed=self.cfg.seed + 2 + pid, **pool_kw)
            for pid in range(n_new, self.n_qp):
                del self.qp_pools[pid]
            self.n_qp = n_new
        self.allocator = nd.QueryAllocator(index)
        live = getattr(index, "live_owner", None)
        self._live_cursor = live.version if live is not None else 0
        self._stacked = None
        self._processors.clear()
        self.close()     # real workers hold the old index's bundles
        self.invalidate_cache()

    def qa_data_bytes(self) -> int:
        """QA singleton: attribute Q-index + centroids + P-V map."""
        idx = self.index
        return int(idx.attr_index.codes.nbytes
                   + idx.partitioning.centroids.nbytes
                   + idx.partitioning.assign.nbytes)

    def qp_data_bytes(self, pid: int) -> int:
        """QP singleton: the partition's OSQ indexes (the S3 object)."""
        part = self.index.parts[pid]
        return int(part.packed.nbytes + part.low.packed.nbytes
                   + part.codes.nbytes + part.quant.boundaries.nbytes)

    # ----------------------------------------------------------------- search

    def search(
        self,
        queries: np.ndarray,
        predicates: Sequence[Predicate] = (),
        k: int = 10,
    ) -> SearchResult:
        """Run one query batch through the full CO → QA → QP choreography."""
        self._sync_index()      # drain any live-index mutations first
        queries = np.atleast_2d(np.asarray(queries, dtype=np.float64))
        qn = queries.shape[0]
        if qn == 0:
            empty = assemble_run_trace(
                [], makespan_s=0.0, escalations=0, dre=DreStats(),
                efs_reads=0, efs_read_bytes=0, stats=SearchStats(),
                mem_qa_mb=self.cfg.mem_qa_mb, mem_qp_mb=self.cfg.mem_qp_mb,
                mem_co_mb=self.cfg.mem_co_mb, prices=self.cfg.prices,
                transport=self.cfg.transport)
            return SearchResult(ids=np.full((0, k), -1, np.int64),
                                dists=np.full((0, k), np.inf),
                                stats=SearchStats(), trace=empty)
        return _Execution(self, qn, k).run(queries, list(predicates))


class _Execution:
    """One search run: the event choreography plus its accumulators.

    The choreography is transport-agnostic: every function body executes
    through ``transport.submit(...).result()``. Under LocalTransport the
    submit is lazy and the body runs inline at collection, reproducing the
    PR 2–4 virtual-time behavior exactly; under ProcessTransport submits
    are eager at *issue* time, so one wave's workers run concurrently while
    the virtual scheduler collects their results in deterministic order.
    """

    def __init__(self, rt: ServerlessRuntime, qn: int, k: int):
        self.rt = rt
        self.cfg = rt.cfg
        self.transport = rt.transport
        self.real = rt.is_real        # process or socket workers (not inline)
        self.loop = EventLoop()
        self.qn = qn
        self.k = k
        self.qpq = -(-qn // rt.n_qa)          # queries per QA (ceil)
        self.nodes: List[NodeTrace] = []
        self.dre = DreStats()
        self.stats = SearchStats(queries=qn)
        self.escalations = 0
        self.efs_reads = 0
        self.efs_read_bytes = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self.out_ids = np.full((qn, k), -1, dtype=np.int64)
        self.out_dists = np.full((qn, k), np.inf, dtype=np.float64)
        self.rec = Recorder() if rt.cfg.obs_enabled else None
        self.wall0 = time.perf_counter()  # squash: ignore[wallclock] -- measured wall-clock feeds the measured timeline/trace only; ids and SearchStats never depend on it

    # ------------------------------------------------------------- utilities

    def _tx(self, nbytes: int) -> float:
        return nbytes / self.cfg.payload_bandwidth_bps

    def _qrange(self, idlo: int, idhi: int):
        return idlo * self.qpq, min(idhi * self.qpq, self.qn)

    def _own_range(self, spec: invocation.NodeSpec):
        if spec.node_id == -1:
            return 0, 0
        return self._qrange(spec.node_id, spec.node_id + 1)

    def _acquire(self, pool: ContainerPool, key, nbytes: int,
                 merge: bool = True) -> Lease:
        """Lease a container; with ``merge=False`` the caller folds the
        lease's per-call stats delta into ``self.dre`` itself — used by the
        QP path so the delta can first absorb the derived-hit outcome and
        be merged exactly once (the old flow merged here and then bumped
        ``derived_hits`` by hand, double-counting against the pool)."""
        lease = pool.acquire(key, nbytes, use_dre=self.cfg.use_dre)
        if merge:
            self.dre.merge(lease.stats)
        return lease

    def _invoke_overhead(self, warm: bool) -> float:
        return (self.cfg.invoke_latency_warm_s if warm
                else self.cfg.invoke_latency_cold_s)

    def _merge_real_dre(self, info: tp.InvokeInfo, data_bytes: int,
                        derived: bool = False) -> None:
        """Fold a worker's real container report into the run's DreStats."""
        self.dre.merge(DreStats(
            invocations=1,
            warm_starts=int(info.warm),
            dre_hits=int(info.state_hit),
            derived_hits=int(derived and info.state_hit),
            s3_gets=int(not info.state_hit),
            bytes_fetched=0 if info.state_hit else data_bytes,
            fetch_seconds=info.fetch_s,
        ))

    def _wall_kw(self, info: Optional[tp.InvokeInfo],
                 t0: float, t1: float) -> Dict:
        """NodeTrace measured-wall fields, relative to the run submit."""
        if info is not None and self.real:
            return dict(wall_issue_s=info.wall_submit - self.wall0,
                        wall_start_s=info.wall_sent - self.wall0,
                        wall_end_s=info.wall_done - self.wall0,
                        wall_compute_s=info.compute_s,
                        worker_pid=info.os_pid,
                        worker_host=info.host,
                        retries=info.retries)
        return dict(wall_issue_s=t0 - self.wall0,
                    wall_start_s=t0 - self.wall0,
                    wall_end_s=t1 - self.wall0,
                    wall_compute_s=t1 - t0,
                    worker_pid=os.getpid(),
                    worker_host="",
                    retries=0)

    # -------------------------------------------------------------- tracing

    def _ctx(self, sid: Optional[str]) -> Optional[Dict]:
        """Wire span context for one invocation, or None when obs is off."""
        if self.rec is None or sid is None:
            return None
        return {"run": self.rec.run_id, "span": sid}

    def _record_node_span(self, sid, parent_sid, name, kind, ci, t_issue,
                          t_start, t_avail, t_end, inv, fetch_s, compute_s,
                          warm, wallkw, winfo) -> None:
        """Stitch one node invocation into the run's span tree.

        Records the node span on the modeled clock with its derived phase
        children (issue → wire → fetch → compute → respond), then grafts the
        worker-reported wall-clock sub-spans beneath it — but only when the
        worker echoed back *this* run and parent span id, so a stale or
        foreign report can never stitch into the wrong tree.
        """
        rec = self.rec
        if rec is None or sid is None:
            return
        rec.record(name, t_issue, t_end, span_id=sid, parent_id=parent_sid,
                   kind=kind, chunk=ci, warm=bool(warm),
                   retries=int(wallkw.get("retries", 0)),
                   worker_pid=int(wallkw.get("worker_pid", 0)),
                   worker_host=wallkw.get("worker_host", ""))
        rec.record("issue", t_issue, t_issue + inv, parent_id=sid, phase=True)
        rec.record("wire", t_issue + inv, t_start, parent_id=sid, phase=True)
        if fetch_s > 0:
            rec.record("fetch", t_start, t_start + fetch_s, parent_id=sid,
                       phase=True)
        rec.record("compute", t_avail, t_avail + compute_s, parent_id=sid,
                   phase=True)
        rec.record("respond", t_avail + compute_s, t_end, parent_id=sid,
                   phase=True)
        wspans = winfo.spans if winfo is not None else None
        if (wspans and wspans.get("run") == rec.run_id
                and wspans.get("parent") == sid):
            base = float(wallkw.get("wall_start_s", 0.0))
            for mname, m0, m1 in wspans.get("spans", ()):
                rec.record(f"worker.{mname}", base + float(m0),
                           base + float(m1), parent_id=sid, clock="wall")

    # ------------------------------------------------------------------ run

    def run(self, queries: np.ndarray, predicates: List[Predicate]
            ) -> SearchResult:
        root_req = {
            "qidx": np.arange(self.qn, dtype=np.int32),
            "queries": queries,
            "preds": pl.predicates_to_json(predicates),
            "k": int(self.k),
        }

        def root_respond(resp: Dict) -> None:
            rows = resp["qidx"].astype(np.int64)
            self.out_ids[rows] = resp["ids"]
            self.out_dists[rows] = resp["dists"]

        root_sid = self.rec.new_span_id() if self.rec is not None else None
        self._invoke_allocator(self.rt.topology[-1], root_req,
                               t_issue=0.0, parent="client",
                               respond=root_respond, parent_sid=root_sid)
        makespan = self.loop.run()
        measured = time.perf_counter() - self.wall0  # squash: ignore[wallclock] -- measured wall-clock feeds the measured timeline/trace only; ids and SearchStats never depend on it
        trace = assemble_run_trace(
            self.nodes, makespan_s=makespan, escalations=self.escalations,
            dre=self.dre, efs_reads=self.efs_reads,
            efs_read_bytes=self.efs_read_bytes, stats=self.stats,
            mem_qa_mb=self.cfg.mem_qa_mb, mem_qp_mb=self.cfg.mem_qp_mb,
            mem_co_mb=self.cfg.mem_co_mb, prices=self.cfg.prices,
            cache_hits=self.cache_hits, cache_misses=self.cache_misses,
            transport=self.cfg.transport, measured_makespan_s=measured)
        if self.rec is not None:
            self.rec.record("search", 0.0, makespan, span_id=root_sid,
                            transport=self.cfg.transport, queries=self.qn,
                            k=self.k)
            # Fleet telemetry: pull remote registries (socket hosts answer
            # STATS; pipe-worker deltas were absorbed per response) so the
            # exported record carries the merged, source-labelled view, and
            # feed the rolling SLO monitors with this run.
            fleet_metrics = None
            if _METRICS.enabled:
                self.transport.collect_metrics()
                fleet_metrics = _METRICS.fleet_snapshot()
            tracker = self.rt.slo_tracker
            if tracker is not None:
                tracker.observe_run(trace)
            exporter = self.rt.obs_exporter
            if exporter is not None:
                exporter.export(run_record(
                    self.rec, run_trace=trace,
                    meta={"transport": self.cfg.transport,
                          "queries": self.qn, "k": self.k,
                          "makespan_s": makespan,
                          "measured_makespan_s": measured},
                    metrics=fleet_metrics,
                    slo=None if tracker is None else tracker.snapshot()))
        return SearchResult(ids=self.out_ids, dists=self.out_dists,
                            stats=self.stats, trace=trace)

    # ------------------------------------------------------- allocator nodes

    def _invoke_allocator(
        self,
        spec: invocation.NodeSpec,
        req: Dict,
        t_issue: float,
        parent: str,
        respond: Callable[[Dict], None],
        parent_sid: Optional[str] = None,
    ) -> float:
        """Issue one logical CO/QA invocation (possibly chunked).

        Returns the launch occupancy (Σ stagger + invoke overhead over the
        chunks) the issuing thread pays — the sequential strawman serializes
        on exactly this.
        """
        kind = "co" if spec.node_id == -1 else "qa"
        name = "co" if kind == "co" else f"qa:{spec.node_id}"
        chunks = pl.chunk_request(
            req, max_bytes=self.cfg.max_payload_bytes,
            policy=self.cfg.overflow, split=nd.split_search_request,
            num_items=lambda r: r["qidx"].shape[0])
        gather = _Gather(req["qidx"], self.k)
        state = {"left": len(chunks)}
        olo, ohi = self._own_range(spec)

        def chunk_done(resp: Dict) -> None:
            gather.scatter(resp)
            state["left"] -= 1
            if state["left"] == 0:
                respond({"qidx": req["qidx"], "ids": gather.ids,
                         "dists": gather.dists})

        launch_s = 0.0
        for ci, (creq, buf) in enumerate(chunks):
            sid = self.rec.new_span_id() if self.rec is not None else None
            pinv, lease = None, None
            if kind == "co":
                # The Coordinator runs where the runtime lives (it fronts
                # the client); its empty own-slice plan is computed inline.
                warm, hit, fetch_s = True, False, 0.0
            elif self.real:
                pinv = self.transport.submit(
                    "qa", payload=buf,
                    extra=pl.inject_span_context(
                        {"olo": olo, "ohi": ohi}, self._ctx(sid)))
                warm = pinv.predicted_warm
                hit, fetch_s = warm, 0.0       # refined from the worker report
            else:
                # Local: the lease models warm/fetch now; the body itself is
                # submitted at collection, on the handler's *decoded* wire
                # request, so the codec stays on the hop's real path.
                # The fetch-level singleton key embeds the index version and
                # the QA-state generation: after invalidate_cache()/rebind
                # (or any live-index mutation) a warm container's retained
                # bytes are stale and the S3 fetch is paid again.
                lease = self._acquire(
                    self.rt.qa_pool,
                    (self.cfg.dataset_tag, "qa-index",
                     self.rt.index_version, self.rt._qa_generation()),
                    self.rt.qa_data_bytes())
                warm, hit, fetch_s = lease.warm, lease.dre_hit, lease.fetch_s
            inv = self._invoke_overhead(warm)
            t_i = t_issue + launch_s
            launch_s += self.cfg.invoke_stagger_s + inv
            t_start = t_i + inv + self._tx(len(buf))
            # The handler decodes the wire bytes — the codec is on the real
            # path of every hop, not just in the byte accounting.
            self.loop.at(t_start, lambda buf=buf, lease=lease, pinv=pinv,
                         warm=warm, hit=hit, fetch_s=fetch_s, inv=inv,
                         ci=ci, t_i=t_i, t_start=t_start, sid=sid:
                         self._allocator_handler(
                             spec, kind, name, parent, ci,
                             pl.decode_message(buf), len(buf),
                             lease, pinv, warm, hit, fetch_s, inv, t_i,
                             t_start, chunk_done,
                             sid=sid, parent_sid=parent_sid))
        return launch_s

    def _allocator_handler(
        self, spec, kind, name, parent, ci, creq, req_bytes, lease, pinv,
        warm, hit, fetch_s, inv, t_issue, t_start, respond_chunk,
        sid=None, parent_sid=None,
    ) -> None:
        cfg = self.cfg
        t0 = time.perf_counter()  # squash: ignore[wallclock] -- measured wall-clock feeds the measured timeline/trace only; ids and SearchStats never depend on it
        predicates = pl.predicates_from_json(creq["preds"])
        k = int(creq["k"])
        full_qidx = creq["qidx"]
        qidx, queries = full_qidx, creq["queries"]

        # §5.6 result-cache split (CO only): hits never enter the fan-out —
        # the tree below sees only the miss slice. Lookup runs inside the
        # measured window: the Coordinator pays for its own cache probes.
        cache = self.rt.result_cache if kind == "co" else None
        hit_entries: List[tuple] = []        # (global qidx, (ids, dists))
        miss_keys: Dict[int, object] = {}    # global qidx → cache key
        if cache is not None:
            miss_rows = []
            pp = cache.canonical_predicates(predicates)
            for i in range(qidx.shape[0]):
                ckey = (cache.query_key(queries[i]), pp, k)
                entry = cache.get(ckey)
                if entry is not None:
                    hit_entries.append((int(qidx[i]), entry))
                else:
                    miss_rows.append(i)
                    miss_keys[int(qidx[i])] = ckey
            if hit_entries:
                rows = np.asarray(miss_rows, dtype=np.int64)
                qidx, queries = qidx[rows], queries[rows]
            self.cache_hits += len(hit_entries)
            self.cache_misses += len(miss_keys)

        olo, ohi = self._own_range(spec)
        own_mask = (qidx >= olo) & (qidx < ohi)
        own_qidx = qidx[own_mask]

        # Collect the node's plan from the transport. The CO plans inline
        # (its own slice is empty by construction); QA plans were submitted
        # at issue — under ProcessTransport they may already have finished
        # in a worker while sibling handlers ran.
        winfo = None
        if kind == "co":
            presp = wk.qa_compute(self.rt.allocator, creq, olo, ohi)
        elif self.real:
            raw, winfo = pinv.result()
            presp = wk.unpack_plan_response(raw)
            warm, hit, fetch_s = winfo.warm, winfo.state_hit, winfo.fetch_s
            self._merge_real_dre(winfo, self.rt.qa_data_bytes())
        else:
            pinv = self.transport.submit(
                "qa", request=creq,
                extra=pl.inject_span_context(
                    {"olo": olo, "ohi": ohi}, self._ctx(sid)))
            presp, winfo = pinv.result()
        t1 = time.perf_counter()  # squash: ignore[wallclock] -- measured wall-clock feeds the measured timeline/trace only; ids and SearchStats never depend on it
        measured = (winfo.compute_s if (self.real and winfo is not None)
                    else t1 - t0)
        fixed = cfg.co_compute_s if kind == "co" else cfg.qa_compute_s
        compute_s = measured if fixed is None else fixed
        t_avail = t_start + fetch_s
        t_ready = t_avail + compute_s
        wallkw = self._wall_kw(winfo, t0, t1)

        qp_requests = presp["plans"]
        self.stats.filter_pass += presp["filter_pass"]
        self.stats.partitions_visited += presp["partitions_visited"]
        self.escalations += presp["escalations"]

        gather = _Gather(full_qidx, k)
        m_own = own_qidx.shape[0]
        own_streams: Dict[int, tuple] = {}
        own_gather = _Gather(own_qidx, k) if m_own else None
        pending = {"n": 0}

        def finalize() -> None:
            if m_own:
                streams = [own_streams[pid] for pid in sorted(own_streams)]
                ids, dists = nd.merge_partition_topk(m_own, k, streams)
                gather.scatter({"qidx": own_qidx, "ids": ids, "dists": dists})
            if hit_entries:
                gather.scatter({
                    "qidx": np.asarray([q for q, _ in hit_entries], np.int32),
                    "ids": np.stack([e[0] for _, e in hit_entries]),
                    "dists": np.stack([e[1] for _, e in hit_entries])})
            if miss_keys:
                # Dependency sets for segment-granular invalidation: the
                # home partitions of the returned ids (a result can only
                # change if one of them — or, for underfilled entries, the
                # candidate supply — changes; see invalidate_cache).
                assign = self.rt.index.partitioning.assign
                n_parts = len(self.rt.index.parts)
                for gq, ckey in miss_keys.items():
                    row = gather.pos[gq]
                    ids_row = gather.ids[row]
                    deps = np.unique(assign[ids_row[ids_row >= 0]])
                    cache.put(ckey, (ids_row.copy(),
                                     gather.dists[row].copy()),
                              parts=deps[deps < n_parts])
            resp = {"qidx": full_qidx, "ids": gather.ids,
                    "dists": gather.dists}
            rbuf = pl.encode_message(resp)
            # Responses are budgeted too: under the chunk policy an
            # oversized response paginates — each extra page is a warm
            # round-trip back to this (still-leased) container.
            n_pages = pl.response_chunks(
                len(rbuf), max_bytes=cfg.max_payload_bytes,
                policy=cfg.overflow)
            t_end = max(self.loop.now, t_ready)
            t_end += (n_pages - 1) * cfg.invoke_latency_warm_s
            self.nodes.append(NodeTrace(
                node=name, kind=kind, parent=parent, chunk=ci,
                t_issue=t_issue, t_start=t_start, t_end=t_end,
                invoke_s=inv, fetch_s=fetch_s, compute_s=compute_s,
                request_bytes=req_bytes, response_bytes=len(rbuf),
                warm=warm, dre_hit=hit, queries=int(full_qidx.shape[0]),
                own_queries=m_own, response_chunks=n_pages,
                cache_hits=len(hit_entries), **wallkw))
            self._record_node_span(
                sid, parent_sid, name, kind, ci, t_issue, t_start,
                t_avail, t_end, inv, fetch_s, compute_s, warm, wallkw,
                winfo)
            if lease is not None:
                self.loop.at(t_end, lambda: self.rt.qa_pool.release(lease))
            self.loop.at(t_end + self._tx(len(rbuf)),
                         lambda: respond_chunk(resp))

        def done() -> None:
            pending["n"] -= 1
            if pending["n"] == 0:
                finalize()

        # Children launch first (keep the tree expanding), then the node's
        # own QP fan-out once Alg. 1 has produced the request payloads.
        # The primary chunk (ci == 0) launches every child — the whole-fleet
        # tree launch is the Fig. 7 artifact — but overflow chunks forward
        # only to subtrees that actually hold some of their queries, and a
        # Coordinator whose batch was thinned by cache *hits* forwards only
        # to subtrees that still hold misses (a fully-hit batch launches no
        # tree at all). A cold cache (no hits) must reproduce the cache-off
        # fleet exactly, so the skip is gated on hits, not on cache_enabled.
        seq_t = t_avail
        for i, ch_id in enumerate(spec.children):
            ch = self.rt.topology[ch_id]
            clo, chi = self._qrange(*ch.id_range(self.rt.n_qa))
            mask = (qidx >= clo) & (qidx < chi)
            if (ci > 0 or hit_entries) and not mask.any():
                continue
            subreq = {"qidx": qidx[mask], "queries": queries[mask],
                      "preds": creq["preds"], "k": k}
            pending["n"] += 1

            def child_done(resp: Dict) -> None:
                gather.scatter(resp)
                done()

            if cfg.sequential and kind == "co":
                seq_t += self._invoke_allocator(ch, subreq, seq_t, name,
                                                child_done, parent_sid=sid)
            else:
                self._invoke_allocator(
                    ch, subreq, t_avail + i * cfg.invoke_stagger_s, name,
                    child_done, parent_sid=sid)

        for j, pid in enumerate(sorted(qp_requests)):
            qreq = qp_requests[pid]
            pending["n"] += 1

            def qp_done(resp: Dict, pid: int = pid) -> None:
                rows = own_gather.rows_of(resp["qidx"])
                own_streams[pid] = (rows, resp["ids"], resp["dists"])
                done()

            self._invoke_processor(pid, qreq,
                                   t_ready + j * cfg.invoke_stagger_s,
                                   name, qp_done, parent_sid=sid)

        if pending["n"] == 0:
            self.loop.at(t_ready, finalize)

    # ------------------------------------------------------- processor nodes

    def _invoke_processor(
        self,
        pid: int,
        req: Dict,
        t_issue: float,
        parent: str,
        respond: Callable[[Dict], None],
        parent_sid: Optional[str] = None,
    ) -> None:
        cfg = self.cfg
        chunks = pl.chunk_request(
            req, max_bytes=cfg.max_payload_bytes, policy=cfg.overflow,
            split=nd.split_processor_request,
            num_items=lambda r: r["qidx"].shape[0],
            fallback_split=nd.split_processor_rows,
            fallback_num=lambda r: int(r["rows"].shape[0]))
        gather = _ChunkGather(req["qidx"], self.k)
        state = {"left": len(chunks)}

        def chunk_done(ci: int, resp: Dict) -> None:
            gather.add(ci, resp)
            state["left"] -= 1
            if state["left"] == 0:
                ids, dists = gather.merged()
                respond({"qidx": req["qidx"], "ids": ids, "dists": dists})

        for ci, (creq, buf) in enumerate(chunks):
            sid = self.rec.new_span_id() if self.rec is not None else None
            pinv, lease = None, None
            if self.real:
                pinv = self.transport.submit(
                    f"qp:{pid}", payload=buf,
                    extra=pl.inject_span_context(
                        {"sleep_s": cfg.worker_sleep_s}, self._ctx(sid)))
                warm = pinv.predicted_warm
            else:
                # Versioned fetch key: index version + per-partition
                # generation, so invalidation and live mutations stale the
                # *fetch* retention too (not just derived state — the old
                # unversioned key let a warm container score a free DRE hit
                # on stale partition bytes after invalidate_cache()). The
                # stats delta merges in the handler, after the derived-hit
                # outcome lands on it.
                lease = self._acquire(
                    self.rt.qp_pools[pid],
                    (cfg.dataset_tag, f"part{pid}",
                     self.rt.index_version, self.rt._generation(pid)),
                    self.rt.qp_data_bytes(pid), merge=False)
                warm = lease.warm
            inv = self._invoke_overhead(warm)
            t_i = t_issue + ci * cfg.invoke_stagger_s
            t_start = t_i + inv + self._tx(len(buf))
            # Local handlers decode the wire bytes at collection (codec on
            # the hop's real path); process workers decode in-process.
            self.loop.at(t_start, lambda lease=lease, pinv=pinv,
                         buf=buf, inv=inv, ci=ci, t_i=t_i, t_start=t_start,
                         sid=sid:
                         self._processor_handler(
                             pid, parent, ci,
                             None if pinv else pl.decode_message(buf),
                             len(buf), lease, pinv,
                             inv, t_i, t_start, chunk_done,
                             sid=sid, parent_sid=parent_sid))

    def _processor_handler(
        self, pid, parent, ci, creq, req_bytes, lease, pinv, inv, t_issue,
        t_start, respond_chunk, sid=None, parent_sid=None,
    ) -> None:
        cfg = self.cfg
        t0 = time.perf_counter()  # squash: ignore[wallclock] -- measured wall-clock feeds the measured timeline/trace only; ids and SearchStats never depend on it
        if self.real:
            raw, winfo = pinv.result()
            resp, counters = wk.unpack_qp_response(raw)
            warm, hit, fetch_s = winfo.warm, winfo.state_hit, winfo.fetch_s
            # In a real worker, retained derived state (the device-resident
            # slice + traced plane) lives and dies with the process — a
            # state hit *is* a derived hit.
            self._merge_real_dre(winfo, self.rt.qp_data_bytes(pid),
                                 derived=True)
            setup_s = 0.0
            measured = winfo.compute_s
            t1 = time.perf_counter()  # squash: ignore[wallclock] -- measured wall-clock feeds the measured timeline/trace only; ids and SearchStats never depend on it
        else:
            # Derived-state retention (DRE beyond the fetch): a container
            # that already materialized this partition's device-resident
            # slice skips the setup step; DRE-off pays it on every
            # invocation. Keys embed the index version so invalidation
            # makes retained state stale.
            winfo = None
            warm, hit, fetch_s = lease.warm, lease.dre_hit, lease.fetch_s
            pool = self.rt.qp_pools[pid]
            setup_s = cfg.qp_setup_s
            if cfg.use_dre:
                dkey = ("stacked", pid, self.rt.index_version,
                        self.rt._generation(pid))
                if pool.derived_hit(lease, dkey):
                    setup_s = 0.0
                else:
                    pool.retain_derived(lease, dkey)
            # One merge of the per-call delta (Lease.stats), which now
            # carries the derived-hit outcome — pool.stats and the run's
            # DreStats stay consistent by construction.
            self.dre.merge(lease.stats)
            raw, linfo = self.transport.submit(
                f"qp:{pid}", request=creq,
                extra=pl.inject_span_context({}, self._ctx(sid))).result()
            resp, counters = raw
            winfo = linfo
            measured = linfo.compute_s
            t1 = time.perf_counter()  # squash: ignore[wallclock] -- measured wall-clock feeds the measured timeline/trace only; ids and SearchStats never depend on it
        t_avail = t_start + fetch_s + setup_s
        compute_s = measured if cfg.qp_compute_s is None else cfg.qp_compute_s
        t_end = t_avail + compute_s

        self.stats.hamming_in += counters["hamming_in"]
        self.stats.hamming_kept += counters["hamming_kept"]
        self.stats.adc_evals += counters["adc_evals"]
        self.stats.refined += counters["refined"]
        # Stage 5 reads full-precision rows from shared storage ('EFS').
        self.efs_reads += counters["refined"]
        self.efs_read_bytes += (counters["refined"] * self.rt.index.dim
                                * np.dtype(np.float32).itemsize)

        rbuf = pl.encode_message(resp)
        n_pages = pl.response_chunks(len(rbuf),
                                     max_bytes=cfg.max_payload_bytes,
                                     policy=cfg.overflow)
        t_end += (n_pages - 1) * cfg.invoke_latency_warm_s
        nq = int(resp["qidx"].shape[0])
        wallkw = self._wall_kw(winfo if self.real else None, t0, t1)
        self.nodes.append(NodeTrace(
            node=f"qp:{pid}", kind="qp", parent=parent, chunk=ci,
            t_issue=t_issue, t_start=t_start, t_end=t_end,
            invoke_s=inv, fetch_s=fetch_s, compute_s=compute_s,
            request_bytes=req_bytes, response_bytes=len(rbuf),
            warm=warm, dre_hit=hit,
            queries=nq, own_queries=nq,
            response_chunks=n_pages, setup_s=setup_s,
            hamming_in=counters["hamming_in"],
            hamming_kept=counters["hamming_kept"],
            adc_evals=counters["adc_evals"],
            refined=counters["refined"],
            **wallkw))
        self._record_node_span(
            sid, parent_sid, f"qp:{pid}", "qp", ci, t_issue, t_start,
            t_avail, t_end, inv, fetch_s, compute_s, warm, wallkw, winfo)
        if lease is not None:
            self.loop.at(t_end, lambda: self.rt.qp_pools[pid].release(lease))
        self.loop.at(t_end + self._tx(len(rbuf)),
                     lambda: respond_chunk(ci, resp))
