"""Per-node run traces + §3.5 cost assembly for the serverless runtime.

Every invocation (Coordinator, each QueryAllocator chunk, each
QueryProcessor chunk) leaves one :class:`NodeTrace` carrying its virtual
timeline, payload bytes, DRE outcome and billed duration. A finished run
folds them into a :class:`RunTrace`: the makespan, aggregate DRE stats, the
:class:`~repro.core.cost_model.LambdaFleet` inputs and the Eqs. 3–8 dollar
breakdown.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.core.cost_model import (LambdaFleet, PricingConstants,
                                   squash_query_cost)
from repro.core.dre import DreStats
from repro.core.pipeline import SearchStats

__all__ = ["NodeTrace", "RunTrace", "assemble_run_trace", "attribute_cost"]


def _from_fields(cls, data: Dict):
    """Build a dataclass from a dict, ignoring unknown keys (forward
    compatibility: a trace written by a newer build still loads)."""
    names = {f.name for f in dataclasses.fields(cls)}
    return cls(**{k: v for k, v in data.items() if k in names})


@dataclasses.dataclass
class NodeTrace:
    """One invocation's timeline (virtual seconds) and payload accounting."""

    node: str                 # "co", "qa:<id>", "qp:<pid>"
    kind: str                 # "co" | "qa" | "qp"
    parent: str               # invoking node's name ("client" for the CO)
    chunk: int                # chunk index within the logical request
    t_issue: float            # parent issued the invocation
    t_start: float            # container entered the handler
    t_end: float              # response sent (billing stops here)
    invoke_s: float           # cold/warm invocation overhead
    fetch_s: float            # DRE-miss S3 fetch time (0 on a hit)
    compute_s: float          # handler busy time (measured or configured)
    request_bytes: int
    response_bytes: int
    warm: bool
    dre_hit: bool
    queries: int              # queries carried by this chunk's request
    own_queries: int = 0      # queries in the node's *own* slice (QA/QP work)
    response_chunks: int = 1  # >1 → response exceeded the cap and paginated
    cache_hits: int = 0       # CO only: queries served from the §5.6 cache
    setup_s: float = 0.0      # QP derived-state build (0 on a retained hit)
    # Measured wall-clock twin of the modeled timeline (seconds relative to
    # the run's submit instant). Under LocalTransport these record where the
    # host actually spent time executing the virtual schedule; under
    # ProcessTransport they are the *real* distributed execution — submit →
    # wire → worker handler → response — so ``RunTrace`` can report modeled
    # vs measured side by side.
    wall_issue_s: float = 0.0
    wall_start_s: float = 0.0
    wall_end_s: float = 0.0
    wall_compute_s: float = 0.0
    worker_pid: int = 0       # OS pid of the serving worker (host pid local)
    worker_host: str = ""     # "host:port" that served it (socket transport)
    retries: int = 0          # re-invocations after worker crashes
    # QP pruning accounting (0 for CO/QA nodes): candidates entering the
    # Hamming stage, survivors of it, and ADC table evaluations — the knob
    # the autotune profile turns, so the §3.5 cost fold can attribute
    # GB-second savings to fewer ADC evals per invocation.
    hamming_in: int = 0
    hamming_kept: int = 0
    adc_evals: int = 0
    refined: int = 0          # stage-5 full-precision rows this node read

    @property
    def billed_s(self) -> float:
        """Lambda bills wall time from handler entry to response."""
        return max(self.t_end - self.t_start, 0.0)

    def to_json(self) -> Dict:
        """Plain JSON-able dict (all fields are scalars already)."""
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(data: Dict) -> "NodeTrace":
        return _from_fields(NodeTrace, data)


@dataclasses.dataclass
class RunTrace:
    """Aggregate accounting for one ``ServerlessRuntime.search`` run."""

    nodes: List[NodeTrace]
    makespan_s: float
    escalations: int          # (query, partition) visits past the Alg. 1 cut
    request_bytes: int
    response_bytes: int
    dre: DreStats
    efs_reads: int
    efs_read_bytes: int
    stats: SearchStats
    fleet: Optional[LambdaFleet] = None
    cost: Optional[Dict] = None
    cache_hits: int = 0       # queries served from the §5.6 result cache
    cache_misses: int = 0     # queries that traversed the Alg. 2 tree
    transport: str = "local"  # which Transport backend executed the run
    measured_makespan_s: float = 0.0   # real wall-clock of the whole search
    worker_retries: int = 0   # Σ re-invocations after worker crashes
    # Per-node dollar attribution: one row per invocation (plus a synthetic
    # "co" row when a run billed the coordinator without tracing one), each
    # splitting the Eqs. 3–8 components. Rows sum to ``cost`` — see
    # :func:`attribute_cost`.
    dollars_attributed: Optional[List[Dict]] = None

    @property
    def payload_bytes(self) -> int:
        return self.request_bytes + self.response_bytes

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def invocations(self, kind: Optional[str] = None) -> int:
        return sum(1 for n in self.nodes if kind is None or n.kind == kind)

    @property
    def worker_hosts(self) -> List[str]:
        """Distinct hosts that served this run (socket transport; else [])."""
        return sorted({n.worker_host for n in self.nodes if n.worker_host})

    def to_json(self) -> Dict:
        """JSON-able dict; inverse of :meth:`from_json`.

        ``cost`` is already a plain dict; the nested dataclasses
        (``nodes``/``dre``/``stats``/``fleet``) flatten via ``asdict``.
        """
        out = dataclasses.asdict(self)
        out["nodes"] = [n.to_json() for n in self.nodes]
        out["fleet"] = (None if self.fleet is None
                        else dataclasses.asdict(self.fleet))
        return out

    @staticmethod
    def from_json(data: Dict) -> "RunTrace":
        data = dict(data)
        data["nodes"] = [NodeTrace.from_json(n) for n in data.get("nodes", ())]
        data["dre"] = _from_fields(DreStats, data.get("dre") or {})
        data["stats"] = _from_fields(SearchStats, data.get("stats") or {})
        fleet = data.get("fleet")
        data["fleet"] = None if fleet is None else _from_fields(LambdaFleet,
                                                                fleet)
        return _from_fields(RunTrace, data)


def _distribute(rows: List[Dict], key: str, weights: List[float],
                total: float) -> None:
    """Split ``total`` over ``rows[key]`` proportional to ``weights``.

    Zero totals distribute nothing; an all-zero weight vector falls back to
    a uniform split (the component was billed but no node claimed it). The
    float residual of the proportional split lands on the largest share, so
    the rows sum back to ``total`` to within one rounding of the final add.
    """
    if not total or not rows:
        return
    w_sum = math.fsum(weights)
    if w_sum <= 0.0:
        weights = [1.0] * len(rows)
        w_sum = float(len(rows))
    shares = [total * w / w_sum for w in weights]
    big = max(range(len(shares)), key=lambda i: shares[i])
    shares[big] += total - math.fsum(shares)
    for row, share in zip(rows, shares):
        row[key] += share


def attribute_cost(nodes: List[NodeTrace], *, fleet: LambdaFleet,
                   cost: Dict, prices: PricingConstants) -> List[Dict]:
    """Fold the Eqs. 3–8 run cost back onto the invocations that caused it.

    Returns one row per node — ``{"node", "kind", "chunk", "invocation",
    "runtime", "s3", "efs", "total"}`` — whose component columns sum to the
    matching ``cost`` entries (and totals to ``cost["total"]``), so the
    dashboard's $/query view and the §3.5 aggregate can never disagree:

    * **invocation** — each QA/QP node is one Lambda invocation; the cost
      model's ``+1`` coordinator charge splits over the CO's chunks (a
      synthetic CO row is added when the model billed a coordinator but no
      CO node ran, e.g. the empty-batch trace).
    * **runtime** — each node's own ``billed_s × mem_mb`` GB-seconds.
    * **s3** — DRE-miss gets, weighted by each miss's fetch time (uniform
      over the misses when fetches were instantaneous).
    * **efs** — stage-5 refinement reads, weighted by each node's
      ``refined`` row count (falling back to ``adc_evals``, then uniform
      over QP nodes, when refinement accounting is absent).
    """
    mem_mb = {"qa": fleet.mem_qa_mb, "qp": fleet.mem_qp_mb,
              "co": fleet.mem_co_mb}
    rows = [{"node": n.node, "kind": n.kind, "chunk": n.chunk,
             "invocation": 0.0, "runtime": 0.0, "s3": 0.0, "efs": 0.0}
            for n in nodes]
    billed = [n.billed_s for n in nodes]
    if not any(n.kind == "co" for n in nodes):
        rows.append({"node": "co", "kind": "co", "chunk": -1,
                     "invocation": 0.0, "runtime": 0.0, "s3": 0.0,
                     "efs": 0.0})
        billed.append(0.0)

    # Invocations: one per QA/QP node, one (total) for the coordinator.
    per_inv = prices.lambda_per_invocation
    n_co = sum(1 for r in rows if r["kind"] == "co")
    for row in rows:
        row["invocation"] = (per_inv / n_co if row["kind"] == "co"
                             else per_inv)
    big = max(range(len(rows)), key=lambda i: rows[i]["invocation"])
    rows[big]["invocation"] += (cost["lambda_invocation"]
                                - math.fsum(r["invocation"] for r in rows))

    # Runtime: each node's own GB-seconds (residual → largest consumer).
    _distribute(rows, "runtime",
                [b * mem_mb[r["kind"]] for r, b in zip(rows, billed)],
                cost["lambda_runtime"])

    # S3: DRE misses, weighted by fetch time; uniform over misses when the
    # modeled fetches were free.
    s3_w = [0.0 if n.dre_hit else n.fetch_s for n in nodes]
    if math.fsum(s3_w) <= 0.0:
        s3_w = [0.0 if n.dre_hit else 1.0 for n in nodes]
    s3_w += [0.0] * (len(rows) - len(nodes))
    _distribute(rows, "s3", s3_w, cost["s3"])

    # EFS: refinement reads; adc_evals approximates when refined counts are
    # missing (older traces), then uniform over the QP fleet.
    efs_w = [float(n.refined) for n in nodes]
    if math.fsum(efs_w) <= 0.0:
        efs_w = [float(n.adc_evals) for n in nodes]
    if math.fsum(efs_w) <= 0.0:
        efs_w = [1.0 if n.kind == "qp" else 0.0 for n in nodes]
    efs_w += [0.0] * (len(rows) - len(nodes))
    _distribute(rows, "efs", efs_w, cost["efs"])

    for row in rows:
        row["total"] = math.fsum((row["invocation"], row["runtime"],
                                  row["s3"], row["efs"]))
    big = max(range(len(rows)), key=lambda i: rows[i]["total"])
    rows[big]["total"] += (cost["total"]
                           - math.fsum(r["total"] for r in rows))
    return rows


def assemble_run_trace(
    nodes: List[NodeTrace],
    *,
    makespan_s: float,
    escalations: int,
    dre: DreStats,
    efs_reads: int,
    efs_read_bytes: int,
    stats: SearchStats,
    mem_qa_mb: int,
    mem_qp_mb: int,
    mem_co_mb: int,
    prices: PricingConstants,
    cache_hits: int = 0,
    cache_misses: int = 0,
    transport: str = "local",
    measured_makespan_s: float = 0.0,
) -> RunTrace:
    """Fold node traces into fleet inputs and the Eqs. 3–8 breakdown."""
    t_qa = sum(n.billed_s for n in nodes if n.kind == "qa")
    t_qp = sum(n.billed_s for n in nodes if n.kind == "qp")
    t_co = sum(n.billed_s for n in nodes if n.kind == "co")
    fleet = LambdaFleet(
        n_qa=sum(1 for n in nodes if n.kind == "qa"),
        n_qp=sum(1 for n in nodes if n.kind == "qp"),
        mem_qa_mb=mem_qa_mb,
        mem_qp_mb=mem_qp_mb,
        mem_co_mb=mem_co_mb,
        t_qa_s=t_qa,
        t_qp_s=t_qp,
        t_co_s=t_co,
        s3_gets=dre.s3_gets,
        efs_reads=efs_reads,
        efs_read_bytes=efs_read_bytes,
    )
    cost = squash_query_cost(fleet, prices)
    return RunTrace(
        nodes=nodes,
        makespan_s=makespan_s,
        escalations=escalations,
        request_bytes=sum(n.request_bytes for n in nodes),
        response_bytes=sum(n.response_bytes for n in nodes),
        dre=dre,
        efs_reads=efs_reads,
        efs_read_bytes=efs_read_bytes,
        stats=stats,
        fleet=fleet,
        cost=cost,
        dollars_attributed=attribute_cost(nodes, fleet=fleet, cost=cost,
                                          prices=prices),
        cache_hits=cache_hits,
        cache_misses=cache_misses,
        transport=transport,
        measured_makespan_s=measured_makespan_s,
        worker_retries=sum(n.retries for n in nodes),
    )
