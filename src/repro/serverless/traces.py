"""Per-node run traces + §3.5 cost assembly for the serverless runtime.

Every invocation (Coordinator, each QueryAllocator chunk, each
QueryProcessor chunk) leaves one :class:`NodeTrace` carrying its virtual
timeline, payload bytes, DRE outcome and billed duration. A finished run
folds them into a :class:`RunTrace`: the makespan, aggregate DRE stats, the
:class:`~repro.core.cost_model.LambdaFleet` inputs and the Eqs. 3–8 dollar
breakdown.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.cost_model import (LambdaFleet, PricingConstants,
                                   squash_query_cost)
from repro.core.dre import DreStats
from repro.core.pipeline import SearchStats

__all__ = ["NodeTrace", "RunTrace", "assemble_run_trace"]


def _from_fields(cls, data: Dict):
    """Build a dataclass from a dict, ignoring unknown keys (forward
    compatibility: a trace written by a newer build still loads)."""
    names = {f.name for f in dataclasses.fields(cls)}
    return cls(**{k: v for k, v in data.items() if k in names})


@dataclasses.dataclass
class NodeTrace:
    """One invocation's timeline (virtual seconds) and payload accounting."""

    node: str                 # "co", "qa:<id>", "qp:<pid>"
    kind: str                 # "co" | "qa" | "qp"
    parent: str               # invoking node's name ("client" for the CO)
    chunk: int                # chunk index within the logical request
    t_issue: float            # parent issued the invocation
    t_start: float            # container entered the handler
    t_end: float              # response sent (billing stops here)
    invoke_s: float           # cold/warm invocation overhead
    fetch_s: float            # DRE-miss S3 fetch time (0 on a hit)
    compute_s: float          # handler busy time (measured or configured)
    request_bytes: int
    response_bytes: int
    warm: bool
    dre_hit: bool
    queries: int              # queries carried by this chunk's request
    own_queries: int = 0      # queries in the node's *own* slice (QA/QP work)
    response_chunks: int = 1  # >1 → response exceeded the cap and paginated
    cache_hits: int = 0       # CO only: queries served from the §5.6 cache
    setup_s: float = 0.0      # QP derived-state build (0 on a retained hit)
    # Measured wall-clock twin of the modeled timeline (seconds relative to
    # the run's submit instant). Under LocalTransport these record where the
    # host actually spent time executing the virtual schedule; under
    # ProcessTransport they are the *real* distributed execution — submit →
    # wire → worker handler → response — so ``RunTrace`` can report modeled
    # vs measured side by side.
    wall_issue_s: float = 0.0
    wall_start_s: float = 0.0
    wall_end_s: float = 0.0
    wall_compute_s: float = 0.0
    worker_pid: int = 0       # OS pid of the serving worker (host pid local)
    worker_host: str = ""     # "host:port" that served it (socket transport)
    retries: int = 0          # re-invocations after worker crashes
    # QP pruning accounting (0 for CO/QA nodes): candidates entering the
    # Hamming stage, survivors of it, and ADC table evaluations — the knob
    # the autotune profile turns, so the §3.5 cost fold can attribute
    # GB-second savings to fewer ADC evals per invocation.
    hamming_in: int = 0
    hamming_kept: int = 0
    adc_evals: int = 0

    @property
    def billed_s(self) -> float:
        """Lambda bills wall time from handler entry to response."""
        return max(self.t_end - self.t_start, 0.0)

    def to_json(self) -> Dict:
        """Plain JSON-able dict (all fields are scalars already)."""
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(data: Dict) -> "NodeTrace":
        return _from_fields(NodeTrace, data)


@dataclasses.dataclass
class RunTrace:
    """Aggregate accounting for one ``ServerlessRuntime.search`` run."""

    nodes: List[NodeTrace]
    makespan_s: float
    escalations: int          # (query, partition) visits past the Alg. 1 cut
    request_bytes: int
    response_bytes: int
    dre: DreStats
    efs_reads: int
    efs_read_bytes: int
    stats: SearchStats
    fleet: Optional[LambdaFleet] = None
    cost: Optional[Dict] = None
    cache_hits: int = 0       # queries served from the §5.6 result cache
    cache_misses: int = 0     # queries that traversed the Alg. 2 tree
    transport: str = "local"  # which Transport backend executed the run
    measured_makespan_s: float = 0.0   # real wall-clock of the whole search
    worker_retries: int = 0   # Σ re-invocations after worker crashes

    @property
    def payload_bytes(self) -> int:
        return self.request_bytes + self.response_bytes

    @property
    def cache_hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def invocations(self, kind: Optional[str] = None) -> int:
        return sum(1 for n in self.nodes if kind is None or n.kind == kind)

    @property
    def worker_hosts(self) -> List[str]:
        """Distinct hosts that served this run (socket transport; else [])."""
        return sorted({n.worker_host for n in self.nodes if n.worker_host})

    def to_json(self) -> Dict:
        """JSON-able dict; inverse of :meth:`from_json`.

        ``cost`` is already a plain dict; the nested dataclasses
        (``nodes``/``dre``/``stats``/``fleet``) flatten via ``asdict``.
        """
        out = dataclasses.asdict(self)
        out["nodes"] = [n.to_json() for n in self.nodes]
        out["fleet"] = (None if self.fleet is None
                        else dataclasses.asdict(self.fleet))
        return out

    @staticmethod
    def from_json(data: Dict) -> "RunTrace":
        data = dict(data)
        data["nodes"] = [NodeTrace.from_json(n) for n in data.get("nodes", ())]
        data["dre"] = _from_fields(DreStats, data.get("dre") or {})
        data["stats"] = _from_fields(SearchStats, data.get("stats") or {})
        fleet = data.get("fleet")
        data["fleet"] = None if fleet is None else _from_fields(LambdaFleet,
                                                                fleet)
        return _from_fields(RunTrace, data)


def assemble_run_trace(
    nodes: List[NodeTrace],
    *,
    makespan_s: float,
    escalations: int,
    dre: DreStats,
    efs_reads: int,
    efs_read_bytes: int,
    stats: SearchStats,
    mem_qa_mb: int,
    mem_qp_mb: int,
    mem_co_mb: int,
    prices: PricingConstants,
    cache_hits: int = 0,
    cache_misses: int = 0,
    transport: str = "local",
    measured_makespan_s: float = 0.0,
) -> RunTrace:
    """Fold node traces into fleet inputs and the Eqs. 3–8 breakdown."""
    t_qa = sum(n.billed_s for n in nodes if n.kind == "qa")
    t_qp = sum(n.billed_s for n in nodes if n.kind == "qp")
    t_co = sum(n.billed_s for n in nodes if n.kind == "co")
    fleet = LambdaFleet(
        n_qa=sum(1 for n in nodes if n.kind == "qa"),
        n_qp=sum(1 for n in nodes if n.kind == "qp"),
        mem_qa_mb=mem_qa_mb,
        mem_qp_mb=mem_qp_mb,
        mem_co_mb=mem_co_mb,
        t_qa_s=t_qa,
        t_qp_s=t_qp,
        t_co_s=t_co,
        s3_gets=dre.s3_gets,
        efs_reads=efs_reads,
        efs_read_bytes=efs_read_bytes,
    )
    return RunTrace(
        nodes=nodes,
        makespan_s=makespan_s,
        escalations=escalations,
        request_bytes=sum(n.request_bytes for n in nodes),
        response_bytes=sum(n.response_bytes for n in nodes),
        dre=dre,
        efs_reads=efs_reads,
        efs_read_bytes=efs_read_bytes,
        stats=stats,
        fleet=fleet,
        cost=squash_query_cost(fleet, prices),
        cache_hits=cache_hits,
        cache_misses=cache_misses,
        transport=transport,
        measured_makespan_s=measured_makespan_s,
        worker_retries=sum(n.retries for n in nodes),
    )
