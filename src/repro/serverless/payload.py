"""Request/response payload codec + byte budgets (paper §3.3 payload flow).

Every hop in the invocation tree exchanges *encoded* payloads: a JSON header
(scalars, predicate lists, array manifest) followed by raw C-contiguous
array buffers. Encoding is what gives the runtime honest byte accounting —
the 6 MB synchronous-invocation cap AWS Lambda enforces is applied to the
encoded size, with an explicit overflow policy:

* ``"error"`` — raise :class:`PayloadOverflowError` (the deploy-time guard).
* ``"chunk"`` — split the request on its query axis into multiple
  invocations of the same function (each chunk pays its own invocation
  overhead and payload transfer; responses merge by global query index).
  An oversized *response* paginates instead: :func:`response_chunks` tells
  the runtime how many pages to bill as warm round-trips.

A payload that cannot be split further (a single query) always raises.

The module also defines the **length-prefixed frame protocol** the socket
transport speaks over TCP (``serverless.socket_transport`` on the client
side, ``repro.serverless.host`` on the server side): one frame = a 1-byte
kind tag + a little-endian uint32 body length + the body. Request/response
frames carry ``encode_message`` bytes and are held to the same 6 MB budget
the in-process hops model; INIT frames carry the function *deployment* (the
pickled ``WorkerInit`` bundle — the analogue of the S3 code package, not a
synchronous invocation payload) and are budget-exempt.
"""

from __future__ import annotations

import json
import pickle
import struct
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.core.attributes import Predicate

__all__ = [
    "MAX_SYNC_PAYLOAD_BYTES", "OVERFLOW_POLICIES", "PayloadOverflowError",
    "encode_message", "decode_message", "chunk_request", "response_chunks",
    "predicates_to_json", "predicates_from_json",
    "OBS_EXTRA_KEY", "inject_span_context", "extract_span_context",
    "FRAME_INIT", "FRAME_REQ", "FRAME_RESP", "FRAME_PING", "FRAME_PONG",
    "FRAME_SHUTDOWN", "FRAME_STATS", "write_frame", "read_frame",
    "encode_init", "decode_init",
]

# AWS Lambda request/response limit for synchronous invocations (6 MB).
MAX_SYNC_PAYLOAD_BYTES = 6 * 1024 * 1024

OVERFLOW_POLICIES = ("error", "chunk")

_MAGIC = b"SQP1"


class PayloadOverflowError(RuntimeError):
    """A payload exceeded the per-invocation byte budget and could not be
    (or was configured not to be) chunked."""


def encode_message(msg: Dict) -> bytes:
    """Serialize a flat dict of numpy arrays + JSON-able scalars."""
    arrays: List[Tuple[str, np.ndarray]] = []
    meta: Dict = {}
    for key, val in msg.items():
        if isinstance(val, np.ndarray):
            arrays.append((key, np.ascontiguousarray(val)))
        elif isinstance(val, (np.integer, np.floating)):
            meta[key] = val.item()
        else:
            meta[key] = val
    header = {
        "meta": meta,
        "arrays": [
            {"name": k, "dtype": a.dtype.str, "shape": list(a.shape)}
            for k, a in arrays
        ],
    }
    hb = json.dumps(header, separators=(",", ":")).encode("utf-8")
    out = [_MAGIC, struct.pack("<I", len(hb)), hb]
    out.extend(a.tobytes() for _, a in arrays)
    return b"".join(out)


def decode_message(buf: bytes) -> Dict:
    """Inverse of :func:`encode_message` (arrays come back bit-identical)."""
    if buf[:4] != _MAGIC:
        raise ValueError("not a SQUASH payload (bad magic)")
    (hlen,) = struct.unpack("<I", buf[4:8])
    header = json.loads(buf[8 : 8 + hlen].decode("utf-8"))
    msg: Dict = dict(header["meta"])
    off = 8 + hlen
    for spec in header["arrays"]:
        dt = np.dtype(spec["dtype"])
        shape = tuple(spec["shape"])
        nbytes = dt.itemsize * int(np.prod(shape, dtype=np.int64))
        msg[spec["name"]] = np.frombuffer(
            buf[off : off + nbytes], dtype=dt
        ).reshape(shape).copy()
        off += nbytes
    return msg


def chunk_request(
    req: Dict,
    *,
    max_bytes: int,
    policy: str,
    split: Callable[[Dict, int, int], Dict],
    num_items: Callable[[Dict], int],
    fallback_split: Callable[[Dict, int, int], Dict] = None,
    fallback_num: Callable[[Dict], int] = None,
) -> List[Tuple[Dict, bytes]]:
    """Encode ``req``; on overflow apply the policy.

    ``split(req, lo, hi)`` must return the sub-request covering item
    positions [lo, hi) of the splittable axis (queries); ``num_items`` its
    length. When a *single-item* request still overflows and a fallback axis
    is provided (``fallback_split``/``fallback_num`` — the QP requests'
    candidate-row axis inside one partition), chunking recurses along it
    instead of erroring; a request indivisible on every axis always raises.
    Returns [(request, encoded_bytes), ...] — one entry per invocation the
    caller must issue.
    """
    if policy not in OVERFLOW_POLICIES:
        raise ValueError(f"unknown overflow policy {policy!r}; "
                         f"expected {OVERFLOW_POLICIES}")
    out: List[Tuple[Dict, bytes]] = []

    def rec(r: Dict) -> None:
        buf = encode_message(r)
        if len(buf) <= max_bytes:
            out.append((r, buf))
            return
        n = num_items(r)
        if policy != "error" and n > 1:
            rec(split(r, 0, n // 2))
            rec(split(r, n // 2, n))
            return
        if policy != "error" and fallback_split is not None:
            m = fallback_num(r)
            if m > 1:
                rec(fallback_split(r, 0, m // 2))
                rec(fallback_split(r, m // 2, m))
                return
        raise PayloadOverflowError(
            f"request payload of {len(buf)} B exceeds the "
            f"{max_bytes} B budget"
            + ("" if policy == "chunk"
               else " (overflow policy 'error')")
            + (" and cannot be split further"
               if policy == "chunk" and n <= 1 else "")
        )

    rec(req)
    return out


def response_chunks(nbytes: int, *, max_bytes: int, policy: str) -> int:
    """Number of response payloads needed; raises under the error policy."""
    if nbytes <= max_bytes:
        return 1
    if policy == "error":
        raise PayloadOverflowError(
            f"response payload of {nbytes} B exceeds the {max_bytes} B budget "
            "(overflow policy 'error')")
    return -(-nbytes // max_bytes)


# ------------------------------------------------------- span-context envelope

# Key under which a distributed-trace span context rides the invocation's
# ``extra`` envelope. The context travels *outside* the budgeted payload —
# pickled with ``extra`` over process pipes, as JSON meta inside the socket
# REQ frame's codec wrapper (FRAME_SLACK headroom) — so request-byte
# accounting and the 6 MB budget are bitwise-identical with tracing on or
# off. The value is a plain ``{"run": ..., "span": ...}`` dict
# (``repro.obs.spans.SpanContext.to_wire``); this module stays
# dependency-free by not importing the obs layer.
OBS_EXTRA_KEY = "obs"


def inject_span_context(extra: Dict, ctx: Dict) -> Dict:
    """Attach a span context to an invocation's ``extra`` envelope.

    Mutates and returns ``extra``. A falsy ``ctx`` (tracing disabled) leaves
    the envelope untouched, so disabled runs serialize identical bytes.
    """
    if ctx:
        extra[OBS_EXTRA_KEY] = dict(ctx)
    return extra


def extract_span_context(extra) -> Dict:
    """The span context carried by ``extra``, or None (worker side)."""
    if not extra:
        return None
    ctx = extra.get(OBS_EXTRA_KEY)
    return dict(ctx) if ctx else None


# ------------------------------------------------------------ socket frames

FRAME_INIT = b"I"       # function deployment: pickled WorkerInit (no budget)
FRAME_REQ = b"Q"        # one invocation request (codec body; budgeted)
FRAME_RESP = b"R"       # one invocation response page (codec body; budgeted)
FRAME_PING = b"P"       # client liveness probe (hang guard)
FRAME_PONG = b"O"       # host heartbeat / deploy-ack / ping answer
FRAME_SHUTDOWN = b"X"   # close this worker connection cleanly
# Fleet-telemetry pull (PR 10): the client sends an empty-body STATS frame;
# the host's receiver thread answers with a STATS frame whose body is
# ``encode_message({"os_pid": ..., "snapshot": <registry snapshot>})`` — the
# host process's *cumulative* metrics registry dump. Telemetry is control
# plane, like PING/PONG: it never rides the budgeted invocation payload, so
# request-byte accounting is identical with aggregation on or off.
FRAME_STATS = b"S"      # metrics-registry pull (request and reply)

_FRAME_HEADER = struct.Struct("<cI")

# REQ/RESP frames wrap the budgeted invocation payload in a small codec
# envelope (rid, extra, pagination fields); the per-frame cap allows the
# envelope this much headroom so the *inner* payload is held to exactly the
# Lambda budget, with no double-counting of wrapper bytes.
FRAME_SLACK = 64 * 1024


def write_frame(sock, kind: bytes, body: bytes = b"", *,
                max_bytes: int = None) -> None:
    """Send one length-prefixed frame; caller serializes access to ``sock``.

    ``max_bytes`` applies the per-frame payload budget at the socket layer
    itself — an over-budget body raises :class:`PayloadOverflowError` before
    any byte hits the wire, so a mis-chunked request can never sneak past
    the Lambda-style cap just because it travels over TCP.
    """
    if max_bytes is not None and len(body) > max_bytes:
        raise PayloadOverflowError(
            f"socket frame body of {len(body)} B exceeds the "
            f"{max_bytes} B per-frame budget")
    sock.sendall(_FRAME_HEADER.pack(kind, len(body)) + body)


def _recv_exact(sock, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("socket closed mid-frame")
        buf.extend(chunk)
    return bytes(buf)


def read_frame(sock) -> Tuple[bytes, bytes]:
    """Receive one frame → ``(kind, body)``; raises ConnectionError on EOF."""
    kind, length = _FRAME_HEADER.unpack(_recv_exact(sock, _FRAME_HEADER.size))
    return kind, _recv_exact(sock, length)


def encode_init(init, max_bytes: int) -> bytes:
    """Serialize a FRAME_INIT body: ``(WorkerInit bundle, payload budget)``.

    The deployment bundle carries arbitrary callables and index state, so it
    is the one wire body that legitimately needs pickle — confining the
    ``pickle.dumps`` here keeps every other module codec-only (the
    wire-discipline invariant squashlint enforces). INIT frames are exempt
    from the 6 MB budget: deployment is the control plane, not a Lambda
    invocation.
    """
    return pickle.dumps((init, max_bytes), protocol=pickle.HIGHEST_PROTOCOL)


def decode_init(body: bytes):
    """Inverse of :func:`encode_init` → ``(init, max_bytes)``.

    Only ever called by the worker host on its deployment socket — the
    connecting side is trusted (same user, loopback fleet); invocation
    request/response bodies never go through pickle.
    """
    return pickle.loads(body)


def predicates_to_json(predicates: Sequence[Predicate]) -> List[Dict]:
    return [
        {"attr": int(p.attr), "op": p.op, "lo": float(p.lo),
         "hi": float(p.hi), "values": [float(v) for v in p.values],
         "group": p.group}
        for p in predicates
    ]


def predicates_from_json(items: Sequence[Dict]) -> List[Predicate]:
    return [
        Predicate(attr=int(d["attr"]), op=d["op"], lo=d["lo"], hi=d["hi"],
                  values=tuple(d["values"]), group=d["group"])
        for d in items
    ]
