"""Pluggable invocation transports for the serverless runtime (§3.3/§4).

The choreography in ``runtime.py`` decides *what* to invoke (the Alg. 2
tree, payload chunks, QP fan-out); a :class:`Transport` decides *where and
when the function bodies run*:

* :class:`LocalTransport` — the in-process backend behind the virtual-time
  scheduler (``events.EventLoop``): handler bodies run inline at collection
  time, warm/cold and S3-fetch economics are simulated by the
  ``core.dre.ContainerPool`` leases the runtime holds. This is the modeled
  execution PRs 2–4 built.
* :class:`ProcessTransport` — a real worker-pool backend: one long-lived
  ``multiprocessing`` process per QueryProcessor partition plus a pool for
  the shared allocator function. Payloads cross the process boundary
  codec-encoded; submissions are **eager** so one QA wave's processors
  genuinely execute concurrently (the sequential Fig. 7 strawman instead
  defers each send to collection, serializing the fleet for an honest
  measured comparison); warm starts and data retention are *real* — keyed
  to the worker's OS pid and observed from the worker's own report — and a
  crashed worker is detected (pipe EOF / process sentinel), respawned cold,
  and its in-flight invocations re-sent under a bounded retry budget.

Both transports expose the same contract::

    inv = transport.submit(fn, payload=wire_bytes, extra={...})
    response_dict, info = inv.result()      # InvokeInfo: pid/warm/fetch/…
    transport.invoke(fn, ...)               # submit + result shorthand

so the runtime's traces can report the modeled §3.5 timeline and the
measured wall-clock one side by side from a single choreography.
"""

from __future__ import annotations

import dataclasses
import itertools
import multiprocessing as mp
import multiprocessing.connection as mpc
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.obs.metrics import DEFAULT_BYTES_BUCKETS
from repro.obs.metrics import REGISTRY as _METRICS
from repro.serverless import payload as pl
from repro.serverless import workers as wk

__all__ = ["TransportError", "InvokeInfo", "Transport", "LocalTransport",
           "ProcessTransport", "TRANSPORTS"]

# Backend registry: "socket" is implemented by
# serverless.socket_transport.SocketTransport (imported lazily by the
# runtime so the TCP machinery never loads for in-process runs).
TRANSPORTS = ("local", "process", "socket")


class TransportError(RuntimeError):
    """An invocation could not be completed (worker crash budget exhausted,
    handler exception crossing the wire, or a response timeout)."""


@dataclasses.dataclass
class InvokeInfo:
    """Measured facts about one completed invocation.

    ``warm``/``state_hit`` are *real* under ProcessTransport (reported by
    the worker that served the request); LocalTransport leaves them False —
    its warm/cold economics are simulated by the runtime's container pools.
    Wall times are absolute ``perf_counter`` values.
    """

    os_pid: int
    warm: bool
    state_hit: bool
    fetch_s: float
    compute_s: float
    retries: int
    wall_submit: float
    wall_sent: float
    wall_done: float
    host: str = ""       # "host:port" that served it (SocketTransport only)
    # Worker-side sub-spans for distributed tracing: the ``info["obs"]``
    # dict the worker shipped back ({"run", "parent", "spans": [[name, t0,
    # t1], ...]} with offsets relative to handler entry), or None when the
    # request carried no span context. The runtime stitches these into the
    # RunTrace's span tree; nothing else reads them.
    spans: Optional[Dict] = None


class Transport:
    """Interface both backends implement (duck-typed; no ABC machinery)."""

    kind: str = "?"

    def submit(self, fn: str, *, request: Optional[Dict] = None,
               payload: Optional[bytes] = None,
               extra: Optional[Dict] = None):
        raise NotImplementedError

    def invoke(self, fn: str, **kw) -> Tuple[Dict, InvokeInfo]:
        return self.submit(fn, **kw).result()

    def collect_metrics(self) -> Dict[str, Dict]:
        """Pull remote registries into the local one (fleet telemetry).

        Backends whose workers cannot push telemetry on their responses
        override this (SocketTransport's STATS pull); LocalTransport has no
        remote processes and ProcessTransport's pipe workers echo registry
        deltas on every response instead, so the default is a no-op.
        """
        return {}

    def close(self) -> None:  # pragma: no cover - trivial default
        pass


# ------------------------------------------------------------------- local

class _LocalInvocation:
    def __init__(self, transport: "LocalTransport", fn: str,
                 request: Optional[Dict], payload: Optional[bytes],
                 extra: Optional[Dict]):
        self._transport = transport
        self.fn = fn
        self._request = request
        self._payload = payload
        self.extra = extra or {}
        self.predicted_warm = False
        self.t_submit = time.perf_counter()

    def result(self):
        t0 = time.perf_counter()
        req = (self._request if self._request is not None
               else pl.decode_message(self._payload))
        role = self.fn.split(":", 1)[0]
        resp = self._transport.handlers[role](self.fn, req, self.extra)
        t1 = time.perf_counter()
        spans = None
        ctx = pl.extract_span_context(self.extra)
        if ctx is not None:
            # Inline execution has no worker boundary; synthesize the one
            # sub-span that exists (the handler body) so traces from all
            # three transports stitch through the same code path.
            spans = {"run": ctx["run"], "parent": ctx["span"],
                     "spans": [["compute", 0.0, t1 - t0]]}
        _METRICS.counter("transport.local.submits").inc()
        _METRICS.histogram("transport.local.invoke_s").observe(
            t1 - self.t_submit)
        info = InvokeInfo(
            os_pid=os.getpid(), warm=False, state_hit=False,
            fetch_s=0.0, compute_s=t1 - t0, retries=0,
            wall_submit=self.t_submit, wall_sent=t0, wall_done=t1,
            spans=spans)
        return resp, info


class LocalTransport(Transport):
    """Inline execution: the handler body runs in the caller's interpreter.

    Laziness is the point — nothing runs at ``submit``; the body executes
    when the virtual-time scheduler collects the result, so the modeled
    timeline drives host execution order exactly as in PRs 2–4.
    """

    kind = "local"

    def __init__(self, handlers: Dict[str, Callable[[str, Dict, Dict], Dict]]):
        self.handlers = handlers

    def submit(self, fn, *, request=None, payload=None, extra=None):
        return _LocalInvocation(self, fn, request, payload, extra)


# ------------------------------------------------------------------ process

class _Worker:
    """One live worker process + its two simplex pipes."""

    def __init__(self, ctx, init: wk.WorkerInit):
        req_r, req_w = ctx.Pipe(duplex=False)
        resp_r, resp_w = ctx.Pipe(duplex=False)
        self.proc = ctx.Process(
            target=wk.worker_main, args=(init, req_r, resp_w), daemon=True,
            name=f"squash-{init.fn.replace(':', '-')}")
        self.proc.start()
        req_r.close()
        resp_w.close()
        self.req_conn = req_w        # parent → worker requests
        self.resp_conn = resp_r      # worker → parent responses
        self.init = init
        self.fn = init.fn
        self.assigned = 0            # guarded-by: _lock -- routed (sent or queued)
        self.done = 0                # guarded-by: _lock -- responses received
        self.dead = False            # guarded-by: _lock
        self.send_lock = threading.Lock()

    @property
    def inflight(self) -> int:  # squash: holds[_lock]
        return self.assigned - self.done


class _Pending:
    def __init__(self, rid: int, fn: str, payload: bytes, extra: Dict):
        self.rid = rid
        self.fn = fn
        self.payload = payload
        self.extra = extra
        self.worker: Optional[_Worker] = None  # guarded-by: _lock
        self.retries = 0
        self.sent = False                      # guarded-by: _lock
        self.event = threading.Event()
        self.value = None
        self.error: Optional[Exception] = None
        self.t_submit = time.perf_counter()
        self.t_sent = 0.0
        self.t_done = 0.0

    @property
    def resolved(self) -> bool:
        return self.event.is_set()

    def resolve(self, data, winfo) -> None:
        self.value = (data, winfo)
        self.t_done = time.perf_counter()
        self.event.set()

    def fail(self, exc: Exception) -> None:
        self.error = exc
        self.t_done = time.perf_counter()
        self.event.set()


class _ProcessInvocation:
    def __init__(self, transport: "ProcessTransport", pending: _Pending,
                 predicted_warm: bool):
        self._transport = transport
        self._pending = pending
        self.fn = pending.fn
        self.extra = pending.extra
        self.predicted_warm = predicted_warm

    def result(self):
        t = self._transport
        p = self._pending  # squash: ignore[lock-guarded-access] -- name collision: the invocation's own _Pending object (bound once at construction), not the transport's guarded dict
        if not p.sent and not p.resolved:  # squash: ignore[lock-guarded-access] -- lock-free fast path: a stale False only makes _send re-check (it exits on sent/resolved); a stale True means the send already happened
            t._send(p)                       # lazy (sequential) mode
        if not p.event.wait(t.invoke_timeout_s):
            timed_out = False
            with t._lock:
                # Re-check under the lock: the response may have landed
                # between the wait expiring and us acquiring the lock.
                if not p.resolved:
                    # Forget it AND rebalance its worker: dropping only the
                    # pending left ``assigned`` permanently inflated, so the
                    # least-loaded routing shunned a hung worker forever
                    # (even after it recovered) — and a late response was
                    # double-booked into ``done`` for a request nobody
                    # awaits, skewing inflight negative.
                    t._pending.pop(p.rid, None)
                    if p.worker is not None:
                        p.worker.assigned -= 1
                    if p.sent:
                        t._timed_out[p.rid] = p.worker
                    timed_out = True
            if timed_out:
                _METRICS.counter(f"transport.{t.kind}.timeouts").inc()
                raise TransportError(
                    f"invocation of {p.fn!r} timed out after "
                    f"{t.invoke_timeout_s:.0f}s (worker pool hung?)")
        if p.error is not None:
            raise p.error
        data, winfo = p.value
        resp = pl.decode_message(data)
        _METRICS.histogram(f"transport.{t.kind}.invoke_s").observe(
            p.t_done - p.t_submit)
        # Fleet telemetry: a pipe worker serving an obs-enabled request
        # echoes its registry delta since the previous echo; absorb it
        # under the worker's pid so fleet_snapshot() can label the source.
        wmetrics = winfo.get("metrics")
        if wmetrics:
            _METRICS.absorb_snapshot(
                wmetrics, source=f"pid:{int(winfo['os_pid'])}")
        info = InvokeInfo(
            os_pid=int(winfo["os_pid"]),
            warm=int(winfo["served_before"]) > 0,
            state_hit=bool(winfo["state_hit"]),
            fetch_s=float(winfo["fetch_s"]),
            compute_s=float(winfo["compute_s"]),
            retries=p.retries,
            wall_submit=p.t_submit,
            wall_sent=p.t_sent or p.t_submit,
            wall_done=p.t_done,
            spans=winfo.get("obs"))
        return resp, info


class ProcessTransport(Transport):
    """Real multi-process worker-pool backend (see module docstring)."""

    kind = "process"

    def __init__(
        self,
        inits: Dict[str, Tuple[wk.WorkerInit, int]],
        *,
        eager: bool = True,
        start_method: str = "spawn",
        invoke_timeout_s: float = 180.0,
        max_retries: int = 2,
    ):
        self._ctx = mp.get_context(start_method)
        self.eager = eager
        self.invoke_timeout_s = invoke_timeout_s
        self.max_retries = max_retries
        self._rid = itertools.count()
        self._lock = threading.Lock()
        self._pending: Dict[int, _Pending] = {}   # guarded-by: _lock
        self._timed_out: Dict[int, _Worker] = {}  # guarded-by: _lock -- dropped
                                                  # on timeout; a late response
                                                  # must not re-book
        self._dead_births: Dict[str, int] = {}   # guarded-by: _lock
        self._respawning: Dict[str, int] = {}    # guarded-by: _lock
        self._closed = False                     # guarded-by: _lock
        self._workers: Dict[str, List[_Worker]] = {  # guarded-by: _lock
            fn: [_Worker(self._ctx, init) for _ in range(count)]
            for fn, (init, count) in inits.items()
        }
        self._collector = threading.Thread(
            target=self._collect_loop, daemon=True,
            name="squash-transport-collector")
        self._collector.start()

    # ------------------------------------------------------------ submission

    def submit(self, fn, *, request=None, payload=None, extra=None):
        if payload is None:
            payload = pl.encode_message(request)
        _METRICS.counter(f"transport.{self.kind}.submits").inc()
        _METRICS.histogram(f"transport.{self.kind}.request_bytes",
                           buckets=DEFAULT_BYTES_BUCKETS).observe(len(payload))
        pending = _Pending(next(self._rid), fn, payload, dict(extra or {}))
        deadline = time.perf_counter() + min(self.invoke_timeout_s, 30.0)
        while True:
            with self._lock:
                # Checked under the same lock that registers the pending: a
                # submit racing close() used to insert into _pending *after*
                # close had failed-and-cleared it, leaving an invocation
                # whose result() blocked the full invoke_timeout_s.
                if self._closed:
                    raise TransportError("transport is closed")
                worker = self._pick(fn)
                if worker is not None:
                    predicted_warm = worker.assigned > 0 or worker.done > 0
                    pending.worker = worker
                    worker.assigned += 1
                    self._pending[pending.rid] = pending
                    break
            # The pool's only worker died and its replacement is still being
            # spawned (outside the lock) — wait for it rather than erroring.
            if time.perf_counter() > deadline:
                raise TransportError(
                    f"no live worker for {fn!r} (respawn stuck?)")
            time.sleep(0.01)
        if self.eager:
            self._send(pending)
        return _ProcessInvocation(self, pending, predicted_warm)

    def _pick(self, fn: str) -> Optional[_Worker]:  # squash: holds[_lock]
        """Least-loaded live worker; None while a respawn is in flight."""
        if fn not in self._workers:
            raise TransportError(f"no worker pool for function {fn!r}")
        pool = [w for w in self._workers[fn] if not w.dead]
        if not pool:
            if self._respawning.get(fn, 0) > 0:
                return None
            raise TransportError(
                f"no live worker for {fn!r} (pool exceeded its respawn "
                f"budget)")
        return min(pool, key=lambda w: (w.inflight, w.assigned))

    def _send(self, pending: _Pending) -> None:
        """Deliver a pending request, following it across worker respawns.

        A send that hits a dead pipe triggers the failure path (which
        re-routes this pending to the freshly-spawned replacement — or
        fails it once budgets are exhausted) and then retries; the loop
        terminates because every failure either resolves the pending or
        installs a live worker to send to.
        """
        while not pending.resolved and not pending.sent:  # squash: ignore[lock-guarded-access] -- lock-free loop condition; the locked re-check below is what commits the sent flag
            worker = pending.worker  # squash: ignore[lock-guarded-access] -- routing snapshot; if the failure path re-routes concurrently, the locked re-check below refuses to mark sent and the loop retries on the replacement
            try:
                with worker.send_lock:
                    worker.req_conn.send(
                        (pending.rid, pending.payload, pending.extra))
                # Commit the sent flag under the transport lock, re-checking
                # the routing: the worker can die between the pipe write and
                # here, in which case _on_worker_failure has already
                # re-routed this pending to the replacement (it saw
                # sent=False, so it expects *this* loop to deliver) —
                # blindly marking it sent stranded the invocation until its
                # timeout, with nobody ever writing it to the new pipe.
                with self._lock:
                    if pending.worker is worker:
                        pending.sent = True
                        pending.t_sent = time.perf_counter()
            except (BrokenPipeError, OSError):
                self._on_worker_failure(worker)

    # ------------------------------------------------------------ collection

    def _collect_loop(self) -> None:
        while not self._closed:  # squash: ignore[lock-guarded-access] -- lock-free shutdown poll; a stale read costs one 0.25s wait tick, never correctness
            with self._lock:
                live = [w for ws in self._workers.values()
                        for w in ws if not w.dead]
                conns = {w.resp_conn: w for w in live}
                sentinels = {w.proc.sentinel: w for w in live}
            if not conns:
                time.sleep(0.02)
                continue
            try:
                ready = mpc.wait(list(conns) + list(sentinels), timeout=0.25)
            except OSError:      # a pipe vanished mid-wait; re-scan
                continue
            for r in ready:
                if self._closed:  # squash: ignore[lock-guarded-access] -- lock-free shutdown poll; close() owns failing the stragglers
                    return
                # The collector must survive anything a single worker's
                # failure path throws — a dead collector silently turns
                # every outstanding result() into a timeout.
                try:
                    if r in conns:
                        self._drain(conns[r])
                    else:
                        self._on_worker_failure(sentinels[r])
                except Exception:                        # noqa: BLE001
                    _METRICS.counter(
                        f"transport.{self.kind}.swallowed_errors").inc()
                    continue

    def _drain(self, worker: _Worker) -> None:
        try:
            msg = worker.resp_conn.recv()  # squash: ignore[wire-raw-socket] -- mp pipe Connection.recv, not a TCP socket; the payload inside was budget-checked at submit
        except (EOFError, OSError):
            self._on_worker_failure(worker)
            return
        rid, ok, data, winfo = msg
        if ok:
            _METRICS.histogram(
                f"transport.{self.kind}.response_bytes",
                buckets=DEFAULT_BYTES_BUCKETS).observe(len(data))
        with self._lock:
            pending = self._pending.pop(rid, None)
            if pending is not None:
                worker.done += 1
            else:
                # Late response for a request result() already timed out and
                # dropped: its assignment was rebalanced at drop time, so
                # booking ``done`` here would drive inflight negative and
                # make the worker look under-loaded. Other unknown rids
                # (close() cleared the table) are ignored the same way.
                self._timed_out.pop(rid, None)
        if pending is None or pending.resolved:
            return
        if ok:
            pending.resolve(data, winfo)
        else:
            pending.fail(TransportError(
                f"worker {worker.fn!r} (pid {winfo.get('os_pid')}) handler "
                f"raised:\n{data}"))

    # ----------------------------------------------------- crash / retry path

    def _on_worker_failure(self, worker: _Worker) -> None:
        """Respawn a dead worker and re-route its in-flight invocations.

        Respawns are budgeted per function (``max_retries + 1`` consecutive
        dead births): a function whose workers die on arrival — e.g. an
        environment where workers cannot start at all — fails its pending
        invocations fast instead of spinning up processes forever. A worker
        that served at least one request resets the budget. The replacement
        is spawned *outside* the lock (pickling a QP bundle is not cheap),
        so concurrent submits and drains of other workers proceed during
        recovery; ``submit`` waits on the ``_respawning`` count if the pool
        is momentarily empty.
        """
        with self._lock:
            if worker.dead or self._closed:
                return
            worker.dead = True
            pool = self._workers.get(worker.fn, [])
            if worker in pool:
                pool.remove(worker)
            affected = [p for p in self._pending.values()
                        if p.worker is worker and not p.resolved]
            # Timed-out requests in flight on this worker can never arrive.
            for rid in [r for r, w in self._timed_out.items() if w is worker]:
                del self._timed_out[rid]
            if worker.done > 0:
                self._dead_births[worker.fn] = 0
            births = self._dead_births.get(worker.fn, 0) + 1
            self._dead_births[worker.fn] = births
            if births > self.max_retries + 1:
                self._fail_locked(affected, TransportError(
                    f"workers for {worker.fn!r} keep dying at startup "
                    f"({births} consecutive failed births); giving up"))
                self._reap(worker)
                return
            self._respawning[worker.fn] = \
                self._respawning.get(worker.fn, 0) + 1
        _METRICS.counter(f"transport.{self.kind}.respawns").inc()
        try:
            replacement = _Worker(self._ctx, worker.init)
        except Exception as exc:                     # spawn itself failed
            with self._lock:
                self._respawning[worker.fn] -= 1
                self._fail_locked(affected, TransportError(
                    f"could not respawn worker for {worker.fn!r}: {exc}"))
            self._reap(worker)
            return
        resend: List[_Pending] = []
        with self._lock:
            self._respawning[worker.fn] -= 1
            if self._closed:
                replacement.proc.terminate()
                self._fail_locked(affected,
                                  TransportError("transport closed"))
            else:
                self._workers[worker.fn].append(replacement)
                for p in affected:
                    if p.resolved:
                        continue
                    if not p.sent:
                        # Unsent (lazy mode): re-route only — the _send loop
                        # that owns this pending retries against the
                        # replacement itself.
                        p.worker = replacement
                        replacement.assigned += 1
                        continue
                    p.retries += 1
                    if p.retries > self.max_retries:
                        self._fail_locked([p], TransportError(
                            f"invocation of {p.fn!r} failed after "
                            f"{p.retries - 1} retries (worker kept dying)"))
                        continue
                    p.worker = replacement
                    p.sent = False
                    replacement.assigned += 1
                    resend.append(p)
        if resend:
            _METRICS.counter(
                f"transport.{self.kind}.retries").inc(len(resend))
        for p in resend:
            self._send(p)
        self._reap(worker)

    def _fail_locked(self, pendings: List[_Pending],
                     exc: Exception) -> None:  # squash: holds[_lock]
        """Fail + forget pendings (caller holds the lock) — failed entries
        must not linger in ``_pending`` or they accumulate for the
        transport's lifetime and get re-scanned on every later failure."""
        for p in pendings:
            if not p.resolved:
                p.fail(exc)
            self._pending.pop(p.rid, None)

    @staticmethod
    def _reap(worker: _Worker) -> None:
        try:
            worker.proc.join(timeout=0.1)
            for conn in (worker.req_conn, worker.resp_conn):
                conn.close()
        except (OSError, ValueError):
            _METRICS.counter("transport.process.swallowed_errors").inc()

    # --------------------------------------------------------------- lifecycle

    def worker_pids(self, fn: str) -> List[int]:
        """Live OS pids serving ``fn`` (tests kill these to exercise retry)."""
        with self._lock:
            return [w.proc.pid for w in self._workers.get(fn, ())
                    if not w.dead]

    def close(self) -> None:
        # Check-and-set under the lock: two racing close() calls (user +
        # __del__, or two fixtures sharing a transport) both used to pass
        # the unlocked `if self._closed` test, double-sending SHUTDOWN and
        # double-closing every pipe.
        with self._lock:
            if self._closed:
                return
            self._closed = True
            workers = [w for ws in self._workers.values() for w in ws]
            for p in self._pending.values():
                if not p.resolved:
                    p.fail(TransportError("transport closed"))
            self._pending.clear()
            self._timed_out.clear()
        for w in workers:
            try:
                with w.send_lock:
                    w.req_conn.send(wk.SHUTDOWN)
            except (BrokenPipeError, OSError, ValueError):
                _METRICS.counter(
                    f"transport.{self.kind}.swallowed_errors").inc()
        for w in workers:
            w.proc.join(timeout=2.0)
        for w in workers:
            if w.proc.is_alive():
                w.proc.terminate()
                w.proc.join(timeout=0.5)
            for conn in (w.req_conn, w.resp_conn):
                try:
                    conn.close()
                except (OSError, ValueError):
                    _METRICS.counter(
                        f"transport.{self.kind}.swallowed_errors").inc()
        if self._collector.is_alive():
            self._collector.join(timeout=1.0)

    def __del__(self):  # pragma: no cover - best-effort cleanup
        try:
            self.close()
        except Exception:
            pass
