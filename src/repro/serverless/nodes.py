"""Actor roles of the serverless runtime (paper §3.1): compute logic only.

The event choreography (invocation latencies, payload budgets, DRE leases)
lives in ``runtime.py``; this module holds what each function *computes* when
its handler runs:

* :class:`Coordinator` / :class:`QueryAllocator` — Stage 1 attribute
  filtering + Algorithm 1 partition ranking/selection over the node's own
  query slice, including the §2.5 single-pass guarantee (partitions past the
  Eq. 1 threshold cut are escalated into the visit set until ≥ k
  predicate-passing candidates exist — reported as ``escalations``), then
  the per-partition QueryProcessor request payloads.
* :class:`QueryProcessor` — Stages 3–5 of the real batched data plane
  (``core.dataplane``) over one partition shard, the same jitted plane the
  ``backend="jax"`` path runs, so ids are bitwise-identical.
* :func:`merge_partition_topk` — the MPI-style single-pass top-k combine
  (§2.4.5) applied to response streams in ascending-partition order, which
  reproduces the reference tie-breaking exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import attributes as attr_mod
from repro.core import dataplane
from repro.core import partitions as part_mod
from repro.core.pipeline import SquashIndex

__all__ = ["Coordinator", "QueryAllocator", "QueryProcessor", "QAPlan",
           "merge_partition_topk", "split_search_request",
           "split_processor_request", "split_processor_rows"]


# ------------------------------------------------------------- request splits

def split_search_request(req: Dict, lo: int, hi: int) -> Dict:
    """Sub-request over query positions [lo, hi) (payload chunking)."""
    out = dict(req)
    out["qidx"] = req["qidx"][lo:hi]
    out["queries"] = req["queries"][lo:hi]
    return out


def split_processor_request(req: Dict, lo: int, hi: int) -> Dict:
    """QP sub-request over query positions [lo, hi), re-based row offsets."""
    off = req["row_offsets"]
    out = dict(req)
    out["qidx"] = req["qidx"][lo:hi]
    out["queries"] = req["queries"][lo:hi]
    out["keep"] = req["keep"][lo:hi]
    out["take"] = req["take"][lo:hi]
    out["rows"] = req["rows"][off[lo]:off[hi]]
    out["row_offsets"] = (off[lo : hi + 1] - off[lo]).astype(np.int32)
    return out


def split_processor_rows(req: Dict, lo: int, hi: int) -> Dict:
    """Secondary (candidate-row) axis split of a *single-query* QP request.

    When one query's candidate list alone busts the payload budget, the
    request splits along the partition's row axis instead of erroring (the
    ROADMAP's known limit). Each row chunk keeps at most its own row count
    (``keep``/``take`` clamp), which preserves a **superset** of the
    unsplit stages' survivors: a row inside the unsplit top-``keep`` by
    Hamming is top-``keep`` within any chunk containing it (fewer
    competitors), and likewise for the ADC take — so the exact-distance
    merge of the chunk responses returns the same ids. The runtime merges
    same-query chunk responses in ascending chunk order, matching the
    ascending-row tie order of the unsplit stream.
    """
    if int(req["qidx"].shape[0]) != 1:
        raise ValueError("row-axis split applies to single-query requests")
    rows = req["rows"][lo:hi]
    out = dict(req)
    out["rows"] = rows
    out["row_offsets"] = np.asarray([0, rows.shape[0]], dtype=np.int32)
    keep = np.minimum(np.asarray(req["keep"]), rows.shape[0])
    out["keep"] = keep.astype(np.asarray(req["keep"]).dtype)
    out["take"] = np.minimum(np.asarray(req["take"]), keep).astype(
        np.asarray(req["take"]).dtype)
    return out


# ------------------------------------------------------------ QueryAllocator

@dataclasses.dataclass
class QAPlan:
    """Result of one QA's dynamic stages over its own query slice."""

    qidx: np.ndarray                     # (m,) global query indices
    qp_requests: Dict[int, Dict]         # partition id → request payload
    filter_pass: int
    partitions_visited: int
    escalations: int                     # visits past the Eq. 1 threshold cut


class QueryAllocator:
    """Stage 1 + Algorithm 1 for one node's query slice (paper §3.1 QA)."""

    def __init__(self, index: SquashIndex):
        self.index = index

    def plan(self, qidx: np.ndarray, queries: np.ndarray,
             predicates: Sequence[attr_mod.Predicate], k: int) -> QAPlan:
        idx = self.index
        m = queries.shape[0]
        if m == 0:
            return QAPlan(qidx=qidx, qp_requests={}, filter_pass=0,
                          partitions_visited=0, escalations=0)
        r = attr_mod.build_r_lookup(idx.attr_index, predicates)
        f_one = np.asarray(attr_mod.filter_mask(r, idx.attr_index.codes))
        live = getattr(idx, "live_mask", None)
        if live is not None:
            # Live-index tombstones fail Stage 1 — same masking the
            # in-process pipeline applies, so QA candidate sets (and hence
            # every downstream stage counter) stay bitwise-identical.
            f_one = f_one & live
        f = np.broadcast_to(f_one, (m, f_one.shape[0]))
        pg = idx.partitioning
        # §2.5 escalation accounting happens inside Alg. 1 itself (visits
        # past the T·d_min cut taken to reach ≥ k passing candidates).
        esc_box = [0]
        visit, cands = part_mod.select_partitions(
            queries, pg.centroids, f, pg.assign, pg.threshold, k,
            escalations=esc_box)
        p, n_max = len(idx.parts), max(pt.size for pt in idx.parts)
        _, n_cand = dataplane.build_cand_arrays(cands, m, p, n_max)
        # Per-partition budgets: under a calibration profile each partition
        # gets its own keep fraction (core/autotune.py); the derived keep /
        # take vectors ship to the QPs inside the Alg. 2 request payloads.
        keep, take = dataplane.stage_counts(n_cand, idx.config, k,
                                            getattr(idx, "profile", None))

        qp_requests: Dict[int, Dict] = {}
        for pid in range(p):
            rows_q = [cands[qi].get(pid) for qi in range(m)]
            sel = [qi for qi in range(m) if rows_q[qi] is not None]
            if not sel:
                continue
            rows = np.concatenate([rows_q[qi] for qi in sel]).astype(np.int32)
            offsets = np.zeros(len(sel) + 1, dtype=np.int32)
            offsets[1:] = np.cumsum([rows_q[qi].size for qi in sel])
            qp_requests[pid] = {
                "pid": pid,
                "k": int(k),
                "qidx": qidx[sel],
                "queries": queries[sel],
                "rows": rows,
                "row_offsets": offsets,
                "keep": keep[sel, pid],
                "take": take[sel, pid],
            }
        return QAPlan(
            qidx=qidx,
            qp_requests=qp_requests,
            filter_pass=int(f_one.sum()) * m,
            partitions_visited=int(visit.sum()),
            escalations=esc_box[0],
        )


class Coordinator(QueryAllocator):
    """Root of the tree (id −1). Owns no query slice; fans out and merges."""


# ------------------------------------------------------------ QueryProcessor

class QueryProcessor:
    """Stage 3–5 executor for one partition (function squash-processor-<pid>).

    Holds the partition's slice of the stacked device payload — the DRE
    singleton — and runs the same jitted plane as ``backend="jax"``.
    """

    def __init__(self, pid: int, stacked_slice, plane_for, config,
                 query_dtype):
        self.pid = pid
        self.stacked_slice = stacked_slice
        self._plane_for = plane_for       # k -> jitted plane callable
        self.config = config
        self.query_dtype = query_dtype

    def handle(self, req: Dict) -> Tuple[Dict, Dict]:
        """Execute one request payload. Returns (response, stage counters)."""
        import jax.numpy as jnp

        m = int(req["qidx"].shape[0])
        k = int(req["k"])
        n_max = int(self.stacked_slice.n_max)
        off = req["row_offsets"]
        cand_mask = np.zeros((m, 1, n_max), dtype=bool)
        for qi in range(m):
            cand_mask[qi, 0, req["rows"][off[qi]:off[qi + 1]]] = True

        # Bucket the slice to a power of two so repeated invocations share
        # one trace per (bucket, k) — the QP-side analogue of the service's
        # batch bucketing. Padded queries are dead (keep = 0, empty mask).
        qb = 1 << (m - 1).bit_length() if m > 1 else 1
        queries = np.zeros((qb, req["queries"].shape[1]), dtype=np.float64)
        queries[:m] = req["queries"]
        mask = np.zeros((qb, 1, n_max), dtype=bool)
        mask[:m] = cand_mask
        keep = np.zeros((qb, 1), dtype=np.int32)
        keep[:m, 0] = req["keep"]
        take = np.zeros((qb, 1), dtype=np.int32)
        take[:m, 0] = req["take"]

        plane = self._plane_for(k)
        ids, dists = plane(
            jnp.asarray(queries, self.query_dtype), self.stacked_slice,
            jnp.asarray(mask), jnp.asarray(keep), jnp.asarray(take),
        )
        resp = {
            "pid": self.pid,
            "qidx": req["qidx"],
            "ids": np.asarray(ids[:m], dtype=np.int64),
            "dists": np.asarray(dists[:m], dtype=np.float64),
        }
        refined = int(take.sum()) if self.config.enable_refine else 0
        counters = {
            "hamming_in": int(req["rows"].shape[0]),
            "hamming_kept": int(keep.sum()),
            "adc_evals": int(keep.sum()),
            "refined": refined,
        }
        return resp, counters


# ------------------------------------------------------------------- merging

def merge_partition_topk(
    m: int,
    k: int,
    streams: List[Tuple[np.ndarray, np.ndarray, np.ndarray]],
) -> Tuple[np.ndarray, np.ndarray]:
    """Single-pass MPI-style top-k combine over per-partition responses.

    ``streams`` must be in **ascending partition order**; each entry is
    (row_positions (s,), ids (s, k), dists (s, k)) scattering that
    partition's response into the node's own query rows. Ties resolve by
    (distance, partition, rank) — identical to both reference planes.
    """
    out_ids = np.full((m, k), -1, dtype=np.int64)
    out_d = np.full((m, k), np.inf, dtype=np.float64)
    if not streams:
        return out_ids, out_d
    ns = len(streams)
    all_i = np.full((m, ns, k), -1, dtype=np.int64)
    all_d = np.full((m, ns, k), np.inf, dtype=np.float64)
    for j, (rows, ids, dists) in enumerate(streams):
        all_i[rows, j] = ids
        all_d[rows, j] = dists
    flat_i = all_i.reshape(m, ns * k)
    flat_d = all_d.reshape(m, ns * k)
    order = np.argsort(flat_d, axis=1, kind="stable")[:, :k]
    return (np.take_along_axis(flat_i, order, axis=1),
            np.take_along_axis(flat_d, order, axis=1))
