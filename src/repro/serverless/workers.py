"""Worker-side handlers of the serverless runtime (the *function bodies*).

This module is what actually runs inside a FaaS container. It is shared by
both transports:

* :class:`~repro.serverless.transport.LocalTransport` calls
  :func:`qa_compute` / :func:`qp_compute` inline (same interpreter, no
  codec round-trip beyond what the choreography already does);
* :class:`~repro.serverless.transport.ProcessTransport` runs
  :func:`worker_main` in long-lived ``multiprocessing`` processes — one
  process per QueryProcessor partition (the ``squash-processor-<pid>``
  function) and a small pool for the shared allocator function — and every
  request/response crosses the process boundary codec-encoded;
* :class:`~repro.serverless.socket_transport.SocketTransport` serves the
  same loop over TCP connections to ``repro.serverless.host`` processes
  (possibly on other machines). Both long-lived substrates share
  :class:`RequestServer`, so container economics (warm starts, fetch
  timing, derived-state retention) are reported identically.

Worker state mirrors the paper's DRE story with *real* retention: a worker
is a container. Its first request pays ``fetch_s`` (materializing the
function's singleton — the QA routing structures, or the QP's device-
resident partition slice + jitted plane); subsequent requests hit the
retained state for free, and the parent observes genuine warm starts keyed
to the worker's OS pid. A killed worker loses everything, exactly like a
reclaimed Lambda container.

Bundles (:func:`build_qa_bundle` / :func:`build_qp_bundle`) are plain
numpy/py-data and picklable; a QP bundle carries only its *own* partition's
slab (``dataplane.part_stack_arrays``) plus the global stack geometry, so
worker memory scales with one shard, not the index.
"""

from __future__ import annotations

import dataclasses
import os
import time
import traceback
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import REGISTRY as _METRICS
from repro.obs.metrics import snapshot_delta
from repro.serverless import payload as pl

__all__ = [
    "WorkerInit", "build_qa_bundle", "build_qp_bundle",
    "qa_compute", "qp_compute",
    "pack_plan_response", "unpack_plan_response",
    "pack_qp_response", "unpack_qp_response",
    "configure_jax", "RequestServer", "worker_main", "SHUTDOWN",
]

SHUTDOWN = None  # sentinel message asking a worker to exit its loop


@dataclasses.dataclass
class WorkerInit:
    """Everything a spawned worker needs before its first request.

    ``bundle`` is role-specific picklable state (see the builders below);
    ``x64``/``platform`` replicate the parent's jax configuration so the
    worker's plane produces bitwise-identical ids.
    """

    role: str                 # "qa" | "qp"
    fn: str                   # function name ("qa", "qp:<pid>")
    pid: Optional[int]        # partition id (qp only)
    x64: bool
    platform: str
    bundle: Dict


# ------------------------------------------------------------------ bundles

def build_qa_bundle(index) -> Dict:
    """Picklable state for the allocator function (Stage 1 + Alg. 1).

    Carries the live-index tombstone bitmap (None for a frozen index) so a
    worker-side QA masks dead rows in Stage 1 exactly like the in-process
    pipeline.
    """
    return {
        "config": index.config,
        "partitioning": index.partitioning,
        "attr_index": index.attr_index,
        "part_sizes": [pt.size for pt in index.parts],
        "profile": getattr(index, "profile", None),
        "dim": index.dim,
        "live_mask": getattr(index, "live_mask", None),
    }


def build_qp_bundle(index, pid: int, dtype) -> Dict:
    """Picklable state for one processor function: its partition slab only.

    Live-index tombstones fold into the slab's ``valid`` bits, so a worker
    QP's Stage 3 drops dead rows even when a request names them.
    """
    from repro.core import dataplane

    n_max = max(pt.size for pt in index.parts)
    m1 = max(pt.quant.boundaries.shape[0] for pt in index.parts)
    live_mask = getattr(index, "live_mask", None)
    pt = index.parts[pid]
    live_rows = None if live_mask is None else live_mask[pt.vector_ids]
    return {
        "config": index.config,
        "profile": getattr(index, "profile", None),
        "pid": pid,
        "part_arrays": dataplane.part_stack_arrays(
            pt, n_max=n_max, m1=m1, d=index.dim, dtype=dtype,
            live_rows=live_rows),
        "dim": index.dim,
    }


class _SizeOnlyPart:
    """Partition stand-in carrying just ``size`` (all the QA plan reads)."""

    def __init__(self, size: int):
        self.size = size


class _QAIndexView:
    """Duck-typed ``SquashIndex`` view for ``nodes.QueryAllocator``."""

    def __init__(self, bundle: Dict):
        self.config = bundle["config"]
        self.partitioning = bundle["partitioning"]
        self.attr_index = bundle["attr_index"]
        self.parts = [_SizeOnlyPart(s) for s in bundle["part_sizes"]]
        self.profile = bundle["profile"]
        self.dim = bundle["dim"]
        self.live_mask = bundle.get("live_mask")


# ----------------------------------------------------- role compute (shared)

def qa_compute(allocator, creq: Dict, olo: int, ohi: int) -> Dict:
    """One allocator handler body: plan the node's own query slice.

    ``allocator`` is a :class:`~repro.serverless.nodes.QueryAllocator`
    (bound to the real index in-process, or to a :class:`_QAIndexView` in a
    worker). Returns the transport-neutral plan response::

        {"filter_pass", "partitions_visited", "escalations",
         "plans": {pid: qp_request_dict}}
    """
    qidx = creq["qidx"]
    own = (qidx >= olo) & (qidx < ohi)
    plan = allocator.plan(qidx[own], creq["queries"][own],
                          pl.predicates_from_json(creq["preds"]),
                          int(creq["k"]))
    return {
        "filter_pass": int(plan.filter_pass),
        "partitions_visited": int(plan.partitions_visited),
        "escalations": int(plan.escalations),
        "plans": plan.qp_requests,
    }


def qp_compute(processor, creq: Dict) -> Tuple[Dict, Dict]:
    """One processor handler body: Stages 3–5 over the request's candidates."""
    return processor.handle(creq)


# ------------------------------------------------------------- wire packing

def pack_plan_response(presp: Dict) -> Dict:
    """Flatten a plan response for the codec (nested requests → uint8)."""
    out = {k: presp[k]
           for k in ("filter_pass", "partitions_visited", "escalations")}
    pids = sorted(presp["plans"])
    out["pids"] = np.asarray(pids, dtype=np.int32)
    for pid in pids:
        out[f"plan:{pid}"] = np.frombuffer(
            pl.encode_message(presp["plans"][pid]), dtype=np.uint8)
    return out


def unpack_plan_response(wire: Dict) -> Dict:
    plans = {int(pid): pl.decode_message(wire[f"plan:{int(pid)}"].tobytes())
             for pid in wire["pids"]}
    return {
        "filter_pass": int(wire["filter_pass"]),
        "partitions_visited": int(wire["partitions_visited"]),
        "escalations": int(wire["escalations"]),
        "plans": plans,
    }


_CTR_KEYS = ("hamming_in", "hamming_kept", "adc_evals", "refined")


def pack_qp_response(resp: Dict, counters: Dict) -> Dict:
    out = dict(resp)
    for k in _CTR_KEYS:
        out[f"ctr:{k}"] = int(counters[k])
    return out


def unpack_qp_response(wire: Dict) -> Tuple[Dict, Dict]:
    counters = {k: int(wire.pop(f"ctr:{k}")) for k in _CTR_KEYS}
    return wire, counters


# --------------------------------------------------------- worker-side state

def _build_state(init: WorkerInit):
    """Materialize the function singleton (the DRE 'fetch' + derived setup)."""
    if init.role == "qa":
        from repro.serverless import nodes as nd

        return nd.QueryAllocator(_QAIndexView(init.bundle))

    # QP: single-partition stacked slice + per-k jitted planes.
    from repro.core import dataplane
    from repro.serverless import nodes as nd

    bundle = init.bundle
    stacked = dataplane.stack_single_part(bundle["part_arrays"])
    config = bundle["config"]
    profile = bundle["profile"]
    qdtype = np.float64 if init.x64 else np.float32
    planes: Dict = {}
    trace_counter = [0]

    def plane_for(k: int):
        keep_s, take_s = dataplane.static_counts(
            stacked.n_max, config, k, profile)
        key = (k, keep_s, take_s, config.enable_refine)
        plane = planes.get(key)
        if plane is None:
            plane = dataplane.make_plane(
                k=k, keep_s=keep_s, take_s=take_s,
                refine=config.enable_refine, trace_counter=trace_counter)
            planes[key] = plane
        return plane

    return nd.QueryProcessor(bundle["pid"], stacked, plane_for, config,
                             qdtype)


def configure_jax(init: WorkerInit) -> None:
    """Replicate the parent's jax configuration inside a worker process."""
    os.environ.setdefault("JAX_PLATFORMS", init.platform)
    import jax

    jax.config.update("jax_enable_x64", init.x64)


class RequestServer:
    """One live container's request loop body, transport-neutral.

    Shared by the pipe-served :func:`worker_main` (ProcessTransport) and the
    TCP-served ``repro.serverless.host`` connections (SocketTransport), so
    both long-lived substrates report identical container economics.
    :meth:`handle` returns ``(ok, data, info)`` — ``data`` is the encoded
    response on success or a formatted traceback string — where ``info``
    carries ``os_pid``, ``served_before`` (warm-start evidence), ``fetch_s``
    (singleton build on a cold hit, 0 afterwards — true DRE), ``state_hit``
    and ``compute_s`` (handler busy seconds, including any injected
    busy-sleep used by the concurrency benches).

    ``served`` counts *attempts*, not successes: a container whose first
    request raised still kept its process (and, if the failure came after
    the singleton build, its retained state), so the retry must report warm
    evidence — counting only successes made the parent book a cold start
    (``warm=False`` with ``state_hit=True``) for a container that
    demonstrably retained its singleton.

    When the request's ``extra`` carries a span context
    (``payload.extract_span_context``), the worker additionally times its
    internal segments — singleton fetch, payload deserialize, handler
    compute, response serialize — and ships them back as
    ``info["obs"] = {"run", "parent", "spans": [[name, t0, t1], ...]}``
    with offsets relative to handler entry, echoing the received context so
    the client can verify the stitch. Without a context none of this runs —
    tracing is strictly opt-in per request.

    A span context also switches on this process's metrics registry
    (fleet telemetry: the parent asked for observability, so the container
    starts accounting) and records the worker-side instruments —
    ``worker.requests`` / ``worker.state_hits`` counters and the
    ``worker.handle_s`` busy histogram — that only exist in worker
    processes, never in the client. With ``echo_metrics=True`` (the pipe
    workers: their only wire back is the response) each response's ``info``
    additionally carries ``info["metrics"]``, the registry delta since the
    previous echo, for the client to absorb per pid. Socket hosts pass
    ``echo_metrics=False``: several RequestServers share one host process
    (and one process-global registry), so per-server deltas would double-
    count — the host answers the transport's STATS frame with one
    cumulative process snapshot instead.
    """

    def __init__(self, init: WorkerInit, echo_metrics: bool = False):
        self.init = init
        self.state = None
        self.served = 0
        self.echo_metrics = echo_metrics
        self._echoed: Optional[Dict] = None   # cumulative snapshot last sent

    def handle(self, payload: bytes, extra: Optional[Dict]):
        extra = extra or {}
        obs_ctx = pl.extract_span_context(extra)
        marks = [] if obs_ctx is not None else None
        if obs_ctx is not None and not _METRICS.enabled:
            _METRICS.enable()
        info = {"os_pid": os.getpid(), "served_before": self.served}
        self.served += 1
        try:
            t0 = time.perf_counter()
            if self.state is None:
                self.state = _build_state(self.init)
                info["fetch_s"] = time.perf_counter() - t0
                info["state_hit"] = False
                if marks is not None:
                    marks.append(["fetch", 0.0, info["fetch_s"]])
            else:
                info["fetch_s"] = 0.0
                info["state_hit"] = True
            td = time.perf_counter()
            creq = pl.decode_message(payload)
            t1 = time.perf_counter()
            if marks is not None:
                marks.append(["deserialize", td - t0, t1 - t0])
            sleep_s = float(extra.get("sleep_s") or 0.0)
            if sleep_s > 0.0:
                time.sleep(sleep_s)      # emulated busy time (benches/tests)
            if self.init.role == "qa":
                wire = pack_plan_response(qa_compute(
                    self.state, creq, int(extra["olo"]), int(extra["ohi"])))
            else:
                wire = pack_qp_response(*qp_compute(self.state, creq))
            t2 = time.perf_counter()
            info["compute_s"] = t2 - t1
            data = pl.encode_message(wire)
            if marks is not None:
                t3 = time.perf_counter()
                marks.append(["compute", t1 - t0, t2 - t0])
                marks.append(["serialize", t2 - t0, t3 - t0])
                info["obs"] = {"run": obs_ctx["run"],
                               "parent": obs_ctx["span"], "spans": marks}
                # Worker-side instruments (exist only in this process —
                # the fleet view is where the client ever sees them).
                _METRICS.counter("worker.requests").inc()
                if info["state_hit"]:
                    _METRICS.counter("worker.state_hits").inc()
                _METRICS.histogram("worker.handle_s").observe(t3 - t0)
                if self.echo_metrics:
                    cur = _METRICS.snapshot()
                    info["metrics"] = snapshot_delta(cur, self._echoed)
                    self._echoed = cur
            return True, data, info
        except Exception:                            # noqa: BLE001
            info.setdefault("fetch_s", 0.0)
            info.setdefault("state_hit", self.state is not None)
            info["compute_s"] = 0.0
            return False, traceback.format_exc(), info


def worker_main(init: WorkerInit, req_conn, resp_conn) -> None:
    """Long-lived worker loop: recv (req_id, payload, extra) → send response.

    Response tuples are ``(req_id, ok, payload_or_traceback, info)`` with
    the :class:`RequestServer` semantics above.
    """
    configure_jax(init)
    server = RequestServer(init, echo_metrics=True)
    while True:
        try:
            msg = req_conn.recv()  # squash: ignore[wire-raw-socket] -- mp pipe Connection.recv, not a TCP socket; the payload inside was budget-checked at submit
        except (EOFError, OSError):
            break
        if msg is SHUTDOWN:
            break
        req_id, payload, extra = msg
        ok, data, info = server.handle(payload, extra)
        try:
            resp_conn.send((req_id, ok, data, info))
        except (BrokenPipeError, OSError):
            break
