"""Discrete-event loop for the serverless runtime.

A minimal virtual-clock scheduler: handlers are plain callables scheduled at
absolute virtual times and executed in (time, insertion) order. Real
computation (attribute filtering, the jitted data plane) runs *inside*
handlers; its wall-clock duration — or a configured constant — is then used
to schedule downstream events, so the virtual timeline models a fleet of
concurrent FaaS workers while the host executes them one at a time.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Tuple

__all__ = ["EventLoop"]


class EventLoop:
    """Priority-queue event loop over a virtual clock (seconds)."""

    def __init__(self):
        self.now: float = 0.0
        self._seq = itertools.count()
        self._queue: List[Tuple[float, int, Callable[[], None]]] = []

    def at(self, when: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` at absolute virtual time ``when`` (>= now)."""
        if when < self.now:
            raise ValueError(f"cannot schedule into the past: {when} < {self.now}")
        heapq.heappush(self._queue, (when, next(self._seq), fn))

    def after(self, delay: float, fn: Callable[[], None]) -> None:
        self.at(self.now + max(delay, 0.0), fn)

    def run(self) -> float:
        """Drain the queue; returns the final virtual time (the makespan)."""
        while self._queue:
            self.now, _, fn = heapq.heappop(self._queue)
            fn()
        return self.now
