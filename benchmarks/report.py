"""Render results/*.json into the markdown tables used by EXPERIMENTS.md.

  PYTHONPATH=src python -m benchmarks.report [--out results/tables.md]
"""

from __future__ import annotations

import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _fmt_bytes(b):
    if b >= 2 ** 30:
        return f"{b / 2**30:.2f} GiB"
    if b >= 2 ** 20:
        return f"{b / 2**20:.1f} MiB"
    return f"{b / 2**10:.0f} KiB"


def dryrun_table(rows, title) -> str:
    out = [f"### {title}", "",
           "| arch | shape | compile | args/dev | temp/dev | collectives "
           "(compiled HLO) |",
           "|---|---|---:|---:|---:|---|"]
    for r in rows:
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | FAILED | | | "
                       f"{r['error'][:60]} |")
            continue
        m = r["memory"]
        per = r["collectives"]["per_op"]
        cs = ", ".join(f"{k.replace('collective-','c-')}×{v['count']}"
                       for k, v in sorted(per.items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f}s | "
            f"{_fmt_bytes(m['argument_bytes'])} | "
            f"{_fmt_bytes(m['temp_bytes'])} | {cs or '—'} |")
    return "\n".join(out) + "\n"


def roofline_table(rows) -> str:
    out = ["| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bottleneck | MODEL/HLO FLOPs |",
           "|---|---|---:|---:|---:|---|---:|"]
    for r in rows:
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | | | | ERROR | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.2f} | "
            f"{r['t_memory']*1e3:.2f} | {r['t_collective']*1e3:.2f} | "
            f"{r['bottleneck'].replace('t_','')} | "
            f"{r['useful_ratio']:.2f} |")
    return "\n".join(out) + "\n"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(RESULTS, "tables.md"))
    args = ap.parse_args(argv)
    parts = []
    sp = _load("dryrun_single_pod.json")
    mp = _load("dryrun_multi_pod.json")
    rl = _load("roofline_single_pod.json")
    rlo = _load("roofline_single_pod_optimized.json")
    if sp:
        ok = sum(1 for r in sp if "error" not in r)
        parts.append(f"## Dry-run — single pod 16×16 ({ok}/{len(sp)} OK)\n")
        parts.append(dryrun_table(sp, "single-pod (256 chips)"))
    if mp:
        ok = sum(1 for r in mp if "error" not in r)
        parts.append(f"## Dry-run — multi-pod 2×16×16 ({ok}/{len(mp)} OK)\n")
        parts.append(dryrun_table(mp, "multi-pod (512 chips)"))
    if rl:
        parts.append("## Roofline (baseline) — single pod, per-layer-"
                     "extrapolated unrolled HLO\n")
        parts.append(roofline_table(rl))
    if rlo:
        parts.append("## Roofline (optimized — after §Perf iterations)\n")
        parts.append(roofline_table(rlo))
    txt = "\n".join(parts)
    with open(args.out, "w") as f:
        f.write(txt)
    print(f"wrote {args.out} ({len(txt)} chars)")


if __name__ == "__main__":
    main()
