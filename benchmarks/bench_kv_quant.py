"""OSQ-KV sweep — serving quality vs cache compression (beyond-paper).

The paper's segment-packed SQ applied to the KV cache (DESIGN.md §5.ii),
swept over bit widths on a real (reduced) model: for each of 16/8/4 bits
and the non-uniform 8/4 split, measure cache compression, decode logit
error vs the fp32 cache, and greedy-token agreement over a batch of
requests. The shape of the curve mirrors the paper's Fig.-2 argument:
non-uniform allocation dominates uniform at equal average bits.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import header, save_json
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serve.kv_quant import (cache_bytes, dequantize_caches,
                                  dequantize_leaf_nonuniform,
                                  quantize_caches, quantize_leaf_nonuniform)


def _nonuniform_roundtrip(caches):
    """8/4-bit variance-split roundtrip over every KV leaf."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    out = []
    nbytes = 0
    from repro.serve.kv_quant import _buf_axis
    for path, leaf in flat:
        axis = _buf_axis(path, leaf)
        if axis >= 0:
            q, m = quantize_leaf_nonuniform(leaf, axis, hi_bits=8,
                                            lo_bits=4, hi_frac=0.5)
            nbytes += sum(x.size * x.dtype.itemsize for x in q
                          if x is not None)
            out.append(dequantize_leaf_nonuniform(q, m))
        else:
            nbytes += leaf.size * leaf.dtype.itemsize
            out.append(leaf)
    return treedef.unflatten(out), nbytes


def run(quick: bool = True) -> dict:
    header("OSQ-KV sweep — bits vs decode fidelity")
    cfg = get_config("llama3-8b").reduced(
        num_layers=2, d_model=128, d_ff=256, vocab_size=512)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b, s = (8, 48) if quick else (16, 96)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s),
                                       dtype=np.int32))
    _, caches = T.prefill(params, prompts, cfg, buf_len=s + 1)
    tok = jnp.ones((b, 1), jnp.int32)
    ref_logits, _ = T.decode_step(params, tok, caches, s, cfg)
    ref_tok = np.asarray(jnp.argmax(ref_logits[:, 0], axis=-1))
    base_bytes = cache_bytes(caches)

    rows = []
    for label in ["16b", "8b", "4b", "nonuniform-8/4"]:
        if label == "nonuniform-8/4":
            qcaches, qbytes = _nonuniform_roundtrip(caches)
        else:
            bits = int(label.rstrip("b"))
            qc, meta = quantize_caches(caches, bits)
            qbytes = cache_bytes(qc)
            qcaches = dequantize_caches(qc, meta)
        logits, _ = T.decode_step(params, tok, qcaches, s, cfg)
        err = float(jnp.sqrt(jnp.mean(
            (logits - ref_logits).astype(jnp.float32) ** 2)))
        agree = float((np.asarray(jnp.argmax(logits[:, 0], axis=-1))
                       == ref_tok).mean())
        rows.append({"variant": label,
                     "compression": base_bytes / qbytes,
                     "logit_rmse": err, "token_agreement": agree})
        print(f"  {label:15s} compression={rows[-1]['compression']:.1f}x "
              f"logit-RMSE={err:.4f} token-agree={agree:.0%}")
    by = {r["variant"]: r for r in rows}
    assert by["8b"]["token_agreement"] >= 0.85
    # non-uniform (avg 6 bits) must beat uniform 4-bit on fidelity while
    # compressing more than 8-bit
    assert by["nonuniform-8/4"]["logit_rmse"] < by["4b"]["logit_rmse"]
    assert by["nonuniform-8/4"]["compression"] > by["8b"]["compression"]
    save_json("BENCH_kv_quant", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
