"""Paper Fig. 9 — queries/second under FaaS parallelism.

No AWS in this container, so QPS is *derived*: per-stage compute is measured
on this host (QA-side filtering + Alg. 1, QP-side pipeline per partition),
then the serverless makespan is assembled from the invocation-tree simulator
(Alg. 2) exactly as the paper's run-time entities compose:

  makespan ≈ tree_launch + QA work + max_p(QP work) + merge
  QPS      = batch_queries / makespan per QA wave · N_QA-way parallelism

A single-server baseline (the paper's c7i comparison) runs the same pipeline
serially with process-level parallelism bounded by host cores.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import header, save_json, timed
from repro.core import attributes as am, partitions as pm
from repro.core.invocation import InvocationSim, tree_size
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data.synthetic import default_predicates, make_vector_dataset
from repro.serve.vector_service import ServiceConfig, VectorSearchService

FAAS_CONFIGS = {10: (10, 1), 20: (4, 2), 84: (4, 3), 155: (5, 3),
                258: (6, 3), 340: (4, 4)}

BACKEND_BATCH = 64  # Q for the numpy-vs-jax data-plane shootout


def backend_shootout(quick: bool) -> dict:
    """Single-host data-plane comparison: numpy loop vs batched jax plane.

    Same index, same Q=64 query batch, same predicates — wall time per
    backend (jax timed post-trace, i.e. DRE-warm), identical-ids check.
    """
    scale = 0.005 if quick else 0.02
    ds = make_vector_dataset("sift1m", scale=scale, num_queries=BACKEND_BATCH)
    preds = default_predicates(ds.attr_cardinality)
    idx = SquashIndex.build(ds.vectors, ds.attributes,
                            SquashConfig(num_partitions=10))
    svc = VectorSearchService(idx, ServiceConfig(backend="auto"))
    svc.warmup(BACKEND_BATCH)                        # trace the jax plane
    repeats = 3
    for _ in range(repeats):
        ids_j, _, _ = svc.query(ds.queries, preds, backend="jax")
        ids_n, _, _ = svc.query(ds.queries, preds, backend="numpy")
    qps_np, qps_jax = svc.qps("numpy"), svc.qps("jax")
    row = {
        "n": ds.n, "queries": BACKEND_BATCH,
        "qps_numpy": qps_np, "qps_jax": qps_jax,
        "speedup": qps_jax / max(qps_np, 1e-9),
        "ids_identical": bool(np.array_equal(ids_j, ids_n)),
    }
    print(f"  backends @Q={BACKEND_BATCH}: numpy={qps_np:8.0f} qps  "
          f"jax={qps_jax:8.0f} qps  ({row['speedup']:.1f}x, ids "
          f"{'identical' if row['ids_identical'] else 'DIVERGED'})")
    return row


def measure_stage_times(preset: str, quick: bool):
    scale = 0.01 if preset.endswith("1m") else 0.001
    nq = 32 if quick else 100
    ds = make_vector_dataset(preset, scale=scale, num_queries=nq)
    preds = default_predicates(ds.attr_cardinality)
    p = 10 if preset.endswith("1m") else 20
    cfg = SquashConfig(num_partitions=p)
    idx = SquashIndex.build(ds.vectors, ds.attributes, cfg)

    # QA-side: predicate parse + filter mask + Algorithm 1.
    def qa_side():
        r = am.build_r_lookup(idx.attr_index, preds)
        f_one = np.asarray(am.filter_mask(r, idx.attr_index.codes))
        f = np.broadcast_to(f_one, (nq, f_one.shape[0]))
        return pm.select_partitions(
            ds.queries.astype(np.float64), idx.partitioning.centroids, f,
            idx.partitioning.assign, idx.partitioning.threshold, 10)

    (visit, cands), t_qa = timed(qa_side, repeats=2)

    # QP-side: full per-partition pipeline for the busiest partition.
    stats_probe = idx.search(ds.queries[:4], preds, 10)[2]
    _, t_all = timed(idx.search, ds.queries, preds, 10, repeats=1)
    t_qp_total = max(t_all - t_qa, 1e-4)
    visits = max(int(visit.sum()), 1)
    t_qp_per_visit = t_qp_total / visits
    return {
        "dataset": preset, "n": ds.n, "queries": nq,
        "t_qa_s": t_qa, "t_qp_per_visit_s": t_qp_per_visit,
        "visits_per_query": visits / nq, "partitions": p,
    }


def serverless_qps(meas: dict, n_qa: int, batch: int = 1000) -> dict:
    f, lmax = FAAS_CONFIGS[n_qa]
    sim = InvocationSim(branching=f, max_level=lmax, node_compute=0.0)
    t_tree = sim.makespan()
    q_per_qa = batch / n_qa
    scale_q = q_per_qa / meas["queries"]
    t_qa = meas["t_qa_s"] * scale_q
    # each QA launches one QP per visited partition; QPs run in parallel,
    # each processing its share of the QA's queries
    t_qp = meas["t_qp_per_visit_s"] * meas["visits_per_query"] * q_per_qa \
        / meas["partitions"] * 4.0   # 1770MB Lambda ≈ 1/4 of a host core-set
    t_merge = 0.002 * np.log2(max(n_qa, 2))
    makespan = t_tree + t_qa + t_qp + t_merge
    return {"n_qa": n_qa, "makespan_s": makespan, "qps": batch / makespan}


def run(quick: bool = True) -> dict:
    header("Fig. 9 — QPS (derived from measured stage times + Alg. 2 sim)")
    presets = ["sift1m", "gist1m"] if quick else ["sift1m", "gist1m",
                                                  "sift10m", "deep10m"]
    out = []
    backends = backend_shootout(quick)
    for preset in presets:
        meas = measure_stage_times(preset, quick)
        best = None
        for n_qa in FAAS_CONFIGS:
            r = serverless_qps(meas, n_qa)
            r.update(dataset=preset)
            out.append(r)
            if best is None or r["qps"] > best["qps"]:
                best = r
        # server baseline: same pipeline, host-bound parallelism (≈8 workers)
        t_serial = (meas["t_qa_s"] + meas["t_qp_per_visit_s"]
                    * meas["visits_per_query"] * meas["queries"]
                    / meas["partitions"]) / meas["queries"]
        server_qps = 8.0 / max(t_serial, 1e-6)
        out.append({"dataset": preset, "n_qa": 0, "makespan_s": None,
                    "qps": server_qps, "server_baseline": True})
        print(f"  {preset:8s} best FaaS QPS={best['qps']:.0f} (N_QA="
              f"{best['n_qa']}), server-8core QPS={server_qps:.0f} → "
              f"{best['qps'] / server_qps:.1f}x")
    save_json("BENCH_qps", {"rows": out, "backend_shootout": backends})
    return {"rows": out, "backend_shootout": backends}


if __name__ == "__main__":
    run()
