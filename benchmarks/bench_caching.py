"""Paper Table 3 — performance with result caching (vs Vexless).

The paper finds the cache ratio (query-duplication factor) SQUASH needs to
beat Vexless's published QPS on each common dataset; GIST1M needs ratio 1
(no duplication). We reproduce the experiment shape with our ResultCache:
measure effective QPS at increasing duplication ratios and report the first
ratio where SQUASH(QPS) > Vexless(QPS), using our measured base throughput
scaled the same way the paper's Table 3 is constructed.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import header, save_json, timed
from repro.core.dre import ResultCache
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data.synthetic import default_predicates, make_vector_dataset

VEXLESS_QPS = {"gist1m": 285, "sift10m": 3125, "deep10m": 2500}
SQUASH_PAPER_QPS = {"gist1m": 326, "sift10m": 3388, "deep10m": 2804}
PAPER_RATIO = {"gist1m": 1, "sift10m": 10, "deep10m": 8}


def run(quick: bool = True) -> dict:
    header("Table 3 — caching: cache-ratio to beat Vexless")
    rows = []
    presets = ["gist1m"] if quick else list(VEXLESS_QPS)
    for preset in presets:
        scale = 0.01 if preset.endswith("1m") else 0.001
        ds = make_vector_dataset(preset, scale=scale, num_queries=16)
        preds = default_predicates(ds.attr_cardinality)
        p = 10 if preset.endswith("1m") else 20
        idx = SquashIndex.build(ds.vectors, ds.attributes,
                                SquashConfig(num_partitions=p))
        _, t_base = timed(idx.search, ds.queries, preds, 10, repeats=1)
        base_qps = ds.queries.shape[0] / t_base

        for ratio in [1, 2, 4, 8, 10, 16]:
            cache = ResultCache()
            t_total = 0.0
            hits = 0
            for rep in range(ratio):
                for qi in range(ds.queries.shape[0]):
                    key = cache.key(ds.queries[qi], preds, 10)
                    if cache.get(key) is not None:
                        t_total += 1e-5          # cache hit ≈ free
                        hits += 1
                    else:
                        t_total += t_base / ds.queries.shape[0]
                        cache.put(key, True)
            eff_qps = ratio * ds.queries.shape[0] / t_total
            # scale to paper units: our CPU base ↔ paper's no-cache QPS
            paper_scaled = (SQUASH_PAPER_QPS[preset]
                            * (eff_qps / base_qps) / 1.0)
            beats = paper_scaled > VEXLESS_QPS[preset] * (
                eff_qps / eff_qps)  # direct comparison in paper units
            rows.append({"dataset": preset, "ratio": ratio,
                         "effective_qps": eff_qps, "hit_rate": cache.hit_rate,
                         "paper_scaled_qps": paper_scaled,
                         "beats_vexless": bool(
                             paper_scaled > VEXLESS_QPS[preset])})
        first = next(r["ratio"] for r in rows
                     if r["dataset"] == preset and r["beats_vexless"])
        curve = ["%.2f" % r["hit_rate"] for r in rows
                 if r["dataset"] == preset]
        print(f"  {preset}: cache ratio {first} beats Vexless "
              f"(paper: {PAPER_RATIO[preset]}); hit rates {curve}")
    save_json("bench_caching", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
