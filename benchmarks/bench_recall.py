"""Paper §5.3 calibration — recall@10 ≥ 0.97 at H_perc = 10, R = 2, b = 4d.

Builds the full SQUASH index on the synthetic stand-ins (paper Table 2
shapes, N scaled for CPU), generates A = 4 uniform attributes with ~8 % joint
selectivity (§5.1), and measures filtered recall@10 against exact brute
force. Also demonstrates the "> 99 % if configured to do so" claim with a
higher-H_perc / higher-R configuration.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import header, recall_at_k, save_json, timed
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data.synthetic import (default_predicates, ground_truth,
                                  make_vector_dataset)

PAPER_T = {"sift1m": 1.15, "gist1m": 1.2, "sift10m": 1.15, "deep10m": 1.13}


def run(quick: bool = True) -> dict:
    header("§5.3 — recall calibration (target ≥ 0.97 @ k=10)")
    rows = []
    presets = ["sift1m", "gist1m"] if quick else list(PAPER_T)
    for preset in presets:
        scale = 0.01 if preset.endswith("1m") else 0.001
        nq = 32 if quick else 100
        ds = make_vector_dataset(preset, scale=scale, num_queries=nq)
        preds = default_predicates(ds.attr_cardinality)
        gt_ids, _ = ground_truth(ds, preds, k=10)
        p = 10 if preset.endswith("1m") else 20
        for label, cfg in {
            "paper(Hperc=10,R=2)": SquashConfig(
                num_partitions=p, hamming_perc=10.0, refine_ratio=2.0,
                threshold_override=PAPER_T[preset]),
            "high(Hperc=30,R=4)": SquashConfig(
                num_partitions=p, hamming_perc=30.0, refine_ratio=4.0,
                threshold_override=PAPER_T[preset] + 0.1),
        }.items():
            idx = SquashIndex.build(ds.vectors, ds.attributes, cfg)
            (ids, dists, stats), secs = timed(
                idx.search, ds.queries, preds, 10, repeats=1)
            rec = recall_at_k(ids, gt_ids)
            rows.append({"dataset": preset, "config": label, "recall": rec,
                         "queries": nq, "seconds": secs,
                         "partitions_visited": stats.partitions_visited / nq,
                         "hamming_kept_frac":
                             stats.hamming_kept / max(stats.hamming_in, 1)})
            print(f"  {preset:8s} {label:22s} recall@10={rec:.3f} "
                  f"({secs:.2f}s, {stats.partitions_visited / nq:.1f} parts/q)")
    save_json("bench_recall", {"rows": rows})
    paper_rows = [r for r in rows if r["config"].startswith("paper")]
    assert all(r["recall"] >= 0.95 for r in paper_rows), \
        "paper configuration must reach ≥0.95 recall on the stand-ins"
    return {"rows": rows}


if __name__ == "__main__":
    run()
