"""Paper §5.3 calibration — recall@10 ≥ 0.97 at H_perc = 10, R = 2, b = 4d.

Builds the full SQUASH index on the synthetic stand-ins (paper Table 2
shapes, N scaled for CPU), generates A = 4 uniform attributes with ~8 % joint
selectivity (§5.1), and measures filtered recall@10 against exact brute
force. Also demonstrates the "> 99 % if configured to do so" claim with a
higher-H_perc / higher-R configuration, and the recall-targeted Hamming
autotune (core/autotune.py): the calibrated per-partition keep profile must
hold the paper configuration's recall while evaluating strictly fewer ADC
candidates than the static H_perc = 10 knob.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import header, recall_at_k, save_json, timed
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data.synthetic import (default_predicates, ground_truth,
                                  make_vector_dataset)

PAPER_T = {"sift1m": 1.15, "gist1m": 1.2, "sift10m": 1.15, "deep10m": 1.13}

RECALL_TARGET = 0.95


def run(quick: bool = True) -> dict:
    header("§5.3 — recall calibration (target ≥ 0.97 @ k=10) + autotune")
    rows = []
    presets = ["sift1m", "gist1m"] if quick else list(PAPER_T)
    for preset in presets:
        scale = 0.01 if preset.endswith("1m") else 0.001
        nq = 32 if quick else 100
        ds = make_vector_dataset(preset, scale=scale, num_queries=nq)
        preds = default_predicates(ds.attr_cardinality)
        gt_ids, _ = ground_truth(ds, preds, k=10)
        p = 10 if preset.endswith("1m") else 20
        for label, cfg in {
            "paper(Hperc=10,R=2)": SquashConfig(
                num_partitions=p, hamming_perc=10.0, refine_ratio=2.0,
                threshold_override=PAPER_T[preset]),
            "high(Hperc=30,R=4)": SquashConfig(
                num_partitions=p, hamming_perc=30.0, refine_ratio=4.0,
                threshold_override=PAPER_T[preset] + 0.1),
        }.items():
            idx = SquashIndex.build(ds.vectors, ds.attributes, cfg)
            (ids, dists, stats), secs = timed(
                idx.search, ds.queries, preds, 10, repeats=1)
            rec = recall_at_k(ids, gt_ids)
            rows.append({"dataset": preset, "config": label, "recall": rec,
                         "queries": nq, "seconds": secs,
                         "adc_evals": stats.adc_evals,
                         "partitions_visited": stats.partitions_visited / nq,
                         "hamming_kept_frac":
                             stats.hamming_kept / max(stats.hamming_in, 1)})
            print(f"  {preset:8s} {label:22s} recall@10={rec:.3f} "
                  f"({secs:.2f}s, {stats.partitions_visited / nq:.1f} parts/q,"
                  f" {stats.adc_evals} ADC)")
            if label.startswith("paper"):
                # Recall-targeted autotune on the same build: per-partition
                # keep fractions + calibrated floor instead of the one knob.
                profile = idx.autotune(recall_target=RECALL_TARGET, k=10,
                                       sample=64, seed=0)
                (ids_t, _, stats_t), secs_t = timed(
                    idx.search, ds.queries, preds, 10, repeats=1)
                rec_t = recall_at_k(ids_t, gt_ids)
                rows.append({
                    "dataset": preset, "config": "autotuned", "recall": rec_t,
                    "queries": nq, "seconds": secs_t,
                    "adc_evals": stats_t.adc_evals,
                    "partitions_visited": stats_t.partitions_visited / nq,
                    "hamming_kept_frac":
                        stats_t.hamming_kept / max(stats_t.hamming_in, 1),
                    "keep_frac": [float(x) for x in profile.keep_frac],
                    "min_keep": profile.min_keep,
                    "adc_savings":
                        1.0 - stats_t.adc_evals / max(stats.adc_evals, 1),
                })
                print(f"  {preset:8s} {'autotuned':22s} recall@10={rec_t:.3f}"
                      f" ({secs_t:.2f}s, {stats_t.adc_evals} ADC, "
                      f"{1 - stats_t.adc_evals / max(stats.adc_evals, 1):.0%}"
                      f" fewer)")
                idx.set_profile(None)
    save_json("BENCH_recall", {"rows": rows})
    paper_rows = [r for r in rows if r["config"].startswith("paper")]
    assert all(r["recall"] >= 0.95 for r in paper_rows), \
        "paper configuration must reach ≥0.95 recall on the stand-ins"
    for preset in presets:
        static = next(r for r in rows if r["dataset"] == preset
                      and r["config"].startswith("paper"))
        tuned = next(r for r in rows if r["dataset"] == preset
                     and r["config"] == "autotuned")
        assert tuned["recall"] >= RECALL_TARGET, \
            f"{preset}: autotuned recall {tuned['recall']} below target"
        assert tuned["adc_evals"] < static["adc_evals"], \
            f"{preset}: autotune must evaluate strictly fewer ADC candidates"
    return {"rows": rows}


if __name__ == "__main__":
    run()
