"""Component ablations — what each stage of the SQUASH design buys.

Two regimes on the same data/queries (§5.1 predicates):

  **paper budget** (b = 4·d, SIFT-like): recall saturates for every variant
  (the paper's working point is deliberately comfortable); here the low-bit
  Hamming stage shows up as a pure COST optimization — ADC evaluations drop
  ~5–10× at unchanged recall.

  **compressed budget** (b = 1·d, GIST-like 960-d): the regime where OSQ's
  §2.2 contribution is visible — variance-greedy non-uniform allocation
  beats uniform 1-bit-per-dim by a wide recall margin, and R·k refinement
  recovers the ordering the coarse codes lose.

KLT note: on these synthetic manifold datasets the decorrelating transform
shows no measurable recall delta (variance-greedy allocation adapts either
way); it matters for correlated real embedding distributions — kept as a
config flag, reported honestly.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import header, recall_at_k, save_json, timed
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data.synthetic import (default_predicates, ground_truth,
                                  make_vector_dataset)


def _measure(ds, preds, gt, cfg):
    idx = SquashIndex.build(ds.vectors, ds.attributes, cfg)
    (ids, _, stats), secs = timed(idx.search, ds.queries, preds, 10,
                                  repeats=1)
    return {
        "recall": recall_at_k(ids, gt),
        "seconds": secs,
        "adc_evals_per_query": stats.adc_evals / stats.queries,
        "hamming_kept_frac": stats.hamming_kept / max(stats.hamming_in, 1),
        "refined_per_query": stats.refined / stats.queries,
    }


def run(quick: bool = True) -> dict:
    header("Ablations — stage-by-stage contribution")
    rows = []

    # ---- regime 1: paper budget, low-bit stage as cost optimization -------
    ds = make_vector_dataset("sift1m", scale=0.02 if quick else 0.05,
                             num_queries=24 if quick else 64, seed=11)
    preds = default_predicates(ds.attr_cardinality)
    gt, _ = ground_truth(ds, preds, k=10)
    # paper floor (min_hamming_keep = 64): H_perc = 10 % of the post-filter
    # candidates, never fewer than 64 — the regime where recall holds.
    base = SquashConfig(num_partitions=8)
    for name, cfg in {
        "full(b=4d)": base,
        "no-lowbit(b=4d)": dataclasses.replace(base, hamming_perc=100.0),
        "no-refine(b=4d)": dataclasses.replace(base, enable_refine=False),
    }.items():
        m = _measure(ds, preds, gt, cfg)
        rows.append({"variant": name, "regime": "paper-budget", **m})
        print(f"  {name:20s} recall@10={m['recall']:.3f} "
              f"adc/q={m['adc_evals_per_query']:.0f} "
              f"kept={m['hamming_kept_frac']:.0%}")

    # ---- regime 2: compressed budget, allocation matters -------------------
    ds2 = make_vector_dataset("gist1m", scale=0.004 if quick else 0.01,
                              num_queries=24 if quick else 64, seed=11)
    preds2 = default_predicates(ds2.attr_cardinality)
    gt2, _ = ground_truth(ds2, preds2, k=10)
    base2 = SquashConfig(num_partitions=6, bits_per_dim=1.0,
                         min_hamming_keep=16, refine_ratio=1.0)
    for name, cfg in {
        "full(b=1d)": base2,
        "uniform-bits(b=1d)": dataclasses.replace(base2, max_bits_per_dim=1),
        "no-klt(b=1d)": dataclasses.replace(base2, use_klt=False),
        "no-refine(b=1d)": dataclasses.replace(base2, enable_refine=False),
    }.items():
        m = _measure(ds2, preds2, gt2, cfg)
        rows.append({"variant": name, "regime": "compressed-budget", **m})
        print(f"  {name:20s} recall@10={m['recall']:.3f}")

    by = {r["variant"]: r for r in rows}
    assert by["full(b=4d)"]["recall"] >= 0.95
    # low-bit pruning: recall holds while ADC work shrinks
    assert by["no-lowbit(b=4d)"]["recall"] <= by["full(b=4d)"]["recall"] + 0.02
    assert by["full(b=4d)"]["adc_evals_per_query"] < \
        0.7 * by["no-lowbit(b=4d)"]["adc_evals_per_query"]
    # non-uniform allocation beats uniform at tight budgets (§2.2)
    assert by["full(b=1d)"]["recall"] > \
        by["uniform-bits(b=1d)"]["recall"] + 0.05
    # refinement buys the final recall points
    assert by["no-refine(b=1d)"]["recall"] <= by["full(b=1d)"]["recall"]
    save_json("BENCH_ablations", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
