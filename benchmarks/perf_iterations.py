"""§Perf hillclimb driver — hypothesis → change → re-lower → measure.

Three pairs (selection rationale in EXPERIMENTS.md §Perf):
  A. arctic-480b × train_4k     (worst roofline fraction, memory-dominated)
  B. llama3-8b × decode_32k     (most collective-bound)
  C. mamba2-370m × prefill_32k  (paper-technique representative)

Each variant toggles ONE mechanism and re-derives the three roofline terms
via benchmarks.roofline.roofline_pair. Results append to
results/perf_iterations.json.

  PYTHONPATH=src python -m benchmarks.perf_iterations [--pair A|B|C] [--variant NAME]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks.roofline import RESULTS_DIR, roofline_pair

import jax.numpy as jnp  # noqa: E402

from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import hints as H  # noqa: E402
from repro.models import ssm as SSM  # noqa: E402


def _set(obj, attr, val):
    old = getattr(obj, attr)
    setattr(obj, attr, val)
    return old


def run_variant(pair: str, variant: str, mesh) -> dict:
    """Configure the variant, measure, restore."""
    kw = {}
    restores = []
    try:
        if pair == "A":          # arctic MoE train
            arch, shape = "arctic-480b", "train_4k"
            if variant == "baseline":
                restores.append((H, "HINTS_ENABLED",
                                 _set(H, "HINTS_ENABLED", False)))
            elif variant == "moe_hints":
                restores.append((H, "HINTS_ENABLED",
                                 _set(H, "HINTS_ENABLED", True)))
            elif variant == "no_remat":
                kw = {"remat": False}
            elif variant == "accum4":
                kw = {"accum_steps": 4}
            else:
                raise ValueError(variant)
        elif pair == "B":        # llama decode
            arch, shape = "llama3-8b", "decode_32k"
            if variant == "baseline":
                kw = {"cache_profile": "tp"}
            elif variant == "dp_cache":
                kw = {"cache_profile": "dp-cache"}
            elif variant == "seq_cache":
                kw = {"cache_profile": "seq"}
            else:
                raise ValueError(variant)
        elif pair == "C":        # mamba prefill
            arch, shape = "mamba2-370m", "prefill_32k"
            if variant == "baseline":
                pass
            elif variant == "ssm_hints":
                restores.append((H, "HINTS_ENABLED",
                                 _set(H, "HINTS_ENABLED", True)))
            elif variant == "no_hints":
                restores.append((H, "HINTS_ENABLED",
                                 _set(H, "HINTS_ENABLED", False)))
            elif variant == "ssd_bf16":
                restores.append((SSM, "SSD_COMPUTE_DTYPE",
                                 _set(SSM, "SSD_COMPUTE_DTYPE",
                                      jnp.bfloat16)))
            elif variant.startswith("chunk"):
                # handled through a registered temp config below
                import dataclasses
                import repro.configs.base as base
                from repro.configs.base import get_config
                cfg = get_config(arch)
                new_chunk = int(variant.split("_")[1])
                tmp = dataclasses.replace(cfg, ssm_chunk=new_chunk,
                                          name=f"mamba2-370m-c{new_chunk}")
                base.register(tmp)
                arch = tmp.name
            elif variant == "ssd_bf16_chunk_128":
                import dataclasses
                import repro.configs.base as base
                from repro.configs.base import get_config
                restores.append((SSM, "SSD_COMPUTE_DTYPE",
                                 _set(SSM, "SSD_COMPUTE_DTYPE",
                                      jnp.bfloat16)))
                cfg = get_config(arch)
                tmp = dataclasses.replace(cfg, ssm_chunk=128,
                                          name="mamba2-370m-bf16c128")
                base.register(tmp)
                arch = tmp.name
            else:
                raise ValueError(variant)
        else:
            raise ValueError(pair)
        res = roofline_pair(arch, shape, mesh, **kw)
        res["pair"] = pair
        res["variant"] = variant
        return res
    finally:
        for obj, attr, old in restores:
            setattr(obj, attr, old)


VARIANTS = {
    "A": ["baseline", "moe_hints", "no_remat", "accum4"],
    "B": ["baseline", "dp_cache", "seq_cache"],
    "C": ["no_hints", "ssm_hints", "chunk_128", "ssd_bf16_chunk_128"],
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default=None, choices=["A", "B", "C"])
    ap.add_argument("--variant", default=None)
    args = ap.parse_args(argv)
    mesh = make_production_mesh()
    pairs = [args.pair] if args.pair else ["A", "B", "C"]
    out_path = os.path.join(RESULTS_DIR, "perf_iterations.json")
    results = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    for p in pairs:
        variants = [args.variant] if args.variant else VARIANTS[p]
        for v in variants:
            print(f"=== pair {p} variant {v} ===", flush=True)
            try:
                res = run_variant(p, v, mesh)
            except Exception as e:
                print(f"FAILED: {e}", flush=True)
                res = {"pair": p, "variant": v, "error": str(e)}
            results = [r for r in results
                       if not (r.get("pair") == p
                               and r.get("variant") == v)] + [res]
            with open(out_path, "w") as f:
                json.dump(results, f, indent=1, default=float)
    print(f"wrote {out_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
