"""Roofline analysis per (arch × shape) on the single-pod production mesh.

Three terms (seconds), per the assignment:

  t_compute    = HLO_FLOPs   / (chips · 197e12 bf16 FLOP/s)
  t_memory     = HLO_bytes   / (chips · 819e9 B/s HBM)
  t_collective = coll_bytes  / (chips · 50e9 B/s ICI link)

**Method — per-layer extrapolation from unrolled compiles.** XLA's
``cost_analysis()`` counts a ``while`` (lax.scan) body ONCE regardless of
trip count, so lowering the full scanned model under-reports FLOPs by ~L×.
Instead we compile two UNROLLED reduced-depth variants (L₁, L₂ layers — or
1/2 schedule *units* for gemma3/zamba2) at the full production shapes and
mesh, then extrapolate:

  per_layer = (cost(L₂) − cost(L₁)) / (L₂ − L₁)
  total     = cost(L₁) − L₁·per_layer  +  L_eff · per_layer

Exact for the 8 uniform-stack archs; for gemma3/zamba2 the tail layers are
folded in as fractional units (documented approximation < 2 %).

MODEL_FLOPS = 6·N·T (train) or 2·N·T (prefill/decode), N = non-embedding
params, N_active for MoE. The ratio MODEL_FLOPS / HLO_FLOPs exposes remat
recompute and attention/quadratic overhead.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Any, Dict, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def _ensure_devices():
    if "xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512 "
            + os.environ.get("XLA_FLAGS", ""))


_ensure_devices()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import INPUT_SHAPES, get_config, list_configs  # noqa: E402
from repro.launch.mesh import HW, make_production_mesh  # noqa: E402
from repro.launch import dryrun as DR  # noqa: E402
from repro.models import attention as ATT  # noqa: E402
from repro.models import transformer as T  # noqa: E402

# Accurate-FLOPs compiles: disable query chunking so the attention q-chunk
# scan has length 1 and cost_analysis counts every attention FLOP (the
# production default 512 keeps the scan for memory discipline; abstract
# compiles have no memory to save).
ATT.Q_CHUNK = 1 << 30


# ---------------------------------------------------------------- model flops

def param_counts(cfg) -> Dict[str, float]:
    sds = jax.eval_shape(lambda k: T.init_params(k, cfg, dtype=jnp.bfloat16),
                         jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(sds)[0]

    def name_of(path):
        return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)

    total = emb = expert = 0
    for path, leaf in flat:
        n = name_of(path)
        sz = int(np.prod(leaf.shape))
        total += sz
        if "embed" in n and "table" in n:
            emb += sz
        if "experts" in n:
            expert += sz
    n_params = total - emb
    if cfg.num_experts:
        active = expert * cfg.top_k / cfg.num_experts
        n_active = n_params - expert + active
    else:
        n_active = n_params
    return {"total": total, "non_embedding": n_params, "active": n_active}


def model_flops(cfg, shape) -> float:
    counts = param_counts(cfg)
    if shape.mode == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * counts["active"] * toks
    if shape.mode == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * counts["active"] * toks
    return 2.0 * counts["active"] * shape.global_batch  # one token / request


# ------------------------------------------------------- per-layer extraction

def _depths(cfg):
    """(L1, L2, L_eff) for the extrapolation."""
    if cfg.local_global_ratio:
        unit = cfg.local_global_ratio + 1
        units = cfg.num_layers // unit
        tail = cfg.num_layers - units * unit
        return unit, 2 * unit, units + tail / unit
    if cfg.hybrid_attn_every:
        e = cfg.hybrid_attn_every
        units = cfg.num_layers // e
        tail = cfg.num_layers - units * e
        return e, 2 * e, units + tail / e
    return 2, 4, cfg.num_layers


def _compile_costs(name: str, shape_name: str, mesh, num_layers: int,
                   **kw) -> Dict[str, float]:
    import repro.configs.base as base
    cfg_full = DR.arch_for_shape(name, INPUT_SHAPES[shape_name])
    cfg = dataclasses.replace(cfg_full, num_layers=num_layers)
    # register a temp name so lower_pair's registry lookup finds it
    tmp = dataclasses.replace(cfg, name=f"__roofline_{name}_{num_layers}")
    base.register(tmp)
    lowered, _, _ = DR.lower_pair(tmp.name, shape_name, mesh=mesh,
                                  unroll=True, **kw)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = DR.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total_bytes"])}


def roofline_pair(name: str, shape_name: str, mesh=None,
                  verbose: bool = True, **kw) -> Dict[str, Any]:
    mesh = mesh or make_production_mesh()
    shape = INPUT_SHAPES[shape_name]
    cfg = DR.arch_for_shape(name, shape)
    l1, l2, l_eff = _depths(cfg)
    c1 = _compile_costs(name, shape_name, mesh, l1, **kw)
    c2 = _compile_costs(name, shape_name, mesh, l2, **kw)
    units_eff = l_eff if cfg.local_global_ratio or cfg.hybrid_attn_every \
        else cfg.num_layers / l1
    total = {}
    for k in c1:
        per_unit = c2[k] - c1[k]
        fixed = c1[k] - per_unit
        est = fixed + units_eff * per_unit
        if per_unit <= 0 or est <= 0:
            # CPU-backend fusion noise made the two-point fit degenerate;
            # fall back to pure proportional scaling from the deeper compile.
            est = c2[k] * units_eff / 2.0
        total[k] = est

    chips = int(np.prod(list(mesh.shape.values())))
    mf = model_flops(cfg, shape)
    hlo_flops_global = total["flops"] * chips      # cost_analysis is per-device
    hlo_bytes_global = total["bytes"] * chips
    coll_global = total["coll"] * chips
    res = {
        "arch": name, "shape": shape_name, "chips": chips,
        "hlo_flops": hlo_flops_global,
        "hlo_bytes": hlo_bytes_global,
        "coll_bytes": coll_global,
        "model_flops": mf,
        "useful_ratio": mf / hlo_flops_global if hlo_flops_global else 0.0,
        "t_compute": hlo_flops_global / (chips * HW.PEAK_BF16_FLOPS),
        "t_memory": hlo_bytes_global / (chips * HW.HBM_BW),
        "t_collective": coll_global / (chips * HW.ICI_BW),
    }
    terms = {k: res[k] for k in ("t_compute", "t_memory", "t_collective")}
    res["bottleneck"] = max(terms, key=terms.get)
    res["roofline_s"] = max(terms.values())
    res["compute_fraction"] = (res["t_compute"]
                               / max(res["roofline_s"], 1e-30))
    if verbose:
        print(f"[roofline] {name} × {shape_name}: "
              f"comp={res['t_compute']*1e3:.2f}ms "
              f"mem={res['t_memory']*1e3:.2f}ms "
              f"coll={res['t_collective']*1e3:.2f}ms "
              f"→ {res['bottleneck']}  useful={res['useful_ratio']:.2f}")
    return res


def run(quick: bool = True, archs=None, shapes=None,
        out_json: str = "roofline_single_pod.json") -> dict:
    archs = archs or (["llama3-8b", "mamba2-370m"] if quick
                      else list_configs())
    shapes = shapes or list(INPUT_SHAPES)
    mesh = make_production_mesh()
    rows = []
    for a in archs:
        for s in shapes:
            try:
                rows.append(roofline_pair(a, s, mesh))
            except Exception as e:
                print(f"[roofline] FAILED {a} × {s}: {e}")
                rows.append({"arch": a, "shape": s, "error": str(e)})
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, out_json)
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    print(f"[roofline] wrote {path} ({len(rows)} rows)")
    return {"rows": rows}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default="roofline_single_pod.json")
    args = ap.parse_args(argv)
    if args.all:
        run(quick=False, out_json=args.json)
    elif args.arch:
        run(quick=True, archs=[args.arch],
            shapes=[args.shape] if args.shape else None, out_json=args.json)
    else:
        run(quick=True, out_json=args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
