"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """(result, best_seconds) — best-of-N wall time."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def save_json(name: str, payload: Dict[str, Any]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def safe_ratio(num: float, den: float):
    """num / den, or None when the denominator is not positive.

    Measured wall-clock denominators can legitimately be 0.0 (sub-resolution
    timer on a trivial run, or a field defaulted before measurement); a
    modeled-vs-measured ratio over one is noise, not data, so callers
    persist None and skip the derived prints instead of dividing.
    """
    if den is None or den <= 0:
        return None
    return num / den


def recall_at_k(found_ids: np.ndarray, gt_ids: np.ndarray) -> float:
    """Paper §5.1: recall@k = |G ∩ R| / k, averaged over queries."""
    q, k = gt_ids.shape
    total = 0.0
    for i in range(q):
        g = set(int(x) for x in gt_ids[i] if x >= 0)
        r = set(int(x) for x in found_ids[i] if x >= 0)
        denom = min(k, len(g)) or 1
        total += len(g & r) / denom
    return total / q


def header(title: str):
    print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")


def build_tiny_squash_index(*, scale: float = 0.003, num_queries: int = 16,
                            num_partitions: int = 6, seed: int = 3):
    """Small dataset + predicates + built SquashIndex for runtime benches.

    Returns (dataset, predicates, index) — the shared fixture of
    bench_invocation / bench_dre / bench_cost.
    """
    from repro.core.pipeline import SquashConfig, SquashIndex
    from repro.data.synthetic import default_predicates, make_vector_dataset

    ds = make_vector_dataset("sift1m", scale=scale, num_queries=num_queries,
                             seed=seed)
    preds = default_predicates(ds.attr_cardinality)
    idx = SquashIndex.build(
        ds.vectors, ds.attributes,
        SquashConfig(num_partitions=num_partitions, kmeans_iters=4,
                     lloyd_iters=6), seed=seed)
    return ds, preds, idx
