"""Paper Fig. 8 — daily cost vs (uniform) query volume.

The per-batch dollars now come from a real serverless-runtime trace: one
warm wave of the N_QA = 84 fleet (F=4, l_max=3) over a 10-partition index,
with node busy times pinned to the Fig. 10 sweet-spot latencies (≈2.5 s QA /
≈3 s QP per invocation). Because those busy times are per-wave constants,
the wave's fleet cost prices any batch up to the paper's 1000 queries; the
daily curve scales it against the two always-on server baselines
(2× c7i.16xlarge / 2× c7i.4xlarge) to validate the paper's ordering:
serverless cheaper until ~1M–3.5M queries/day.

Since PR 5 the bench leads with a modeled-vs-measured latency comparison:
the same warm wave under the virtual-time LocalTransport (modeled makespan)
and under the real worker substrates — multi-process ProcessTransport and
the TCP SocketTransport fleet (measured wall-clock) — persisted under
``modeled_vs_measured`` in the saved JSON.
"""

from __future__ import annotations

from benchmarks.common import header, safe_ratio, save_json
from repro.core.cost_model import (PricingConstants, daily_cost_curve,
                                   server_baseline_cost)

VOLUMES = [1_000, 10_000, 100_000, 500_000, 1_000_000, 3_500_000, 10_000_000]

BATCH_QUERIES = 1000   # the paper's batch; wave cost is Q-independent here


def _measured_batch_cost() -> dict:
    from benchmarks.common import build_tiny_squash_index
    from repro.serverless import RuntimeConfig, ServerlessRuntime

    ds, preds, idx = build_tiny_squash_index(
        scale=0.004, num_queries=64, num_partitions=10, seed=5)
    rt = ServerlessRuntime(idx, RuntimeConfig(
        branching=4, max_level=3, warm_prob=0.95,
        qa_compute_s=2.5, qp_compute_s=3.0, co_compute_s=1.0))
    rt.search(ds.queries, preds, k=10)            # cold wave: warm the fleet
    trace = rt.search(ds.queries, preds, k=10).trace
    return {"trace": trace, "per_batch": trace.cost["total"]}


def _modeled_vs_measured_latency() -> dict:
    """Modeled §3.5 timeline vs real measured wall-clock, same choreography.

    The same small fleet runs once under LocalTransport (virtual clock: QP
    busy time pinned to the injected sleep, concurrency modeled by
    staggered launch) and once under each real substrate — ProcessTransport
    (pipes) and SocketTransport (TCP, auto-spawned loopback hosts) — where
    the sleep actually elapses inside real workers, concurrently. All warm
    waves are compared: the modeled makespan prices the fleet, the measured
    ones are what a client would clock over each wire.
    """
    from benchmarks.common import build_tiny_squash_index
    from repro.serverless import RuntimeConfig, ServerlessRuntime

    sleep = 0.1
    ds, preds, idx = build_tiny_squash_index(
        scale=0.003, num_queries=16, num_partitions=4, seed=5)
    local = ServerlessRuntime(idx, RuntimeConfig(
        branching=2, max_level=1, qp_compute_s=sleep))
    local.search(ds.queries, preds, k=10)
    t_local = local.search(ds.queries, preds, k=10).trace

    def real_wave(transport):
        rt = ServerlessRuntime(idx, RuntimeConfig(
            branching=2, max_level=1, transport=transport, qa_workers=1,
            worker_sleep_s=sleep))
        try:
            rt.search(ds.queries, preds, k=10)    # cold: build worker state
            return rt.search(ds.queries, preds, k=10).trace
        finally:
            rt.close()

    t_proc = real_wave("process")
    t_sock = real_wave("socket")
    return {
        "qp_busy_s": sleep,
        "qp_invocations": t_proc.invocations("qp"),
        "modeled_local_s": t_local.makespan_s,
        "modeled_process_s": t_proc.makespan_s,
        "measured_process_s": t_proc.measured_makespan_s,
        "measured_socket_s": t_sock.measured_makespan_s,
        # None (not a division blow-up / inf) when a measured makespan is 0.
        "modeled_over_measured_process": safe_ratio(
            t_proc.makespan_s, t_proc.measured_makespan_s),
        "modeled_over_measured_socket": safe_ratio(
            t_sock.makespan_s, t_sock.measured_makespan_s),
        "socket_hosts": t_sock.worker_hosts,
        "cost_modeled_local": t_local.cost["total"],
        "cost_modeled_process": t_proc.cost["total"],
    }


def _autotune_adc_savings() -> dict:
    """Static vs autotuned keep budgets on a measured (not pinned) warm wave.

    The recall-targeted profile (core/autotune.py) reduces per-QP ADC
    evaluations; with measured handler busy times those evaluations are
    exactly what the §3.5 GB-second fold prices, so the saving reads
    directly off the per-node traces (``NodeTrace.adc_evals``).
    """
    from benchmarks.common import build_tiny_squash_index
    from repro.serverless import RuntimeConfig, ServerlessRuntime

    ds, preds, idx = build_tiny_squash_index(
        scale=0.004, num_queries=64, num_partitions=10, seed=5)
    cfg = RuntimeConfig(branching=4, max_level=2, warm_prob=1.0)

    def warm_wave(runtime):
        runtime.search(ds.queries, preds, k=10)       # cold: trace + warm
        return runtime.search(ds.queries, preds, k=10).trace

    t_static = warm_wave(ServerlessRuntime(idx, cfg))
    idx.autotune(recall_target=0.95, k=10, sample=48, seed=5)
    t_tuned = warm_wave(ServerlessRuntime(idx, cfg))
    idx.set_profile(None)
    adc_static = sum(n.adc_evals for n in t_static.nodes)
    adc_tuned = sum(n.adc_evals for n in t_tuned.nodes)
    assert adc_tuned < adc_static, "autotune must cut ADC evaluations"
    return {
        "adc_static": adc_static,
        "adc_tuned": adc_tuned,
        "adc_savings": 1.0 - adc_tuned / max(adc_static, 1),
        "qp_gbs_static": t_static.fleet.t_qp_s,
        "qp_gbs_tuned": t_tuned.fleet.t_qp_s,
        "cost_static": t_static.cost["total"],
        "cost_tuned": t_tuned.cost["total"],
    }


def run(quick: bool = True) -> dict:
    header("Fig. 8 — daily cost of SQUASH vs provisioned servers")
    lat = _modeled_vs_measured_latency()
    print(f"  modeled vs measured (warm wave, {lat['qp_invocations']} QPs x "
          f"{lat['qp_busy_s']:.2f}s busy): modeled local "
          f"{lat['modeled_local_s']:.3f}s / modeled process "
          f"{lat['modeled_process_s']:.3f}s / MEASURED process "
          f"{lat['measured_process_s']:.3f}s / MEASURED socket "
          f"{lat['measured_socket_s']:.3f}s "
          f"({len(lat['socket_hosts'])} hosts)")
    ratio = lat["modeled_over_measured_process"]
    if ratio is not None:
        print(f"  modeled/measured ratio: process {ratio:.2f}x"
              + (f" / socket {lat['modeled_over_measured_socket']:.2f}x"
                 if lat["modeled_over_measured_socket"] is not None else ""))
    tune = _autotune_adc_savings()
    print(f"  autotuned keep budgets: ADC evals {tune['adc_static']} → "
          f"{tune['adc_tuned']} ({tune['adc_savings']:.0%} fewer), "
          f"measured warm wave ${tune['cost_static']:.6f} → "
          f"${tune['cost_tuned']:.6f}")
    measured = _measured_batch_cost()
    trace = measured["trace"]
    per_batch = measured["per_batch"]
    print(f"  measured warm wave: {trace.invocations('qa')} QA / "
          f"{trace.invocations('qp')} QP invocations, "
          f"${per_batch:.4f} per batch "
          f"(λ-runtime {trace.cost['lambda_runtime'] / per_batch:.0%})")
    squash_daily = daily_cost_curve(per_batch, BATCH_QUERIES, VOLUMES)
    prices = PricingConstants()
    big = server_baseline_cost(24.0, 2, prices.ec2_c7i_16xlarge_hour)
    small = server_baseline_cost(24.0, 2, prices.ec2_c7i_4xlarge_hour)
    rows = []
    for v, c in zip(VOLUMES, squash_daily):
        rows.append({"daily_queries": v, "squash": c,
                     "server_large": big, "server_small": small})
        print(f"  {v:>10,d} q/day  SQUASH=${c:8.2f}  small-2x=${small:7.2f} "
              f" large-2x=${big:7.2f}")
    # Paper ordering: SQUASH cheaper than the small server at low volume,
    # servers win at very large volumes.
    assert rows[0]["squash"] < small
    assert rows[-1]["squash"] > small
    crossover = next(r["daily_queries"] for r in rows
                     if r["squash"] > small)
    print(f"  crossover vs 2×c7i.4xlarge at ≈{crossover:,} q/day "
          f"(paper: ~1M–3.5M)")
    assert 100_000 <= crossover <= 50_000_000
    save_json("BENCH_cost", {"rows": rows, "per_batch_cost": per_batch,
                             "crossover": crossover,
                             "autotune": tune,
                             "modeled_vs_measured": lat,
                             "fleet": {"n_qa": trace.fleet.n_qa,
                                       "n_qp": trace.fleet.n_qp,
                                       "t_qa_s": trace.fleet.t_qa_s,
                                       "t_qp_s": trace.fleet.t_qp_s}})
    return {"rows": rows}


if __name__ == "__main__":
    run()
