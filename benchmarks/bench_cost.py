"""Paper Fig. 8 — daily cost vs (uniform) query volume.

SQUASH (N_QA = 84 fleet, priced per batch by Eqs. 3–8) against the two
always-on server baselines (2× c7i.16xlarge / 2× c7i.4xlarge). Validates the
paper's ordering: serverless is cheaper until ~1M–3.5M queries/day.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import header, save_json
from repro.core.cost_model import (LambdaFleet, PricingConstants,
                                   daily_cost_curve, server_baseline_cost,
                                   squash_query_cost)

VOLUMES = [1_000, 10_000, 100_000, 500_000, 1_000_000, 3_500_000, 10_000_000]


def run(quick: bool = True) -> dict:
    header("Fig. 8 — daily cost of SQUASH vs provisioned servers")
    # A measured-representative batch: N_QA=84, ~2 QPs per QA visit,
    # sub-second runtimes (cf. Fig. 10 sweet spot), warm fleet.
    batch_q = 1000
    # Fig. 10 sweet-spot latencies: ≈2.5 s QA / ≈3 s QP busy time per wave.
    fleet = LambdaFleet(
        n_qa=84, n_qp=170,
        t_qa_s=84 * 2.5, t_qp_s=170 * 3.0, t_co_s=5.0,
        s3_gets=0, efs_read_bytes=batch_q * 2 * 10 * 512,
    )
    per_batch = squash_query_cost(fleet)["total"]
    squash_daily = daily_cost_curve(per_batch, batch_q, VOLUMES)
    prices = PricingConstants()
    big = server_baseline_cost(24.0, 2, prices.ec2_c7i_16xlarge_hour)
    small = server_baseline_cost(24.0, 2, prices.ec2_c7i_4xlarge_hour)
    rows = []
    for v, c in zip(VOLUMES, squash_daily):
        rows.append({"daily_queries": v, "squash": c,
                     "server_large": big, "server_small": small})
        print(f"  {v:>10,d} q/day  SQUASH=${c:8.2f}  small-2x=${small:7.2f} "
              f" large-2x=${big:7.2f}")
    # Paper ordering: SQUASH cheaper than the small server at low volume,
    # servers win at very large volumes.
    assert rows[0]["squash"] < small
    assert rows[-1]["squash"] > small
    crossover = next(r["daily_queries"] for r in rows
                     if r["squash"] > small)
    print(f"  crossover vs 2×c7i.4xlarge at ≈{crossover:,} q/day "
          f"(paper: ~1M–3.5M)")
    save_json("bench_cost", {"rows": rows, "per_batch_cost": per_batch,
                             "crossover": crossover})
    return {"rows": rows}


if __name__ == "__main__":
    run()
