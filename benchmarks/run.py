"""Benchmark driver: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--skip-roofline]

Paper-artifact map (DESIGN.md §6):
  Fig. 2  → bench_compression     Fig. 6  → bench_dre
  Fig. 8  → bench_cost            Fig. 9  → bench_qps
  Fig. 10 → bench_scaling         Table 3 → bench_caching
  Alg. 2  → bench_invocation      kernels → bench_kernels
  §Roofline → roofline (subprocess: needs 512 XLA host devices before
              jax init, so it cannot share this interpreter)
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size runs (default: quick)")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (bench_ablations, bench_baselines, bench_caching,
                            bench_compression, bench_cost, bench_dre,
                            bench_invocation, bench_kernels, bench_kv_quant,
                            bench_qps, bench_recall, bench_scaling)
    suite = {
        "compression": bench_compression,
        "invocation": bench_invocation,
        "dre": bench_dre,
        "cost": bench_cost,
        "kernels": bench_kernels,
        "recall": bench_recall,
        "qps": bench_qps,
        "scaling": bench_scaling,
        "caching": bench_caching,
        "baselines": bench_baselines,
        "ablations": bench_ablations,
        "kv_quant": bench_kv_quant,
    }
    only = set(args.only.split(",")) if args.only else None
    failures = []
    t_start = time.time()
    for name, mod in suite.items():
        if only and name not in only:
            continue
        try:
            mod.run(quick=quick)
        except Exception as e:
            print(f"[bench:{name}] FAILED: {type(e).__name__}: {e}")
            failures.append(name)
    if not args.skip_roofline and (only is None or "roofline" in only):
        print("\n" + "=" * 72 + "\nRoofline (subprocess, 512 host devices)\n"
              + "=" * 72)
        cmd = [sys.executable, "-m", "benchmarks.roofline",
               "--json", "roofline_quick.json"]
        env = dict(os.environ)
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        rc = subprocess.call(cmd, env=env)
        if rc != 0:
            failures.append("roofline")
    dt = time.time() - t_start
    print(f"\n[benchmarks] done in {dt:.0f}s; "
          f"{'ALL OK' if not failures else 'FAILURES: ' + ','.join(failures)}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
