"""Benchmark driver: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--skip-roofline]
  PYTHONPATH=src python -m benchmarks.run --smoke   # tiny post-test gate

Paper-artifact map (DESIGN.md §7):
  Fig. 2  → bench_compression     Fig. 6  → bench_dre
  Fig. 8  → bench_cost            Fig. 9  → bench_qps
  Fig. 10 → bench_scaling         §5.3    → bench_recall (+ autotune)
  Alg. 2  → bench_invocation      kernels → bench_kernels
  §5.6 + Table 3 → bench_cache (the one cache bench: runtime result
              cache on a Zipf workload + the Table 3 cache-ratio study)
  §Roofline → roofline (subprocess: needs 512 XLA host devices before
              jax init, so it cannot share this interpreter)
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

# Path bootstrap: make `repro` importable from a bare checkout
# (`python -m benchmarks.run --smoke` without PYTHONPATH=src).
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def smoke() -> int:
    """Tiny-shape sanity gate: both query data planes, asserted parity.

    Builds a small index, runs identical query batches through the numpy
    and jax backends (selective + empty predicates), and asserts identical
    ids plus equal recall against brute force. Intended as a fast
    post-test CI step: ``python -m benchmarks.run --smoke``.
    """
    import jax
    import numpy as np

    jax.config.update("jax_enable_x64", True)

    from repro.core.attributes import Predicate
    from repro.core.pipeline import SquashConfig, SquashIndex
    from repro.data import synthetic

    t0 = time.time()
    ds = synthetic.make_vector_dataset("sift1m", scale=0.004, num_queries=16,
                                       seed=7)
    preds = synthetic.default_predicates(ds.attr_cardinality)
    cfg = SquashConfig(num_partitions=6, kmeans_iters=4, lloyd_iters=6)
    idx = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=7)
    gt_ids, _ = synthetic.ground_truth(ds, preds, k=10)

    def recall_of(ids):
        per_q = []
        for qi in range(ds.queries.shape[0]):
            g = set(gt_ids[qi][gt_ids[qi] >= 0].tolist())
            if g:
                per_q.append(len(g & set(ids[qi].tolist())) / len(g))
        return float(np.mean(per_q))

    recalls = {}
    results = {}
    for backend in ("numpy", "jax"):
        ids, dists, stats = idx.search(ds.queries, preds, k=10,
                                       backend=backend)
        results[backend] = (ids, dists, stats)
        recalls[backend] = recall_of(ids)
    ids_n, _, stats_n = results["numpy"]
    ids_j, _, stats_j = results["jax"]
    assert np.array_equal(ids_n, ids_j), "backend ids diverged"
    assert recalls["numpy"] == recalls["jax"], f"recall drift: {recalls}"
    assert stats_n == stats_j, f"stats drift: {stats_n} vs {stats_j}"

    empty = [Predicate(attr=0, op="=", lo=1e9)]
    for backend in ("numpy", "jax"):
        ids, _, _ = idx.search(ds.queries[:4], empty, k=5, backend=backend)
        assert (ids == -1).all(), f"{backend}: empty predicate leaked ids"

    # Serverless-runtime gate: the full Coordinator → QA → QP path over the
    # same tiny index must return the jax plane's ids bit-for-bit and emit
    # latency / payload / DRE / cost traces.
    from repro.serverless import RuntimeConfig, ServerlessRuntime

    rt = ServerlessRuntime(idx, RuntimeConfig(branching=3, max_level=2))
    res = rt.search(ds.queries, preds, k=10)
    assert np.array_equal(res.ids, ids_j), "serverless runtime ids diverged"
    assert res.stats == stats_j, (
        f"serverless stats drift: {res.stats} vs {stats_j}")
    tr = res.trace
    assert tr.makespan_s > 0 and tr.payload_bytes > 0
    assert tr.cost["total"] > 0 and tr.dre.invocations > 0
    assert tr.invocations("qa") == 12 and tr.invocations("co") == 1

    # Transport-parity gate: the same choreography over the real
    # multi-process worker pool must return the jax plane's ids bit-for-bit
    # with equal stats, on a measured (not virtual) clock, with zero crash
    # retries. CI wraps --smoke in a hard `timeout` so a hung worker pool
    # fails the job fast instead of stalling it.
    rt_proc = ServerlessRuntime(idx, RuntimeConfig(
        branching=2, max_level=1, transport="process", qa_workers=1,
        invoke_timeout_s=120.0))
    try:
        res_p = rt_proc.search(ds.queries, preds, k=10)
        assert np.array_equal(res_p.ids, ids_j), "process-transport ids diverged"
        assert res_p.stats == stats_j, (
            f"process-transport stats drift: {res_p.stats} vs {stats_j}")
        tp = res_p.trace
        assert tp.transport == "process" and tp.measured_makespan_s > 0
        assert tp.worker_retries == 0, "workers crashed during the smoke wave"
        assert tp.dre.invocations > 0 and tp.cost["total"] > 0
        warm_p = rt_proc.search(ds.queries, preds, k=10).trace
        assert warm_p.dre.s3_gets == 0, "live workers must serve warm"
    finally:
        rt_proc.close()

    # Socket-parity gate: the same choreography again, this time over the
    # TCP worker fleet (auto-spawned loopback hosts, length-prefixed codec
    # frames, heartbeats). Ids and stats must stay bitwise-identical, every
    # served node must report the host:port that ran it, and the smoke wave
    # must complete with zero reconnect-driven retries.
    rt_sock = ServerlessRuntime(idx, RuntimeConfig(
        branching=2, max_level=1, transport="socket", qa_workers=1,
        invoke_timeout_s=120.0))
    try:
        res_s = rt_sock.search(ds.queries, preds, k=10)
        assert np.array_equal(res_s.ids, ids_j), "socket-transport ids diverged"
        assert res_s.stats == stats_j, (
            f"socket-transport stats drift: {res_s.stats} vs {stats_j}")
        ts = res_s.trace
        assert ts.transport == "socket" and ts.measured_makespan_s > 0
        assert ts.worker_retries == 0, "socket links dropped during smoke wave"
        assert ts.worker_hosts, "socket trace must carry worker hosts"
        assert all(n.worker_host for n in ts.nodes if n.kind != "co"), (
            "served socket QA/QP nodes must record their host")
        warm_s = rt_sock.search(ds.queries, preds, k=10).trace
        assert warm_s.dre.s3_gets == 0, "live socket hosts must serve warm"
    finally:
        rt_sock.close()

    # §5.6 result-cache gate: with the cache enabled, both the cold pass and
    # the fully-repeated pass must stay bitwise-identical to the jax plane,
    # while the repeat pass shows strictly fewer invocations, payload bytes
    # and §3.5 dollars (hits never enter the QA/QP fleet).
    rt_c = ServerlessRuntime(idx, RuntimeConfig(branching=3, max_level=2,
                                                cache_enabled=True))
    c1 = rt_c.search(ds.queries, preds, k=10)
    c2 = rt_c.search(ds.queries, preds, k=10)
    assert np.array_equal(c1.ids, ids_j), "cache-on cold ids diverged"
    assert np.array_equal(c2.ids, ids_j), "cache-served ids diverged"
    t2 = c2.trace
    assert t2.cache_hits == ds.queries.shape[0] and t2.cache_misses == 0
    assert len(t2.nodes) < len(tr.nodes)
    assert t2.payload_bytes < tr.payload_bytes
    assert t2.cost["total"] < tr.cost["total"]

    # Observability gate (repro.obs): the same choreography with tracing ON
    # must stay bitwise-identical to the jax plane across all three
    # transports, while persisting one JSONL trace record per transport —
    # CO/QA/QP spans stitched parent→child, worker-side sub-spans from both
    # real substrates — and a metrics registry that yields latency
    # quantiles. Fleet telemetry (PR 10) rides the same pass: pipe workers
    # and socket hosts must surface in ``fleet_snapshot()`` under pid/host
    # labels with worker-side counters the client-local registry never
    # sees, the rolling SLO gate must pass over the exported records, and
    # every record's per-node dollar attribution must sum back to its §3.5
    # cost total. The trace file and the merged metrics snapshot are
    # uploaded as CI artifacts.
    import json as _json
    import math as _math

    from repro.obs.metrics import REGISTRY as obs_registry
    from repro.obs.export import read_jsonl
    from repro.obs.slo import SloTracker, default_policy

    trace_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "results", "SMOKE_trace.jsonl")
    metrics_path = os.path.join(os.path.dirname(trace_path),
                                "SMOKE_metrics.json")
    if os.path.exists(trace_path):
        os.remove(trace_path)
    obs_registry.reset()
    try:
        fleet = {}
        for transport in ("local", "process", "socket"):
            rt_o = ServerlessRuntime(idx, RuntimeConfig(
                branching=2, max_level=1, transport=transport, qa_workers=1,
                invoke_timeout_s=120.0, obs_enabled=True,
                obs_trace_path=trace_path))
            try:
                res_o = rt_o.search(ds.queries, preds, k=10)
                assert np.array_equal(res_o.ids, ids_j), (
                    f"{transport}: obs-enabled ids diverged")
                assert res_o.stats == stats_j, (
                    f"{transport}: obs-enabled stats drift")
                fleet[transport] = obs_registry.fleet_snapshot()
            finally:
                rt_o.close()
        # Fleet-aggregation gate. The registry accumulates across the loop:
        # after the local pass there must be no remote sources; the process
        # pass must add pid-labelled pipe workers; the socket pass must add
        # host:port/pid-labelled hosts — each carrying worker.* instruments
        # that exist in the merged view but never client-locally.
        assert not fleet["local"]["remote"], (
            f"local transport leaked remote sources: "
            f"{sorted(fleet['local']['remote'])}")
        pid_src = [s for s in fleet["process"]["remote"]
                   if s.startswith("pid:")]
        assert pid_src, "pipe workers missing from fleet_snapshot()"
        host_src = [s for s in fleet["socket"]["remote"]
                    if "/pid:" in s and ":" in s.split("/", 1)[0]]
        assert host_src, (
            f"socket hosts missing from fleet_snapshot(): "
            f"{sorted(fleet['socket']['remote'])}")
        for label, sources in (("pipe", pid_src), ("host", host_src)):
            served = sum(
                fleet["socket"]["remote"][s]["counters"].get(
                    "worker.requests", 0) for s in sources)
            assert served > 0, f"{label} workers reported no requests"
        merged_c = fleet["socket"]["merged"]["counters"]
        local_c = fleet["socket"]["local"]["counters"]
        assert merged_c.get("worker.requests", 0) > 0
        assert "worker.requests" not in local_c, (
            "worker-side counters must not exist client-locally")
        assert "worker.handle_s" in fleet["socket"]["merged"]["histograms"]
        records = read_jsonl(trace_path)
        assert len(records) == 3, f"expected 3 trace records, got {len(records)}"
        by_transport = {r["meta"]["transport"]: r for r in records}
        for transport in ("process", "socket"):
            spans = by_transport[transport]["spans"]
            kinds = {s["attrs"].get("kind") for s in spans
                     if s["attrs"].get("kind")}
            assert kinds == {"co", "qa", "qp"}, (
                f"{transport}: missing node kinds in trace: {kinds}")
            wnames = {s["name"] for s in spans
                      if s["name"].startswith("worker.")}
            assert {"worker.compute", "worker.serialize"} <= wnames, (
                f"{transport}: worker-side sub-spans missing: {wnames}")
            ids_in_run = {s["id"] for s in spans}
            assert all(s["parent"] is None or s["parent"] in ids_in_run
                       for s in spans), f"{transport}: dangling span parent"
        snap = obs_registry.snapshot()
        h = snap["histograms"]["transport.process.invoke_s"]
        assert h["p50"] is not None and h["p99"] is not None
        obs_p50, obs_p99 = h["p50"], h["p99"]
        # Rolling-SLO gate: the monitors must evaluate p50/p99 (and the
        # retry/error budgets) from the live record stream, conclusively,
        # and the permissive default policy must pass a healthy smoke run.
        slo_tracker = SloTracker.from_records(records)
        slo_report = default_policy().evaluate(slo_tracker)
        assert slo_report.conclusive, (
            f"SLO monitors missing data: {slo_report.summary()}")
        assert slo_report.ok, f"SLO gate failed: {slo_report.summary()}"
        # Cost-attribution gate: per-node dollars must sum back to each
        # run's Eqs. 3–8 total (exact by construction, checked to float
        # noise), and every exported record must carry a fleet snapshot.
        for r in records:
            rows = r["run_trace"]["dollars_attributed"]
            total = r["run_trace"]["cost"]["total"]
            attributed = _math.fsum(x["total"] for x in rows)
            assert rows and abs(attributed - total) <= 1e-9 * total, (
                f"{r['meta']['transport']}: attributed ${attributed} != "
                f"run total ${total}")
            assert r.get("metrics") is not None, (
                f"{r['meta']['transport']}: record missing fleet metrics")
        with open(metrics_path, "w") as f:
            _json.dump({"fleet": obs_registry.fleet_snapshot(),
                        "slo": slo_report.to_json(),
                        "slo_monitors": slo_tracker.snapshot()},
                       f, indent=2, default=float)
    finally:
        obs_registry.disable()
        obs_registry.reset()

    # Search-under-mutation gate (live index, ISSUE 9): on its own small
    # build — streaming insert + delete, then drop-only compaction, must be
    # bitwise-invisible: ids AND SearchStats identical during-vs-after,
    # numpy ≡ jax ≡ serverless at every step, tombstones never returned,
    # and the §5.6 cache keeps serving entries compaction didn't touch.
    from repro.core.live import LiveIndex

    ds_m = synthetic.make_vector_dataset("sift1m", scale=0.002,
                                         num_queries=8, seed=13)
    idx_m = SquashIndex.build(
        ds_m.vectors, ds_m.attributes,
        SquashConfig(num_partitions=5, kmeans_iters=4, lloyd_iters=6),
        seed=13)
    live = LiveIndex(idx_m)
    rt_m = ServerlessRuntime(live, RuntimeConfig(cache_enabled=True))
    m0 = rt_m.search(ds_m.queries, [], k=10)
    live.insert(ds_m.vectors[:4] + 1e-3, ds_m.attributes[:4])
    live.delete(m0.ids[:, 0])
    m_during = rt_m.search(ds_m.queries, [], k=10)
    ref_n = idx_m.search(ds_m.queries, [], k=10, backend="numpy")
    ref_j = idx_m.search(ds_m.queries, [], k=10, backend="jax")
    assert np.array_equal(ref_n[0], ref_j[0]), "mutated numpy/jax diverged"
    assert ref_n[2] == ref_j[2], "mutated numpy/jax stats drift"
    assert np.array_equal(m_during.ids, ref_j[0]), "mutated serverless diverged"
    assert m_during.stats == ref_j[2], "mutated serverless stats drift"
    assert np.intersect1d(m_during.ids.ravel(), m0.ids[:, 0]).size == 0, (
        "tombstoned ids leaked into results")
    for pid in live.dirty_partitions():
        live.compact(pid, requantize=False)
    m_after = rt_m.search(ds_m.queries, [], k=10)
    assert np.array_equal(m_after.ids, m_during.ids), (
        "search during compaction != search after")
    assert np.array_equal(m_after.dists, m_during.dists)
    assert m_after.trace.cache_hits == ds_m.queries.shape[0], (
        "drop-only compaction must not evict untouched cache entries")
    ref_a = idx_m.search(ds_m.queries, [], k=10, backend="jax")
    assert np.array_equal(ref_a[0], m_during.ids)
    assert ref_a[2] == m_during.stats, "compaction changed stage counters"

    # Recall-targeted autotune gate: the calibrated per-partition profile
    # must hold recall at-or-above the static configuration's while
    # evaluating strictly fewer ADC candidates, with all three backends
    # still bitwise-identical under the same profile.
    static_recall = recalls["numpy"]
    static_adc = stats_n.adc_evals
    idx.autotune(recall_target=0.95, k=10, sample=48, seed=7)
    ids_tn, _, st_tn = idx.search(ds.queries, preds, k=10, backend="numpy")
    ids_tj, _, st_tj = idx.search(ds.queries, preds, k=10, backend="jax")
    assert np.array_equal(ids_tn, ids_tj), "autotuned backend ids diverged"
    assert st_tn == st_tj, f"autotuned stats drift: {st_tn} vs {st_tj}"
    rt_t = ServerlessRuntime(idx, RuntimeConfig(branching=3, max_level=2))
    res_t = rt_t.search(ds.queries, preds, k=10)
    assert np.array_equal(res_t.ids, ids_tj), "autotuned serverless diverged"
    tuned_recall = recall_of(ids_tn)
    assert tuned_recall >= min(0.95, static_recall), (
        f"autotuned recall {tuned_recall:.3f} fell below gate")
    assert st_tn.adc_evals < static_adc, (
        f"autotune must prune more: {st_tn.adc_evals} vs {static_adc}")

    print(f"[smoke] OK in {time.time() - t0:.1f}s — recall@10="
          f"{recalls['jax']:.3f}, ids identical across numpy/jax/serverless"
          f" (±cache, local AND process AND socket transport; process "
          f"measured {tp.measured_makespan_s:.2f}s cold / "
          f"{warm_p.measured_makespan_s:.2f}s warm; socket measured "
          f"{ts.measured_makespan_s:.2f}s cold over "
          f"{len(ts.worker_hosts)} host(s)); runtime: "
          f"{tr.invocations('qa')} QA + "
          f"{tr.invocations('qp')} QP, ${tr.cost['total']:.6f}/batch; "
          f"cached repeat: {len(t2.nodes)} invocation(s), "
          f"${t2.cost['total']:.6f}/batch; autotuned: recall@10="
          f"{tuned_recall:.3f} at {st_tn.adc_evals}/{static_adc} ADC evals; "
          f"obs: 3-transport trace at {os.path.relpath(trace_path)}, "
          f"process invoke p50={obs_p50 * 1e3:.1f}ms p99={obs_p99 * 1e3:.1f}ms"
          f"; fleet: {len(pid_src)} pipe + {len(host_src)} host source(s) "
          f"aggregated, SLO gate PASS "
          f"(p99={slo_tracker.snapshot()['latency_p99_s']:.2f}s), "
          f"metrics snapshot at {os.path.relpath(metrics_path)}"
          f"; live-index mutation gate: search during ≡ after compaction, "
          f"{live.live_count()} live rows")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size runs (default: quick)")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny both-backends parity gate, then exit")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke()
    quick = not args.full

    from benchmarks import (bench_ablations, bench_baselines, bench_cache,
                            bench_compression, bench_cost, bench_dre,
                            bench_invocation, bench_kernels, bench_kv_quant,
                            bench_qps, bench_recall, bench_scaling)
    suite = {
        "compression": bench_compression,
        "invocation": bench_invocation,
        "dre": bench_dre,
        # The one cache bench: §5.6 Zipf workload + Table 3 cache ratios
        # (the seed's separate bench_caching is folded into bench_cache).
        "cache": bench_cache,
        "cost": bench_cost,
        "kernels": bench_kernels,
        "recall": bench_recall,
        "qps": bench_qps,
        "scaling": bench_scaling,
        "baselines": bench_baselines,
        "ablations": bench_ablations,
        "kv_quant": bench_kv_quant,
    }
    only = set(args.only.split(",")) if args.only else None
    failures = []
    t_start = time.time()
    results_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               "..", "results")
    for name, mod in suite.items():
        if only and name not in only:
            continue
        try:
            mod.run(quick=quick)
            # Persistence guarantee: every bench must leave its paper
            # artifact behind — a bench that runs green but writes nothing
            # breaks the trajectory (plots/CI consume these files).
            artifact = os.path.join(results_dir, f"BENCH_{name}.json")
            if not os.path.exists(artifact):
                raise FileNotFoundError(
                    f"bench ran but wrote no {os.path.basename(artifact)}")
        except Exception as e:
            print(f"[bench:{name}] FAILED: {type(e).__name__}: {e}")
            failures.append(name)
    if not args.skip_roofline and (only is None or "roofline" in only):
        print("\n" + "=" * 72 + "\nRoofline (subprocess, 512 host devices)\n"
              + "=" * 72)
        cmd = [sys.executable, "-m", "benchmarks.roofline",
               "--json", "roofline_quick.json"]
        env = dict(os.environ)
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        rc = subprocess.call(cmd, env=env)
        if rc != 0:
            failures.append("roofline")
    dt = time.time() - t_start
    print(f"\n[benchmarks] done in {dt:.0f}s; "
          f"{'ALL OK' if not failures else 'FAILURES: ' + ','.join(failures)}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
