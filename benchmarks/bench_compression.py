"""Paper Fig. 2 — bit savings of OSQ segment packing vs standard SQ.

For each paper dataset (Table 2 shapes, b = 4d, S = 8) we compute the real
bit-allocation (variance-greedy on the synthetic stand-in) and compare the
storage footprint: G_SQ = d segments/vector vs G_OSQ = ceil(b/S).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import header, save_json
from repro.core import osq, segments
from repro.data.synthetic import DATASET_PRESETS, make_vector_dataset


def run(quick: bool = True) -> dict:
    header("Fig. 2 — OSQ vs SQ bit wastage / compression")
    rows = []
    for preset, spec in DATASET_PRESETS.items():
        d = spec["d"]
        b = 4 * d                      # paper: bit budget b = 4·d, S = 8
        ds = make_vector_dataset(preset, scale=0.002 if quick else 0.01,
                                 num_queries=4)
        var = ds.vectors.astype(np.float64).var(axis=0)
        bits = osq.allocate_bits(var, b)
        w = segments.sq_wastage(bits, seg_bits=8)
        g_osq = int(np.ceil(b / 8))
        rows.append({
            "dataset": preset, "d": d, "b": b,
            "segments_sq": w["segments_sq"], "segments_osq": w["segments_osq"],
            "waste_bits_sq": w["waste_sq"], "waste_bits_osq": w["waste_osq"],
            "saving_ratio": w["saving_ratio"],
            "g_osq_expected": g_osq,
        })
        assert w["segments_osq"] == g_osq, "G_OSQ must equal ceil(b/S)"
        print(f"  {preset:8s} d={d:4d} b={b:5d}  SQ={w['segments_sq']}seg/vec "
              f" OSQ={w['segments_osq']}seg/vec  waste {w['waste_sq']}b→"
              f"{w['waste_osq']}b  saving={w['saving_ratio']:.2f}x")
    save_json("BENCH_compression", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
