"""Paper Alg. 2 / Fig. 7 — tree-based invocation vs sequential fan-out.

Makespan of the tree launch for every §5.3 configuration against the naïve
coordinator-invokes-everything strawman, plus cold-start sensitivity.
"""

from __future__ import annotations

from benchmarks.common import header, save_json
from repro.core.invocation import InvocationSim, tree_size

CONFIGS = [(10, 1), (4, 2), (4, 3), (5, 3), (6, 3), (4, 4)]


def run(quick: bool = True) -> dict:
    header("Alg. 2 — tree invocation makespan vs sequential")
    rows = []
    for f, lmax in CONFIGS:
        n = tree_size(f, lmax)
        for warm in ([1.0] if quick else [1.0, 0.9]):
            sim = InvocationSim(branching=f, max_level=lmax,
                                warm_fraction=warm)
            tree_s = sim.makespan()
            seq_s = sim.sequential_makespan()
            rows.append({"F": f, "l_max": lmax, "n_qa": n,
                         "warm_fraction": warm, "tree_s": tree_s,
                         "sequential_s": seq_s,
                         "speedup": seq_s / tree_s})
            print(f"  F={f} l_max={lmax} N_QA={n:4d} warm={warm:.1f} "
                  f"tree={tree_s:.3f}s seq={seq_s:.3f}s "
                  f"({seq_s / tree_s:.1f}x)")
    assert all(r["speedup"] > 2.0 for r in rows if r["n_qa"] >= 84)
    save_json("bench_invocation", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
