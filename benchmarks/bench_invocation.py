"""Paper Alg. 2 / Fig. 7 — tree-based invocation vs sequential fan-out.

Unlike the seed's closed-form simulator, this drives the real serverless
runtime: every §5.3 (F, l_max) configuration launches the full Coordinator →
QA → QP choreography over a small index, and the makespans come out of the
event-driven traces (tree mode vs the CO-invokes-everything strawman). Node
busy times are pinned so the comparison isolates invocation structure; the
first wave runs cold (empty container pools), the second warm.

Since PR 5 the bench also sweeps the *transport*: the same choreography
runs under the virtual-time LocalTransport (modeled makespan) and under the
real worker substrates — multi-process pipes and the TCP socket fleet —
measured wall-clock, tree vs sequential, with an injected per-QP busy-sleep
standing in for heavy Stage 3–5 work. That yields measured (not modeled)
data points of the perf trajectory: real concurrent QP waves beating the
serialized strawman on the same worker fleet, over pipes and over TCP.
Results persist as ``results/BENCH_invocation.json`` via
``benchmarks/run.py``.
"""

from __future__ import annotations

import time

from benchmarks.common import (build_tiny_squash_index, header, safe_ratio,
                               save_json)

CONFIGS = [(10, 1), (4, 2), (4, 3), (5, 3), (6, 3), (4, 4)]

_COMPUTE = dict(qa_compute_s=0.05, qp_compute_s=0.05, co_compute_s=0.01)

# Transport sweep: small fleet (4 partitions → 4 QP workers + 1 QA worker),
# per-QP busy-sleep so the measurement reflects the transport, not the
# microscopic toy index compute.
_SWEEP_SLEEP_S = 0.15


def _virtual_sweep(quick: bool, ds, preds, idx) -> list:
    from repro.core.invocation import tree_size
    from repro.serverless import RuntimeConfig, ServerlessRuntime

    configs = CONFIGS if not quick else [c for c in CONFIGS if c != (4, 4)]
    rows = []
    for f, lmax in configs:
        n = tree_size(f, lmax)
        tree = ServerlessRuntime(idx, RuntimeConfig(
            branching=f, max_level=lmax, **_COMPUTE))
        seq = ServerlessRuntime(idx, RuntimeConfig(
            branching=f, max_level=lmax, sequential=True, **_COMPUTE))
        tree_cold = tree.search(ds.queries, preds, k=10).trace.makespan_s
        tree_warm = tree.search(ds.queries, preds, k=10).trace.makespan_s
        seq_cold = seq.search(ds.queries, preds, k=10).trace.makespan_s
        seq_warm = seq.search(ds.queries, preds, k=10).trace.makespan_s
        rows.append({"F": f, "l_max": lmax, "n_qa": n,
                     "tree_cold_s": tree_cold, "tree_warm_s": tree_warm,
                     "sequential_cold_s": seq_cold,
                     "sequential_warm_s": seq_warm,
                     "speedup_warm": seq_warm / tree_warm})
        print(f"  F={f} l_max={lmax} N_QA={n:4d} "
              f"tree={tree_warm:.3f}s (cold {tree_cold:.3f}s) "
              f"seq={seq_warm:.3f}s ({seq_warm / tree_warm:.1f}x)")
    return rows


def _transport_sweep(ds, preds, idx) -> list:
    """Measured wall-clock: real-worker transports, tree vs sequential.

    Sweeps both real substrates — process pipes and the TCP socket fleet —
    so the persisted results carry a measured socket column next to the
    process one. Within each transport the concurrent tree launch must beat
    the sequential strawman on the same fleet.
    """
    from repro.serverless import RuntimeConfig, ServerlessRuntime

    rows = []
    for transport in ("process", "socket"):
        for mode, sequential in (("tree", False), ("sequential", True)):
            rt = ServerlessRuntime(idx, RuntimeConfig(
                branching=2, max_level=1, sequential=sequential,
                transport=transport, qa_workers=1,
                worker_sleep_s=_SWEEP_SLEEP_S))
            try:
                t0 = time.perf_counter()
                cold = rt.search(ds.queries, preds, k=10)
                cold_s = time.perf_counter() - t0
                warm = rt.search(ds.queries, preds, k=10)
            finally:
                rt.close()
            rows.append({
                "mode": mode,
                "transport": transport,
                "qp_invocations": warm.trace.invocations("qp"),
                "qp_busy_sleep_s": _SWEEP_SLEEP_S,
                "measured_cold_s": cold_s,
                "measured_warm_s": warm.trace.measured_makespan_s,
                "modeled_warm_s": warm.trace.makespan_s,
                # None when the measured makespan is 0 (guarded ratio).
                "modeled_over_measured": safe_ratio(
                    warm.trace.makespan_s,
                    warm.trace.measured_makespan_s),
                "worker_hosts": warm.trace.worker_hosts,
            })
            print(f"  {transport}/{mode:<10s} measured warm="
                  f"{warm.trace.measured_makespan_s:.3f}s "
                  f"(modeled {warm.trace.makespan_s:.3f}s, "
                  f"{warm.trace.invocations('qp')} QPs x "
                  f"{_SWEEP_SLEEP_S:.2f}s busy)")
        tree_s, seq_s = (rows[-2]["measured_warm_s"],
                         rows[-1]["measured_warm_s"])
        assert tree_s < seq_s, (
            f"{transport}: concurrent QP wave ({tree_s:.3f}s) must beat the "
            f"sequential strawman ({seq_s:.3f}s) in *measured* wall-clock")
        speedup = safe_ratio(seq_s, tree_s)
        if speedup is not None:
            print(f"  {transport}: measured tree speedup over sequential: "
                  f"{speedup:.1f}x")
    return rows


def run(quick: bool = True) -> dict:
    header("Alg. 2 — tree invocation makespan vs sequential (real runtime)")
    ds, preds, idx = build_tiny_squash_index(seed=3)
    rows = _virtual_sweep(quick, ds, preds, idx)
    assert all(r["speedup_warm"] > 2.0 for r in rows if r["n_qa"] >= 84), \
        "tree launch must beat sequential fan-out on large fleets"
    assert all(r["tree_cold_s"] >= r["tree_warm_s"] for r in rows), \
        "cold fleet cannot be faster than warm"
    header("Transport sweep — measured wall-clock, process + socket fleets")
    ds4, preds4, idx4 = build_tiny_squash_index(seed=3, num_partitions=4)
    transport_rows = _transport_sweep(ds4, preds4, idx4)
    payload = {"rows": rows, "transport": transport_rows}
    save_json("BENCH_invocation", payload)
    return payload


if __name__ == "__main__":
    run()
