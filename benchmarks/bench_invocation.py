"""Paper Alg. 2 / Fig. 7 — tree-based invocation vs sequential fan-out.

Unlike the seed's closed-form simulator, this drives the real serverless
runtime: every §5.3 (F, l_max) configuration launches the full Coordinator →
QA → QP choreography over a small index, and the makespans come out of the
event-driven traces (tree mode vs the CO-invokes-everything strawman). Node
busy times are pinned so the comparison isolates invocation structure; the
first wave runs cold (empty container pools), the second warm.
"""

from __future__ import annotations

from benchmarks.common import build_tiny_squash_index, header, save_json

CONFIGS = [(10, 1), (4, 2), (4, 3), (5, 3), (6, 3), (4, 4)]

_COMPUTE = dict(qa_compute_s=0.05, qp_compute_s=0.05, co_compute_s=0.01)


def run(quick: bool = True) -> dict:
    header("Alg. 2 — tree invocation makespan vs sequential (real runtime)")
    from repro.core.invocation import tree_size
    from repro.serverless import RuntimeConfig, ServerlessRuntime

    ds, preds, idx = build_tiny_squash_index(seed=3)
    configs = CONFIGS if not quick else [c for c in CONFIGS if c != (4, 4)]
    rows = []
    for f, lmax in configs:
        n = tree_size(f, lmax)
        tree = ServerlessRuntime(idx, RuntimeConfig(
            branching=f, max_level=lmax, **_COMPUTE))
        seq = ServerlessRuntime(idx, RuntimeConfig(
            branching=f, max_level=lmax, sequential=True, **_COMPUTE))
        tree_cold = tree.search(ds.queries, preds, k=10).trace.makespan_s
        tree_warm = tree.search(ds.queries, preds, k=10).trace.makespan_s
        seq_cold = seq.search(ds.queries, preds, k=10).trace.makespan_s
        seq_warm = seq.search(ds.queries, preds, k=10).trace.makespan_s
        rows.append({"F": f, "l_max": lmax, "n_qa": n,
                     "tree_cold_s": tree_cold, "tree_warm_s": tree_warm,
                     "sequential_cold_s": seq_cold,
                     "sequential_warm_s": seq_warm,
                     "speedup_warm": seq_warm / tree_warm})
        print(f"  F={f} l_max={lmax} N_QA={n:4d} "
              f"tree={tree_warm:.3f}s (cold {tree_cold:.3f}s) "
              f"seq={seq_warm:.3f}s ({seq_warm / tree_warm:.1f}x)")
    assert all(r["speedup_warm"] > 2.0 for r in rows if r["n_qa"] >= 84), \
        "tree launch must beat sequential fan-out on large fleets"
    assert all(r["tree_cold_s"] >= r["tree_warm_s"] for r in rows), \
        "cold fleet cannot be faster than warm"
    save_json("bench_invocation", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
