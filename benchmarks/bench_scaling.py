"""Paper Fig. 10 — cost-to-performance trade-off vs N_QA.

For each FaaS parallelism level (N_QA ∈ {10, 20, 84, 155, 258, 340}, the
paper's §5.3 tree configurations) we assemble batch latency from the
invocation simulator + measured stage times, then price the fleet with the
§3.5 cost model. Reproduces the paper's qualitative findings: 84–155 is the
sweet spot; 340 is invocation-dominated.
"""

from __future__ import annotations

from benchmarks.common import header, save_json
from benchmarks.bench_qps import FAAS_CONFIGS, measure_stage_times, serverless_qps
from repro.core.cost_model import LambdaFleet, squash_query_cost
from repro.core.invocation import InvocationSim


def run(quick: bool = True) -> dict:
    header("Fig. 10 — runtime & cost vs N_QA")
    presets = ["sift1m"] if quick else ["sift1m", "gist1m"]
    rows = []
    for preset in presets:
        meas = measure_stage_times(preset, quick)
        for n_qa, (f, lmax) in FAAS_CONFIGS.items():
            perf = serverless_qps(meas, n_qa)
            n_qp = int(n_qa * meas["visits_per_query"]
                       * 1000 / n_qa / max(1, 1000 // n_qa))
            n_qp = max(n_qp, n_qa)
            fleet = LambdaFleet(
                n_qa=n_qa, n_qp=n_qp,
                t_qa_s=n_qa * (perf["makespan_s"] * 0.4),
                t_qp_s=n_qp * (perf["makespan_s"] * 0.5),
                t_co_s=perf["makespan_s"],
                s3_gets=0,  # warm fleet (DRE); cold adds n_qa + n_qp GETs
                efs_read_bytes=int(1000 * 2 * 10
                                   * meas["n"] / 1000 * 4),  # R·k rows
            )
            cost = squash_query_cost(fleet)
            rows.append({"dataset": preset, "n_qa": n_qa,
                         "makespan_s": perf["makespan_s"],
                         "qps": perf["qps"],
                         "cost_per_1k_queries": cost["total"],
                         **{f"cost_{k}": v for k, v in cost.items()}})
            print(f"  {preset} N_QA={n_qa:4d} latency={perf['makespan_s']:.2f}s"
                  f" qps={perf['qps']:7.0f} cost/1k=${cost['total']:.5f}")
        # sweet spot check: 84 or 155 should dominate 340 on cost·latency
        by = {r["n_qa"]: r for r in rows if r["dataset"] == preset}
        score = lambda r: r["makespan_s"] * r["cost_per_1k_queries"]
        assert min(score(by[84]), score(by[155])) < score(by[340]), \
            "84–155 should beat 340 on cost×latency (paper §5.5)"
    save_json("BENCH_scaling", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
