"""§5.6 result cache — Zipf workload, plus the Table 3 cache-ratio study.

The single cache benchmark of the suite (the seed's separate
``bench_caching.py`` is folded in here, so the registry exercises exactly
one cache bench). Two sections:

* **Zipf workload** — drives a skewed (Zipf-distributed) query stream
  through the real serverless runtime twice — cache disabled vs enabled —
  and reports, per skew exponent, the observed Coordinator hit rate against
  the latency and §3.5 dollar reductions. The dollar axis follows the
  Fig. 8 cost shape: per-batch cost extrapolated to daily query volumes, so
  the cache's effect reads directly as a left-shift of the serverless cost
  curve. Results parity is asserted on every wave: the cache-on run must
  return ids bitwise-identical to the cache-off run.
* **Table 3 (vs Vexless)** — the paper finds the cache ratio
  (query-duplication factor) SQUASH needs to beat Vexless's published QPS
  per dataset; GIST1M needs ratio 1. We reproduce the experiment shape with
  our ``ResultCache``: effective QPS at increasing duplication ratios, first
  ratio where the paper-scaled throughput beats Vexless.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (build_tiny_squash_index, header, save_json,
                               timed)

WAVES_QUICK = 6
WAVES_FULL = 16
BATCH = 16                 # queries per wave (pool sampled with Zipf skew)
POOL = 48                  # distinct queries in the workload
ZIPF_EXPONENTS = (0.0, 0.8, 1.4)   # 0.0 = uniform; higher = more repeats

_COMPUTE = dict(qa_compute_s=0.02, qp_compute_s=0.05, co_compute_s=0.005)
_DAILY_VOLUMES = (10_000, 100_000, 1_000_000, 10_000_000)


def _zipf_stream(pool_size: int, batch: int, waves: int, s: float,
                 seed: int) -> np.ndarray:
    """(waves, batch) indices into the query pool, Zipf(s)-distributed."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, pool_size + 1, dtype=np.float64)
    p = ranks ** -s
    p /= p.sum()
    return rng.choice(pool_size, size=(waves, batch), p=p)


def _drive(rt, pool_queries, preds, stream):
    ids = []
    makespan = cost = payload = invocations = hits = lookups = 0.0
    for wave in stream:
        res = rt.search(pool_queries[wave], preds, k=10)
        ids.append(res.ids)
        tr = res.trace
        makespan += tr.makespan_s
        cost += tr.cost["total"]
        payload += tr.payload_bytes
        invocations += len(tr.nodes)
        hits += tr.cache_hits
        lookups += tr.cache_hits + tr.cache_misses
    return ids, {
        "makespan_s": makespan, "cost": cost, "payload_bytes": int(payload),
        "invocations": int(invocations),
        "hit_rate": hits / lookups if lookups else 0.0,
    }


# ------------------------------------------------- Table 3 (cache ratios)

VEXLESS_QPS = {"gist1m": 285, "sift10m": 3125, "deep10m": 2500}
SQUASH_PAPER_QPS = {"gist1m": 326, "sift10m": 3388, "deep10m": 2804}
PAPER_RATIO = {"gist1m": 1, "sift10m": 10, "deep10m": 8}


def _table3_cache_ratio(quick: bool) -> list:
    """Paper Table 3 — cache ratio needed to beat Vexless (per dataset)."""
    from repro.core.dre import ResultCache
    from repro.core.pipeline import SquashConfig, SquashIndex
    from repro.data.synthetic import default_predicates, make_vector_dataset

    header("Table 3 — caching: cache-ratio to beat Vexless")
    rows = []
    presets = ["gist1m"] if quick else list(VEXLESS_QPS)
    for preset in presets:
        scale = 0.01 if preset.endswith("1m") else 0.001
        ds = make_vector_dataset(preset, scale=scale, num_queries=16)
        preds = default_predicates(ds.attr_cardinality)
        p = 10 if preset.endswith("1m") else 20
        idx = SquashIndex.build(ds.vectors, ds.attributes,
                                SquashConfig(num_partitions=p))
        _, t_base = timed(idx.search, ds.queries, preds, 10, repeats=1)
        base_qps = ds.queries.shape[0] / t_base

        for ratio in [1, 2, 4, 8, 10, 16]:
            cache = ResultCache()
            t_total = 0.0
            for rep in range(ratio):
                for qi in range(ds.queries.shape[0]):
                    key = cache.key(ds.queries[qi], preds, 10)
                    if cache.get(key) is not None:
                        t_total += 1e-5          # cache hit ≈ free
                    else:
                        t_total += t_base / ds.queries.shape[0]
                        cache.put(key, True)
            eff_qps = ratio * ds.queries.shape[0] / t_total
            # scale to paper units: our CPU base ↔ paper's no-cache QPS
            paper_scaled = SQUASH_PAPER_QPS[preset] * (eff_qps / base_qps)
            rows.append({"dataset": preset, "ratio": ratio,
                         "effective_qps": eff_qps, "hit_rate": cache.hit_rate,
                         "paper_scaled_qps": paper_scaled,
                         "beats_vexless": bool(
                             paper_scaled > VEXLESS_QPS[preset])})
        first = next(r["ratio"] for r in rows
                     if r["dataset"] == preset and r["beats_vexless"])
        curve = ["%.2f" % r["hit_rate"] for r in rows
                 if r["dataset"] == preset]
        print(f"  {preset}: cache ratio {first} beats Vexless "
              f"(paper: {PAPER_RATIO[preset]}); hit rates {curve}")
    return rows


def run(quick: bool = True) -> dict:
    from repro.core.cost_model import daily_cost_curve, server_baseline_cost
    from repro.serverless import RuntimeConfig, ServerlessRuntime

    header("§5.6 result cache — Zipf workload: hit-rate vs latency / $")
    ds, preds, idx = build_tiny_squash_index(seed=5, num_queries=POOL)
    waves = WAVES_QUICK if quick else WAVES_FULL
    base = dict(branching=4, max_level=2, warm_prob=0.95, **_COMPUTE)

    rows = []
    for s in ZIPF_EXPONENTS:
        stream = _zipf_stream(POOL, BATCH, waves, s, seed=11)
        off = ServerlessRuntime(idx, RuntimeConfig(**base))
        on = ServerlessRuntime(idx, RuntimeConfig(cache_enabled=True, **base))
        ids_off, m_off = _drive(off, ds.queries, preds, stream)
        ids_on, m_on = _drive(on, ds.queries, preds, stream)
        for a, b in zip(ids_off, ids_on):
            assert np.array_equal(a, b), "cache broke result parity"

        n_queries = waves * BATCH
        daily_on = daily_cost_curve(m_on["cost"] / waves, BATCH,
                                    _DAILY_VOLUMES)
        daily_off = daily_cost_curve(m_off["cost"] / waves, BATCH,
                                     _DAILY_VOLUMES)
        row = {
            "zipf_s": s,
            "waves": waves,
            "queries": n_queries,
            "hit_rate": m_on["hit_rate"],
            "makespan_off_s": m_off["makespan_s"],
            "makespan_on_s": m_on["makespan_s"],
            "latency_reduction": m_off["makespan_s"] / m_on["makespan_s"],
            "dollars_per_1k_off": m_off["cost"] * 1000 / n_queries,
            "dollars_per_1k_on": m_on["cost"] * 1000 / n_queries,
            "cost_reduction": m_off["cost"] / m_on["cost"],
            "payload_off": m_off["payload_bytes"],
            "payload_on": m_on["payload_bytes"],
            "invocations_off": m_off["invocations"],
            "invocations_on": m_on["invocations"],
            "daily_cost_on": daily_on,
            "daily_cost_off": daily_off,
            "daily_volumes": list(_DAILY_VOLUMES),
            "server_baseline_daily": server_baseline_cost(hours=24.0),
        }
        rows.append(row)
        print(f"  zipf s={s:.1f}: hit-rate {row['hit_rate']:.2f} → "
              f"latency {row['latency_reduction']:.2f}x, "
              f"$ {row['cost_reduction']:.2f}x "
              f"(${row['dollars_per_1k_off']:.5f} → "
              f"${row['dollars_per_1k_on']:.5f} per 1k), "
              f"invocations {row['invocations_off']} → "
              f"{row['invocations_on']}")

    # Monotone sanity: more skew → more repeats → higher hit rate, and any
    # nonzero hit rate must strictly reduce invocations + payload + dollars.
    hit_rates = [r["hit_rate"] for r in rows]
    assert hit_rates == sorted(hit_rates), "hit rate must grow with skew"
    for r in rows:
        if r["hit_rate"] > 0:
            assert r["invocations_on"] < r["invocations_off"]
            assert r["payload_on"] < r["payload_off"]
            assert r["cost_reduction"] > 1.0
    table3 = _table3_cache_ratio(quick)
    save_json("BENCH_cache", {"rows": rows, "table3": table3})
    return {"rows": rows, "table3": table3}


if __name__ == "__main__":
    run()
