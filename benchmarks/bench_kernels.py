"""Pallas TPU kernel micro-benchmarks (interpret mode) vs jnp oracles.

Correctness (allclose) + wall time of the interpreted kernels against the
pure-jnp reference implementations. On real TPU hardware the pallas_call
paths run compiled; interpret=True executes the same kernel body on CPU.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import header, save_json, timed
from repro.core import osq, segments
from repro.kernels import ops, ref


def run(quick: bool = True) -> dict:
    header("Pallas kernels — interpret-mode correctness + timing")
    rng = np.random.default_rng(0)
    n, d, g = (512, 128, 16) if quick else (4096, 128, 16)
    rows = []

    qb = rng.integers(0, 2 ** 32, size=(g,), dtype=np.uint32)
    db = rng.integers(0, 2 ** 32, size=(n, g), dtype=np.uint32)
    out_k, t_k = timed(lambda: np.asarray(
        ops.hamming_distances(jnp.asarray(qb), jnp.asarray(db))), repeats=2)
    out_r, t_r = timed(lambda: np.asarray(
        ref.hamming_ref(jnp.asarray(qb), jnp.asarray(db))), repeats=2)
    assert np.array_equal(out_k, out_r)
    rows.append({"kernel": "hamming", "t_pallas_interp": t_k, "t_ref": t_r})

    m1 = 17
    table = rng.random((m1, d)).astype(np.float32)
    codes = rng.integers(0, m1, size=(n, d)).astype(np.int32)
    out_k, t_k = timed(lambda: np.asarray(
        ops.adc_distances(jnp.asarray(table), jnp.asarray(codes))), repeats=2)
    out_r, t_r = timed(lambda: np.asarray(
        ref.adc_lb_ref(jnp.asarray(table), jnp.asarray(codes))), repeats=2)
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-5)
    rows.append({"kernel": "adc_lookup", "t_pallas_interp": t_k, "t_ref": t_r})

    # Batched (multi-query × stacked-partition) kernels — the data-plane
    # shapes from core/dataplane.py. Pallas interpret vs jnp XLA twin.
    qn, pn = (16, 4) if quick else (64, 10)
    qs = rng.integers(0, 2 ** 32, size=(qn, pn, g), dtype=np.uint32)
    dbs = rng.integers(0, 2 ** 32, size=(pn, n, g), dtype=np.uint32)
    out_k, t_k = timed(lambda: np.asarray(ops.hamming_stacked(
        jnp.asarray(qs), jnp.asarray(dbs), use_pallas=True, interpret=True)),
        repeats=2)
    out_r, t_r = timed(lambda: np.asarray(ops.hamming_stacked(
        jnp.asarray(qs), jnp.asarray(dbs), use_pallas=False)), repeats=2)
    assert np.array_equal(out_k, out_r)
    rows.append({"kernel": "hamming_stacked", "t_pallas_interp": t_k,
                 "t_ref": t_r})

    b, keep = (8, 32) if quick else (64, 64)
    tables_b = rng.random((b, m1, d)).astype(np.float32)
    codes_b = rng.integers(0, m1, size=(b, keep, d)).astype(np.int32)
    out_k, t_k = timed(lambda: np.asarray(ops.adc_batch(
        jnp.asarray(tables_b), jnp.asarray(codes_b), use_pallas=True,
        interpret=True)), repeats=2)
    out_r, t_r = timed(lambda: np.asarray(ops.adc_batch(
        jnp.asarray(tables_b), jnp.asarray(codes_b), use_pallas=False)),
        repeats=2)
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-5)
    rows.append({"kernel": "adc_batch", "t_pallas_interp": t_k,
                 "t_ref": t_r})

    bits = osq.allocate_bits(rng.random(d) + 0.1, 4 * d)
    layout = segments.build_layout(bits, seg_bits=8)
    codes2 = np.stack([rng.integers(0, 2 ** b if b else 1, size=n)
                       for b in bits], axis=1).astype(np.int64)
    packed = segments.pack_codes(layout, codes2)
    out_k, t_k = timed(lambda: np.asarray(
        ops.extract_codes(jnp.asarray(packed), layout)), repeats=2)
    out_r, t_r = timed(lambda: np.asarray(
        ref.extract_ref(jnp.asarray(packed), layout)), repeats=2)
    assert np.array_equal(out_k, out_r)
    rows.append({"kernel": "bitpack_extract", "t_pallas_interp": t_k,
                 "t_ref": t_r})

    for r in rows:
        print(f"  {r['kernel']:16s} pallas(interp)={r['t_pallas_interp']*1e3:8.2f}ms"
              f"  jnp-ref={r['t_ref']*1e3:8.2f}ms  (correctness: OK)")
    save_json("BENCH_kernels", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
