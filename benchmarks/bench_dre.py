"""Paper Fig. 6 — DRE (data-retention-exploitation) cost/latency/S3 savings.

Simulates 20 successive batch invocations of an N_QA = 84 fleet (the paper's
figure configuration, SIFT1M-sized index files) with and without DRE, and
reports S3-request, latency and cost reductions.
"""

from __future__ import annotations

from benchmarks.common import header, save_json
from repro.core.cost_model import LambdaFleet, squash_query_cost
from repro.core.dre import ContainerPool

N_QA = 84
N_QP = 170
INDEX_BYTES_QA = 18_000_000      # attr codes + centroids + P-V map
INDEX_BYTES_QP = 35_000_000      # per-partition OSQ + low-bit + boundaries
WAVES = 20


def simulate(use_dre: bool) -> dict:
    qa_pool = ContainerPool(warm_prob=0.95, seed=1)
    qp_pools = [ContainerPool(warm_prob=0.95, seed=2 + i)
                for i in range(N_QP)]
    for _ in range(WAVES):
        for _ in range(N_QA):
            qa_pool.invoke("sift1m/qa", INDEX_BYTES_QA, use_dre=use_dre)
        for i, pool in enumerate(qp_pools):
            pool.invoke(f"sift1m/part{i}", INDEX_BYTES_QP, use_dre=use_dre)
    s3 = qa_pool.stats.s3_gets + sum(p.stats.s3_gets for p in qp_pools)
    fetch_s = (qa_pool.stats.fetch_seconds
               + max(p.stats.fetch_seconds for p in qp_pools))
    fleet = LambdaFleet(
        n_qa=N_QA * WAVES, n_qp=N_QP * WAVES,
        t_qa_s=N_QA * WAVES * 0.35 + qa_pool.stats.fetch_seconds,
        t_qp_s=N_QP * WAVES * 0.40
        + sum(p.stats.fetch_seconds for p in qp_pools),
        t_co_s=WAVES * 0.9,
        s3_gets=s3,
    )
    cost = squash_query_cost(fleet)["total"]
    return {"s3_gets": s3, "fetch_critical_path_s": fetch_s, "cost": cost}


def run(quick: bool = True) -> dict:
    header("Fig. 6 — DRE: cost / latency / S3 request reduction (N_QA=84)")
    with_dre = simulate(True)
    without = simulate(False)
    out = {
        "with_dre": with_dre, "without_dre": without,
        "s3_reduction": without["s3_gets"] / max(with_dre["s3_gets"], 1),
        "cost_reduction": without["cost"] / with_dre["cost"],
        "latency_reduction": (without["fetch_critical_path_s"]
                              / max(with_dre["fetch_critical_path_s"], 1e-9)),
    }
    print(f"  S3 GETs: {without['s3_gets']} → {with_dre['s3_gets']} "
          f"({out['s3_reduction']:.1f}x fewer)")
    print(f"  cost: ${without['cost']:.4f} → ${with_dre['cost']:.4f} "
          f"({out['cost_reduction']:.2f}x)")
    print(f"  fetch critical path: {without['fetch_critical_path_s']:.1f}s → "
          f"{with_dre['fetch_critical_path_s']:.2f}s")
    assert out["s3_reduction"] > 5.0, "DRE must eliminate most S3 GETs"
    assert out["cost_reduction"] > 1.0
    save_json("bench_dre", out)
    return out


if __name__ == "__main__":
    run()
