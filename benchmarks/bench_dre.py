"""Paper Fig. 6 — DRE (data-retention-exploitation) cost/latency/S3 savings.

Drives successive query-batch waves through the real serverless runtime
(N_QA = 84, the paper's figure configuration) with DRE enabled vs disabled,
and reports S3-request, makespan and dollar reductions straight from the
per-wave run traces. Container pools persist inside the runtime across
waves, so warm-start singleton reuse is what actually eliminates fetches.
"""

from __future__ import annotations

from benchmarks.common import build_tiny_squash_index, header, save_json

WAVES_QUICK = 8
WAVES_FULL = 20

_COMPUTE = dict(qa_compute_s=0.02, qp_compute_s=0.05, co_compute_s=0.005)

# S3 regime pinned so fetch time is a visible share of the wave makespan
# (the Fig. 6 latency axis): slower effective GET bandwidth + higher RTT
# than the warm-path defaults.
_FETCH = dict(fetch_bandwidth_bps=20e6, fetch_rtt_s=0.05)


def simulate(ds, preds, idx, use_dre: bool, waves: int) -> dict:
    from repro.serverless import RuntimeConfig, ServerlessRuntime

    rt = ServerlessRuntime(idx, RuntimeConfig(
        branching=4, max_level=3, use_dre=use_dre, warm_prob=0.95,
        **_COMPUTE, **_FETCH))
    s3 = cost = makespan = fetch = 0.0
    for _ in range(waves):
        tr = rt.search(ds.queries, preds, k=10).trace
        s3 += tr.dre.s3_gets
        cost += tr.cost["total"]
        makespan += tr.makespan_s
        fetch += tr.dre.fetch_seconds
    return {"s3_gets": int(s3), "cost": cost, "mean_makespan_s":
            makespan / waves, "fetch_seconds": fetch}


def run(quick: bool = True) -> dict:
    header("Fig. 6 — DRE: cost / latency / S3 request reduction (N_QA=84)")
    ds, preds, idx = build_tiny_squash_index(seed=4)
    waves = WAVES_QUICK if quick else WAVES_FULL
    with_dre = simulate(ds, preds, idx, True, waves)
    without = simulate(ds, preds, idx, False, waves)
    out = {
        "waves": waves,
        "with_dre": with_dre, "without_dre": without,
        "s3_reduction": without["s3_gets"] / max(with_dre["s3_gets"], 1),
        "cost_reduction": without["cost"] / with_dre["cost"],
        "latency_reduction": (without["mean_makespan_s"]
                              / with_dre["mean_makespan_s"]),
    }
    print(f"  S3 GETs over {waves} waves: {without['s3_gets']} → "
          f"{with_dre['s3_gets']} ({out['s3_reduction']:.1f}x fewer)")
    print(f"  cost: ${without['cost']:.4f} → ${with_dre['cost']:.4f} "
          f"({out['cost_reduction']:.2f}x)")
    print(f"  mean wave makespan: {without['mean_makespan_s']:.3f}s → "
          f"{with_dre['mean_makespan_s']:.3f}s "
          f"({out['latency_reduction']:.2f}x)")
    assert out["s3_reduction"] > 5.0, "DRE must eliminate most S3 GETs"
    assert out["cost_reduction"] > 1.0
    assert out["latency_reduction"] > 1.02, \
        "fetch elimination must show up in the wave makespan"
    save_json("BENCH_dre", out)
    return out


if __name__ == "__main__":
    run()
