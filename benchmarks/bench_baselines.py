"""Baseline comparison — SQUASH/OSQ vs HNSW (Vexless's index) vs IVF-SQ8.

The paper's §2.1/Table 1 arguments, measured:

  1. unfiltered recall@10 at comparable work — HNSW is a strong ANN index;
  2. HYBRID recall under the §5.1 selective predicate (~8 %): post-filtered
     HNSW collapses unless ef is widened ~1/selectivity, while SQUASH's
     single-pass filtered search holds recall with NO extra passes;
  3. index memory: HNSW needs full-precision vectors + graph resident;
     OSQ holds ~b/8 bytes/vector (+ 1-bit low-bit index).

IVF-SQ8 (Milvus/FAISS-style coarse quantizer + uniform 8-bit SQ) is the
"basic SQ as data compressor" strawman of §1 — same partition count as
SQUASH, uniform bits, no segments/low-bit stage.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import header, recall_at_k, save_json, timed
from repro.core.hnsw import HNSWConfig, HNSWIndex
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data.synthetic import (default_predicates, ground_truth,
                                  make_vector_dataset)


def ivf_sq8_search(vectors, attrs, queries, preds, k, nprobe=3, parts=10,
                   seed=0):
    """Minimal IVF-SQ8: k-means coarse + uniform 8-bit SQ + pre-filter."""
    from repro.core.partitions import balanced_kmeans
    cent, assign = balanced_kmeans(vectors.astype(np.float64), parts, seed=seed)
    lo = vectors.min(axis=0, keepdims=True)
    hi = vectors.max(axis=0, keepdims=True)
    scale = np.maximum((hi - lo) / 255.0, 1e-12)
    codes = np.clip(np.round((vectors - lo) / scale), 0, 255).astype(np.uint8)
    mask = np.ones(len(vectors), dtype=bool)
    for p in preds:
        mask &= p.eval(attrs[:, p.attr])
    out = np.full((len(queries), k), -1, np.int64)
    for qi, q in enumerate(queries):
        cd = ((cent - q[None, :]) ** 2).sum(axis=1)
        probe = np.argsort(cd)[:nprobe]
        cand = np.where(np.isin(assign, probe) & mask)[0]
        if cand.size == 0:
            continue
        deq = codes[cand].astype(np.float32) * scale + lo
        d = ((deq - q[None, :]) ** 2).sum(axis=1)
        best = cand[np.argsort(d)[:k]]
        out[qi, :len(best)] = best
    return out


def run(quick: bool = True) -> dict:
    header("Baselines — SQUASH/OSQ vs HNSW (post-filter) vs IVF-SQ8")
    ds = make_vector_dataset("sift1m", scale=0.004 if quick else 0.02,
                             num_queries=24 if quick else 64, seed=7)
    preds = default_predicates(ds.attr_cardinality)
    gt_f, _ = ground_truth(ds, preds, k=10)

    squash = SquashIndex.build(ds.vectors, ds.attributes,
                               SquashConfig(num_partitions=6))
    (sq_ids, _, _), t_squash = timed(squash.search, ds.queries, preds, 10,
                                     repeats=1)
    rec_squash = recall_at_k(sq_ids, gt_f)
    sq_bytes = squash.index_bytes()
    sq_mem = sq_bytes["primary_osq"] + sq_bytes["lowbit_osq"] \
        + sq_bytes["attr_codes"]

    hnsw = HNSWIndex(ds.vectors, HNSWConfig(m=12, ef_construction=80,
                                            ef_search=64),
                     attributes=ds.attributes)
    rows = [{
        "system": "SQUASH", "recall_filtered": rec_squash,
        "seconds": t_squash, "index_bytes": sq_mem,
        "passes": 1,
    }]
    for expansion in (1, 4, 12):
        (h_ids, _), t_h = timed(hnsw.search_filtered, ds.queries, preds, 10,
                                repeats=1, expansion=expansion)
        rec_h = recall_at_k(h_ids, gt_f)
        rows.append({"system": f"HNSW post-filter ef×{expansion}",
                     "recall_filtered": rec_h, "seconds": t_h,
                     "index_bytes": hnsw.graph_bytes(),
                     "passes": 1})
    (ivf_ids), t_i = timed(ivf_sq8_search, ds.vectors, ds.attributes,
                           ds.queries, preds, 10, repeats=1)
    rows.append({"system": "IVF-SQ8 pre-filter",
                 "recall_filtered": recall_at_k(ivf_ids, gt_f),
                 "seconds": t_i,
                 "index_bytes": int(ds.vectors.shape[0]
                                    * (ds.vectors.shape[1] + 4)),
                 "passes": 1})
    for r in rows:
        print(f"  {r['system']:26s} recall@10={r['recall_filtered']:.3f} "
              f"t={r['seconds']:.2f}s index={r['index_bytes']/1e6:.1f}MB")

    hnsw1 = next(r for r in rows if r["system"].endswith("ef×1"))
    assert rec_squash >= 0.9
    assert rec_squash > hnsw1["recall_filtered"] + 0.05, \
        "single-pass filtered SQUASH must beat narrow post-filtered HNSW"
    assert sq_mem < hnsw.graph_bytes() / 3, \
        "OSQ index must be ≥3x smaller than graph+full-precision HNSW"
    save_json("BENCH_baselines", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
