"""Substrate tests: optimizer, schedules, checkpoint, OSQ-KV quant, engine."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import restore_pytree, save_pytree
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule, global_norm,
                         linear_schedule)
from repro.serve import (Engine, ServeConfig, cache_bytes, dequantize_caches,
                         quantize_caches)
from repro.serve.kv_quant import dequantize_leaf, quantize_leaf


# -------------------------------------------------------------------- optim

def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(params, grads, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_weight_decay_decouples():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=0.0)
    params = {"w": jnp.asarray([10.0])}
    state = adamw_init(params, cfg)
    zero_grad = {"w": jnp.asarray([0.0])}
    params, state, _ = adamw_update(params, zero_grad, state, cfg)
    assert float(params["w"][0]) < 10.0, "decay shrinks params w/o gradient"


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    cos = cosine_schedule(1.0, warmup=10, total=100)
    lin = linear_schedule(1.0, warmup=10, total=100)
    for sched in (cos, lin):
        assert float(sched(jnp.asarray(0))) == pytest.approx(0.0)
        assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(sched(jnp.asarray(100))) < 0.2
    # cosine floor
    assert float(cos(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)


def test_adamw_bf16_state_dtype():
    cfg = AdamWConfig(state_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((8, 8))}
    state = adamw_init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((8, 8))}
    _, state2, _ = adamw_update(params, grads, state, cfg)
    assert state2["v"]["w"].dtype == jnp.bfloat16


# --------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_nested():
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": [jnp.ones((4,)), jnp.zeros((2, 2), jnp.int32)]}
    with tempfile.TemporaryDirectory() as d:
        save_pytree(tree, d)
        out = restore_pytree(tree, d)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert bool(jnp.array_equal(x, y))
        assert x.dtype == y.dtype


# ----------------------------------------------------------------- kv quant

@settings(deadline=None, max_examples=20)
@given(
    b=st.integers(1, 3),
    s=st.sampled_from([16, 33, 64]),
    ch=st.sampled_from([8, 24]),
    bits=st.sampled_from([4, 8, 16]),
    axis_from_end=st.sampled_from([2, 3]),
)
def test_quantize_leaf_roundtrip_error_bound(b, s, ch, bits, axis_from_end):
    rng = np.random.default_rng(abs(hash((b, s, ch, bits))) % 2 ** 31)
    if axis_from_end == 3:
        x = jnp.asarray(rng.normal(size=(b, s, 4, ch)), jnp.float32)
        axis = 1
    else:
        x = jnp.asarray(rng.normal(size=(b, s, ch)), jnp.float32)
        axis = 1
    q, meta = quantize_leaf(x, bits, axis)
    y = dequantize_leaf(q, meta)
    assert y.shape == x.shape
    # max quantization error = scale/2 per channel
    span = (x.max(axis=axis, keepdims=True) - x.min(axis=axis, keepdims=True))
    bound = np.asarray(span) / ((1 << bits) - 1) * 0.5 + 1e-6
    assert np.all(np.abs(np.asarray(y - x)) <= bound)


def test_quantize_caches_compresses_and_roundtrips():
    cfg = get_config("llama3-8b").reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.ones((2, 32), jnp.int32)
    _, caches = T.prefill(params, tokens, cfg, buf_len=48)
    qc, meta = quantize_caches(caches, 8)
    ratio = cache_bytes(caches) / cache_bytes(qc)
    assert ratio > 3.5, f"8-bit packing should be ~4x, got {ratio}"
    back = dequantize_caches(qc, meta)
    for a, b in zip(jax.tree_util.tree_leaves(caches),
                    jax.tree_util.tree_leaves(back)):
        assert a.shape == b.shape
    qc4, _ = quantize_caches(caches, 4)
    assert cache_bytes(qc) / cache_bytes(qc4) > 1.8, "4-bit ≈ 2x vs 8-bit"


def test_engine_kv_quant_generation_agrees():
    cfg = get_config("phi4-mini-3.8b").reduced(vocab_size=256, d_model=128,
                                               num_layers=2)
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    prompts = np.ones((2, 24), np.int32)
    out = Engine(cfg, params, ServeConfig(max_new_tokens=8)).generate(prompts)
    out8 = Engine(cfg, params,
                  ServeConfig(max_new_tokens=8, kv_bits=8)).generate(prompts)
    assert (out == out8).mean() >= 0.75


# ------------------------------------------------------------------- engine

def test_engine_greedy_matches_manual_decode():
    cfg = get_config("llama3-8b").reduced(vocab_size=128, d_model=64,
                                          num_layers=2)
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    prompts = np.arange(12, dtype=np.int32).reshape(2, 6) % 128
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=4))
    out = eng.generate(prompts)
    # manual greedy
    logits, caches = T.prefill(params, jnp.asarray(prompts), cfg, buf_len=10)
    tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
    manual = []
    for i in range(4):
        manual.append(np.asarray(tok))
        logits, caches = T.decode_step(params, tok[:, None], caches, 6 + i,
                                       cfg)
        tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
    np.testing.assert_array_equal(out, np.stack(manual, axis=-1))


def test_engine_audio_generation_shape():
    cfg = get_config("musicgen-large").reduced()
    params = T.init_params(jax.random.PRNGKey(3), cfg)
    prompts = np.ones((2, cfg.num_codebooks, 6), np.int32)
    out = Engine(cfg, params, ServeConfig(max_new_tokens=3)).generate(prompts)
    assert out.shape == (2, cfg.num_codebooks, 3)


def test_nonuniform_osq_kv_beats_uniform_at_equal_budget():
    """Variance-ranked 8/4-bit split (avg 6 bits) should beat uniform 6-ish
    bits in MSE on data with heterogeneous channel variances — the paper's
    non-uniform allocation claim, on KV data."""
    from repro.serve.kv_quant import (dequantize_leaf,
                                      dequantize_leaf_nonuniform,
                                      quantize_leaf,
                                      quantize_leaf_nonuniform)
    rng = np.random.default_rng(0)
    scales = np.geomspace(4.0, 0.05, 32)               # decaying channel energy
    x = jnp.asarray(rng.normal(size=(2, 64, 32)) * scales[None, None, :],
                    jnp.float32)
    qn, mn = quantize_leaf_nonuniform(x, 1, hi_bits=8, lo_bits=4,
                                      hi_frac=0.5)
    yn = dequantize_leaf_nonuniform(qn, mn)
    assert yn.shape == x.shape
    # uniform 4-bit (same storage as the lo tier, less than the 6-bit avg)
    q4, m4 = quantize_leaf(x, 4, 1)
    y4 = dequantize_leaf(q4, m4)
    mse_n = float(jnp.mean((yn - x) ** 2))
    mse_4 = float(jnp.mean((y4 - x) ** 2))
    assert mse_n < mse_4, (mse_n, mse_4)
    # high-variance channels carry most reconstruction fidelity
    err_ch = np.asarray(jnp.mean((yn - x) ** 2, axis=(0, 1)))
    assert err_ch[:8].mean() < 10 * err_ch[-8:].mean() + 1e-6
