"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(2 layers, d_model ≤ 512, ≤ 4 experts), run one forward and one train step on
CPU, assert output shapes and absence of NaNs. A separate consistency test
checks that prefill + decode reproduce the train-forward logits exactly
(float tolerance) — covering ring-buffer windowed decode, absorbed-MLA
decode, Mamba2 chunked-vs-recurrent equivalence, hybrid shared attention,
M-RoPE and multi-codebook audio heads.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES, get_config, list_configs
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.train import make_train_step

ALL_ARCHS = list_configs()


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.num_codebooks:
        tokens = rng.integers(0, cfg.vocab_size,
                              (b, cfg.num_codebooks, s + 1), dtype=np.int32)
    else:
        tokens = rng.integers(0, cfg.vocab_size, (b, s + 1), dtype=np.int32)
    batch = {"tokens": jnp.asarray(tokens)}
    if cfg.mrope:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.vlm_num_patches, cfg.d_model)),
            jnp.float32)
    return batch


def test_all_ten_assigned_archs_registered():
    assert ALL_ARCHS == sorted([
        "mamba2-370m", "deepseek-v2-lite-16b", "qwen2-vl-2b", "arctic-480b",
        "gemma3-4b", "llama3-8b", "musicgen-large", "granite-20b",
        "zamba2-7b", "phi4-mini-3.8b",
    ])


def test_full_configs_match_assignment():
    spec = {
        "mamba2-370m": (48, 1024, 0, 50280),
        "deepseek-v2-lite-16b": (27, 2048, 1408, 102400),
        "qwen2-vl-2b": (28, 1536, 8960, 151936),
        "arctic-480b": (35, 7168, 4864, 32000),
        "gemma3-4b": (34, 2560, 10240, 262144),
        "llama3-8b": (32, 4096, 14336, 128256),
        "musicgen-large": (48, 2048, 8192, 2048),
        "granite-20b": (52, 6144, 24576, 49152),
        "zamba2-7b": (81, 3584, 14336, 32000),
        "phi4-mini-3.8b": (32, 3072, 8192, 200064),
    }
    for name, (nl, dm, dff, vocab) in spec.items():
        cfg = get_config(name)
        assert (cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size) == \
            (nl, dm, dff, vocab), name
    assert get_config("deepseek-v2-lite-16b").kv_lora_rank == 512
    assert get_config("arctic-480b").num_experts == 128
    assert get_config("arctic-480b").top_k == 2
    assert get_config("mamba2-370m").ssm_state == 128
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("granite-20b").num_kv_heads == 1


def test_input_shapes_match_assignment():
    assert (INPUT_SHAPES["train_4k"].seq_len,
            INPUT_SHAPES["train_4k"].global_batch) == (4096, 256)
    assert (INPUT_SHAPES["prefill_32k"].seq_len,
            INPUT_SHAPES["prefill_32k"].global_batch) == (32768, 32)
    assert (INPUT_SHAPES["decode_32k"].seq_len,
            INPUT_SHAPES["decode_32k"].global_batch) == (32768, 128)
    assert (INPUT_SHAPES["long_500k"].seq_len,
            INPUT_SHAPES["long_500k"].global_batch) == (524288, 1)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward(name):
    cfg = get_config(name).reduced()
    assert cfg.num_layers <= 5 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    tokens = batch["tokens"]
    inputs = tokens[..., :-1]
    logits, aux = T.forward_train(params, inputs, cfg,
                                  embeds=batch.get("embeds"))
    b, s = 2, 16
    if cfg.num_codebooks:
        assert logits.shape == (b, s, cfg.num_codebooks, cfg.vocab_size)
    elif cfg.mrope:
        assert logits.shape == (b, s + cfg.vlm_num_patches, cfg.vocab_size)
    else:
        assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_train_step(name):
    cfg = get_config(name).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamWConfig(lr=1e-3)
    state = adamw_init(params, opt)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _batch(cfg)
    params2, state2, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        not bool(jnp.array_equal(a, b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(params2)))
    assert moved
    assert int(state2["step"]) == 1


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_matches_train_forward(name):
    cfg = get_config(name).reduced()
    if cfg.num_experts:
        # capacity large enough that no token drops → paths must agree exactly
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 24
    rng = np.random.default_rng(3)
    if cfg.num_codebooks:
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                          (b, cfg.num_codebooks, s),
                                          dtype=np.int32))
        pre = tokens[:, :, : s - 1]
        last = tokens[:, :, s - 1 : s]
    else:
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s),
                                          dtype=np.int32))
        pre = tokens[:, : s - 1]
        last = tokens[:, s - 1 : s]
    embeds = (jnp.asarray(rng.normal(size=(b, cfg.vlm_num_patches,
                                           cfg.d_model)), jnp.float32)
              if cfg.mrope else None)
    ref, _ = T.forward_train(params, tokens, cfg, embeds=embeds, remat=False)
    prefix = cfg.vlm_num_patches if cfg.mrope else 0
    lp, caches = T.prefill(params, pre, cfg, buf_len=prefix + s,
                           embeds=embeds)
    ld, _ = T.decode_step(params, last, caches, prefix + s - 1, cfg)
    np.testing.assert_allclose(np.asarray(lp[:, 0]), np.asarray(ref[:, -2]),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(ref[:, -1]),
                               atol=2e-4, rtol=2e-4)


def test_decode_cache_template_matches_prefill():
    """init_decode_caches must produce the exact pytree prefill returns."""
    for name in ["gemma3-4b", "zamba2-7b", "llama3-8b", "mamba2-370m"]:
        cfg = get_config(name).reduced()
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        b, s = 2, 16
        tokens = jnp.ones((b, s), jnp.int32)
        _, caches = T.prefill(params, tokens, cfg, buf_len=s)
        template = T.init_decode_caches(cfg, b, s)
        s1 = jax.tree_util.tree_structure(caches)
        s2 = jax.tree_util.tree_structure(template)
        assert s1 == s2, name
        for a, c in zip(jax.tree_util.tree_leaves(template),
                        jax.tree_util.tree_leaves(caches)):
            assert a.shape == c.shape, (name, a.shape, c.shape)
