"""Transport-tier observability tests: real workers, real boundaries.

What only real substrates can pin (auto-marked ``transport`` via
conftest; CI runs them under a hard timeout):

* Bitwise parity with observability ON over ProcessTransport and
  SocketTransport — ids and ``SearchStats`` identical to the obs-off run,
  i.e. the span context riding the ``extra`` envelope and the metric
  counters never perturb the search.
* Span stitching across the process / TCP boundary: worker-side
  fetch / deserialize / compute / serialize sub-spans, recorded inside
  the worker process against its own clock, come back in the response
  info and appear as ``worker.*`` children of the node span the client
  minted at submit time — with no dangling parents.
* Failure-path metrics: a SIGKILLed process worker increments
  ``transport.process.respawns`` / ``.retries``; a dropped TCP link
  increments ``transport.socket.reconnects`` / ``.retries`` — while the
  search still returns bit-identical results.

Every obs-enabled test disables + resets the global registry in a
``finally`` (enabling via ``RuntimeConfig(obs_enabled=True)`` is one-way).
"""

from __future__ import annotations

import os
import signal
import threading

import numpy as np
import pytest

from repro.obs.metrics import REGISTRY
from repro.serverless.runtime import RuntimeConfig, ServerlessRuntime

WORKER_SPANS = {"worker.fetch", "worker.deserialize", "worker.compute",
                "worker.serialize"}


@pytest.fixture(scope="module")
def built():
    from benchmarks.common import build_tiny_squash_index

    ds, preds, idx = build_tiny_squash_index(
        scale=0.003, num_queries=8, num_partitions=3, seed=7)
    ids, dists, stats = idx.search(ds.queries, preds, k=10,
                                   collect_stats=True, backend="jax")
    return ds, preds, idx, (ids, stats)


def _cfg(transport, **overrides):
    kw = dict(branching=2, max_level=1, transport=transport, qa_workers=1,
              invoke_timeout_s=120.0)
    kw.update(overrides)
    return RuntimeConfig(**kw)


def _obs_record(rt):
    records = rt.obs_exporter.records
    assert len(records) >= 1
    return records[-1]


def _assert_stitched(record):
    spans = record["spans"]
    ids = {s["id"] for s in spans}
    assert all(s["parent"] in ids for s in spans
               if s["parent"] is not None), "dangling span parents"
    kinds = {s["attrs"].get("kind") for s in spans} - {None}
    assert kinds == {"co", "qa", "qp"}
    wnames = {s["name"] for s in spans if s["name"].startswith("worker.")}
    assert WORKER_SPANS <= wnames
    # worker sub-spans hang off node spans, on the wall clock
    by_id = {s["id"]: s for s in spans}
    for s in spans:
        if s["name"] in WORKER_SPANS:
            parent = by_id[s["parent"]]
            assert parent["attrs"].get("kind") in {"qa", "qp"}
            assert s["attrs"].get("clock") == "wall"


@pytest.mark.parametrize("transport", ["process", "socket"])
def test_real_transport_obs_parity_and_stitching(built, transport):
    ds, preds, idx, (ref_ids, ref_stats) = built

    rt_off = ServerlessRuntime(idx, _cfg(transport))
    try:
        r_off = rt_off.search(ds.queries, preds, k=10)
    finally:
        rt_off.close()

    rt_on = ServerlessRuntime(idx, _cfg(transport, obs_enabled=True))
    try:
        r_on = rt_on.search(ds.queries, preds, k=10)
        np.testing.assert_array_equal(r_on.ids, r_off.ids)
        np.testing.assert_array_equal(r_on.ids, ref_ids)
        assert r_on.stats == r_off.stats == ref_stats

        _assert_stitched(_obs_record(rt_on))
        snap = REGISTRY.snapshot()
        assert snap["counters"].get(f"transport.{transport}.submits", 0) >= 1
        hist = snap["histograms"].get(f"transport.{transport}.invoke_s")
        assert hist is not None and hist["count"] >= 1
        assert hist["p50"] is not None and hist["p99"] >= hist["p50"]
        if transport == "socket":
            assert snap["histograms"]["transport.socket.frame_bytes"][
                "count"] >= 1
    finally:
        rt_on.close()
        REGISTRY.disable()
        REGISTRY.reset()


@pytest.mark.parametrize("transport", ["process", "socket"])
def test_fleet_aggregation_over_real_workers(built, transport):
    """PR 10: worker-side instruments reach the client's fleet view.

    Pipe workers echo registry *deltas* in response info; socket hosts
    answer STATS pulls with *cumulative* snapshots (absorbed with
    replace). Either way, the merged ``worker.requests`` must equal the
    number of QA/QP invocations actually served — echoed deltas that
    double-counted, or replace that summed, would break the equality —
    and worker instruments must never appear in the client-local registry.
    """
    ds, preds, idx, (ref_ids, _) = built
    rt = ServerlessRuntime(idx, _cfg(transport, obs_enabled=True))
    try:
        r1 = rt.search(ds.queries, preds, k=10)
        r2 = rt.search(ds.queries, preds, k=10)
        np.testing.assert_array_equal(r2.ids, ref_ids)
        fleet = REGISTRY.fleet_snapshot()
        sources = sorted(fleet["remote"])
        assert sources, "no remote sources absorbed"
        if transport == "process":
            assert all(s.startswith("pid:") for s in sources)
        else:
            # host:port/pid labels, matching the hosts the trace reports.
            assert all(":" in s.split("/pid:")[0] for s in sources)
            hosts = {s.split("/pid:")[0] for s in sources}
            assert hosts == set(r2.trace.worker_hosts)
        served = sum(1 for n in (*r1.trace.nodes, *r2.trace.nodes)
                     if n.kind != "co")
        merged = fleet["merged"]["counters"]
        assert merged.get("worker.requests") == served
        assert sum(snap["counters"].get("worker.requests", 0)
                   for snap in fleet["remote"].values()) == served
        assert "worker.requests" not in fleet["local"]["counters"]
        handle = fleet["merged"]["histograms"]["worker.handle_s"]
        assert handle["count"] == served and handle["p50"] is not None
        # The exported record carries the same merged view.
        rec = _obs_record(rt)
        assert rec["metrics"]["merged"]["counters"][
            "worker.requests"] == served
        assert rec["slo"]["runs"] == 2
    finally:
        rt.close()
        REGISTRY.disable()
        REGISTRY.reset()


def test_process_crash_increments_retry_metrics(built):
    ds, preds, idx, (ref_ids, _) = built
    rt = ServerlessRuntime(idx, _cfg("process", obs_enabled=True,
                                     worker_sleep_s=0.6))
    try:
        rt.search(ds.queries, preds, k=10)            # warm the fleet
        pid0 = rt.transport.worker_pids("qp:0")[0]
        killer = threading.Timer(
            0.25, lambda: os.kill(pid0, signal.SIGKILL))
        killer.start()
        r = rt.search(ds.queries, preds, k=10)
        killer.join()
        np.testing.assert_array_equal(r.ids, ref_ids)
        assert r.trace.worker_retries >= 1
        snap = REGISTRY.snapshot()["counters"]
        assert snap.get("transport.process.respawns", 0) >= 1
        assert snap.get("transport.process.retries", 0) >= 1
    finally:
        rt.close()
        REGISTRY.disable()
        REGISTRY.reset()


def test_socket_drop_increments_reconnect_metrics(built):
    ds, preds, idx, (ref_ids, _) = built
    rt = ServerlessRuntime(idx, _cfg("socket", obs_enabled=True,
                                     worker_sleep_s=0.6))
    try:
        rt.search(ds.queries, preds, k=10)            # warm the fleet
        dropper = threading.Timer(
            0.25, lambda: rt.transport.drop_connection("qp:0"))
        dropper.start()
        r = rt.search(ds.queries, preds, k=10)
        dropper.join()
        np.testing.assert_array_equal(r.ids, ref_ids)
        assert r.trace.worker_retries >= 1
        snap = REGISTRY.snapshot()["counters"]
        assert snap.get("transport.socket.reconnects", 0) >= 1
        assert snap.get("transport.socket.retries", 0) >= 1
    finally:
        rt.close()
        REGISTRY.disable()
        REGISTRY.reset()
