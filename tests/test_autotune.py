"""Keep-fraction math + calibration-profile properties (core/autotune.py).

Property-based (hypothesis, or the deterministic shim when absent) checks of
the one keep-count formula every data plane shares, plus the profile
artifact's validation/serialization contract and the pow2-bucket boundary
cases where the batched jax plane must agree with the NumPy reference.
"""

import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import autotune, dataplane
from repro.core.autotune import CalibrationProfile
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data import synthetic

# ------------------------------------------------------- keep-count formula


@settings(max_examples=60, deadline=None)
@given(n=st.integers(min_value=0, max_value=5000),
       frac=st.floats(min_value=0.001, max_value=100.0),
       floor=st.integers(min_value=1, max_value=256))
def test_floor_always_respected(n, frac, floor):
    keep = autotune.keep_count(n, frac, floor)
    if n == 0:
        assert keep == 0
    else:
        assert min(floor, n) <= keep <= n


@settings(max_examples=60, deadline=None)
@given(n=st.integers(min_value=0, max_value=5000),
       f1=st.floats(min_value=0.001, max_value=100.0),
       f2=st.floats(min_value=0.001, max_value=100.0),
       floor=st.integers(min_value=1, max_value=256))
def test_keep_monotone_in_fraction(n, f1, f2, floor):
    lo, hi = min(f1, f2), max(f1, f2)
    assert (autotune.keep_count(n, lo, floor)
            <= autotune.keep_count(n, hi, floor))


@settings(max_examples=60, deadline=None)
@given(n=st.integers(min_value=0, max_value=4000),
       n_max=st.integers(min_value=0, max_value=4000),
       frac=st.floats(min_value=0.001, max_value=100.0),
       floor=st.integers(min_value=1, max_value=256))
def test_keep_monotone_in_candidates(n, n_max, frac, floor):
    """The static_counts bound argument: keep at n_max bounds keep at n≤n_max."""
    lo, hi = min(n, n_max), max(n, n_max)
    assert (autotune.keep_count(lo, frac, floor)
            <= autotune.keep_count(hi, frac, floor))


@settings(max_examples=40, deadline=None)
@given(floor=st.integers(min_value=1, max_value=128),
       frac=st.floats(min_value=0.001, max_value=100.0))
def test_boundary_candidate_counts(floor, frac):
    """n = 1, n = floor, n = floor ± 1: the floor/fraction crossover edges."""
    assert autotune.keep_count(1, frac, floor) == 1
    assert autotune.keep_count(floor, frac, floor) == floor
    if floor > 1:
        assert autotune.keep_count(floor - 1, frac, floor) == floor - 1
    over = autotune.keep_count(floor + 1, frac, floor)
    assert floor <= over <= floor + 1


@settings(max_examples=30, deadline=None)
@given(p=st.integers(min_value=1, max_value=12),
       qn=st.integers(min_value=1, max_value=6),
       floor=st.integers(min_value=1, max_value=128),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_stage_counts_match_scalar_reference_under_profile(p, qn, floor, seed):
    """dataplane.stage_counts (vectorized) ≡ pipeline's per-pair keep_count
    for per-partition fractions — the cross-module agreement the backend
    parity contract rests on."""
    rng = np.random.default_rng(seed)
    frac = rng.uniform(0.5, 100.0, size=p)
    n_cand = rng.integers(0, 3000, size=(qn, p)).astype(np.int32)
    profile = CalibrationProfile(
        keep_frac=frac, min_keep=floor, recall_target=0.95, seed=0,
        sample_queries=1, rank_corr=np.ones(p), required=frac / 100.0)
    cfg = SquashConfig(min_hamming_keep=floor + 7, hamming_perc=3.0)
    keep, take = dataplane.stage_counts(n_cand, cfg, k=10, profile=profile)
    cap = int(np.ceil(cfg.refine_ratio * 10))
    for qi in range(qn):
        for pid in range(p):
            ref = autotune.keep_count(int(n_cand[qi, pid]), frac[pid], floor)
            assert keep[qi, pid] == ref
            assert take[qi, pid] == min(cap, ref)
    keep_s, take_s = dataplane.static_counts(int(n_cand.max()), cfg, k=10,
                                             profile=profile)
    assert (keep <= keep_s).all() and (take <= take_s).all()


def test_stage_counts_profile_none_matches_config():
    """profile=None must reproduce the original static-knob formulas."""
    cfg = SquashConfig(min_hamming_keep=8, hamming_perc=10.0)
    n_cand = np.array([[0, 1, 7, 8, 50, 500, 3000]], dtype=np.int32)
    keep, take = dataplane.stage_counts(n_cand, cfg, k=10)
    for i, n in enumerate(n_cand[0]):
        assert keep[0, i] == autotune.keep_count(
            int(n), cfg.hamming_perc, cfg.min_hamming_keep)


# --------------------------------------------------------- profile artifact


def test_profile_validation():
    ones = np.ones(3)
    with pytest.raises(ValueError, match="keep_frac"):
        CalibrationProfile(keep_frac=np.array([0.0, 50.0, 10.0]), min_keep=4,
                           recall_target=0.9, seed=0, sample_queries=8,
                           rank_corr=ones, required=ones)
    with pytest.raises(ValueError, match="keep_frac"):
        CalibrationProfile(keep_frac=np.array([101.0]), min_keep=4,
                           recall_target=0.9, seed=0, sample_queries=8,
                           rank_corr=ones[:1], required=ones[:1])
    with pytest.raises(ValueError, match="min_keep"):
        CalibrationProfile(keep_frac=np.array([10.0]), min_keep=0,
                           recall_target=0.9, seed=0, sample_queries=8,
                           rank_corr=ones[:1], required=ones[:1])


def test_profile_json_round_trip():
    prof = CalibrationProfile(
        keep_frac=np.array([12.5, 3.25, 100.0]), min_keep=40,
        recall_target=0.95, seed=17, sample_queries=64,
        rank_corr=np.array([0.9, 0.5, 0.7]),
        required=np.array([0.1, 0.02, 0.9]))
    back = CalibrationProfile.from_dict(json.loads(json.dumps(prof.to_dict())))
    np.testing.assert_array_equal(back.keep_frac, prof.keep_frac)
    np.testing.assert_array_equal(back.rank_corr, prof.rank_corr)
    np.testing.assert_array_equal(back.required, prof.required)
    assert back.min_keep == prof.min_keep
    assert back.recall_target == prof.recall_target
    assert back.seed == prof.seed and back.sample_queries == prof.sample_queries


def test_spearman_basics():
    x = np.arange(10.0)
    assert autotune.spearman(x, x) == pytest.approx(1.0)
    assert autotune.spearman(x, -x) == pytest.approx(-1.0)
    assert autotune.spearman(np.ones(5), x[:5]) == pytest.approx(1.0)


# ------------------------------------------- calibration + plane integration


@pytest.fixture(scope="module")
def tuned_index():
    ds = synthetic.make_vector_dataset("sift1m", scale=0.006, num_queries=16,
                                       seed=11)
    preds = synthetic.default_predicates(ds.attr_cardinality)
    cfg = SquashConfig(num_partitions=5, kmeans_iters=4, lloyd_iters=6)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=11)
    profile = index.autotune(recall_target=0.95, sample=32, seed=3)
    return ds, preds, index, profile


def test_calibration_deterministic(tuned_index):
    ds, _, index, profile = tuned_index
    again = autotune.calibrate(index, recall_target=0.95, sample=32, seed=3)
    np.testing.assert_array_equal(profile.keep_frac, again.keep_frac)
    np.testing.assert_array_equal(profile.rank_corr, again.rank_corr)
    assert profile.min_keep == again.min_keep


def test_set_profile_validates_partition_count(tuned_index):
    _, _, index, profile = tuned_index
    bad = CalibrationProfile.from_dict(profile.to_dict())
    bad.keep_frac = bad.keep_frac[:-1]
    with pytest.raises(ValueError, match="partitions"):
        index.set_profile(bad)
    index.set_profile(profile)  # restore


def test_pow2_bucket_boundaries_backend_parity(tuned_index):
    """Query counts on and just past the pow2 bucket edges (1, 2, 3, 4, 5,
    8, 9) must keep numpy/jax ids bitwise-identical under the profile."""
    ds, preds, index, _ = tuned_index
    for qn in (1, 2, 3, 4, 5, 8, 9):
        q = ds.queries[:qn]
        ids_n, _, s_n = index.search(q, preds, k=7, backend="numpy")
        ids_j, _, s_j = index.search(q, preds, k=7, backend="jax")
        np.testing.assert_array_equal(ids_n, ids_j)
        assert s_n == s_j


def test_profile_changes_plane_key_not_correctness(tuned_index):
    """Installing/clearing a profile flushes the jitted-plane cache (static
    keep shapes change) and flips stats between tuned and static budgets."""
    ds, preds, index, profile = tuned_index
    _, _, s_tuned = index.search(ds.queries, preds, k=10, backend="jax")
    index.set_profile(None)
    try:
        _, _, s_static = index.search(ds.queries, preds, k=10, backend="jax")
    finally:
        index.set_profile(profile)
    assert s_tuned.hamming_in == s_static.hamming_in
    assert s_tuned.hamming_kept != s_static.hamming_kept
