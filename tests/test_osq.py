"""Unit + property tests for OSQ quantization (paper §2.2)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import osq


def test_allocate_bits_sums_to_budget():
    var = np.array([10.0, 1.0, 0.1, 0.01])
    bits = osq.allocate_bits(var, budget=16)
    assert bits.sum() == 16
    # Highest-variance dimension gets the most bits.
    assert bits[0] == bits.max()
    assert np.all(bits >= 0)


def test_allocate_bits_nonuniform():
    var = np.geomspace(100.0, 0.001, 16)
    bits = osq.allocate_bits(var, budget=64)
    assert bits.sum() == 64
    assert bits[0] > bits[-1], "variance-greedy must be non-uniform"


@given(
    d=st.integers(2, 24),
    per_dim=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_allocate_bits_property(d, per_dim, seed):
    rng = np.random.default_rng(seed)
    var = np.abs(rng.normal(size=d)) + 1e-9
    budget = d * per_dim
    bits = osq.allocate_bits(var, budget)
    assert bits.sum() == budget
    assert bits.min() >= 0
    assert bits.max() <= 12


def test_lloyd_max_boundaries_sorted():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(4096, 3)) * np.array([1.0, 5.0, 0.2])
    b = osq.lloyd_max_1d(x, k=8)
    assert b.shape == (9, 3)
    assert np.all(np.diff(b[1:-1], axis=0) >= 0)
    assert np.isneginf(b[0]).all() and np.isposinf(b[-1]).all()


def test_encode_decode_roundtrip_error_shrinks_with_bits():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(8192, 8))
    errs = []
    for per_dim in (2, 4, 6):
        bits = np.full(8, per_dim, dtype=np.int32)
        q = osq.design_quantizers(x, bits)
        codes = osq.encode(q, x)
        assert codes.min() >= 0
        assert np.all(codes.max(axis=0) < q.cells)
        rec = osq.decode_cell_centers(q, codes)
        errs.append(np.mean((rec - x) ** 2))
    assert errs[0] > errs[1] > errs[2], f"MSE must shrink with bits: {errs}"


def test_encode_out_of_range_values_clamp_to_edge_cells():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(2048, 4))
    q = osq.design_quantizers(x, np.full(4, 3, dtype=np.int32))
    extreme = np.array([[1e9, -1e9, 0.0, 0.0]])
    codes = osq.encode(q, extreme)
    assert codes[0, 0] == q.cells[0] - 1
    assert codes[0, 1] == 0


def test_zero_bit_dimension():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(1024, 3))
    bits = np.array([4, 0, 2], dtype=np.int32)
    q = osq.design_quantizers(x, bits)
    codes = osq.encode(q, x)
    assert np.all(codes[:, 1] == 0)
    assert q.cells.tolist() == [16, 1, 4]


def test_nonuniform_beats_uniform_on_skewed_data():
    """The point of VA+-style allocation: skewed variance ⇒ lower MSE."""
    rng = np.random.default_rng(4)
    scales = np.geomspace(10.0, 0.01, 12)
    x = rng.normal(size=(8192, 12)) * scales
    budget = 12 * 4
    uni = osq.design_quantizers(x, np.full(12, 4, dtype=np.int32))
    non = osq.design_quantizers(x, osq.allocate_bits(x.var(axis=0), budget))
    mse_u = np.mean((osq.decode_cell_centers(uni, osq.encode(uni, x)) - x) ** 2)
    mse_n = np.mean((osq.decode_cell_centers(non, osq.encode(non, x)) - x) ** 2)
    assert mse_n < mse_u
