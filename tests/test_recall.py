"""Tier-1 recall regression gate (paper §5.3 + the autotune contract).

Brute-force ground truth on a seeded synthetic dataset gates recall@k for
both the static configuration (the paper's H_perc = 10 / R = 2 calibration)
and the recall-targeted autotune profile — across all three backends. The
autotuned profile must reach the target while evaluating strictly fewer ADC
candidates than the static config, and the NumPy / jax / serverless planes
must return bitwise-identical ids under the same calibration profile.
"""

import numpy as np
import pytest

from benchmarks.common import recall_at_k
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data import synthetic

K = 10
TARGET = 0.95


@pytest.fixture(scope="module")
def workload():
    ds = synthetic.make_vector_dataset("sift1m", scale=0.01, num_queries=32,
                                       seed=0)
    preds = synthetic.default_predicates(ds.attr_cardinality)
    gt_ids, _ = synthetic.ground_truth(ds, preds, k=K)
    cfg = SquashConfig(num_partitions=8, hamming_perc=10.0, refine_ratio=2.0,
                       kmeans_iters=6, lloyd_iters=10)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=0)
    return ds, preds, gt_ids, index


@pytest.fixture(scope="module")
def static_run(workload):
    ds, preds, _, index = workload
    assert index.profile is None
    ids, dists, stats = index.search(ds.queries, preds, k=K, backend="numpy")
    return ids, dists, stats


@pytest.fixture(scope="module")
def tuned_run(workload, static_run):
    """Calibrate after the static pass so both configs run on one build."""
    ds, preds, _, index = workload
    profile = index.autotune(recall_target=TARGET, k=K, sample=64, seed=0)
    ids, dists, stats = index.search(ds.queries, preds, k=K, backend="numpy")
    return profile, ids, dists, stats


def test_static_config_meets_target(workload, static_run):
    _, _, gt_ids, _ = workload
    ids, _, _ = static_run
    rec = recall_at_k(ids, gt_ids)
    assert rec >= TARGET, f"static recall@{K} = {rec}"


def test_autotuned_meets_target_with_fewer_adc_evals(workload, static_run,
                                                     tuned_run):
    _, _, gt_ids, _ = workload
    _, _, s_static = static_run
    profile, ids, _, s_tuned = tuned_run
    rec = recall_at_k(ids, gt_ids)
    assert rec >= TARGET, f"autotuned recall@{K} = {rec}"
    assert s_tuned.adc_evals < s_static.adc_evals, (
        f"autotune must prune more: {s_tuned.adc_evals} vs "
        f"{s_static.adc_evals} static ADC evals")
    assert profile.recall_target == TARGET
    assert profile.num_partitions == len(workload[3].parts)


def test_backends_bitwise_identical_under_profile(workload, tuned_run):
    """numpy / jax / serverless must agree bit-for-bit on ids (equal stats)
    when searching under the same calibration profile."""
    ds, preds, gt_ids, index = workload
    _, ids_numpy, _, s_numpy = tuned_run
    assert index.profile is not None

    ids_jax, _, s_jax = index.search(ds.queries, preds, k=K, backend="jax")
    np.testing.assert_array_equal(ids_numpy, ids_jax)
    assert s_numpy == s_jax

    from repro.serverless import RuntimeConfig, ServerlessRuntime

    rt = ServerlessRuntime(index, RuntimeConfig(branching=3, max_level=2))
    res = rt.search(ds.queries, preds, k=K)
    np.testing.assert_array_equal(ids_numpy, res.ids)
    assert res.stats == s_numpy
    # The QP NodeTraces carry the pruning accounting the autotune turns into
    # §3.5 dollars: their fold must equal the aggregate SearchStats.
    qp = [n for n in res.trace.nodes if n.kind == "qp"]
    assert sum(n.adc_evals for n in qp) == s_numpy.adc_evals
    assert sum(n.hamming_kept for n in qp) == s_numpy.hamming_kept
    assert recall_at_k(np.asarray(res.ids), gt_ids) >= TARGET


def test_profile_artifact_reusable_across_backends(workload, tuned_run):
    """A profile serialized + reloaded into a *fresh equal build* must
    reproduce the tuned ids exactly (the artifact is the calibration)."""
    from repro.core.autotune import CalibrationProfile

    ds, preds, _, _ = workload
    profile, ids_tuned, _, _ = tuned_run
    cfg = SquashConfig(num_partitions=8, hamming_perc=10.0, refine_ratio=2.0,
                       kmeans_iters=6, lloyd_iters=10)
    rebuilt = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=0)
    rebuilt.set_profile(CalibrationProfile.from_dict(profile.to_dict()))
    ids, _, _ = rebuilt.search(ds.queries, preds, k=K, backend="numpy")
    np.testing.assert_array_equal(ids, ids_tuned)


def test_service_recall_target_calibrates_and_recalibrates(workload):
    """ServiceConfig(recall_target=...) installs a profile at bind time and
    re-calibrates when the index is swapped."""
    from repro.serve.vector_service import ServiceConfig, VectorSearchService

    ds, preds, gt_ids, _ = workload
    cfg = SquashConfig(num_partitions=8, hamming_perc=10.0, refine_ratio=2.0,
                       kmeans_iters=6, lloyd_iters=10)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=0)
    svc = VectorSearchService(index, ServiceConfig(
        backend="numpy", recall_target=TARGET, calibration_sample=64,
        calibration_seed=0))
    assert svc.profile is not None
    ids, _, _ = svc.query(ds.queries, preds, k=K)
    assert recall_at_k(ids, gt_ids) >= TARGET

    other = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=1)
    svc.swap_index(other)
    assert svc.profile is not None and svc.profile is other.profile
    assert other.profile.num_partitions == len(other.parts)
