"""squashlint self-tests: fixture corpus per rule + whole-repo cleanliness.

Each checker gets at least one true-positive snippet and one clean snippet,
the pragma/baseline machinery is exercised end to end, and the final test
runs the real suite over ``src/repro`` asserting zero unbaselined findings —
the same gate CI enforces with ``python -m repro.analysis --strict``.
"""

import json
import textwrap

import pytest

from repro.analysis import locks, runner
from repro.analysis.findings import Finding, count_by_key
from repro.analysis.runner import Report, analyze_source, analyze_tree
from repro.analysis.source import parse_source


def rules_of(text, rel="serverless/somefile.py"):
    findings, _ = analyze_source(rel, textwrap.dedent(text))
    return sorted(f.rule for f in findings)


def findings_of(text, rel="serverless/somefile.py"):
    findings, _ = analyze_source(rel, textwrap.dedent(text))
    return findings


# ---------------------------------------------------------------- lock rule

LOCKED_CLASS = """
    import threading

    class Pool:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0  # guarded-by: _lock

        def bump(self):
            {body}
"""


def test_lock_guarded_access_true_positive():
    text = LOCKED_CLASS.format(body="self.count += 1")
    assert rules_of(text) == ["lock-guarded-access"]


def test_lock_guarded_access_clean_under_with():
    text = LOCKED_CLASS.format(
        body="with self._lock:\n                self.count += 1")
    assert rules_of(text) == []


def test_lock_guarded_access_constructor_exempt():
    # The __init__ assignment itself must not be flagged (pre-publication).
    text = LOCKED_CLASS.format(body="pass")
    assert rules_of(text) == []


def test_lock_holds_contract_honored():
    text = """
    import threading

    class Pool:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0  # guarded-by: _lock

        def _bump_locked(self):  # squash: holds[_lock]
            self.count += 1
    """
    assert rules_of(text) == []


def test_lock_holds_contract_on_wrapped_signature():
    # The pragma may sit on a continuation line of a multi-line def.
    text = """
    import threading

    class Pool:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0  # guarded-by: _lock

        def _bump_locked(self, a_very_long_parameter_name,
                         another_one):  # squash: holds[_lock]
            self.count += 1
    """
    assert rules_of(text) == []


def test_lock_nested_def_does_not_inherit_held_set():
    # A nested def is a thread target: the with-scope must not leak into it.
    text = """
    import threading

    class Pool:
        def __init__(self):
            self._lock = threading.Lock()
            self.count = 0  # guarded-by: _lock

        def go(self):
            with self._lock:
                def worker():
                    self.count += 1
                return worker
    """
    assert rules_of(text) == ["lock-guarded-access"]


def test_lock_order_cycle_detected():
    text = textwrap.dedent("""
    import threading

    class A:
        def __init__(self):
            self._lock = threading.Lock()
            self.send_lock = threading.Lock()

        def one(self):
            with self._lock:
                with self.send_lock:
                    pass

        def two(self):
            with self.send_lock:
                with self._lock:
                    pass
    """)
    _, edges = analyze_source("serverless/a.py", text)
    cycle_findings = locks.order_cycles(edges)
    assert {f.rule for f in cycle_findings} == {"lock-order"}
    assert len(cycle_findings) == 2          # both inversion sites anchored


def test_lock_order_clean_when_consistent():
    text = textwrap.dedent("""
    import threading

    class A:
        def __init__(self):
            self._lock = threading.Lock()
            self.send_lock = threading.Lock()

        def one(self):
            with self._lock:
                with self.send_lock:
                    pass

        def two(self):
            with self._lock:
                with self.send_lock:
                    pass
    """)
    _, edges = analyze_source("serverless/a.py", text)
    assert edges                             # the graph saw the nesting
    assert locks.order_cycles(edges) == []


# ------------------------------------------------------- determinism rules

def test_wallclock_flagged_in_parity_scope():
    text = """
    import time

    def stamp():
        return time.perf_counter()
    """
    assert rules_of(text, rel="core/util.py") == ["wallclock"]


def test_wallclock_ignored_outside_parity_scope():
    text = """
    import time

    def stamp():
        return time.perf_counter()
    """
    assert rules_of(text, rel="serverless/transport.py") == []


def test_unseeded_rng_true_positive_and_clean():
    dirty = """
    import numpy as np

    def noise(n):
        return np.random.rand(n)
    """
    clean = """
    import numpy as np

    def noise(n, seed):
        return np.random.default_rng(seed).random(n)
    """
    assert rules_of(dirty, rel="core/x.py") == ["unseeded-rng"]
    assert rules_of(clean, rel="core/x.py") == []


def test_set_iteration_true_positive_and_sorted_clean():
    dirty = """
    def order(items):
        return [x for x in set(items)]
    """
    clean = """
    def order(items):
        return [x for x in sorted(set(items))]
    """
    assert rules_of(dirty, rel="core/x.py") == ["set-iteration"]
    assert rules_of(clean, rel="core/x.py") == []


# -------------------------------------------------------------- wire rules

def test_wire_pickle_flagged_outside_codec():
    text = """
    import pickle

    def ship(obj):
        return pickle.dumps(obj)
    """
    assert rules_of(text, rel="serverless/rogue.py") == ["wire-pickle"]


def test_wire_rules_allowlisted_in_payload_module():
    text = """
    import pickle

    def ship(sock, obj):
        sock.sendall(pickle.dumps(obj))
    """
    assert rules_of(text, rel="serverless/payload.py") == []


def test_wire_raw_socket_flagged():
    text = """
    def pump(sock):
        return sock.recv(4096)
    """
    assert rules_of(text, rel="serverless/rogue.py") == ["wire-raw-socket"]


# --------------------------------------------------------------- jit rules

def test_jit_per_call_true_positive():
    text = """
    import jax

    def search(f, x):
        return jax.jit(f)(x)
    """
    assert rules_of(text, rel="core/distributed.py") == ["jit-per-call"]


def test_jit_cached_wrapper_clean():
    text = """
    import jax

    def make(f):
        g = jax.jit(f)
        def run(x):
            return g(x)
        return run
    """
    assert rules_of(text, rel="core/distributed.py") == []


def test_jit_concretize_item_flagged():
    text = """
    import jax

    @jax.jit
    def f(x):
        return x.sum().item()
    """
    assert rules_of(text, rel="kernels/k.py") == ["jit-concretize"]


def test_jit_shape_arithmetic_clean():
    text = """
    import jax

    @jax.jit
    def f(x):
        scale = float(x.shape[0])
        return x * scale
    """
    assert rules_of(text, rel="kernels/k.py") == []


def test_jit_mutable_global_flagged():
    text = """
    import jax
    import numpy as np

    TABLE = np.zeros(8)

    @jax.jit
    def f(x):
        return x + TABLE
    """
    assert rules_of(text, rel="kernels/k.py") == ["jit-mutable-global"]


def test_jit_static_argnames_flagged_and_clean():
    dirty = """
    import jax
    from functools import partial

    @partial(jax.jit)
    def f(x, k=10):
        return x[:k]
    """
    clean = """
    import jax
    from functools import partial

    @partial(jax.jit, static_argnames=("k",))
    def f(x, k=10):
        return x[:k]
    """
    assert rules_of(dirty, rel="core/dataplane.py") == ["jit-static-argnames"]
    assert rules_of(clean, rel="core/dataplane.py") == []


# ------------------------------------------------------ pragmas + baseline

def test_pragma_with_justification_suppresses():
    text = """
    import time

    def stamp():
        return time.perf_counter()  # squash: ignore[wallclock] -- trace timing only
    """
    assert rules_of(text, rel="core/x.py") == []


def test_pragma_without_justification_is_bad_pragma():
    text = """
    import time

    def stamp():
        return time.perf_counter()  # squash: ignore[wallclock]
    """
    assert rules_of(text, rel="core/x.py") == ["bad-pragma"]


def test_pragma_for_wrong_rule_does_not_suppress():
    text = """
    import time

    def stamp():
        return time.perf_counter()  # squash: ignore[wire-pickle] -- wrong rule
    """
    assert "wallclock" in rules_of(text, rel="core/x.py")


def test_parse_error_is_a_finding():
    findings = findings_of("def broken(:\n", rel="core/x.py")
    assert [f.rule for f in findings] == ["parse-error"]


def _finding(rule="wallclock", path="core/x.py", line=3):
    return Finding(path, line, rule, "msg")


def test_baseline_covers_known_findings():
    f = _finding()
    report = Report([f], {f.key: 1})
    assert report.clean and report.ratchet_ok
    assert report.baselined == [f]


def test_new_finding_fails_even_with_baseline():
    f, g = _finding(), _finding(line=9)
    report = Report([f, g], {f.key: 1})       # key covers only one of two
    assert not report.clean
    assert len(report.new) == 1


def test_stale_baseline_trips_ratchet():
    report = Report([], {"wallclock:core/x.py": 2})
    assert report.clean                       # nothing new...
    assert not report.ratchet_ok              # ...but the debt must shrink
    assert report.stale == {"wallclock:core/x.py": 2}


def test_update_baseline_roundtrip(tmp_path):
    f = _finding()
    path = str(tmp_path / "baseline.json")
    runner.save_baseline(count_by_key([f]), path)
    assert runner.load_baseline(path) == {f.key: 1}
    data = json.loads((tmp_path / "baseline.json").read_text())
    assert "entries" in data


def test_guarded_attrs_extracted_from_annotations():
    src = parse_source("x.py", textwrap.dedent("""
    class C:
        def __init__(self):
            self.a = 0  # guarded-by: _lock
            self.b = 0
    """))
    assert src.guarded_attrs() == {"a": {"_lock"}}


# ------------------------------------------------------------- whole repo

def test_repo_is_clean_under_strict():
    """The CI gate: zero unbaselined findings, no stale baseline debt."""
    report = analyze_tree(runner.default_root())
    assert report.new == [], "\n".join(f.render() for f in report.new)
    assert report.ratchet_ok, f"stale baseline entries: {report.stale}"


def test_cli_strict_exits_zero(capsys):
    assert runner.main(["--strict"]) == 0
    out = capsys.readouterr().out
    assert "squashlint: clean" in out
