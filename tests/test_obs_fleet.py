"""Tier-1 fleet-telemetry tests: merge algebra, SLO monitors, attribution.

Everything here runs without real worker processes (the transport-tier
half of PR 10 — pipe-worker delta echoes and socket STATS pulls over live
fleets — lives in ``tests/test_obs_transport.py``):

* Snapshot **merge exactness**: counters/gauges add losslessly, histogram
  merges add per-bucket counts (including the +inf overflow bucket) so
  quantiles over the merged registry equal quantiles over the union of
  observations; mismatched bucket layouts refuse to merge.
* ``snapshot_delta``: cumulative → incremental conversion (what pipe
  workers echo), including the no-change and first-echo cases.
* **Source-labelled absorption**: the same instrument name arriving from
  several pids/hosts keeps per-source registries intact while the merged
  view adds across them; re-absorbing a cumulative source with
  ``replace=True`` does not double-count; gauges are last-write-wins
  within a source and additive across sources.
* **SLO monitors**: exact windowed quantiles (empty window, single
  sample, eviction), ratio windows, the insufficient-data gate semantics,
  and ``SloTracker`` over both live ``RunTrace`` objects and JSONL
  records.
* **Cost attribution**: per-node dollars sum back to every §3.5
  component on a real run, the synthetic-CO row covers the empty batch,
  and the JSON round-trip preserves rows.
* The ``obs.top`` dashboard and the spans-less ``obs.timeline`` fallback
  render from the same records.
"""

from __future__ import annotations

import json
import math

import numpy as np
import pytest

from repro.obs.export import run_record
from repro.obs.metrics import (Histogram, MetricsRegistry,
                               bounds_from_buckets, snapshot_delta)
from repro.obs.slo import (RollingQuantile, RollingRatio, SloObjective,
                           SloPolicy, SloTracker, default_policy)
from repro.obs.spans import Recorder
from repro.serverless.runtime import RuntimeConfig, ServerlessRuntime
from repro.serverless.traces import (NodeTrace, RunTrace, assemble_run_trace,
                                     attribute_cost)


# ------------------------------------------------------------ merge algebra


def test_histogram_merge_is_lossless():
    # Two registries observing disjoint values; merging one's snapshot into
    # the other must equal a single histogram that saw every observation.
    a = Histogram("h", buckets=(1.0, 2.0, 4.0))
    b = Histogram("h", buckets=(1.0, 2.0, 4.0))
    ref = Histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0):
        a.observe(v)
        ref.observe(v)
    for v in (3.5, 100.0, 0.25):           # 100.0 → +inf overflow bucket
        b.observe(v)
        ref.observe(v)
    a.merge(b.snapshot())
    sa, sr = a.snapshot(), ref.snapshot()
    assert sa["count"] == sr["count"] == 6
    assert sa["buckets"] == sr["buckets"]
    assert sa["sum"] == pytest.approx(sr["sum"])
    for q in (0.25, 0.5, 0.9, 0.99):
        assert a.quantile(q) == ref.quantile(q)


def test_histogram_merge_rejects_mismatched_buckets():
    a = Histogram("h", buckets=(1.0, 2.0))
    b = Histogram("h", buckets=(1.0, 3.0))
    with pytest.raises(ValueError):
        a.merge(b.snapshot())


def test_bounds_round_trip_through_snapshot():
    bounds = (0.001, 0.25, 7.5, 1e6)
    h = Histogram("h", buckets=bounds)
    assert bounds_from_buckets(h.snapshot()["buckets"]) == bounds


def test_snapshot_delta_cumulative_to_incremental():
    reg = MetricsRegistry(enabled=True)
    reg.counter("c").inc(3)
    reg.histogram("h", buckets=(1.0, 2.0)).observe(0.5)
    first = reg.snapshot()
    # First echo: no previous snapshot → the delta IS the snapshot.
    assert snapshot_delta(first, None) == first
    reg.counter("c").inc(2)
    reg.histogram("h", buckets=(1.0, 2.0)).observe(1.5)
    delta = snapshot_delta(reg.snapshot(), first)
    assert delta["counters"] == {"c": 2}
    hd = delta["histograms"]["h"]
    assert hd["count"] == 1 and hd["buckets"]["2.0"] == 1
    assert hd["buckets"]["1.0"] == 0
    # Nothing changed since → empty delta sections.
    quiet = snapshot_delta(reg.snapshot(), reg.snapshot())
    assert quiet["counters"] == {} and quiet["histograms"] == {}


def test_absorb_labels_sources_and_merges_across_them():
    reg = MetricsRegistry(enabled=True)
    reg.counter("worker.requests").inc(1)      # client-local share
    # Two pids and one remote host all report the SAME instrument names.
    reg.absorb_snapshot({"counters": {"worker.requests": 5}}, source="pid:10")
    reg.absorb_snapshot({"counters": {"worker.requests": 7}}, source="pid:11")
    reg.absorb_snapshot({"counters": {"worker.requests": 2}},
                        source="10.0.0.2:9000/pid:44")
    fleet = reg.fleet_snapshot()
    assert sorted(fleet["remote"]) == ["10.0.0.2:9000/pid:44",
                                      "pid:10", "pid:11"]
    assert fleet["remote"]["pid:10"]["counters"]["worker.requests"] == 5
    assert fleet["local"]["counters"]["worker.requests"] == 1
    assert fleet["merged"]["counters"]["worker.requests"] == 15


def test_absorb_replace_does_not_double_count_cumulative_sources():
    reg = MetricsRegistry(enabled=True)
    # A socket host reports *cumulative* snapshots: pulling twice with
    # replace=True must keep the latest, not the sum.
    reg.absorb_snapshot({"counters": {"worker.requests": 5}},
                        source="h:1/pid:9", replace=True)
    reg.absorb_snapshot({"counters": {"worker.requests": 8}},
                        source="h:1/pid:9", replace=True)
    assert reg.fleet_snapshot()["merged"]["counters"]["worker.requests"] == 8
    # Without replace (pipe-worker deltas), absorption accumulates.
    reg.absorb_snapshot({"counters": {"worker.requests": 2}}, source="pid:3")
    reg.absorb_snapshot({"counters": {"worker.requests": 2}}, source="pid:3")
    assert reg.fleet_snapshot()["remote"]["pid:3"][
        "counters"]["worker.requests"] == 4


def test_gauge_last_write_within_source_additive_across():
    reg = MetricsRegistry(enabled=True)
    reg.absorb_snapshot({"gauges": {"pool.live": 3}}, source="pid:1")
    reg.absorb_snapshot({"gauges": {"pool.live": 4}}, source="pid:1")
    reg.absorb_snapshot({"gauges": {"pool.live": 2}}, source="pid:2")
    fleet = reg.fleet_snapshot()
    assert fleet["remote"]["pid:1"]["gauges"]["pool.live"] == 4
    assert fleet["merged"]["gauges"]["pool.live"] == 6


def test_histogram_merge_through_fleet_snapshot_keeps_quantiles():
    reg = MetricsRegistry(enabled=True)
    reg.histogram("lat", buckets=(1.0, 2.0, 4.0)).observe(0.5)
    worker = MetricsRegistry(enabled=True)
    for v in (1.5, 3.0, 3.5, 50.0):
        worker.histogram("lat", buckets=(1.0, 2.0, 4.0)).observe(v)
    reg.absorb_snapshot(worker.snapshot(), source="pid:5")
    ref = Histogram("lat", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 3.5, 50.0):
        ref.observe(v)
    merged = reg.fleet_snapshot()["merged"]["histograms"]["lat"]
    assert merged["count"] == 5
    assert merged["p50"] == ref.snapshot()["p50"]
    assert merged["p99"] == ref.snapshot()["p99"]


def test_disabled_registry_fleet_calls_are_noops():
    reg = MetricsRegistry(enabled=False)
    reg.absorb_snapshot({"counters": {"c": 1}}, source="pid:1")
    reg.merge_snapshot({"counters": {"c": 1}})
    assert reg.remote_sources() == ()


# -------------------------------------------------------------- SLO monitors


def test_rolling_quantile_empty_single_and_eviction():
    rq = RollingQuantile(window=3)
    assert rq.quantile(0.5) is None and rq.mean is None
    rq.observe(10.0)
    assert rq.quantile(0.0) == rq.quantile(0.5) == rq.quantile(1.0) == 10.0
    rq.observe(20.0)
    rq.observe(30.0)
    assert rq.quantile(0.5) == 20.0
    rq.observe(40.0)                      # evicts the 10.0 sample
    assert rq.count == 3
    assert rq.quantile(0.0) == 20.0 and rq.quantile(1.0) == 40.0
    with pytest.raises(ValueError):
        rq.quantile(1.5)


def test_rolling_ratio_window_eviction():
    rr = RollingRatio(window=2)
    assert rr.ratio is None
    rr.observe(1, 1)                      # a failure...
    rr.observe(0, 1)
    rr.observe(0, 1)                      # ...evicted here
    assert rr.ratio == 0.0


def test_slo_gate_insufficient_data_is_not_a_violation():
    tracker = SloTracker(window=4)
    report = default_policy().evaluate(tracker)
    assert report.ok and not report.conclusive
    assert all(e["ok"] is None for e in report.entries)


def test_slo_gate_violation_and_summary():
    tracker = SloTracker(window=4)
    tracker._observe(latency_s=50.0, retries=0, invocations=3,
                     cache_hits=0, cache_misses=0)
    policy = default_policy(p50_s=1.0)
    report = policy.evaluate(tracker)
    assert not report.ok and report.failures
    assert "VIOLATED" in report.summary()
    # Floors gate with >=: a cache-hit-rate floor fails from below.
    floor = SloPolicy([SloObjective("cache", "cache_hit_rate", 0.9, ">=")])
    tracker.cache.observe(1, 10)
    assert not floor.evaluate(tracker).ok


def test_slo_objective_validation():
    with pytest.raises(ValueError):
        SloObjective("x", "nope", 1.0)
    with pytest.raises(ValueError):
        SloObjective("x", "latency_p50", 1.0, op="!=")
    tracker = SloTracker()
    with pytest.raises(ValueError):
        tracker.value("nope")


def test_slo_tracker_error_budget_and_records():
    tracker = SloTracker(window=8)
    for _ in range(3):
        tracker.observe_record(
            {"meta": {"measured_makespan_s": 0.5},
             "run_trace": {"nodes": [{}] * 4, "worker_retries": 1,
                           "cache_hits": 3, "cache_misses": 1}})
    tracker.observe_error()
    assert tracker.value("error_rate") == pytest.approx(0.25)
    assert tracker.value("retry_rate") == pytest.approx(3 / 12)
    assert tracker.value("cache_hit_rate") == pytest.approx(0.75)
    assert tracker.value("latency_p99") == pytest.approx(0.5)
    # from_records builds the same monitors from a persisted stream.
    recs = [{"meta": {"makespan_s": float(i)}, "run_trace": {}}
            for i in (1, 2, 3)]
    assert SloTracker.from_records(recs).value(
        "latency_p50") == pytest.approx(2.0)


# ---------------------------------------------------------- cost attribution


def _tiny_runtime(**overrides):
    from benchmarks.common import build_tiny_squash_index

    ds, preds, idx = build_tiny_squash_index(
        scale=0.003, num_queries=8, num_partitions=3, seed=7)
    return ds, preds, ServerlessRuntime(
        idx, RuntimeConfig(branching=2, max_level=1, **overrides))


COMPONENTS = (("invocation", "lambda_invocation"),
              ("runtime", "lambda_runtime"), ("s3", "s3"), ("efs", "efs"),
              ("total", "total"))


def _assert_sums(trace):
    rows = trace.dollars_attributed
    assert rows
    for comp, key in COMPONENTS:
        attributed = math.fsum(r[comp] for r in rows)
        assert attributed == pytest.approx(trace.cost[key], rel=1e-9,
                                           abs=1e-18), comp


def test_attribution_sums_to_cost_on_real_run():
    ds, preds, rt = _tiny_runtime()
    trace = rt.search(ds.queries, preds, k=10).trace
    _assert_sums(trace)
    rows = trace.dollars_attributed
    # One row per node, all components non-negative, EFS lands on QPs
    # (they do the stage-5 refinement) and every QA/QP row pays exactly
    # one invocation before residual correction.
    assert len(rows) == len(trace.nodes)
    assert all(r[c] >= 0 for r in rows for c, _ in COMPONENTS)
    assert math.fsum(r["efs"] for r in rows if r["kind"] == "qp") == (
        pytest.approx(trace.cost["efs"], rel=1e-9, abs=1e-18))
    # refined counts made it onto the QP nodes and drive the EFS weights.
    assert sum(n.refined for n in trace.nodes) == trace.stats.refined > 0


def test_attribution_empty_batch_synthesizes_co_row():
    ds, preds, rt = _tiny_runtime()
    trace = rt.search(np.zeros((0, ds.queries.shape[1])), k=5).trace
    rows = trace.dollars_attributed
    assert [r["kind"] for r in rows] == ["co"] and rows[0]["chunk"] == -1
    assert math.fsum(r["total"] for r in rows) == pytest.approx(
        trace.cost["total"], rel=1e-9, abs=1e-18)


def test_attribution_fallback_weights():
    # Hand-built nodes with no refinement accounting: EFS falls back to
    # adc_evals; S3 splits over the DRE misses by fetch time.
    nodes = [
        NodeTrace(node="co", kind="co", parent="client", chunk=0,
                  t_issue=0.0, t_start=0.1, t_end=0.4, invoke_s=0.1,
                  fetch_s=0.0, compute_s=0.1, request_bytes=10,
                  response_bytes=10, warm=True, dre_hit=True, queries=4),
        NodeTrace(node="qp:0", kind="qp", parent="co", chunk=0,
                  t_issue=0.1, t_start=0.2, t_end=0.3, invoke_s=0.1,
                  fetch_s=0.2, compute_s=0.1, request_bytes=10,
                  response_bytes=10, warm=False, dre_hit=False, queries=4,
                  adc_evals=30),
        NodeTrace(node="qp:1", kind="qp", parent="co", chunk=0,
                  t_issue=0.1, t_start=0.2, t_end=0.35, invoke_s=0.1,
                  fetch_s=0.6, compute_s=0.1, request_bytes=10,
                  response_bytes=10, warm=False, dre_hit=False, queries=4,
                  adc_evals=10),
    ]
    from repro.core.cost_model import PricingConstants
    from repro.core.dre import DreStats
    from repro.core.pipeline import SearchStats

    trace = assemble_run_trace(
        nodes, makespan_s=0.4, escalations=0,
        dre=DreStats(invocations=3, s3_gets=2), efs_reads=40,
        efs_read_bytes=40 * 512, stats=SearchStats(queries=4),
        mem_qa_mb=1770, mem_qp_mb=1770, mem_co_mb=1770,
        prices=PricingConstants())
    _assert_sums(trace)
    rows = {r["node"]: r for r in trace.dollars_attributed}
    assert rows["co"]["s3"] == 0.0 and rows["co"]["efs"] == 0.0
    # fetch-time weighting: qp:1 fetched 3× longer → 3× the S3 share.
    assert rows["qp:1"]["s3"] == pytest.approx(3 * rows["qp:0"]["s3"])
    # adc fallback: qp:0 did 3× the ADC work → 3× the EFS share.
    assert rows["qp:0"]["efs"] == pytest.approx(3 * rows["qp:1"]["efs"])


def test_attribution_round_trips_json():
    ds, preds, rt = _tiny_runtime()
    trace = rt.search(ds.queries, preds, k=10).trace
    back = RunTrace.from_json(json.loads(json.dumps(trace.to_json())))
    assert back.dollars_attributed == trace.dollars_attributed
    # Old traces without the field still load.
    legacy = trace.to_json()
    del legacy["dollars_attributed"]
    assert RunTrace.from_json(legacy).dollars_attributed is None


def test_attribute_cost_distributes_full_total():
    # Direct fold on a degenerate single-node fleet: the lone QP carries
    # everything except the coordinator's synthetic invocation share.
    from repro.core.cost_model import (LambdaFleet, PricingConstants,
                                      squash_query_cost)

    node = NodeTrace(node="qp:0", kind="qp", parent="co", chunk=0,
                     t_issue=0.0, t_start=0.0, t_end=1.0, invoke_s=0.0,
                     fetch_s=0.0, compute_s=1.0, request_bytes=1,
                     response_bytes=1, warm=True, dre_hit=True, queries=1,
                     refined=5)
    prices = PricingConstants()
    fleet = LambdaFleet(n_qa=0, n_qp=1, mem_qa_mb=1, mem_qp_mb=1024,
                        mem_co_mb=1, t_qa_s=0.0, t_qp_s=1.0, t_co_s=0.0,
                        s3_gets=0, efs_reads=5, efs_read_bytes=5 * 512)
    cost = squash_query_cost(fleet, prices)
    rows = attribute_cost([node], fleet=fleet, cost=cost, prices=prices)
    assert {r["node"] for r in rows} == {"qp:0", "co"}
    assert math.fsum(r["total"] for r in rows) == pytest.approx(
        cost["total"], rel=1e-12, abs=1e-18)


# ------------------------------------------------------- dashboard + timeline


def _record_with_everything(rt, ds, preds):
    from repro.obs.metrics import REGISTRY

    res = rt.search(ds.queries, preds, k=10)
    rec = rt.obs_exporter.records[-1]
    return res, rec


def test_top_dashboard_renders_records():
    from repro.obs.metrics import REGISTRY
    from repro.obs.top import render_dashboard, render_metrics

    ds, preds, rt = _tiny_runtime(obs_enabled=True)
    try:
        _, rec = _record_with_everything(rt, ds, preds)
        text = render_dashboard([rec])
        assert "fleet metrics:" in text and "slo:" in text
        assert "cost attribution" in text and "/query" in text
        assert "gate [default]: PASS" in text
        # The metrics pane accepts both fleet and plain snapshots.
        assert "worker" not in render_metrics({})  # empty → no crash
        assert render_metrics(rec["metrics"])
        assert render_dashboard([]) == "(no run records yet)"
    finally:
        REGISTRY.disable()
        REGISTRY.reset()


def test_timeline_spansless_fallback_and_metrics_flag():
    from repro.obs.metrics import REGISTRY
    from repro.obs.timeline import render_record, render_records

    ds, preds, rt = _tiny_runtime(obs_enabled=True)
    try:
        _, rec = _record_with_everything(rt, ds, preds)
        bare = dict(rec)
        bare["spans"] = []                # zero stitched spans
        text = render_record(bare)
        assert "qp:" in text and "(modeled)" in text
        with_metrics = render_records([rec], metrics=True)
        assert "fleet metrics:" in with_metrics
        assert "fleet metrics:" not in render_records([rec])
    finally:
        REGISTRY.disable()
        REGISTRY.reset()


def test_run_record_carries_metrics_and_slo_sections():
    rec = Recorder()
    rec.record("search", 0.0, 1.0)
    record = run_record(rec, meta={"transport": "local"},
                        metrics={"merged": {}, "remote": {}, "local": {}},
                        slo={"runs": 1})
    assert record["metrics"]["remote"] == {} and record["slo"]["runs"] == 1
    assert "metrics" not in run_record(rec)   # optional sections stay absent
