"""Pallas SSD intra-chunk kernel vs jnp oracle + vs models/ssm.ssd_chunked."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.ssm import ssd_chunked


@pytest.mark.parametrize("g,h,lc,n,p", [
    (2, 2, 16, 8, 8),
    (1, 4, 32, 16, 8),
    (3, 1, 64, 128, 64),     # production-like dims (mamba2-370m)
    (2, 3, 8, 4, 4),
])
def test_ssd_kernel_matches_oracle(g, h, lc, n, p):
    rng = np.random.default_rng(g * 1000 + h)
    c = jnp.asarray(rng.normal(size=(g, lc, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(g, lc, n)), jnp.float32)
    da = jnp.asarray(-np.abs(rng.normal(size=(g, h, lc))), jnp.float32)
    x = jnp.asarray(rng.normal(size=(g, h, lc, p)), jnp.float32)
    got = np.asarray(ops.ssd_intra(c, b, da, x))
    want = np.asarray(ref.ssd_intra_ref(c, b, da, x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_ssd_kernel_matches_full_chunked_scan_first_chunk():
    """With zero initial state, chunk 0 of ssd_chunked equals the pure
    intra-chunk kernel output (no inter-chunk contribution yet)."""
    rng = np.random.default_rng(0)
    bsz, s, hh, pp, nn, lc = 2, 32, 2, 4, 8, 16
    x = jnp.asarray(rng.normal(size=(bsz, s, hh, pp)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(bsz, s, hh))) + 0.1, jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(hh,))) - 0.1, jnp.float32)
    b = jnp.asarray(rng.normal(size=(bsz, s, nn)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(bsz, s, nn)), jnp.float32)
    y_full, _ = ssd_chunked(x, dt, a, b, c, lc)

    # kernel on chunk 0 blocks
    g = bsz
    cc = c[:, :lc]
    bb = b[:, :lc]
    da = (dt[:, :lc] * a[None, None, :]).transpose(0, 2, 1)   # (B, H, lc)
    xdt = (x[:, :lc] * dt[:, :lc, :, None]).transpose(0, 2, 1, 3)
    got = np.asarray(ops.ssd_intra(cc, bb, da, xdt))          # (B, H, lc, P)
    want = np.asarray(y_full[:, :lc]).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_model_path_with_pallas_intra_matches_einsum_path():
    """ssd_chunked with USE_PALLAS_INTRA produces the same outputs as the
    jnp einsum path (full multi-chunk sequence, including inter-chunk)."""
    import repro.models.ssm as SSM
    rng = np.random.default_rng(5)
    bsz, s, hh, pp, nn, lc = 2, 48, 3, 8, 16, 16
    x = jnp.asarray(rng.normal(size=(bsz, s, hh, pp)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(bsz, s, hh))) + 0.1, jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(hh,))) - 0.1, jnp.float32)
    b = jnp.asarray(rng.normal(size=(bsz, s, nn)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(bsz, s, nn)), jnp.float32)
    y_ref, st_ref = ssd_chunked(x, dt, a, b, c, lc)
    old = SSM.USE_PALLAS_INTRA
    try:
        SSM.USE_PALLAS_INTRA = True
        y_k, st_k = SSM.ssd_chunked(x, dt, a, b, c, lc)
    finally:
        SSM.USE_PALLAS_INTRA = old
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)
