"""End-to-end SQUASH pipeline tests — the paper's recall claims (§5)."""

import numpy as np
import pytest

from repro.core.attributes import Predicate
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data import synthetic


@pytest.fixture(scope="module")
def sift_small():
    ds = synthetic.make_vector_dataset("sift1m", scale=0.01, num_queries=40, seed=0)
    preds = synthetic.default_predicates()
    cfg = SquashConfig(num_partitions=8, kmeans_iters=6, lloyd_iters=10)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=0)
    return ds, preds, index


def test_recall_at_10_meets_paper_target(sift_small):
    """Paper §5.3: SQUASH calibrated to 97 % recall@k (and can exceed 99 %).
    With H_perc=10, R=2 defaults we require ≥0.95 on the synthetic stand-in."""
    ds, preds, index = sift_small
    gt_ids, _ = synthetic.ground_truth(ds, preds, k=10)
    ids, dists, stats = index.search(ds.queries, preds, k=10)
    recalls = []
    for qi in range(ds.queries.shape[0]):
        g = set(gt_ids[qi][gt_ids[qi] >= 0].tolist())
        r = set(ids[qi][ids[qi] >= 0].tolist())
        if g:
            recalls.append(len(g & r) / len(g))
    recall = float(np.mean(recalls))
    assert recall >= 0.95, f"recall@10 = {recall}"


def test_all_results_satisfy_predicate(sift_small):
    """Hybrid guarantee: every returned vector passes the filter."""
    ds, preds, index = sift_small
    ids, _, _ = index.search(ds.queries[:10], preds, k=10)
    for row in ids:
        for vid in row[row >= 0]:
            for p in preds:
                assert p.eval(np.array([ds.attributes[vid, p.attr]]))[0]


def test_results_sorted_and_unique(sift_small):
    ds, preds, index = sift_small
    ids, dists, _ = index.search(ds.queries[:10], preds, k=10)
    for qi in range(10):
        valid = ids[qi] >= 0
        d = dists[qi][valid]
        assert np.all(np.diff(d) >= -1e-9)
        assert np.unique(ids[qi][valid]).size == valid.sum()


def test_pruning_pipeline_reduces_work(sift_small):
    """Multi-stage pruning: ADC evaluations ≪ N, refinement ≈ R·k."""
    ds, preds, index = sift_small
    qn = 10
    _, _, stats = index.search(ds.queries[:qn], preds, k=10, collect_stats=True)
    # Attribute filter alone prunes to ~8 %.
    assert stats.filter_pass < 0.16 * ds.n * qn
    # Hamming keeps H_perc (plus floor).
    assert stats.hamming_kept <= max(
        0.2 * stats.hamming_in, index.config.min_hamming_keep * stats.partitions_visited
    )
    # Refinement is tiny: ≤ R·k per (query, partition).
    assert stats.refined <= stats.partitions_visited * 2 * 10


def test_exact_match_query(sift_small):
    """A query equal to a database vector passing the filter returns it."""
    ds, preds, index = sift_small
    mask = np.ones(ds.n, dtype=bool)
    for p in preds:
        mask &= p.eval(ds.attributes[:, p.attr])
    target = int(np.where(mask)[0][0])
    ids, dists, _ = index.search(ds.vectors[target][None, :], preds, k=5)
    assert target in ids[0].tolist()
    assert dists[0][ids[0].tolist().index(target)] < 1e-5


def test_unfiltered_search():
    ds = synthetic.make_vector_dataset("deep10m", scale=0.001, num_queries=10, seed=1)
    cfg = SquashConfig(num_partitions=4, kmeans_iters=4, lloyd_iters=8)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=1)
    gt_ids, _ = synthetic.ground_truth(ds, [], k=10)
    ids, _, _ = index.search(ds.queries, [], k=10)
    recalls = [
        len(set(gt_ids[q].tolist()) & set(ids[q].tolist())) / 10
        for q in range(10)
    ]
    assert np.mean(recalls) >= 0.9


def test_index_compression(sift_small):
    """OSQ primary index ≈ b/32 of full precision (b = 4·d vs 32-bit floats)."""
    ds, _, index = sift_small
    sizes = index.index_bytes()
    full = sizes["full_precision"]
    # float64 in-memory copy: compare against float32 (the paper's baseline).
    full32 = full // 2
    assert sizes["primary_osq"] <= full32 / 7.0
    assert sizes["lowbit_osq"] <= full32 / 30.0


def test_no_refine_mode():
    ds = synthetic.make_vector_dataset("sift1m", scale=0.005, num_queries=10, seed=2)
    cfg = SquashConfig(num_partitions=4, enable_refine=False, kmeans_iters=4,
                       lloyd_iters=8)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=2)
    ids, dists, _ = index.search(ds.queries, [], k=10)
    assert (ids >= 0).all()
