"""Mutation regression tier: the LiveIndex subsystem (ISSUE 9).

Pins the live-index parity contract — insert/delete/compact keep ids *and*
``SearchStats`` bitwise-identical across the numpy, jax and serverless
backends — plus the stale-retention fixes the subsystem exposed in the
DRE/cache layer:

* tombstoned ids are never returned, even by hand-built Stage 3 requests;
* search during the tombstone phase ≡ search after compaction;
* per-partition generations stale warm-container fetch/derived keys;
* ``invalidate_cache()`` denies both fetch-level and derived DRE hits;
* ``ResultCache`` invalidation is segment-granular (only touched
  partitions' entries evict) and a zero-capacity cache rejects up front;
* ``ContainerPool.release`` is idempotent under many idle containers and
  ``derived_hit`` routes accounting through the lease delta exactly once.

Auto-marked ``mutation`` (conftest); the process/socket parity tests are
additionally marked ``transport`` so tier-1 (``-m "not transport"``) skips
the worker-spawning ones.
"""

import numpy as np
import pytest

from repro.core import dataplane
from repro.core.dre import ContainerPool, ResultCache
from repro.core.live import LiveIndex, SegmentBlock
from repro.core.pipeline import SearchStats, SquashConfig, SquashIndex
from repro.data import synthetic
from repro.serverless import RuntimeConfig, ServerlessRuntime
from repro.serverless import workers as wk


def _build(num_partitions=5, scale=0.002, seed=9, **cfg_kw):
    ds = synthetic.make_vector_dataset("sift1m", scale=scale, num_queries=6,
                                       seed=seed)
    cfg = SquashConfig(num_partitions=num_partitions, kmeans_iters=4,
                       lloyd_iters=6, **cfg_kw)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=seed)
    return ds, index


def _stats_eq(a: SearchStats, b: SearchStats) -> bool:
    return a.__dict__ == b.__dict__


def _all_backends(index, queries, preds, k=10):
    """(ids, dists, stats) from numpy and jax, asserted bitwise-identical."""
    rn = index.search(queries, preds, k=k, backend="numpy")
    rj = index.search(queries, preds, k=k, backend="jax")
    np.testing.assert_array_equal(rn[0], rj[0])
    np.testing.assert_allclose(rn[1], rj[1], rtol=0, atol=1e-9)
    assert _stats_eq(rn[2], rj[2])
    return rn


# ------------------------------------------------------------- wrap basics

def test_wrap_sets_mask_and_double_wrap_raises(rng):
    _, index = _build()
    live = LiveIndex(index)
    assert index.live_mask is not None and index.live_mask.all()
    assert index.live_owner is live
    assert live.version == 0
    assert live.dirty_partitions() == ()
    with pytest.raises(ValueError):
        LiveIndex(index)


def test_frozen_wrap_is_search_invisible(rng):
    """Wrapping alone (no mutation) changes nothing about search."""
    ds, index = _build()
    before = index.search(ds.queries, [], k=10, backend="jax")
    LiveIndex(index)
    after = _all_backends(index, ds.queries, [], k=10)
    np.testing.assert_array_equal(before[0], after[0])
    assert _stats_eq(before[2], after[2])


# ---------------------------------------------------------------- inserts

def test_insert_appends_tail_segment_and_is_searchable(rng):
    ds, index = _build()
    live = LiveIndex(index)
    segs0 = {pid: live.segments_of(pid) for pid in range(live.num_partitions)}
    new_vecs = ds.vectors[:4] + 1e-4 * rng.normal(size=(4, index.dim))
    new_ids = live.insert(new_vecs, ds.attributes[:4])
    assert new_ids.tolist() == list(range(ds.vectors.shape[0],
                                          ds.vectors.shape[0] + 4))
    # the touched partitions grew a tail block under generation + 1
    touched = set(index.partitioning.assign[new_ids].tolist())
    for pid in range(live.num_partitions):
        segs = live.segments_of(pid)
        if pid in touched:
            assert len(segs) == len(segs0[pid]) + 1
            tail = segs[-1]
            assert isinstance(tail, SegmentBlock)
            assert tail.hi - tail.lo == int(
                (index.partitioning.assign[new_ids] == pid).sum())
            assert tail.generation == live.generations[pid]
        else:
            assert segs == segs0[pid]
    # near-duplicates of existing rows must surface as top hits
    ids, _, _ = _all_backends(index, new_vecs, [], k=10)
    for row, gid in zip(ids, new_ids):
        assert gid in row


def test_insert_attr_encoding_matches_build_codes(rng):
    """Re-inserting rows with build-time attribute values reproduces their
    original attribute codes exactly (Stage 1 parity for new rows)."""
    ds, index = _build()
    live = LiveIndex(index)
    src = rng.choice(ds.vectors.shape[0], size=8, replace=False)
    new_ids = live.insert(ds.vectors[src], ds.attributes[src])
    np.testing.assert_array_equal(index.attr_index.codes[new_ids],
                                  index.attr_index.codes[src])


def test_insert_parity_with_predicates(rng):
    ds, index = _build()
    preds = synthetic.default_predicates(ds.attr_cardinality)
    live = LiveIndex(index)
    live.insert(ds.vectors[:6] + 1e-3, ds.attributes[:6])
    _all_backends(index, ds.queries, preds, k=10)


# ---------------------------------------------------------------- deletes

def test_deleted_ids_never_returned_any_backend(rng):
    ds, index = _build()
    live = LiveIndex(index)
    first = index.search(ds.queries, [], k=10, backend="jax")
    victims = np.unique(first[0][:, :3].ravel())
    assert live.delete(victims) == victims.size
    assert live.delete(victims) == 0          # idempotent
    ids, _, _ = _all_backends(index, ds.queries, [], k=10)
    assert np.intersect1d(ids.ravel(), victims).size == 0
    assert live.live_count() == ds.vectors.shape[0] - victims.size


def test_delete_changes_filter_pass_only_through_mask(rng):
    """Stage 1 counts live rows: filter_pass drops by exactly the number of
    predicate-passing tombstones, identically on both backends."""
    ds, index = _build()
    live = LiveIndex(index)
    r0 = index.search(ds.queries, [], k=10, backend="numpy")
    victims = r0[0][:, 0]
    live.delete(victims)
    r1 = _all_backends(index, ds.queries, [], k=10)
    lost = np.unique(victims).size * ds.queries.shape[0]
    assert r0[2].filter_pass - r1[2].filter_pass == lost


def test_stage3_numpy_defense_masks_hand_built_rows(rng):
    """A raw ``_search_partition`` call naming tombstoned local rows still
    never returns them (defense in depth beyond Stage 1)."""
    ds, index = _build()
    live = LiveIndex(index)
    pid = 0
    part = index.parts[pid]
    dead = part.vector_ids[: max(3, part.size // 4)]
    live.delete(dead)
    stats = SearchStats()
    ids, _ = index._search_partition(
        part, pid, ds.queries[0], np.arange(part.size), k=10, stats=stats)
    assert np.intersect1d(ids, dead).size == 0

    # all-dead request degenerates to an empty stream, not an error
    live.delete(part.vector_ids)
    ids2, d2 = index._search_partition(
        part, pid, ds.queries[0], np.arange(part.size), k=10,
        stats=SearchStats())
    assert ids2.size == 0 and d2.size == 0


def test_stage3_jax_valid_fold_masks_tombstones(rng):
    """The stacked device payload folds the tombstone bitmap into ``valid``,
    so even a full candidate mask cannot surface a dead row."""
    ds, index = _build()
    live = LiveIndex(index)
    first = index.search(ds.queries, [], k=10, backend="jax")
    live.delete(first[0][:, 0])
    stacked = dataplane.stack_index(index)
    for pid, part in enumerate(index.parts):
        valid = np.asarray(stacked.valid[pid][: part.size])
        np.testing.assert_array_equal(valid,
                                      index.live_mask[part.vector_ids])


def test_qp_bundle_folds_tombstones(rng):
    """Serverless QP slabs ship tombstones pre-folded: a hand-built request
    naming dead rows cannot return them from a worker either."""
    import jax.numpy as jnp

    ds, index = _build()
    live = LiveIndex(index)
    first = index.search(ds.queries, [], k=10, backend="jax")
    live.delete(first[0][:, 0])
    for pid, part in enumerate(index.parts):
        bundle = wk.build_qp_bundle(index, pid, jnp.float64)
        valid = np.asarray(bundle["part_arrays"]["valid"][: part.size])
        np.testing.assert_array_equal(valid,
                                      index.live_mask[part.vector_ids])


# ------------------------------------------------------------- compaction

def test_compact_clean_partition_is_noop(rng):
    _, index = _build()
    live = LiveIndex(index)
    gens0 = list(live.generations)
    assert live.compact(0) is False
    assert live.generations == gens0
    assert live.version == 0


def test_drop_only_compact_is_bitwise_invisible(rng):
    """The tentpole gate (in-process half): search during the tombstone
    phase ≡ search after compaction — ids, dists and every stage counter."""
    ds, index = _build()
    live = LiveIndex(index)
    first = index.search(ds.queries, [], k=10, backend="jax")
    live.delete(np.unique(first[0][:, :2].ravel()))
    during = _all_backends(index, ds.queries, [], k=10)
    for pid in live.dirty_partitions():
        assert live.compact(pid, requantize=False) is True
    assert live.dirty_partitions() == ()
    after = _all_backends(index, ds.queries, [], k=10)
    np.testing.assert_array_equal(during[0], after[0])
    np.testing.assert_array_equal(during[1], after[1])
    assert _stats_eq(during[2], after[2])
    # dead rows are physically gone and sentinel-assigned
    n_resident = sum(pt.size for pt in index.parts)
    assert n_resident == live.live_count()
    assert (index.partitioning.assign == live.sentinel).sum() == \
        ds.vectors.shape[0] - live.live_count()


def test_requantize_compact_exact_results_match(rng):
    """Requantization changes codes but not geometry: under exhaustive
    refinement (take = keep = all candidates) the exact top-k is identical
    before and after the OSQ re-run."""
    ds, index = _build(num_partitions=5, hamming_perc=100.0,
                       refine_ratio=8.0)
    live = LiveIndex(index)
    first = index.search(ds.queries, [], k=10, backend="jax")
    live.delete(np.unique(first[0][:, :2].ravel()))
    during = _all_backends(index, ds.queries, [], k=10)
    for pid in live.dirty_partitions():
        assert live.compact(pid, requantize=True) is True
    after = _all_backends(index, ds.queries, [], k=10)
    np.testing.assert_array_equal(during[0], after[0])
    np.testing.assert_allclose(during[1], after[1], rtol=0, atol=1e-9)
    # segment ledger collapsed to one block under the bumped generation
    for pid in range(live.num_partitions):
        segs = live.segments_of(pid)
        assert len(segs) == 1
        assert segs[0].generation == live.generations[pid]


def test_generations_bump_on_every_mutation(rng):
    ds, index = _build()
    live = LiveIndex(index)
    v0 = live.version
    new = live.insert(ds.vectors[:2] + 1e-3, ds.attributes[:2])
    touched = set(index.partitioning.assign[new].tolist())
    assert live.version == v0 + 1
    for pid in range(live.num_partitions):
        assert live.generations[pid] == (1 if pid in touched else 0)
    gens = list(live.generations)
    live.delete(new[:1])
    pid = int(index.partitioning.assign[new[0]])
    assert live.generations[pid] == gens[pid] + 1
    assert live.compact(pid, requantize=False) is True
    assert live.generations[pid] == gens[pid] + 2
    _, events = live.events_since(0)
    assert [e.kind for e in events] == ["insert", "delete", "compact"]
    cursor, tail = live.events_since(events[1].seq)
    assert [e.kind for e in tail] == ["compact"] and cursor == live.version


def test_residency_bitmap_tolerates_sentinel(rng):
    ds, index = _build()
    live = LiveIndex(index)
    first = index.search(ds.queries, [], k=10, backend="jax")
    live.delete(first[0][:, 0])
    for pid in live.dirty_partitions():
        live.compact(pid, requantize=False)
    pv = index.partitioning.residency_bitmap()
    assert pv.shape[1] == index.partitioning.assign.shape[0]
    # compacted-away rows are resident nowhere; live rows in exactly one pid
    resident = pv.any(axis=0)
    np.testing.assert_array_equal(resident, index.live_mask)


# ---------------------------------------------- serverless runtime parity

def test_serverless_search_under_mutation_parity_local(rng):
    """The tentpole acceptance gate, local transport: the same runtime
    (warm pools, caches) tracks insert → delete → compact and stays
    bitwise-identical to a fresh in-process search at every step."""
    ds, index = _build()
    live = LiveIndex(index)
    rt = ServerlessRuntime(live, RuntimeConfig(cache_enabled=False))
    try:
        r0 = rt.search(ds.queries, [], k=10)
        ref0 = index.search(ds.queries, [], k=10, backend="jax")
        np.testing.assert_array_equal(r0.ids, ref0[0])
        assert _stats_eq(r0.stats, ref0[2])

        live.insert(ds.vectors[:3] + 1e-3, ds.attributes[:3])
        live.delete(r0.ids[:, 0])
        during = rt.search(ds.queries, [], k=10)
        refd = index.search(ds.queries, [], k=10, backend="jax")
        np.testing.assert_array_equal(during.ids, refd[0])
        assert _stats_eq(during.stats, refd[2])
        assert np.intersect1d(during.ids.ravel(), r0.ids[:, 0]).size == 0

        for pid in live.dirty_partitions():
            live.compact(pid, requantize=False)
        after = rt.search(ds.queries, [], k=10)
        np.testing.assert_array_equal(after.ids, during.ids)
        np.testing.assert_array_equal(after.dists, during.dists)
        assert _stats_eq(after.stats, during.stats)
    finally:
        rt.close()


def test_mutation_forces_refetch_untouched_stay_warm(rng):
    """Per-partition generations in the fetch keys: after a delete the
    touched partitions' warm containers refetch, untouched ones keep their
    fetch-level DRE hits."""
    ds, index = _build(num_partitions=5)
    live = LiveIndex(index)
    rt = ServerlessRuntime(live, RuntimeConfig())
    try:
        r1 = rt.search(ds.queries, [], k=10)
        r2 = rt.search(ds.queries, [], k=10)
        assert r2.trace.dre.s3_gets == 0
        assert r2.trace.dre.dre_hits == r2.trace.dre.invocations

        victim = int(r1.ids[0, -1])
        live.delete([victim])
        r3 = rt.search(ds.queries, [], k=10)
        assert r3.trace.dre.s3_gets > 0, "touched partition must refetch"
        assert r3.trace.dre.dre_hits > 0, "untouched partitions stay warm"
    finally:
        rt.close()


def test_cache_survives_drop_only_compact(rng):
    """Drop-only compaction is bitwise-invisible, so the §5.6 cache keeps
    its entries — and serving them is still correct."""
    ds, index = _build()
    live = LiveIndex(index)
    rt = ServerlessRuntime(live, RuntimeConfig(cache_enabled=True))
    try:
        r0 = rt.search(ds.queries, [], k=10)
        live.delete(r0.ids[:, 0])
        r1 = rt.search(ds.queries, [], k=10)   # repopulates post-delete
        for pid in live.dirty_partitions():
            live.compact(pid, requantize=False)
        r2 = rt.search(ds.queries, [], k=10)
        assert r2.trace.cache_hits == ds.queries.shape[0]
        np.testing.assert_array_equal(r2.ids, r1.ids)
        ref = index.search(ds.queries, [], k=10, backend="jax")
        np.testing.assert_array_equal(r2.ids, ref[0])
    finally:
        rt.close()


def test_cache_invalidation_is_segment_granular(rng):
    """A delete only evicts cache entries whose dependency set touches the
    mutated partitions; other entries keep serving hits."""
    ds, index = _build(num_partitions=6)
    live = LiveIndex(index)
    rt = ServerlessRuntime(live, RuntimeConfig(cache_enabled=True))
    try:
        cents = index.partitioning.centroids
        r = rt.search(cents, [], k=10)
        assert (r.ids >= 0).all(), "test needs fully-filled entries"
        assign = index.partitioning.assign
        deps = [frozenset(assign[row].tolist()) for row in r.ids]
        pair = next(((i, j) for i in range(len(deps))
                     for j in range(len(deps)) if not (deps[i] & deps[j])),
                    None)
        assert pair is not None, "need two queries with disjoint deps"
        qa, qb = pair
        # delete a result of query A → A's entry evicts, B's survives
        live.delete([int(r.ids[qa, -1])])
        ra = rt.search(cents[qa][None, :], [], k=10)
        assert ra.trace.cache_hits == 0
        rb = rt.search(cents[qb][None, :], [], k=10)
        assert rb.trace.cache_hits == 1
        np.testing.assert_array_equal(rb.ids[0], r.ids[qb])
        assert rt.result_cache.targeted_evictions > 0
    finally:
        rt.close()


def test_insert_evicts_only_displaced_entries(rng):
    """An insert far from every cached query's kth-neighbor radius evicts
    nothing; a near-duplicate of a cached top hit evicts that entry."""
    ds, index = _build()
    live = LiveIndex(index)
    rt = ServerlessRuntime(live, RuntimeConfig(cache_enabled=True))
    try:
        r = rt.search(ds.queries, [], k=10)
        assert (r.ids >= 0).all()
        far = ds.vectors.max(axis=0) + 100.0
        live.insert(far[None, :], ds.attributes[:1])
        r2 = rt.search(ds.queries, [], k=10)
        assert r2.trace.cache_hits == ds.queries.shape[0]

        near = ds.vectors[r.ids[0, 0]] + 1e-6
        live.insert(near[None, :], ds.attributes[r.ids[0, 0]][None, :])
        r3 = rt.search(ds.queries[:1], [], k=10)
        assert r3.trace.cache_hits == 0, "displaced entry must re-derive"
        ref = index.search(ds.queries[:1], [], k=10, backend="jax")
        np.testing.assert_array_equal(r3.ids, ref[0])
    finally:
        rt.close()


# ------------------------------------------- DRE stale-retention satellites

def test_invalidate_denies_fetch_and_derived_hits(rng):
    """Acceptance: a warm container acquired before ``invalidate_cache()``
    scores neither a fetch-level nor a derived DRE hit afterwards — the
    version lives in *both* key layers."""
    ds, index = _build()
    rt = ServerlessRuntime(index, RuntimeConfig())
    try:
        rt.search(ds.queries, [], k=10)
        warm = rt.search(ds.queries, [], k=10)
        assert warm.trace.dre.dre_hits == warm.trace.dre.invocations
        assert warm.trace.dre.derived_hits == warm.trace.invocations("qp")
        rt.invalidate_cache()
        cold = rt.search(ds.queries, [], k=10)
        assert cold.trace.dre.dre_hits == 0, "fetch-level hit on stale key"
        assert cold.trace.dre.s3_gets == cold.trace.dre.invocations
        assert cold.trace.dre.derived_hits == 0
        assert all(n.setup_s > 0 for n in cold.trace.nodes if n.kind == "qp")
    finally:
        rt.close()


def test_derived_hit_routes_through_lease_delta_once():
    """Satellite 2: ``derived_hit`` counts in the lease's per-call delta and
    the pool's cumulative stats exactly once each, so merging lease deltas
    reproduces the pool totals without double accounting."""
    pool = ContainerPool(warm_prob=1.0, seed=3)
    merged_total = 0
    l1 = pool.acquire("key-v0", 1024)
    pool.retain_derived(l1, "derived-v0")
    pool.release(l1)
    merged_total += l1.stats.derived_hits

    l2 = pool.acquire("key-v0", 1024)
    assert pool.derived_hit(l2, "derived-v0") is True
    assert l2.stats.derived_hits == 1
    assert pool.stats.derived_hits == 1
    pool.release(l2)
    merged_total += l2.stats.derived_hits

    l3 = pool.acquire("key-v0", 1024)
    assert pool.derived_hit(l3, "missing") is False
    assert l3.stats.derived_hits == 0
    pool.release(l3)
    merged_total += l3.stats.derived_hits

    assert merged_total == pool.stats.derived_hits == 1


def test_result_cache_zero_capacity_rejects_up_front():
    """Satellite 3: capacity=0 must refuse admission (oversize_skips), not
    admit-then-evict (which polluted the eviction counter)."""
    cache = ResultCache(capacity=0)
    cache.put("k", (np.arange(4), np.arange(4.0)))
    assert cache.get("k") is None
    assert cache.oversize_skips == 1
    assert cache.evictions == 0
    assert cache.current_bytes == 0


def test_result_cache_eviction_and_deps_bookkeeping():
    cache = ResultCache(capacity=2)
    cache.put("a", (np.arange(2), np.arange(2.0)), parts=[0])
    cache.put("b", (np.arange(2), np.arange(2.0)), parts=[1])
    assert cache.deps("a") == frozenset({0})
    cache.put("c", (np.arange(2), np.arange(2.0)), parts=[2])  # evicts "a"
    assert cache.evictions == 1 and cache.deps("a") is None
    dropped = cache.invalidate_partitions([1])
    assert dropped == 1 and cache.get("b") is None
    assert cache.get("c") is not None          # untouched survives
    assert cache.targeted_evictions == 1
    # legacy (deps-less) entries evict on any partition invalidation
    cache.put("d", (np.arange(2), np.arange(2.0)))
    assert cache.invalidate_partitions([5]) == 1
    assert cache.get("d") is None


def test_container_pool_double_release_many_containers():
    """Satellite 4: releasing the same lease twice among many idle
    containers must not duplicate the free list (set-backed membership)."""
    pool = ContainerPool(warm_prob=1.0, seed=0)
    leases = [pool.acquire(f"k{i}", 64) for i in range(200)]
    for lease in leases:
        pool.release(lease)
    pool.release(leases[0])                    # double release
    pool.release(leases[123])
    assert len(pool._free) == len(set(pool._free)) == 200
    # the pool hands out 200 distinct containers again, no aliasing
    again = [pool.acquire(f"k{i}", 64) for i in range(200)]
    cids = [lease.container_id for lease in again]
    assert len(set(cids)) == 200
    for lease in again:
        pool.release(lease)


# ----------------------------------------------------------- service swap

def test_swap_index_keeps_runtime_and_drains_state(rng):
    """``swap_index`` rebinds the existing runtime (warm pools survive as
    objects) instead of discarding it, while staling every cache layer."""
    from repro.serve.vector_service import ServiceConfig, VectorSearchService

    ds, index = _build()
    svc = VectorSearchService(index, ServiceConfig(
        backend="serverless", cache_enabled=True))
    try:
        svc.query(ds.queries, [], k=10)
        svc.query(ds.queries, [], k=10)
        assert svc.last_trace.cache_hits == ds.queries.shape[0]
        rt_before = svc.runtime()
        pools_before = (rt_before.qa_pool,
                        tuple(rt_before.qp_pools.values()))

        rebuilt_cfg = SquashConfig(num_partitions=4, kmeans_iters=4,
                                   lloyd_iters=6)
        rebuilt = SquashIndex.build(ds.vectors[::-1].copy(), ds.attributes,
                                    rebuilt_cfg, seed=21)
        live = LiveIndex(rebuilt)
        svc.swap_index(live)                   # LiveIndex wrapper accepted
        assert svc.index is rebuilt
        assert svc.runtime() is rt_before, "runtime must survive the swap"
        assert rt_before.qa_pool is pools_before[0]

        ids, _, _ = svc.query(ds.queries, [], k=10, backend="serverless")
        assert svc.last_trace.cache_hits == 0
        ref = rebuilt.search(ds.queries, [], k=10, backend="jax")
        np.testing.assert_array_equal(ids, ref[0])

        # the swapped-in live index mutates through the same runtime
        live.delete(ids[:, 0])
        ids2, _, _ = svc.query(ds.queries, [], k=10, backend="serverless")
        assert np.intersect1d(ids2.ravel(), ids[:, 0]).size == 0
        ref2 = rebuilt.search(ds.queries, [], k=10, backend="jax")
        np.testing.assert_array_equal(ids2, ref2[0])
    finally:
        svc.close()


# -------------------------------------------------- real-transport parity

@pytest.mark.transport
@pytest.mark.parametrize("transport", ["process", "socket"])
def test_search_under_mutation_parity_real_transports(transport, rng):
    """The tentpole gate over real worker fleets: mutation → fresh bundles,
    ids and stats bitwise-identical to the in-process reference."""
    ds, index = _build(num_partitions=3)
    live = LiveIndex(index)
    rt = ServerlessRuntime(live, RuntimeConfig(
        branching=2, max_level=1, transport=transport, qa_workers=2))
    try:
        r0 = rt.search(ds.queries, [], k=10)
        ref0 = index.search(ds.queries, [], k=10, backend="jax")
        np.testing.assert_array_equal(r0.ids, ref0[0])
        assert _stats_eq(r0.stats, ref0[2])

        live.delete(r0.ids[:, 0])
        during = rt.search(ds.queries, [], k=10)
        refd = index.search(ds.queries, [], k=10, backend="jax")
        np.testing.assert_array_equal(during.ids, refd[0])
        assert _stats_eq(during.stats, refd[2])
        assert np.intersect1d(during.ids.ravel(), r0.ids[:, 0]).size == 0

        for pid in live.dirty_partitions():
            live.compact(pid, requantize=False)
        after = rt.search(ds.queries, [], k=10)
        np.testing.assert_array_equal(after.ids, during.ids)
        assert _stats_eq(after.stats, during.stats)
    finally:
        rt.close()
