"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import lowbit, osq, segments
from repro.core.adc import build_adc_table
from repro.kernels import adc_lookup, hamming, ops, ref


# ------------------------------------------------------------------- hamming

@pytest.mark.parametrize("n", [1, 7, 512, 513, 2048])
@pytest.mark.parametrize("g", [1, 4, 30])
def test_hamming_kernel_sweep(n, g):
    rng = np.random.default_rng(n * 31 + g)
    q = rng.integers(0, 2**32, size=(g,), dtype=np.uint32)
    db = rng.integers(0, 2**32, size=(n, g), dtype=np.uint32)
    got = np.asarray(ops.hamming_distances(jnp.asarray(q), jnp.asarray(db),
                                           interpret=True))
    want = np.asarray(ref.hamming_ref(jnp.asarray(q), jnp.asarray(db)))
    np.testing.assert_array_equal(got, want)


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 300),
       g=st.integers(1, 12))
@settings(max_examples=15, deadline=None)
def test_hamming_kernel_property(seed, n, g):
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 2**32, size=(g,), dtype=np.uint32)
    db = rng.integers(0, 2**32, size=(n, g), dtype=np.uint32)
    got = np.asarray(ops.hamming_distances(jnp.asarray(q), jnp.asarray(db),
                                           interpret=True))
    np.testing.assert_array_equal(got, np.asarray(ref.hamming_ref(q, db)))


def test_hamming_kernel_on_real_lowbit_index():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(1000, 96))
    idx = lowbit.build_lowbit_index(x)
    q = idx.encode_queries(rng.normal(size=(1, 96)))[0]
    got = np.asarray(ops.hamming_distances(jnp.asarray(q),
                                           jnp.asarray(idx.packed),
                                           interpret=True))
    want = np.asarray(lowbit.hamming_distances(q, idx.packed))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------- adc_lookup

@pytest.mark.parametrize("n,d,m1", [(1, 4, 5), (100, 16, 17), (300, 33, 9),
                                    (257, 128, 32)])
def test_adc_kernel_sweep(n, d, m1):
    rng = np.random.default_rng(n + d + m1)
    table = rng.exponential(size=(m1, d)).astype(np.float32)
    codes = rng.integers(0, m1, size=(n, d)).astype(np.int32)
    got = np.asarray(ops.adc_distances(jnp.asarray(table), jnp.asarray(codes),
                                       interpret=True))
    want = np.asarray(ref.adc_lb_ref(table, codes))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_adc_kernel_dtypes(dtype):
    rng = np.random.default_rng(5)
    table = rng.exponential(size=(9, 24)).astype(dtype)
    codes = rng.integers(0, 9, size=(64, 24)).astype(np.int32)
    got = np.asarray(ops.adc_distances(jnp.asarray(table), jnp.asarray(codes),
                                       interpret=True))
    want = np.asarray(ref.adc_lb_ref(table.astype(np.float32), codes))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_adc_kernel_matches_real_quantizer():
    """End-to-end: kernel LB == reference LB on a real OSQ index + query."""
    rng = np.random.default_rng(6)
    x = rng.normal(size=(800, 32)) * np.geomspace(3, 0.2, 32)
    bits = osq.allocate_bits(x.var(axis=0), 4 * 32)
    q_obj = osq.design_quantizers(x, bits)
    codes = osq.encode(q_obj, x).astype(np.int32)
    table = build_adc_table(rng.normal(size=32), q_obj.boundaries, q_obj.cells)
    safe = np.where(np.isfinite(table), table, 0.0).astype(np.float32)
    got = np.asarray(ops.adc_distances(jnp.asarray(safe), jnp.asarray(codes),
                                       interpret=True))
    want = np.asarray(ref.adc_lb_ref(safe, codes))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_adc_kernel_property(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 200))
    d = int(rng.integers(1, 40))
    m1 = int(rng.integers(2, 40))
    table = rng.exponential(size=(m1, d)).astype(np.float32)
    codes = rng.integers(0, m1, size=(n, d)).astype(np.int32)
    got = np.asarray(ops.adc_distances(jnp.asarray(table), jnp.asarray(codes),
                                       interpret=True, sqrt=False))
    want = np.asarray(ref.adc_lb_ref(table, codes, sqrt=False))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------- batched (multi-query) variants

@pytest.mark.parametrize("q,p,n", [(1, 1, 1), (3, 2, 7), (9, 4, 513),
                                   (2, 3, 515)])
@pytest.mark.parametrize("g", [1, 5])
def test_hamming_stacked_sweep(q, p, n, g):
    """Padding edges: N not a multiple of block_n, Q not of block_q,
    single-row/single-query inputs."""
    rng = np.random.default_rng(q * 131 + p * 17 + n + g)
    qs = rng.integers(0, 2**32, size=(q, p, g), dtype=np.uint32)
    db = rng.integers(0, 2**32, size=(p, n, g), dtype=np.uint32)
    got = np.asarray(hamming.packed_hamming_stacked(
        jnp.asarray(qs), jnp.asarray(db), interpret=True, block_n=256,
        block_q=4))
    want = np.asarray(ref.hamming_stacked_ref(jnp.asarray(qs),
                                              jnp.asarray(db)))
    np.testing.assert_array_equal(got, want)


def test_hamming_multi_matches_per_query_kernel():
    rng = np.random.default_rng(3)
    qs = rng.integers(0, 2**32, size=(6, 3), dtype=np.uint32)
    db = rng.integers(0, 2**32, size=(40, 3), dtype=np.uint32)
    got = np.asarray(hamming.packed_hamming_multi(
        jnp.asarray(qs), jnp.asarray(db), interpret=True, block_n=16))
    for qi in range(6):
        row = np.asarray(ops.hamming_distances(
            jnp.asarray(qs[qi]), jnp.asarray(db), interpret=True))
        np.testing.assert_array_equal(got[qi], row)


@given(seed=st.integers(0, 2**31 - 1), q=st.integers(1, 12),
       p=st.integers(1, 5), n=st.integers(1, 300))
@settings(max_examples=10, deadline=None)
def test_hamming_stacked_property(seed, q, p, n):
    rng = np.random.default_rng(seed)
    g = int(rng.integers(1, 8))
    qs = rng.integers(0, 2**32, size=(q, p, g), dtype=np.uint32)
    db = rng.integers(0, 2**32, size=(p, n, g), dtype=np.uint32)
    got = np.asarray(hamming.packed_hamming_stacked(
        jnp.asarray(qs), jnp.asarray(db), interpret=True))
    np.testing.assert_array_equal(got, np.asarray(
        ref.hamming_stacked_ref(jnp.asarray(qs), jnp.asarray(db))))


@pytest.mark.parametrize("b,n,d,m1", [(1, 1, 1, 2), (3, 33, 17, 9),
                                      (5, 257, 24, 12)])
def test_adc_batch_sweep(b, n, d, m1):
    """Padding edges: N not a multiple of block_n, d not of block_d,
    single-row inputs."""
    rng = np.random.default_rng(b + n + d + m1)
    tables = rng.exponential(size=(b, m1, d)).astype(np.float32)
    codes = rng.integers(0, m1, size=(b, n, d)).astype(np.int32)
    got = np.asarray(adc_lookup.adc_lb_distances_batch(
        jnp.asarray(tables), jnp.asarray(codes), interpret=True, block_n=64,
        block_d=8))
    want = np.asarray(ref.adc_lb_batch_ref(tables, codes))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_adc_batch_matches_per_pair_kernel():
    rng = np.random.default_rng(11)
    tables = rng.exponential(size=(4, 9, 24)).astype(np.float32)
    codes = rng.integers(0, 9, size=(4, 64, 24)).astype(np.int32)
    got = np.asarray(adc_lookup.adc_lb_distances_batch(
        jnp.asarray(tables), jnp.asarray(codes), interpret=True))
    for bi in range(4):
        row = np.asarray(ops.adc_distances(
            jnp.asarray(tables[bi]), jnp.asarray(codes[bi]), interpret=True))
        np.testing.assert_allclose(got[bi], row, rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_adc_batch_property(seed):
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 6))
    n = int(rng.integers(1, 120))
    d = int(rng.integers(1, 40))
    m1 = int(rng.integers(2, 24))
    tables = rng.exponential(size=(b, m1, d)).astype(np.float32)
    codes = rng.integers(0, m1, size=(b, n, d)).astype(np.int32)
    got = np.asarray(adc_lookup.adc_lb_distances_batch(
        jnp.asarray(tables), jnp.asarray(codes), interpret=True, sqrt=False))
    want = np.asarray(ref.adc_lb_batch_ref(tables, codes, sqrt=False))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- bitpack

@pytest.mark.parametrize("seg_bits", [8, 16, 32])
def test_extract_kernel_roundtrip(seg_bits):
    rng = np.random.default_rng(seg_bits)
    bits = rng.integers(0, 10, size=24).tolist()
    bits[0] = max(bits[0], 1)
    layout = segments.build_layout(bits, seg_bits=seg_bits)
    codes = np.stack(
        [rng.integers(0, 1 << b, size=700) if b else np.zeros(700, np.int64)
         for b in bits], axis=1)
    packed = segments.pack_codes(layout, codes)
    got = np.asarray(ops.extract_codes(jnp.asarray(packed), layout,
                                       interpret=True))
    np.testing.assert_array_equal(got, codes)
    want = np.asarray(ref.extract_ref(packed, layout))
    np.testing.assert_array_equal(got, want)


def test_extract_kernel_odd_sizes():
    layout = segments.build_layout([3, 9, 1, 7, 12], seg_bits=8)
    rng = np.random.default_rng(1)
    codes = np.stack(
        [rng.integers(0, 1 << b, size=13) for b in [3, 9, 1, 7, 12]], axis=1)
    packed = segments.pack_codes(layout, codes)
    got = np.asarray(ops.extract_codes(jnp.asarray(packed), layout,
                                       interpret=True))
    np.testing.assert_array_equal(got, codes)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_extract_kernel_property(seed):
    rng = np.random.default_rng(seed)
    d = int(rng.integers(1, 16))
    bits = rng.integers(0, 11, size=d).tolist()
    if sum(bits) == 0:
        bits[0] = 1
    layout = segments.build_layout(bits, seg_bits=int(rng.choice([8, 16, 32])))
    n = int(rng.integers(1, 150))
    codes = np.stack(
        [rng.integers(0, 1 << b, size=n) if b else np.zeros(n, np.int64)
         for b in bits], axis=1)
    packed = segments.pack_codes(layout, codes)
    got = np.asarray(ops.extract_codes(jnp.asarray(packed), layout,
                                       interpret=True))
    np.testing.assert_array_equal(got, codes)
