"""Tests for the serverless runtime pieces: Alg. 2 tree, DRE, cost model."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cost_model, dre, invocation


# ----------------------------------------------------------------- Algorithm 2

def test_tree_size_formula():
    # Paper §5.3 configurations: (F, l_max) → N_QA.
    assert invocation.tree_size(10, 1) == 10
    assert invocation.tree_size(4, 2) == 20
    assert invocation.tree_size(4, 3) == 84
    assert invocation.tree_size(5, 3) == 155
    assert invocation.tree_size(6, 3) == 258
    assert invocation.tree_size(4, 4) == 340


@pytest.mark.parametrize("f,lmax", [(10, 1), (4, 2), (4, 3), (5, 3), (6, 3), (4, 4)])
def test_tree_covers_all_ids_exactly_once(f, lmax):
    tree = invocation.build_tree(f, lmax)
    n_qa = invocation.tree_size(f, lmax)
    seen = [kid for kids in tree.values() for kid in kids]
    assert sorted(seen) == list(range(n_qa)), "every QA invoked exactly once"


@pytest.mark.parametrize("f,lmax", [(4, 3), (5, 3), (4, 4)])
def test_subtree_id_contiguity(f, lmax):
    """The invariant that enables response routing: the sub-tree rooted at x
    (next sibling x + J_S) contains exactly the ids y with x < y < x + J_S."""
    tree = invocation.build_tree(f, lmax)

    def collect(nid):
        out = []
        for kid in tree.get(nid, []):
            out.append(kid)
            out.extend(collect(kid))
        return out

    for nid, kids in tree.items():
        if nid == -1:
            continue
        sub = collect(nid)
        if sub:
            assert min(sub) == nid + 1
            assert sorted(sub) == list(range(nid + 1, nid + 1 + len(sub)))


def test_fanout_bounded_by_branching_factor():
    for f, lmax in [(4, 3), (6, 3), (10, 1)]:
        tree = invocation.build_tree(f, lmax)
        assert max(len(k) for k in tree.values()) <= f


def test_tree_beats_sequential_invocation():
    sim = invocation.InvocationSim(branching=4, max_level=3)
    assert sim.makespan() < sim.sequential_makespan() / 5.0


# ------------------------------------------------------------------------ DRE

def test_dre_eliminates_repeat_fetches():
    pool = dre.ContainerPool(warm_prob=1.0, seed=0)
    for _ in range(10):
        pool.invoke("sift1m/part0", data_bytes=10_000_000, use_dre=True)
    assert pool.stats.s3_gets == 1, "warm containers must reuse the singleton"
    assert pool.stats.dre_hits == 9


def test_no_dre_refetches_every_time():
    pool = dre.ContainerPool(warm_prob=1.0, seed=0)
    for _ in range(10):
        pool.invoke("sift1m/part0", data_bytes=10_000_000, use_dre=False)
    assert pool.stats.s3_gets == 10


def test_dre_dataset_mismatch_refetches():
    pool = dre.ContainerPool(warm_prob=1.0, seed=0)
    pool.invoke("sift1m/part0", 1000)
    pool.invoke("gist1m/part0", 1000)  # different dataset in same container
    assert pool.stats.s3_gets == 2


def test_result_cache():
    cache = dre.ResultCache()
    from repro.core.attributes import Predicate

    q = np.array([1.0, 2.0])
    preds = [Predicate(attr=0, op="<", lo=3.0)]
    key = cache.key(q, preds, 10)
    assert cache.get(key) is None
    cache.put(key, ("ids", "dists"))
    assert cache.get(key) == ("ids", "dists")
    assert cache.hit_rate == 0.5


# ----------------------------------------------------------------- cost model

def test_cost_model_components():
    fleet = cost_model.LambdaFleet(
        n_qa=84, n_qp=500, t_qa_s=84 * 0.5, t_qp_s=500 * 0.3, t_co_s=1.0,
        s3_gets=584, efs_read_bytes=2 * 10 * 128 * 4 * 1000,
    )
    c = cost_model.squash_query_cost(fleet)
    assert c["total"] == pytest.approx(
        c["lambda_invocation"] + c["lambda_runtime"] + c["s3"] + c["efs"]
    )
    # Eq. 5: (N_QA + N_QP + 1) · C_inv
    assert c["lambda_invocation"] == pytest.approx(585 * 2.0e-7)
    assert c["lambda_runtime"] > 0


def test_serverless_cheaper_at_low_volume_crossover_at_high():
    """Fig. 8 shape: SQUASH scales with volume, servers are flat — there is a
    crossover somewhere in the millions of queries/day."""
    fleet = cost_model.LambdaFleet(
        n_qa=84, n_qp=400, t_qa_s=84 * 0.4, t_qp_s=400 * 0.25, t_co_s=1.0,
        s3_gets=484, efs_read_bytes=20 * 512 * 1000,
    )
    per_batch = cost_model.squash_query_cost(fleet)["total"]  # 1000 queries
    volumes = [10_000, 100_000, 1_000_000, 10_000_000, 100_000_000]
    squash_daily = cost_model.daily_cost_curve(per_batch, 1000, volumes)
    server_daily = cost_model.server_baseline_cost(hours=24.0)
    assert squash_daily[0] < server_daily, "cheap at low volume"
    assert squash_daily[-1] > server_daily, "servers win at huge volume"
    # Paper §5.4: crossover around 1M–3.5M queries/day for the large server;
    # our synthetic fleet times put it within an order of magnitude of that.
    crossover = next(v for v, c in zip(volumes, squash_daily) if c > server_daily)
    assert 1_000_000 <= crossover <= 100_000_000


@given(
    n_qa=st.integers(1, 500), n_qp=st.integers(0, 2000),
    t=st.floats(0.0, 10.0),
)
@settings(max_examples=25, deadline=None)
def test_cost_monotonicity(n_qa, n_qp, t):
    base = cost_model.LambdaFleet(n_qa=n_qa, n_qp=n_qp, t_qa_s=t, t_qp_s=t)
    more = cost_model.LambdaFleet(n_qa=n_qa + 1, n_qp=n_qp, t_qa_s=t, t_qp_s=t)
    assert (
        cost_model.squash_query_cost(more)["total"]
        >= cost_model.squash_query_cost(base)["total"]
    )
