"""Tests for the serverless subsystem: Alg. 2 tree, DRE, cost model, and the
event-driven Coordinator → QueryAllocator → QueryProcessor runtime."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cost_model, dre, invocation


# ----------------------------------------------------------------- Algorithm 2

def test_tree_size_formula():
    # Paper §5.3 configurations: (F, l_max) → N_QA.
    assert invocation.tree_size(10, 1) == 10
    assert invocation.tree_size(4, 2) == 20
    assert invocation.tree_size(4, 3) == 84
    assert invocation.tree_size(5, 3) == 155
    assert invocation.tree_size(6, 3) == 258
    assert invocation.tree_size(4, 4) == 340


@pytest.mark.parametrize("f,lmax", [(10, 1), (4, 2), (4, 3), (5, 3), (6, 3), (4, 4)])
def test_tree_covers_all_ids_exactly_once(f, lmax):
    tree = invocation.build_tree(f, lmax)
    n_qa = invocation.tree_size(f, lmax)
    seen = [kid for kids in tree.values() for kid in kids]
    assert sorted(seen) == list(range(n_qa)), "every QA invoked exactly once"


@pytest.mark.parametrize("f,lmax", [(4, 3), (5, 3), (4, 4)])
def test_subtree_id_contiguity(f, lmax):
    """The invariant that enables response routing: the sub-tree rooted at x
    (next sibling x + J_S) contains exactly the ids y with x < y < x + J_S."""
    tree = invocation.build_tree(f, lmax)

    def collect(nid):
        out = []
        for kid in tree.get(nid, []):
            out.append(kid)
            out.extend(collect(kid))
        return out

    for nid, kids in tree.items():
        if nid == -1:
            continue
        sub = collect(nid)
        if sub:
            assert min(sub) == nid + 1
            assert sorted(sub) == list(range(nid + 1, nid + 1 + len(sub)))


def test_fanout_bounded_by_branching_factor():
    for f, lmax in [(4, 3), (6, 3), (10, 1)]:
        tree = invocation.build_tree(f, lmax)
        assert max(len(k) for k in tree.values()) <= f


def test_tree_beats_sequential_invocation():
    sim = invocation.InvocationSim(branching=4, max_level=3)
    assert sim.makespan() < sim.sequential_makespan() / 5.0


# ------------------------------------------------------------------------ DRE

def test_dre_eliminates_repeat_fetches():
    pool = dre.ContainerPool(warm_prob=1.0, seed=0)
    for _ in range(10):
        pool.invoke("sift1m/part0", data_bytes=10_000_000, use_dre=True)
    assert pool.stats.s3_gets == 1, "warm containers must reuse the singleton"
    assert pool.stats.dre_hits == 9


def test_no_dre_refetches_every_time():
    pool = dre.ContainerPool(warm_prob=1.0, seed=0)
    for _ in range(10):
        pool.invoke("sift1m/part0", data_bytes=10_000_000, use_dre=False)
    assert pool.stats.s3_gets == 10


def test_dre_dataset_mismatch_refetches():
    pool = dre.ContainerPool(warm_prob=1.0, seed=0)
    pool.invoke("sift1m/part0", 1000)
    pool.invoke("gist1m/part0", 1000)  # different dataset in same container
    assert pool.stats.s3_gets == 2


def test_result_cache():
    cache = dre.ResultCache()
    from repro.core.attributes import Predicate

    q = np.array([1.0, 2.0])
    preds = [Predicate(attr=0, op="<", lo=3.0)]
    key = cache.key(q, preds, 10)
    assert cache.get(key) is None
    cache.put(key, ("ids", "dists"))
    assert cache.get(key) == ("ids", "dists")
    assert cache.hit_rate == 0.5


def test_result_cache_exact_keys_no_float_aliasing():
    """Regression: the old key rounded coordinates to 6 decimals, so queries
    differing at the 8th decimal aliased to one entry and the second query
    silently got the first query's neighbors."""
    cache = dre.ResultCache()
    q1 = np.array([1.0, 2.0])
    q2 = np.array([1.0, 2.00000001])       # differs at the 8th decimal
    k1 = cache.key(q1, [], 10)
    k2 = cache.key(q2, [], 10)
    assert k1 != k2
    cache.put(k1, "neighbors-of-q1")
    assert cache.get(k2) is None, "distinct query must not hit q1's entry"
    # dtype normalization: equal values hash equal regardless of input dtype
    assert cache.key(np.array([1.0, 2.0], np.float32), [], 10) == k1


def test_result_cache_key_canonicalizes_predicates():
    from repro.core.attributes import Predicate

    q = np.array([0.5])
    a = Predicate(attr=0, op="<", lo=3.0)
    b = Predicate(attr=1, op="IN", values=(2.0, 1.0))
    b_sorted = Predicate(attr=1, op="IN", values=(1.0, 2.0))
    cache = dre.ResultCache()
    # predicate order and IN value order are spelling, not semantics
    assert cache.key(q, [a, b], 10) == cache.key(q, [b_sorted, a], 10)
    # different k, different operand, different group → different keys
    assert cache.key(q, [a, b], 10) != cache.key(q, [a, b], 11)
    assert cache.key(q, [a], 10) != cache.key(
        q, [Predicate(attr=0, op="<", lo=3.1)], 10)
    grouped = Predicate(attr=0, op="<", lo=3.0, group=1)
    assert cache.key(q, [a], 10) != cache.key(q, [grouped], 10)


def test_result_cache_lru_get_refreshes_recency():
    """Regression: eviction used to pop insertion order with no refresh on
    get — a hot entry inserted first was evicted before a stale one."""
    cache = dre.ResultCache(capacity=2)
    cache.put("hot", 1)
    cache.put("stale", 2)
    assert cache.get("hot") == 1           # refresh: hot is now most recent
    cache.put("new", 3)                    # evicts 'stale', not 'hot'
    assert cache.get("hot") == 1
    assert cache.get("stale") is None
    assert cache.get("new") == 3
    assert cache.evictions == 1


def test_result_cache_byte_budget_accounting():
    row = np.zeros(128)                    # 1 KiB of float64 payload
    cache = dre.ResultCache(max_bytes=4096)
    for i in range(8):
        cache.put(("q", i), row.copy())
    assert cache.current_bytes <= 4096
    assert len(cache) < 8 and cache.evictions > 0
    # an entry larger than the whole budget is never admitted
    cache.put(("huge",), np.zeros(4096))
    assert ("huge",) not in cache
    cache.invalidate()
    assert len(cache) == 0 and cache.current_bytes == 0


def test_result_cache_oversize_put_preserves_existing_entry():
    """Regression: putting an over-budget value under a live key used to
    evict the old entry first and then cache nothing — the cache silently
    lost an entry it could have kept serving."""
    cache = dre.ResultCache(max_bytes=4096)
    cache.put("q", np.zeros(16))
    cache.put("q", np.zeros(4096))         # over the whole budget: rejected
    got = cache.get("q")
    assert got is not None and got.shape == (16,), (
        "over-budget put must leave the existing entry intact")
    assert cache.oversize_skips == 1
    assert cache.evictions == 0
    assert cache.current_bytes <= 4096


def test_container_pool_double_release_is_idempotent():
    """Regression: releasing one lease twice put its container id into the
    free list twice, so two concurrent acquires shared one container."""
    pool = dre.ContainerPool(warm_prob=1.0, seed=0)
    lease = pool.acquire("ds/p0", 1000)
    pool.release(lease)
    pool.release(lease)                    # double release: no-op
    a = pool.acquire("ds/p0", 1000)
    b = pool.acquire("ds/p0", 1000)        # concurrent wave
    assert a.container_id != b.container_id, (
        "double-released container handed to two in-flight leases")


def test_container_pool_dre_off_does_not_seed_retention():
    """Regression (off→on sequence): a DRE-off invocation used to install
    the singleton anyway, so a later DRE-on call scored a hit it never paid
    for."""
    pool = dre.ContainerPool(warm_prob=1.0, seed=0)
    pool.invoke("sift1m/part0", 1000, use_dre=False)
    warm, hit = pool.invoke("sift1m/part0", 1000, use_dre=True)
    assert warm and not hit, "first DRE-on call must pay the fetch"
    warm, hit = pool.invoke("sift1m/part0", 1000, use_dre=True)
    assert hit, "second DRE-on call hits the retention it paid for"
    assert pool.stats.s3_gets == 2


def test_container_pool_derived_state_retention():
    pool = dre.ContainerPool(warm_prob=1.0, seed=0)
    lease = pool.acquire("ds/p0", 1000)
    assert not pool.derived_hit(lease, ("stacked", 0))
    pool.retain_derived(lease, ("stacked", 0))
    pool.release(lease)
    lease2 = pool.acquire("ds/p0", 1000)
    assert lease2.container_id == lease.container_id
    assert pool.derived_hit(lease2, ("stacked", 0))
    assert not pool.derived_hit(lease2, ("stacked", 1)), "key-specific"
    assert not pool.derived_hit(lease2, ("stacked", 0), use_dre=False)
    assert pool.stats.derived_hits == 1


def test_container_pool_stale_lease_cannot_resurrect_derived_state():
    """Regression (lease accounting): a lease still in flight when
    ``clear_derived()`` runs (invalidate_cache/swap_index) must not re-add
    derived state on its way out — the resurrected entry would be keyed to a
    dead index_version and leak forever, and a buggy version-less key would
    be served as a false hit for the new index."""
    pool = dre.ContainerPool(warm_prob=1.0, seed=0)
    stale = pool.acquire("ds/p0", 1000)
    pool.retain_derived(stale, ("stacked", 0, 0))
    pool.clear_derived()                      # invalidation while leased
    pool.retain_derived(stale, ("stacked", 0, 0))   # in-flight retain: dropped
    pool.release(stale)
    fresh = pool.acquire("ds/p0", 1000)
    assert fresh.container_id == stale.container_id
    assert not pool.derived_hit(fresh, ("stacked", 0, 0)), (
        "stale lease resurrected cleared derived state")
    # the new-epoch lease retains normally
    pool.retain_derived(fresh, ("stacked", 0, 1))
    pool.release(fresh)
    again = pool.acquire("ds/p0", 1000)
    assert pool.derived_hit(again, ("stacked", 0, 1))


# ----------------------------------------------------------------- cost model

def test_cost_model_exports_daily_cost_curve():
    """``daily_cost_curve`` is public API (Fig. 8 consumers import it)."""
    assert "daily_cost_curve" in cost_model.__all__


def test_cost_model_components():
    fleet = cost_model.LambdaFleet(
        n_qa=84, n_qp=500, t_qa_s=84 * 0.5, t_qp_s=500 * 0.3, t_co_s=1.0,
        s3_gets=584, efs_read_bytes=2 * 10 * 128 * 4 * 1000,
    )
    c = cost_model.squash_query_cost(fleet)
    assert c["total"] == pytest.approx(
        c["lambda_invocation"] + c["lambda_runtime"] + c["s3"] + c["efs"]
    )
    # Eq. 5: (N_QA + N_QP + 1) · C_inv
    assert c["lambda_invocation"] == pytest.approx(585 * 2.0e-7)
    assert c["lambda_runtime"] > 0


def test_serverless_cheaper_at_low_volume_crossover_at_high():
    """Fig. 8 shape: SQUASH scales with volume, servers are flat — there is a
    crossover somewhere in the millions of queries/day."""
    fleet = cost_model.LambdaFleet(
        n_qa=84, n_qp=400, t_qa_s=84 * 0.4, t_qp_s=400 * 0.25, t_co_s=1.0,
        s3_gets=484, efs_read_bytes=20 * 512 * 1000,
    )
    per_batch = cost_model.squash_query_cost(fleet)["total"]  # 1000 queries
    volumes = [10_000, 100_000, 1_000_000, 10_000_000, 100_000_000]
    squash_daily = cost_model.daily_cost_curve(per_batch, 1000, volumes)
    server_daily = cost_model.server_baseline_cost(hours=24.0)
    assert squash_daily[0] < server_daily, "cheap at low volume"
    assert squash_daily[-1] > server_daily, "servers win at huge volume"
    # Paper §5.4: crossover around 1M–3.5M queries/day for the large server;
    # our synthetic fleet times put it within an order of magnitude of that.
    crossover = next(v for v, c in zip(volumes, squash_daily) if c > server_daily)
    assert 1_000_000 <= crossover <= 100_000_000


@given(
    n_qa=st.integers(1, 500), n_qp=st.integers(0, 2000),
    t=st.floats(0.0, 10.0),
)
@settings(max_examples=25, deadline=None)
def test_cost_monotonicity(n_qa, n_qp, t):
    base = cost_model.LambdaFleet(n_qa=n_qa, n_qp=n_qp, t_qa_s=t, t_qp_s=t)
    more = cost_model.LambdaFleet(n_qa=n_qa + 1, n_qp=n_qp, t_qa_s=t, t_qp_s=t)
    assert (
        cost_model.squash_query_cost(more)["total"]
        >= cost_model.squash_query_cost(base)["total"]
    )


# ======================================================== serverless runtime

from repro.core.attributes import Predicate  # noqa: E402
from repro.core.pipeline import SquashConfig, SquashIndex  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.serverless import (PayloadOverflowError, RuntimeConfig,  # noqa: E402
                              ServerlessRuntime, decode_message,
                              encode_message)


@pytest.fixture(scope="module")
def built():
    ds = synthetic.make_vector_dataset("sift1m", scale=0.004, num_queries=12,
                                       seed=7)
    preds = synthetic.default_predicates(ds.attr_cardinality)
    cfg = SquashConfig(num_partitions=5, kmeans_iters=4, lloyd_iters=6)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=7)
    return ds, preds, index


def _runtime(index, **kw):
    kw.setdefault("branching", 3)
    kw.setdefault("max_level", 2)
    return ServerlessRuntime(index, RuntimeConfig(**kw))


def test_codec_roundtrip():
    msg = {
        "qidx": np.arange(7, dtype=np.int32),
        "queries": np.random.default_rng(0).normal(size=(7, 16)),
        "rows": np.array([], dtype=np.int32),
        "k": 10,
        "preds": [{"attr": 0, "op": "B", "lo": 1.0, "hi": 2.0,
                   "values": [], "group": None}],
    }
    out = decode_message(encode_message(msg))
    assert out["k"] == 10 and out["preds"] == msg["preds"]
    np.testing.assert_array_equal(out["qidx"], msg["qidx"])
    np.testing.assert_array_equal(out["queries"], msg["queries"])
    assert out["rows"].dtype == np.int32 and out["rows"].shape == (0,)


def test_runtime_matches_jax_backend_bitwise(built):
    """Acceptance: Coordinator → QA → QP ids are bitwise-identical to
    SquashIndex.search(backend='jax'), stats counters equal."""
    ds, preds, index = built
    rt = _runtime(index)
    res = rt.search(ds.queries, preds, k=10)
    ids_j, d_j, s_j = index.search(ds.queries, preds, k=10, backend="jax")
    np.testing.assert_array_equal(res.ids, ids_j)
    np.testing.assert_array_equal(np.isfinite(res.dists), np.isfinite(d_j))
    fin = np.isfinite(d_j)
    np.testing.assert_array_equal(res.dists[fin], d_j[fin])
    assert res.stats == s_j


def test_runtime_unfiltered_and_empty_predicates(built):
    ds, _, index = built
    rt = _runtime(index)
    res = rt.search(ds.queries, [], k=5)
    ids_j, _, _ = index.search(ds.queries, [], k=5, backend="jax")
    np.testing.assert_array_equal(res.ids, ids_j)
    impossible = [Predicate(attr=0, op="=", lo=1e9)]
    res2 = rt.search(ds.queries[:4], impossible, k=5)
    assert (res2.ids == -1).all() and np.isinf(res2.dists).all()
    assert res2.trace.invocations("qp") == 0


def test_tree_fanout_every_qa_invoked_once(built):
    """Fan-out correctness: each of the N_QA allocators is invoked exactly
    once per batch (no chunking), the coordinator once, and per-node traces
    carry a consistent timeline."""
    ds, preds, index = built
    rt = _runtime(index, branching=3, max_level=2)
    res = rt.search(ds.queries, preds, k=10)
    t = res.trace
    qa_nodes = [n for n in t.nodes if n.kind == "qa"]
    assert len(qa_nodes) == invocation.tree_size(3, 2) == 12
    assert sorted(n.node for n in qa_nodes) == sorted(
        f"qa:{i}" for i in range(12))
    assert t.invocations("co") == 1
    for n in t.nodes:
        assert n.t_issue <= n.t_start <= n.t_end
        assert n.billed_s >= n.compute_s
    assert t.makespan_s >= max(n.t_end for n in t.nodes)
    # every query lands in exactly one QA's own slice
    assert sum(n.own_queries for n in qa_nodes) == ds.queries.shape[0]


def test_filter_count_escalation_path(built):
    """§2.5 single-pass guarantee: a highly selective predicate forces
    Alg. 1 past the Eq. 1 threshold cut; the runtime reports the escalated
    visits and still matches the reference plane."""
    ds, _, index = built
    narrow = [Predicate(attr=0, op="=", lo=float(ds.attributes[0, 0])),
              Predicate(attr=1, op="=", lo=float(ds.attributes[0, 1]))]
    rt = _runtime(index)
    res = rt.search(ds.queries, narrow, k=10)
    ids_j, _, s_j = index.search(ds.queries, narrow, k=10, backend="jax")
    np.testing.assert_array_equal(res.ids, ids_j)
    assert res.stats == s_j
    assert res.trace.escalations > 0, "narrow predicate must escalate"
    # escalation is bounded by the visited count
    assert res.trace.escalations <= res.stats.partitions_visited


def test_payload_overflow_error_policy(built):
    ds, preds, index = built
    rt = _runtime(index, max_payload_bytes=4096, overflow="error")
    with pytest.raises(PayloadOverflowError):
        rt.search(ds.queries, preds, k=10)


def test_payload_overflow_chunking_preserves_results(built):
    ds, preds, index = built
    rt = _runtime(index, max_payload_bytes=4096, overflow="chunk")
    res = rt.search(ds.queries, preds, k=10)
    ids_j, _, _ = index.search(ds.queries, preds, k=10, backend="jax")
    np.testing.assert_array_equal(res.ids, ids_j)
    # chunking means strictly more invocations than the unchunked tree
    base = _runtime(index).search(ds.queries, preds, k=10)
    assert len(res.trace.nodes) > len(base.trace.nodes)
    for n in res.trace.nodes:
        assert n.request_bytes <= 4096


def test_response_payload_pagination(built):
    """Oversized responses (large k) are budgeted too: under the chunk
    policy they paginate — extra warm round-trips in the trace — and the
    merged results still match the reference plane."""
    ds, preds, index = built
    rt = _runtime(index, max_payload_bytes=4096, overflow="chunk")
    res = rt.search(ds.queries, preds, k=200)
    ids_j, _, _ = index.search(ds.queries, preds, k=200, backend="jax")
    np.testing.assert_array_equal(res.ids, ids_j)
    paged = [n for n in res.trace.nodes if n.response_chunks > 1]
    assert paged, "k=200 responses must exceed the 4 KB budget"


def test_single_query_payload_cannot_chunk(built):
    """A payload that cannot split below one query raises even under the
    chunk policy."""
    ds, preds, index = built
    rt = _runtime(index, max_payload_bytes=256, overflow="chunk")
    with pytest.raises(PayloadOverflowError):
        rt.search(ds.queries[:2], preds, k=10)


def test_dre_warm_reuse_across_batches(built):
    """Second batch on a warm fleet: zero S3 GETs, all DRE hits, smaller
    makespan and cost (Fig. 6 shape)."""
    ds, preds, index = built
    rt = _runtime(index, warm_prob=1.0)
    r1 = rt.search(ds.queries, preds, k=10)
    r2 = rt.search(ds.queries, preds, k=10)
    assert r1.trace.dre.s3_gets > 0
    assert r2.trace.dre.s3_gets == 0
    assert r2.trace.dre.dre_hits == r2.trace.dre.invocations
    assert r2.trace.makespan_s < r1.trace.makespan_s
    np.testing.assert_array_equal(r1.ids, r2.ids)


def test_dre_disabled_refetches(built):
    ds, preds, index = built
    rt = _runtime(index, use_dre=False)
    rt.search(ds.queries, preds, k=10)
    r2 = rt.search(ds.queries, preds, k=10)
    # every QA/QP invocation refetches even on warm containers
    assert r2.trace.dre.s3_gets == r2.trace.dre.invocations
    assert r2.trace.dre.dre_hits == 0


def test_cost_and_fleet_assembly(built):
    ds, preds, index = built
    rt = _runtime(index, qa_compute_s=0.1, qp_compute_s=0.2, co_compute_s=0.01)
    res = rt.search(ds.queries, preds, k=10)
    t = res.trace
    c = t.cost
    assert c["total"] == pytest.approx(
        c["lambda_invocation"] + c["lambda_runtime"] + c["s3"] + c["efs"])
    assert c["total"] > 0 and c["lambda_runtime"] > 0
    assert t.fleet.n_qa == t.invocations("qa")
    assert t.fleet.n_qp == t.invocations("qp")
    assert t.fleet.s3_gets == t.dre.s3_gets
    assert t.fleet.efs_read_bytes == t.efs_read_bytes
    assert t.efs_reads == res.stats.refined
    assert t.payload_bytes == t.request_bytes + t.response_bytes > 0
    # billed time covers at least the configured compute
    assert t.fleet.t_qp_s >= 0.2 * t.invocations("qp")


def test_sequential_strawman_slower_than_tree(built):
    """Fig. 7 via the runtime: the CO-invokes-everything strawman's makespan
    exceeds the Alg. 2 tree's for the same fleet and workload."""
    ds, preds, index = built
    fixed = dict(qa_compute_s=0.05, qp_compute_s=0.05, co_compute_s=0.01)
    tree = _runtime(index, branching=3, max_level=2, **fixed)
    seq = _runtime(index, branching=3, max_level=2, sequential=True, **fixed)
    r_tree = tree.search(ds.queries, preds, k=10)
    r_seq = seq.search(ds.queries, preds, k=10)
    np.testing.assert_array_equal(r_tree.ids, r_seq.ids)
    assert r_seq.trace.makespan_s > r_tree.trace.makespan_s


def test_runtime_single_query_and_large_k(built):
    ds, preds, index = built
    rt = _runtime(index)
    for qn, k in ((1, 10), (3, 50)):
        res = rt.search(ds.queries[:qn], preds, k=k)
        ids_j, _, _ = index.search(ds.queries[:qn], preds, k=k, backend="jax")
        np.testing.assert_array_equal(res.ids, ids_j)


def test_service_serverless_backend(built):
    from repro.serve.vector_service import ServiceConfig, VectorSearchService

    ds, preds, index = built
    svc = VectorSearchService(index, ServiceConfig(backend="auto"))
    ids, _, _ = svc.query(ds.queries, preds, backend="serverless")
    ids_j, _, _ = index.search(ds.queries, preds, k=10, backend="jax")
    np.testing.assert_array_equal(ids, ids_j)
    assert svc.last_trace is not None
    assert svc.last_trace.cost["total"] > 0
    assert svc.queries_served["serverless"] == ds.queries.shape[0]


# ============================================== §5.6 result cache in the runtime


def test_cache_on_off_bitwise_parity_repeated_batches(built):
    """Acceptance: with caching enabled, repeated-workload ids/dists are
    bitwise-identical to a cache-off run, while the repeat pass shows
    strictly fewer invocations, payload bytes and §3.5 dollars."""
    ds, preds, index = built
    off = _runtime(index)
    on = _runtime(index, cache_enabled=True)
    off1 = off.search(ds.queries, preds, k=10)
    off2 = off.search(ds.queries, preds, k=10)
    on1 = on.search(ds.queries, preds, k=10)
    on2 = on.search(ds.queries, preds, k=10)
    for a, b in ((off1, on1), (off2, on2)):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.dists, b.dists)
    # cold pass: every query misses, then populates
    assert on1.trace.cache_hits == 0
    assert on1.trace.cache_misses == ds.queries.shape[0]
    # repeat pass: all served at the CO, fleet never launches
    assert on2.trace.cache_hits == ds.queries.shape[0]
    assert on2.trace.cache_misses == 0
    assert on2.trace.invocations() < off2.trace.invocations()
    assert on2.trace.invocations("qa") == 0
    assert on2.trace.invocations("qp") == 0
    assert on2.trace.payload_bytes < off2.trace.payload_bytes
    assert on2.trace.cost["total"] < off2.trace.cost["total"]
    assert on2.trace.cache_hit_rate == 1.0
    # the CO's own trace marks the served queries
    co = [n for n in on2.trace.nodes if n.kind == "co"]
    assert sum(n.cache_hits for n in co) == ds.queries.shape[0]


def test_cache_cold_pass_fleet_matches_cache_off(built):
    """A cold cache (0 hits) must not change the modeled fleet: only *hits*
    may thin the Fig. 7 whole-fleet launch, so a small batch that leaves
    some subtrees query-empty still launches them, exactly like cache-off."""
    ds, preds, index = built
    off = _runtime(index, branching=4, max_level=2)
    on = _runtime(index, branching=4, max_level=2, cache_enabled=True)
    r_off = off.search(ds.queries[:2], preds, k=10)
    r_on = on.search(ds.queries[:2], preds, k=10)
    assert r_on.trace.invocations() == r_off.trace.invocations()
    assert r_on.trace.invocations("qa") == r_off.trace.invocations("qa")
    np.testing.assert_array_equal(r_on.ids, r_off.ids)


def test_cache_mixed_hit_miss_slices(built):
    """Partially-repeated batch: the hit slice never reaches the fleet, the
    miss slice traverses the tree, and the merged result is bitwise equal
    to a cache-off run of the same batch."""
    ds, preds, index = built
    half = ds.queries.shape[0] // 2
    mixed = np.concatenate([ds.queries[:half], ds.queries[:half] + 0.25])
    off = _runtime(index)
    on = _runtime(index, cache_enabled=True)
    on.search(ds.queries[:half], preds, k=10)        # populate first half
    r_on = on.search(mixed, preds, k=10)
    r_off = off.search(mixed, preds, k=10)
    np.testing.assert_array_equal(r_on.ids, r_off.ids)
    np.testing.assert_array_equal(r_on.dists, r_off.dists)
    assert r_on.trace.cache_hits == half
    assert r_on.trace.cache_misses == half
    assert 0.0 < r_on.trace.cache_hit_rate < 1.0
    assert r_on.trace.invocations("qp") <= r_off.trace.invocations("qp")
    assert r_on.trace.payload_bytes < r_off.trace.payload_bytes
    # different k must not hit entries stored under k=10
    r_k5 = on.search(mixed[:2], preds, k=5)
    assert r_k5.trace.cache_hits == 0


def test_cache_respects_predicates(built):
    """Same query under a different filter is a different result — the
    canonical predicate tuple must keep them apart, while a reordered
    spelling of the same filter still hits."""
    ds, preds, index = built
    if len(preds) < 2:
        pytest.skip("needs >= 2 predicates to reorder")
    on = _runtime(index, cache_enabled=True)
    on.search(ds.queries[:4], preds, k=10)
    r_reordered = on.search(ds.queries[:4], list(reversed(preds)), k=10)
    assert r_reordered.trace.cache_hits == 4
    r_unfiltered = on.search(ds.queries[:4], [], k=10)
    assert r_unfiltered.trace.cache_hits == 0
    ids_j, _, _ = index.search(ds.queries[:4], [], k=10, backend="jax")
    np.testing.assert_array_equal(r_unfiltered.ids, ids_j)


def test_cache_invalidation_serves_fresh_results(built):
    ds, preds, index = built
    on = _runtime(index, cache_enabled=True)
    on.search(ds.queries, preds, k=10)
    on.invalidate_cache()
    r = on.search(ds.queries, preds, k=10)
    assert r.trace.cache_hits == 0 and r.trace.cache_misses == ds.queries.shape[0]
    ids_j, _, _ = index.search(ds.queries, preds, k=10, backend="jax")
    np.testing.assert_array_equal(r.ids, ids_j)


def test_qp_derived_state_retention_in_runtime(built):
    """Warm QP containers retain derived (device-resident) state beyond the
    fetched bytes: the first wave pays setup on every QP invocation, the
    second wave skips it on retained containers; DRE-off always pays."""
    ds, preds, index = built
    rt = _runtime(index, warm_prob=1.0)
    r1 = rt.search(ds.queries, preds, k=10)
    r2 = rt.search(ds.queries, preds, k=10)
    assert r1.trace.dre.derived_hits == 0
    assert r2.trace.dre.derived_hits == r2.trace.invocations("qp") > 0
    qp1 = [n for n in r1.trace.nodes if n.kind == "qp"]
    qp2 = [n for n in r2.trace.nodes if n.kind == "qp"]
    assert all(n.setup_s > 0 for n in qp1)
    assert all(n.setup_s == 0 for n in qp2)
    off = _runtime(index, warm_prob=1.0, use_dre=False)
    off.search(ds.queries, preds, k=10)
    r_off = off.search(ds.queries, preds, k=10)
    assert r_off.trace.dre.derived_hits == 0
    assert all(n.setup_s > 0 for n in r_off.trace.nodes if n.kind == "qp")


def test_invalidate_cache_resets_derived_retention(built):
    """Runtime-level twin of the stale-lease regression: after
    ``invalidate_cache()`` the next wave re-pays QP setup on every container
    (no resurrected derived state), then retention resumes normally."""
    ds, preds, index = built
    rt = _runtime(index, warm_prob=1.0)
    rt.search(ds.queries, preds, k=10)
    rt.invalidate_cache()
    r = rt.search(ds.queries, preds, k=10)
    assert r.trace.dre.derived_hits == 0
    assert all(n.setup_s > 0 for n in r.trace.nodes if n.kind == "qp")
    r2 = rt.search(ds.queries, preds, k=10)
    assert r2.trace.dre.derived_hits == r2.trace.invocations("qp") > 0


def test_service_cache_config_and_invalidation_on_rebuild(built):
    """Service-level wiring: ServiceConfig(cache_enabled=True) reaches the
    runtime, and swap_index invalidates so a rebuilt index can never serve
    stale cached neighbors."""
    from repro.serve.vector_service import ServiceConfig, VectorSearchService

    ds, preds, index = built
    svc = VectorSearchService(index, ServiceConfig(
        backend="serverless", cache_enabled=True))
    svc.query(ds.queries, preds, k=10)
    ids_a, _, _ = svc.query(ds.queries, preds, k=10)
    assert svc.last_trace.cache_hits == ds.queries.shape[0]
    assert svc.result_cache is not None and svc.result_cache.hits > 0

    # rebuild the index on perturbed vectors → same queries, new neighbors
    cfg = SquashConfig(num_partitions=5, kmeans_iters=4, lloyd_iters=6)
    rebuilt = SquashIndex.build(ds.vectors[::-1].copy(), ds.attributes,
                                cfg, seed=11)
    svc.swap_index(rebuilt)
    ids_b, _, _ = svc.query(ds.queries, preds, k=10)
    assert svc.last_trace.cache_hits == 0, "stale cache served after rebuild"
    ids_j, _, _ = rebuilt.search(ds.queries, preds, k=10, backend="jax")
    np.testing.assert_array_equal(ids_b, ids_j)


def test_cache_with_payload_chunking(built):
    """Cache split composes with the chunk overflow policy: a chunked CO
    request still serves hits per chunk and stays bitwise-correct."""
    ds, preds, index = built
    on = _runtime(index, cache_enabled=True, max_payload_bytes=4096,
                  overflow="chunk")
    r1 = on.search(ds.queries, preds, k=10)
    r2 = on.search(ds.queries, preds, k=10)
    ids_j, _, _ = index.search(ds.queries, preds, k=10, backend="jax")
    np.testing.assert_array_equal(r1.ids, ids_j)
    np.testing.assert_array_equal(r2.ids, ids_j)
    assert r2.trace.cache_hits == ds.queries.shape[0]
    assert r2.trace.invocations("qp") == 0
