"""Attribute quantization + filter mask tests (paper §2.3, Fig. 4)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import attributes as am


def _uniform_attrs(n=5000, a=4, card=16, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, card, size=(n, a)).astype(np.float64)


def test_paper_example_lt():
    """§2.3.1: V = [0,5,10,15,20], a0 < 15 ⇒ R = [1,1,1,0,0]."""
    attrs = np.repeat(np.array([2.5, 7.5, 12.5, 17.5]), 50)[:, None]
    idx = am.build_attribute_index(attrs, bits=[2])
    pred = am.Predicate(attr=0, op="<", lo=15.0)
    r = am.build_r_lookup(idx, [pred])
    assert r[:4, 0].tolist() == [1, 1, 1, 0]


def test_filter_mask_exact_vs_ground_truth():
    attrs = _uniform_attrs()
    idx = am.build_attribute_index(attrs)
    preds = [
        am.Predicate(attr=0, op="<=", lo=7.0),
        am.Predicate(attr=1, op="B", lo=4.0, hi=11.0),
        am.Predicate(attr=2, op=">", lo=2.0),
        am.Predicate(attr=3, op="=", lo=5.0),
    ]
    r = am.build_r_lookup(idx, preds)
    f = np.asarray(am.filter_mask(r, idx.codes))
    gt = am.ground_truth_mask(attrs, preds)
    np.testing.assert_array_equal(f, gt)


def test_in_operator_categorical():
    attrs = _uniform_attrs(card=8, a=1, seed=3)
    idx = am.build_attribute_index(attrs)
    pred = am.Predicate(attr=0, op="IN", values=(1.0, 3.0, 6.0))
    r = am.build_r_lookup(idx, [pred])
    f = np.asarray(am.filter_mask(r, idx.codes))
    gt = am.ground_truth_mask(attrs, [pred])
    np.testing.assert_array_equal(f, gt)


def test_no_predicates_passes_everything():
    attrs = _uniform_attrs(n=100)
    idx = am.build_attribute_index(attrs)
    r = am.build_r_lookup(idx, [])
    f = np.asarray(am.filter_mask(r, idx.codes))
    assert f.all()


def test_unfiltered_attribute_not_constrained():
    attrs = _uniform_attrs(n=2000, a=3)
    idx = am.build_attribute_index(attrs)
    preds = [am.Predicate(attr=1, op=">=", lo=8.0)]
    r = am.build_r_lookup(idx, preds)
    f = np.asarray(am.filter_mask(r, idx.codes))
    gt = am.ground_truth_mask(attrs, preds)
    np.testing.assert_array_equal(f, gt)


@given(
    seed=st.integers(0, 2**31 - 1),
    card=st.integers(4, 32),
    op=st.sampled_from(["<", "<=", "=", ">", ">=", "B"]),
)
@settings(max_examples=30, deadline=None)
def test_filter_equals_raw_semantics_property(seed, card, op):
    """With one cell per distinct value, quantized filtering is exact."""
    rng = np.random.default_rng(seed)
    attrs = rng.integers(0, card, size=(1000, 2)).astype(np.float64)
    idx = am.build_attribute_index(attrs)
    lo = float(rng.integers(0, card))
    hi = float(min(card - 1, lo + rng.integers(0, card)))
    pred = am.Predicate(attr=0, op=op, lo=lo, hi=hi)
    r = am.build_r_lookup(idx, [pred])
    f = np.asarray(am.filter_mask(r, idx.codes))
    gt = am.ground_truth_mask(attrs, [pred])
    np.testing.assert_array_equal(f, gt)


def test_empty_predicate_list_edge_case():
    """No predicates: R is all-ones over valid cells, selectivity 1.0."""
    attrs = _uniform_attrs(n=500, a=2)
    idx = am.build_attribute_index(attrs)
    r = am.build_r_lookup(idx, [])
    for a in range(idx.num_attributes):
        k = int(idx.cells[a])
        assert r[:k, a].all() and not r[k:, a].any()
    assert am.predicate_selectivity(attrs, []) == 1.0
    assert am.ground_truth_mask(attrs, []).all()


def test_in_with_single_value_equals_equality():
    attrs = _uniform_attrs(n=3000, a=1, card=8, seed=11)
    idx = am.build_attribute_index(attrs)
    p_in = am.Predicate(attr=0, op="IN", values=(5.0,))
    p_eq = am.Predicate(attr=0, op="=", lo=5.0)
    f_in = np.asarray(am.filter_mask(am.build_r_lookup(idx, [p_in]), idx.codes))
    f_eq = np.asarray(am.filter_mask(am.build_r_lookup(idx, [p_eq]), idx.codes))
    np.testing.assert_array_equal(f_in, f_eq)
    assert f_in.sum() > 0, "degenerate test: value 5 never drawn"


def test_between_with_lo_equals_hi():
    attrs = _uniform_attrs(n=3000, a=1, card=8, seed=12)
    idx = am.build_attribute_index(attrs)
    p_b = am.Predicate(attr=0, op="B", lo=3.0, hi=3.0)
    p_eq = am.Predicate(attr=0, op="=", lo=3.0)
    f_b = np.asarray(am.filter_mask(am.build_r_lookup(idx, [p_b]), idx.codes))
    f_eq = np.asarray(am.filter_mask(am.build_r_lookup(idx, [p_eq]), idx.codes))
    np.testing.assert_array_equal(f_b, f_eq)
    gt = am.ground_truth_mask(attrs, [p_b])
    np.testing.assert_array_equal(f_b, gt)
    # inverted bounds pass nothing
    p_inv = am.Predicate(attr=0, op="B", lo=4.0, hi=2.0)
    f_inv = np.asarray(am.filter_mask(am.build_r_lookup(idx, [p_inv]),
                                      idx.codes))
    assert not f_inv.any()


def test_disjunct_group_or_combination():
    """Predicates sharing a group id OR together; groups AND with the rest."""
    attrs = _uniform_attrs(n=5000, a=2, card=16, seed=13)
    idx = am.build_attribute_index(attrs)
    preds = [
        am.Predicate(attr=0, op="<", lo=3.0, group=0),
        am.Predicate(attr=0, op=">", lo=12.0, group=0),
        am.Predicate(attr=1, op="B", lo=4.0, hi=11.0),
    ]
    r = am.build_r_lookup(idx, preds)
    f = np.asarray(am.filter_mask(r, idx.codes))
    raw = ((attrs[:, 0] < 3.0) | (attrs[:, 0] > 12.0)) & \
        (attrs[:, 1] >= 4.0) & (attrs[:, 1] <= 11.0)
    np.testing.assert_array_equal(f, raw)
    assert 0 < f.sum() < attrs.shape[0]
    np.testing.assert_array_equal(am.ground_truth_mask(attrs, preds), raw)
    sel = am.predicate_selectivity(attrs, preds)
    assert sel == pytest.approx(raw.mean())


def test_disjunct_group_cross_attribute_rejected():
    attrs = _uniform_attrs(n=200, a=2)
    idx = am.build_attribute_index(attrs)
    preds = [am.Predicate(attr=0, op="<", lo=3.0, group=1),
             am.Predicate(attr=1, op=">", lo=12.0, group=1)]
    with pytest.raises(ValueError, match="spans attributes"):
        am.build_r_lookup(idx, preds)
    with pytest.raises(ValueError, match="spans attributes"):
        am.ground_truth_mask(attrs, preds)


def test_selectivity_targeting():
    from repro.data.synthetic import default_predicates

    attrs = _uniform_attrs(n=50_000, a=4, card=16, seed=9)
    preds = default_predicates(attr_cardinality=16, num_attributes=4)
    sel = am.predicate_selectivity(attrs, preds)
    assert 0.03 < sel < 0.16, f"joint selectivity {sel} should be ≈8%"
