"""End-to-end system tests: full pipeline vs distributed path, sharding rules,
and the launch-layer spec builders (no 512-device init here — that's the
dry-run's job; spec/rule logic is tested pure)."""

import dataclasses
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, get_config
from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data.synthetic import (default_predicates, ground_truth,
                                  make_vector_dataset)
from repro.launch import shardings as SH
from repro.models import transformer as T


# ----------------------------------------------------- end-to-end vs oracle

def test_full_system_recall_and_stage_accounting():
    ds = make_vector_dataset("sift1m", scale=0.005, num_queries=16, seed=3)
    preds = default_predicates(ds.attr_cardinality)
    idx = SquashIndex.build(ds.vectors, ds.attributes,
                            SquashConfig(num_partitions=6))
    ids, dists, stats = idx.search(ds.queries, preds, k=10,
                                   collect_stats=True)
    gt_ids, gt_d = ground_truth(ds, preds, k=10)
    hits = sum(len(set(ids[i]) & set(gt_ids[i])) for i in range(len(ids)))
    assert hits / gt_ids.size >= 0.9
    # every returned id satisfies the predicate (paper's hard guarantee)
    for row in ids:
        for vid in row:
            if vid >= 0:
                assert all(p.eval(np.asarray([ds.attributes[vid, p.attr]]))[0]
                           for p in preds)
    # stage monotonicity: filter ∩ → hamming prune → adc → refine
    assert stats.hamming_kept <= stats.hamming_in
    assert stats.refined <= stats.adc_evals


def test_distributed_matches_single_host_pipeline():
    from repro.core.distributed import distributed_search
    ds = make_vector_dataset("sift1m", scale=0.004, num_queries=8, seed=5)
    preds = default_predicates(ds.attr_cardinality)
    idx = SquashIndex.build(ds.vectors, ds.attributes,
                            SquashConfig(num_partitions=4))
    ids_ref, d_ref, _ = idx.search(ds.queries, preds, k=5)
    ids_dist, d_dist = distributed_search(idx, ds.queries, preds, k=5)
    # same neighbor sets (order ties can differ at equal distance)
    for a, b in zip(ids_ref, ids_dist):
        assert set(a.tolist()) == set(b.tolist())
    np.testing.assert_allclose(d_ref, d_dist, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- sharding rules

FAKE_MESH = SimpleNamespace(shape={"data": 16, "model": 16})


def test_fit_spec_drops_nondivisible_axes():
    assert SH.fit_spec(P("model", None), (50280, 1024), FAKE_MESH) == \
        P(None, None)
    assert SH.fit_spec(P("model", None), (49152, 1024), FAKE_MESH) == \
        P("model", None)
    assert SH.fit_spec(P(("data",), None), (1, 1), FAKE_MESH) == P(None, None)
    assert SH.fit_spec(P("data", "model"), (256, 4096), FAKE_MESH) == \
        P("data", "model")


def test_param_pspec_rules():
    mk = lambda *names: [SimpleNamespace(key=n) for n in names]
    leaf2 = SimpleNamespace(ndim=2, shape=(4096, 4096))
    leaf3 = SimpleNamespace(ndim=3, shape=(32, 4096, 4096))
    leafE = SimpleNamespace(ndim=4, shape=(32, 64, 2048, 1408))
    assert SH.param_pspec(mk("blocks", "attn", "wq", "w"), leaf3) == \
        P(None, "data", "model")
    assert SH.param_pspec(mk("blocks", "attn", "wo", "w"), leaf3) == \
        P(None, "model", "data")
    assert SH.param_pspec(mk("blocks", "ffn", "experts", "gate"), leafE) == \
        P(None, "model", "data", None)
    assert SH.param_pspec(mk("embed", "table"), leaf2) == P("model", None)
    leaf1 = SimpleNamespace(ndim=1, shape=(4096,))
    assert SH.param_pspec(mk("final_norm", "scale"), leaf1) == P()


def test_every_arch_param_tree_has_valid_specs():
    """Rule fn must produce specs whose sharded dims divide under the
    production mesh after fit_spec, for every architecture."""
    for name in ["llama3-8b", "arctic-480b", "mamba2-370m", "gemma3-4b",
                 "zamba2-7b", "deepseek-v2-lite-16b", "musicgen-large"]:
        cfg = get_config(name)
        sds = jax.eval_shape(
            lambda k: T.init_params(k, cfg, dtype=jnp.bfloat16),
            jax.random.PRNGKey(0))
        flat = jax.tree_util.tree_flatten_with_path(sds)[0]
        for path, leaf in flat:
            spec = SH.fit_spec(SH.param_pspec(path, leaf), leaf.shape,
                               FAKE_MESH)
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = int(np.prod([FAKE_MESH.shape[a] for a in axes]))
                assert leaf.shape[i] % size == 0, (name, path, leaf.shape)


# --------------------------------------------------------- input spec logic

def test_input_specs_shapes():
    from repro.launch.dryrun import arch_for_shape, input_specs
    cfg = get_config("llama3-8b")
    sp = input_specs(cfg, INPUT_SHAPES["train_4k"])
    assert sp["batch"]["tokens"].shape == (256, 4097)
    sp = input_specs(cfg, INPUT_SHAPES["decode_32k"])
    assert sp["tokens"].shape == (128, 1)
    # cache holds full seq_len buffers per layer
    kv_leaves = [l for l in jax.tree_util.tree_leaves(sp["caches"])
                 if l.ndim == 5]
    assert all(l.shape[2] == 32768 for l in kv_leaves)
    # audio tokens carry the codebook axis
    mg = get_config("musicgen-large")
    sp = input_specs(mg, INPUT_SHAPES["prefill_32k"])
    assert sp["tokens"].shape == (32, 4, 32768)


def test_long_500k_window_variant_for_full_attention():
    from repro.launch.dryrun import arch_for_shape
    cfg = arch_for_shape("llama3-8b", INPUT_SHAPES["long_500k"])
    assert cfg.attention == "sliding" and cfg.sliding_window == 8192
    cfg = arch_for_shape("mamba2-370m", INPUT_SHAPES["long_500k"])
    assert cfg.attention != "sliding"          # native recurrent decode
    cfg = arch_for_shape("gemma3-4b", INPUT_SHAPES["long_500k"])
    assert cfg.attention == "local_global"     # native 5:1 pattern
    # decode-cache memory stays bounded for the window variant
    win_cfg = arch_for_shape("llama3-8b", INPUT_SHAPES["long_500k"])
    caches = jax.eval_shape(
        lambda: T.init_decode_caches(win_cfg, 1, 524288, dtype=jnp.bfloat16))
    total = sum(np.prod(l.shape) * 2 for l in
                jax.tree_util.tree_leaves(caches))
    assert total < 5e9, "windowed long-context cache must be ≪ full cache"
