"""Partition selection (Alg. 1, Eq. 1), ADC tables, low-bit Hamming tests."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import adc, lowbit, osq, partitions


# ----------------------------------------------------------------- partitions

def test_balanced_kmeans_balance():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(4000, 16))
    cent, assign = partitions.balanced_kmeans(x, 8, iters=5)
    counts = np.bincount(assign, minlength=8)
    assert counts.max() <= int(np.ceil(1.05 * 4000 / 8))
    assert counts.min() > 0
    assert cent.shape == (8, 16)


def test_threshold_formula():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(2000, 32))
    cent, assign = partitions.balanced_kmeans(x, 4, iters=4)
    t = partitions.compute_threshold(x, cent, assign, beta=0.001)
    # T = 1 + σ_µ/µ_µ + β√d  — must exceed 1 and stay sane.
    assert 1.0 < t < 3.0


def test_select_partitions_guarantee():
    """Alg. 1 guarantee: if ≥k filtered vectors exist globally, the visited
    partitions cover ≥k of them; every centroid within T·d_min is visited."""
    rng = np.random.default_rng(2)
    x = rng.normal(size=(3000, 8))
    cent, assign = partitions.balanced_kmeans(x, 6, iters=4)
    q = rng.normal(size=(5, 8))
    f = rng.random((5, 3000)) < 0.05
    k = 10
    visit, cands = partitions.select_partitions(q, cent, f, assign, 1.2, k)
    for qi in range(5):
        total = sum(v.size for v in cands[qi].values())
        assert total >= min(k, int(f[qi].sum()))
        # Threshold condition: all partitions within T·dmin visited
        # (unless they hold no candidates).
        d = np.sqrt(((q[qi][None, :] - cent) ** 2).sum(-1))
        dmin = d.min()
        for pid in range(6):
            if d[pid] <= 1.2 * dmin:
                has_cand = (f[qi] & (assign == pid)).any()
                assert visit[qi, pid] == bool(has_cand)


def test_select_partitions_empty_filter():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(500, 4))
    cent, assign = partitions.balanced_kmeans(x, 3, iters=3)
    q = rng.normal(size=(2, 4))
    f = np.zeros((2, 500), dtype=bool)
    visit, cands = partitions.select_partitions(q, cent, f, assign, 1.2, 5)
    assert not visit.any()
    assert all(not c for c in cands)


def test_local_candidate_indices_are_local():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(1000, 4))
    cent, assign = partitions.balanced_kmeans(x, 4, iters=3)
    q = rng.normal(size=(1, 4))
    f = np.ones((1, 1000), dtype=bool)
    visit, cands = partitions.select_partitions(q, cent, f, assign, 10.0, 5)
    for pid, rows in cands[0].items():
        n_local = int((assign == pid).sum())
        assert rows.max() < n_local
        assert rows.min() >= 0
        assert np.unique(rows).size == rows.size


# ------------------------------------------------------------------------ ADC

def _quantize(x, per_dim=4):
    bits = np.full(x.shape[1], per_dim, dtype=np.int32)
    q = osq.design_quantizers(x, bits)
    return q, osq.encode(q, x)


def test_adc_is_lower_bound():
    """LB(q, v) ≤ ||q − v|| for every vector — the VA-file invariant."""
    rng = np.random.default_rng(5)
    x = rng.normal(size=(2000, 12))
    q_obj, codes = _quantize(x)
    for qi in range(5):
        qv = rng.normal(size=12)
        table = adc.build_adc_table(qv, q_obj.boundaries, q_obj.cells)
        lb = np.asarray(adc.lb_distances(table, codes))
        exact = np.sqrt(((x - qv[None, :]) ** 2).sum(axis=1))
        assert np.all(lb <= exact + 1e-4), (lb - exact).max()


def test_adc_zero_for_own_cell():
    rng = np.random.default_rng(6)
    x = rng.normal(size=(1000, 6))
    q_obj, codes = _quantize(x)
    # Query = an existing data point ⇒ LB to itself must be 0.
    table = adc.build_adc_table(x[42], q_obj.boundaries, q_obj.cells)
    lb = np.asarray(adc.lb_distances(table, codes[42:43]))
    assert lb[0] == 0.0


def test_adc_onehot_matches_gather():
    rng = np.random.default_rng(7)
    x = rng.normal(size=(512, 9))
    q_obj, codes = _quantize(x, per_dim=3)
    qv = rng.normal(size=9)
    table = adc.build_adc_table(qv, q_obj.boundaries, q_obj.cells)
    a = np.asarray(adc.lb_distances(table, codes))
    b = np.asarray(adc.lb_distances_onehot(table, codes))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_adc_table_cost():
    """Paper: building L needs only (Σ C[j]) − 1 distance computations — i.e.
    the table has Σ C[j] meaningful entries; padding must be inf."""
    rng = np.random.default_rng(8)
    x = rng.normal(size=(500, 4))
    bits = np.array([2, 3, 1, 4], dtype=np.int32)
    q_obj = osq.design_quantizers(x, bits)
    table = adc.build_adc_table(rng.normal(size=4), q_obj.boundaries, q_obj.cells)
    finite = np.isfinite(table).sum()
    assert finite == q_obj.cells.sum()


# --------------------------------------------------------------------- lowbit

def test_hamming_matches_bit_count():
    rng = np.random.default_rng(9)
    bits_a = rng.integers(0, 2, size=(64,))
    bits_b = rng.integers(0, 2, size=(20, 64))
    pa = lowbit.pack_bits_u32(bits_a[None, :])[0]
    pb = lowbit.pack_bits_u32(bits_b)
    d = np.asarray(lowbit.hamming_distances(pa, pb))
    expect = (bits_a[None, :] != bits_b).sum(axis=1)
    np.testing.assert_array_equal(d, expect)


@given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 200))
@settings(max_examples=25, deadline=None)
def test_hamming_property(seed, d):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2, size=(d,))
    b = rng.integers(0, 2, size=(7, d))
    pa = lowbit.pack_bits_u32(a[None, :])[0]
    pb = lowbit.pack_bits_u32(b)
    got = np.asarray(lowbit.hamming_distances(pa, pb))
    np.testing.assert_array_equal(got, (a[None, :] != b).sum(axis=1))


def test_hamming_prune_retains_true_neighbors():
    """§2.4.3's enabling observation, tested as the pipeline uses it: on
    clustered data, the true Euclidean top-k survives a 10 % Hamming cut."""
    rng = np.random.default_rng(10)
    centers = rng.normal(0, 10, size=(16, 128))
    which = rng.integers(0, 16, size=2000)
    x = centers[which] + rng.normal(size=(2000, 128))
    idx = lowbit.build_lowbit_index(x)
    survived = []
    for qi in range(10):
        q = centers[rng.integers(0, 16)] + rng.normal(size=128)
        qp = idx.encode_queries(q[None, :])[0]
        ham = np.asarray(lowbit.hamming_distances(qp, idx.packed)).astype(float)
        eu = np.sqrt(((x - q[None, :]) ** 2).sum(axis=1))
        top10 = np.argsort(eu)[:10]
        cut = np.percentile(ham, 10.0)
        survived.append((ham[top10] <= cut).mean())
    assert np.mean(survived) > 0.8, f"Hamming cut loses neighbors: {survived}"


def test_hamming_prune_keeps_best():
    rng = np.random.default_rng(11)
    x = rng.normal(size=(500, 64))
    idx = lowbit.build_lowbit_index(x)
    q = rng.normal(size=64)
    qp = idx.encode_queries(q[None, :])[0]
    mask = np.ones(500, dtype=np.int32)
    kept_idx, kept_d = lowbit.hamming_prune(qp, idx.packed, mask, keep=50)
    all_d = np.asarray(lowbit.hamming_distances(qp, idx.packed))
    assert np.asarray(kept_d).max() <= np.partition(all_d, 50)[50:].min() + 1
