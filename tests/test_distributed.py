"""Distributed (shard_map) search tests — single device + 8-device subprocess."""

import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.pipeline import SquashConfig, SquashIndex
from repro.core import distributed
from repro.data import synthetic


@pytest.fixture(scope="module")
def built():
    ds = synthetic.make_vector_dataset("deep10m", scale=0.002, num_queries=20, seed=3)
    preds = synthetic.default_predicates()
    cfg = SquashConfig(num_partitions=8, kmeans_iters=5, lloyd_iters=8)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=3)
    return ds, preds, index


def test_distributed_matches_reference(built):
    """shard_map engine ≡ single-host reference pipeline (same stages)."""
    ds, preds, index = built
    ref_ids, ref_d, _ = index.search(ds.queries, preds, k=10)
    got_ids, got_d = distributed.distributed_search(index, ds.queries, preds, k=10)
    # Distances must agree; ids may swap under exact ties.
    for qi in range(ds.queries.shape[0]):
        rd = ref_d[qi][ref_ids[qi] >= 0]
        gd = got_d[qi][got_ids[qi] >= 0][: rd.size]
        np.testing.assert_allclose(gd, rd, rtol=1e-4, atol=1e-4)
    overlap = np.mean([
        len(set(ref_ids[q].tolist()) & set(got_ids[q].tolist())) / 10
        for q in range(ds.queries.shape[0])
    ])
    assert overlap >= 0.95


def test_distributed_recall(built):
    ds, preds, index = built
    gt_ids, _ = synthetic.ground_truth(ds, preds, k=10)
    got_ids, _ = distributed.distributed_search(index, ds.queries, preds, k=10)
    recalls = []
    for qi in range(ds.queries.shape[0]):
        g = set(gt_ids[qi][gt_ids[qi] >= 0].tolist())
        if g:
            recalls.append(len(g & set(got_ids[qi].tolist())) / len(g))
    assert np.mean(recalls) >= 0.9, np.mean(recalls)


def test_stacked_index_roundtrip(built):
    _, _, index = built
    st = distributed.stack_index(index, pad_to_multiple=4)
    assert st.num_partitions % 4 == 0
    total_valid = int(np.asarray(st.valid).sum())
    assert total_valid == sum(p.size for p in index.parts)
    ids = np.asarray(st.vector_ids)[np.asarray(st.valid)]
    assert np.unique(ids).size == total_valid


_MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core.pipeline import SquashConfig, SquashIndex
    from repro.core import distributed
    from repro.data import synthetic

    ds = synthetic.make_vector_dataset("deep10m", scale=0.002, num_queries=8, seed=3)
    preds = synthetic.default_predicates()
    cfg = SquashConfig(num_partitions=8, kmeans_iters=5, lloyd_iters=8)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=3)

    ref_ids, ref_d, _ = index.search(ds.queries, preds, k=10)
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    got_ids, got_d = distributed.distributed_search(
        index, ds.queries, preds, k=10, mesh=mesh)
    for qi in range(8):
        rd = ref_d[qi][ref_ids[qi] >= 0]
        gd = got_d[qi][got_ids[qi] >= 0][: rd.size]
        np.testing.assert_allclose(gd, rd, rtol=1e-4, atol=1e-4)
    print("MULTIDEV_OK")
    """
)


def test_eight_device_mesh_equivalence():
    """2×4 (data×model) host-device mesh reproduces the reference results."""
    proc = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    assert "MULTIDEV_OK" in proc.stdout, proc.stderr[-3000:]
