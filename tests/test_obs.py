"""Tier-1 observability tests: metrics, spans, exporters, parity (local).

Pins the PR-7 contracts that don't need real worker processes:

* Histogram quantile *exactness* on hand-computable distributions
  (Prometheus-style interpolation inside the containing bucket, clamp at
  the last finite bound for +inf mass).
* The disabled registry hands out one shared no-op singleton — enabling
  is what turns call sites into real instruments.
* Span-context wire round-trip through the transport ``extra`` envelope
  (``payload.inject_span_context`` / ``extract_span_context``) and the
  invariant that injection never touches the budgeted payload bytes.
* Exporters: in-memory, JSONL append + ``read_jsonl``, timeline render.
* ``NodeTrace``/``RunTrace`` JSON round-trip on a real (tiny) run.
* Local-transport bitwise parity: ids and ``SearchStats`` identical with
  observability off and on, and the obs-on run yields a stitched span
  tree with all three node kinds.
* ``safe_ratio`` guard for modeled-vs-measured ratios.

The real-transport parity/stitching/crash-counter tests live in
``tests/test_obs_transport.py`` (transport tier).
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.obs.export import InMemoryExporter, JsonlExporter, read_jsonl, \
    run_record
from repro.obs.metrics import DEFAULT_BYTES_BUCKETS, \
    DEFAULT_LATENCY_BUCKETS, Histogram, MetricsRegistry, REGISTRY, _NULL
from repro.obs.spans import Recorder, Span, SpanContext
from repro.obs.timeline import render_record, render_records
from repro.serverless import payload as pl
from repro.serverless.runtime import RuntimeConfig, ServerlessRuntime
from repro.serverless.traces import RunTrace


# --------------------------------------------------------------- histograms


def test_histogram_quantiles_exact():
    h = Histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 3.5):
        h.observe(v)
    # counts: (0,1]=1, (1,2]=1, (2,4]=2; interpolation is exact here.
    assert h.count == 4
    assert h.quantile(0.50) == pytest.approx(2.0)
    assert h.quantile(0.75) == pytest.approx(3.0)
    assert h.quantile(0.0) is not None
    assert h.quantile(1.0) == pytest.approx(4.0)


def test_histogram_overflow_clamps_to_last_bound():
    h = Histogram("h", buckets=(1.0, 2.0, 4.0))
    h.observe(0.5)
    h.observe(100.0)                       # lands in the +inf bucket
    assert h.bucket_counts()["+inf"] == 1
    # quantiles never extrapolate past the last finite bound
    assert h.quantile(1.0) == pytest.approx(4.0)


def test_histogram_empty_and_validation():
    h = Histogram("h", buckets=(1.0, 2.0))
    assert h.quantile(0.5) is None
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(2.0, 1.0))


def test_default_buckets_shape():
    assert DEFAULT_LATENCY_BUCKETS == tuple(sorted(DEFAULT_LATENCY_BUCKETS))
    assert DEFAULT_LATENCY_BUCKETS[-2:] == (30.0, 60.0)
    assert DEFAULT_BYTES_BUCKETS[0] == 64.0
    # 6 MB Lambda payload budget sits inside the covered range
    assert DEFAULT_BYTES_BUCKETS[-1] >= 6 * 2**20


# ----------------------------------------------------------------- registry


def test_disabled_registry_is_noop_singleton():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("x")
    assert c is _NULL
    assert c is reg.histogram("y") is reg.gauge("z")
    c.inc(10)
    assert c.value == 0 and c.count == 0 and c.sum == 0.0
    assert c.quantile(0.99) == 0.0
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}


def test_enabled_registry_real_instruments_and_snapshot():
    reg = MetricsRegistry(enabled=True)
    reg.counter("a.b").inc()
    reg.counter("a.b").inc(2)
    reg.gauge("g").set(7.5)
    reg.histogram("h", buckets=(1.0, 2.0)).observe(0.5)
    snap = reg.snapshot()
    assert snap["counters"]["a.b"] == 3
    assert snap["gauges"]["g"] == 7.5
    assert snap["histograms"]["h"]["count"] == 1
    json.dumps(snap)                       # snapshot must be JSON-able
    reg.reset()
    assert reg.snapshot()["counters"] == {}


def test_global_registry_disabled_by_default():
    assert REGISTRY.enabled is False
    assert REGISTRY.counter("anything") is _NULL


# -------------------------------------------------------------------- spans


def test_span_json_round_trip():
    s = Span(name="qp:0", span_id="s3", parent_id="s1", t0=0.5, t1=1.25,
             attrs={"kind": "qp", "chunk": 0})
    assert Span.from_json(s.to_json()) == s


def test_recorder_ids_and_children():
    rec = Recorder(run_id="r1")
    root = rec.record("search", 0.0, 1.0)
    sid = rec.new_span_id()
    rec.record("qa:0", 0.1, 0.9, span_id=sid, parent_id=root, kind="qa")
    assert {s.span_id for s in rec.spans} == {root, sid}
    assert [s.name for s in rec.children(root)] == ["qa:0"]
    assert rec.by_name("search")[0].parent_id is None


def test_span_context_wire_round_trip_via_envelope():
    ctx = Recorder(run_id="abc").context("s7")
    extra = {"olo": 0, "ohi": 4}
    out = pl.inject_span_context(extra, ctx.to_wire())
    assert out is extra                       # in-place, same envelope dict
    assert pl.extract_span_context(extra) == {"run": "abc", "span": "s7"}
    assert SpanContext.from_wire(pl.extract_span_context(extra)) == \
        SpanContext("abc", "s7")
    # absent / falsy context leaves the envelope untouched
    clean = {"olo": 0}
    assert pl.inject_span_context(clean, None) == {"olo": 0}
    assert pl.extract_span_context(clean) is None


# ---------------------------------------------------------------- exporters


def test_exporters_and_read_jsonl(tmp_path):
    rec = Recorder(run_id="runA")
    rec.record("search", 0.0, 1.0)
    record = run_record(rec, meta={"transport": "local"})
    mem = InMemoryExporter()
    mem.export(record)
    assert mem.records == [record]

    path = str(tmp_path / "trace.jsonl")
    jl = JsonlExporter(path)
    jl.export(record)
    jl.export(record)                         # append mode: one line each
    back = read_jsonl(path)
    assert len(back) == 2
    assert back[0]["run"] == "runA"
    assert back[0]["spans"][0]["name"] == "search"
    assert back[0]["meta"] == {"transport": "local"}


def test_timeline_renders_record():
    rec = Recorder(run_id="runB")
    root = rec.record("search", 0.0, 2.0, transport="local")
    sid = rec.new_span_id()
    rec.record("qp:0", 0.2, 1.8, span_id=sid, parent_id=root, kind="qp",
               warm=True, retries=0)
    rec.record("compute", 0.3, 1.5, parent_id=sid, phase=True)
    text = render_record(run_record(rec, meta={"transport": "local"}))
    assert "runB" in text and "qp:0" in text
    assert render_records([run_record(rec)])  # multi-record wrapper works


# ------------------------------------------------------- trace JSON + parity


def _tiny_runtime(**overrides):
    from benchmarks.common import build_tiny_squash_index

    ds, preds, idx = build_tiny_squash_index(
        scale=0.003, num_queries=8, num_partitions=3, seed=7)
    cfg = RuntimeConfig(branching=2, max_level=1, **overrides)
    return ds, preds, ServerlessRuntime(idx, cfg)


def test_run_trace_json_round_trip():
    ds, preds, rt = _tiny_runtime()
    trace = rt.search(ds.queries, preds, k=10).trace
    blob = json.dumps(trace.to_json())        # must be pure-JSON already
    back = RunTrace.from_json(json.loads(blob))
    assert back.makespan_s == trace.makespan_s
    assert len(back.nodes) == len(trace.nodes)
    assert [n.node for n in back.nodes] == [n.node for n in trace.nodes]
    assert back.nodes[0].t_start == trace.nodes[0].t_start
    assert back.stats == trace.stats
    assert back.fleet == trace.fleet
    assert back.to_json() == trace.to_json()


def test_local_obs_parity_and_span_tree():
    # Pinned busy times: the modeled timeline then depends only on the
    # choreography, so the off/on makespans must match bitwise.
    pinned = dict(qa_compute_s=0.05, qp_compute_s=0.05, co_compute_s=0.01)
    ds, preds, rt_off = _tiny_runtime(**pinned)
    rt_off.search(ds.queries, preds, k=10)        # warm the global DRE pool
    r_off = rt_off.search(ds.queries, preds, k=10)
    try:
        ds2, preds2, rt_on = _tiny_runtime(obs_enabled=True, **pinned)
        rt_on.search(ds2.queries, preds2, k=10)
        r_on = rt_on.search(ds2.queries, preds2, k=10)

        # bitwise parity: observability must not perturb results, stats or
        # the modeled timeline (both are warm waves over the shared pool)
        np.testing.assert_array_equal(r_off.ids, r_on.ids)
        assert r_off.stats == r_on.stats
        assert r_off.trace.makespan_s == r_on.trace.makespan_s

        records = rt_on.obs_exporter.records
        assert len(records) == 2              # one record per search
        spans = records[-1]["spans"]
        kinds = {s["attrs"].get("kind") for s in spans} - {None}
        assert kinds == {"co", "qa", "qp"}
        # every parent id resolves inside the same record
        ids = {s["id"] for s in spans}
        assert all(s["parent"] in ids for s in spans
                   if s["parent"] is not None)
        # local transport synthesizes a compute worker sub-span
        assert any(s["name"] == "worker.compute" for s in spans)
        # metrics flowed through the (now enabled) global registry
        snap = REGISTRY.snapshot()
        assert snap["counters"].get("transport.local.submits", 0) >= 1
        assert snap["counters"].get("dre.pool.leases", 0) >= 1
    finally:
        REGISTRY.disable()
        REGISTRY.reset()


def test_obs_exporter_none_when_disabled():
    _, _, rt = _tiny_runtime()
    assert rt.obs_exporter is None


# --------------------------------------------------------------- safe_ratio


def test_safe_ratio_guards():
    from benchmarks.common import safe_ratio

    assert safe_ratio(1.0, 2.0) == 0.5
    assert safe_ratio(1.0, 0.0) is None
    assert safe_ratio(1.0, -3.0) is None
    assert safe_ratio(1.0, None) is None
