"""Transport subsystem tests: Local/Process/Socket parity, real concurrency,
worker crash + connection-loss retry, and payload budgets over real process
and TCP boundaries (PR 5/6 acceptance).

Auto-marked ``transport`` (conftest): these tests spawn real worker
processes and TCP host processes, so CI runs them under a hard timeout and
they can be deselected with ``-m "not transport"``.
"""

import os
import signal
import socket
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import SquashConfig, SquashIndex
from repro.data import synthetic
from repro.serverless import (PayloadOverflowError, RuntimeConfig,
                              ServerlessRuntime)
from repro.serverless import nodes as nd
from repro.serverless import payload as pl
from repro.serverless import transport as tp
from repro.serverless import workers as wk


@pytest.fixture(scope="module")
def built():
    ds = synthetic.make_vector_dataset("sift1m", scale=0.003, num_queries=8,
                                       seed=7)
    preds = synthetic.default_predicates(ds.attr_cardinality)
    cfg = SquashConfig(num_partitions=3, kmeans_iters=4, lloyd_iters=6)
    index = SquashIndex.build(ds.vectors, ds.attributes, cfg, seed=7)
    ref = index.search(ds.queries, preds, k=10, backend="jax")
    return ds, preds, index, ref


@pytest.fixture(scope="module")
def process_rt(built):
    """One long-lived ProcessTransport runtime shared by the parity tests
    (worker processes persist across searches — that is the DRE story)."""
    _, _, index, _ = built
    rt = ServerlessRuntime(index, RuntimeConfig(
        branching=2, max_level=1, transport="process", qa_workers=2))
    yield rt
    rt.close()


@pytest.fixture(scope="module")
def socket_rt(built):
    """One long-lived SocketTransport runtime (auto-spawned loopback hosts);
    TCP workers persist across searches like the process pool does."""
    _, _, index, _ = built
    rt = ServerlessRuntime(index, RuntimeConfig(
        branching=2, max_level=1, transport="socket", qa_workers=2))
    yield rt
    rt.close()


def _qa_process_transport(index, **kw):
    """A bare one-worker allocator pool for transport-internals tests."""
    import jax

    init = wk.WorkerInit(
        role="qa", fn="qa", pid=None,
        x64=bool(jax.config.jax_enable_x64),
        platform=os.environ.get("JAX_PLATFORMS", "cpu") or "cpu",
        bundle=wk.build_qa_bundle(index))
    return tp.ProcessTransport({"qa": (init, 1)}, **kw)


def _qa_request(ds, preds):
    """A minimal valid allocator request (one query, own slice [0, 1))."""
    return {
        "qidx": np.asarray([0], dtype=np.int32),
        "queries": ds.queries[:1],
        "preds": pl.predicates_to_json(preds),
        "k": 5,
    }


# ------------------------------------------------------------------- parity

def test_process_transport_bitwise_parity(built, process_rt):
    """Acceptance: ProcessTransport ids/dists/stats are bitwise-identical to
    LocalTransport and to SquashIndex.search(backend='jax'), with payloads
    crossing real process boundaries."""
    ds, preds, index, (ids_j, d_j, s_j) = built
    local = ServerlessRuntime(index, RuntimeConfig(branching=2, max_level=1))
    r_l = local.search(ds.queries, preds, k=10)
    r_p = process_rt.search(ds.queries, preds, k=10)
    for r in (r_l, r_p):
        np.testing.assert_array_equal(r.ids, ids_j)
        fin = np.isfinite(d_j)
        np.testing.assert_array_equal(np.isfinite(r.dists), fin)
        np.testing.assert_array_equal(r.dists[fin], d_j[fin])
        assert r.stats == s_j
    assert r_l.trace.transport == "local"
    assert r_p.trace.transport == "process"
    # the measured clock is real and the handlers ran in other processes
    assert r_p.trace.measured_makespan_s > 0
    worker_pids = {n.worker_pid for n in r_p.trace.nodes
                   if n.kind in ("qa", "qp")}
    assert worker_pids and os.getpid() not in worker_pids
    # modeled §3.5 accounting still assembles under the process transport
    assert r_p.trace.cost["total"] > 0
    assert r_p.trace.invocations("co") == 1


def test_process_transport_real_warm_reuse(built, process_rt):
    """Second batch on live workers: zero state rebuilds, every invocation
    is a real warm start on the same OS pids (DRE keyed to worker pids)."""
    ds, preds, _, (ids_j, _, _) = built
    r1 = process_rt.search(ds.queries, preds, k=10)
    pids1 = {n.node: n.worker_pid for n in r1.trace.nodes if n.kind == "qp"}
    r2 = process_rt.search(ds.queries, preds, k=10)
    np.testing.assert_array_equal(r2.ids, ids_j)
    t = r2.trace
    assert t.dre.s3_gets == 0
    assert t.dre.dre_hits == t.dre.invocations > 0
    qp = [n for n in t.nodes if n.kind == "qp"]
    assert all(n.warm and n.dre_hit and n.fetch_s == 0.0 for n in qp)
    # retention is per-process: the same worker pid serves each partition
    assert {n.node: n.worker_pid for n in qp} == pids1


def test_service_transport_passthrough(built):
    from repro.serve.vector_service import ServiceConfig, VectorSearchService

    _, _, index, _ = built
    svc = VectorSearchService(index, ServiceConfig(
        backend="serverless", transport="process"))
    assert svc.runtime().cfg.transport == "process"  # lazily built, no spawn
    svc.close()
    svc2 = VectorSearchService(index, ServiceConfig(backend="serverless"))
    assert svc2.runtime().cfg.transport == "local"


def test_transport_config_validation():
    with pytest.raises(ValueError, match="transport"):
        RuntimeConfig(transport="bogus")


# -------------------------------------------------------------- concurrency

def test_concurrent_qp_wave_beats_sequential_strawman(built):
    """Acceptance: with real busy handlers, the eager tree launch's measured
    wall-clock beats the sequential strawman's — QPs genuinely execute in
    parallel processes, not as staggered-launch modeling."""
    ds, preds, index, (ids_j, _, _) = built
    sleep = 0.3
    kw = dict(branching=2, max_level=1, transport="process", qa_workers=1,
              worker_sleep_s=sleep)
    tree = ServerlessRuntime(index, RuntimeConfig(**kw))
    seq = ServerlessRuntime(index, RuntimeConfig(sequential=True, **kw))
    try:
        tree.search(ds.queries, preds, k=10)        # cold: build worker state
        seq.search(ds.queries, preds, k=10)
        r_tree = tree.search(ds.queries, preds, k=10)
        r_seq = seq.search(ds.queries, preds, k=10)
    finally:
        tree.close()
        seq.close()
    np.testing.assert_array_equal(r_tree.ids, ids_j)
    np.testing.assert_array_equal(r_seq.ids, ids_j)
    n_qp = r_tree.trace.invocations("qp")
    assert n_qp >= 3
    # sequential pays ~n_qp sleeps serially; the tree overlaps them. Sleeps
    # overlap even on a single-core runner, so the margin is robust.
    assert (r_tree.trace.measured_makespan_s
            < 0.8 * r_seq.trace.measured_makespan_s), (
        f"tree {r_tree.trace.measured_makespan_s:.2f}s not faster than "
        f"sequential {r_seq.trace.measured_makespan_s:.2f}s over {n_qp} QPs")


# -------------------------------------------------------------- fault paths

def test_worker_crash_in_flight_retries_and_recovers(built):
    """Kill a QP worker while its invocation is in flight: the transport
    detects the death, respawns the worker cold, re-sends the invocation,
    and the search still returns bitwise-correct results with the retry
    visible in the trace."""
    ds, preds, index, (ids_j, _, s_j) = built
    rt = ServerlessRuntime(index, RuntimeConfig(
        branching=2, max_level=1, transport="process", qa_workers=1,
        worker_sleep_s=0.6))
    try:
        rt.search(ds.queries, preds, k=10)          # warm the fleet
        pid0 = rt.transport.worker_pids("qp:0")[0]
        killer = threading.Timer(
            0.25, lambda: os.kill(pid0, signal.SIGKILL))
        killer.start()
        r = rt.search(ds.queries, preds, k=10)
        killer.join()
    finally:
        rt.close()
    np.testing.assert_array_equal(r.ids, ids_j)
    assert r.stats == s_j
    assert r.trace.worker_retries >= 1
    qp0 = [n for n in r.trace.nodes if n.node == "qp:0"]
    assert all(n.worker_pid != pid0 for n in qp0), "respawned worker serves"
    assert any(not n.warm for n in qp0), "the replacement starts cold"


def test_worker_killed_while_idle_respawns_cold(built, process_rt):
    """A worker reclaimed between batches (killed while idle) is replaced;
    the next search sees a cold start on that partition but stays correct."""
    ds, preds, _, (ids_j, _, _) = built
    process_rt.search(ds.queries, preds, k=10)
    pid1 = process_rt.transport.worker_pids("qp:1")[0]
    os.kill(pid1, signal.SIGKILL)
    deadline = 50
    while pid1 in process_rt.transport.worker_pids("qp:1") and deadline:
        threading.Event().wait(0.1)      # let the collector notice the death
        deadline -= 1
    r = process_rt.search(ds.queries, preds, k=10)
    np.testing.assert_array_equal(r.ids, ids_j)
    qp1 = [n for n in r.trace.nodes if n.node == "qp:1"]
    assert qp1 and all(n.worker_pid != pid1 for n in qp1)


# --------------------------------------------------- payload budget / wire

def test_payload_chunking_over_the_wire(built):
    """Query-axis chunking composes with the real process boundary: every
    chunk's encoded bytes stay under the budget on the wire, and the merged
    results match the unchunked reference bitwise."""
    ds, preds, index, (ids_j, _, _) = built
    rt = ServerlessRuntime(index, RuntimeConfig(
        branching=2, max_level=1, transport="process", qa_workers=1,
        max_payload_bytes=4096))
    try:
        r = rt.search(ds.queries, preds, k=10)
    finally:
        rt.close()
    np.testing.assert_array_equal(r.ids, ids_j)
    assert all(n.request_bytes <= 4096 for n in r.trace.nodes)
    base = ServerlessRuntime(index, RuntimeConfig(branching=2, max_level=1))
    assert len(r.trace.nodes) > len(
        base.search(ds.queries, preds, k=10).trace.nodes)


def test_row_axis_chunking_single_query_budget(built):
    """ROADMAP known-limit regression: when one query's candidate rows alone
    bust the budget, the QP request chunks on the partition-row axis instead
    of erroring, and the chunk-merged ids equal the unchunked run's."""
    ds, preds, index, (ids_j, _, _) = built
    rt = ServerlessRuntime(index, RuntimeConfig(
        branching=2, max_level=1, max_payload_bytes=1600))
    r = rt.search(ds.queries, preds, k=10)
    np.testing.assert_array_equal(r.ids, ids_j)
    qp = [n for n in r.trace.nodes if n.kind == "qp"]
    assert all(n.request_bytes <= 1600 for n in r.trace.nodes)
    assert max(n.chunk for n in qp) >= 1, (
        "tiny budget must force row-axis chunks")
    base = ServerlessRuntime(index, RuntimeConfig(branching=2, max_level=1))
    r_base = base.search(ds.queries, preds, k=10)
    assert len(qp) > r_base.trace.invocations("qp"), (
        "row chunks must appear as extra QP invocations")


def test_row_split_unit_clamps_budgets():
    req = {
        "pid": 0, "k": 5,
        "qidx": np.asarray([3], np.int32),
        "queries": np.zeros((1, 4)),
        "rows": np.arange(100, dtype=np.int32),
        "row_offsets": np.asarray([0, 100], np.int32),
        "keep": np.asarray([64], np.int32),
        "take": np.asarray([10], np.int32),
    }
    lo = nd.split_processor_rows(req, 0, 50)
    hi = nd.split_processor_rows(req, 50, 100)
    np.testing.assert_array_equal(
        np.concatenate([lo["rows"], hi["rows"]]), req["rows"])
    assert lo["keep"][0] == 50 and hi["keep"][0] == 50   # clamped to chunk
    assert lo["take"][0] == 10
    assert lo["row_offsets"].tolist() == [0, 50]
    with pytest.raises(ValueError):
        nd.split_processor_rows({**req, "qidx": np.asarray([1, 2], np.int32)},
                                0, 1)


def test_chunk_request_falls_back_to_row_axis():
    """payload.chunk_request recurses on the fallback axis only once the
    query axis is exhausted, and still raises when nothing can split."""
    rng = np.random.default_rng(0)
    req = {
        "pid": 0, "k": 5,
        "qidx": np.asarray([0], np.int32),
        "queries": rng.normal(size=(1, 8)),
        "rows": np.arange(4096, dtype=np.int32),
        "row_offsets": np.asarray([0, 4096], np.int32),
        "keep": np.asarray([256], np.int32),
        "take": np.asarray([10], np.int32),
    }
    chunks = pl.chunk_request(
        req, max_bytes=6000, policy="chunk",
        split=nd.split_processor_request,
        num_items=lambda r: r["qidx"].shape[0],
        fallback_split=nd.split_processor_rows,
        fallback_num=lambda r: int(r["rows"].shape[0]))
    assert len(chunks) >= 2
    assert all(len(buf) <= 6000 for _, buf in chunks)
    got = np.concatenate([c["rows"] for c, _ in chunks])
    np.testing.assert_array_equal(np.sort(got), req["rows"])
    with pytest.raises(PayloadOverflowError):
        pl.chunk_request(req, max_bytes=6000, policy="error",
                         split=nd.split_processor_request,
                         num_items=lambda r: r["qidx"].shape[0],
                         fallback_split=nd.split_processor_rows,
                         fallback_num=lambda r: int(r["rows"].shape[0]))
    with pytest.raises(PayloadOverflowError):
        pl.chunk_request(req, max_bytes=64, policy="chunk",
                         split=nd.split_processor_request,
                         num_items=lambda r: r["qidx"].shape[0])


# ------------------------------------------------------- transport primitives

def test_local_transport_inline_contract():
    calls = []

    def handler(fn, req, extra):
        calls.append((fn, extra))
        return {"echo": req["x"] * 2}

    t = tp.LocalTransport({"fn": handler})
    inv = t.submit("fn:7", request={"x": 21}, extra={"a": 1})
    assert not calls, "LocalTransport is lazy: nothing runs before result()"
    resp, info = inv.result()
    assert resp == {"echo": 42}
    assert calls == [("fn:7", {"a": 1})]
    assert info.os_pid == os.getpid() and info.retries == 0
    # payload form decodes through the codec
    inv2 = t.submit("fn", payload=pl.encode_message({"x": 3}))
    assert inv2.result()[0] == {"echo": 6}


# --------------------------------------------- process-transport bookkeeping

def test_timeout_rebalances_worker_counters(built):
    """Satellite regression: a timed-out invocation used to bump
    ``assigned`` forever (the hung worker was shunned by least-loaded
    routing even after recovering), and its late response was double-booked
    into ``done``, driving ``inflight`` negative."""
    ds, preds, index, _ = built
    t = _qa_process_transport(index)
    req = _qa_request(ds, preds)
    extra = {"olo": 0, "ohi": 1}
    try:
        t.invoke("qa", request=req, extra=extra)        # warm the worker
        worker = t._workers["qa"][0]
        t.invoke_timeout_s = 0.4
        inv = t.submit("qa", request=req,
                       extra={**extra, "sleep_s": 1.2})
        with pytest.raises(tp.TransportError, match="timed out"):
            inv.result()
        assert worker.inflight == 0, "timeout must hand back the assignment"
        assert t._timed_out, "in-flight rid parked for the late response"
        # The worker is still sleeping; its late response must be dropped
        # without re-booking ``done``.
        deadline = time.perf_counter() + 10.0
        while t._timed_out and time.perf_counter() < deadline:
            time.sleep(0.05)
        assert not t._timed_out, "late response must clear the parked rid"
        assert worker.inflight == 0, "late response must not re-book done"
        t.invoke_timeout_s = 180.0
        resp, info = t.invoke("qa", request=req, extra=extra)   # still usable
        assert wk.unpack_plan_response(resp)["plans"]
        assert info.warm and worker.inflight == 0
    finally:
        t.close()


def test_timeout_with_worker_that_never_responds(built):
    """The never-responds flavour: counters rebalance at the drop even when
    no late response ever arrives, and close() doesn't hang on the worker."""
    ds, preds, index, _ = built
    t = _qa_process_transport(index, invoke_timeout_s=0.4)
    req = _qa_request(ds, preds)
    try:
        inv = t.submit("qa", request=req,
                       extra={"olo": 0, "ohi": 1, "sleep_s": 30.0})
        with pytest.raises(tp.TransportError, match="timed out"):
            inv.result()
        worker = t._workers["qa"][0]
        assert worker.inflight == 0 and worker.assigned == worker.done
        assert len(t._timed_out) == 1
    finally:
        t0 = time.perf_counter()
        t.close()
        assert time.perf_counter() - t0 < 10.0
    assert not t._timed_out, "close() clears parked rids"


def test_submit_racing_close_fails_fast(built):
    """Satellite regression: ``_closed`` was checked before the lock that
    registers the pending, so a submit racing close() could enqueue an
    invocation whose result() then blocked the full invoke timeout."""
    ds, preds, index, _ = built
    t = _qa_process_transport(index)
    req = _qa_request(ds, preds)
    t.close()
    t0 = time.perf_counter()
    with pytest.raises(tp.TransportError, match="closed"):
        t.submit("qa", request=req, extra={"olo": 0, "ohi": 1})
    assert time.perf_counter() - t0 < 1.0, "must fail fast, not time out"


def test_warm_accounting_survives_failed_first_request(built):
    """Satellite regression: ``served`` counted successes, so a container
    whose first request raised reported ``warm=False`` with
    ``state_hit=True`` on the retry — a cold start for a process that
    demonstrably retained its singleton."""
    ds, preds, index, _ = built
    t = _qa_process_transport(index)
    try:
        inv = t.submit("qa", request={"bogus": 1},
                       extra={"olo": 0, "ohi": 1})
        with pytest.raises(tp.TransportError, match="handler raised"):
            inv.result()
        resp, info = t.invoke("qa", request=_qa_request(ds, preds),
                              extra={"olo": 0, "ohi": 1})
        assert info.state_hit, "singleton built before the failure is kept"
        assert info.warm, "an attempt is warm evidence, success or not"
    finally:
        t.close()


# ----------------------------------------------------------- socket transport

def test_socket_transport_bitwise_parity(built, process_rt, socket_rt):
    """Acceptance: 4-way bitwise parity — jax reference, LocalTransport,
    ProcessTransport and SocketTransport all return identical ids/dists and
    aggregate SearchStats, with the socket fleet served over real TCP."""
    ds, preds, index, (ids_j, d_j, s_j) = built
    local = ServerlessRuntime(index, RuntimeConfig(branching=2, max_level=1))
    r_l = local.search(ds.queries, preds, k=10)
    r_p = process_rt.search(ds.queries, preds, k=10)
    r_s = socket_rt.search(ds.queries, preds, k=10)
    fin = np.isfinite(d_j)
    for r in (r_l, r_p, r_s):
        np.testing.assert_array_equal(r.ids, ids_j)
        np.testing.assert_array_equal(np.isfinite(r.dists), fin)
        np.testing.assert_array_equal(r.dists[fin], d_j[fin])
        assert r.stats == s_j
    assert r_s.trace.transport == "socket"
    assert r_s.trace.measured_makespan_s > 0
    served = [n for n in r_s.trace.nodes if n.kind in ("qa", "qp")]
    assert served and all(n.worker_host for n in served), (
        "every socket-served node records its host:port")
    assert r_s.trace.worker_hosts, "RunTrace aggregates the serving hosts"
    assert os.getpid() not in {n.worker_pid for n in served}, (
        "socket workers live in host processes, not the client")


def test_socket_real_warm_reuse(built, socket_rt):
    """Second batch over live TCP workers: zero rebuilds, every invocation a
    real warm start on the same hosts (retention lives in the connection)."""
    ds, preds, _, (ids_j, _, _) = built
    r1 = socket_rt.search(ds.queries, preds, k=10)
    hosts1 = {n.node: n.worker_host for n in r1.trace.nodes
              if n.kind == "qp"}
    r2 = socket_rt.search(ds.queries, preds, k=10)
    np.testing.assert_array_equal(r2.ids, ids_j)
    assert r2.trace.dre.s3_gets == 0
    qp = [n for n in r2.trace.nodes if n.kind == "qp"]
    assert all(n.warm and n.dre_hit and n.fetch_s == 0.0 for n in qp)
    assert {n.node: n.worker_host for n in qp} == hosts1, (
        "partition shards stay pinned to their hosts")


def test_socket_mid_flight_disconnect_reconnects(built):
    """Acceptance: sever a QP link while its invocation is in flight — the
    transport reconnects with backoff, re-sends under the retry budget, and
    the search result stays bitwise-identical with the retry in the trace."""
    ds, preds, index, (ids_j, _, s_j) = built
    rt = ServerlessRuntime(index, RuntimeConfig(
        branching=2, max_level=1, transport="socket", qa_workers=1,
        worker_sleep_s=0.6))
    try:
        rt.search(ds.queries, preds, k=10)              # warm the fleet
        dropper = threading.Timer(
            0.25, lambda: rt.transport.drop_connection("qp:0"))
        dropper.start()
        r = rt.search(ds.queries, preds, k=10)
        dropper.join()
    finally:
        rt.close()
    np.testing.assert_array_equal(r.ids, ids_j)
    assert r.stats == s_j
    assert r.trace.worker_retries >= 1
    qp0 = [n for n in r.trace.nodes if n.node == "qp:0"]
    assert any(not n.warm for n in qp0), (
        "a reconnected link is a fresh container: the re-served request "
        "must report a cold start")


def test_socket_busy_worker_not_declared_dead(built):
    """Heartbeat discrimination: compute far longer than the staleness
    window must NOT trip the hang guard — the host's receiver thread keeps
    answering PING while the compute thread is busy."""
    ds, preds, index, (ids_j, _, _) = built
    rt = ServerlessRuntime(index, RuntimeConfig(
        branching=2, max_level=1, transport="socket", qa_workers=1,
        worker_sleep_s=1.5, heartbeat_s=0.15))   # window ≈ 1.2 s < sleep
    try:
        r = rt.search(ds.queries, preds, k=10)
    finally:
        rt.close()
    np.testing.assert_array_equal(r.ids, ids_j)
    assert r.trace.worker_retries == 0, (
        "busy-but-alive links must not be torn down and retried")


def test_socket_remote_host_serves_qp_shards(built):
    """A genuinely separate server process (spawned via the CLI entrypoint,
    port scraped from its LISTENING line) serves the whole fleet: parity
    holds and every QA/QP invocation reports the server's pid and address."""
    ds, preds, index, (ids_j, _, s_j) = built
    repo_src = os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = repo_src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serverless.host",
         "--listen", "127.0.0.1:0"],
        stdout=subprocess.PIPE, text=True, env=env)
    try:
        line = proc.stdout.readline().strip()
        assert line.startswith("LISTENING "), f"unexpected banner: {line!r}"
        addr = f"127.0.0.1:{int(line.split()[1])}"
        rt = ServerlessRuntime(index, RuntimeConfig(
            branching=2, max_level=1, transport="socket", qa_workers=1,
            hosts=(addr,)))
        try:
            r = rt.search(ds.queries, preds, k=10)
        finally:
            rt.close()
        np.testing.assert_array_equal(r.ids, ids_j)
        assert r.stats == s_j
        served = [n for n in r.trace.nodes if n.kind in ("qa", "qp")]
        assert {n.worker_pid for n in served} == {proc.pid}
        assert {n.worker_host for n in served} == {addr}
        assert r.trace.worker_hosts == [addr]
    finally:
        proc.terminate()
        proc.wait(timeout=10)


def test_socket_frame_budget_enforced(built, socket_rt):
    """The per-frame byte budget is enforced at the socket layer itself, and
    an oversized invocation payload is rejected at submit before any byte
    hits the wire."""
    a, b = socket.socketpair()
    try:
        pl.write_frame(a, pl.FRAME_REQ, b"x" * 100, max_bytes=1000)
        kind, body = pl.read_frame(b)
        assert kind == pl.FRAME_REQ and body == b"x" * 100
        with pytest.raises(PayloadOverflowError):
            pl.write_frame(a, pl.FRAME_REQ, b"y" * 2000, max_bytes=1000)
    finally:
        a.close()
        b.close()
    transport = socket_rt.transport
    with pytest.raises(PayloadOverflowError):
        transport.submit(
            "qa", payload=b"z" * (transport.max_payload_bytes + 1),
            extra={"olo": 0, "ohi": 1})
