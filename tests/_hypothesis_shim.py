"""Deterministic stand-in for ``hypothesis`` when it is not installed.

Seven test modules use ``from hypothesis import given, settings, strategies``
for property-based sweeps. This container does not ship hypothesis, and the
tier-1 gate forbids installing it — so ``tests/conftest.py`` installs this shim
into ``sys.modules`` *only when the real library is absent*. When hypothesis
is available it is used untouched and this module is never imported.

The shim degrades property tests to fixed-example parametrization: each
``@given`` test is executed ``max_examples`` times (from ``@settings``, default
10) with arguments drawn from a ``numpy`` Generator seeded by the test's
qualified name — the same examples on every run, on every machine. Only the
strategy surface the suite actually uses is implemented: ``integers``,
``floats``, ``sampled_from``, ``booleans``, plus ``assume``.
"""

from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies", "assume", "install",
           "HealthCheck"]


class _Strategy:
    """Base class: a strategy is just a deterministic draw(rng) -> value."""

    def draw(self, rng: np.random.Generator):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(2 ** 31) if min_value is None else int(min_value)
        self.hi = 2 ** 31 - 1 if max_value is None else int(max_value)

    def draw(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(_Strategy):
    def __init__(self, min_value=None, max_value=None, **_kwargs):
        self.lo = -1e6 if min_value is None else float(min_value)
        self.hi = 1e6 if max_value is None else float(max_value)

    def draw(self, rng):
        return float(rng.uniform(self.lo, self.hi))


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty collection")

    def draw(self, rng):
        return self.elements[int(rng.integers(0, len(self.elements)))]


class _Booleans(_Strategy):
    def draw(self, rng):
        return bool(rng.integers(0, 2))


class _AssumptionFailed(Exception):
    """Raised by assume(False); the example is skipped, not failed."""


def assume(condition) -> bool:
    if not condition:
        raise _AssumptionFailed()
    return True


class HealthCheck:
    """API-compat placeholder (the shim enforces no health checks)."""

    all = classmethod(lambda cls: [])
    too_slow = data_too_large = filter_too_much = None


_DEFAULT_MAX_EXAMPLES = 10


class settings:  # noqa: N801 — mirrors the hypothesis API name
    """Decorator recording run parameters; composes with @given in any order."""

    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_kwargs):
        self.max_examples = int(max_examples)
        self.deadline = deadline

    def __call__(self, fn):
        fn._shim_settings = self
        return fn


def _seed_for(name: str) -> int:
    return zlib.crc32(name.encode("utf-8")) & 0x7FFFFFFF


def given(*arg_strategies, **kw_strategies):
    """Run the test on a fixed, seed-deterministic batch of drawn examples."""
    if arg_strategies:
        raise TypeError("the shim supports keyword strategies only "
                        "(matching this repo's test suite)")

    def decorate(fn):
        inner_settings = getattr(fn, "_shim_settings", None)

        @functools.wraps(fn)
        def wrapper():
            cfg = (getattr(wrapper, "_shim_settings", None)
                   or inner_settings or settings())
            rng = np.random.default_rng(
                _seed_for(f"{fn.__module__}.{fn.__qualname__}"))
            ran = 0
            attempts = 0
            while ran < cfg.max_examples and attempts < cfg.max_examples * 50:
                attempts += 1
                example = {k: s.draw(rng) for k, s in kw_strategies.items()}
                try:
                    fn(**example)
                except _AssumptionFailed:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({fn.__qualname__}): {example}"
                    ) from e
                ran += 1

        # Hide the strategy parameters from pytest's fixture resolution.
        wrapper.__signature__ = inspect.Signature()
        wrapper.hypothesis_shim = True
        return wrapper

    return decorate


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _Integers
strategies.floats = _Floats
strategies.sampled_from = _SampledFrom
strategies.booleans = _Booleans
strategies.SearchStrategy = _Strategy


def install() -> None:
    """Register the shim as ``hypothesis`` in sys.modules (idempotent)."""
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.assume = assume
    mod.HealthCheck = HealthCheck
    mod.__shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
