"""Targeted regression tests for the races squashlint's rollout surfaced.

Each test pins one concrete fix (see DESIGN.md "Static invariants"):

* ``Gauge.inc`` lost updates (read-modify-write with no lock);
* ``Histogram`` dump methods read mutating state without the lock;
* both transports' ``close()`` used a non-atomic check-and-set of
  ``_closed``, so two racing closers double-sent SHUTDOWN;
* ``ProcessTransport._send`` marked ``sent`` without re-checking routing,
  stranding an invocation re-routed by the failure path mid-send;
* ``SocketTransport._on_response`` reassembled response pages outside the
  transport lock, racing the failure path's ``pages.clear()``.

The transport tests run against stub workers/links (no processes spawned),
so this module stays in tier 1.
"""

import threading

import numpy as np

from repro.obs.metrics import Counter, Gauge, Histogram
from repro.serverless import payload as pl
from repro.serverless import transport as tp
from repro.serverless import workers as wk
from repro.serverless.socket_transport import SocketTransport, _Link


THREADS = 8
INCS = 5000


def hammer(fn):
    barrier = threading.Barrier(THREADS)

    def run():
        barrier.wait()
        for _ in range(INCS):
            fn()

    ts = [threading.Thread(target=run) for _ in range(THREADS)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()


# ------------------------------------------------------------------ metrics

def test_gauge_inc_is_atomic():
    g = Gauge("g")
    hammer(lambda: g.inc(1))
    assert g.value == THREADS * INCS


def test_counter_inc_is_atomic():
    c = Counter("c")
    hammer(lambda: c.inc(1))
    assert c.value == THREADS * INCS


def test_histogram_dump_is_consistent_snapshot():
    """buckets must always sum to count, even mid-hammer."""
    h = Histogram("h", buckets=(1.0, 2.0, 4.0))
    stop = threading.Event()
    bad = []

    def snapshot_loop():
        while not stop.is_set():
            total = sum(h.bucket_counts().values())
            count = h.count
            # count was read *after* the bucket snapshot, so it can only
            # have grown — never the reverse.
            if total > count:
                bad.append((total, count))

    snap = threading.Thread(target=snapshot_loop)
    snap.start()
    try:
        hammer(lambda: h.observe(1.5))
    finally:
        stop.set()
        snap.join()
    assert not bad, f"inconsistent snapshots: {bad[:3]}"
    assert h.count == THREADS * INCS
    assert sum(h.bucket_counts().values()) == THREADS * INCS


def test_histogram_bucket_counts_blocks_on_lock():
    """White-box: the dump path takes the instrument lock (the fix)."""
    h = Histogram("h", buckets=(1.0,))
    h.observe(0.5)
    got = []
    with h._lock:
        t = threading.Thread(target=lambda: got.append(h.bucket_counts()))
        t.start()
        t.join(timeout=0.2)
        assert not got, "bucket_counts() read state without the lock"
    t.join(timeout=2.0)
    assert got and sum(got[0].values()) == 1


# ------------------------------------------------- ProcessTransport stubs

class _StubConn:
    """Pipe-end stand-in recording sends; optional per-send side effect."""

    def __init__(self, side_effect=None):
        self.sent = []
        self.side_effect = side_effect
        self.closed = False

    def send(self, msg):
        self.sent.append(msg)
        if self.side_effect is not None:
            self.side_effect(msg)

    def close(self):
        self.closed = True


class _StubProc:
    def __init__(self):
        self.terminated = False

    def join(self, timeout=None):
        pass

    def is_alive(self):
        return False

    def terminate(self):
        self.terminated = True


def _stub_worker(fn="qp:0", side_effect=None):
    w = object.__new__(tp._Worker)
    w.req_conn = _StubConn(side_effect)
    w.resp_conn = _StubConn()
    w.proc = _StubProc()
    w.fn = fn
    w.assigned = 0
    w.done = 0
    w.dead = False
    w.send_lock = threading.Lock()
    return w


def _stub_process_transport(workers):
    t = object.__new__(tp.ProcessTransport)
    t.eager = True
    t.invoke_timeout_s = 5.0
    t.max_retries = 2
    t._lock = threading.Lock()
    t._pending = {}
    t._timed_out = {}
    t._dead_births = {}
    t._respawning = {}
    t._closed = False
    t._workers = {"qp:0": list(workers)}
    t._collector = threading.Thread(target=lambda: None)
    t._collector.start()
    t._collector.join()
    return t


def test_process_close_is_atomic_under_racing_closers():
    workers = [_stub_worker() for _ in range(3)]
    t = _stub_process_transport(workers)
    barrier = threading.Barrier(4)

    def closer():
        barrier.wait()
        t.close()

    ts = [threading.Thread(target=closer) for _ in range(4)]
    for th in ts:
        th.start()
    for th in ts:
        th.join()
    for w in workers:
        shutdowns = [m for m in w.req_conn.sent if m is wk.SHUTDOWN]
        assert len(shutdowns) == 1, "racing close() double-sent SHUTDOWN"


def test_send_rechecks_routing_before_marking_sent():
    """A pending re-routed mid-send must still reach its new worker.

    The failure path re-routes an *unsent* pending and expects the _send
    loop that owns it to deliver; marking ``sent`` without re-checking the
    routing stranded the invocation until its timeout.
    """
    replacement = _stub_worker("qp:0")
    pending = tp._Pending(0, "qp:0", b"payload", {})

    def reroute(_msg):
        # Simulates _on_worker_failure landing between the pipe write and
        # the sent-flag commit: the pending now belongs to `replacement`.
        pending.worker = replacement
        replacement.assigned += 1

    original = _stub_worker("qp:0", side_effect=reroute)
    t = _stub_process_transport([original, replacement])
    pending.worker = original
    original.assigned += 1
    t._pending[0] = pending

    t._send(pending)

    assert pending.sent
    assert len(replacement.req_conn.sent) == 1, \
        "re-routed pending never delivered to its replacement worker"


# --------------------------------------------------- SocketTransport stubs

def _stub_socket_transport():
    t = object.__new__(SocketTransport)
    t._lock = threading.Lock()
    t._pending = {}
    t._timed_out = {}
    t._closed = False
    return t


def _stub_link():
    link = object.__new__(_Link)
    link.fn = "qp:0"
    link.address = ("127.0.0.1", 1)
    link.assigned = 0
    link.done = 0
    link.dead = False
    link.generation = 0
    link.pages = {}
    return link


def _resp_body(rid, seq, nseq, data=b"x"):
    return pl.encode_message({
        "rid": rid, "ok": True, "seq": seq, "nseq": nseq,
        "info": {"os_pid": 0},
        "data": np.frombuffer(data, dtype=np.uint8),
    })


def test_on_response_reassembles_under_transport_lock():
    """White-box: page reassembly holds _lock (the fix), so the failure
    path's ``pages.clear()`` can never interleave with it."""
    t = _stub_socket_transport()
    link = _stub_link()
    done = []
    with t._lock:
        th = threading.Thread(target=lambda: done.append(
            t._on_response(link, _resp_body(7, 0, 2))))
        th.start()
        th.join(timeout=0.2)
        assert not done, "_on_response touched link.pages without _lock"
    th.join(timeout=2.0)
    assert done
    assert 7 in link.pages                    # first page parked, incomplete


def test_on_response_survives_concurrent_page_clear():
    """Hammer reassembly against the failure path's pages.clear()."""
    t = _stub_socket_transport()
    link = _stub_link()
    errors = []
    stop = threading.Event()

    def clear_loop():
        while not stop.is_set():
            with t._lock:
                link.pages.clear()

    clearer = threading.Thread(target=clear_loop)
    clearer.start()
    try:
        for rid in range(300):
            try:
                t._on_response(link, _resp_body(rid, 0, 2))
                t._on_response(link, _resp_body(rid, 1, 2))
            except Exception as exc:          # noqa: BLE001
                errors.append(exc)
                break
    finally:
        stop.set()
        clearer.join()
    assert not errors, f"page reassembly raced the clear: {errors[0]!r}"


class _StubSock:
    def __init__(self):
        self.frames = []
        self.closed = False

    def sendall(self, data):
        self.frames.append(bytes(data))

    def close(self):
        self.closed = True


def test_socket_close_is_atomic_under_racing_closers():
    t = _stub_socket_transport()
    links = []
    for _ in range(3):
        link = _stub_link()
        link.send_lock = threading.Lock()
        link.sock = _StubSock()
        links.append(link)
    t._links = {"qp:0": links}
    t._owned_hosts = []
    t._monitor = None
    barrier = threading.Barrier(4)

    def closer():
        barrier.wait()
        t.close()

    ts = [threading.Thread(target=closer) for _ in range(4)]
    for th in ts:
        th.start()
    for th in ts:
        th.join()
    assert t._closed
    for link in links:
        assert link.sock.closed
        shutdowns = [f for f in link.sock.frames
                     if f[:1] == pl.FRAME_SHUTDOWN]
        assert len(shutdowns) == 1, "racing close() double-sent SHUTDOWN"
