"""Segment packing / dimensional extraction tests (paper §2.2.1–2.2.2, Fig. 3)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import segments


def _random_codes(bits, n, seed=0):
    rng = np.random.default_rng(seed)
    return np.stack(
        [rng.integers(0, 1 << b, size=n) if b else np.zeros(n, np.int64)
         for b in bits],
        axis=1,
    )


def test_paper_fig3_style_layout():
    # Dims spanning segments, as in the paper's Fig. 3 (S=8, D2 split 3+2).
    bits = [4, 5, 3, 6]
    layout = segments.build_layout(bits, seg_bits=8)
    assert layout.total_bits == 18
    assert layout.num_segments == 3
    # D2 (index 1) starts at bit 4 and spans segments 0 and 1 (4 + 1 bits).
    plan = layout.plans[1]
    assert [p.seg for p in plan] == [0, 1]
    assert sum(p.nbits for p in plan) == 5


def test_segment_count_matches_paper_formula():
    # Illustrative example from §2.2.1: d=128, S=8, b=512 ⇒ G_OSQ=64, G_SQ=128.
    bits = [4] * 128
    layout = segments.build_layout(bits, seg_bits=8)
    assert layout.num_segments == 64
    w = segments.sq_wastage(bits, seg_bits=8)
    assert w["segments_osq"] == 64 and w["segments_sq"] == 128
    assert w["saving_ratio"] == 2.0


def test_pack_extract_roundtrip_s8():
    bits = [3, 5, 1, 8, 2, 9, 0, 4]
    layout = segments.build_layout(bits, seg_bits=8)
    codes = _random_codes(bits, 257)
    packed = segments.pack_codes(layout, codes)
    assert packed.dtype == np.uint8
    out = np.asarray(segments.extract_all(packed, layout))
    np.testing.assert_array_equal(out, codes)


def test_pack_extract_roundtrip_s32():
    bits = [7, 12, 3, 11, 1, 6]
    layout = segments.build_layout(bits, seg_bits=32)
    codes = _random_codes(bits, 100, seed=3)
    packed = segments.pack_codes(layout, codes)
    assert packed.dtype == np.uint32
    out = np.asarray(segments.extract_all(packed, layout))
    np.testing.assert_array_equal(out, codes)


def test_extract_single_dim_matches():
    bits = [5, 5, 6]
    layout = segments.build_layout(bits, seg_bits=8)
    codes = _random_codes(bits, 64, seed=1)
    packed = segments.pack_codes(layout, codes)
    for j in range(3):
        np.testing.assert_array_equal(
            np.asarray(segments.extract_dim(packed, layout, j)), codes[:, j]
        )


def test_over_segment_dimension():
    """Paper: a 9-bit dim packs fine with S=8 (the whole point of OSQ)."""
    bits = [9, 9, 9]
    layout = segments.build_layout(bits, seg_bits=8)
    assert layout.num_segments == 4  # ceil(27/8)
    codes = _random_codes(bits, 50, seed=2)
    packed = segments.pack_codes(layout, codes)
    out = np.asarray(segments.extract_all(packed, layout))
    np.testing.assert_array_equal(out, codes)


@given(
    seed=st.integers(0, 2**31 - 1),
    seg_bits=st.sampled_from([8, 16, 32]),
    d=st.integers(1, 20),
)
@settings(max_examples=25, deadline=None)
def test_roundtrip_property(seed, seg_bits, d):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 13, size=d).tolist()
    if sum(bits) == 0:
        bits[0] = 1
    layout = segments.build_layout(bits, seg_bits=seg_bits)
    codes = _random_codes(bits, 33, seed=seed)
    packed = segments.pack_codes(layout, codes)
    out = np.asarray(segments.extract_all(packed, layout))
    np.testing.assert_array_equal(out, codes)
    # OSQ is storage-optimal: wastage < one segment.
    assert layout.num_segments * seg_bits - layout.total_bits < seg_bits
